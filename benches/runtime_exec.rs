//! PJRT runtime benchmarks: per-program execution latency of the AOT
//! artifacts (requires `make artifacts`; skips gracefully otherwise).

use kfac::backend::{ModelBackend, PjrtBackend};
use kfac::bench::{bench, default_budget};
use kfac::linalg::Mat;
use kfac::rng::Rng;
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("runtime_exec: no artifacts/ — run `make artifacts` first; skipping");
        return;
    }
    let budget = default_budget();
    for name in ["tiny_ae", "mnist_ae"] {
        let mut backend = match PjrtBackend::new(&dir, name) {
            Ok(b) => b,
            Err(e) => {
                println!("skipping {name}: {e:#}");
                continue;
            }
        };
        let arch = backend.arch().clone();
        let mut rng = Rng::new(1);
        let params = arch.glorot_init(&mut rng);
        let c = backend.chunk_size();
        let x = Mat::randn(c, arch.widths[0], 0.5, &mut rng);
        let y = Mat::from_fn(c, *arch.widths.last().unwrap(), |_, _| rng.bernoulli(0.3));

        bench(&format!("pjrt_{name}_fwd_loss_chunk{c}"), budget, || {
            std::hint::black_box(backend.loss(&params, &x, &y));
        });
        bench(&format!("pjrt_{name}_grad_chunk{c}"), budget, || {
            std::hint::black_box(backend.grad(&params, &x, &y));
        });
        bench(&format!("pjrt_{name}_grad_stats_chunk{c}"), budget, || {
            std::hint::black_box(backend.grad_and_stats(&params, &x, &y, c, 7));
        });
        let v = arch.glorot_init(&mut rng);
        let u = arch.glorot_init(&mut rng);
        bench(&format!("pjrt_{name}_fvp2_chunk{c}"), budget, || {
            std::hint::black_box(backend.fvp_quad(&params, &x, c, &[&v, &u]));
        });
    }
}
