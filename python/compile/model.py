"""Layer-2 JAX model: the paper's feed-forward networks with manual
forward/backward (Algorithm 1), Fisher-factor statistics (Section 5)
and exact-Fisher quadratic forms (Appendix C), built on the Pallas
kernels and lowered AOT per architecture by ``aot.py``.

Conventions (mirroring the Rust `nn` module exactly):

- batches are row-major (`[m, d]`, one case per row);
- homogeneous coordinates: `abar = [a, 1]`, bias = last column of `W`;
- the output nonlinearity lives in the loss (`z` = natural parameters);
- every program output is a **weighted sum** over the per-row 0/1 mask
  `w`, so the Rust coordinator can chunk arbitrary mini-batches through
  fixed-shape executables exactly.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp

from . import prng
from .kernels import cov as kcov
from .kernels import linear as klinear
from .kernels import matmul as kmatmul


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """Architecture + lowering metadata for one model variant."""

    name: str
    widths: Tuple[int, ...]
    acts: Tuple[str, ...]  # one per layer; last must be "identity"
    loss: str  # sigmoid_ce | softmax_ce | squared_error
    chunk: int  # rows per compiled executable

    def __post_init__(self):
        assert len(self.widths) == len(self.acts) + 1
        assert self.acts[-1] == "identity"
        assert self.loss in ("sigmoid_ce", "softmax_ce", "squared_error")

    @property
    def num_layers(self) -> int:
        return len(self.acts)

    def weight_shapes(self) -> List[Tuple[int, int]]:
        return [
            (self.widths[i + 1], self.widths[i] + 1)
            for i in range(self.num_layers)
        ]

    def manifest_entry(self, programs: Dict[str, str]) -> dict:
        return {
            "name": self.name,
            "widths": list(self.widths),
            "acts": list(self.acts),
            "loss": self.loss,
            "chunk": self.chunk,
            "programs": programs,
        }


# ---------------------------------------------------------------------------
# forward / backward (Algorithm 1)
# ---------------------------------------------------------------------------


def _append_ones(a):
    return jnp.concatenate([a, jnp.ones((a.shape[0], 1), jnp.float32)], axis=1)


def _act_deriv(name, s, a):
    if name == "tanh":
        return 1.0 - a * a
    if name == "logistic":
        return a * (1.0 - a)
    if name == "relu":
        return (s > 0.0).astype(jnp.float32)
    return jnp.ones_like(s)


def forward(md: ModelDef, params, x):
    """Returns (abars, ss): `abars[i]` feeds layer i; `z = ss[-1]`."""
    abars, ss = [_append_ones(x)], []
    for i in range(md.num_layers):
        s = kmatmul.matmul_nt(abars[i], params[i])  # pre-activations
        ss.append(s)
        if i + 1 < md.num_layers:
            act = klinear.act_fn(md.acts[i])
            a = act(s) if act is not None else s
            abars.append(_append_ones(a))
    return abars, ss


def backward(md: ModelDef, params, abars, ss, dz):
    """Per-case pre-activation derivatives `gs` from output derivs `dz`."""
    l = md.num_layers
    gs = [None] * l
    gs[l - 1] = dz
    for i in reversed(range(l - 1)):
        da = kmatmul.matmul(gs[i + 1], params[i + 1][:, :-1])
        act = klinear.act_fn(md.acts[i])
        a = act(ss[i]) if act is not None else ss[i]
        gs[i] = da * _act_deriv(md.acts[i], ss[i], a)
    return gs


def grad_sums(md: ModelDef, abars, gs):
    """Weight-gradient **sums** `dW_i = g_i^T abar_{i-1}` (mask folded
    into `gs` upstream)."""
    return [kmatmul.matmul_tn(gs[i], abars[i]) for i in range(md.num_layers)]


# ---------------------------------------------------------------------------
# losses (natural-parameter exp-family; per-case values)
# ---------------------------------------------------------------------------


def predict(md: ModelDef, z):
    if md.loss == "sigmoid_ce":
        return 1.0 / (1.0 + jnp.exp(-z))
    if md.loss == "softmax_ce":
        zm = z - jnp.max(z, axis=1, keepdims=True)
        e = jnp.exp(zm)
        return e / jnp.sum(e, axis=1, keepdims=True)
    return z


def per_case_loss(md: ModelDef, z, y):
    if md.loss == "sigmoid_ce":
        sp = jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z)))
        return jnp.sum(sp - y * z, axis=1)
    if md.loss == "softmax_ce":
        zm = z - jnp.max(z, axis=1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(zm), axis=1, keepdims=True)) - zm
        return jnp.sum(y * lse, axis=1)
    return 0.5 * jnp.sum((z - y) ** 2, axis=1)


def per_case_error(md: ModelDef, z, y):
    if md.loss == "softmax_ce":
        return (jnp.argmax(z, axis=1) != jnp.argmax(y, axis=1)).astype(jnp.float32)
    p = predict(md, z)
    return jnp.sum((p - y) ** 2, axis=1)


def sample_targets(md: ModelDef, z, seed):
    """Targets from the model's predictive distribution (Section 5)."""
    p = predict(md, z)
    if md.loss == "sigmoid_ce":
        return prng.bernoulli(seed, p, stream=1)
    if md.loss == "softmax_ce":
        return prng.categorical_onehot(seed, z, stream=1)
    return z + prng.normal(seed, z.shape, stream=1)


def fr_quad_sum(md: ModelDef, z, jz1, jz2, w):
    """Σ_cases w · jz1^T F_R(z) jz2 (Appendix C inner products)."""
    if md.loss == "squared_error":
        return jnp.sum(w[:, None] * jz1 * jz2)
    p = predict(md, z)
    if md.loss == "sigmoid_ce":
        return jnp.sum(w[:, None] * p * (1.0 - p) * jz1 * jz2)
    sab = jnp.sum(p * jz1 * jz2, axis=1)
    sa = jnp.sum(p * jz1, axis=1)
    sb = jnp.sum(p * jz2, axis=1)
    return jnp.sum(w * (sab - sa * sb))


# ---------------------------------------------------------------------------
# the four AOT programs (see rust/src/backend/pjrt.rs for the contract)
# ---------------------------------------------------------------------------


def make_fwd_loss(md: ModelDef):
    def fwd_loss(*args):
        l = md.num_layers
        params, (x, y, w) = list(args[:l]), args[l:]
        _, ss = forward(md, params, x)
        z = ss[-1]
        return (
            jnp.sum(w * per_case_loss(md, z, y)),
            jnp.sum(w * per_case_error(md, z, y)),
        )

    return fwd_loss


def make_grad(md: ModelDef):
    def grad(*args):
        l = md.num_layers
        params, (x, y, w) = list(args[:l]), args[l:]
        abars, ss = forward(md, params, x)
        z = ss[-1]
        dz = (predict(md, z) - y) * w[:, None]
        gs = backward(md, params, abars, ss, dz)
        dws = grad_sums(md, abars, gs)
        return (
            jnp.sum(w * per_case_loss(md, z, y)),
            jnp.sum(w * per_case_error(md, z, y)),
            *dws,
        )

    return grad


def make_grad_stats(md: ModelDef):
    def grad_stats(*args):
        l = md.num_layers
        params, (x, y, w, seed) = list(args[:l]), args[l:]
        abars, ss = forward(md, params, x)
        z = ss[-1]
        # supervised gradient (mask folded into dz)
        dz = (predict(md, z) - y) * w[:, None]
        gs = backward(md, params, abars, ss, dz)
        dws = grad_sums(md, abars, gs)
        # Fisher statistics: extra backward pass with sampled targets
        ys = sample_targets(md, z, seed)
        dz_s = (predict(md, z) - ys) * w[:, None]
        gs_s = backward(md, params, abars, ss, dz_s)
        aa = [kcov.cov(abars[i], abars[i], w) for i in range(l)]
        aa_off = [kcov.cov(abars[i], abars[i + 1], w) for i in range(l - 1)]
        # gs_s already carries one factor of w (w²=w for 0/1 masks)
        ones = jnp.ones_like(w)
        gg = [kcov.cov(gs_s[i], gs_s[i], ones) for i in range(l)]
        gg_off = [kcov.cov(gs_s[i], gs_s[i + 1], ones) for i in range(l - 1)]
        return (
            jnp.sum(w * per_case_loss(md, z, y)),
            jnp.sum(w * per_case_error(md, z, y)),
            *dws,
            *aa,
            *aa_off,
            *gg,
            *gg_off,
        )

    return grad_stats


def _jvp_z(md: ModelDef, params, abars, ss, dirs):
    """Linearized forward pass: dz/dθ · v from cached activations."""
    l = md.num_layers
    jabar = jnp.zeros_like(abars[0])
    jz = None
    for i in range(l):
        js = kmatmul.matmul_nt(abars[i], dirs[i]) + kmatmul.matmul_nt(
            jabar, params[i]
        )
        if i + 1 < l:
            act = klinear.act_fn(md.acts[i])
            a = act(ss[i]) if act is not None else ss[i]
            ja = js * _act_deriv(md.acts[i], ss[i], a)
            jabar = jnp.concatenate(
                [ja, jnp.zeros((ja.shape[0], 1), jnp.float32)], axis=1
            )
        else:
            jz = js
    return jz


def make_fvp2(md: ModelDef):
    def fvp2(*args):
        l = md.num_layers
        params = list(args[:l])
        x, w = args[l], args[l + 1]
        v = list(args[l + 2 : l + 2 + l])
        u = list(args[l + 2 + l : l + 2 + 2 * l])
        abars, ss = forward(md, params, x)
        z = ss[-1]
        jzv = _jvp_z(md, params, abars, ss, v)
        jzu = _jvp_z(md, params, abars, ss, u)
        return (
            fr_quad_sum(md, z, jzv, jzv, w),
            fr_quad_sum(md, z, jzv, jzu, w),
            fr_quad_sum(md, z, jzu, jzu, w),
        )

    return fvp2


def make_precond(md: ModelDef, layer: int):
    """Standalone per-layer preconditioner program (L1 showcase; the
    Rust coordinator can offload `Ginv V Ainv` for its widest layer)."""
    from .kernels import precond as kprecond

    def precond(ginv, v, ainv):
        return (kprecond.kron_apply(ginv, v, ainv),)

    return precond


# ---------------------------------------------------------------------------
# example-input builders (for jax.jit(...).lower)
# ---------------------------------------------------------------------------


def _f32(shape):
    import jax

    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32():
    import jax

    return jax.ShapeDtypeStruct((), jnp.int32)


def program_specs(md: ModelDef):
    """(program name -> (fn, example arg specs)) for AOT lowering."""
    c = md.chunk
    d0, dl = md.widths[0], md.widths[-1]
    ws = [_f32(s) for s in md.weight_shapes()]
    x, y, w = _f32((c, d0)), _f32((c, dl)), _f32((c,))
    specs = {
        "fwd_loss": (make_fwd_loss(md), [*ws, x, y, w]),
        "grad": (make_grad(md), [*ws, x, y, w]),
        "grad_stats": (make_grad_stats(md), [*ws, x, y, w, _i32()]),
        "fvp2": (make_fvp2(md), [*ws, x, w, *ws, *ws]),
    }
    # preconditioner for the widest layer (a pure-L1 program)
    widest = max(range(md.num_layers), key=lambda i: md.widths[i + 1])
    (r, cc) = md.weight_shapes()[widest]
    specs["precond"] = (
        make_precond(md, widest),
        [_f32((r, r)), _f32((r, cc)), _f32((cc, cc))],
    )
    return specs


# ---------------------------------------------------------------------------
# model registry (must stay in sync with rust Problem::arch!)
# ---------------------------------------------------------------------------


def _ae(name, widths, chunk, loss="sigmoid_ce"):
    acts = tuple(["tanh"] * (len(widths) - 2) + ["identity"])
    return ModelDef(name, tuple(widths), acts, loss, chunk)


REGISTRY: List[ModelDef] = [
    _ae("mnist_ae", [784, 400, 200, 100, 30, 100, 200, 400, 784], 250),
    _ae(
        "curves_ae",
        [784, 200, 100, 50, 25, 12, 6, 12, 25, 50, 100, 200, 784],
        250,
    ),
    _ae(
        "faces_ae",
        [625, 500, 250, 125, 30, 125, 250, 500, 625],
        250,
        loss="squared_error",
    ),
    ModelDef(
        "mnist_clf",
        (256, 20, 20, 20, 20, 10),
        ("tanh", "tanh", "tanh", "tanh", "identity"),
        "softmax_ce",
        250,
    ),
    # tiny variants for tests / fast smoke runs
    _ae("tiny_ae", [8, 5, 3, 5, 8], 16),
    ModelDef("tiny_clf", (6, 5, 4), ("tanh", "identity"), "softmax_ce", 8),
]


def by_name(name: str) -> ModelDef:
    for md in REGISTRY:
        if md.name == name:
            return md
    raise KeyError(name)
