//! Section 8 — the per-iteration cost model. Measures the wall-clock of
//! each "task" (1–8 in the paper) on a mid-sized autoencoder and prints
//! the K-FAC/SGD per-iteration cost ratio, amortized with the paper's
//! schedule constants (τ₁ = 1/8, τ₂ = 1/4, T₁ = 5, T₂ = 20, T₃ = 20).
//!
//! The paper's claim to reproduce: a K-FAC iteration costs only a small
//! constant factor (~2–3.5×) more than an SGD iteration once the
//! inverse refresh is amortized.

use kfac::backend::{ModelBackend, RustBackend};
use kfac::bench::Timer;
use kfac::data::mnist_like;
use kfac::experiments::{results_dir, scaled};
use kfac::fisher::stats::{KfacStats, RawStats};
use kfac::fisher::{BlockDiagInverse, FisherInverse, TridiagInverse};
use kfac::nn::{Act, Arch};
use kfac::rng::Rng;
use kfac::util::write_csv;

fn time_it(reps: usize, mut f: impl FnMut()) -> f64 {
    // one warmup + median of reps
    f();
    let mut ts = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        ts.push(t.elapsed_s());
    }
    kfac::util::median(&ts)
}

fn main() {
    println!("== Section 8: per-task cost model ==");
    let arch = Arch::autoencoder(&[256, 100, 40, 12, 40, 100, 256], Act::Tanh);
    let m = scaled(1000, 250);
    let ds = mnist_like::autoencoder_dataset(m, 16, 0);
    let mut backend = RustBackend::new(arch.clone());
    let mut rng = Rng::new(1);
    let params = arch.sparse_init(&mut rng);
    let (x, y) = (ds.x.clone(), ds.y.clone());
    println!("# arch {:?}, m = {m}", arch.widths);

    let tau1 = 1.0 / 8.0;
    let tau2 = 1.0 / 4.0;
    let (t1, t2, t3) = (5.0, 20.0, 20.0);
    let s1 = ((tau1 * m as f64).ceil() as usize).max(1);
    let s2 = ((tau2 * m as f64).ceil() as usize).max(1);

    // tasks 1+2: gradient computation (fwd+bwd+outer products) = 1 SGD step's compute
    let t_grad = time_it(5, || {
        let _ = backend.grad(&params, &x, &y);
    });
    // tasks 3+4: extra sampled-target backward + statistics (on τ₁m rows)
    let t_gradstats = time_it(5, || {
        let _ = backend.grad_and_stats(&params, &x, &y, s1, 7);
    });
    let t_stats = (t_gradstats - t_grad).max(0.0);

    // build EMA'd stats for the inverse tasks
    let (_, _, raw) = backend.grad_and_stats(&params, &x, &y, s1, 7);
    let mut stats = KfacStats::new(&arch);
    stats.update(&raw);
    let gamma = 1.0;

    // task 5: inverse refresh
    let t_inv_bd = time_it(3, || {
        let _ = BlockDiagInverse::build(&stats.s, gamma);
    });
    let t_inv_tri = time_it(3, || {
        let _ = TridiagInverse::build(&stats.s, gamma);
    });

    // task 6: preconditioner application
    let inv_bd = BlockDiagInverse::build(&stats.s, gamma);
    let inv_tri = TridiagInverse::build(&stats.s, gamma);
    let (_, grad) = backend.grad(&params, &x, &y);
    let t_apply_bd = time_it(10, || {
        let _ = inv_bd.apply(&grad);
    });
    let t_apply_tri = time_it(10, || {
        let _ = inv_tri.apply(&grad);
    });

    // task 7: FVP scalars on τ₂m rows (2 directions, momentum case)
    let d2 = grad.scale(0.5);
    let t_fvp = time_it(5, || {
        let _ = backend.fvp_quad(&params, &x, s2, &[&grad, &d2]);
    });

    // task 8: extra forward pass for ρ (every T₁ iterations)
    let t_fwd = time_it(5, || {
        let _ = backend.loss(&params, &x, &y);
    });

    println!("\nper-task wall-clock (median):");
    println!("  1+2  gradient (≡ SGD step compute)        {:>9.1} ms", t_grad * 1e3);
    println!("  3+4  sampled bwd + stats (τ₁m rows)       {:>9.1} ms", t_stats * 1e3);
    println!("  5    inverse refresh  blkdiag             {:>9.1} ms", t_inv_bd * 1e3);
    println!("  5    inverse refresh  blktridiag          {:>9.1} ms", t_inv_tri * 1e3);
    println!("  6    precondition     blkdiag             {:>9.1} ms", t_apply_bd * 1e3);
    println!("  6    precondition     blktridiag          {:>9.1} ms", t_apply_tri * 1e3);
    println!("  7    FVP scalars (τ₂m rows, 2 dirs)       {:>9.1} ms", t_fvp * 1e3);
    println!("  8    extra forward (ρ)                    {:>9.1} ms", t_fwd * 1e3);

    // amortized per-iteration cost (γ adjustment triples tasks 5+6+7 on
    // every T₂-th iteration → factor (1 + 2/T₂) on those tasks)
    let g_adj = 1.0 + 2.0 / t2;
    let amort = |kind: &str| -> f64 {
        let (t_inv, t_apply) =
            if kind == "tri" { (t_inv_tri, t_apply_tri) } else { (t_inv_bd, t_apply_bd) };
        t_grad + t_stats + g_adj * (t_inv / t3 + t_apply + t_fvp) + t_fwd / t1
    };
    let kfac_bd = amort("bd");
    let kfac_tri = amort("tri");
    println!("\namortized per-iteration cost (τ₁=1/8, τ₂=1/4, T₁=5, T₂=20, T₃=20):");
    println!("  SGD                {:>9.1} ms   (1.00×)", t_grad * 1e3);
    println!("  K-FAC blkdiag      {:>9.1} ms   ({:.2}×)", kfac_bd * 1e3, kfac_bd / t_grad);
    println!("  K-FAC blktridiag   {:>9.1} ms   ({:.2}×)", kfac_tri * 1e3, kfac_tri / t_grad);
    println!("(paper model: K-FAC ≈ 2–3.5× the SGD iteration; tridiag > blkdiag)");

    assert!(kfac_tri >= kfac_bd * 0.9, "tridiag should not be cheaper than blkdiag");
    assert!(
        kfac_bd / t_grad < 20.0,
        "amortized K-FAC overhead implausibly large: {:.1}×",
        kfac_bd / t_grad
    );

    let path = results_dir().join("sec8_cost.csv");
    write_csv(
        &path,
        &["task", "ms"],
        &[
            vec![1.0, t_grad * 1e3],
            vec![3.0, t_stats * 1e3],
            vec![5.0, t_inv_bd * 1e3],
            vec![5.5, t_inv_tri * 1e3],
            vec![6.0, t_apply_bd * 1e3],
            vec![6.5, t_apply_tri * 1e3],
            vec![7.0, t_fvp * 1e3],
            vec![8.0, t_fwd * 1e3],
            vec![100.0, kfac_bd / t_grad],
            vec![101.0, kfac_tri / t_grad],
        ],
    )
    .unwrap();
    println!("wrote {}", path.display());
}
