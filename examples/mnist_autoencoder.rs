//! END-TO-END driver: the paper's MNIST deep-autoencoder benchmark on
//! the full three-layer stack — JAX/Pallas AOT artifacts executed from
//! Rust via PJRT (Python never runs here), K-FAC with the exponentially
//! increasing batch-size schedule of Section 13, SGD+NAG baseline for
//! comparison, loss curves logged to `results/e2e_mnist_*.csv`.
//!
//!     make artifacts && cargo run --release --example mnist_autoencoder
//!
//! Flags: --iters N (default 120) --data N (default 4000) --sgd
//!        --quick (tiny run for smoke-testing)
//!        --checkpoint PATH --checkpoint-every N --resume PATH

use kfac::backend::{ModelBackend, PjrtBackend};
use kfac::coordinator::cli::Args;
use kfac::coordinator::{log_to_csv, TrainSession};
use kfac::coordinator::Problem;
use kfac::optim::{BatchSchedule, Kfac, KfacConfig, Optimizer, Sgd, SgdConfig};
use kfac::rng::Rng;
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    let quick = args.get_flag("quick");
    let iters = args.get_usize("iters", if quick { 10 } else { 120 });
    let n_data = args.get_usize("data", if quick { 600 } else { 4000 });
    let problem = Problem::MnistAe;
    let arch = problem.arch();

    println!("# generating synthetic MNIST ({n_data} cases)…");
    let ds = problem.dataset(n_data, 0);

    println!("# loading AOT artifacts (arch {:?}, {} params)…", arch.widths, arch.num_params());
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut backend = PjrtBackend::new(&artifacts, problem.name()).unwrap_or_else(|e| {
        eprintln!("error: {e:#}\nrun `make artifacts` first");
        std::process::exit(1);
    });
    assert_eq!(backend.arch().widths, arch.widths);

    let (optimizer, tag): (Box<dyn Optimizer>, &str) = if args.get_flag("sgd") {
        (
            Box::new(Sgd::new(SgdConfig { lr: args.get_f64("lr", 0.02), ..Default::default() })),
            "e2e_mnist_sgd",
        )
    } else {
        (
            Box::new(Kfac::new(
                &arch,
                KfacConfig { lambda0: args.get_f64("lambda0", 150.0), ..Default::default() },
            )),
            "e2e_mnist_kfac",
        )
    };

    println!("# training ({tag})…");
    let mut session = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(iters)
        // paper §13: m_k = min(m₁ exp((k−1)/b), |S|), saturating at ~¾ of
        // the run
        .schedule(BatchSchedule::exponential_reaching(
            250.min(n_data),
            n_data,
            (iters * 3 / 4).max(2),
        ))
        .seed(0)
        .eval_every(5)
        .eval_rows(1000.min(n_data))
        .polyak(0.99)
        .params(arch.sparse_init(&mut Rng::new(1)))
        .optimizer_boxed(optimizer)
        .backend(&mut backend)
        .verbose(true);
    if let Some(path) = args.get("checkpoint") {
        session = session.checkpoint_every(args.get_usize("checkpoint-every", 25), path);
    }
    if let Some(path) = args.get("resume") {
        session = session.resume_from(path);
    }
    let report = session.run();

    let out = PathBuf::from(format!("results/{tag}.csv"));
    log_to_csv(&out, &report.log).expect("writing csv");
    match report.log.last() {
        Some(last) => println!(
            "# done: {} iters, {:.1}s train time, final reconstruction error {:.4}",
            last.iter, last.time_s, last.train_err
        ),
        // e.g. --resume from a checkpoint already at/past --iters
        None => println!("# done: no iterations to run"),
    }
    println!("# loss curve written to {}", out.display());
}
