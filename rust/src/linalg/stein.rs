//! Structured inverses of `A ⊗ B ± C ⊗ D` (paper Appendix B).
//!
//! The block-tridiagonal approximation's Λ blocks are
//! `Σ_{i|i+1} = Ā ⊗ G − (Ψ^Ā Ā' Ψ^Āᵀ) ⊗ (Ψ^G G' Ψ^Gᵀ)`, a *difference*
//! of Kronecker products, and the exact factored-Tikhonov variant
//! (eqn. 6) is a *sum*. Neither inverts with the simple identity
//! `(A⊗B)⁻¹ = A⁻¹⊗B⁻¹`, so the paper derives (Appendix B):
//!
//! `(A⊗B ± C⊗D)⁻¹ v = vec( K₂ [ (K₂ᵀ V K₁) ⊘ (11ᵀ ± s₂s₁ᵀ) ] K₁ᵀ )`
//!
//! with `K₁ = A^{-1/2} E₁`, `K₂ = B^{-1/2} E₂`, where
//! `E₁ S₁ E₁ᵀ = A^{-1/2} C A^{-1/2}` and `E₂ S₂ E₂ᵀ = B^{-1/2} D B^{-1/2}`.
//! The factorization is computed **once** and cached; every subsequent
//! apply is three small GEMMs plus an elementwise divide — which is what
//! makes the tridiagonal variant affordable inside the optimizer loop.

use super::eig::SymEig;
use super::Mat;

/// Cached factorization of `(A ⊗ B + sign · C ⊗ D)⁻¹` for SPD `A,B,C,D`.
pub struct KronPairInverse {
    k1: Mat,
    k2: Mat,
    s1: Vec<f64>,
    s2: Vec<f64>,
    sign: f64,
}

impl KronPairInverse {
    /// Build the cached inverse. `sign` is `+1.0` or `-1.0`.
    ///
    /// For `sign = -1` the overall matrix must still be PD, which in the
    /// K-FAC use-case it is (Σ is a conditional covariance); tiny
    /// negative denominators from roundoff are floored.
    pub fn new(a: &Mat, b: &Mat, c: &Mat, d: &Mat, sign: f64) -> KronPairInverse {
        assert!(sign == 1.0 || sign == -1.0);
        let ea = SymEig::new(a);
        let eb = SymEig::new(b);
        let a_is = ea.inv_sqrt_psd(1e-12);
        let b_is = eb.inv_sqrt_psd(1e-12);
        let m1 = a_is.matmul(c).matmul(&a_is).symmetrize();
        let m2 = b_is.matmul(d).matmul(&b_is).symmetrize();
        let e1 = SymEig::new(&m1);
        let e2 = SymEig::new(&m2);
        let k1 = a_is.matmul(&e1.v);
        let k2 = b_is.matmul(&e2.v);
        KronPairInverse { k1, k2, s1: e1.w, s2: e2.w, sign }
    }

    /// Apply to a vectorized matrix `V` of shape (B.rows, A.rows):
    /// result of the same shape.
    pub fn apply(&self, v: &Mat) -> Mat {
        assert_eq!(v.rows, self.k2.rows, "stein apply: V rows");
        assert_eq!(v.cols, self.k1.rows, "stein apply: V cols");
        // T = K2ᵀ V K1
        let mut t = self.k2.matmul_tn(&v.matmul(&self.k1));
        // elementwise divide by (1 ± s2_i s1_j), floored away from 0
        for i in 0..t.rows {
            for j in 0..t.cols {
                let denom = 1.0 + self.sign * self.s2[i] * self.s1[j];
                let denom = if denom.abs() < 1e-12 { 1e-12_f64.copysign(denom) } else { denom };
                t.set(i, j, t.at(i, j) / denom);
            }
        }
        // K2 T K1ᵀ
        self.k2.matmul(&t.matmul_nt(&self.k1))
    }

    /// `(A-side dim, B-side dim)` of the factorization.
    pub fn dims(&self) -> (usize, usize) {
        (self.k1.rows, self.k2.rows)
    }

    /// Flat length of [`to_flat`](Self::to_flat) for given dims — the
    /// layer-part size the sharded-build seam advertises.
    pub fn flat_len(na: usize, ng: usize) -> usize {
        na * na + ng * ng + na + ng + 1
    }

    /// Serialize the cached factorization as `k1 ‖ k2 ‖ s1 ‖ s2 ‖ sign`
    /// (row-major matrices). Bit-exact: [`from_flat`](Self::from_flat)
    /// reproduces identical `apply` results, which is what lets the
    /// distributed sharded-build path broadcast factorizations instead
    /// of re-deriving them per rank.
    pub fn to_flat(&self) -> Vec<f64> {
        let (na, ng) = self.dims();
        let mut out = Vec::with_capacity(Self::flat_len(na, ng));
        out.extend_from_slice(&self.k1.data);
        out.extend_from_slice(&self.k2.data);
        out.extend_from_slice(&self.s1);
        out.extend_from_slice(&self.s2);
        out.push(self.sign);
        out
    }

    /// Inverse of [`to_flat`](Self::to_flat). `None` on length mismatch
    /// or a sign that is not `±1.0` (corrupt part).
    pub fn from_flat(na: usize, ng: usize, flat: &[f64]) -> Option<KronPairInverse> {
        if flat.len() != Self::flat_len(na, ng) {
            return None;
        }
        let (k1d, rest) = flat.split_at(na * na);
        let (k2d, rest) = rest.split_at(ng * ng);
        let (s1, rest) = rest.split_at(na);
        let (s2, rest) = rest.split_at(ng);
        let sign = rest[0];
        if sign != 1.0 && sign != -1.0 {
            return None;
        }
        Some(KronPairInverse {
            k1: Mat::from_vec(na, na, k1d.to_vec()),
            k2: Mat::from_vec(ng, ng, k2d.to_vec()),
            s1: s1.to_vec(),
            s2: s2.to_vec(),
            sign,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kron::{kron, unvec, vec_mat};
    use crate::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng, diag: f64) -> Mat {
        let x = Mat::randn(n + 3, n, 1.0, rng);
        x.matmul_tn(&x).scale(1.0 / n as f64).add_diag(diag)
    }

    #[test]
    fn sum_matches_dense_inverse() {
        let mut rng = Rng::new(1);
        let (na, nb) = (4, 3);
        let a = random_spd(na, &mut rng, 0.5);
        let b = random_spd(nb, &mut rng, 0.5);
        let c = random_spd(na, &mut rng, 0.2);
        let d = random_spd(nb, &mut rng, 0.2);
        let dense = kron(&a, &b).add(&kron(&c, &d));
        let inv = dense.inverse();
        let fast = KronPairInverse::new(&a, &b, &c, &d, 1.0);
        let x = Mat::randn(nb, na, 1.0, &mut rng);
        let want = unvec(&inv.matvec(&vec_mat(&x)), nb, na);
        let got = fast.apply(&x);
        assert!(got.sub(&want).max_abs() < 1e-8, "err={}", got.sub(&want).max_abs());
    }

    #[test]
    fn difference_matches_dense_inverse() {
        let mut rng = Rng::new(2);
        let (na, nb) = (3, 5);
        let a = random_spd(na, &mut rng, 1.0);
        let b = random_spd(nb, &mut rng, 1.0);
        // make C ⊗ D a strict contraction of A ⊗ B so the difference is PD
        let c = a.scale(0.3);
        let d = b.scale(0.5);
        let dense = kron(&a, &b).sub(&kron(&c, &d));
        let inv = dense.inverse();
        let fast = KronPairInverse::new(&a, &b, &c, &d, -1.0);
        let x = Mat::randn(nb, na, 1.0, &mut rng);
        let want = unvec(&inv.matvec(&vec_mat(&x)), nb, na);
        let got = fast.apply(&x);
        assert!(got.sub(&want).max_abs() < 1e-7, "err={}", got.sub(&want).max_abs());
    }

    #[test]
    fn tikhonov_sum_with_identity_factors() {
        // eqn 6 case: A⊗B + (λ+η) I⊗I
        let mut rng = Rng::new(3);
        let (na, nb) = (4, 4);
        let a = random_spd(na, &mut rng, 0.1);
        let b = random_spd(nb, &mut rng, 0.1);
        let lam = 0.7;
        let c = Mat::eye(na).scale(lam);
        let d = Mat::eye(nb);
        let dense = kron(&a, &b).add_diag(lam);
        let inv = dense.inverse();
        let fast = KronPairInverse::new(&a, &b, &c, &d, 1.0);
        let x = Mat::randn(nb, na, 1.0, &mut rng);
        let want = unvec(&inv.matvec(&vec_mat(&x)), nb, na);
        let got = fast.apply(&x);
        assert!(got.sub(&want).max_abs() < 1e-8);
    }

    #[test]
    fn flat_roundtrip_is_bit_exact() {
        let mut rng = Rng::new(4);
        let (na, nb) = (5, 3);
        let a = random_spd(na, &mut rng, 0.4);
        let b = random_spd(nb, &mut rng, 0.4);
        let c = random_spd(na, &mut rng, 0.1);
        let d = random_spd(nb, &mut rng, 0.1);
        let orig = KronPairInverse::new(&a, &b, &c, &d, 1.0);
        let flat = orig.to_flat();
        assert_eq!(flat.len(), KronPairInverse::flat_len(na, nb));
        let back = KronPairInverse::from_flat(na, nb, &flat).expect("roundtrip");
        let x = Mat::randn(nb, na, 1.0, &mut rng);
        let (y1, y2) = (orig.apply(&x), back.apply(&x));
        for (p, q) in y1.data.iter().zip(y2.data.iter()) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
        // corrupt parts are rejected, not misinterpreted
        assert!(KronPairInverse::from_flat(na, nb, &flat[1..]).is_none());
        let mut bad = flat.clone();
        *bad.last_mut().unwrap() = 0.5;
        assert!(KronPairInverse::from_flat(na, nb, &bad).is_none());
    }

    #[test]
    fn property_random_sizes_and_seeds() {
        for seed in 0..10 {
            let mut rng = Rng::new(50 + seed);
            let na = 2 + rng.below(5);
            let nb = 2 + rng.below(5);
            let a = random_spd(na, &mut rng, 0.8);
            let b = random_spd(nb, &mut rng, 0.8);
            let c = random_spd(na, &mut rng, 0.1);
            let d = random_spd(nb, &mut rng, 0.1);
            let dense = kron(&a, &b).add(&kron(&c, &d));
            let fast = KronPairInverse::new(&a, &b, &c, &d, 1.0);
            let x = Mat::randn(nb, na, 1.0, &mut rng);
            // check  dense * fast.apply(x) == x
            let y = fast.apply(&x);
            let back = unvec(&dense.matvec(&vec_mat(&y)), nb, na);
            let err = back.sub(&x).max_abs();
            assert!(err < 1e-7, "seed={seed} err={err}");
        }
    }
}
