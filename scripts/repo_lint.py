#!/usr/bin/env python3
"""Repo-specific lint pass (stdlib only), blocking in CI (`repo-lint` job).

Rules — each encodes an invariant the generic toolchain can't check:

  R1  safety-comments   Every `unsafe` occurrence in code (block, fn,
                        impl) carries a `// SAFETY:` justification (or a
                        `# Safety` doc section) within the preceding
                        lines. Scope: all committed .rs files.
  R2  banned-calls      No `partial_cmp(..).unwrap()` and no
                        `.get(..).unwrap()` in library code (rust/src
                        outside `#[cfg(test)]` regions): the first is a
                        NaN panic waiting for a pathological loss, use
                        `total_cmp`; the second hides index provenance,
                        use `[]` (same panic, better message) or handle
                        the None.
  R3  env-registry      Every `env::var("KFAC_*")` literal read in .rs
                        code is listed in docs/env_registry.md, and the
                        registry lists no var that no code reads.
  R4  checkpoint-keys   The literal keys written into optimizer state
                        (`set_scalar/set_mats/set_str` in non-test
                        rust/src) exactly match the committed
                        KNOWN_OPT_STATE_KEYS pin in
                        rust/src/coordinator/checkpoint.rs — a new writer
                        key without a pin update silently changes the
                        checkpoint format.
  R5  deny-attr         rust/src/lib.rs keeps `#![deny(unsafe_op_in_unsafe_fn)]`.
  R6  dist-no-unsafe    The distributed transport layer (rust/src/dist)
                        contains no `unsafe` at all — framing/length
                        handling there parses attacker-reachable network
                        input, so it stays in fully safe Rust (R1's
                        SAFETY-comment escape hatch does not apply).

Usage:
  scripts/repo_lint.py [--root DIR]   lint the tree (exit 1 on findings)
  scripts/repo_lint.py --self-test    run the rule engine's own checks
"""

import argparse
import os
import re
import sys

LOOKBACK = 10  # lines above an unsafe site that may hold its SAFETY comment

# Directories scanned for .rs files (repo-relative).
RS_DIRS = ["rust/src", "tests", "benches", "examples", "verify"]
LIB_DIR = "rust/src"  # scope for R2/R4
DIST_DIR = "rust/src/dist"  # scope for R6

ENV_REGISTRY = "docs/env_registry.md"
CHECKPOINT_RS = "rust/src/coordinator/checkpoint.rs"
LIB_RS = "rust/src/lib.rs"

ENV_VAR_RE = re.compile(r'env::var(?:_os)?\s*\(\s*"(KFAC_[A-Z0-9_]+)"')
ENV_NAME_RE = re.compile(r"\bKFAC_[A-Z0-9_]+\b")
UNSAFE_RE = re.compile(r"\bunsafe\b")
SAFETY_RE = re.compile(r"SAFETY|# Safety")
SETTER_RE = re.compile(r'\.set_(?:scalar|mats|str)\s*\(\s*"([A-Za-z0-9_]+)"')
PIN_RE = re.compile(r"KNOWN_OPT_STATE_KEYS\s*:\s*&\[&str\]\s*=\s*&\[(.*?)\];", re.S)
BANNED = [
    (re.compile(r"partial_cmp\s*\([^()]*\)\s*\.\s*unwrap\s*\("), "partial_cmp(..).unwrap()"),
    (re.compile(r"\.get\s*\([^()]*\)\s*\.\s*unwrap\s*\("), ".get(..).unwrap()"),
]
DENY_ATTR = "#![deny(unsafe_op_in_unsafe_fn)]"
CFG_TEST_RE = re.compile(r"#\[cfg\((?:test\b|all\(\s*test\b)")


def split_views(text):
    """Two same-shape views of Rust source, one char scanner pass.

    Returns (code, no_comments): `code` blanks comment AND string-literal
    interiors (for keyword/structure matching); `no_comments` blanks only
    comments (string literals kept, for extracting key/env literals).
    Line structure is preserved exactly in both.
    """
    code = []
    nocom = []
    i, n = 0, len(text)
    state = "normal"
    depth = 0  # nested block comments
    raw_hashes = 0

    def put(ch, in_code, in_nocom):
        code.append(ch if in_code else (ch if ch == "\n" else " "))
        nocom.append(ch if in_nocom else (ch if ch == "\n" else " "))

    while i < n:
        ch = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if state == "normal":
            if ch == "/" and nxt == "/":
                state = "line_comment"
                put(ch, False, False)
            elif ch == "/" and nxt == "*":
                state = "block_comment"
                depth = 1
                put(ch, False, False)
                put(nxt, False, False)
                i += 1
            elif ch == '"':
                state = "string"
                put(ch, True, True)
            elif ch == "r" and re.match(r'r#*"', text[i:]):
                m = re.match(r'r(#*)"', text[i:])
                raw_hashes = len(m.group(1))
                for c in m.group(0):
                    put(c, True, True)
                i += len(m.group(0)) - 1
                state = "raw_string"
            elif ch == "'":
                # char literal vs lifetime: a literal closes within a
                # couple of chars ('x', '\n', '\u{..}' is rare here)
                m = re.match(r"'(\\.|[^\\'])'", text[i:])
                if m:
                    put(ch, True, True)
                    for c in m.group(1):
                        put(c, False, True)
                    put("'", True, True)
                    i += len(m.group(0)) - 1
                else:
                    put(ch, True, True)  # lifetime tick
            else:
                put(ch, True, True)
        elif state == "line_comment":
            if ch == "\n":
                state = "normal"
            put(ch, False, False)
        elif state == "block_comment":
            if ch == "/" and nxt == "*":
                depth += 1
                put(ch, False, False)
                put(nxt, False, False)
                i += 1
            elif ch == "*" and nxt == "/":
                depth -= 1
                put(ch, False, False)
                put(nxt, False, False)
                i += 1
                if depth == 0:
                    state = "normal"
            else:
                put(ch, False, False)
        elif state == "string":
            if ch == "\\":
                put(ch, False, True)
                if nxt:
                    put(nxt, False, True)
                    i += 1
            elif ch == '"':
                put(ch, True, True)
                state = "normal"
            else:
                put(ch, False, True)
        elif state == "raw_string":
            closer = '"' + "#" * raw_hashes
            if text.startswith(closer, i):
                for c in closer:
                    put(c, True, True)
                i += len(closer) - 1
                state = "normal"
            else:
                put(ch, False, True)
        i += 1
    return "".join(code), "".join(nocom)


def test_region_lines(code):
    """Set of 1-based line numbers inside `#[cfg(test)]`-gated items."""
    lines = code.split("\n")
    in_test = set()
    for idx, line in enumerate(lines):
        if not CFG_TEST_RE.search(line):
            continue
        # find the opening brace of the gated item, then brace-match
        depth = 0
        opened = False
        j = idx
        while j < len(lines):
            for ch in lines[j]:
                if ch == "{":
                    depth += 1
                    opened = True
                elif ch == "}":
                    depth -= 1
            in_test.add(j + 1)
            if opened and depth <= 0:
                break
            j += 1
    return in_test


def rs_files(root):
    out = []
    for d in RS_DIRS:
        base = os.path.join(root, d)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [x for x in dirnames if x != "target"]
            for f in sorted(filenames):
                if f.endswith(".rs"):
                    out.append(os.path.join(dirpath, f))
    return out


def line_of(text, pos):
    return text.count("\n", 0, pos) + 1


def lint_safety(rel, raw, code):
    """R1: every code-level `unsafe` has SAFETY within LOOKBACK lines above."""
    findings = []
    raw_lines = raw.split("\n")
    seen = set()
    for m in UNSAFE_RE.finditer(code):
        ln = line_of(code, m.start())
        if ln in seen:
            continue
        seen.add(ln)
        window = raw_lines[max(0, ln - 1 - LOOKBACK) : ln]
        if not any(SAFETY_RE.search(x) for x in window):
            findings.append(
                f"{rel}:{ln}: R1 unsafe without a `// SAFETY:` comment "
                f"in the {LOOKBACK} lines above"
            )
    return findings


def lint_dist_unsafe(rel, code):
    """R6: no `unsafe` of any kind under rust/src/dist."""
    findings = []
    for m in UNSAFE_RE.finditer(code):
        ln = line_of(code, m.start())
        findings.append(f"{rel}:{ln}: R6 `unsafe` in the transport layer (rust/src/dist)")
    return findings


def lint_banned(rel, code, in_test):
    findings = []
    for pat, label in BANNED:
        for m in pat.finditer(code):
            ln = line_of(code, m.start())
            if ln in in_test:
                continue
            findings.append(f"{rel}:{ln}: R2 banned call {label} in library code")
    return findings


def lint_env_registry(root, reads):
    findings = []
    reg_path = os.path.join(root, ENV_REGISTRY)
    if not os.path.exists(reg_path):
        return [f"{ENV_REGISTRY}: R3 missing — every KFAC_* env var must be registered there"]
    with open(reg_path, encoding="utf-8") as f:
        registered = set(ENV_NAME_RE.findall(f.read()))
    for var, sites in sorted(reads.items()):
        if var not in registered:
            findings.append(f"{sites[0]}: R3 env var {var} read but not listed in {ENV_REGISTRY}")
    for var in sorted(registered - set(reads)):
        findings.append(f"{ENV_REGISTRY}: R3 registered env var {var} is read by no code")
    return findings


def lint_checkpoint_keys(root, written):
    ck_path = os.path.join(root, CHECKPOINT_RS)
    if not os.path.exists(ck_path):
        return [f"{CHECKPOINT_RS}: R4 file missing"]
    with open(ck_path, encoding="utf-8") as f:
        _, nocom = split_views(f.read())
    m = PIN_RE.search(nocom)
    if not m:
        return [f"{CHECKPOINT_RS}: R4 KNOWN_OPT_STATE_KEYS pin not found"]
    pinned = set(re.findall(r'"([^"]+)"', m.group(1)))
    findings = []
    for key, sites in sorted(written.items()):
        if key not in pinned:
            findings.append(
                f"{sites[0]}: R4 optimizer state key \"{key}\" written but not in "
                f"KNOWN_OPT_STATE_KEYS ({CHECKPOINT_RS})"
            )
    for key in sorted(pinned - set(written)):
        findings.append(
            f"{CHECKPOINT_RS}: R4 pinned key \"{key}\" is written by no library code"
        )
    return findings


def lint_deny_attr(root):
    lib = os.path.join(root, LIB_RS)
    with open(lib, encoding="utf-8") as f:
        if DENY_ATTR not in f.read():
            return [f"{LIB_RS}: R5 missing `{DENY_ATTR}`"]
    return []


def run_lint(root):
    findings = []
    env_reads = {}  # var -> [site, ...]
    key_writes = {}  # key -> [site, ...]
    for path in rs_files(root):
        rel = os.path.relpath(path, root)
        with open(path, encoding="utf-8") as f:
            raw = f.read()
        code, nocom = split_views(raw)
        in_test = test_region_lines(code)

        findings += lint_safety(rel, raw, code)
        if rel.startswith(DIST_DIR + os.sep) or rel.startswith(DIST_DIR + "/"):
            findings += lint_dist_unsafe(rel, code)
        if rel.startswith(LIB_DIR + os.sep) or rel.startswith(LIB_DIR + "/"):
            findings += lint_banned(rel, code, in_test)
            for m in SETTER_RE.finditer(nocom):
                ln = line_of(nocom, m.start())
                if ln in in_test:
                    continue
                key_writes.setdefault(m.group(1), []).append(f"{rel}:{ln}")
        for m in ENV_VAR_RE.finditer(nocom):
            env_reads.setdefault(m.group(1), []).append(f"{rel}:{line_of(nocom, m.start())}")

    findings += lint_env_registry(root, env_reads)
    findings += lint_checkpoint_keys(root, key_writes)
    findings += lint_deny_attr(root)
    return findings


# ---------------------------------------------------------------------
# self-test: the engine's own invariants, on synthetic snippets
# ---------------------------------------------------------------------


def self_test():
    failures = []

    def check(name, cond):
        if not cond:
            failures.append(name)

    # comment/string stripping
    code, nocom = split_views('let s = "unsafe // not code"; // unsafe trailing\n')
    check("strip: unsafe in string blanked", "unsafe" not in code)
    check("strip: string kept in nocom view", "unsafe // not code" in nocom)
    check("strip: trailing comment blanked in both", "trailing" not in nocom)

    code, _ = split_views("/* unsafe /* nested */ still comment */ fn f() {}\n")
    check("strip: nested block comment", "unsafe" not in code and "fn f()" in code)

    code, _ = split_views("let c = '\"'; let x = 1; // tick\n")
    check("strip: char literal quote", "let x = 1" in code)

    code, nocom = split_views('let r = r#"unsafe "quoted" text"#; unsafe {}\n')
    check("strip: raw string blanked in code", code.count("unsafe") == 1)
    check("strip: raw string kept in nocom", 'unsafe "quoted" text' in nocom)

    # R1
    good = "// SAFETY: fine\nunsafe { x() }\n"
    bad = "fn f() {\n    unsafe { x() }\n}\n"
    attr = "#![deny(unsafe_op_in_unsafe_fn)]\n"
    c, _ = split_views(good)
    check("R1: safety comment accepted", not lint_safety("t.rs", good, c))
    c, _ = split_views(bad)
    check("R1: bare unsafe flagged", len(lint_safety("t.rs", bad, c)) == 1)
    c, _ = split_views(attr)
    check("R1: deny attr not a false positive", not lint_safety("t.rs", attr, c))

    # R2 + test-region exclusion
    lib = "fn f() { a.partial_cmp(b).unwrap(); v.get(0).unwrap(); }\n"
    c, _ = split_views(lib)
    check("R2: both banned calls flagged", len(lint_banned("t.rs", c, set())) == 2)
    tested = "#[cfg(test)]\nmod tests {\n    fn g() { a.partial_cmp(b).unwrap(); }\n}\n"
    c, _ = split_views(tested)
    check("R2: cfg(test) region excluded", not lint_banned("t.rs", c, test_region_lines(c)))
    gated = "#[cfg(all(test, not(loom)))]\nmod tests { fn g() { v.get(0).unwrap(); } }\n"
    c, _ = split_views(gated)
    check("R2: cfg(all(test,..)) excluded", not lint_banned("t.rs", c, test_region_lines(c)))

    # R6: unsafe in dist is flagged even with a SAFETY comment
    dist_src = "// SAFETY: irrelevant here\nunsafe { x() }\n"
    c, _ = split_views(dist_src)
    check("R6: unsafe in dist flagged", len(lint_dist_unsafe("rust/src/dist/t.rs", c)) == 1)
    c, _ = split_views('let s = "unsafe in a string";\n')
    check("R6: string/comment unsafe ignored", not lint_dist_unsafe("rust/src/dist/t.rs", c))

    # R4 key extraction
    src = 'fn s(&mut self) { st.set_scalar("k", 1.0); st.set_str(&dyn_key, "x"); }\n'
    _, nc = split_views(src)
    keys = [m.group(1) for m in SETTER_RE.finditer(nc)]
    check("R4: literal key extracted, dynamic skipped", keys == ["k"])

    # R3 env extraction
    _, nc = split_views('let v = std::env::var("KFAC_DEMO").ok();\n')
    check("R3: env literal extracted", ENV_VAR_RE.search(nc).group(1) == "KFAC_DEMO")

    if failures:
        print("repo_lint self-test FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("repo_lint self-test: all checks passed")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()

    if args.self_test:
        sys.exit(self_test())

    findings = run_lint(args.root)
    if findings:
        print(f"repo_lint: {len(findings)} finding(s):")
        for f in findings:
            print(f"  {f}")
        sys.exit(1)
    print("repo_lint: clean")


if __name__ == "__main__":
    main()
