"""Layer-1 Pallas kernels (build-time only).

The per-iteration dense hot spots of K-FAC, written as tiled Pallas
kernels and lowered with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls; the interpret lowering emits plain HLO ops
with identical numerics — see DESIGN.md §Hardware-Adaptation).

- ``matmul``: the tiled GEMM every other kernel rides on
  (128x128 MXU-aligned output tiles, reduction loop over K).
- ``linear``: fused layer forward ``act(abar @ W^T)``.
- ``cov``: weighted second moments ``(w*x)^T y`` (Fisher-factor stats).
- ``precond``: Kronecker preconditioner application ``Ginv V Ainv``.

``ref.py`` holds the pure-jnp oracles used by the pytest suite.
"""

from . import cov, linear, matmul, precond, ref  # noqa: F401
