//! Figure 11 — the same runs as Figure 10 plotted against **iteration**
//! count. The paper's headline per-iteration findings to reproduce:
//!  - K-FAC variants make orders-of-magnitude more per-iteration
//!    progress than SGD,
//!  - the block-tridiagonal version makes ~25–40% more per-iteration
//!    progress than the block-diagonal version.
//!
//! Reuses the cached fig10 runs when present (run fig10_wallclock
//! first, or this binary will run them itself).

use kfac::coordinator::cli::Args;
use kfac::experiments::{scaled, training_curves_fig10};

fn main() {
    let args = Args::from_env();
    let backend = args.get_or("backend", "pjrt");
    let iters = args.get_usize("iters", scaled(80, 20));
    let n_data = args.get_usize("data", scaled(2500, 600));
    println!("== Figure 11: training error vs iteration ==");

    let runs = training_curves_fig10(&backend, iters, n_data);

    println!(
        "\n{:>10} {:>18} {:>8} {:>12} {:>12}",
        "problem", "variant", "iters", "err@25%", "final_err"
    );
    let mut tri_vs_diag: Vec<(String, f64, f64)> = Vec::new();
    for (problem, vname, log) in &runs {
        let last = log.last().unwrap();
        let q = log
            .iter()
            .find(|r| r.iter * 4 >= last.iter)
            .unwrap_or(last);
        println!(
            "{:>10} {:>18} {:>8} {:>12.5} {:>12.5}",
            problem.name(),
            vname,
            last.iter,
            q.train_err,
            last.train_err
        );
        if vname == "kfac_blktridiag" {
            tri_vs_diag.push((problem.name().to_string(), last.train_err, f64::NAN));
        } else if vname == "kfac_blkdiag" {
            if let Some(e) = tri_vs_diag.iter_mut().find(|e| e.0 == problem.name()) {
                e.2 = last.train_err;
            }
        }
    }

    println!("\nblock-tridiagonal vs block-diagonal (same iteration budget):");
    for (p, tri, diag) in &tri_vs_diag {
        if diag.is_nan() {
            continue;
        }
        println!(
            "  {p}: tridiag err {tri:.5} vs blkdiag err {diag:.5}  ({})",
            if tri <= diag { "tridiag ahead, as in the paper" } else { "blkdiag ahead here" }
        );
    }
    println!("\nper-run CSVs are in results/fig10_*.csv (iter column = x-axis)");
}
