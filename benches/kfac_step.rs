//! End-to-end per-iteration benchmark: full K-FAC step (every
//! registered preconditioner, with momentum) vs an SGD step, on the
//! scaled autoencoder — the bench-form of the paper's Section-8 cost
//! comparison.

use kfac::backend::RustBackend;
use kfac::bench::{bench, default_budget};
use kfac::data::mnist_like;
use kfac::fisher::precond;
use kfac::nn::{Act, Arch};
use kfac::optim::{Kfac, KfacConfig, Optimizer, Sgd, SgdConfig};
use kfac::rng::Rng;

fn main() {
    let budget = default_budget();
    let arch = Arch::autoencoder(&[256, 100, 40, 12, 40, 100, 256], Act::Tanh);
    let ds = mnist_like::autoencoder_dataset(1000, 16, 0);
    let m = 500;

    for p in [precond::block_diag(), precond::block_tridiag(), precond::ekfac()] {
        let name = p.name().to_string();
        let mut backend = RustBackend::new(arch.clone());
        let mut params = arch.sparse_init(&mut Rng::new(1));
        let mut opt = Kfac::new(&arch, KfacConfig { precond: p, ..Default::default() });
        let mut rng = Rng::new(2);
        let r = bench(&format!("kfac_step_{name}_m{m}"), budget, || {
            let (x, y) = ds.minibatch(m, &mut rng);
            std::hint::black_box(opt.step(&mut backend, &mut params, &x, &y));
        });
        let _ = r;
    }

    let mut backend = RustBackend::new(arch.clone());
    let mut params = arch.sparse_init(&mut Rng::new(1));
    let mut sgd = Sgd::new(SgdConfig::default());
    let mut rng = Rng::new(2);
    bench(&format!("sgd_step_m{m}"), budget, || {
        let (x, y) = ds.minibatch(m, &mut rng);
        std::hint::black_box(sgd.step(&mut backend, &mut params, &x, &y));
    });
}
