"""AOT lowering path: programs lower to parseable HLO text with the
expected parameter/output arity, and the manifest entries are complete.
(The Rust side's ability to *execute* these is covered by
tests/pjrt_roundtrip.rs.)"""

import json

from compile import aot, model


def test_tiny_programs_lower_to_hlo_text():
    md = model.by_name("tiny_clf")
    specs = model.program_specs(md)
    assert set(specs) == {"fwd_loss", "grad", "grad_stats", "fvp2", "precond"}
    import jax

    for name, (fn, args) in specs.items():
        lowered = jax.jit(fn).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        # no TPU/CPU custom-calls may appear (xla_extension 0.5.1 cannot
        # execute them) — the whole reason for the pure-jnp PRNG
        assert "custom-call" not in text, f"{name} contains a custom call"
        assert "ROOT" in text


def test_grad_stats_output_arity_matches_contract():
    # rust/src/backend/pjrt.rs expects:
    # loss, err, dW×l, aa×l, aa_off×(l−1), gg×l, gg_off×(l−1)
    md = model.by_name("tiny_ae")
    fn, args = model.program_specs(md)["grad_stats"]
    out = fn(*[_zeros(a) for a in args])
    l = md.num_layers
    assert len(out) == 2 + l + l + (l - 1) + l + (l - 1)


def _zeros(spec):
    import jax.numpy as jnp
    import numpy as np

    return jnp.asarray(np.zeros(spec.shape, spec.dtype))


def test_manifest_entry_shape(tmp_path):
    md = model.by_name("tiny_clf")
    entry = aot.lower_model(md, str(tmp_path))
    # round-trips through json and has everything the rust parser needs
    entry = json.loads(json.dumps(entry))
    for key in ["name", "widths", "acts", "loss", "chunk", "programs"]:
        assert key in entry, key
    assert entry["widths"] == list(md.widths)
    for rel in entry["programs"].values():
        assert (tmp_path / rel).exists()
