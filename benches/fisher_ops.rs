//! Benchmarks for the Fisher-approximation operations on a
//! paper-scale architecture (the MNIST autoencoder): statistics
//! computation, inverse refresh (task 5), preconditioner application
//! (task 6) for both structures, the EKFAC amortized scale-refresh
//! path (per-example gradient projection + diagonal swap), the KFC
//! conv statistics + inverse refresh on the conv classifier, and the
//! per-step overhead of a full K-FAC step vs SGD with the inverse
//! rebuild amortized synchronously (t_inv) or hidden entirely behind
//! the asynchronous background refresh (KFAC_ASYNC), plus the frontier
//! structures (KPSVD builds/applies and the ikfac rank-k incremental
//! update vs the full block-diagonal refactorization) at the paper's
//! 8-layer autoencoder shapes.
//!
//! Results are written as JSON (`KFAC_BENCH_JSON`, default
//! `BENCH_fisher_ops.json`) in the same schema as the linalg bench so
//! CI can merge them into one report.

use kfac::backend::{ModelBackend, RustBackend};
use kfac::bench::{bench, default_budget, write_results_json, BenchResult};
use kfac::coordinator::Problem;
use kfac::data::mnist_like;
use kfac::fisher::ikfac::IkfacPrecond;
use kfac::fisher::kpsvd::KpsvdPrecond;
use kfac::fisher::stats::KfacStats;
use kfac::fisher::{
    BlockDiagInverse, EkfacInverse, FisherInverse, KfcInverse, Preconditioner, TridiagInverse,
    UpdateOutcome,
};
use kfac::linalg::{KronBasis, SymEig};
use kfac::nn::{Act, Arch};
use kfac::optim::{Kfac, KfacConfig, Optimizer, Sgd, SgdConfig};
use kfac::rng::Rng;

fn main() {
    let budget = default_budget();
    let problem = Problem::MnistAe;
    let arch = problem.arch();
    println!("arch {:?} ({} params)", arch.widths, arch.num_params());
    let ds = problem.dataset(256, 0);
    let mut backend = RustBackend::new(arch.clone());
    let params = arch.sparse_init(&mut Rng::new(1));
    let (x, y) = (ds.x.clone(), ds.y.clone());
    let mut results: Vec<(BenchResult, Option<f64>)> = Vec::new();

    let r = bench("grad_and_stats_m256", budget, || {
        std::hint::black_box(backend.grad_and_stats(&params, &x, &y, 32, 7));
    });
    results.push((r, None));

    let (_, grad, raw) = backend.grad_and_stats(&params, &x, &y, 256, 7);
    let mut stats = KfacStats::new(&arch);
    stats.update(&raw);
    let gamma = 1.0;

    // One eigendecomposition of a real (damped) activation factor — the
    // unit of work the blocked eigensolver threads inside every
    // tridiag/EKFAC refresh. Pick the factor closest to 256 wide so the
    // number is comparable to the sym_eig_256 linalg bench.
    let aa = &stats.s.aa;
    let (fi, _) = aa
        .iter()
        .enumerate()
        .min_by_key(|(_, m)| (m.rows as i64 - 256).unsigned_abs())
        .expect("at least one layer");
    let factor = aa[fi].add_diag(1.0);
    let r = bench(&format!("sym_eig_factor_{}(mnist_ae)", factor.rows), budget, || {
        std::hint::black_box(SymEig::new(&factor));
    });
    results.push((r, None));

    let r = bench("blockdiag_build(mnist_ae)", budget, || {
        std::hint::black_box(BlockDiagInverse::build(&stats.s, gamma));
    });
    results.push((r, None));
    let r = bench("tridiag_build(mnist_ae)", budget, || {
        std::hint::black_box(TridiagInverse::build(&stats.s, gamma));
    });
    results.push((r, None));
    let r = bench("ekfac_build(mnist_ae)", budget, || {
        std::hint::black_box(EkfacInverse::build(&stats.s, gamma));
    });
    results.push((r, None));

    let bd = BlockDiagInverse::build(&stats.s, gamma);
    let tri = TridiagInverse::build(&stats.s, gamma);
    let ek = EkfacInverse::build(&stats.s, gamma);
    let r = bench("blockdiag_apply(mnist_ae)", budget, || {
        std::hint::black_box(bd.apply(&grad));
    });
    results.push((r, None));
    let r = bench("tridiag_apply(mnist_ae)", budget, || {
        std::hint::black_box(tri.apply(&grad));
    });
    results.push((r, None));
    let r = bench("ekfac_apply(mnist_ae)", budget, || {
        std::hint::black_box(ek.apply(&grad));
    });
    results.push((r, None));

    let r = bench("fvp_quad_2dirs_m64", budget, || {
        let d2 = grad.scale(0.5);
        std::hint::black_box(backend.fvp_quad(&params, &x, 64, &[&grad, &d2]));
    });
    results.push((r, None));

    // KFC on the conv classifier: patch-based statistics (im2col rows
    // dominate the GEMM) and the conv-block inverse refresh.
    let conv_problem = Problem::ConvClf;
    let conv_arch = conv_problem.arch();
    let conv_ds = conv_problem.dataset(256, 0);
    let mut conv_backend = RustBackend::new(conv_arch.clone());
    let conv_params = conv_arch.sparse_init(&mut Rng::new(1));
    let r = bench("conv_grad_and_stats_m256(conv_clf)", budget, || {
        std::hint::black_box(conv_backend.grad_and_stats(
            &conv_params,
            &conv_ds.x,
            &conv_ds.y,
            256,
            7,
        ));
    });
    results.push((r, None));
    let (_, _, conv_raw) =
        conv_backend.grad_and_stats(&conv_params, &conv_ds.x, &conv_ds.y, 256, 7);
    let mut conv_stats = KfacStats::new(&conv_arch);
    conv_stats.update(&conv_raw);
    let r = bench("kfc_build(conv_clf)", budget, || {
        std::hint::black_box(KfcInverse::build(&conv_stats.s, gamma));
    });
    results.push((r, None));

    // EKFAC amortized scale refresh: project per-example gradients into
    // the cached eigenbasis (one forward + sampled backward + two
    // squared GEMMs per layer), then swap the diagonal in.
    let bases: Vec<KronBasis> = ek.eigenbases().expect("ekfac exposes bases").to_vec();
    let r = bench("ekfac_grad_sq_in_basis_m32", budget, || {
        std::hint::black_box(backend.grad_sq_in_basis(&params, &x, &y, 32, 7, &bases));
    });
    results.push((r, None));
    let sq = backend.grad_sq_in_basis(&params, &x, &y, 32, 7, &bases);
    let mut ek_refresh = EkfacInverse::build(&stats.s, gamma);
    let r = bench("ekfac_set_scales(mnist_ae)", budget, || {
        std::hint::black_box(ek_refresh.set_scales(&sq, gamma));
    });
    results.push((r, None));

    // Per-step overhead vs SGD on the scaled autoencoder: the sync
    // refresh pays the rebuild inline every t_inv-th step (it shows up
    // in the mean), the async refresh submits it to the background pool
    // and only ever pays statistics + apply in the foreground.
    let step_arch = Arch::autoencoder(&[256, 100, 40, 12, 40, 100, 256], Act::Tanh);
    let step_ds = mnist_like::autoencoder_dataset(1000, 16, 0);
    let m = 256;

    let mut sgd_backend = RustBackend::new(step_arch.clone());
    let mut sgd_params = step_arch.sparse_init(&mut Rng::new(1));
    let mut sgd = Sgd::new(SgdConfig::default());
    let mut rng = Rng::new(2);
    let r = bench(&format!("sgd_step_m{m}"), budget, || {
        let (x, y) = step_ds.minibatch(m, &mut rng);
        std::hint::black_box(sgd.step(&mut sgd_backend, &mut sgd_params, &x, &y));
    });
    results.push((r, None));

    for (label, refresh_async) in [("sync", false), ("async", true)] {
        let mut be = RustBackend::new(step_arch.clone());
        let mut params = step_arch.sparse_init(&mut Rng::new(1));
        let cfg = KfacConfig { t_inv: 5, refresh_async, ..Default::default() };
        let mut opt = Kfac::new(&step_arch, cfg);
        let mut rng = Rng::new(2);
        let r = bench(&format!("kfac_step_{label}_refresh_m{m}"), budget, || {
            let (x, y) = step_ds.minibatch(m, &mut rng);
            std::hint::black_box(opt.step(&mut be, &mut params, &x, &y));
        });
        results.push((r, None));
        println!("  {label} refresh: {} background stalls", opt.refresh_stalls());
    }

    // Frontier structures at the paper's 8-layer autoencoder shapes:
    // KPSVD full builds/applies, and the ikfac rank-k Woodbury
    // correction vs the full block-diagonal refactorization it replaces
    // (blockdiag_build(ae8) is the baseline the incremental update must
    // beat).
    let mut fr_backend = RustBackend::new(step_arch.clone());
    let fr_params = step_arch.sparse_init(&mut Rng::new(1));
    let (_, fr_grad, fr_raw) =
        fr_backend.grad_and_stats(&fr_params, &step_ds.x, &step_ds.y, 256, 7);
    let mut fr_stats = KfacStats::new(&step_arch);
    fr_stats.update(&fr_raw);

    let r = bench("blockdiag_build(ae8)", budget, || {
        std::hint::black_box(BlockDiagInverse::build(&fr_stats.s, gamma));
    });
    results.push((r, None));
    for rank in [1usize, 2] {
        let kp = KpsvdPrecond::new(rank);
        let r = bench(&format!("kpsvd_build_r{rank}(ae8)"), budget, || {
            std::hint::black_box(kp.build(&fr_stats.s, gamma));
        });
        results.push((r, None));
        let inv = kp.build(&fr_stats.s, gamma);
        let r = bench(&format!("kpsvd_apply_r{rank}(ae8)"), budget, || {
            std::hint::black_box(inv.apply(&fr_grad));
        });
        results.push((r, None));
    }

    // ikfac: snapshot the base statistics, drift them with one more
    // batch, and time the rank-k correction against that fixed delta.
    let fr_base = fr_stats.s.clone();
    let mut ik_inv = IkfacPrecond::new(4, 1e300).build(&fr_base, gamma);
    let (_, _, fr_raw2) =
        fr_backend.grad_and_stats(&fr_params, &step_ds.x, &step_ds.y, 256, 8);
    fr_stats.update(&fr_raw2);
    let fr_delta = fr_stats.s.delta_from(&fr_base);
    assert!(
        matches!(ik_inv.update(&fr_delta, gamma), UpdateOutcome::Updated),
        "ikfac must accept the drift delta it is benched on"
    );
    let r = bench("ikfac_update_k4(ae8)", budget, || {
        std::hint::black_box(ik_inv.update(&fr_delta, gamma));
    });
    results.push((r, None));
    let r = bench("ikfac_apply(ae8)", budget, || {
        std::hint::black_box(ik_inv.apply(&fr_grad));
    });
    results.push((r, None));

    let path =
        std::env::var("KFAC_BENCH_JSON").unwrap_or_else(|_| "BENCH_fisher_ops.json".to_string());
    write_results_json(std::path::Path::new(&path), &results).expect("writing bench json");
    println!("wrote {path} ({} benches)", results.len());
}
