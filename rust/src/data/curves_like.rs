//! CURVES substitute: images of random cubic Bézier curves at 28×28.
//! The original CURVES benchmark (Hinton & Salakhutdinov 2006) is itself
//! synthetic curve images, so this generator reproduces the dataset in
//! spirit, not just in format.

use super::{blur, draw_segment, Dataset};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Render one random cubic Bézier curve.
pub fn render_curve(side: usize, rng: &mut Rng) -> Vec<f64> {
    let mut img = vec![0.0; side * side];
    // 4 control points in the (padded) unit square
    let p: Vec<(f64, f64)> = (0..4)
        .map(|_| (0.12 + 0.76 * rng.uniform(), 0.12 + 0.76 * rng.uniform()))
        .collect();
    let bez = |t: f64| {
        let u = 1.0 - t;
        let b = [u * u * u, 3.0 * u * u * t, 3.0 * u * t * t, t * t * t];
        let x = b.iter().zip(&p).map(|(w, q)| w * q.0).sum::<f64>();
        let y = b.iter().zip(&p).map(|(w, q)| w * q.1).sum::<f64>();
        (x, y)
    };
    let steps = 24;
    let mut prev = bez(0.0);
    for i in 1..=steps {
        let cur = bez(i as f64 / steps as f64);
        draw_segment(&mut img, side, prev.0, prev.1, cur.0, cur.1, 0.045);
        prev = cur;
    }
    img
}

/// Autoencoding dataset of curves: `x = y`, `n × side²`.
pub fn autoencoder_dataset(n: usize, side: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Mat::zeros(n, side * side);
    for r in 0..n {
        let img = render_curve(side, &mut rng);
        x.row_mut(r).copy_from_slice(&img);
    }
    let x = blur(&x);
    Dataset::new(x.clone(), x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_are_sparse_unit_interval_images() {
        let ds = autoencoder_dataset(50, 28, 1);
        assert_eq!(ds.x.cols, 784);
        assert!(ds.x.data.iter().all(|v| (0.0..=1.0).contains(v)));
        // curves are thin: most pixels dark
        let frac_on = ds.x.data.iter().filter(|v| **v > 0.3).count() as f64
            / ds.x.data.len() as f64;
        assert!(frac_on > 0.005 && frac_on < 0.35, "frac_on={frac_on}");
    }

    #[test]
    fn distinct_seeds_give_distinct_data() {
        let a = autoencoder_dataset(5, 28, 1);
        let b = autoencoder_dataset(5, 28, 2);
        assert!(a.x.sub(&b.x).max_abs() > 0.1);
    }
}
