"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (including non-divisible-by-block sizes that
exercise the padding path) and checks allclose against ``ref.py``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cov, linear, matmul, precond, ref

RNG = np.random.default_rng(0)


def randm(r, c):
    return RNG.standard_normal((r, c)).astype(np.float32)


dims = st.integers(min_value=1, max_value=40)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims)
def test_matmul_matches_ref(m, k, n):
    x, y = randm(m, k), randm(k, n)
    got = np.asarray(matmul.matmul(x, y, block=16))
    want = np.asarray(ref.matmul(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(1, 1, 1), (128, 128, 128), (129, 64, 7), (200, 3, 250)])
def test_matmul_edge_shapes(shape):
    m, k, n = shape
    x, y = randm(m, k), randm(k, n)
    np.testing.assert_allclose(
        np.asarray(matmul.matmul(x, y)),
        np.asarray(ref.matmul(x, y)),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("act", ["tanh", "logistic", "relu", "identity"])
def test_linear_fwd_matches_ref(act):
    abar, w = randm(33, 17), randm(9, 17)
    got = np.asarray(linear.linear_fwd(abar, w, act=act))
    want = np.asarray(ref.linear_fwd(abar, w, act=act))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=dims, d1=dims, d2=dims)
def test_cov_matches_ref(m, d1, d2):
    x, y = randm(m, d1), randm(m, d2)
    w = (RNG.uniform(size=m) < 0.7).astype(np.float32)
    got = np.asarray(cov.cov(x, y, w))
    want = np.asarray(ref.cov(x, y, w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_cov_mask_zeroes_rows():
    x = randm(10, 4)
    w = np.zeros(10, np.float32)
    got = np.asarray(cov.cov(x, x, w))
    assert np.abs(got).max() == 0.0


def test_precond_matches_ref():
    g, v, a = randm(12, 12), randm(12, 21), randm(21, 21)
    got = np.asarray(precond.kron_apply(g, v, a))
    want = np.asarray(ref.kron_apply(g, v, a))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_fused_activation():
    import jax.numpy as jnp

    x, y = randm(20, 20), randm(20, 20)
    got = np.asarray(matmul.matmul(x, y, activation=jnp.tanh, block=8))
    want = np.tanh(np.asarray(ref.matmul(x, y)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
