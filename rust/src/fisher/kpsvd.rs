//! KPSVD — rank-R Kronecker-sum curvature per block (Koroko et al.
//! 2022, "Efficient approximations of the Fisher matrix in neural
//! networks using Kronecker product singular value decomposition").
//!
//! K-FAC approximates each Fisher block with a *single* Kronecker
//! product and then damps it by factoring `γ²I` into the two factors
//! (paper §6.3), which leaves a nonzero cross-term residual
//! `πγ I⊗G + (γ/π) Ā⊗I`. KPSVD instead approximates the damped block
//!
//! `T = Ā ⊗ G + γ² I ⊗ I`
//!
//! by the best rank-R Kronecker sum `Σᵣ Aᵣ⊗Gᵣ` in Frobenius norm,
//! which by the Van Loan–Pitsianis identity (see
//! [`linalg::kron::rearrange`](crate::linalg::kron::rearrange)) is the
//! rank-R truncated SVD of the rearranged matrix
//!
//! `R(T) = vec(Ā) vec(G)ᵀ + vec(I) (γ² vec(I))ᵀ`.
//!
//! `R(T)` is at most rank 2 and never materialized: the fit runs block
//! power iteration against the implicit operator (two dot products and
//! two axpys per application, `O(d_a² + d_g²)` per iteration), so a
//! KPSVD refresh costs the same order as a block-diagonal one.
//!
//! Supported ranks (`KFAC_KPSVD_RANK`, default 2):
//!
//! - **R = 1** is the paper's own §6.3 analysis: the best single-term
//!   fit is exactly what factored Tikhonov damping approximates, so
//!   rank 1 *is* the block-diagonal structure — `build` returns the
//!   literal [`BlockDiagInverse`], bit-for-bit.
//! - **R = 2** recovers `T` to convergence precision (the target is
//!   exactly Kronecker-rank 2), and the sum of two Kronecker products
//!   is inverted with the shared Appendix-B machinery
//!   ([`KronPairInverse`]): the first term is PD⊗PD by a Perron
//!   argument (its factors are nonnegative combinations of `Ā, I` and
//!   `G, I`), the second may be indefinite, which Appendix B allows.
//!
//! Ranks above 2 are rejected: a sum of three or more Kronecker terms
//! no longer admits the simultaneous-diagonalization inverse, so the
//! apply cost would jump from three small GEMMs to an iterative solve.

use super::blockdiag::BlockDiagInverse;
use super::damping::damped_factors;
use super::precond::Preconditioner;
use super::stats::RawStats;
use super::FisherInverse;
use crate::linalg::kron::{kron, unvec, vec_mat};
use crate::linalg::{KronPairInverse, Mat};
use crate::nn::Params;

/// Iterations of block power iteration on the implicit `R(T)`. The
/// operator is exactly rank 2, so the iterated subspace is exact after
/// one application; the extra rounds only polish roundoff, and a fixed
/// count keeps the fit a deterministic pure function of its inputs
/// (checkpoint resume rebuilds through it).
const FIT_ITERS: usize = 8;

/// A fitted rank-2 Kronecker sum `a⊗b + c⊗d` for one layer.
/// `(a, b)` carries the dominant singular value and is PD⊗PD;
/// `(c, d)` carries the (signed) second term and is `None` when the
/// target degenerated to a single Kronecker term (e.g. `γ = 0`, or
/// `G ∝ I` so the damping folds into the first factor exactly).
pub struct KpsvdTerms {
    pub a: Mat,
    pub b: Mat,
    pub cd: Option<(Mat, Mat)>,
}

fn dot(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y.iter()).map(|(a, b)| a * b).sum()
}

fn norm(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Normalize in place; `false` when the norm collapsed below
/// `1e-13 · ref_norm` (the direction is numerically degenerate and
/// the caller drops it).
fn normalize(x: &mut [f64], ref_norm: f64) -> bool {
    let n = norm(x);
    if n <= 1e-13 * ref_norm.max(1e-300) {
        return false;
    }
    for xi in x.iter_mut() {
        *xi /= n;
    }
    true
}

/// Orthogonalize `x` against the unit vector `b`, then normalize.
/// `false` when `x` was numerically inside span{b}.
fn orth_unit(x: &mut [f64], b: &[f64]) -> bool {
    let pre = norm(x);
    let d = dot(x, b);
    axpy(x, -d, b);
    normalize(x, pre)
}

/// Fit `Ā ⊗ G + γ²I ⊗ I ≈ a⊗b [+ c⊗d]` by block power iteration on
/// the implicit rearranged operator. Deterministic; `O(d_a² + d_g²)`
/// per iteration.
pub fn fit_terms(aa: &Mat, gg: &Mat, gamma: f64) -> KpsvdTerms {
    let (na, ng) = (aa.rows, gg.rows);
    // R(T) = p1 q1ᵀ + p2 q2ᵀ in vec coordinates.
    let p1 = vec_mat(aa);
    let p2 = vec_mat(&Mat::eye(na));
    let q1 = vec_mat(gg);
    let q2 = vec_mat(&Mat::eye(ng).scale(gamma * gamma));
    let scale = norm(&p1) * norm(&q1) + norm(&p2) * norm(&q2);
    if scale <= 0.0 {
        // Zero target (zero stats at γ=0) — unreachable from the
        // optimizer (bootstrap always has statistics), but stay total:
        // fall back to the factored-damping pair like blockdiag would.
        let (ad, gd) = damped_factors(aa, gg, gamma);
        return KpsvdTerms { a: ad, b: gd, cd: None };
    }
    let lv = |v: &[f64]| -> Vec<f64> {
        // u = p1 (q1·v) + p2 (q2·v)
        let mut u = vec![0.0; na * na];
        axpy(&mut u, dot(&q1, v), &p1);
        axpy(&mut u, dot(&q2, v), &p2);
        u
    };
    let ltu = |u: &[f64]| -> Vec<f64> {
        let mut v = vec![0.0; ng * ng];
        axpy(&mut v, dot(&p1, u), &q1);
        axpy(&mut v, dot(&p2, u), &q2);
        v
    };
    // Start subspace in the row space: q1 and its complement in q2.
    let mut v1 = q1.clone();
    if !normalize(&mut v1, norm(&q1).max(norm(&q2))) {
        v1 = q2.clone();
        normalize(&mut v1, norm(&q2));
    }
    let mut v2 = Some(q2.clone());
    let mut u1 = vec![0.0; na * na];
    let mut u2: Option<Vec<f64>> = None;
    for _ in 0..FIT_ITERS {
        if let Some(w) = v2.as_mut() {
            if !orth_unit(w, &v1) {
                v2 = None;
            }
        }
        u1 = lv(&v1);
        normalize(&mut u1, scale);
        u2 = v2.as_ref().map(|w| lv(w));
        if let Some(w) = u2.as_mut() {
            if !orth_unit(w, &u1) {
                u2 = None;
                v2 = None;
            }
        }
        v1 = ltu(&u1);
        normalize(&mut v1, scale);
        v2 = u2.as_ref().map(|u| ltu(u));
    }
    if let Some(w) = v2.as_mut() {
        if !orth_unit(w, &v1) {
            v2 = None;
            u2 = None;
        }
    }
    // Rayleigh 2×2 (or 1×1) projection M = Uᵀ R V, then its exact SVD
    // rotates (U, V) into singular-vector estimates. Because the
    // subspace is exact, this step resolves even σ₁ ≈ σ₂ ties that
    // plain deflated power iteration cannot.
    let lam1 = lv(&v1);
    let lam2 = v2.as_ref().map(|w| lv(w));
    let m00 = dot(&u1, &lam1);
    let sv = match (&u2, &lam2) {
        (Some(u2v), Some(l2)) => {
            let m = [[m00, dot(&u1, l2)], [dot(u2v, &lam1), dot(u2v, l2)]];
            svd2(m)
        }
        _ => Svd2 {
            s1: m00.abs(),
            s2: 0.0,
            p1: [m00.signum(), 0.0],
            p2: [0.0, 0.0],
            q1: [1.0, 0.0],
            q2: [0.0, 0.0],
        },
    };
    let combine = |c: [f64; 2], x1: &[f64], x2: Option<&Vec<f64>>| -> Vec<f64> {
        let mut out = vec![0.0; x1.len()];
        axpy(&mut out, c[0], x1);
        if let Some(x2) = x2 {
            axpy(&mut out, c[1], x2);
        }
        out
    };
    let mut a1 = unvec(&combine(sv.p1, &u1, u2.as_ref()), na, na).symmetrize();
    let mut g1 = unvec(&combine(sv.q1, &v1, v2.as_ref()), ng, ng).symmetrize();
    // Deterministic orientation; the dominant pair is PD⊗PD up to a
    // joint sign (Perron: nonnegative combinations of Ā,I and G,I).
    if a1.trace() < 0.0 {
        a1 = a1.scale(-1.0);
        g1 = g1.scale(-1.0);
    }
    let cd = if sv.s2 > 1e-13 * sv.s1 {
        let mut a2 = unvec(&combine(sv.p2, &u1, u2.as_ref()), na, na).symmetrize();
        let mut g2 = unvec(&combine(sv.q2, &v1, v2.as_ref()), ng, ng).symmetrize();
        if g2.trace() < 0.0 {
            a2 = a2.scale(-1.0);
            g2 = g2.scale(-1.0);
        }
        Some((a2.scale(sv.s2), g2))
    } else {
        None
    };
    KpsvdTerms { a: a1.scale(sv.s1), b: g1, cd }
}

/// Exact SVD `M = P Σ Qᵀ` of a 2×2 matrix, with σ₁ ≥ σ₂ ≥ 0 and the
/// P/Q columns as coefficient pairs over the iterated subspace.
struct Svd2 {
    s1: f64,
    s2: f64,
    p1: [f64; 2],
    p2: [f64; 2],
    q1: [f64; 2],
    q2: [f64; 2],
}

fn svd2(m: [[f64; 2]; 2]) -> Svd2 {
    // Eigendecompose MᵀM (symmetric 2×2, closed form) for Q and Σ².
    let a = m[0][0] * m[0][0] + m[1][0] * m[1][0];
    let b = m[0][0] * m[0][1] + m[1][0] * m[1][1];
    let c = m[0][1] * m[0][1] + m[1][1] * m[1][1];
    let tr = a + c;
    let disc = (((a - c) * 0.5).powi(2) + b * b).sqrt();
    let l1 = tr * 0.5 + disc;
    let l2 = (tr * 0.5 - disc).max(0.0);
    let q1 = if b.abs() > 1e-300 {
        let v = [b, l1 - a];
        let n = (v[0] * v[0] + v[1] * v[1]).sqrt();
        [v[0] / n, v[1] / n]
    } else if a >= c {
        [1.0, 0.0]
    } else {
        [0.0, 1.0]
    };
    let q2 = [-q1[1], q1[0]];
    let s1 = l1.max(0.0).sqrt();
    let s2 = l2.sqrt();
    let mul = |q: [f64; 2]| [m[0][0] * q[0] + m[0][1] * q[1], m[1][0] * q[0] + m[1][1] * q[1]];
    let unit = |w: [f64; 2], s: f64| {
        if s > 1e-300 {
            [w[0] / s, w[1] / s]
        } else {
            [0.0, 0.0]
        }
    };
    Svd2 { s1, s2, p1: unit(mul(q1), s1), p2: unit(mul(q2), s2), q1, q2 }
}

/// Dense fitted approximation `Σᵣ Aᵣ⊗Gᵣ` of one damped block at rank
/// `r ∈ {1, 2}` — test/experiment machinery (the harness compares it
/// against the dense target `Ā⊗G + γ²I`). Rank 1 is the factored
/// Tikhonov pair, exactly what [`BlockDiagInverse`] inverts.
pub fn fitted_dense(aa: &Mat, gg: &Mat, gamma: f64, r: usize) -> Mat {
    match r {
        1 => {
            let (ad, gd) = damped_factors(aa, gg, gamma);
            kron(&ad, &gd)
        }
        2 => {
            let t = fit_terms(aa, gg, gamma);
            let mut out = kron(&t.a, &t.b);
            if let Some((c, d)) = &t.cd {
                out = out.add(&kron(c, d));
            }
            out
        }
        _ => panic!("kpsvd: fitted_dense rank must be 1 or 2 (got {r})"),
    }
}

/// Cached rank-2 KPSVD inverse: one Appendix-B [`KronPairInverse`]
/// per layer. (The rank-1 structure never constructs this type — it
/// is the literal [`BlockDiagInverse`].)
pub struct KpsvdInverse {
    pub blocks: Vec<KronPairInverse>,
}

fn build_layer_pair(aa: &Mat, gg: &Mat, gamma: f64) -> KronPairInverse {
    let t = fit_terms(aa, gg, gamma);
    match &t.cd {
        Some((c, d)) => KronPairInverse::new(&t.a, &t.b, c, d, 1.0),
        // Single-term degenerate fit: a zero second pair makes the
        // Appendix-B machinery an exact (a⊗b)⁻¹.
        None => KronPairInverse::new(
            &t.a,
            &t.b,
            &Mat::zeros(aa.rows, aa.rows),
            &Mat::zeros(gg.rows, gg.rows),
            1.0,
        ),
    }
}

impl KpsvdInverse {
    /// Rank-2 build: fit + factorize every layer (pool-parallel, like
    /// every other per-layer refresh).
    pub fn build(stats: &RawStats, gamma: f64) -> KpsvdInverse {
        let l = stats.num_layers();
        let blocks = crate::par::par_map_send(l, 1, |i| {
            super::check_factors_finite("kpsvd", i, &stats.aa[i], &stats.gg[i]);
            build_layer_pair(&stats.aa[i], &stats.gg[i], gamma)
        });
        KpsvdInverse { blocks }
    }
}

impl FisherInverse for KpsvdInverse {
    fn apply(&self, grads: &Params) -> Params {
        Params(grads.0.iter().zip(self.blocks.iter()).map(|(v, b)| b.apply(v)).collect())
    }
}

/// Read `KFAC_KPSVD_RANK` (default 2). Panics descriptively on any
/// other value — see the module docs for why only 1 and 2 exist.
pub fn rank_from_env() -> usize {
    match std::env::var("KFAC_KPSVD_RANK") {
        Err(_) => 2,
        Ok(s) => match s.parse::<usize>() {
            Ok(r @ (1 | 2)) => r,
            _ => panic!(
                "KFAC_KPSVD_RANK must be 1 or 2 (got '{s}'): rank 1 is the factored-damping \
                 single-term fit (≡ blkdiag) and rank 2 recovers the Tikhonov-damped block \
                 exactly; higher ranks have no simultaneous-diagonalization inverse"
            ),
        },
    }
}

/// KPSVD preconditioner: registered as `"kpsvd"` (CLI `kfac_kpsvd`),
/// rank selected by [`rank_from_env`] at registration or pinned via
/// [`KpsvdPrecond::new`].
pub struct KpsvdPrecond {
    r: usize,
}

impl KpsvdPrecond {
    pub fn new(r: usize) -> KpsvdPrecond {
        assert!(r == 1 || r == 2, "kpsvd: rank must be 1 or 2 (got {r})");
        KpsvdPrecond { r }
    }

    pub fn rank(&self) -> usize {
        self.r
    }
}

impl Preconditioner for KpsvdPrecond {
    fn name(&self) -> &str {
        "kpsvd"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        match self.r {
            // Rank 1 IS the block-diagonal structure (module docs):
            // return the literal blockdiag build, bit-for-bit.
            1 => Box::new(BlockDiagInverse::build(stats, gamma)),
            _ => Box::new(KpsvdInverse::build(stats, gamma)),
        }
    }

    fn layer_part_len(&self, stats: &RawStats, layer: usize) -> Option<usize> {
        let a = stats.aa[layer].rows;
        let g = stats.gg[layer].rows;
        match self.r {
            1 => Some(a * a + g * g),
            _ => Some(KronPairInverse::flat_len(a, g)),
        }
    }

    fn build_layer_part(&self, stats: &RawStats, gamma: f64, layer: usize) -> Vec<f64> {
        match self.r {
            // Rank 1 shards exactly like blockdiag (same inverse type).
            1 => super::precond::BlockDiagPrecond.build_layer_part(stats, gamma, layer),
            _ => {
                // Mirrors KpsvdInverse::build's per-layer closure exactly so
                // a sharded refresh is bitwise identical to a replicated one.
                super::check_factors_finite("kpsvd", layer, &stats.aa[layer], &stats.gg[layer]);
                build_layer_pair(&stats.aa[layer], &stats.gg[layer], gamma).to_flat()
            }
        }
    }

    fn assemble_parts(
        &self,
        stats: &RawStats,
        gamma: f64,
        parts: &[Vec<f64>],
    ) -> Option<Box<dyn FisherInverse + Send>> {
        if self.r == 1 {
            return super::precond::BlockDiagPrecond.assemble_parts(stats, gamma, parts);
        }
        if parts.len() != stats.num_layers() {
            return None;
        }
        let mut blocks = Vec::with_capacity(parts.len());
        for (layer, part) in parts.iter().enumerate() {
            let a = stats.aa[layer].rows;
            let g = stats.gg[layer].rows;
            blocks.push(KronPairInverse::from_flat(a, g, part)?);
        }
        Some(Box::new(KpsvdInverse { blocks }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::stats::KfacStats;
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    fn toy_stats() -> (Arch, RawStats) {
        let arch =
            Arch::new(vec![5, 4, 3], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(64, 5, 1.0, &mut rng);
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        let mut st = KfacStats::new(&arch);
        st.update(&RawStats::from_batch(&fwd, &gs));
        (arch, st.s)
    }

    #[test]
    fn rank2_fit_recovers_damped_block_to_machine_precision() {
        // T = Ā⊗G + γ²I is exactly Kronecker-rank 2, so the block
        // power iteration must recover it (dense check per layer).
        let (_, stats) = toy_stats();
        let gamma = 0.7;
        for i in 0..stats.num_layers() {
            let target = kron(&stats.aa[i], &stats.gg[i]).add_diag(gamma * gamma);
            let fit = fitted_dense(&stats.aa[i], &stats.gg[i], gamma, 2);
            let err = fit.sub(&target).frob_norm();
            assert!(err < 1e-10 * target.frob_norm(), "layer {i}: rel err {err}");
        }
    }

    #[test]
    fn rank2_apply_matches_exact_tikhonov_dense() {
        // The rank-2 inverse is (Ā⊗G + γ²I)⁻¹ — dense cross-check.
        let (arch, stats) = toy_stats();
        let gamma = 0.4;
        let inv = KpsvdInverse::build(&stats, gamma);
        let mut rng = Rng::new(5);
        let grads = Params(
            (0..arch.num_layers())
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::randn(r, c, 1.0, &mut rng)
                })
                .collect(),
        );
        let got = inv.apply(&grads);
        for i in 0..arch.num_layers() {
            let dense = kron(&stats.aa[i], &stats.gg[i]).add_diag(gamma * gamma).inverse();
            let want = unvec(
                &dense.matvec(&vec_mat(&grads.0[i])),
                grads.0[i].rows,
                grads.0[i].cols,
            );
            let err = got.0[i].sub(&want).max_abs();
            assert!(err < 1e-7, "layer {i} err={err}");
        }
    }

    #[test]
    fn gamma_zero_degenerates_to_single_term() {
        // At γ = 0 the target is a single Kronecker product; the fit
        // must drop the second term rather than keep numerical noise.
        let (_, stats) = toy_stats();
        let t = fit_terms(&stats.aa[0], &stats.gg[0], 0.0);
        assert!(t.cd.is_none(), "γ=0 fit kept a spurious second term");
        let target = kron(&stats.aa[0], &stats.gg[0]);
        let err = kron(&t.a, &t.b).sub(&target).frob_norm();
        assert!(err < 1e-10 * target.frob_norm());
    }

    #[test]
    fn dominant_pair_is_positive_definite() {
        let (_, stats) = toy_stats();
        for i in 0..stats.num_layers() {
            let t = fit_terms(&stats.aa[i], &stats.gg[i], 0.9);
            for (name, m) in [("a", &t.a), ("b", &t.b)] {
                let e = crate::linalg::SymEig::new(m);
                assert!(
                    e.w.iter().all(|&w| w > -1e-10 * (1.0 + m.max_abs())),
                    "layer {i}: dominant factor {name} has negative eigenvalue"
                );
            }
        }
    }

    #[test]
    fn rank_validation_panics_descriptively() {
        let err = std::panic::catch_unwind(|| KpsvdPrecond::new(3)).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("rank must be 1 or 2"), "unexpected message: {msg}");
    }

    #[test]
    fn rank2_shard_parts_match_replicated_build_bitwise() {
        let (arch, stats) = toy_stats();
        let gamma = 0.6;
        let pre = KpsvdPrecond::new(2);
        let parts: Vec<Vec<f64>> = (0..stats.num_layers())
            .map(|i| {
                let part = pre.build_layer_part(&stats, gamma, i);
                assert_eq!(part.len(), pre.layer_part_len(&stats, i).unwrap());
                part
            })
            .collect();
        let assembled = pre.assemble_parts(&stats, gamma, &parts).expect("assembles");
        let plain = KpsvdInverse::build(&stats, gamma);
        let mut rng = Rng::new(9);
        let g = Params(
            (0..arch.num_layers())
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::randn(r, c, 1.0, &mut rng)
                })
                .collect(),
        );
        let (ua, ub) = (assembled.apply(&g), plain.apply(&g));
        for (a, b) in ua.0.iter().zip(ub.0.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
