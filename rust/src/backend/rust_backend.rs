//! Pure-Rust model backend over the `nn` substrate.

use super::{BatchStats, ModelBackend};
use crate::fisher::stats::RawStats;
use crate::linalg::{KronBasis, Mat};
use crate::nn::net::Net;
use crate::nn::{Arch, Params};
use crate::rng::Rng;

/// f64 reference backend. Deterministic given the per-call `seed`.
pub struct RustBackend {
    net: Net,
}

impl RustBackend {
    pub fn new(arch: Arch) -> RustBackend {
        RustBackend { net: Net::new(arch) }
    }

    pub fn net(&self) -> &Net {
        &self.net
    }
}

impl ModelBackend for RustBackend {
    fn arch(&self) -> &Arch {
        &self.net.arch
    }

    fn loss(&mut self, p: &Params, x: &Mat, y: &Mat) -> f64 {
        self.net.loss(p, x, y)
    }

    fn eval(&mut self, p: &Params, x: &Mat, y: &Mat) -> (f64, f64) {
        let fwd = self.net.forward(p, x);
        let loss = self.net.arch.loss.loss(fwd.z(), y);
        let err = self.net.arch.loss.error(fwd.z(), y);
        (loss, err)
    }

    fn grad(&mut self, p: &Params, x: &Mat, y: &Mat) -> (f64, Params) {
        self.net.loss_and_grad(p, x, y)
    }

    fn grad_and_stats(
        &mut self,
        p: &Params,
        x: &Mat,
        y: &Mat,
        stats_rows: usize,
        seed: u64,
    ) -> (f64, Params, BatchStats) {
        let fwd = self.net.forward(p, x);
        let loss = self.net.arch.loss.loss(fwd.z(), y);
        let dz = self.net.arch.loss.dz(fwd.z(), y);
        let gs = self.net.backward(p, &fwd, &dz);
        let grads = self.net.grads_from(&fwd, &gs);

        // Statistics on the τ₁ subset with model-sampled targets
        // (Section 5): one extra backward pass.
        let rows = stats_rows.clamp(1, x.rows);
        let xs = x.top_rows(rows);
        let sfwd = self.net.forward(p, &xs);
        let mut rng = Rng::new(seed);
        let sgs = self.net.sampled_backward(p, &sfwd, &mut rng);
        let stats = RawStats::from_batch(&sfwd, &sgs);
        (loss, grads, stats)
    }

    fn fvp_quad(&mut self, p: &Params, x: &Mat, fvp_rows: usize, dirs: &[&Params]) -> Mat {
        let rows = fvp_rows.clamp(1, x.rows);
        let xs = x.top_rows(rows);
        self.net.fvp_quad(p, &xs, dirs)
    }

    fn grad_sq_in_basis(
        &mut self,
        p: &Params,
        x: &Mat,
        _y: &Mat,
        rows: usize,
        seed: u64,
        bases: &[KronBasis],
    ) -> Vec<Mat> {
        // Model-sampled targets (Section 5), like `grad_and_stats`:
        // the second moments estimate the standard Fisher, so `y` is
        // unused here. One forward + one sampled backward pass.
        let rows = rows.clamp(1, x.rows);
        let xs = x.top_rows(rows);
        let fwd = self.net.forward(p, &xs);
        let mut rng = Rng::new(seed);
        let gs = self.net.sampled_backward(p, &fwd, &mut rng);
        self.net.grad_sq_in_basis(&fwd, &gs, bases)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Act, LossKind};

    #[test]
    fn backend_consistent_with_net() {
        let arch = Arch::new(vec![4, 3, 2], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
        let mut be = RustBackend::new(arch.clone());
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(10, 4, 1.0, &mut rng);
        let mut y = Mat::zeros(10, 2);
        for r in 0..10 {
            y.set(r, r % 2, 1.0);
        }
        let (l1, g) = be.grad(&p, &x, &y);
        let l2 = be.loss(&p, &x, &y);
        assert!((l1 - l2).abs() < 1e-14);
        let (l3, g2, stats) = be.grad_and_stats(&p, &x, &y, 5, 7);
        assert!((l1 - l3).abs() < 1e-14);
        assert!(g.0[0].sub(&g2.0[0]).max_abs() < 1e-14);
        assert_eq!(stats.aa[0].rows, 5);
        // deterministic given seed
        let (_, _, stats2) = be.grad_and_stats(&p, &x, &y, 5, 7);
        assert!(stats.gg[0].sub(&stats2.gg[0]).max_abs() == 0.0);
    }

    #[test]
    fn grad_sq_in_basis_is_deterministic_and_matches_net() {
        let arch = Arch::new(vec![4, 3, 2], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
        let mut be = RustBackend::new(arch.clone());
        let mut rng = Rng::new(2);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(12, 4, 1.0, &mut rng);
        let y = Mat::zeros(12, 2);
        let ortho = |n: usize, rng: &mut Rng| {
            crate::linalg::SymEig::new_jacobi(&Mat::randn(n, n, 1.0, rng).symmetrize()).v
        };
        let bases: Vec<KronBasis> = (0..arch.num_layers())
            .map(|i| {
                let (r, c) = arch.weight_shape(i);
                KronBasis { ua: ortho(c, &mut rng), ug: ortho(r, &mut rng) }
            })
            .collect();
        let rows = 8;
        let seed = 5;
        let s1 = be.grad_sq_in_basis(&p, &x, &y, rows, seed, &bases);
        let s2 = be.grad_sq_in_basis(&p, &x, &y, rows, seed, &bases);
        for (a, b) in s1.iter().zip(s2.iter()) {
            assert!(a.sub(b).max_abs() == 0.0, "not deterministic given seed");
        }
        // shapes are weight-shaped, entries are non-negative means
        for (i, s) in s1.iter().enumerate() {
            assert_eq!((s.rows, s.cols), arch.weight_shape(i));
            assert!(s.data.iter().all(|v| *v >= 0.0 && v.is_finite()));
        }
        // consistent with the documented contract: forward on the τ₁
        // rows, then a sampled backward seeded by `seed`
        let net = be.net().clone();
        let xs = x.top_rows(rows);
        let fwd = net.forward(&p, &xs);
        let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(seed));
        let want = net.grad_sq_in_basis(&fwd, &gs, &bases);
        for (a, b) in s1.iter().zip(want.iter()) {
            assert!(a.sub(b).max_abs() == 0.0, "backend deviates from Net contract");
        }
    }
}
