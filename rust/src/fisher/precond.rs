//! The open preconditioner seam.
//!
//! K-FAC's outer loop (statistics → damped inverse → rescaled update)
//! is generic over the curvature structure used for the update
//! proposal. [`Preconditioner`] is the factory interface the optimizer
//! calls at every inverse refresh: given the current factor statistics
//! and a damping strength γ, build a [`FisherInverse`] it can apply to
//! gradients until the next refresh.
//!
//! The paper's two structures (block-diagonal §4.2, block-tridiagonal
//! §4.3) and the EKFAC eigenbasis-diagonal structure (George et al.
//! 2018) ship as built-in implementations; external code can implement
//! the trait and (optionally) [`register`] instances under a name so
//! CLIs and config files can select them.

use super::blockdiag::BlockDiagInverse;
use super::ekfac::EkfacInverse;
use super::ikfac::IkfacPrecond;
use super::kfc::KfcPrecond;
use super::kpsvd::KpsvdPrecond;
use super::stats::RawStats;
use super::tridiag::TridiagInverse;
use super::FisherInverse;
use crate::nn::Arch;
use std::sync::{Arc, Mutex, OnceLock};

/// Shared handle to a (stateless) preconditioner factory.
pub type PrecondRef = Arc<dyn Preconditioner + Send + Sync>;

/// Factory for approximate Fisher inverses: the structure-specific
/// part of a K-FAC-family optimizer.
pub trait Preconditioner {
    /// Stable identifier (used by CLIs, logs and the registry).
    fn name(&self) -> &str;

    /// Build the approximate inverse from factor statistics with
    /// damping strength `gamma`. Must be deterministic in its inputs —
    /// checkpoint resume rebuilds cached inverses through this method
    /// and relies on bit-identical results.
    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send>;

    /// Whether this structure's factor semantics are defined for
    /// `arch`. The default accepts everything; structures whose
    /// cross-layer or eigenbasis machinery is only validated for dense
    /// nets (tridiag, EKFAC) return a descriptive `Err` here so the
    /// optimizer can fail at construction time instead of silently
    /// degrading (see "Optional capabilities" in [`super`]).
    fn check_arch(&self, arch: &Arch) -> Result<(), String> {
        let _ = arch;
        Ok(())
    }

    /// Whether [`FisherInverse::update`] may accept stats deltas for
    /// this structure (the incremental-update capability; default
    /// `false`). When `true`, the optimizer offers the drift since the
    /// last rebuild at each `t_inv` boundary before falling back to a
    /// full `build`.
    fn incremental(&self) -> bool {
        false
    }

    /// Flat length of layer `layer`'s independently-buildable part, or
    /// `None` if this structure cannot shard its build per layer (the
    /// default). When `Some` for every layer, `dist::sharded_build` splits
    /// the refresh round-robin across ranks via `build_layer_part` /
    /// `assemble_parts`; otherwise every rank falls back to a replicated
    /// `build` from the (identical, already all-reduced) statistics.
    fn layer_part_len(&self, stats: &RawStats, layer: usize) -> Option<usize> {
        let _ = (stats, layer);
        None
    }

    /// Factorize layer `layer` only, returning exactly
    /// `layer_part_len(stats, layer)` f64s. Must be bitwise identical to
    /// the corresponding slice of a full `build` — resume and the
    /// `ranks=1` equivalence contract depend on it.
    fn build_layer_part(&self, stats: &RawStats, gamma: f64, layer: usize) -> Vec<f64> {
        let _ = (stats, gamma, layer);
        Vec::new()
    }

    /// Reassemble a full inverse from one part per layer (each produced by
    /// `build_layer_part` on some rank and broadcast). Returns `None` when
    /// the structure does not support sharding or a part is malformed.
    fn assemble_parts(
        &self,
        stats: &RawStats,
        gamma: f64,
        parts: &[Vec<f64>],
    ) -> Option<Box<dyn FisherInverse + Send>> {
        let _ = (stats, gamma, parts);
        None
    }
}

/// `F̌⁻¹` — block-diagonal (paper §4.2), factored Tikhonov damping.
pub struct BlockDiagPrecond;

impl Preconditioner for BlockDiagPrecond {
    fn name(&self) -> &str {
        "blkdiag"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        Box::new(BlockDiagInverse::build(stats, gamma))
    }

    fn layer_part_len(&self, stats: &RawStats, layer: usize) -> Option<usize> {
        let a = stats.aa[layer].rows;
        let g = stats.gg[layer].rows;
        Some(a * a + g * g)
    }

    fn build_layer_part(&self, stats: &RawStats, gamma: f64, layer: usize) -> Vec<f64> {
        // Mirrors BlockDiagInverse::build's per-layer closure exactly so a
        // sharded refresh is bitwise identical to a replicated one.
        super::check_factors_finite("blkdiag", layer, &stats.aa[layer], &stats.gg[layer]);
        let (ad, gd) = super::damping::damped_factors(&stats.aa[layer], &stats.gg[layer], gamma);
        let ainv = crate::linalg::chol::spd_inverse(&ad);
        let ginv = crate::linalg::chol::spd_inverse(&gd);
        let mut out = ainv.data;
        out.extend_from_slice(&ginv.data);
        out
    }

    fn assemble_parts(
        &self,
        stats: &RawStats,
        _gamma: f64,
        parts: &[Vec<f64>],
    ) -> Option<Box<dyn FisherInverse + Send>> {
        if parts.len() != stats.num_layers() {
            return None;
        }
        let mut ainv = Vec::with_capacity(parts.len());
        let mut ginv = Vec::with_capacity(parts.len());
        for (layer, part) in parts.iter().enumerate() {
            let a = stats.aa[layer].rows;
            let g = stats.gg[layer].rows;
            if part.len() != a * a + g * g {
                return None;
            }
            ainv.push(crate::linalg::Mat::from_vec(a, a, part[..a * a].to_vec()));
            ginv.push(crate::linalg::Mat::from_vec(g, g, part[a * a..].to_vec()));
        }
        Some(Box::new(BlockDiagInverse { ainv, ginv }))
    }
}

/// `F̂⁻¹` — block-tridiagonal (paper §4.3), factored Tikhonov damping.
pub struct TridiagPrecond;

impl Preconditioner for TridiagPrecond {
    fn name(&self) -> &str {
        "blktridiag"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        Box::new(TridiagInverse::build(stats, gamma))
    }

    fn check_arch(&self, arch: &Arch) -> Result<(), String> {
        if arch.has_conv() {
            return Err(
                "blktridiag is unsupported on conv architectures: the adjacent \
                 off-diagonal factors Ā_{i,i+1}/G_{i,i+1} are identically zero for \
                 any pair touching a conv layer, which silently degrades the \
                 structure to block-diagonal at tridiagonal cost — use kfac_kfc or \
                 kfac_blkdiag instead"
                    .to_string(),
            );
        }
        Ok(())
    }
}

/// EKFAC — diagonal rescaling in the Kronecker eigenbasis with exact
/// (eigenbasis) Tikhonov damping.
pub struct EkfacPrecond;

impl Preconditioner for EkfacPrecond {
    fn name(&self) -> &str {
        "ekfac"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        Box::new(EkfacInverse::build(stats, gamma))
    }

    fn check_arch(&self, arch: &Arch) -> Result<(), String> {
        if arch.has_conv() {
            return Err(
                "ekfac is unsupported on conv architectures: the per-example \
                 eigenbasis scale re-estimation is only validated for dense layers \
                 — use kfac_kfc or kfac_blkdiag instead"
                    .to_string(),
            );
        }
        Ok(())
    }
}

/// The block-diagonal preconditioner (paper §4.2).
pub fn block_diag() -> PrecondRef {
    Arc::new(BlockDiagPrecond)
}

/// The block-tridiagonal preconditioner (paper §4.3, the default).
pub fn block_tridiag() -> PrecondRef {
    Arc::new(TridiagPrecond)
}

/// The EKFAC eigenbasis-diagonal preconditioner.
pub fn ekfac() -> PrecondRef {
    Arc::new(EkfacPrecond)
}

/// The KFC preconditioner (Grosse & Martens 2016): block-diagonal with
/// conv-aware Kronecker factor semantics.
pub fn kfc() -> PrecondRef {
    Arc::new(KfcPrecond)
}

/// The KPSVD rank-R Kronecker-sum preconditioner (Koroko et al. 2022).
/// Rank from `KFAC_KPSVD_RANK` (default 2; rank 1 is bitwise identical
/// to `blkdiag`).
pub fn kpsvd() -> PrecondRef {
    Arc::new(KpsvdPrecond::new(super::kpsvd::rank_from_env()))
}

/// The iterative K-FAC preconditioner (Chen 2021): rank-k Woodbury
/// inverse corrections between full rebuilds. Rank from
/// `KFAC_IKFAC_RANK` (default 4), rebuild trigger from
/// `KFAC_IKFAC_DRIFT` (default 0.5).
pub fn ikfac() -> PrecondRef {
    Arc::new(IkfacPrecond::new(super::ikfac::rank_from_env(), super::ikfac::drift_from_env()))
}

fn registry() -> &'static Mutex<Vec<PrecondRef>> {
    static REG: OnceLock<Mutex<Vec<PrecondRef>>> = OnceLock::new();
    REG.get_or_init(|| {
        Mutex::new(vec![block_diag(), block_tridiag(), ekfac(), kfc(), kpsvd(), ikfac()])
    })
}

/// Register a preconditioner under its `name()`, replacing any
/// previous registration with the same name.
pub fn register(p: PrecondRef) {
    let mut reg = registry().lock().unwrap();
    let name = p.name().to_string();
    reg.retain(|q| q.name() != name);
    reg.push(p);
}

/// Look up a registered preconditioner by name.
pub fn from_name(name: &str) -> Option<PrecondRef> {
    registry().lock().unwrap().iter().find(|p| p.name() == name).cloned()
}

/// Names of all registered preconditioners (for CLI help/errors).
pub fn names() -> Vec<String> {
    registry().lock().unwrap().iter().map(|p| p.name().to_string()).collect()
}

/// Resolve a CLI `--optimizer` value to a preconditioner through the
/// registry: `"kfac"` is the paper's default (block-tridiagonal), and
/// `"kfac_<name>"` selects any registered structure — built-in or
/// plugged in via [`register`] — with zero per-structure CLI code. The
/// `Err` lists the live registry contents so the usage message stays
/// accurate as structures come and go. (`"sgd"` is not a
/// preconditioner and is handled before this by the caller.)
pub fn resolve_optimizer(optimizer: &str) -> Result<PrecondRef, String> {
    let name = match optimizer {
        "kfac" => "blktridiag",
        other => match other.strip_prefix("kfac_") {
            Some(rest) if !rest.is_empty() => rest,
            _ => {
                return Err(format!(
                    "unknown optimizer '{optimizer}' (expected sgd, kfac, or kfac_<p> with \
                     p one of: {})",
                    names().join(", ")
                ))
            }
        },
    };
    from_name(name).ok_or_else(|| {
        format!(
            "unknown preconditioner '{name}' in optimizer '{optimizer}' (registered: {})",
            names().join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::stats::KfacStats;
    use crate::linalg::Mat;
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind, Params};
    use crate::rng::Rng;

    fn toy_stats() -> (Arch, RawStats) {
        let arch = Arch::new(
            vec![5, 4, 3],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(64, 5, 1.0, &mut rng);
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        let mut st = KfacStats::new(&arch);
        st.update(&crate::fisher::RawStats::from_batch(&fwd, &gs));
        (arch, st.s)
    }

    #[test]
    fn builtins_are_registered() {
        for name in ["blkdiag", "blktridiag", "ekfac", "kfc", "kpsvd", "ikfac"] {
            let p = from_name(name).unwrap_or_else(|| panic!("{name} not registered"));
            assert_eq!(p.name(), name);
        }
        assert!(from_name("nonexistent").is_none());
        let all = names();
        assert!(all.iter().any(|n| n == "ekfac"), "names() missing ekfac: {all:?}");
    }

    #[test]
    fn resolve_optimizer_is_registry_driven() {
        assert_eq!(resolve_optimizer("kfac").unwrap().name(), "blktridiag");
        for name in ["blkdiag", "blktridiag", "ekfac", "kfc", "kpsvd", "ikfac"] {
            let p = resolve_optimizer(&format!("kfac_{name}")).unwrap();
            assert_eq!(p.name(), name);
        }
        // strict parse: no prefix-matching, no empty suffix, and the
        // error names the live registry so CLI help stays accurate
        for bad in ["kfacx", "kfac_", "kfac_nope", "adam", ""] {
            let err = resolve_optimizer(bad).unwrap_err();
            assert!(err.contains("blkdiag"), "error for '{bad}' should list registry: {err}");
        }
        // a plugged-in structure is immediately CLI-reachable
        struct ResolvePlug;
        impl Preconditioner for ResolvePlug {
            fn name(&self) -> &str {
                "resolve-plug-test"
            }
            fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
                Box::new(BlockDiagInverse::build(stats, gamma))
            }
        }
        register(Arc::new(ResolvePlug));
        assert_eq!(resolve_optimizer("kfac_resolve-plug-test").unwrap().name(), "resolve-plug-test");
    }

    #[test]
    fn conv_fences_reject_at_construction_only_for_conv() {
        use crate::linalg::pack::ConvShape;
        use crate::nn::Layer;
        let shape = ConvShape { in_h: 8, in_w: 8, in_c: 1, kh: 3, kw: 3, stride: 2, pad: 1 };
        let conv_arch = Arch::from_layers(
            vec![
                Layer::Conv2d { shape, out_c: 4, act: Act::Tanh },
                Layer::Dense { d_in: 64, d_out: 10, act: Act::Identity },
            ],
            LossKind::SoftmaxCe,
        );
        let (dense_arch, _) = toy_stats();
        let tri_err = block_tridiag().check_arch(&conv_arch).unwrap_err();
        assert!(
            tri_err.contains("unsupported on conv architectures"),
            "tridiag fence message changed: {tri_err}"
        );
        let ek_err = ekfac().check_arch(&conv_arch).unwrap_err();
        assert!(
            ek_err.contains("unsupported on conv architectures"),
            "ekfac fence message changed: {ek_err}"
        );
        // conv-capable structures and all-dense nets are unaffected
        for name in names() {
            let p = from_name(&name).unwrap();
            assert!(
                p.check_arch(&dense_arch).is_ok(),
                "{name} must accept all-dense architectures"
            );
        }
        for p in [block_diag(), kfc(), kpsvd(), ikfac()] {
            assert!(p.check_arch(&conv_arch).is_ok(), "{} must accept conv", p.name());
        }
    }

    #[test]
    fn capability_pairs_are_all_or_nothing() {
        // Every registered preconditioner must implement each optional
        // capability pair completely or not at all (the convention
        // documented in the fisher module docs).
        let (arch, stats) = toy_stats();
        let gamma = 0.5;
        let l = stats.num_layers();
        for name in names() {
            let p = from_name(&name).unwrap();
            if p.check_arch(&arch).is_err() {
                continue;
            }
            // -- sharded-build trio --
            let lens: Vec<Option<usize>> = (0..l).map(|i| p.layer_part_len(&stats, i)).collect();
            let shardable = lens[0].is_some();
            assert!(
                lens.iter().all(|len| len.is_some() == shardable),
                "{name}: layer_part_len must be Some for all layers or none"
            );
            if shardable {
                let parts: Vec<Vec<f64>> =
                    (0..l).map(|i| p.build_layer_part(&stats, gamma, i)).collect();
                for (i, part) in parts.iter().enumerate() {
                    assert_eq!(
                        part.len(),
                        lens[i].unwrap(),
                        "{name}: build_layer_part length must match layer_part_len"
                    );
                }
                let asm = p.assemble_parts(&stats, gamma, &parts);
                assert!(asm.is_some(), "{name}: shardable but assemble_parts declined");
            } else {
                assert!(
                    p.build_layer_part(&stats, gamma, 0).is_empty(),
                    "{name}: non-shardable build_layer_part must stay inert"
                );
                assert!(
                    p.assemble_parts(&stats, gamma, &[]).is_none(),
                    "{name}: non-shardable assemble_parts must stay inert"
                );
            }
            // -- incremental-update pair --
            let mut inv = p.build(&stats, gamma);
            let zero = stats.delta_from(&stats);
            let outcome = inv.update(&zero, gamma);
            if p.incremental() {
                assert_eq!(
                    outcome,
                    crate::fisher::UpdateOutcome::Updated,
                    "{name}: incremental() but update declined a zero delta"
                );
            } else {
                assert_eq!(
                    outcome,
                    crate::fisher::UpdateOutcome::NeedsRebuild,
                    "{name}: not incremental() but update accepted a delta"
                );
            }
            // -- eigenbasis-scales pair --
            let mut inv = p.build(&stats, gamma);
            let has_bases = inv.eigenbases().is_some();
            let scales: Vec<Mat> = (0..l)
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::from_fn(r, c, |_, _| 1.0)
                })
                .collect();
            let accepted = inv.set_scales(&scales, gamma);
            assert_eq!(
                has_bases, accepted,
                "{name}: eigenbases()/set_scales must be implemented together"
            );
        }
    }

    #[test]
    fn every_builtin_builds_a_working_inverse() {
        let (arch, stats) = toy_stats();
        let mut rng = Rng::new(2);
        let grads = Params(
            (0..arch.num_layers())
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::randn(r, c, 1.0, &mut rng)
                })
                .collect(),
        );
        for p in [block_diag(), block_tridiag(), ekfac(), kfc(), kpsvd(), ikfac()] {
            let inv = p.build(&stats, 0.5);
            let u = inv.apply(&grads);
            assert_eq!(u.0.len(), grads.0.len(), "{}", p.name());
            assert!(
                u.0.iter().all(|m| m.data.iter().all(|v| v.is_finite())),
                "{} produced non-finite update",
                p.name()
            );
            // descent-direction sanity: ⟨g, F⁻¹g⟩ > 0
            assert!(grads.dot(&u) > 0.0, "{} not positive definite", p.name());
        }
    }

    #[test]
    fn external_preconditioners_plug_in() {
        // The seam is open: a custom structure registers and resolves
        // like the built-ins.
        struct IdentityInverse;
        impl FisherInverse for IdentityInverse {
            fn apply(&self, grads: &Params) -> Params {
                grads.clone()
            }
        }
        struct IdentityPrecond;
        impl Preconditioner for IdentityPrecond {
            fn name(&self) -> &str {
                "identity-test"
            }
            fn build(&self, _stats: &RawStats, _gamma: f64) -> Box<dyn FisherInverse + Send> {
                Box::new(IdentityInverse)
            }
        }
        register(Arc::new(IdentityPrecond));
        let p = from_name("identity-test").expect("custom preconditioner registered");
        let (_, stats) = toy_stats();
        let mut rng = Rng::new(3);
        let g = Params(vec![Mat::randn(4, 6, 1.0, &mut rng)]);
        let u = p.build(&stats, 1.0).apply(&g);
        assert!(u.0[0].sub(&g.0[0]).max_abs() < 1e-15);
    }
}
