//! Procedural MNIST substitute: per-class stroke templates with elastic
//! jitter, rendered with anti-aliased thick lines and blurred — pixel
//! intensities in [0, 1], like MNIST after the usual /255 scaling.

use super::{blur, draw_segment, Dataset};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Stroke templates (polylines in the unit square) for digits 0–9.
fn digit_strokes(d: usize) -> Vec<Vec<(f64, f64)>> {
    match d {
        0 => vec![vec![
            (0.5, 0.15),
            (0.75, 0.3),
            (0.75, 0.7),
            (0.5, 0.85),
            (0.25, 0.7),
            (0.25, 0.3),
            (0.5, 0.15),
        ]],
        1 => vec![vec![(0.35, 0.3), (0.55, 0.15), (0.55, 0.85)]],
        2 => vec![vec![(0.27, 0.3), (0.5, 0.15), (0.72, 0.3), (0.3, 0.85), (0.75, 0.85)]],
        3 => vec![vec![
            (0.3, 0.2),
            (0.7, 0.2),
            (0.45, 0.48),
            (0.72, 0.68),
            (0.5, 0.87),
            (0.28, 0.78),
        ]],
        4 => vec![vec![(0.65, 0.85), (0.65, 0.15), (0.25, 0.6), (0.8, 0.6)]],
        5 => vec![vec![
            (0.72, 0.15),
            (0.3, 0.15),
            (0.28, 0.5),
            (0.65, 0.45),
            (0.72, 0.7),
            (0.45, 0.87),
            (0.27, 0.78),
        ]],
        6 => vec![vec![
            (0.68, 0.18),
            (0.35, 0.4),
            (0.28, 0.7),
            (0.5, 0.87),
            (0.7, 0.7),
            (0.55, 0.5),
            (0.3, 0.6),
        ]],
        7 => vec![vec![(0.25, 0.15), (0.75, 0.15), (0.45, 0.85)]],
        8 => vec![
            vec![(0.5, 0.15), (0.68, 0.3), (0.5, 0.48), (0.32, 0.3), (0.5, 0.15)],
            vec![(0.5, 0.48), (0.72, 0.68), (0.5, 0.87), (0.28, 0.68), (0.5, 0.48)],
        ],
        9 => vec![vec![
            (0.7, 0.4),
            (0.45, 0.5),
            (0.3, 0.3),
            (0.5, 0.13),
            (0.7, 0.3),
            (0.68, 0.6),
            (0.5, 0.87),
        ]],
        _ => unreachable!(),
    }
}

/// Render one jittered digit as a `side*side` image row.
pub fn render_digit(d: usize, side: usize, rng: &mut Rng) -> Vec<f64> {
    let mut img = vec![0.0; side * side];
    // global affine jitter
    let (sx, sy) = (0.85 + 0.3 * rng.uniform(), 0.85 + 0.3 * rng.uniform());
    let (tx, ty) = (0.08 * (rng.uniform() - 0.5), 0.08 * (rng.uniform() - 0.5));
    let rot = 0.25 * (rng.uniform() - 0.5);
    let (cr, sr) = (rot.cos(), rot.sin());
    let jitter = 0.03;
    for stroke in digit_strokes(d) {
        let pts: Vec<(f64, f64)> = stroke
            .iter()
            .map(|&(x, y)| {
                // jitter control points, then affine around center
                let (mut x, mut y) = (x + jitter * rng.normal(), y + jitter * rng.normal());
                x = (x - 0.5) * sx;
                y = (y - 0.5) * sy;
                let (xr, yr) = (cr * x - sr * y, sr * x + cr * y);
                (xr + 0.5 + tx, yr + 0.5 + ty)
            })
            .collect();
        for w in pts.windows(2) {
            draw_segment(&mut img, side, w[0].0, w[0].1, w[1].0, w[1].1, 0.055);
        }
    }
    img
}

/// Classification dataset: `x` is `n × side²`, `y` is one-hot `n × 10`.
pub fn classification_dataset(n: usize, side: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Mat::zeros(n, side * side);
    let mut y = Mat::zeros(n, 10);
    for r in 0..n {
        let d = r % 10;
        let img = render_digit(d, side, &mut rng);
        x.row_mut(r).copy_from_slice(&img);
        y.set(r, d, 1.0);
    }
    let x = blur(&x);
    Dataset::new(x, y)
}

/// Autoencoding dataset: targets equal inputs.
pub fn autoencoder_dataset(n: usize, side: usize, seed: u64) -> Dataset {
    let ds = classification_dataset(n, side, seed);
    Dataset::new(ds.x.clone(), ds.x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixels_in_unit_interval_and_nontrivial() {
        let ds = classification_dataset(100, 16, 1);
        assert_eq!(ds.x.cols, 256);
        assert!(ds.x.data.iter().all(|v| (0.0..=1.0).contains(v)));
        let mean = ds.x.sum() / ds.x.data.len() as f64;
        assert!(mean > 0.02 && mean < 0.5, "mean={mean}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // same-class images should correlate more than cross-class ones
        let ds = classification_dataset(200, 16, 2);
        let corr = |a: usize, b: usize| {
            let (ra, rb) = (ds.x.row(a), ds.x.row(b));
            let dot: f64 = ra.iter().zip(rb).map(|(x, y)| x * y).sum();
            let na: f64 = ra.iter().map(|v| v * v).sum::<f64>().sqrt();
            let nb: f64 = rb.iter().map(|v| v * v).sum::<f64>().sqrt();
            dot / (na * nb + 1e-12)
        };
        // rows 0,10,20.. are class 0; row 1 is class 1
        let same = (corr(0, 10) + corr(0, 20) + corr(10, 30)) / 3.0;
        let diff = (corr(0, 1) + corr(0, 7) + corr(10, 3)) / 3.0;
        assert!(same > diff, "same={same} diff={diff}");
    }

    #[test]
    fn autoencoder_targets_equal_inputs() {
        let ds = autoencoder_dataset(20, 16, 3);
        assert!(ds.x.sub(&ds.y).max_abs() == 0.0);
    }
}
