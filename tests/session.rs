//! Integration tests for the redesigned optimization API: the
//! `TrainSession` builder, versioned checkpoint save→load→resume
//! (bit-exact trajectories), golden-seed determinism of `Kfac`/`Sgd`
//! behind the `Optimizer` trait, and the EKFAC preconditioner plugged
//! through the `Preconditioner` seam.

use kfac::coordinator::{checkpoint, Event, LogRow, Problem, TrainSession};
use kfac::data::mnist_like;
use kfac::fisher::precond;
use kfac::nn::{Act, Arch, Params};
use kfac::optim::{BatchSchedule, Kfac, KfacConfig, Optimizer, Sgd, SgdConfig};
use kfac::rng::Rng;
use std::path::PathBuf;

fn small_setup() -> (Arch, kfac::data::Dataset) {
    let arch = Arch::autoencoder(&[64, 24, 8, 24, 64], Act::Tanh);
    let ds = mnist_like::autoencoder_dataset(128, 8, 3);
    (arch, ds)
}

fn kfac_cfg() -> KfacConfig {
    // pinned synchronous so the bit-exactness tests measure the same
    // trajectory on the KFAC_ASYNC=1 CI leg
    KfacConfig { lambda0: 5.0, refresh_async: false, ..Default::default() }
}

fn tmp_ckpt(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("kfac_session_tests/{name}.ckpt"))
}

/// Log rows must match bit-for-bit on everything except wall-clock.
fn assert_rows_bit_equal(a: &LogRow, b: &LogRow, what: &str) {
    assert_eq!(a.iter, b.iter, "{what}: iter");
    assert_eq!(a.cases.to_bits(), b.cases.to_bits(), "{what}: cases");
    assert_eq!(a.batch_loss.to_bits(), b.batch_loss.to_bits(), "{what}: batch_loss");
    assert_eq!(a.train_err.to_bits(), b.train_err.to_bits(), "{what}: train_err");
    assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{what}: train_loss");
}

#[test]
fn checkpoint_save_load_resume_is_bit_exact() {
    let (arch, ds) = small_setup();
    let seed = 42u64;
    let init = arch.sparse_init(&mut Rng::new(seed));
    let session = |opt: Kfac, iters: usize| {
        TrainSession::for_dataset(arch.clone(), &ds)
            .iters(iters)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(5)
            .eval_rows(64)
            .polyak(0.99)
            .seed(seed)
            .params(init.clone())
            .optimizer(opt)
    };

    // reference: 20 uninterrupted iterations
    let full = session(Kfac::new(&arch, kfac_cfg()), 20).run();

    // interrupted: 10 iterations with a checkpoint, then resume to 20
    let path = tmp_ckpt("bit_exact");
    let first_leg = session(Kfac::new(&arch, kfac_cfg()), 10).checkpoint_every(10, &path).run();
    assert!(path.exists(), "checkpoint file written");
    let resumed = session(Kfac::new(&arch, kfac_cfg()), 20).resume_from(&path).run();
    assert_eq!(resumed.iters_run, 10, "resume continues from iteration 10");

    // the resumed parameters must equal the uninterrupted run's exactly
    assert!(
        full.params == resumed.params,
        "resumed parameters differ from the uninterrupted run"
    );
    assert!(
        full.avg_params == resumed.avg_params,
        "resumed Polyak average differs from the uninterrupted run"
    );

    // and every post-resume evaluation point must match bit-for-bit
    for row in &resumed.log {
        let want = full
            .log
            .iter()
            .find(|r| r.iter == row.iter)
            .unwrap_or_else(|| panic!("uninterrupted run has no eval at iter {}", row.iter));
        assert_rows_bit_equal(want, row, "post-resume eval");
    }
    // the first leg's rows also prefix-match the uninterrupted run
    for row in &first_leg.log {
        let want = full.log.iter().find(|r| r.iter == row.iter).unwrap();
        assert_rows_bit_equal(want, row, "pre-checkpoint eval");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn ekfac_scale_state_checkpoint_roundtrip_is_bit_exact() {
    // The EKFAC amortized scale re-estimation adds mutable optimizer
    // state (running second moments in the current eigenbasis); a
    // checkpoint taken mid-refresh-interval must carry it and resume
    // bit-exactly. t_inv = 4 / t_scale = 3: at the k = 7 checkpoint the
    // scale epoch seeded at k = 6 is live and the next rebuild (k = 8)
    // has not yet happened.
    let (arch, ds) = small_setup();
    let seed = 11u64;
    let init = arch.sparse_init(&mut Rng::new(seed));
    let cfg = || KfacConfig {
        lambda0: 5.0,
        t_inv: 4,
        t_scale: 3,
        refresh_async: false,
        ..KfacConfig::ekfac()
    };
    let session = |opt: Kfac, iters: usize| {
        TrainSession::for_dataset(arch.clone(), &ds)
            .iters(iters)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(2)
            .eval_rows(64)
            .polyak(0.99)
            .seed(seed)
            .params(init.clone())
            .optimizer(opt)
    };
    let full = session(Kfac::new(&arch, cfg()), 14).run();
    let path = tmp_ckpt("ekfac_scales");
    session(Kfac::new(&arch, cfg()), 7).checkpoint_every(7, &path).run();

    // the checkpoint must carry the running scale state
    let ck = checkpoint::load(&path).unwrap();
    assert_eq!(ck.version, checkpoint::CHECKPOINT_VERSION);
    assert_eq!(ck.opt.str_val("precond"), Some("ekfac"));
    assert!(ck.opt.mats("scale_s").is_some(), "scale state missing from checkpoint");
    assert!(ck.opt.scalar("scale_k").is_some());

    let resumed = session(Kfac::new(&arch, cfg()), 14).resume_from(&path).run();
    assert!(full.params == resumed.params, "EKFAC scale resume diverged");
    assert!(full.avg_params == resumed.avg_params, "Polyak average diverged");
    for row in &resumed.log {
        let want = full.log.iter().find(|r| r.iter == row.iter).unwrap();
        assert_rows_bit_equal(want, row, "ekfac post-resume eval");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn v1_checkpoints_are_cleanly_rejected() {
    // The scale state bumped KFACCKPT to v2; a v1 file must be refused
    // with a version error, not mis-read into a diverging trajectory.
    assert_eq!(checkpoint::CHECKPOINT_VERSION, 2);
    let (arch, ds) = small_setup();
    let path = tmp_ckpt("v1_reject");
    TrainSession::for_dataset(arch.clone(), &ds)
        .iters(2)
        .schedule(BatchSchedule::Fixed(32))
        .eval_rows(32)
        .optimizer(Kfac::new(&arch, kfac_cfg()))
        .checkpoint_every(2, &path)
        .run();
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[8..12].copy_from_slice(&1u32.to_le_bytes()); // forge version 1
    std::fs::write(&path, &bytes).unwrap();
    let err = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(4)
        .optimizer(Kfac::new(&arch, kfac_cfg()))
        .resume_from(&path)
        .try_run()
        .unwrap_err();
    assert!(err.contains("version 1"), "unexpected error: {err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sgd_checkpoint_resume_is_bit_exact() {
    let (arch, ds) = small_setup();
    let init = arch.sparse_init(&mut Rng::new(7));
    let session = |iters: usize| {
        TrainSession::for_dataset(arch.clone(), &ds)
            .iters(iters)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(4)
            .eval_rows(64)
            .polyak(0.99)
            .seed(7)
            .params(init.clone())
            .optimizer(Sgd::new(SgdConfig { lr: 0.05, ..Default::default() }))
    };
    let full = session(16).run();
    let path = tmp_ckpt("sgd_bit_exact");
    session(8).checkpoint_every(8, &path).run();
    let resumed = session(16).resume_from(&path).run();
    assert!(full.params == resumed.params, "sgd resume diverged");
    for row in &resumed.log {
        let want = full.log.iter().find(|r| r.iter == row.iter).unwrap();
        assert_rows_bit_equal(want, row, "sgd post-resume eval");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn golden_seed_trajectories_are_deterministic_and_learn() {
    // The redesigned API must preserve the pre-refactor training
    // behaviour: fixed seeds give reproducible trajectories, and both
    // optimizers make progress through the same `Optimizer` trait.
    let (arch, ds) = small_setup();
    let run_kfac = || {
        TrainSession::for_dataset(arch.clone(), &ds)
            .iters(12)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(3)
            .eval_rows(64)
            .seed(5)
            .params(arch.sparse_init(&mut Rng::new(5)))
            .optimizer(Kfac::new(&arch, kfac_cfg()))
            .run()
    };
    let a = run_kfac();
    let b = run_kfac();
    assert_eq!(a.log.len(), b.log.len());
    for (ra, rb) in a.log.iter().zip(b.log.iter()) {
        assert_rows_bit_equal(ra, rb, "kfac golden seed");
    }
    assert!(a.params == b.params);
    let first = a.log.first().unwrap().train_loss;
    let last = a.log.last().unwrap().train_loss;
    assert!(last < first, "kfac failed to learn: {first} -> {last}");

    let run_sgd = || {
        TrainSession::for_dataset(arch.clone(), &ds)
            .iters(40)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(10)
            .eval_rows(64)
            .seed(6)
            .params(arch.sparse_init(&mut Rng::new(6)))
            .optimizer(Sgd::new(SgdConfig { lr: 0.05, ..Default::default() }))
            .run()
    };
    let sa = run_sgd();
    let sb = run_sgd();
    for (ra, rb) in sa.log.iter().zip(sb.log.iter()) {
        assert_rows_bit_equal(ra, rb, "sgd golden seed");
    }
    let sgd_first = sa.log.first().unwrap().train_loss;
    let sgd_last = sa.log.last().unwrap().train_loss;
    assert!(sgd_last < sgd_first, "sgd failed to learn: {sgd_first} -> {sgd_last}");
}

#[test]
fn ekfac_preconditioner_trains_through_session() {
    // EKFAC plugs into the optimizer through the Preconditioner seam
    // and trains the Figure-2 classifier through the session API.
    let report = TrainSession::for_problem(Problem::MnistClf)
        .data(128, 1)
        .iters(10)
        .schedule(BatchSchedule::Fixed(128))
        .eval_every(5)
        .eval_rows(128)
        .eval_initial()
        .optimizer(Kfac::new(
            &Problem::MnistClf.arch(),
            KfacConfig { lambda0: 5.0, ..KfacConfig::ekfac() },
        ))
        .run();
    let first = report.log.first().unwrap();
    let last = report.log.last().unwrap();
    assert_eq!(first.iter, 0);
    assert!(last.train_loss.is_finite());
    assert!(
        last.train_err < first.train_err,
        "ekfac did not reduce error: {} -> {}",
        first.train_err,
        last.train_err
    );
}

#[test]
fn resume_rejects_wrong_optimizer_and_arch() {
    let (arch, ds) = small_setup();
    let path = tmp_ckpt("mismatch");
    TrainSession::for_dataset(arch.clone(), &ds)
        .iters(2)
        .schedule(BatchSchedule::Fixed(32))
        .eval_rows(32)
        .optimizer(Kfac::new(&arch, kfac_cfg()))
        .checkpoint_every(2, &path)
        .run();

    // wrong optimizer kind
    let err = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(4)
        .optimizer(Sgd::new(SgdConfig::default()))
        .resume_from(&path)
        .try_run()
        .unwrap_err();
    assert!(err.contains("optimizer"), "unexpected error: {err}");

    // wrong architecture
    let other = Arch::autoencoder(&[64, 12, 64], Act::Tanh);
    let err = TrainSession::for_dataset(other.clone(), &ds)
        .iters(4)
        .optimizer(Kfac::new(&other, kfac_cfg()))
        .resume_from(&path)
        .try_run()
        .unwrap_err();
    assert!(err.contains("layers") || err.contains("arch"), "unexpected error: {err}");

    // corrupt file
    std::fs::write(&path, b"definitely not a checkpoint").unwrap();
    let err = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(4)
        .optimizer(Kfac::new(&arch, kfac_cfg()))
        .resume_from(&path)
        .try_run()
        .unwrap_err();
    assert!(err.contains("magic") || err.contains("truncated"), "unexpected error: {err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn checkpoint_file_is_versioned_and_self_describing() {
    let (arch, ds) = small_setup();
    let path = tmp_ckpt("versioned");
    let mut ckpt_events = 0usize;
    TrainSession::for_dataset(arch.clone(), &ds)
        .iters(3)
        .schedule(BatchSchedule::Fixed(32))
        .eval_rows(32)
        .optimizer(Kfac::new(&arch, kfac_cfg()))
        .checkpoint_every(3, &path)
        .observer(|e| {
            if let Event::Checkpoint { iter, .. } = e {
                assert_eq!(*iter, 3);
                ckpt_events += 1;
            }
        })
        .run();
    assert_eq!(ckpt_events, 1, "one checkpoint event at the final iteration");
    let ck = checkpoint::load(&path).unwrap();
    assert_eq!(ck.version, checkpoint::CHECKPOINT_VERSION);
    assert_eq!(ck.iter, 3);
    assert_eq!(ck.opt.kind, "kfac");
    assert_eq!(ck.params.0.len(), arch.num_layers());
    // the full optimizer state rides along: preconditioner identity,
    // λ/γ, EMA factors, δ₀
    assert_eq!(ck.opt.str_val("precond"), Some("blktridiag"));
    assert!(ck.opt.scalar("lambda").is_some());
    assert!(ck.opt.scalar("gamma").is_some());
    assert!(ck.opt.mats("stats_aa").is_some());
    assert!(ck.opt.mats("delta_prev").is_some());
    let (xi, avg) = ck.polyak.expect("polyak state saved");
    assert_eq!(xi, 0.99);
    assert!(avg.is_some());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn custom_optimizer_drives_session_through_the_trait() {
    // The Optimizer seam is open: a hand-rolled gradient-descent
    // optimizer (no K-FAC machinery at all) drives the same session.
    struct PlainGd {
        lr: f64,
    }
    impl Optimizer for PlainGd {
        fn name(&self) -> &str {
            "plain-gd"
        }
        fn step(
            &mut self,
            backend: &mut dyn kfac::backend::ModelBackend,
            params: &mut Params,
            x: &kfac::linalg::Mat,
            y: &kfac::linalg::Mat,
        ) -> kfac::optim::StepInfo {
            let (loss, grad) = backend.grad(params, x, y);
            params.axpy(-self.lr, &grad);
            kfac::optim::StepInfo::with_loss(loss)
        }
        fn state(&self) -> kfac::optim::OptState {
            kfac::optim::OptState::new("plain-gd")
        }
        fn load_state(&mut self, st: &kfac::optim::OptState) -> Result<(), String> {
            if st.kind != "plain-gd" {
                return Err("wrong kind".into());
            }
            Ok(())
        }
    }

    let (arch, ds) = small_setup();
    let report = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(40)
        .schedule(BatchSchedule::Fixed(128))
        .eval_every(10)
        .eval_rows(64)
        .eval_initial()
        .no_polyak()
        .seed(9)
        .optimizer(PlainGd { lr: 0.1 })
        .run();
    let first = report.log.first().unwrap().train_loss;
    let last = report.log.last().unwrap().train_loss;
    assert!(last.is_finite() && last < first, "plain GD via the trait: {first} -> {last}");
}

#[test]
fn sync_split_cadence_replays_presplit_trajectory_bit_exactly() {
    // Deterministic-replay harness for the t_cov/t_inv cadence split:
    // with the refresh pinned synchronous (KFAC_ASYNC=0), t_cov = 0 and
    // t_cov = 1 both mean "accumulate every step" — the pre-split
    // single-knob behaviour — and must produce bit-identical params,
    // per-step loss traces and OptState snapshots for every registered
    // preconditioner. The sync checkpoint must also stay v2 and carry
    // exactly the pre-split key set: no async keys may leak into
    // synchronous sessions.
    let (arch, ds) = small_setup();
    let init = arch.sparse_init(&mut Rng::new(13));
    let run = |cfg: KfacConfig, name: &str| {
        let mut losses: Vec<u64> = Vec::new();
        let path = tmp_ckpt(name);
        let report = TrainSession::for_dataset(arch.clone(), &ds)
            .iters(10)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(5)
            .eval_rows(64)
            .polyak(0.99)
            .seed(13)
            .params(init.clone())
            .optimizer(Kfac::new(&arch, cfg))
            .checkpoint_every(10, &path)
            .observer(|e| {
                if let Event::Step { info, .. } = e {
                    losses.push(info.loss.to_bits());
                }
            })
            .run();
        let ck = checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        (report, losses, ck)
    };
    for p in [precond::block_diag(), precond::block_tridiag(), precond::ekfac()] {
        let name = p.name().to_string();
        let cfg = |t_cov: usize| KfacConfig {
            precond: p.clone(),
            lambda0: 5.0,
            t_cov,
            t_inv: 4,
            refresh_async: false,
            ..Default::default()
        };
        let (ra, la, cka) = run(cfg(0), &format!("replay_presplit_{name}"));
        let (rb, lb, ckb) = run(cfg(1), &format!("replay_split_{name}"));
        assert_eq!(la, lb, "{name}: per-step loss trace diverged");
        assert!(!la.is_empty(), "{name}: no Step events observed");
        assert!(ra.params == rb.params, "{name}: final params diverged");
        assert!(ra.avg_params == rb.avg_params, "{name}: Polyak average diverged");
        assert_eq!(cka.opt, ckb.opt, "{name}: OptState snapshots diverged");

        // key-set pin: v2 checkpoints written by a synchronous session
        // contain the pre-split entries and nothing else
        assert_eq!(ckb.version, checkpoint::CHECKPOINT_VERSION, "{name}: sync stays v2");
        let mut want = vec![
            "delta_prev",
            "gamma",
            "k",
            "lambda",
            "precond",
            "refresh_aa",
            "refresh_aa_off",
            "refresh_gamma",
            "refresh_gg",
            "refresh_gg_off",
            "stats_aa",
            "stats_aa_off",
            "stats_gg",
            "stats_gg_off",
            "stats_k",
        ];
        if name == "ekfac" {
            want.extend(["scale_k", "scale_s"]);
        }
        want.sort_unstable();
        let got: Vec<&str> = ckb.opt.entries.keys().map(String::as_str).collect();
        assert_eq!(got, want, "{name}: sync OptState keys drifted from the pre-split set");
    }
}

#[test]
fn async_mid_flight_checkpoint_resumes_bit_exactly() {
    // A KFAC_ASYNC=1 session checkpointed while a background rebuild is
    // in flight (submitted at k = 8, due at k = 12, checkpoint at
    // k = 10) must record the pending job's *inputs* in a v3 checkpoint
    // and resume bit-exactly: the job is re-submitted from the restored
    // snapshot rather than silently dropped, so the swap at k = 12
    // installs the identical inverse.
    let (arch, ds) = small_setup();
    let init = arch.sparse_init(&mut Rng::new(21));
    let cfg = || KfacConfig { lambda0: 5.0, t_inv: 4, refresh_async: true, ..Default::default() };
    let session = |iters: usize| {
        TrainSession::for_dataset(arch.clone(), &ds)
            .iters(iters)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(2)
            .eval_rows(64)
            .polyak(0.99)
            .seed(21)
            .params(init.clone())
            .optimizer(Kfac::new(&arch, cfg()))
    };
    let full = session(16).run();
    let path = tmp_ckpt("async_mid_flight");
    session(10).checkpoint_every(10, &path).run();

    let ck = checkpoint::load(&path).unwrap();
    assert_eq!(ck.version, checkpoint::CHECKPOINT_VERSION_ASYNC, "in-flight build ⇒ v3");
    assert!(ck.opt.scalar("inv_epoch").is_some(), "async sessions tag the inverse epoch");
    assert!(ck.opt.scalar("pending_gamma").is_some(), "pending build γ missing");
    assert!(ck.opt.scalar("pending_k").is_some(), "pending build submission step missing");
    assert!(ck.opt.mats("pending_aa").is_some(), "pending build statistics missing");

    let resumed = session(16).resume_from(&path).run();
    assert!(full.params == resumed.params, "async mid-flight resume diverged");
    assert!(full.avg_params == resumed.avg_params, "Polyak average diverged");
    for row in &resumed.log {
        let want = full.log.iter().find(|r| r.iter == row.iter).unwrap();
        assert_rows_bit_equal(want, row, "async post-resume eval");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn async_checkpoint_carries_and_restores_stall_counter() {
    // Degraded-mode diagnostics must survive save → load: a v3 (async)
    // checkpoint carries `refresh_stalls`, and `Kfac::load_state`
    // restores it together with `inv_epoch` instead of silently
    // resetting the counter. Older v3 files without the key (written
    // before the counter was checkpointed) restart it at zero.
    let (arch, ds) = small_setup();
    let init = arch.sparse_init(&mut Rng::new(29));
    let cfg = || KfacConfig { lambda0: 5.0, t_inv: 4, refresh_async: true, ..Default::default() };
    let path = tmp_ckpt("async_stalls");
    TrainSession::for_dataset(arch.clone(), &ds)
        .iters(10)
        .schedule(BatchSchedule::Fixed(64))
        .eval_every(5)
        .eval_rows(64)
        .seed(29)
        .params(init.clone())
        .optimizer(Kfac::new(&arch, cfg()))
        .checkpoint_every(10, &path)
        .run();

    let mut ck = checkpoint::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert!(
        ck.opt.scalar("refresh_stalls").is_some(),
        "async checkpoints must carry the stall counter"
    );
    let epoch = ck.opt.scalar("inv_epoch").expect("async checkpoints tag the inverse epoch");

    // forge a non-zero counter (healthy test runs never stall) and load
    ck.opt.set_scalar("refresh_stalls", 5.0);
    let mut opt = Kfac::new(&arch, cfg());
    opt.load_state(&ck.opt).unwrap();
    assert_eq!(opt.refresh_stalls(), 5, "stall counter lost on load");
    assert_eq!(opt.inverse_epoch() as f64, epoch, "inverse epoch lost on load");

    // a v3 snapshot without the key (pre-counter writer) loads cleanly
    ck.opt.entries.remove("refresh_stalls");
    let mut opt = Kfac::new(&arch, cfg());
    opt.load_state(&ck.opt).unwrap();
    assert_eq!(opt.refresh_stalls(), 0, "missing key must restart the counter at zero");
}

#[test]
fn sync_v2_checkpoint_loads_into_async_session() {
    // Forward interop: a checkpoint written by a synchronous session
    // carries no async keys (v2), and a KFAC_ASYNC=1 session must
    // accept it and continue with background refreshes from the
    // restored state.
    fn cfg(refresh_async: bool) -> KfacConfig {
        KfacConfig { lambda0: 5.0, t_inv: 4, refresh_async, ..Default::default() }
    }
    let (arch, ds) = small_setup();
    let init = arch.sparse_init(&mut Rng::new(23));
    let session = |c: KfacConfig, iters: usize| {
        TrainSession::for_dataset(arch.clone(), &ds)
            .iters(iters)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(4)
            .eval_rows(64)
            .polyak(0.99)
            .seed(23)
            .params(init.clone())
            .optimizer(Kfac::new(&arch, c))
    };
    let path = tmp_ckpt("v2_into_async");
    session(cfg(false), 8).checkpoint_every(8, &path).run();
    let ck = checkpoint::load(&path).unwrap();
    assert_eq!(ck.version, checkpoint::CHECKPOINT_VERSION, "sync session writes v2");
    assert!(ck.opt.scalar("inv_epoch").is_none(), "async key leaked into a sync checkpoint");

    let resumed = session(cfg(true), 16).resume_from(&path).run();
    assert_eq!(resumed.iters_run, 8, "resume continues from iteration 8");
    for row in &resumed.log {
        assert!(row.train_loss.is_finite(), "async continuation diverged at {}", row.iter);
    }
    let _ = std::fs::remove_file(&path);
}
