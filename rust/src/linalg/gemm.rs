//! Packed, cache-blocked, multi-threaded f64 GEMM.
//!
//! One kernel serves every transpose variant the crate needs: the
//! operands are described by (row, column) strides, so `A`, `Aᵀ`, `B`
//! and `Bᵀ` all flow through the same packing layer (the
//! [`super::pack`] seam, which convolution's im2col view also
//! implements) —
//!
//! - `op(A)[i, p] = a[i·ars + p·acs]`
//! - `op(B)[p, c] = b[p·brs + c·bcs]`
//! - `C[i, c] += Σ_p op(A)[i,p] · op(B)[p,c]`, `C` row-major `m × n`.
//!
//! The blocked path is the classic GotoBLAS/BLIS decomposition:
//!
//! ```text
//! for jc in 0..n step NC            // B column panel (shared, packed once)
//!   for pc in 0..k step KC          //   rank-KC update, B packed to panels of NR
//!     for ic in 0..m step MR        //     MR-row panels, PARALLEL over the pool
//!       pack A sub-block (≤ MC rows) to panels of MR
//!       macro-kernel: MR×NR micro-tiles over the packed panels
//! ```
//!
//! The `MR × NR` micro-tile itself lives in [`super::simd`]: an
//! explicitly-SIMD kernel chosen once per process at runtime (AVX2+FMA
//! 4×8, AVX-512F 8×8, or the portable scalar 4×8 — `KFAC_SIMD`
//! overrides). MR/NR are **per-kernel** constants, so the packing layer
//! here takes them from the kernel instead of crate globals; only the
//! cache-blocking sizes MC/KC/NC stay shared.
//!
//! Packing zero-pads ragged edges to full MR/NR panels, so the
//! micro-kernel has no edge variants and its fixed-shape inner loops
//! stay branch-free; only the write-back masks the padding off. Shapes
//! too small (or too narrow) to amortize packing fall back to a
//! row-parallel saxpy/dot kernel that preserves the old behaviour.

use super::pack::{self, Strided};
use super::simd::{self, Kernel};
use crate::par;

/// Row-block size: one packed A block (MC×KC f64) stays L2-resident.
/// Divisible by every kernel's MR (4 or 8).
pub const MC: usize = 128;
/// Depth-block size: panels of KC keep micro-kernel streams in L1/L2.
pub const KC: usize = 256;
/// Column-block size: one packed B block (KC×NC f64) stays L3-resident.
pub const NC: usize = 2048;

/// Below this many flops (2·m·n·k) the packed path cannot amortize its
/// packing traffic; use the direct kernel.
const NAIVE_MAX_FLOPS: usize = 1 << 18;

/// `C += op(A) · op(B)` with stride-described operands (see module doc).
/// `c` must be row-major `m × n`, and is accumulated into (callers that
/// want `C = op(A)·op(B)` pass a zeroed buffer).
pub fn gemm_strided(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    b: &[f64],
    brs: usize,
    bcs: usize,
    c: &mut [f64],
) {
    assert_eq!(c.len(), m * n, "gemm: C buffer is {} not {m}x{n}", c.len());
    gemm_strided_into(m, n, k, a, ars, acs, b, brs, bcs, c, n);
}

/// Like [`gemm_strided`], but `C` rows live at stride `ldc ≥ n`: the
/// output may be a sub-block of a larger row-major matrix. The blocked
/// eigensolver's rank-2b trailing updates accumulate straight into the
/// trailing submatrix this way, without staging copies. Row entries
/// past `n` (up to `ldc`) are left untouched.
pub fn gemm_strided_into(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    b: &[f64],
    brs: usize,
    bcs: usize,
    c: &mut [f64],
    ldc: usize,
) {
    gemm_strided_into_with(simd::active(), m, n, k, a, ars, acs, b, brs, bcs, c, ldc);
}

/// [`gemm_strided_into`] with an explicit micro-kernel instead of the
/// process-wide dispatched one. Benches use this to emit per-kernel
/// GFLOP/s entries; tests use it to pin scalar-vs-SIMD agreement.
#[doc(hidden)]
pub fn gemm_strided_into_with(
    kern: &'static Kernel,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    b: &[f64],
    brs: usize,
    bcs: usize,
    c: &mut [f64],
    ldc: usize,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    assert!(ldc >= n, "gemm: ldc {ldc} < n {n}");
    assert!(c.len() >= (m - 1) * ldc + n, "gemm: C too small for {m}x{n} at stride {ldc}");
    // Operand extents implied by the strides must fit the slices.
    assert!((m - 1) * ars + (k - 1) * acs < a.len(), "gemm: A too small");
    assert!((k - 1) * brs + (n - 1) * bcs < b.len(), "gemm: B too small");

    let flops = 2usize.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if flops <= NAIVE_MAX_FLOPS || m < kern.mr || n < kern.nr {
        gemm_rowpar(m, n, k, a, ars, acs, b, brs, bcs, c, ldc);
        return;
    }
    gemm_blocked(kern, m, n, k, a, ars, acs, b, brs, bcs, c, ldc);
}

/// The packed blocked path with an explicit kernel and no small-shape
/// fallback — every shape goes through pack + macro-kernel, so the
/// property suites can exercise masked tile edges and K-tails on all
/// kernels regardless of the flop cutoff. Test/bench hook only.
#[doc(hidden)]
pub fn gemm_blocked_with(
    kern: &'static Kernel,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    b: &[f64],
    brs: usize,
    bcs: usize,
    c: &mut [f64],
) {
    assert_eq!(c.len(), m * n, "gemm: C buffer is {} not {m}x{n}", c.len());
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    assert!((m - 1) * ars + (k - 1) * acs < a.len(), "gemm: A too small");
    assert!((k - 1) * brs + (n - 1) * bcs < b.len(), "gemm: B too small");
    gemm_blocked(kern, m, n, k, a, ars, acs, b, brs, bcs, c, n);
}

/// Shared mutable output pointer (workers write disjoint row ranges).
type OutPtr = crate::par::SendPtr<f64>;

// ---------------------------------------------------------------------
// direct kernel (small / narrow shapes)
// ---------------------------------------------------------------------

/// Row-parallel direct kernel: saxpy order when op(B) rows are
/// contiguous (`bcs == 1`), dot-product order otherwise (then `brs` is
/// the unit stride for the NT layout).
fn gemm_rowpar(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    b: &[f64],
    brs: usize,
    bcs: usize,
    c: &mut [f64],
    ldc: usize,
) {
    let out = OutPtr(c.as_mut_ptr());
    let chunk = par::chunk_for_flops(m, 2 * n * k);
    par::par_ranges(m, chunk, |lo, hi| {
        let o = out;
        for i in lo..hi {
            // SAFETY: par_ranges hands out disjoint row ranges.
            let crow = unsafe { std::slice::from_raw_parts_mut(o.0.add(i * ldc), n) };
            if bcs == 1 {
                for p in 0..k {
                    let aip = a[i * ars + p * acs];
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &b[p * brs..p * brs + n];
                    for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += aip * bv;
                    }
                }
            } else {
                for (cc, cv) in crow.iter_mut().enumerate() {
                    let bcol = cc * bcs;
                    let mut acc = 0.0;
                    for p in 0..k {
                        acc += a[i * ars + p * acs] * b[bcol + p * brs];
                    }
                    *cv += acc;
                }
            }
        }
    });
}

// ---------------------------------------------------------------------
// packed blocked kernel
// ---------------------------------------------------------------------

fn gemm_blocked(
    kern: &'static Kernel,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    b: &[f64],
    brs: usize,
    bcs: usize,
    c: &mut [f64],
    ldc: usize,
) {
    let (mr, nr) = (kern.mr, kern.nr);
    let out = OutPtr(c.as_mut_ptr());
    let kc_max = KC.min(k);
    let nc_max = NC.min(n);
    let mut bpack = vec![0.0f64; nc_max.div_ceil(nr) * nr * kc_max];
    // Stride-described sources through the shared packing seam
    // (`linalg::pack`): identical loads to the pre-seam packers.
    let asrc = Strided::new(a, ars, acs);
    let bsrc = Strided::new(b, brs, bcs);

    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            // B block packed once per (jc, pc) round, shared read-only
            // by every worker of the ic loop.
            pack::pack_b(&mut bpack, nr, &bsrc, pc, kc, jc, nc);

            // Distribute MR-row panels (not whole MC blocks) across the
            // pool, so even an m = 256 GEMM exposes m/MR ≥ 32 units of
            // parallelism; each worker still packs/multiplies its range
            // in MC-row sub-blocks for cache locality. The chunk target
            // scales with the kernel's flop rate: a SIMD kernel retires
            // the same flops sooner, so it needs bigger chunks to
            // amortize a pool dispatch.
            let panels = m.div_ceil(mr);
            let panels_per_block = MC / mr;
            let chunk = par::chunk_for_flops_at_rate(panels, 2 * mr * nc * kc, kern.rate);
            let bref = &bpack;
            par::par_ranges(panels, chunk, |plo, phi| {
                let o = out;
                let mut apack = vec![0.0f64; MC.div_ceil(mr) * mr * kc];
                let mut p0 = plo;
                while p0 < phi {
                    let pend = (p0 + panels_per_block).min(phi);
                    let row0 = p0 * mr;
                    let mc = (pend * mr).min(m) - row0;
                    pack::pack_a(&mut apack, mr, &asrc, row0, mc, pc, kc);
                    macro_kernel(kern, o, ldc, row0, jc, mc, nc, kc, &apack, bref);
                    p0 = pend;
                }
            });
            pc += kc;
        }
        jc += nc;
    }
}

/// Multiply the packed `mc × kc` A block into the packed `kc × nc` B
/// block, accumulating into `C[row0.., col0..]` (`ldc`-stride rows).
/// The micro-tile is computed into a scratch tile by the dispatched
/// SIMD kernel; the write-back here masks the zero-padded tile edges.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    kern: &'static Kernel,
    out: OutPtr,
    ldc: usize,
    row0: usize,
    col0: usize,
    mc: usize,
    nc: usize,
    kc: usize,
    apack: &[f64],
    bpack: &[f64],
) {
    let (mr, nr) = (kern.mr, kern.nr);
    let m_panels = mc.div_ceil(mr);
    let n_panels = nc.div_ceil(nr);
    let mut acc = [0.0f64; simd::MAX_TILE];
    for jp in 0..n_panels {
        let bpanel = &bpack[jp * kc * nr..(jp + 1) * kc * nr];
        let ncols = nr.min(nc - jp * nr);
        for ip in 0..m_panels {
            let apanel = &apack[ip * kc * mr..(ip + 1) * kc * mr];
            let nrows = mr.min(mc - ip * mr);

            kern.run(kc, apanel, bpanel, &mut acc);

            // write-back, masking the zero-padded tile edge
            let base = (row0 + ip * mr) * ldc + col0 + jp * nr;
            for r in 0..nrows {
                // SAFETY: row ranges are disjoint across workers and the
                // (jp, ip) tiles are disjoint within one worker.
                let crow =
                    unsafe { std::slice::from_raw_parts_mut(out.0.add(base + r * ldc), ncols) };
                for (cv, &av) in crow.iter_mut().zip(acc[r * nr..r * nr + ncols].iter()) {
                    *cv += av;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Trivially-correct triple loop on the same stride description.
    fn reference(
        m: usize,
        n: usize,
        k: usize,
        a: &[f64],
        ars: usize,
        acs: usize,
        b: &[f64],
        brs: usize,
        bcs: usize,
    ) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[i * ars + p * acs] * b[p * brs + j * bcs];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn max_abs_diff(x: &[f64], y: &[f64]) -> f64 {
        x.iter().zip(y.iter()).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max)
    }

    fn randv(n: usize, rng: &mut Rng) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn blocked_path_matches_reference_all_layouts() {
        let mut rng = Rng::new(1);
        // big enough to force the packed path, ragged on every axis
        for &(m, n, k) in &[(131usize, 67usize, 261usize), (140, 72, 64), (257, 130, 40)] {
            assert!(2 * m * n * k > NAIVE_MAX_FLOPS && m >= simd::MAX_MR && n >= simd::MAX_NR);
            let a_nn = randv(m * k, &mut rng); // m×k row-major
            let a_tn = randv(k * m, &mut rng); // k×m row-major (op = transpose)
            let b_nn = randv(k * n, &mut rng); // k×n row-major
            let b_nt = randv(n * k, &mut rng); // n×k row-major (op = transpose)
            for (ars, acs, a) in [(k, 1, &a_nn), (1, m, &a_tn)] {
                for (brs, bcs, b) in [(n, 1, &b_nn), (1, k, &b_nt)] {
                    let want = reference(m, n, k, a, ars, acs, b, brs, bcs);
                    let mut got = vec![0.0; m * n];
                    gemm_strided(m, n, k, a, ars, acs, b, brs, bcs, &mut got);
                    let err = max_abs_diff(&got, &want);
                    assert!(
                        err < 1e-10,
                        "({m},{n},{k}) strides a=({ars},{acs}) b=({brs},{bcs}): {err}"
                    );
                }
            }
        }
    }

    #[test]
    fn every_available_kernel_matches_reference_on_blocked_path() {
        // The forced-kernel hook: each executable micro-kernel (scalar,
        // avx2, avx512 where the host has them) must reproduce the
        // reference through the full pack/macro-kernel path, including
        // ragged tile edges and multi-KC accumulation.
        let mut rng = Rng::new(7);
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 9),
            (9, 9, 9),
            (13, 17, KC + 5),
            (131, 67, 261),
            (129, 65, 63),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let want = reference(m, n, k, &a, k, 1, &b, n, 1);
            for kern in simd::available_kernels() {
                let mut got = vec![0.0; m * n];
                gemm_blocked_with(kern, m, n, k, &a, k, 1, &b, n, 1, &mut got);
                let err = max_abs_diff(&got, &want);
                assert!(err < 1e-9, "{} ({m},{n},{k}): {err}", kern.name);
            }
        }
    }

    #[test]
    fn small_and_degenerate_shapes() {
        let mut rng = Rng::new(2);
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (1, 9, 5),
            (9, 1, 5),
            (5, 9, 1),
            (3, 3, 3),
            (4, 8, 2),
            (3, 7, 7),
            (5, 9, KC + 3),
            (7, 9, 11),
            (9, 7, 11),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let want = reference(m, n, k, &a, k, 1, &b, n, 1);
            let mut got = vec![0.0; m * n];
            gemm_strided(m, n, k, &a, k, 1, &b, n, 1, &mut got);
            assert!(max_abs_diff(&got, &want) < 1e-11, "({m},{n},{k})");
        }
        // zero-extent operands are a no-op
        let mut c: Vec<f64> = vec![];
        gemm_strided(0, 0, 4, &[], 1, 1, &[], 1, 1, &mut c);
        let mut c = vec![7.0; 4];
        gemm_strided(2, 2, 0, &[], 1, 1, &[], 1, 1, &mut c);
        assert_eq!(c, vec![7.0; 4]);
    }

    #[test]
    fn accumulates_into_c() {
        // gemm is C += op(A)op(B); the Mat wrappers rely on a zeroed C.
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![1.0, 0.0, 0.0, 1.0]; // identity
        let mut c = vec![10.0, 20.0, 30.0, 40.0];
        gemm_strided(2, 2, 2, &a, 2, 1, &b, 2, 1, &mut c);
        assert_eq!(c, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn strided_output_writes_subblock_only() {
        // 5x4 product into the middle of a 9x8 row-major matrix: the
        // target block accumulates, everything else stays untouched.
        let mut rng = Rng::new(4);
        let (m, n, k, big_rows, ldc) = (5usize, 4usize, 6usize, 9usize, 8usize);
        let (r0, c0) = (2usize, 3usize);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let want = reference(m, n, k, &a, k, 1, &b, n, 1);
        let mut big = vec![7.0f64; big_rows * ldc];
        let off = r0 * ldc + c0;
        gemm_strided_into(m, n, k, &a, k, 1, &b, n, 1, &mut big[off..], ldc);
        for r in 0..big_rows {
            for cc in 0..ldc {
                let inside = (r0..r0 + m).contains(&r) && (c0..c0 + n).contains(&cc);
                let got = big[r * ldc + cc];
                if inside {
                    let v = want[(r - r0) * n + (cc - c0)] + 7.0;
                    assert!((got - v).abs() < 1e-11, "({r},{cc})");
                } else {
                    assert_eq!(got, 7.0, "({r},{cc}) clobbered outside the block");
                }
            }
        }
    }

    #[test]
    fn strided_output_blocked_path_matches_reference() {
        // large enough for the packed path; ldc > n exercises the
        // macro-kernel's generalized write-back stride — on every
        // executable kernel, since tile edges depend on MR/NR.
        let mut rng = Rng::new(5);
        let (m, n, k, ldc) = (140usize, 72usize, 64usize, 90usize);
        assert!(2 * m * n * k > NAIVE_MAX_FLOPS);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let want = reference(m, n, k, &a, k, 1, &b, n, 1);
        for kern in simd::available_kernels() {
            let mut big = vec![0.0f64; m * ldc];
            gemm_strided_into_with(kern, m, n, k, &a, k, 1, &b, n, 1, &mut big, ldc);
            for r in 0..m {
                for cc in 0..n {
                    let err = (big[r * ldc + cc] - want[r * n + cc]).abs();
                    assert!(err < 1e-10, "{} ({r},{cc}) err={err}", kern.name);
                }
                for cc in n..ldc {
                    assert_eq!(big[r * ldc + cc], 0.0, "{} ({r},{cc}) padding", kern.name);
                }
            }
        }
    }

    #[test]
    fn spans_multiple_nc_kc_blocks() {
        // k and n crossing the KC/NC boundaries exercises the pc/jc
        // accumulation loops (requires KC < k, and C += across rounds).
        let mut rng = Rng::new(3);
        let (m, n, k) = (8 * 8 + 1, 8 * 2 + 3, KC * 2 + 17);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let want = reference(m, n, k, &a, k, 1, &b, n, 1);
        let mut got = vec![0.0; m * n];
        gemm_strided(m, n, k, &a, k, 1, &b, n, 1, &mut got);
        // k ≈ 500 accumulation steps: allow a few ulps more headroom
        assert!(max_abs_diff(&got, &want) < 1e-9);
    }
}
