//! Quickstart: train a small deep autoencoder with K-FAC through the
//! `TrainSession` builder in ~30 lines — the canonical snippet for the
//! README.
//!
//!     cargo run --release --example quickstart

use kfac::coordinator::{Event, TrainSession};
use kfac::prelude::*;

fn main() {
    // 1. Data: synthetic 16×16 digit images, autoencoding targets.
    let ds = kfac::data::mnist_like::autoencoder_dataset(1000, 16, 0);

    // 2. Model: 256-64-16-64-256 tanh autoencoder with sigmoid-CE output.
    let arch = Arch::autoencoder(&[256, 64, 16, 64, 256], Act::Tanh);

    // 3. Optimizer: K-FAC with the paper's defaults (block-tridiagonal
    //    preconditioner, momentum, adaptive λ/γ damping); λ₀ scaled to
    //    the short run. Swap in `KfacConfig::block_diag()` or
    //    `KfacConfig::ekfac()` for the other curvature structures, or
    //    `Sgd::new(..)` for the baseline — anything implementing
    //    `Optimizer` plugs in.
    let opt = Kfac::new(&arch, KfacConfig { lambda0: 5.0, ..Default::default() });

    // 4. Train: the session owns the loop, Polyak averaging, metric
    //    streaming, and versioned checkpoints (delete the checkpoint
    //    line or add `.resume_from(..)` to continue a previous run).
    let report = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(60)
        .schedule(BatchSchedule::Fixed(500))
        .seed(1)
        .optimizer(opt)
        .polyak(0.99)
        .checkpoint_every(30, "results/quickstart.ckpt")
        .observer(|e| {
            if let Event::Step { iter, info, .. } = e {
                if *iter == 1 || iter % 10 == 0 {
                    println!(
                        "iter {iter:>3}  loss {:.4}  |δ| {:.3e}  λ {:.2}  γ {:.3}",
                        info.loss,
                        info.delta_norm.unwrap_or(f64::NAN),
                        info.lambda.unwrap_or(f64::NAN),
                        info.gamma.unwrap_or(f64::NAN)
                    );
                }
            }
        })
        .run();

    // 5. Evaluate reconstruction error on the final parameters.
    let mut backend = RustBackend::new(arch);
    let (loss, err) = backend.eval(&report.params, &ds.x, &ds.y);
    println!("final: train loss {loss:.4}, reconstruction error {err:.4}");
}
