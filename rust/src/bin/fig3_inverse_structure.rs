//! Figure 3 — the *inverse* of F̃ is approximately block-tridiagonal
//! even though F̃ itself is not. Reproduces the right panel: a 4×4
//! matrix of block-average |entries| of F̃⁻¹ for the middle 4 layers of
//! the Figure-2 network, computed subject to factored Tikhonov damping.
//!
//! Output: block maps for F̃ and F̃⁻¹, the tridiagonal-dominance ratio,
//! and results/fig3_inverse_blocks.csv.

use kfac::coordinator::Problem;
use kfac::experiments::{partially_train, results_dir, scaled};
use kfac::fisher::exact::ExactBlocks;
use kfac::linalg::Mat;
use kfac::util::write_csv;

fn band_ratio(map: &Mat) -> f64 {
    let (mut on, mut off) = (0.0, 0.0);
    let (mut n_on, mut n_off) = (0usize, 0usize);
    for r in 0..map.rows {
        for c in 0..map.cols {
            if (r as isize - c as isize).abs() <= 1 {
                on += map.at(r, c);
                n_on += 1;
            } else {
                off += map.at(r, c);
                n_off += 1;
            }
        }
    }
    (on / n_on as f64) / (off / n_off.max(1) as f64)
}

fn main() {
    println!("== Figure 3: F̃ vs F̃⁻¹ block structure (middle 4 layers) ==");
    let (backend, params, ds) = partially_train(Problem::MnistClf, scaled(600, 200), 8, 0);
    let x = ds.x.top_rows(scaled(300, 100).min(ds.len()));
    let eb = ExactBlocks::compute(backend.net(), &params, &x, 1, 5);

    // the paper computes the inverse subject to the factored Tikhonov
    // damping at the same γ K-FAC was using; our partial run ends near
    // γ ≈ sqrt(λ+η) with λ ~ O(1–10) ⇒ use a comparable value.
    let gamma = 0.3;
    let ktilde = eb.ktilde_damped_dense(gamma);
    let ktilde_inv = ktilde.inverse();

    let map_kt = eb.block_avg_abs(&ktilde);
    let map_inv = eb.block_avg_abs(&ktilde_inv);
    let print_map = |name: &str, m: &Mat| {
        println!("\n{name} (block-average |entries|):");
        for r in 0..m.rows {
            print!("  ");
            for c in 0..m.cols {
                print!(" {:>10.3e}", m.at(r, c));
            }
            println!();
        }
    };
    print_map("F̃ (damped)", &map_kt);
    print_map("F̃⁻¹", &map_inv);

    let r_fwd = band_ratio(&map_kt);
    let r_inv = band_ratio(&map_inv);
    println!("\ntridiagonal-band dominance (band avg / off-band avg):");
    println!("  F̃   : {r_fwd:.1}×");
    println!("  F̃⁻¹ : {r_inv:.1}×");
    println!("(paper: the inverse is strongly tridiagonal-dominant; F̃ itself is not)");
    assert!(
        r_inv > 2.0 * r_fwd,
        "inverse should be much more tridiagonal-dominant than F̃ itself"
    );

    let mut rows = Vec::new();
    for r in 0..map_inv.rows {
        for c in 0..map_inv.cols {
            rows.push(vec![r as f64, c as f64, map_kt.at(r, c), map_inv.at(r, c)]);
        }
    }
    let path = results_dir().join("fig3_inverse_blocks.csv");
    write_csv(&path, &["block_i", "block_j", "ktilde", "ktilde_inv"], &rows).unwrap();
    println!("\nwrote {}", path.display());
}
