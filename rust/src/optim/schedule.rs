//! Mini-batch-size schedules (paper Section 13).
//!
//! The headline experiments drive K-FAC with an exponentially increasing
//! schedule `m_k = min(m₁ exp((k−1)/b), |S|)` with `b` chosen so the
//! schedule saturates at a target iteration — the paper's response to
//! the observation (Figure 9) that K-FAC's per-iteration progress is
//! superlinear in `m`.

/// A mini-batch size schedule.
#[derive(Clone, Debug)]
pub enum BatchSchedule {
    /// Constant m.
    Fixed(usize),
    /// `m_k = min(m₁ e^{(k−1)/b}, cap)`.
    Exponential { m1: usize, b: f64, cap: usize },
}

impl BatchSchedule {
    /// Paper's construction: exponential from `m₁` reaching `cap` at
    /// iteration `k_final` (they used m₁=1000, k_final=500, cap=|S|).
    pub fn exponential_reaching(m1: usize, cap: usize, k_final: usize) -> BatchSchedule {
        assert!(cap >= m1 && k_final >= 2);
        let b = (k_final as f64 - 1.0) / (cap as f64 / m1 as f64).ln().max(1e-12);
        BatchSchedule::Exponential { m1, b, cap }
    }

    /// Batch size at (1-based) iteration `k`.
    pub fn size(&self, k: usize) -> usize {
        match self {
            BatchSchedule::Fixed(m) => *m,
            BatchSchedule::Exponential { m1, b, cap } => {
                let m = (*m1 as f64) * (((k as f64) - 1.0) / b).exp();
                (m.round() as usize).min(*cap).max(*m1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_fixed() {
        let s = BatchSchedule::Fixed(256);
        assert_eq!(s.size(1), 256);
        assert_eq!(s.size(1000), 256);
    }

    #[test]
    fn exponential_hits_cap_at_k_final() {
        let s = BatchSchedule::exponential_reaching(1000, 6000, 500);
        assert_eq!(s.size(1), 1000);
        assert_eq!(s.size(500), 6000);
        assert_eq!(s.size(5000), 6000);
        // monotone
        let mut prev = 0;
        for k in 1..600 {
            let m = s.size(k);
            assert!(m >= prev);
            prev = m;
        }
    }
}
