//! Cross-layer integration: the AOT JAX/Pallas artifacts executed via
//! PJRT must agree numerically with the pure-Rust reference backend.
//!
//! Requires `make artifacts` (tests self-skip when artifacts are absent).

use kfac::backend::{ModelBackend, PjrtBackend, RustBackend};
use kfac::linalg::Mat;
use kfac::nn::Params;
use kfac::rng::Rng;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn setup(name: &str) -> Option<(PjrtBackend, RustBackend, Params, Mat, Mat)> {
    let dir = artifacts_dir()?;
    let pjrt = match PjrtBackend::new(&dir, name) {
        Ok(b) => b,
        Err(e) => panic!("failed to load artifacts for {name}: {e:#}"),
    };
    let arch = pjrt.arch().clone();
    let rust = RustBackend::new(arch.clone());
    let mut rng = Rng::new(42);
    let params = arch.glorot_init(&mut rng);
    let m = 20; // deliberately not a multiple of the chunk size
    let x = Mat::randn(m, arch.widths[0], 1.0, &mut rng);
    let d_out = *arch.widths.last().unwrap();
    let y = match arch.loss {
        kfac::nn::LossKind::SoftmaxCe => {
            let mut y = Mat::zeros(m, d_out);
            for r in 0..m {
                let c = rng.below(d_out);
                y.set(r, c, 1.0);
            }
            y
        }
        kfac::nn::LossKind::SigmoidCe => {
            Mat::from_fn(m, d_out, |_, _| rng.bernoulli(0.5))
        }
        kfac::nn::LossKind::SquaredError => Mat::randn(m, d_out, 1.0, &mut rng),
    };
    Some((pjrt, rust, params, x, y))
}

fn check_arch(name: &str) {
    let Some((mut pjrt, mut rust, params, x, y)) = setup(name) else {
        eprintln!("skipping pjrt test: run `make artifacts` first");
        return;
    };

    // loss / eval
    let (lp, ep) = pjrt.eval(&params, &x, &y);
    let (lr, er) = rust.eval(&params, &x, &y);
    assert!((lp - lr).abs() < 1e-3 * (1.0 + lr.abs()), "{name} loss {lp} vs {lr}");
    assert!((ep - er).abs() < 1e-3 * (1.0 + er.abs()), "{name} err {ep} vs {er}");

    // gradients (f32 vs f64 tolerance)
    let (_, gp) = pjrt.grad(&params, &x, &y);
    let (_, gr) = rust.grad(&params, &x, &y);
    for i in 0..gp.0.len() {
        let scale = gr.0[i].max_abs().max(1e-6);
        let err = gp.0[i].sub(&gr.0[i]).max_abs() / scale;
        assert!(err < 1e-3, "{name} grad layer {i} rel err {err}");
    }

    // grad_and_stats: aa factors are deterministic functions of x
    let (_, gp2, sp) = pjrt.grad_and_stats(&params, &x, &y, 12, 7);
    let (_, _gr2, sr) = rust.grad_and_stats(&params, &x, &y, 12, 7);
    for i in 0..sp.aa.len() {
        let scale = sr.aa[i].max_abs().max(1e-6);
        let err = sp.aa[i].sub(&sr.aa[i]).max_abs() / scale;
        assert!(err < 1e-3, "{name} aa[{i}] rel err {err}");
    }
    for i in 0..sp.aa_off.len() {
        let scale = sr.aa_off[i].max_abs().max(1e-6);
        let err = sp.aa_off[i].sub(&sr.aa_off[i]).max_abs() / scale;
        assert!(err < 1e-3, "{name} aa_off[{i}] rel err {err}");
    }
    // gg uses different RNG streams (jnp hash vs rust xoshiro), so only
    // structural checks: symmetry, PSD-ish diagonal, sane magnitude.
    for i in 0..sp.gg.len() {
        let g = &sp.gg[i];
        assert!(g.sub(&g.transpose()).max_abs() < 1e-4 * (1.0 + g.max_abs()), "{name} gg[{i}] sym");
        for d in 0..g.rows {
            assert!(g.at(d, d) >= -1e-6, "{name} gg[{i}] diag");
        }
    }
    // gradient from the two-part (stats chunk + rest) path must agree
    for i in 0..gp2.0.len() {
        let scale = gr.0[i].max_abs().max(1e-6);
        let err = gp2.0[i].sub(&gr.0[i]).max_abs() / scale;
        assert!(err < 1e-3, "{name} split grad layer {i} rel err {err}");
    }

    // FVP quadratic forms
    let mut rng = Rng::new(9);
    let mk = |rng: &mut Rng| {
        Params(params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 0.5, rng)).collect())
    };
    let v = mk(&mut rng);
    let u = mk(&mut rng);
    let qp = pjrt.fvp_quad(&params, &x, 20, &[&v, &u]);
    let qr = rust.fvp_quad(&params, &x, 20, &[&v, &u]);
    for i in 0..2 {
        for j in 0..2 {
            let scale = qr.max_abs().max(1e-9);
            let err = (qp.at(i, j) - qr.at(i, j)).abs() / scale;
            assert!(err < 2e-3, "{name} fvp[{i}{j}] {} vs {}", qp.at(i, j), qr.at(i, j));
        }
    }
    // 1-direction variant
    let q1 = pjrt.fvp_quad(&params, &x, 20, &[&v]);
    assert!((q1.at(0, 0) - qr.at(0, 0)).abs() / qr.max_abs().max(1e-9) < 2e-3);
}

#[test]
fn tiny_autoencoder_matches_rust_backend() {
    check_arch("tiny_ae");
}

#[test]
fn tiny_classifier_matches_rust_backend() {
    check_arch("tiny_clf");
}

#[test]
fn manifest_lists_expected_programs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let m = kfac::runtime::Manifest::load(&dir).unwrap();
    for name in ["tiny_ae", "tiny_clf"] {
        let a = m.find(name).unwrap();
        for prog in ["fwd_loss", "grad", "grad_stats", "fvp2", "precond"] {
            assert!(a.programs.contains_key(prog), "{name} missing {prog}");
            assert!(
                m.program_path(a, prog).unwrap().exists(),
                "{name}/{prog} file missing"
            );
        }
    }
}

#[test]
fn precond_program_runs_standalone() {
    // The pure-L1 Pallas preconditioner program: Ginv V Ainv.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let m = kfac::runtime::Manifest::load(&dir).unwrap();
    let am = m.find("tiny_ae").unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    let prog = kfac::runtime::Program::load(
        &client,
        &m.program_path(am, "precond").unwrap(),
        "precond",
    )
    .unwrap();
    // widest layer of tiny_ae is the last (8 x 6)
    let (r, c) = (8usize, 6usize);
    let mut rng = Rng::new(3);
    let g = Mat::randn(r, r, 1.0, &mut rng);
    let v = Mat::randn(r, c, 1.0, &mut rng);
    let a = Mat::randn(c, c, 1.0, &mut rng);
    let out = prog
        .run(&[
            kfac::runtime::mat_to_literal(&g).unwrap(),
            kfac::runtime::mat_to_literal(&v).unwrap(),
            kfac::runtime::mat_to_literal(&a).unwrap(),
        ])
        .unwrap();
    let got = kfac::runtime::literal_to_mat(&out[0], r, c).unwrap();
    let want = g.matmul(&v).matmul(&a);
    assert!(got.sub(&want).max_abs() < 1e-3 * (1.0 + want.max_abs()));
}

#[test]
fn chunking_is_exact_for_awkward_sizes(){
    // 20 rows through chunk-16 executables must equal the rust oracle —
    // this is the masked-padding guarantee.
    let Some((mut pjrt, mut rust, params, x, y)) = setup("tiny_ae") else {
        return;
    };
    for rows in [1usize, 3, 15, 16, 17, 20] {
        let xs = x.top_rows(rows);
        let ys = y.top_rows(rows);
        let lp = pjrt.loss(&params, &xs, &ys);
        let lr = rust.loss(&params, &xs, &ys);
        assert!((lp - lr).abs() < 1e-3 * (1.0 + lr.abs()), "rows={rows}: {lp} vs {lr}");
    }
}

#[allow(dead_code)]
fn _unused(_: &Path) {}
