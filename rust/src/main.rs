//! `kfac` CLI — train the paper's benchmark problems with K-FAC (any
//! registered preconditioner) or the SGD baseline, on either the
//! pure-Rust backend or the AOT/PJRT backend, with checkpoint
//! save/resume and optional multi-worker data parallelism.
//!
//! Examples:
//!   kfac train --problem mnist_ae --iters 200 --batch 1000
//!   kfac train --problem curves_ae --optimizer sgd --lr 0.05
//!   kfac train --problem mnist_ae --optimizer kfac_ekfac
//!   kfac train --problem mnist_ae --checkpoint results/run.ckpt
//!   kfac train --problem mnist_ae --resume results/run.ckpt --iters 400
//!   kfac train --problem mnist_ae --backend pjrt --artifacts artifacts
//!   kfac train --problem mnist_clf --ranks 4                 (in-process workers)
//!   kfac train --problem mnist_clf --ranks 2 --dist tcp --rank 0   (one per process)
//!   kfac list-archs --artifacts artifacts

use kfac::backend::{ModelBackend, PjrtBackend, RustBackend};
use kfac::coordinator::cli::Args;
use kfac::coordinator::{log_to_csv, LogRow, Problem, TrainSession};
use kfac::data::Dataset;
use kfac::dist::backend::DistBackend;
use kfac::dist::tcp::{TcpCollective, TcpOpts};
use kfac::dist::trainer::run_local_ranks;
use kfac::dist::Collective;
use kfac::fisher::precond;
use kfac::nn::Arch;
use kfac::optim::{BatchSchedule, Kfac, KfacConfig, Optimizer, Sgd, SgdConfig};
use kfac::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

/// Options that take a value (`--key v` / `--key=v`); the strict parser
/// rejects a typo'd option instead of silently ignoring it.
const VALUE_OPTS: &[&str] = &[
    "artifacts",
    "backend",
    "batch",
    "checkpoint",
    "checkpoint-every",
    "data",
    "dist",
    "dist-addr",
    "eval-every",
    "eval-rows",
    "iters",
    "lambda0",
    "lr",
    "mu-max",
    "optimizer",
    "out",
    "problem",
    "rank",
    "ranks",
    "resume",
    "seed",
    "t-cov",
    "t-inv",
    "t-scale",
];

/// Bare boolean flags.
const FLAG_OPTS: &[&str] = &["exp-schedule", "no-momentum"];

fn main() {
    let args = match Args::parse_checked(std::env::args().skip(1), VALUE_OPTS, FLAG_OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
        }
    };
    match args.command.as_deref() {
        Some("train") => train(&args),
        Some("list-archs") => list_archs(&args),
        _ => usage(),
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: kfac <command> [options]\n\
         commands:\n\
         \x20 train        --problem mnist_ae|curves_ae|faces_ae|mnist_clf|conv_clf\n\
         \x20              --optimizer kfac|kfac_<precond>|sgd  --iters N --batch M\n\
         \x20              (preconditioners: {})\n\
         \x20              --data N --seed S --no-momentum --lambda0 L --lr E\n\
         \x20              --t-scale N  (EKFAC scale-refresh period; 0 disables)\n\
         \x20              --t-cov N --t-inv N  (statistics / inverse-rebuild periods;\n\
         \x20              KFAC_ASYNC=1 rebuilds in the background, one epoch stale)\n\
         \x20              --backend rust|pjrt --artifacts DIR --out results/train.csv\n\
         \x20              --exp-schedule  (exponential batch schedule, paper §13)\n\
         \x20              --checkpoint PATH --checkpoint-every N --resume PATH\n\
         \x20              --ranks N --dist local|tcp  (data-parallel workers: `local`\n\
         \x20              spawns N in-process ranks, `tcp` runs one rank per process)\n\
         \x20              --rank R --dist-addr HOST:PORT  (tcp mode: this process's\n\
         \x20              rank; rank 0 listens on the address, others connect;\n\
         \x20              see docs/env_registry.md for KFAC_DIST_* tuning)\n\
         \x20 list-archs   --artifacts DIR",
        precond::names().join("|")
    );
    std::process::exit(2);
}

fn list_archs(args: &Args) {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    match kfac::runtime::Manifest::load(&dir) {
        Ok(m) => {
            for a in &m.archs {
                println!(
                    "{:<12} widths={:?} loss={} chunk={} programs={:?}",
                    a.name,
                    a.widths,
                    a.loss.name(),
                    a.chunk,
                    a.programs.keys().collect::<Vec<_>>()
                );
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Build the optimizer named by `--optimizer`: `sgd`, or anything
/// [`precond::resolve_optimizer`] accepts (`kfac` for the paper
/// default, `kfac_<name>` for any registered preconditioner — the CLI
/// has no per-structure code, so plugging a structure into the registry
/// makes it trainable immediately). In distributed runs `coll` is
/// threaded into [`KfacConfig::collective`] so inverse rebuilds are
/// sharded across ranks; SGD ignores it (its gradients are already
/// all-reduced by the [`DistBackend`] wrapper).
fn build_optimizer(
    args: &Args,
    arch: &Arch,
    coll: Option<Arc<dyn Collective>>,
) -> Box<dyn Optimizer> {
    let name = args.get_or("optimizer", "kfac");
    if name == "sgd" {
        return Box::new(Sgd::new(SgdConfig {
            lr: args.get_f64("lr", 0.02),
            mu_max: args.get_f64("mu-max", 0.99),
            ..Default::default()
        }));
    }
    let precond = precond::resolve_optimizer(&name).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let defaults = KfacConfig::default();
    let cfg = KfacConfig {
        precond,
        momentum: !args.get_flag("no-momentum"),
        lambda0: args.get_f64("lambda0", 150.0),
        // split refresh cadences: statistics accumulation vs
        // inverse rebuild (KFAC_ASYNC=1 moves the rebuild to the
        // background pool via KfacConfig::default)
        t_cov: args.get_usize("t-cov", defaults.t_cov),
        t_inv: args.get_usize("t-inv", defaults.t_inv),
        // amortized EKFAC scale re-estimation cadence (ignored by
        // structures without re-estimable scales)
        t_scale: args.get_usize("t-scale", defaults.t_scale),
        collective: coll,
        ..defaults
    };
    // structures whose factor semantics are undefined for this
    // architecture (e.g. blktridiag/ekfac on conv) fail here, at
    // construction, with the preconditioner's own explanation
    match Kfac::try_new(arch, cfg) {
        Ok(opt) => Box::new(opt),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_session(
    args: &Args,
    arch: &Arch,
    ds: &Dataset,
    backend: &mut dyn ModelBackend,
    iters: usize,
    schedule: BatchSchedule,
    seed: u64,
    coll: Option<Arc<dyn Collective>>,
) -> Vec<LogRow> {
    let (rank, ranks) = coll.as_ref().map_or((0, 1), |c| (c.rank(), c.size()));
    let optimizer = build_optimizer(args, arch, coll.clone());
    // In distributed runs wrap the compute backend so losses, gradients
    // and Kronecker statistics are all-reduced across ranks.
    let mut wrapped;
    let backend: &mut dyn ModelBackend = match &coll {
        Some(c) => {
            wrapped = DistBackend::new(backend, c.clone());
            &mut wrapped
        }
        None => backend,
    };
    let mut session = TrainSession::for_dataset(arch.clone(), ds)
        .iters(iters)
        .schedule(schedule)
        .seed(seed)
        .eval_every(args.get_usize("eval-every", 10))
        .eval_rows(args.get_usize("eval-rows", 1000))
        .polyak(0.99)
        .params(arch.sparse_init(&mut Rng::new(seed ^ 0xA5)))
        .optimizer_boxed(optimizer)
        .backend(backend)
        .shard(rank, ranks.max(1))
        .verbose(rank == 0);
    // Only rank 0 writes checkpoints (all ranks hold identical state,
    // so one copy suffices and concurrent writers would race).
    if rank == 0 {
        if let Some(path) = args.get("checkpoint") {
            session = session.checkpoint_every(args.get_usize("checkpoint-every", 25), path);
        }
    }
    if let Some(path) = args.get("resume") {
        session = session.resume_from(path);
    }
    match session.try_run() {
        Ok(report) => report.log,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn train(args: &Args) {
    let problem_name = args.get_or("problem", "mnist_ae");
    let problem = Problem::from_name(&problem_name).unwrap_or_else(|| {
        eprintln!(
            "unknown --problem {problem_name} \
             (use mnist_ae|curves_ae|faces_ae|mnist_clf|conv_clf)"
        );
        std::process::exit(2);
    });
    let iters = args.get_usize("iters", 100);
    let n_data = args.get_usize("data", 4000);
    let seed = args.get_usize("seed", 0) as u64;
    let batch = args.get_usize("batch", 500);
    let schedule = if args.get_flag("exp-schedule") {
        BatchSchedule::exponential_reaching(batch, n_data, (iters * 3 / 4).max(2))
    } else {
        BatchSchedule::Fixed(batch)
    };

    let ranks = args.get_usize("ranks", 1);
    let dist_mode = args.get_or("dist", "local");
    if dist_mode != "local" && dist_mode != "tcp" {
        eprintln!("unknown --dist {dist_mode} (use local or tcp)");
        std::process::exit(2);
    }
    let backend_name = args.get_or("backend", "rust");
    if ranks > 1 && backend_name != "rust" {
        eprintln!("error: --ranks {ranks} requires --backend rust");
        std::process::exit(2);
    }

    println!("# generating {} dataset (n={n_data})…", problem.name());
    let ds = problem.dataset(n_data, seed);
    let arch = problem.arch();
    println!("# arch {:?} ({} params)", arch.widths, arch.num_params());

    let log = if ranks > 1 && dist_mode == "local" {
        println!("# distributed: {ranks} in-process ranks");
        let (arch_ref, ds_ref, sched) = (&arch, &ds, schedule);
        let mut logs = run_local_ranks(ranks, |_rank, coll| {
            let mut backend = RustBackend::new(arch_ref.clone());
            let sched = sched.clone();
            run_session(args, arch_ref, ds_ref, &mut backend, iters, sched, seed, Some(coll))
        });
        // every rank ends with an identical log; report rank 0's
        logs.swap_remove(0)
    } else if ranks > 1 {
        // tcp: this process is exactly one rank of the group
        let rank = args.get_usize("rank", 0);
        if rank >= ranks {
            eprintln!("error: --rank {rank} out of range for --ranks {ranks}");
            std::process::exit(2);
        }
        let mut opts = TcpOpts::from_env();
        if let Some(a) = args.get("dist-addr") {
            opts.addr = a.to_string();
        }
        println!("# distributed: rank {rank}/{ranks} over tcp at {}", opts.addr);
        let coll: Arc<dyn Collective> = match TcpCollective::connect(rank, ranks, &opts) {
            Ok(c) => Arc::new(c),
            Err(e) => {
                eprintln!("error: distributed setup failed (rank {rank}): {e}");
                std::process::exit(1);
            }
        };
        let mut backend = RustBackend::new(arch.clone());
        run_session(args, &arch, &ds, &mut backend, iters, schedule, seed, Some(coll))
    } else {
        match backend_name.as_str() {
            "rust" => {
                let mut backend = RustBackend::new(arch.clone());
                run_session(args, &arch, &ds, &mut backend, iters, schedule, seed, None)
            }
            "pjrt" => {
                let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
                let mut backend = PjrtBackend::new(&dir, problem.name()).unwrap_or_else(|e| {
                    eprintln!("error loading artifacts: {e:#}");
                    std::process::exit(1);
                });
                assert_eq!(
                    backend.arch().widths,
                    arch.widths,
                    "artifact arch mismatch — re-run `make artifacts`"
                );
                run_session(args, &arch, &ds, &mut backend, iters, schedule, seed, None)
            }
            other => {
                eprintln!("unknown --backend {other}");
                std::process::exit(2);
            }
        }
    };

    if let Some(out) = args.get("out") {
        log_to_csv(&PathBuf::from(out), &log).expect("writing log CSV");
        println!("# wrote {out}");
    }
    match log.last() {
        Some(last) => println!(
            "# done: iters={} time={:.1}s final train_err={:.5} train_loss={:.5}",
            last.iter, last.time_s, last.train_err, last.train_loss
        ),
        // e.g. resuming a checkpoint already at/past --iters
        None => println!("# done: no iterations to run"),
    }
}
