//! Compiled-program wrapper + `Mat` ⇄ `Literal` conversion.

use crate::linalg::Mat;
use anyhow::{Context, Result};
use std::path::Path;

/// An AOT program compiled onto the PJRT client. All our programs are
/// lowered with `return_tuple=True`, so `run` unpacks one tuple.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Program {
    /// Load HLO text, parse, compile.
    pub fn load(client: &xla::PjRtClient, path: &Path, name: &str) -> Result<Program> {
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Program { exe, name: name.to_string() })
    }

    /// Execute with the given inputs; returns the unpacked output tuple.
    /// Accepts owned or borrowed literals (`&[Literal]` or `&[&Literal]`)
    /// so callers can reuse cached parameter literals across chunks
    /// without copying.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let outs = lit.to_tuple().with_context(|| format!("untupling {}", self.name))?;
        Ok(outs)
    }
}

/// Row-major f64 `Mat` → f32 `Literal` of shape `[rows, cols]`.
pub fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
    let data: Vec<f32> = m.data.iter().map(|&v| v as f32).collect();
    let lit = xla::Literal::vec1(&data);
    Ok(lit.reshape(&[m.rows as i64, m.cols as i64])?)
}

/// f32 `Literal` (any shape with `rows*cols` elements) → `Mat`.
pub fn literal_to_mat(l: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v: Vec<f32> = l.to_vec()?;
    anyhow::ensure!(v.len() == rows * cols, "literal size {} != {rows}x{cols}", v.len());
    Ok(Mat::from_vec(rows, cols, v.into_iter().map(|x| x as f64).collect()))
}

/// Scalar f32 literal → f64.
pub fn literal_scalar_f64(l: &xla::Literal) -> Result<f64> {
    Ok(l.get_first_element::<f32>()? as f64)
}

/// f64 vector → f32 literal of shape `[n]`.
pub fn vec_to_literal(v: &[f64]) -> xla::Literal {
    let data: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&data)
}

/// i32 scalar literal (e.g. RNG seeds).
pub fn i32_literal(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}
