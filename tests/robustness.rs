//! Failure-injection / edge-case robustness of the optimizer stack:
//! degenerate batches, converged gradients, extreme damping, and
//! pathological statistics must not produce NaNs or panics.

use kfac::backend::{ModelBackend, RustBackend};
use kfac::fisher::stats::RawStats;
use kfac::fisher::{BlockDiagInverse, FisherInverse, TridiagInverse};
use kfac::linalg::{eig, Mat, SymEig};
use kfac::nn::{Act, Arch, LossKind, Params};
use kfac::optim::{Kfac, KfacConfig, Optimizer};
use kfac::par;
use kfac::rng::Rng;

fn tiny() -> (Arch, Params, Mat, Mat) {
    let arch = Arch::new(vec![4, 3, 2], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
    let mut rng = Rng::new(1);
    let p = arch.glorot_init(&mut rng);
    let x = Mat::randn(8, 4, 1.0, &mut rng);
    let mut y = Mat::zeros(8, 2);
    for r in 0..8 {
        y.set(r, r % 2, 1.0);
    }
    (arch, p, x, y)
}

#[test]
fn single_case_minibatch_does_not_panic() {
    let (arch, mut p, x, y) = tiny();
    let mut be = RustBackend::new(arch.clone());
    let mut opt = Kfac::new(&arch, KfacConfig::default());
    let x1 = x.top_rows(1);
    let y1 = y.top_rows(1);
    for _ in 0..5 {
        let info = opt.step(&mut be, &mut p, &x1, &y1);
        assert!(info.loss.is_finite());
        assert!(info.delta_norm.unwrap().is_finite());
    }
}

#[test]
fn near_zero_gradient_produces_near_zero_update() {
    // At a (near-)optimum the α* solve must not blow up: δ → 0.
    let (arch, mut p, x, y) = tiny();
    let mut be = RustBackend::new(arch.clone());
    let mut opt = Kfac::new(&arch, KfacConfig { lambda0: 1.0, ..Default::default() });
    // drive close to optimum first
    for _ in 0..60 {
        opt.step(&mut be, &mut p, &x, &y);
    }
    let info = opt.step(&mut be, &mut p, &x, &y);
    let dn = info.delta_norm.unwrap();
    assert!(dn.is_finite());
    assert!(dn < 10.0, "update exploded near optimum: {dn}");
}

#[test]
fn extreme_damping_values_are_stable() {
    let (arch, p, x, y) = tiny();
    let mut be = RustBackend::new(arch.clone());
    for lambda0 in [1e-8, 1e8] {
        let mut params = p.clone();
        let mut opt = Kfac::new(&arch, KfacConfig { lambda0, ..Default::default() });
        let info = opt.step(&mut be, &mut params, &x, &y);
        assert!(info.loss.is_finite(), "λ0={lambda0}");
        assert!(info.delta_norm.unwrap().is_finite(), "λ0={lambda0}");
        for w in &params.0 {
            assert!(w.data.iter().all(|v| v.is_finite()), "λ0={lambda0}");
        }
    }
}

#[test]
fn rank_deficient_statistics_are_jitter_recovered() {
    // Constant activities (zero variance apart from the bias) make Ā
    // rank-deficient; the jittered Cholesky must still produce finite
    // inverses for both structures.
    let arch = Arch::new(vec![3, 2, 2], vec![Act::Tanh, Act::Identity], LossKind::SquaredError);
    let mut st = RawStats::zeros(&arch);
    // Ā = ones outer product (rank 1), G = rank-1 too
    for aa in st.aa.iter_mut() {
        *aa = Mat::filled(aa.rows, aa.cols, 1.0);
    }
    for gg in st.gg.iter_mut() {
        *gg = Mat::filled(gg.rows, gg.cols, 0.5);
    }
    let mut rng = Rng::new(3);
    let g = Params(vec![Mat::randn(2, 4, 1.0, &mut rng), Mat::randn(2, 3, 1.0, &mut rng)]);
    for gamma in [0.0, 1e-6, 1.0] {
        let bd = BlockDiagInverse::build(&st, gamma);
        let u = bd.apply(&g);
        assert!(u.0.iter().all(|m| m.data.iter().all(|v| v.is_finite())), "γ={gamma}");
        let tri = TridiagInverse::build(&st, gamma);
        let u = tri.apply(&g);
        assert!(u.0.iter().all(|m| m.data.iter().all(|v| v.is_finite())), "γ={gamma}");
    }
}

#[test]
fn momentum_with_identical_directions_falls_back() {
    // If δ0 is exactly parallel to Δ the 2×2 system is singular; the
    // solver must fall back to the 1-D solution rather than NaN.
    let q = Mat::from_vec(2, 2, vec![2.0, 2.0, 2.0, 2.0]);
    // (access through a full step is awkward; test the behaviour
    // indirectly by stepping twice on a quadratic-like problem)
    let _ = q;
    let (arch, mut p, x, y) = tiny();
    let mut be = RustBackend::new(arch.clone());
    let mut opt = Kfac::new(&arch, KfacConfig { t_inv: 1000, ..Default::default() });
    // two identical steps in a row make Δ and δ0 nearly parallel
    for _ in 0..4 {
        let info = opt.step(&mut be, &mut p, &x, &y);
        assert!(info.alpha.unwrap().is_finite() && info.mu.unwrap().is_finite());
    }
}

#[test]
fn wildly_scaled_inputs_do_not_break_training() {
    let arch = Arch::new(vec![4, 3, 2], vec![Act::Tanh, Act::Identity], LossKind::SquaredError);
    let mut rng = Rng::new(5);
    let mut p = arch.glorot_init(&mut rng);
    let x = Mat::randn(16, 4, 1.0, &mut rng).scale(1e4);
    let y = Mat::randn(16, 2, 1.0, &mut rng).scale(1e-4);
    let mut be = RustBackend::new(arch.clone());
    let l0 = be.loss(&p, &x, &y);
    let mut opt = Kfac::new(&arch, KfacConfig::default());
    for _ in 0..10 {
        let info = opt.step(&mut be, &mut p, &x, &y);
        assert!(info.loss.is_finite());
    }
    assert!(be.loss(&p, &x, &y) <= l0 * 1.001);
}

#[test]
fn background_eig_jobs_keep_counters_race_free_under_pool_contention() {
    // Background factorization jobs — each forcing the deterministic
    // QL→Jacobi fallback and dispatching nested pool work — race the
    // foreground's own GEMM dispatches on the shared pool. Completion
    // proves nested submission from job context cannot deadlock; the
    // process-wide fallback counter must count every forced fallback
    // exactly once, and the one-time stderr log must stay panic-free
    // under concurrency.
    const JOBS: usize = 4;
    const EIGS_PER_JOB: usize = 8;
    let n = 10;
    let mut h = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            h.set(i, j, 1.0 / ((i + j) as f64 + 1.0)); // Hilbert: symmetric
        }
    }
    let before = eig::tql2_fallback_count();
    let handles: Vec<_> = (0..JOBS)
        .map(|_| {
            let a = h.clone();
            par::spawn_job(move || {
                let mut acc = 0.0;
                for _ in 0..EIGS_PER_JOB {
                    // iteration cap 0 deterministically takes the
                    // Jacobi fallback and bumps the counter
                    let e = SymEig::new_blocked_with_iter_cap(&a, 0);
                    acc += e.w.iter().sum::<f64>();
                }
                acc + par::par_map(256, 8, |i| (i as f64).sqrt()).iter().sum::<f64>()
            })
        })
        .collect();
    // foreground: keep the pool busy with GEMM dispatches (large
    // enough to split into row-block chunks) while the background
    // jobs run
    let mut rng = Rng::new(31);
    let g = Mat::randn(160, 160, 1.0, &mut rng).scale(1.0 / 32.0);
    let mut prod = g.clone();
    for _ in 0..10 {
        prod = prod.matmul(&g);
        assert!(prod.data.iter().all(|v| v.is_finite()));
    }
    for hdl in handles {
        assert!(hdl.collect().is_finite(), "background job produced a non-finite result");
    }
    let after = eig::tql2_fallback_count();
    assert_eq!(
        after - before,
        JOBS * EIGS_PER_JOB,
        "fallback counter lost or double-counted concurrent updates"
    );
}

#[test]
fn async_refresh_interleaves_with_foreground_work_without_deadlock() {
    // KFAC_ASYNC=1 training: background inverse rebuilds dispatch
    // nested par_ranges from pool-job context while every foreground
    // step dispatches its own GEMMs into the same pool. Completing the
    // run (with a swap installed at each t_inv boundary past bootstrap)
    // proves submit/collect cannot deadlock against help-first waiting.
    let arch = Arch::new(
        vec![24, 16, 12, 8],
        vec![Act::Tanh, Act::Tanh, Act::Identity],
        LossKind::SquaredError,
    );
    let mut rng = Rng::new(29);
    let mut p = arch.glorot_init(&mut rng);
    let x = Mat::randn(48, 24, 1.0, &mut rng);
    let y = Mat::randn(48, 8, 0.5, &mut rng);
    let mut be = RustBackend::new(arch.clone());
    let cfg = KfacConfig { t_inv: 2, refresh_async: true, lambda0: 10.0, ..Default::default() };
    let mut opt = Kfac::new(&arch, cfg);
    for _ in 0..12 {
        let info = opt.step(&mut be, &mut p, &x, &y);
        assert!(info.loss.is_finite());
        assert!(info.inv_epoch.is_some(), "K-FAC steps must carry the inverse epoch tag");
    }
    // swaps really happened: three bootstrap installs plus at least one
    // collected background rebuild
    assert!(opt.inverse_epoch() > 3, "no asynchronous swap was ever installed");
}
