"""Pure-jnp oracles for the Pallas kernels (the pytest ground truth)."""

import jax.numpy as jnp

_ACTS = {
    "tanh": jnp.tanh,
    "logistic": lambda s: 1.0 / (1.0 + jnp.exp(-s)),
    "relu": lambda s: jnp.maximum(s, 0.0),
    "identity": lambda s: s,
}


def matmul(x, y, activation=None):
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))
    return activation(out) if activation is not None else out


def linear_fwd(abar, w, act="identity"):
    return _ACTS[act](jnp.dot(abar, w.T))


def cov(x, y, w):
    return jnp.dot((x * w[:, None]).T, y)


def kron_apply(ginv, v, ainv):
    return jnp.dot(jnp.dot(ginv, v), ainv)
