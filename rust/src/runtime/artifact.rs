//! Artifact manifest: describes the AOT-lowered programs per model
//! architecture (shapes, loss, chunk size, HLO file paths).

use super::{rt_err, Result};
use crate::nn::{Act, Arch, LossKind};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One architecture entry in the manifest.
#[derive(Clone, Debug)]
pub struct ArchManifest {
    pub name: String,
    pub widths: Vec<usize>,
    pub acts: Vec<Act>,
    pub loss: LossKind,
    /// Rows per program execution; callers chunk mini-batches into
    /// multiples of this (masked, so partial chunks are exact).
    pub chunk: usize,
    /// program name -> HLO text path (relative to the artifacts dir).
    pub programs: BTreeMap<String, PathBuf>,
}

impl ArchManifest {
    /// The `Arch` this entry describes.
    pub fn arch(&self) -> Arch {
        Arch::new(self.widths.clone(), self.acts.clone(), self.loss)
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub archs: Vec<ArchManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            rt_err(format!("reading {} (run `make artifacts`): {e}", path.display()))
        })?;
        let j = Json::parse(&text).map_err(|e| rt_err(format!("manifest parse error: {e}")))?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            return Err(rt_err(format!("unsupported manifest version {version}")));
        }
        let mut archs = Vec::new();
        for a in j.get("archs").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| rt_err("arch missing name"))?
                .to_string();
            let widths = a
                .get("widths")
                .and_then(Json::usize_vec)
                .ok_or_else(|| rt_err(format!("{name}: widths")))?;
            let act_names = a
                .get("acts")
                .and_then(Json::str_vec)
                .ok_or_else(|| rt_err(format!("{name}: acts")))?;
            let acts: Vec<Act> = act_names
                .iter()
                .map(|s| Act::from_name(s).ok_or_else(|| rt_err(format!("{name}: bad act {s}"))))
                .collect::<Result<_>>()?;
            let loss = a
                .get("loss")
                .and_then(Json::as_str)
                .and_then(LossKind::from_name)
                .ok_or_else(|| rt_err(format!("{name}: loss")))?;
            let chunk = a
                .get("chunk")
                .and_then(Json::as_usize)
                .ok_or_else(|| rt_err(format!("{name}: chunk")))?;
            let mut programs = BTreeMap::new();
            if let Some(obj) = a.get("programs").and_then(Json::as_obj) {
                for (k, v) in obj {
                    let rel =
                        v.as_str().ok_or_else(|| rt_err(format!("{name}: program path")))?;
                    programs.insert(k.clone(), PathBuf::from(rel));
                }
            }
            archs.push(ArchManifest { name, widths, acts, loss, chunk, programs });
        }
        Ok(Manifest { dir: dir.to_path_buf(), archs })
    }

    pub fn find(&self, name: &str) -> Result<&ArchManifest> {
        self.archs
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| rt_err(format!("arch '{name}' not in manifest ({:?})", self.names())))
    }

    pub fn names(&self) -> Vec<&str> {
        self.archs.iter().map(|a| a.name.as_str()).collect()
    }

    /// Absolute path of a program's HLO file.
    pub fn program_path(&self, arch: &ArchManifest, program: &str) -> Result<PathBuf> {
        let rel = arch
            .programs
            .get(program)
            .ok_or_else(|| rt_err(format!("arch '{}' missing program '{program}'", arch.name)))?;
        Ok(self.dir.join(rel))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("kfac_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "archs": [
                {"name": "tiny", "widths": [4, 3, 4],
                 "acts": ["tanh", "identity"], "loss": "sigmoid_ce",
                 "chunk": 8,
                 "programs": {"grad": "tiny/grad.hlo.txt"}}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.find("tiny").unwrap();
        assert_eq!(a.chunk, 8);
        let arch = a.arch();
        assert_eq!(arch.num_layers(), 2);
        assert_eq!(
            m.program_path(a, "grad").unwrap(),
            dir.join("tiny/grad.hlo.txt")
        );
        assert!(m.find("nope").is_err());
        assert!(m.program_path(a, "nope").is_err());
    }

    #[test]
    fn errors_are_descriptive() {
        let dir = std::env::temp_dir().join("kfac_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert!(e.to_string().contains("manifest.json"), "{e}");
        std::fs::write(dir.join("manifest.json"), r#"{"version": 2, "archs": []}"#).unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
    }
}
