//! Kronecker-factored Fisher approximations (paper Sections 3–5).
//!
//! - [`stats`]: per-batch second moments `Ā_{i,j}`, `G_{i,j}` and their
//!   online exponentially-decayed estimates (Section 5).
//! - [`damping`]: the factored Tikhonov technique (Section 6.3) with the
//!   trace-norm `π_i`.
//! - [`blockdiag`]: the block-diagonal inverse `F̌⁻¹` (Section 4.2).
//! - [`tridiag`]: the block-tridiagonal inverse `F̂⁻¹` (Section 4.3),
//!   built from the Ψ/Σ/Λ/Ξ machinery and the Appendix-B structured
//!   inverse.
//! - [`exact`]: dense exact `F` and exact `F̃` over a layer range for
//!   small networks — the substrate behind the Figure 2/3/5/6
//!   structure experiments.

pub mod blockdiag;
pub mod damping;
pub mod exact;
pub mod stats;
pub mod tridiag;

pub use blockdiag::BlockDiagInverse;
pub use stats::{KfacStats, RawStats};
pub use tridiag::TridiagInverse;

use crate::nn::Params;

/// A preconditioner: applies an approximate inverse Fisher to a
/// gradient-shaped `Params` (i.e. computes the update proposal
/// `Δ = -F₀⁻¹ ∇h` up to sign).
pub trait FisherInverse {
    fn apply(&self, grads: &Params) -> Params;
}

/// Which inverse approximation the optimizer uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InverseKind {
    /// `F̌⁻¹` — block-diagonal (Section 4.2).
    BlockDiag,
    /// `F̂⁻¹` — block-tridiagonal (Section 4.3).
    BlockTridiag,
}

impl InverseKind {
    pub fn name(self) -> &'static str {
        match self {
            InverseKind::BlockDiag => "blkdiag",
            InverseKind::BlockTridiag => "blktridiag",
        }
    }
}
