//! Figure 7 — effectiveness of the re-scaling technique of Section 6.4.
//! At a partially-trained point of an autoencoder run, sweep the
//! factored-Tikhonov strength γ and measure the improvement in the
//! objective, h(θ) − h(θ+δ), for the update δ produced
//!   (a) without re-scaling (δ = Δ, i.e. α = 1),
//!   (b) with the optimal re-scaling α* computed on the exact Fisher,
//!   (c) with re-scaling + momentum ((α, μ) jointly optimal).
//! The paper's findings to reproduce: the un-rescaled update only helps
//! at very large γ (and is harmful below), while re-scaled updates are
//! robust across γ and achieve a much larger best-case improvement.
//!
//! Output: table + results/fig7_damping.csv.

use kfac::backend::{ModelBackend, RustBackend};
use kfac::data::mnist_like;
use kfac::experiments::{results_dir, scaled};
use kfac::fisher::{FisherInverse, TridiagInverse};
use kfac::linalg::Mat;
use kfac::nn::{Act, Arch, Params};
use kfac::optim::{Kfac, KfacConfig, Optimizer};
use kfac::rng::Rng;
use kfac::util::write_csv;

fn main() {
    println!("== Figure 7: improvement vs γ, with/without re-scaling ==");
    // scaled-down MNIST autoencoder (the paper uses the full one at
    // iteration 500 — we partially train a 16×16 version)
    let arch = Arch::autoencoder(&[256, 100, 40, 12, 40, 100, 256], Act::Tanh);
    let n = scaled(1500, 400);
    let ds = mnist_like::autoencoder_dataset(n, 16, 0);
    let mut backend = RustBackend::new(arch.clone());
    let mut params = arch.sparse_init(&mut Rng::new(1));
    // λ adapted every iteration so it settles near its asymptotic value
    // within the short partial run (the paper probes iteration 500 of a
    // long run, where λ has long converged).
    let mut opt = Kfac::new(&arch, KfacConfig { lambda0: 5.0, t1: 1, ..Default::default() });
    let train_iters = scaled(80, 20);
    println!("# partially training for {train_iters} iterations…");
    let mut rng = Rng::new(2);
    let m = 1000.min(n);
    let (mut x, mut y) = ds.minibatch(m, &mut rng);
    for k in 1..=train_iters {
        let (xx, yy) = ds.minibatch(m, &mut rng);
        x = xx;
        y = yy;
        let info = opt.step(&mut backend, &mut params, &x, &y);
        if k % 20 == 0 {
            println!("# iter {k}: loss {:.4} λ {:.2}", info.loss, info.lambda.unwrap_or(f64::NAN));
        }
    }

    let eta = opt.cfg.eta;
    let lambda = opt.lambda;
    let delta0 = opt.last_update().expect("momentum direction").clone();
    let (loss0_raw, mut grad) = backend.grad(&params, &x, &y);
    let h0 = loss0_raw + 0.5 * eta * params.norm_sq();
    grad.axpy(eta, &params);

    let h_at = |backend: &mut RustBackend, delta: &Params| -> f64 {
        let mut th = params.clone();
        th.axpy(1.0, delta);
        backend.loss(&th, &x, &y) + 0.5 * eta * th.norm_sq()
    };

    println!("\n# sweeping γ (λ = {lambda:.3})…");
    println!(
        "{:>10} {:>14} {:>14} {:>14} {:>8} {:>8}",
        "gamma", "raw Δ", "rescaled", "resc+mom", "alpha", "mu"
    );
    let mut rows = Vec::new();
    for e in -4..=3 {
        for half in [1.0, 3.162278] {
            let gamma = 10f64.powi(e) * half;
            if !(1e-4..=2e3).contains(&gamma) {
                continue;
            }
            let inv = TridiagInverse::build(&opt.stats.s, gamma);
            let delta = inv.apply(&grad).scale(-1.0);

            // (a) raw update
            let imp_raw = h0 - h_at(&mut backend, &delta);

            // (b) rescaled: α* = −∇hᵀΔ / (ΔᵀFΔ + (λ+η)‖Δ‖²)
            let q = backend.fvp_quad(&params, &x, x.rows / 4, &[&delta]);
            let denom = q.at(0, 0) + (lambda + eta) * delta.norm_sq();
            let b0 = grad.dot(&delta);
            let alpha = -b0 / denom;
            let imp_resc = h0 - h_at(&mut backend, &delta.scale(alpha));

            // (c) rescaled + momentum
            let q2 = backend.fvp_quad(&params, &x, x.rows / 4, &[&delta, &delta0]);
            let damp = lambda + eta;
            let qm = Mat::from_vec(
                2,
                2,
                vec![
                    q2.at(0, 0) + damp * delta.dot(&delta),
                    q2.at(0, 1) + damp * delta.dot(&delta0),
                    q2.at(1, 0) + damp * delta.dot(&delta0),
                    q2.at(1, 1) + damp * delta0.dot(&delta0),
                ],
            );
            let bv = [grad.dot(&delta), grad.dot(&delta0)];
            let det = qm.at(0, 0) * qm.at(1, 1) - qm.at(0, 1) * qm.at(1, 0);
            let (am, mu) = (
                -(qm.at(1, 1) * bv[0] - qm.at(0, 1) * bv[1]) / det,
                -(-qm.at(1, 0) * bv[0] + qm.at(0, 0) * bv[1]) / det,
            );
            let mut dmom = delta.scale(am);
            dmom.axpy(mu, &delta0);
            let imp_mom = h0 - h_at(&mut backend, &dmom);

            println!(
                "{gamma:>10.4} {imp_raw:>14.5} {imp_resc:>14.5} {imp_mom:>14.5} {alpha:>8.4} {mu:>8.4}"
            );
            rows.push(vec![gamma, imp_raw, imp_resc, imp_mom, alpha, mu]);
        }
    }

    // paper-shape checks (Figure 7 / §6.4): the un-rescaled update is
    // catastrophically harmful outside a narrow large-γ window, while
    // the re-scaled update is robust (never harmful) across the entire
    // sweep, and momentum improves on plain re-scaling at its best.
    let best = |idx: usize| rows.iter().map(|r| r[idx]).fold(f64::NEG_INFINITY, f64::max);
    let worst = |idx: usize| rows.iter().map(|r| r[idx]).fold(f64::INFINITY, f64::min);
    let (best_raw, best_resc, best_mom) = (best(1), best(2), best(3));
    let (worst_raw, worst_resc, worst_mom) = (worst(1), worst(2), worst(3));
    println!(
        "\nbest improvement:  raw {best_raw:.5}   rescaled {best_resc:.5}   resc+mom {best_mom:.5}"
    );
    println!(
        "worst improvement: raw {worst_raw:.5}   rescaled {worst_resc:.5}   resc+mom {worst_mom:.5}"
    );
    assert!(worst_raw < 0.0, "raw updates should be harmful at small γ (paper Figure 7)");
    assert!(worst_resc > -1e-6, "re-scaled updates must never be harmful (robustness in γ)");
    assert!(worst_mom > -1e-6, "re-scaled+momentum updates must never be harmful");
    assert!(best_mom >= best_resc * 0.99, "momentum should improve on plain re-scaling");
    // γ-robustness ratio: fraction of sweep points with positive improvement
    let frac_pos = |idx: usize| {
        rows.iter().filter(|r| r[idx] > 0.0).count() as f64 / rows.len() as f64
    };
    println!(
        "fraction of γ grid with positive improvement: raw {:.0}%  rescaled {:.0}%  resc+mom {:.0}%",
        100.0 * frac_pos(1),
        100.0 * frac_pos(2),
        100.0 * frac_pos(3)
    );

    let path = results_dir().join("fig7_damping.csv");
    write_csv(&path, &["gamma", "raw", "rescaled", "rescaled_momentum", "alpha", "mu"], &rows)
        .unwrap();
    println!("wrote {}", path.display());
}
