//! Tiny criterion-style benchmark harness (no external crates).
//!
//! Our `[[bench]]` targets use `harness = false` and call into this
//! module: each benchmark warms up, then runs timed iterations until a
//! wall-clock budget is spent, and reports mean / median / stddev /
//! throughput in a stable, greppable format. The figure-level
//! experiment binaries use [`Timer`] directly.

use std::time::{Duration, Instant};

/// Simple wall-clock stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<40} iters={:<6} mean={:>12} median={:>12} stddev={:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
            fmt_time(self.stddev_s),
        );
    }

    /// Report with a derived throughput (e.g. GFLOP/s); returns the
    /// rate so callers can record it (see [`write_results_json`]).
    pub fn report_throughput(&self, unit: &str, per_iter: f64) -> f64 {
        let rate = per_iter / self.median_s / 1e9;
        println!(
            "bench {:<40} iters={:<6} mean={:>12} median={:>12} {rate:>10.3} {unit}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
        );
        rate
    }
}

/// Write bench results as JSON — the stable machine-readable record CI
/// captures (e.g. `BENCH_linalg.json`) so GFLOP/s baselines can be
/// tracked across commits. `gflops` is `null` for benches without a
/// meaningful flop count.
pub fn write_results_json(
    path: &std::path::Path,
    results: &[(BenchResult, Option<f64>)],
) -> std::io::Result<()> {
    use std::io::Write as _;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    // exponent form keeps full precision for ns-scale timings and is
    // still valid JSON ("1.5e-9")
    let json_num = |v: f64| if v.is_finite() { format!("{v:e}") } else { "null".to_string() };
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"threads\": {},", crate::par::num_threads())?;
    writeln!(f, "  \"benches\": [")?;
    for (i, (r, gflops)) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        let g = match gflops {
            Some(v) if v.is_finite() => format!("{v:.3}"),
            _ => "null".to_string(),
        };
        writeln!(
            f,
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_s\": {}, \"median_s\": {}, \"stddev_s\": {}, \"gflops\": {}}}{sep}",
            r.name,
            r.iters,
            json_num(r.mean_s),
            json_num(r.median_s),
            json_num(r.stddev_s),
            g
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    Ok(())
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark `f`, auto-scaling iteration count to the time budget.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // Warmup + calibration: run once to estimate cost.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_secs_f64().max(1e-9);
    let target_iters = ((budget.as_secs_f64() / first) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(target_iters);
    let hard_deadline = Instant::now() + budget.mul_f64(2.0);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if Instant::now() > hard_deadline {
            break;
        }
    }
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: crate::util::mean(&samples),
        median_s: crate::util::median(&samples),
        stddev_s: crate::util::stddev(&samples),
    };
    res.report();
    res
}

/// Default per-benchmark budget (override with KFAC_BENCH_BUDGET_MS).
pub fn default_budget() -> Duration {
    let ms = std::env::var("KFAC_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(1500);
    Duration::from_millis(ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
    }

    #[test]
    fn results_json_is_valid_json() {
        let r1 = BenchResult {
            name: "matmul_256x256x256".into(),
            iters: 5,
            mean_s: 1.5e-3,
            median_s: 1.4e-3,
            stddev_s: 1e-4,
        };
        let r2 = BenchResult {
            name: "sym_eig_101".into(),
            iters: 3,
            mean_s: 2e-2,
            median_s: 2e-2,
            stddev_s: 0.0,
        };
        let path = std::env::temp_dir().join("kfac_bench_json/BENCH_test.json");
        write_results_json(&path, &[(r1, Some(23.9)), (r2, None)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&text).expect("valid json");
        let benches = j.get("benches").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(benches[0].get("name").unwrap().as_str(), Some("matmul_256x256x256"));
        assert_eq!(benches[0].get("gflops").unwrap().as_f64(), Some(23.9));
        assert_eq!(benches[1].get("gflops"), Some(&crate::util::json::Json::Null));
        assert!(j.get("threads").unwrap().as_usize().unwrap() >= 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
