//! END-TO-END driver: the paper's MNIST deep-autoencoder benchmark on
//! the full three-layer stack — JAX/Pallas AOT artifacts executed from
//! Rust via PJRT (Python never runs here), K-FAC with the exponentially
//! increasing batch-size schedule of Section 13, SGD+NAG baseline for
//! comparison, loss curves logged to `results/e2e_mnist_*.csv`.
//!
//!     make artifacts && cargo run --release --example mnist_autoencoder
//!
//! Flags: --iters N (default 120) --data N (default 4000) --sgd
//!        --quick (tiny run for smoke-testing)

use kfac::backend::{ModelBackend, PjrtBackend};
use kfac::coordinator::cli::Args;
use kfac::coordinator::trainer::{log_to_csv, Optimizer, Problem, TrainConfig, Trainer};
use kfac::optim::{BatchSchedule, KfacConfig, SgdConfig};
use kfac::rng::Rng;
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    let quick = args.get_flag("quick");
    let iters = args.get_usize("iters", if quick { 10 } else { 120 });
    let n_data = args.get_usize("data", if quick { 600 } else { 4000 });
    let problem = Problem::MnistAe;
    let arch = problem.arch();

    println!("# generating synthetic MNIST ({n_data} cases)…");
    let ds = problem.dataset(n_data, 0);

    println!("# loading AOT artifacts (arch {:?}, {} params)…", arch.widths, arch.num_params());
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut backend = PjrtBackend::new(&artifacts, problem.name()).unwrap_or_else(|e| {
        eprintln!("error: {e:#}\nrun `make artifacts` first");
        std::process::exit(1);
    });
    assert_eq!(backend.arch().widths, arch.widths);

    let cfg = TrainConfig {
        iters,
        // paper §13: m_k = min(m₁ exp((k−1)/b), |S|), saturating at ~¾ of
        // the run
        schedule: BatchSchedule::exponential_reaching(
            250.min(n_data),
            n_data,
            (iters * 3 / 4).max(2),
        ),
        seed: 0,
        eval_every: 5,
        eval_rows: 1000.min(n_data),
        polyak: Some(0.99),
    };

    let (optimizer, tag) = if args.get_flag("sgd") {
        (
            Optimizer::Sgd(SgdConfig { lr: args.get_f64("lr", 0.02), ..Default::default() }),
            "e2e_mnist_sgd",
        )
    } else {
        (
            Optimizer::Kfac(KfacConfig {
                lambda0: args.get_f64("lambda0", 150.0),
                ..Default::default()
            }),
            "e2e_mnist_kfac",
        )
    };

    println!("# training ({tag})…");
    let mut params = arch.sparse_init(&mut Rng::new(1));
    let log = Trainer::new(cfg, &ds).run(&mut backend, &mut params, optimizer, true);

    let out = PathBuf::from(format!("results/{tag}.csv"));
    log_to_csv(&out, &log).expect("writing csv");
    let last = log.last().unwrap();
    println!(
        "# done: {} iters, {:.1}s train time, final reconstruction error {:.4}",
        last.iter, last.time_s, last.train_err
    );
    println!("# loss curve written to {}", out.display());
}
