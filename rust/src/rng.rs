//! Small, fast, dependency-free PRNG (xoshiro256** seeded via SplitMix64)
//! with the sampling primitives K-FAC needs: uniforms, normals
//! (Box–Muller), Bernoulli and categorical draws (for sampling targets
//! from the model's predictive distribution, Section 5 of the paper),
//! and Fisher–Yates shuffles for mini-batching.

/// xoshiro256** PRNG. Deterministic given the seed; every experiment in
/// this repository threads explicit seeds for reproducibility.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-iteration seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Full generator state (xoshiro words + cached Box–Muller spare)
    /// for checkpointing.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare_normal)
    }

    /// Rebuild a generator from checkpointed [`Rng::state`] output —
    /// the restored stream continues bit-exactly.
    pub fn from_state(s: [u64; 4], spare_normal: Option<f64>) -> Rng {
        Rng { s, spare_normal }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift; bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Bernoulli draw with probability `p` of returning 1.0.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> f64 {
        if self.uniform() < p {
            1.0
        } else {
            0.0
        }
    }

    /// Categorical draw from (possibly unnormalized) non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(9);
        let _ = a.normal(); // populate the Box–Muller spare
        let (s, spare) = a.state();
        let mut b = Rng::from_state(s, spare);
        for _ in 0..20 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval_with_sane_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(11);
        let w = [0.1, 0.7, 0.2];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&w)] += 1;
        }
        let f1 = counts[1] as f64 / 30_000.0;
        assert!((f1 - 0.7).abs() < 0.02, "f1={f1}");
    }

    #[test]
    fn below_bounds_and_shuffle_is_permutation() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
