//! Micro-benchmarks for the dense linear-algebra substrate (the L3 hot
//! paths). Run with `cargo bench --bench linalg`.
//!
//! GFLOP/s is reported for every GEMM transpose variant on both square
//! shapes and the blocked rectangular shapes K-FAC actually produces
//! (activation covariances `Āᵀ Ā`, layer forwards `Ā Wᵀ`, preconditioner
//! GEMMs) — the numbers to watch when touching `linalg::gemm`.
//!
//! Besides the console report, results are written as JSON to
//! `BENCH_linalg.json` (override with `KFAC_BENCH_JSON`) so CI can
//! archive GFLOP/s baselines per commit — including per-size `SymEig`
//! timings (n = 64/256/512, blocked vs. scalar-QL reference) so the
//! eigensolver speedup is tracked alongside GEMM. Square matmul shapes
//! additionally emit one entry per executable micro-kernel
//! (`matmul_512_scalar`, `matmul_512_avx2`, …) so the JSON records the
//! SIMD speedup itself, not just the dispatched winner; CI's
//! `bench-gate` job compares all of this against the committed
//! `BENCH_baseline.json` and fails on >20% median GFLOP/s regressions.

use kfac::bench::{bench, default_budget, write_results_json, BenchResult};
use kfac::linalg::{chol::spd_inverse, gemm, simd, KronPairInverse, Mat, SymEig};
use kfac::rng::Rng;

fn main() {
    let budget = default_budget();
    let mut rng = Rng::new(0);
    let mut results: Vec<(BenchResult, Option<f64>)> = Vec::new();

    // ---- GEMM: all transpose variants over square + K-FAC shapes ----
    // (1000, 257, 100): batch-1000 forward through a 257→100 layer;
    // (257, 1000, 257): the Āᵀ Ā covariance of the same layer;
    // (401, 401, 401): the widest damped-factor inverse GEMM.
    for &(m, k, n) in &[
        (256usize, 256usize, 256usize),
        (1000, 257, 100),
        (257, 1000, 257),
        (401, 401, 401),
        (512, 512, 512),
    ] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let at = a.transpose(); // k×m
        let bt = b.transpose(); // n×k
        let flops = (2 * m * k * n) as f64;

        let r = bench(&format!("matmul_{m}x{k}x{n}"), budget, || {
            std::hint::black_box(a.matmul(&b));
        });
        let g = r.report_throughput("GFLOP/s", flops);
        results.push((r, Some(g)));

        let r = bench(&format!("matmul_tn_{m}x{k}x{n}"), budget, || {
            std::hint::black_box(at.matmul_tn(&b));
        });
        let g = r.report_throughput("GFLOP/s", flops);
        results.push((r, Some(g)));

        let r = bench(&format!("matmul_nt_{m}x{k}x{n}"), budget, || {
            std::hint::black_box(a.matmul_nt(&bt));
        });
        let g = r.report_throughput("GFLOP/s", flops);
        results.push((r, Some(g)));

        // Per-kernel entries on the square shapes (matmul_512_scalar,
        // matmul_512_avx2, …): every micro-kernel this host can execute
        // runs the same NN product through the forced-kernel hook, so
        // BENCH_linalg.json shows the SIMD speedup explicitly instead
        // of only the dispatched winner.
        if m == k && k == n {
            for kern in simd::available_kernels() {
                let r = bench(&format!("matmul_{n}_{}", kern.name), budget, || {
                    let mut out = vec![0.0f64; m * n];
                    gemm::gemm_strided_into_with(
                        kern,
                        m,
                        n,
                        k,
                        &a.data,
                        k,
                        1,
                        &b.data,
                        n,
                        1,
                        &mut out,
                        n,
                    );
                    std::hint::black_box(out);
                });
                let g = r.report_throughput("GFLOP/s", flops);
                results.push((r, Some(g)));
            }
        }
    }

    // ---- matvec (the n = 1 path) ----
    for &(m, k) in &[(1000usize, 1000usize), (4000, 257)] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let v: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let flops = (2 * m * k) as f64;
        let r = bench(&format!("matvec_{m}x{k}"), budget, || {
            std::hint::black_box(a.matvec(&v));
        });
        let g = r.report_throughput("GFLOP/s", flops);
        results.push((r, Some(g)));
    }

    // ---- factor inversions ----
    for n in [101usize, 257, 401] {
        let x = Mat::randn(n + 8, n, 1.0, &mut rng);
        let spd = x.matmul_tn(&x).add_diag(0.5);
        let r = bench(&format!("spd_inverse_{n}"), budget, || {
            std::hint::black_box(spd_inverse(&spd));
        });
        results.push((r, None));
    }

    // ---- eigensolver: per-size SymEig timings tracked per-commit in
    // BENCH_linalg.json alongside GEMM (the blocked, pool-parallel path
    // that dominates every T₃ inverse refresh) ----
    for n in [64usize, 256, 512] {
        let x = Mat::randn(n + 8, n, 1.0, &mut rng);
        let spd = x.matmul_tn(&x).add_diag(0.5);
        let r = bench(&format!("sym_eig_{n}"), budget, || {
            std::hint::black_box(SymEig::new(&spd));
        });
        results.push((r, None));
        // the scalar reference path at the same size, for the speedup
        // ratio the blocked rebuild is meant to move
        let r = bench(&format!("sym_eig_ql_ref_{n}"), budget, || {
            std::hint::black_box(SymEig::new_ql(&spd));
        });
        results.push((r, None));
    }

    // Appendix-B structured inverse: build (amortized, every T3 iters)
    // vs apply (every iteration).
    let na = 101;
    let nb = 40;
    let xa = Mat::randn(na + 4, na, 1.0, &mut rng);
    let a = xa.matmul_tn(&xa).add_diag(1.0);
    let xb = Mat::randn(nb + 4, nb, 1.0, &mut rng);
    let b = xb.matmul_tn(&xb).add_diag(1.0);
    let c = a.scale(0.3);
    let d = b.scale(0.4);
    let r = bench(&format!("kron_pair_inverse_build_{na}x{nb}"), budget, || {
        std::hint::black_box(KronPairInverse::new(&a, &b, &c, &d, -1.0));
    });
    results.push((r, None));
    let kpi = KronPairInverse::new(&a, &b, &c, &d, -1.0);
    let v = Mat::randn(nb, na, 1.0, &mut rng);
    let r = bench(&format!("kron_pair_inverse_apply_{na}x{nb}"), budget, || {
        std::hint::black_box(kpi.apply(&v));
    });
    results.push((r, None));

    let path =
        std::env::var("KFAC_BENCH_JSON").unwrap_or_else(|_| "BENCH_linalg.json".to_string());
    write_results_json(std::path::Path::new(&path), &results).expect("writing bench json");
    println!("wrote {path} ({} benches)", results.len());
}
