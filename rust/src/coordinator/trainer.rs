//! The training loop: composes a dataset, a [`ModelBackend`], and an
//! optimizer (K-FAC or the SGD baseline), with the paper's evaluation
//! protocol — Polyak-style iterate averaging with the reported error
//! being the min over {current, averaged} (Section 13), and wall-clock
//! accounting that excludes evaluation overhead.

use crate::backend::ModelBackend;
use crate::bench::Timer;
use crate::data::{curves_like, faces_like, mnist_like, Dataset};
use crate::nn::{Act, Arch, Params};
use crate::optim::{BatchSchedule, Kfac, KfacConfig, PolyakAverager, Sgd, SgdConfig};
use crate::rng::Rng;

/// The paper's three benchmark problems plus the small classifier used
/// by the Fisher-structure figures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Problem {
    MnistAe,
    CurvesAe,
    FacesAe,
    MnistClf,
}

impl Problem {
    pub fn name(self) -> &'static str {
        match self {
            Problem::MnistAe => "mnist_ae",
            Problem::CurvesAe => "curves_ae",
            Problem::FacesAe => "faces_ae",
            Problem::MnistClf => "mnist_clf",
        }
    }

    pub fn from_name(s: &str) -> Option<Problem> {
        Some(match s {
            "mnist_ae" => Problem::MnistAe,
            "curves_ae" => Problem::CurvesAe,
            "faces_ae" => Problem::FacesAe,
            "mnist_clf" => Problem::MnistClf,
            _ => return None,
        })
    }

    /// Default (scaled-down from the paper; see DESIGN.md) architecture.
    pub fn arch(self) -> Arch {
        match self {
            // paper: 784-1000-500-250-30 (mirrored); ours is ~0.4×
            Problem::MnistAe => {
                Arch::autoencoder(&[784, 400, 200, 100, 30, 100, 200, 400, 784], Act::Tanh)
            }
            // paper: 784-400-200-100-50-25-6 (mirrored), kept at ~0.5×
            Problem::CurvesAe => Arch::autoencoder(
                &[784, 200, 100, 50, 25, 12, 6, 12, 25, 50, 100, 200, 784],
                Act::Tanh,
            ),
            // paper: 625-2000-1000-500-30; ours ~0.25×, Gaussian output
            Problem::FacesAe => Arch::autoencoder_gaussian(
                &[625, 500, 250, 125, 30, 125, 250, 500, 625],
                Act::Tanh,
            ),
            // the Figure-2 network: 16×16 MNIST, 256-20-20-20-20-10 tanh
            Problem::MnistClf => Arch::classifier(&[256, 20, 20, 20, 20, 10], Act::Tanh),
        }
    }

    /// Generate the synthetic dataset (see `data::*`).
    pub fn dataset(self, n: usize, seed: u64) -> Dataset {
        match self {
            Problem::MnistAe => mnist_like::autoencoder_dataset(n, 28, seed),
            Problem::CurvesAe => curves_like::autoencoder_dataset(n, 28, seed),
            Problem::FacesAe => faces_like::autoencoder_dataset(n, 25, seed),
            Problem::MnistClf => mnist_like::classification_dataset(n, 16, seed),
        }
    }
}

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub iters: usize,
    pub schedule: BatchSchedule,
    pub seed: u64,
    /// Evaluate (and log) every this many iterations.
    pub eval_every: usize,
    /// Rows of the training set used for error evaluation.
    pub eval_rows: usize,
    /// Polyak averaging decay ξ (paper: 0.99); `None` disables.
    pub polyak: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 100,
            schedule: BatchSchedule::Fixed(256),
            seed: 0,
            eval_every: 5,
            eval_rows: 1000,
            polyak: Some(0.99),
        }
    }
}

/// One logged evaluation point.
#[derive(Clone, Copy, Debug)]
pub struct LogRow {
    pub iter: usize,
    /// Cumulative training cases processed.
    pub cases: f64,
    /// Cumulative optimizer wall-clock (excludes evaluation).
    pub time_s: f64,
    /// Mini-batch regularized objective at this iteration.
    pub batch_loss: f64,
    /// Training-set error (min over current/averaged params).
    pub train_err: f64,
    /// Training-set loss (same min rule).
    pub train_loss: f64,
}

/// Which optimizer a run uses.
pub enum Optimizer {
    Kfac(KfacConfig),
    Sgd(SgdConfig),
}

/// Runs training and collects the log.
pub struct Trainer<'a> {
    pub cfg: TrainConfig,
    pub ds: &'a Dataset,
}

impl<'a> Trainer<'a> {
    pub fn new(cfg: TrainConfig, ds: &'a Dataset) -> Trainer<'a> {
        Trainer { cfg, ds }
    }

    /// Train `params` in place; returns the evaluation log.
    pub fn run(
        &self,
        backend: &mut dyn ModelBackend,
        params: &mut Params,
        optimizer: Optimizer,
        verbose: bool,
    ) -> Vec<LogRow> {
        let mut rng = Rng::new(self.cfg.seed ^ 0x5EED);
        let eval_rows = self.cfg.eval_rows.min(self.ds.len());
        let eval_x = self.ds.x.top_rows(eval_rows);
        let eval_y = self.ds.y.top_rows(eval_rows);
        let mut avg = self.cfg.polyak.map(PolyakAverager::new);

        enum Opt {
            K(Kfac),
            S(Sgd),
        }
        let mut opt = match optimizer {
            Optimizer::Kfac(c) => Opt::K(Kfac::new(backend.arch(), c)),
            Optimizer::Sgd(c) => Opt::S(Sgd::new(c)),
        };

        let mut log = Vec::new();
        let mut cases = 0.0;
        let mut train_time = 0.0;
        for k in 1..=self.cfg.iters {
            let m = self.cfg.schedule.size(k);
            let (x, y) = self.ds.minibatch(m, &mut rng);
            let t = Timer::start();
            let batch_loss = match &mut opt {
                Opt::K(o) => o.step(backend, params, &x, &y).loss,
                Opt::S(o) => o.step(backend, params, &x, &y),
            };
            train_time += t.elapsed_s();
            cases += m as f64;
            if let Some(a) = avg.as_mut() {
                a.update(params);
            }

            if k % self.cfg.eval_every == 0 || k == self.cfg.iters || k == 1 {
                let (mut loss, mut err) = backend.eval(params, &eval_x, &eval_y);
                if let Some(a) = avg.as_ref() {
                    let (al, ae) = backend.eval(a.get().unwrap(), &eval_x, &eval_y);
                    if ae < err {
                        err = ae;
                        loss = al;
                    }
                }
                let row = LogRow {
                    iter: k,
                    cases,
                    time_s: train_time,
                    batch_loss,
                    train_err: err,
                    train_loss: loss,
                };
                if verbose {
                    println!(
                        "iter {:>5}  m={:>6}  time={:>8.2}s  loss={:.5}  err={:.5}",
                        k, m, train_time, loss, err
                    );
                }
                log.push(row);
            }
        }
        log
    }
}

/// Write a training log as CSV.
pub fn log_to_csv(path: &std::path::Path, log: &[LogRow]) -> std::io::Result<()> {
    crate::util::write_csv(
        path,
        &["iter", "cases", "time_s", "batch_loss", "train_err", "train_loss"],
        &log.iter()
            .map(|r| vec![r.iter as f64, r.cases, r.time_s, r.batch_loss, r.train_err, r.train_loss])
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::RustBackend;
    use crate::fisher::InverseKind;

    #[test]
    fn problems_have_consistent_arch_and_data() {
        for p in [Problem::MnistAe, Problem::CurvesAe, Problem::FacesAe, Problem::MnistClf] {
            let arch = p.arch();
            let ds = p.dataset(20, 1);
            assert_eq!(ds.x.cols, arch.widths[0], "{p:?} input width");
            assert_eq!(ds.y.cols, *arch.widths.last().unwrap(), "{p:?} target width");
            assert_eq!(Problem::from_name(p.name()), Some(p));
        }
    }

    #[test]
    fn kfac_trainer_reduces_error_on_small_autoencoder() {
        // Small end-to-end smoke: 16x16 digit autoencoder, rust backend.
        let arch = Arch::autoencoder(&[256, 32, 8, 32, 256], Act::Tanh);
        let ds = mnist_like::autoencoder_dataset(256, 16, 3);
        let mut backend = RustBackend::new(arch.clone());
        let mut params = arch.sparse_init(&mut Rng::new(1));
        let cfg = TrainConfig {
            iters: 25,
            schedule: BatchSchedule::Fixed(128),
            eval_every: 5,
            eval_rows: 128,
            polyak: Some(0.99),
            seed: 2,
        };
        let kcfg = KfacConfig {
            inverse: InverseKind::BlockDiag,
            lambda0: 15.0,
            ..Default::default()
        };
        let log = Trainer::new(cfg, &ds).run(
            &mut backend,
            &mut params,
            Optimizer::Kfac(kcfg),
            false,
        );
        let first = log.first().unwrap().train_err;
        let last = log.last().unwrap().train_err;
        assert!(last < first, "err did not decrease: {first} -> {last}");
    }
}
