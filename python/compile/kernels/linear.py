"""Fused linear-layer forward kernel: ``act(abar @ W^T)``.

The paper's layer computation ``s_i = W_i abar_{i-1}`` batched row-wise,
with the elementwise activation fused into the last reduction step of
the tiled GEMM so the pre-activations never round-trip to HBM on a real
TPU (on CPU/interpret the fusion is still exercised structurally).
"""

import jax.numpy as jnp

from . import matmul

_ACTS = {
    "tanh": jnp.tanh,
    "logistic": lambda s: 1.0 / (1.0 + jnp.exp(-s)),
    "relu": lambda s: jnp.maximum(s, 0.0),
    "identity": None,
}


def act_fn(name):
    if name not in _ACTS:
        raise ValueError(f"unknown activation {name!r}")
    return _ACTS[name]


def linear_fwd(abar, w, act="identity"):
    """``act(abar @ w.T)`` — `abar` is `[m, d_in+1]`, `w` `[d_out, d_in+1]`."""
    return matmul.matmul_nt(abar, w, activation=act_fn(act))
