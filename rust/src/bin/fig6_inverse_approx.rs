//! Figure 6 — the quantity that actually matters for the update: how
//! well F̌⁻¹ and F̂⁻¹ approximate F̃⁻¹. The paper's findings:
//! (a) F̌⁻¹ is a *reasonable* approximation despite F̌ being a poor
//!     forward approximation (because F̃⁻¹ is ≈ block-diagonal), and
//! (b) F̂⁻¹ is significantly better — even on the diagonal blocks.
//!
//! Output: per-variant inverse-error maps + summary;
//! results/fig6_inverse.csv.

use kfac::coordinator::Problem;
use kfac::experiments::{partially_train, results_dir, scaled};
use kfac::fisher::exact::ExactBlocks;
use kfac::util::write_csv;

fn main() {
    println!("== Figure 6: F̌⁻¹ and F̂⁻¹ vs F̃⁻¹ ==");
    let (backend, params, ds) = partially_train(Problem::MnistClf, scaled(600, 200), 8, 0);
    let x = ds.x.top_rows(scaled(300, 100).min(ds.len()));
    let eb = ExactBlocks::compute(backend.net(), &params, &x, 1, 5);
    let gamma = 0.3;

    let ktilde_inv = eb.ktilde_damped_dense(gamma).inverse();
    let fcheck_inv = eb.fcheck_dense(gamma).inverse();
    let fhat_inv = eb.fhat_inv_dense(gamma);

    let d_check = fcheck_inv.sub(&ktilde_inv);
    let d_hat = fhat_inv.sub(&ktilde_inv);
    let rel_c = d_check.frob_norm() / ktilde_inv.frob_norm();
    let rel_h = d_hat.frob_norm() / ktilde_inv.frob_norm();
    println!("\n‖F̃⁻¹‖_F = {:.6}", ktilde_inv.frob_norm());
    println!("‖F̌⁻¹ − F̃⁻¹‖_F rel = {rel_c:.4}");
    println!("‖F̂⁻¹ − F̃⁻¹‖_F rel = {rel_h:.4}");

    let map_c = eb.block_avg_abs(&d_check);
    let map_h = eb.block_avg_abs(&d_hat);
    for (name, m) in [("|F̌⁻¹ − F̃⁻¹|", &map_c), ("|F̂⁻¹ − F̃⁻¹|", &map_h)] {
        println!("\n{name} (block-average |entries|):");
        for r in 0..m.rows {
            print!("  ");
            for c in 0..m.cols {
                print!(" {:>10.3e}", m.at(r, c));
            }
            println!();
        }
    }

    // paper's finding (b): tridiag better even on the diagonal blocks
    let nb = map_c.rows;
    let diag_c: f64 = (0..nb).map(|i| map_c.at(i, i)).sum();
    let diag_h: f64 = (0..nb).map(|i| map_h.at(i, i)).sum();
    println!("\ndiagonal-block error sums:  F̌⁻¹ {diag_c:.3e}   F̂⁻¹ {diag_h:.3e}");
    assert!(rel_h < rel_c, "F̂⁻¹ must be the better inverse approximation overall");
    assert!(diag_h < diag_c, "F̂⁻¹ must be better even on the diagonal blocks (paper §4.4)");
    println!("OK: F̂⁻¹ beats F̌⁻¹ overall and on the diagonal blocks");

    let mut rows = Vec::new();
    for r in 0..nb {
        for c in 0..nb {
            rows.push(vec![r as f64, c as f64, map_c.at(r, c), map_h.at(r, c)]);
        }
    }
    let path = results_dir().join("fig6_inverse.csv");
    write_csv(&path, &["block_i", "block_j", "fcheck_inv_err", "fhat_inv_err"], &rows).unwrap();
    println!("wrote {}", path.display());
}
