"""Tiled Pallas matmul — the GEMM primitive under every K-FAC hot spot.

TPU-shaped tiling: the grid walks (M/bm, N/bn) output tiles with a
reduction loop over K/bk; each step keeps an (bm, bk) x (bk, bn) pair in
VMEM-sized blocks (default 128, MXU-aligned) and accumulates into the
output tile. Inputs whose dimensions don't divide the block size are
zero-padded outside the kernel (exact for a GEMM) and the result is
sliced back.

Lowered with ``interpret=True`` so the same HLO runs on the CPU PJRT
client; on a real TPU the identical BlockSpec schedule is what Mosaic
would pipeline HBM->VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, activation):
    """One (bm, bn) output tile; k is the innermost grid axis."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    if activation is not None:
        @pl.when(k == nk - 1)
        def _act():
            o_ref[...] = activation(o_ref[...])


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _ceil_mult(n, b):
    return ((n + b - 1) // b) * b


@functools.partial(jax.named_call, name="pl_matmul")
def matmul(x, y, activation=None, block=DEFAULT_BLOCK):
    """``activation(x @ y)`` via the tiled Pallas kernel (f32)."""
    assert x.ndim == 2 and y.ndim == 2 and x.shape[1] == y.shape[0], (
        x.shape,
        y.shape,
    )
    m, k = x.shape
    _, n = y.shape
    bm, bk, bn = min(block, m), min(block, k), min(block, n)
    mp, kp, np_ = _ceil_mult(m, bm), _ceil_mult(k, bk), _ceil_mult(n, bn)
    xp = _pad_to(x.astype(jnp.float32), mp, kp)
    yp = _pad_to(y.astype(jnp.float32), kp, np_)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def matmul_nt(x, y, activation=None, block=DEFAULT_BLOCK):
    """``activation(x @ y.T)`` (layer forward ``abar @ W^T``)."""
    return matmul(x, y.T, activation=activation, block=block)


def matmul_tn(x, y, block=DEFAULT_BLOCK):
    """``x.T @ y`` (gradient / covariance contractions)."""
    return matmul(x.T, y, block=block)
