//! Shared harness code for the figure-regeneration binaries
//! (`rust/src/bin/fig*.rs`). Each paper figure maps to one binary; the
//! common machinery — running a set of optimizer variants on a problem
//! through [`TrainSession`] and collecting training curves, and
//! partially training a network to a given iteration for the
//! structure/damping experiments — lives here.

use crate::backend::{ModelBackend, RustBackend};
use crate::coordinator::{log_to_csv, LogRow, Problem, TrainSession};
use crate::fisher::PrecondRef;
use crate::nn::{Arch, Params};
use crate::optim::{BatchSchedule, Kfac, KfacConfig, Optimizer, Sgd, SgdConfig};
use crate::rng::Rng;
use std::path::PathBuf;

/// A named optimizer variant for comparison plots: a factory so each
/// run builds a fresh optimizer against the problem's architecture.
pub struct Variant {
    pub name: String,
    make: Box<dyn FnOnce(&Arch) -> Box<dyn Optimizer> + Send>,
}

impl Variant {
    pub fn kfac(name: &str, precond: PrecondRef, momentum: bool, lambda0: f64) -> Variant {
        // λ adapted every iteration: the figure runs are 1–2 orders of
        // magnitude shorter than the paper's, so the LM rule must settle
        // within tens of iterations rather than hundreds (T₁ = 5 with
        // λ₀ = 150 would leave the runs over-damped throughout).
        let cfg = KfacConfig { precond, lambda0, momentum, t1: 1, ..Default::default() };
        Variant {
            name: name.to_string(),
            make: Box::new(move |arch| Box::new(Kfac::new(arch, cfg))),
        }
    }

    pub fn sgd(name: &str, lr: f64, mu_max: f64) -> Variant {
        let cfg = SgdConfig { lr, mu_max, ..Default::default() };
        Variant {
            name: name.to_string(),
            make: Box::new(move |_arch| Box::new(Sgd::new(cfg))),
        }
    }

    /// Build the optimizer for `arch`.
    pub fn build(self, arch: &Arch) -> Box<dyn Optimizer> {
        (self.make)(arch)
    }
}

/// Results directory (override with KFAC_RESULTS_DIR).
pub fn results_dir() -> PathBuf {
    std::env::var("KFAC_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// Scale factor for experiment sizes (override with KFAC_EXP_SCALE, in
/// (0, 1]; smaller = faster smoke runs).
pub fn exp_scale() -> f64 {
    std::env::var("KFAC_EXP_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(1.0)
}

/// Scale a count by `exp_scale`, with a floor.
pub fn scaled(n: usize, floor: usize) -> usize {
    ((n as f64 * exp_scale()) as usize).max(floor)
}

/// Knobs for one comparison run (the self-labeling subset of the
/// `TrainSession` builder the figure harness varies). `seed` drives
/// mini-batch sampling, `init_seed` the sparse parameter init — kept
/// separate so the figure runs reproduce the historical trajectories
/// (and stay comparable with CSVs cached by `cached_run`).
#[derive(Clone, Debug)]
pub struct RunCfg {
    pub iters: usize,
    pub schedule: BatchSchedule,
    pub eval_every: usize,
    pub eval_rows: usize,
    pub seed: u64,
    pub init_seed: u64,
}

/// Run one variant on one problem with a fresh backend/params and
/// return the log; also writes `results/<tag>.csv`.
pub fn run_variant(
    problem: Problem,
    ds: &crate::data::Dataset,
    cfg: &RunCfg,
    variant: Variant,
    tag: &str,
) -> Vec<LogRow> {
    let arch = problem.arch();
    let mut backend = RustBackend::new(arch);
    run_variant_with_backend(&mut backend, ds, cfg, variant, tag)
}

/// Same, but with a caller-supplied backend (e.g. PJRT).
pub fn run_variant_with_backend(
    backend: &mut dyn ModelBackend,
    ds: &crate::data::Dataset,
    cfg: &RunCfg,
    variant: Variant,
    tag: &str,
) -> Vec<LogRow> {
    let arch = backend.arch().clone();
    let opt = variant.build(&arch);
    let report = TrainSession::for_dataset(arch.clone(), ds)
        .iters(cfg.iters)
        .schedule(cfg.schedule.clone())
        .seed(cfg.seed)
        .eval_every(cfg.eval_every)
        .eval_rows(cfg.eval_rows)
        .polyak(0.99)
        .params(arch.sparse_init(&mut Rng::new(cfg.init_seed)))
        .optimizer_boxed(opt)
        .backend(backend)
        .verbose(true)
        .run();
    let path = results_dir().join(format!("{tag}.csv"));
    if let Err(e) = log_to_csv(&path, &report.log) {
        eprintln!("warning: could not write {}: {e}", path.display());
    }
    report.log
}

/// Parse a training-log CSV back into rows (cache hits for re-plotting
/// binaries like fig11 that reuse fig10's runs).
pub fn load_log(tag: &str) -> Option<Vec<LogRow>> {
    let path = results_dir().join(format!("{tag}.csv"));
    let (header, rows) = crate::util::read_csv(&path).ok()?;
    if header != ["iter", "cases", "time_s", "batch_loss", "train_err", "train_loss"] {
        return None;
    }
    Some(
        rows.into_iter()
            .map(|r| LogRow {
                iter: r[0] as usize,
                cases: r[1],
                time_s: r[2],
                batch_loss: r[3],
                train_err: r[4],
                train_loss: r[5],
            })
            .collect(),
    )
}

/// Run-or-load: reuse `results/<tag>.csv` when present (so e.g. fig11
/// can replot fig10's runs without re-training).
pub fn cached_run(tag: &str, f: impl FnOnce() -> Vec<LogRow>) -> Vec<LogRow> {
    if let Some(log) = load_log(tag) {
        println!("# {tag}: loaded cached results/{tag}.csv ({} rows)", log.len());
        return log;
    }
    f()
}

/// The Figure 10/11 experiment: all three problems × optimizer variants
/// with the paper's exponentially increasing batch schedule for K-FAC
/// and a fixed batch for the SGD baseline. Returns
/// (problem, variant, log) triples; each run is cached by tag.
pub fn training_curves_fig10(
    backend_kind: &str,
    iters: usize,
    n_data: usize,
) -> Vec<(Problem, String, Vec<LogRow>)> {
    use crate::fisher::precond;
    let mut out = Vec::new();
    for problem in [Problem::CurvesAe, Problem::MnistAe, Problem::FacesAe] {
        let ds = problem.dataset(n_data, 0);
        let m1 = 250.min(n_data);
        let exp_sched = BatchSchedule::exponential_reaching(m1, n_data, (iters * 3 / 4).max(2));
        let variants: Vec<(String, Variant, BatchSchedule)> = vec![
            (
                "kfac_blktridiag".into(),
                Variant::kfac("blktridiag", precond::block_tridiag(), true, 5.0),
                exp_sched.clone(),
            ),
            (
                "kfac_blkdiag".into(),
                Variant::kfac("blkdiag", precond::block_diag(), true, 5.0),
                exp_sched.clone(),
            ),
            (
                "kfac_nomom".into(),
                Variant::kfac("nomom", precond::block_tridiag(), false, 5.0),
                BatchSchedule::Fixed(500.min(n_data)),
            ),
            (
                "sgd".into(),
                Variant::sgd("sgd", 0.02, 0.99),
                BatchSchedule::Fixed(500.min(n_data)),
            ),
        ];
        for (vname, variant, schedule) in variants {
            let tag = format!("fig10_{}_{vname}", problem.name());
            let cfg = RunCfg {
                iters,
                schedule,
                eval_every: 5,
                eval_rows: 1000.min(n_data),
                seed: 0,
                init_seed: 1,
            };
            let log = cached_run(&tag, || {
                println!("# running {tag} ({backend_kind} backend)…");
                match backend_kind {
                    "pjrt" => {
                        let dir = PathBuf::from(
                            std::env::var("KFAC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
                        );
                        match crate::backend::PjrtBackend::new(&dir, problem.name()) {
                            Ok(mut b) => {
                                run_variant_with_backend(&mut b, &ds, &cfg, variant, &tag)
                            }
                            Err(e) => {
                                eprintln!("# pjrt unavailable ({e:#}); falling back to rust");
                                run_variant(problem, &ds, &cfg, variant, &tag)
                            }
                        }
                    }
                    _ => run_variant(problem, &ds, &cfg, variant, &tag),
                }
            });
            out.push((problem, vname, log));
        }
    }
    out
}

/// Partially train a network with K-FAC (rust backend, batch mode) and
/// return (params, backend) — the setup used by Figures 2/3/5/6/7,
/// which examine quantities "at iteration N" of a K-FAC run.
pub fn partially_train(
    problem: Problem,
    n_data: usize,
    iters: usize,
    seed: u64,
) -> (RustBackend, Params, crate::data::Dataset) {
    let arch = problem.arch();
    let ds = problem.dataset(n_data, seed);
    let mut backend = RustBackend::new(arch.clone());
    let opt = Kfac::new(&arch, KfacConfig { lambda0: 15.0, ..Default::default() });
    let report = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(iters)
        .schedule(BatchSchedule::Fixed(n_data))
        .seed(seed)
        .eval_every(usize::MAX)
        .eval_rows(1)
        .no_polyak()
        .params(arch.sparse_init(&mut Rng::new(seed ^ 0xA5)))
        .optimizer(opt)
        .backend(&mut backend)
        .run();
    (backend, report.params, ds)
}
