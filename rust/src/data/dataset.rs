//! In-memory dataset with shuffled mini-batching.

use crate::linalg::Mat;
use crate::rng::Rng;

/// A labelled dataset: one case per row of `x` / `y`.
#[derive(Clone)]
pub struct Dataset {
    pub x: Mat,
    pub y: Mat,
}

impl Dataset {
    pub fn new(x: Mat, y: Mat) -> Dataset {
        assert_eq!(x.rows, y.rows, "dataset: x/y row mismatch");
        Dataset { x, y }
    }

    pub fn len(&self) -> usize {
        self.x.rows
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Random mini-batch of `m` rows (with replacement across calls,
    /// without replacement within one batch; if `m >= len`, the whole
    /// set in random order).
    pub fn minibatch(&self, m: usize, rng: &mut Rng) -> (Mat, Mat) {
        let n = self.len();
        if m >= n {
            let perm = rng.permutation(n);
            return (self.x.gather_rows(&perm), self.y.gather_rows(&perm));
        }
        // sample m distinct indices via partial Fisher–Yates
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + rng.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        (self.x.gather_rows(&idx), self.y.gather_rows(&idx))
    }

    /// Split into (train, test) by a random permutation.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.len();
        let ntr = ((n as f64) * train_frac).round() as usize;
        let perm = rng.permutation(n);
        let (tr, te) = perm.split_at(ntr);
        (
            Dataset::new(self.x.gather_rows(tr), self.y.gather_rows(tr)),
            Dataset::new(self.x.gather_rows(te), self.y.gather_rows(te)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let x = Mat::from_fn(n, 3, |r, c| (r * 3 + c) as f64);
        let y = Mat::from_fn(n, 1, |r, _| r as f64);
        Dataset::new(x, y)
    }

    #[test]
    fn minibatch_rows_are_consistent_pairs() {
        let ds = toy(50);
        let mut rng = Rng::new(1);
        let (x, y) = ds.minibatch(8, &mut rng);
        assert_eq!(x.rows, 8);
        for r in 0..8 {
            let id = y.at(r, 0) as usize;
            assert_eq!(x.at(r, 0), (id * 3) as f64);
        }
    }

    #[test]
    fn minibatch_no_duplicates_within_batch() {
        let ds = toy(20);
        let mut rng = Rng::new(2);
        let (_, y) = ds.minibatch(20, &mut rng);
        let mut ids: Vec<usize> = (0..20).map(|r| y.at(r, 0) as usize).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 20);
    }

    #[test]
    fn split_partitions() {
        let ds = toy(100);
        let (tr, te) = ds.split(0.8, &mut Rng::new(3));
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
    }
}
