//! Kronecker-product utilities.
//!
//! The central identity the whole paper rides on:
//! `(A ⊗ B) vec(X) = vec(B X Aᵀ)` with *column-stacking* `vec`.
//! For K-FAC's blocks, `vec(DW_i) = ā_{i-1} ⊗ g_i`, so the Fisher block
//! is `Ā ⊗ G` with `Ā` on the *input* (column) side and `G` on the
//! *output* (row) side, and applying `(Ā ⊗ G)` to a gradient shaped as
//! the weight matrix `V (d_out × d_in+1)` is just `G V Āᵀ`.
//!
//! Dense `kron` is used only by the exact-Fisher experiments on small
//! networks (Figs 2/3/5/6); the optimizer always uses the vec-trick.

use super::Mat;

/// Dense Kronecker product `A ⊗ B`.
pub fn kron(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows * b.rows, a.cols * b.cols);
    for ia in 0..a.rows {
        for ja in 0..a.cols {
            let s = a.at(ia, ja);
            if s == 0.0 {
                continue;
            }
            for ib in 0..b.rows {
                let orow = ia * b.rows + ib;
                for jb in 0..b.cols {
                    out.set(orow, ja * b.cols + jb, s * b.at(ib, jb));
                }
            }
        }
    }
    out
}

/// `(A ⊗ B) vec(X) = vec(B X Aᵀ)` computed without forming `A ⊗ B`.
/// `X` has shape `(B.cols, A.cols)`; result has shape `(B.rows, A.rows)`.
pub fn kron_apply(a: &Mat, b: &Mat, x: &Mat) -> Mat {
    assert_eq!(x.rows, b.cols, "kron_apply: X rows must match B cols");
    assert_eq!(x.cols, a.cols, "kron_apply: X cols must match A cols");
    b.matmul(&x.matmul_nt(a))
}

/// Column-stacking vec: `vec(X)` as a length `rows*cols` vector.
/// Entry `vec(X)[c*rows + r] = X[r, c]`.
pub fn vec_mat(x: &Mat) -> Vec<f64> {
    let mut v = Vec::with_capacity(x.rows * x.cols);
    for c in 0..x.cols {
        for r in 0..x.rows {
            v.push(x.at(r, c));
        }
    }
    v
}

/// Inverse of [`vec_mat`].
pub fn unvec(v: &[f64], rows: usize, cols: usize) -> Mat {
    assert_eq!(v.len(), rows * cols);
    let mut x = Mat::zeros(rows, cols);
    for c in 0..cols {
        for r in 0..rows {
            x.set(r, c, v[c * rows + r]);
        }
    }
    x
}

/// Van Loan–Pitsianis rearrangement `R(M)` of an `(na·ng)²` matrix
/// viewed as an `na×na` grid of `ng×ng` blocks: row `j·na+i` of the
/// output is `vec(block(i,j))ᵀ` (column-stacking `vec`, consistent
/// with [`vec_mat`]), so
///
/// `R(A ⊗ B) = vec(A) vec(B)ᵀ`    and, in general,
/// `‖M − Σᵣ Aᵣ⊗Gᵣ‖_F = ‖R(M) − Σᵣ vec(Aᵣ) vec(Gᵣ)ᵀ‖_F`.
///
/// The best rank-R Kronecker-sum approximation of `M` (KPSVD, Koroko
/// et al. 2022) is therefore the rank-R truncated SVD of `R(M)`. Dense
/// `rearrange` is test/experiment machinery — the KPSVD preconditioner
/// power-iterates `R(M)` implicitly without forming it.
pub fn rearrange(m: &Mat, na: usize, ng: usize) -> Mat {
    assert_eq!(m.rows, na * ng, "rearrange: M must be (na·ng)²");
    assert_eq!(m.cols, na * ng, "rearrange: M must be (na·ng)²");
    let mut out = Mat::zeros(na * na, ng * ng);
    for j in 0..na {
        for i in 0..na {
            let orow = j * na + i;
            for l in 0..ng {
                for k in 0..ng {
                    out.set(orow, l * ng + k, m.at(i * ng + k, j * ng + l));
                }
            }
        }
    }
    out
}

/// A Kronecker basis pair `U_A ⊗ U_G` for one layer's weight space.
///
/// Follows the K-FAC convention of this module: `U_A` acts on the
/// input (column) side and `U_G` on the output (row) side, so the
/// basis change of a weight-shaped matrix `V` (`d_out × (d_in+1)`) is
/// `U_Gᵀ V U_A`, and `vec(V)`'s coordinate `(q·d_out + p)` in the
/// basis is `(U_Gᵀ V U_A)_{p,q}`. Built from the eigenvectors of the
/// factor statistics by the EKFAC preconditioner, and consumed by the
/// per-example gradient projection (`ModelBackend::grad_sq_in_basis`).
#[derive(Clone, Debug)]
pub struct KronBasis {
    /// Input-side basis (columns), `(d_in+1)²`.
    pub ua: Mat,
    /// Output-side basis (columns), `d_out²`.
    pub ug: Mat,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn kron_known_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::eye(2);
        let k = kron(&a, &b);
        assert_eq!(k.rows, 4);
        assert_eq!(k.at(0, 0), 1.0);
        assert_eq!(k.at(0, 2), 2.0);
        assert_eq!(k.at(3, 1), 3.0); // block (1,0): a[1,0] * b[1,1]
        assert_eq!(k.at(2, 0), 3.0);
        assert_eq!(k.at(3, 3), 4.0);
    }

    #[test]
    fn vec_trick_matches_dense_kron() {
        let mut rng = Rng::new(1);
        for &(p, q, r, s) in &[(2usize, 3usize, 4usize, 2usize), (3, 3, 3, 3), (1, 5, 2, 4)] {
            let a = Mat::randn(p, q, 1.0, &mut rng);
            let b = Mat::randn(r, s, 1.0, &mut rng);
            let x = Mat::randn(s, q, 1.0, &mut rng);
            let dense = kron(&a, &b);
            let want = unvec(&dense.matvec(&vec_mat(&x)), r, p);
            let got = kron_apply(&a, &b, &x);
            assert!(got.sub(&want).max_abs() < 1e-10);
        }
    }

    #[test]
    fn kron_inverse_identity() {
        // (A ⊗ B)^-1 = A^-1 ⊗ B^-1
        let mut rng = Rng::new(2);
        let a = Mat::randn(3, 3, 1.0, &mut rng).add(&Mat::eye(3).scale(3.0));
        let b = Mat::randn(2, 2, 1.0, &mut rng).add(&Mat::eye(2).scale(3.0));
        let lhs = kron(&a, &b).inverse();
        let rhs = kron(&a.inverse(), &b.inverse());
        assert!(lhs.sub(&rhs).max_abs() < 1e-8);
    }

    #[test]
    fn vec_unvec_roundtrip() {
        let mut rng = Rng::new(3);
        let x = Mat::randn(4, 6, 1.0, &mut rng);
        assert_eq!(unvec(&vec_mat(&x), 4, 6), x);
    }

    #[test]
    fn rearrange_of_kron_is_rank_one_outer_product() {
        // R(A ⊗ B) = vec(A) vec(B)ᵀ — the identity KPSVD rides on.
        let mut rng = Rng::new(7);
        for &(na, ng) in &[(3usize, 2usize), (2, 4), (4, 4), (1, 3)] {
            let a = Mat::randn(na, na, 1.0, &mut rng);
            let b = Mat::randn(ng, ng, 1.0, &mut rng);
            let r = rearrange(&kron(&a, &b), na, ng);
            let (va, vb) = (vec_mat(&a), vec_mat(&b));
            for i in 0..na * na {
                for j in 0..ng * ng {
                    assert!((r.at(i, j) - va[i] * vb[j]).abs() < 1e-15, "({na},{ng}) [{i},{j}]");
                }
            }
        }
    }

    #[test]
    fn rearrange_preserves_frobenius_norm() {
        let mut rng = Rng::new(8);
        let m = Mat::randn(12, 12, 1.0, &mut rng);
        let r = rearrange(&m, 3, 4);
        assert!((r.frob_norm() - m.frob_norm()).abs() < 1e-12);
    }

    #[test]
    fn vec_of_outer_product_is_kron_of_vectors() {
        // vec(g ā^T) = ā ⊗ g — the identity underlying F_{i,j} = Ā ⊗ G.
        let g = Mat::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let abar = Mat::from_vec(2, 1, vec![5.0, 7.0]);
        let outer = g.matmul_nt(&abar); // 3x2
        let v = vec_mat(&outer);
        let k = kron(&abar, &g); // 6x1
        for i in 0..6 {
            assert!((v[i] - k.at(i, 0)).abs() < 1e-15);
        }
    }
}
