//! EKFAC — diagonal rescaling in the Kronecker eigenbasis (George et
//! al. 2018, "Fast Approximate Natural Gradient Descent in a
//! Kronecker-factored Eigenbasis").
//!
//! Each block's Kronecker factors are eigendecomposed,
//! `Ā = U_A S_A U_Aᵀ` and `G = U_G S_G U_Gᵀ`, and the preconditioner is
//! a *diagonal* operator in the induced eigenbasis `U_A ⊗ U_G`:
//!
//! `U = U_G [ (U_Gᵀ V U_A) ⊘ D ] U_Aᵀ`,  `D_{pq} = s^G_p s^A_q + γ²`.
//!
//! With the eigenvalue-product scales a fresh build starts from, this
//! is algebraically the **exact** Tikhonov-damped block inverse
//! `(Ā ⊗ G + γ²I)⁻¹` (paper eqn. 6) — the damping lives in the
//! eigenbasis instead of being factored onto `Ā` and `G` — computed
//! with two eigendecompositions per refresh and four layer-sized GEMMs
//! per apply. At `γ = 0` it coincides with the block-diagonal inverse
//! `G⁻¹ V Ā⁻¹`.
//!
//! The full EKFAC algorithm then **re-estimates** the diagonal between
//! eigenbasis rebuilds from second moments of per-example gradients
//! projected into `U_A ⊗ U_G` (the cheap, frequent update the
//! eigendecompositions amortize — provably the Frobenius-optimal
//! diagonal for the basis, George et al. Prop. 1): the optimizer
//! obtains the moments through
//! [`ModelBackend::grad_sq_in_basis`](crate::backend::ModelBackend::grad_sq_in_basis)
//! using the bases exposed by [`FisherInverse::eigenbases`], and swaps
//! them in with [`FisherInverse::set_scales`].

use super::stats::RawStats;
use super::FisherInverse;
use crate::linalg::{KronBasis, Mat, SymEig};
use crate::nn::Params;

/// Floor for the damped diagonal: a tiny fraction of the largest scale
/// so rank-deficient spectra at `γ = 0` stay finite (jitter-style
/// recovery). Deterministic, so checkpoint resume reproduces it.
fn scale_floor(max_scale: f64) -> f64 {
    (1e-13 * max_scale).max(1e-300)
}

/// Cached Kronecker eigenbases and inverse diagonal scales.
pub struct EkfacInverse {
    /// Per layer: eigenvectors of `Ā_{i-1,i-1}` and `G_{i,i}`.
    bases: Vec<KronBasis>,
    /// Per layer: `1 / D` with `D_{pq} = s_{pq} + γ²`, shaped like the
    /// layer's weight matrix (`d_out × (d_in+1)`). `s` starts as the
    /// eigenvalue products `s^G_p s^A_q` and is replaced by
    /// re-estimated second moments via [`FisherInverse::set_scales`].
    inv_scale: Vec<Mat>,
}

impl EkfacInverse {
    /// Build from factor statistics with damping strength `γ` (added as
    /// `γ²` to the eigenvalue products — exact Tikhonov, not factored).
    /// Layer eigendecompositions run in parallel.
    pub fn build(stats: &RawStats, gamma: f64) -> EkfacInverse {
        let l = stats.num_layers();
        let damp = gamma * gamma;
        let parts = crate::par::par_map_send(l, 1, |i| {
            super::check_factors_finite("ekfac", i, &stats.aa[i], &stats.gg[i]);
            let ea = SymEig::new(&stats.aa[i]);
            let eg = SymEig::new(&stats.gg[i]);
            let max_a = ea.w.iter().fold(0.0f64, |m, &w| m.max(w.abs()));
            let max_g = eg.w.iter().fold(0.0f64, |m, &w| m.max(w.abs()));
            let floor = scale_floor(max_a * max_g);
            let mut inv_scale = Mat::zeros(eg.w.len(), ea.w.len());
            for p in 0..eg.w.len() {
                for q in 0..ea.w.len() {
                    let d = eg.w[p].max(0.0) * ea.w[q].max(0.0) + damp;
                    inv_scale.set(p, q, 1.0 / d.max(floor));
                }
            }
            (ea.v, eg.v, inv_scale)
        });
        let mut bases = Vec::with_capacity(l);
        let mut inv_scale = Vec::with_capacity(l);
        for (ua, ug, s) in parts {
            bases.push(KronBasis { ua, ug });
            inv_scale.push(s);
        }
        EkfacInverse { bases, inv_scale }
    }
}

impl FisherInverse for EkfacInverse {
    fn apply(&self, grads: &Params) -> Params {
        Params(
            grads
                .0
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    // project into the eigenbasis, rescale, project back
                    let b = &self.bases[i];
                    let proj = b.ug.matmul_tn(v).matmul(&b.ua);
                    let scaled = proj.hadamard(&self.inv_scale[i]);
                    b.ug.matmul(&scaled).matmul_nt(&b.ua)
                })
                .collect(),
        )
    }

    fn eigenbases(&self) -> Option<&[KronBasis]> {
        Some(&self.bases)
    }

    /// Swap in re-estimated second-moment scales: `D_{pq} = s_{pq} + γ²`
    /// with the same rank-deficiency floor as [`EkfacInverse::build`].
    /// Negative estimates (impossible for true second moments, possible
    /// for externally supplied ones) are clamped to 0.
    fn set_scales(&mut self, scales: &[Mat], gamma: f64) -> bool {
        assert_eq!(scales.len(), self.inv_scale.len(), "ekfac: scale layer count");
        let damp = gamma * gamma;
        let rescaled: Vec<Mat> = scales
            .iter()
            .zip(self.inv_scale.iter())
            .map(|(s, old)| {
                assert_eq!(
                    (s.rows, s.cols),
                    (old.rows, old.cols),
                    "ekfac: scale shape mismatch"
                );
                let floor = scale_floor(s.max_abs());
                s.map(|v| 1.0 / (v.max(0.0) + damp).max(floor))
            })
            .collect();
        self.inv_scale = rescaled;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::blockdiag::BlockDiagInverse;
    use crate::fisher::stats::KfacStats;
    use crate::linalg::kron::{kron, unvec, vec_mat};
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    fn build_stats(arch: &Arch, m: usize, seed: u64) -> RawStats {
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(seed);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(m, arch.widths[0], 1.0, &mut rng);
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        let mut st = KfacStats::new(arch);
        st.update(&RawStats::from_batch(&fwd, &gs));
        st.s
    }

    #[test]
    fn matches_dense_exact_tikhonov_inverse() {
        // (Ā⊗G + γ²I)⁻¹ vec(V) against a dense inverse, per layer.
        let arch = Arch::new(
            vec![5, 4, 3],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let st = build_stats(&arch, 64, 1);
        let gamma = 0.6;
        let inv = EkfacInverse::build(&st, gamma);
        let mut rng = Rng::new(2);
        let grads = Params(
            (0..arch.num_layers())
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::randn(r, c, 1.0, &mut rng)
                })
                .collect(),
        );
        let got = inv.apply(&grads);
        for i in 0..arch.num_layers() {
            let dense = kron(&st.aa[i], &st.gg[i]).add_diag(gamma * gamma).inverse();
            let want = unvec(
                &dense.matvec(&vec_mat(&grads.0[i])),
                grads.0[i].rows,
                grads.0[i].cols,
            );
            let err = got.0[i].sub(&want).max_abs();
            assert!(err < 1e-7, "layer {i} err={err}");
        }
    }

    #[test]
    fn agrees_with_blockdiag_at_zero_damping() {
        // At γ = 0 (full-rank factors) the eigenbasis rescaling is
        // exactly G⁻¹ V Ā⁻¹.
        let arch = Arch::new(vec![6, 4], vec![Act::Identity], LossKind::SquaredError);
        let st = build_stats(&arch, 100, 3);
        let ek = EkfacInverse::build(&st, 0.0);
        let bd = BlockDiagInverse::build(&st, 0.0);
        let mut rng = Rng::new(4);
        let g = Params(vec![Mat::randn(4, 7, 1.0, &mut rng)]);
        let a = ek.apply(&g);
        let b = bd.apply(&g);
        let scale = b.0[0].max_abs().max(1e-12);
        let err = a.0[0].sub(&b.0[0]).max_abs() / scale;
        assert!(err < 1e-6, "rel err {err}");
    }

    #[test]
    fn preconditioner_is_positive_definite() {
        // ⟨g, F⁻¹g⟩ > 0 for random non-zero g (the inverse of an SPD
        // operator is SPD).
        let arch = Arch::new(
            vec![5, 4, 3],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let st = build_stats(&arch, 64, 5);
        let inv = EkfacInverse::build(&st, 0.3);
        let mut rng = Rng::new(6);
        for _ in 0..10 {
            let g = Params(
                (0..arch.num_layers())
                    .map(|i| {
                        let (r, c) = arch.weight_shape(i);
                        Mat::randn(r, c, 1.0, &mut rng)
                    })
                    .collect(),
            );
            let q = g.dot(&inv.apply(&g));
            assert!(q > 0.0, "quadratic form not positive: {q}");
        }
    }

    #[test]
    fn larger_gamma_shrinks_update() {
        let arch = Arch::new(vec![6, 4], vec![Act::Identity], LossKind::SquaredError);
        let st = build_stats(&arch, 48, 7);
        let mut rng = Rng::new(8);
        let g = Params(vec![Mat::randn(4, 7, 1.0, &mut rng)]);
        let small = EkfacInverse::build(&st, 1e-3).apply(&g);
        let large = EkfacInverse::build(&st, 10.0).apply(&g);
        assert!(large.norm_sq() < small.norm_sq());
    }

    #[test]
    fn set_scales_with_eigenvalue_products_reproduces_build() {
        // Feeding the eigenvalue products back through set_scales must
        // reproduce the freshly built (exact-Tikhonov) operator.
        let arch = Arch::new(
            vec![5, 4, 3],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let st = build_stats(&arch, 64, 11);
        let gamma = 0.4;
        let fresh = EkfacInverse::build(&st, gamma);
        let mut swapped = EkfacInverse::build(&st, gamma);
        let products: Vec<Mat> = (0..arch.num_layers())
            .map(|i| {
                let ea = SymEig::new(&st.aa[i]);
                let eg = SymEig::new(&st.gg[i]);
                Mat::from_fn(eg.w.len(), ea.w.len(), |p, q| {
                    eg.w[p].max(0.0) * ea.w[q].max(0.0)
                })
            })
            .collect();
        assert!(swapped.set_scales(&products, gamma));
        let mut rng = Rng::new(12);
        let g = Params(
            (0..arch.num_layers())
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::randn(r, c, 1.0, &mut rng)
                })
                .collect(),
        );
        let a = fresh.apply(&g);
        let b = swapped.apply(&g);
        for i in 0..arch.num_layers() {
            let scale = a.0[i].max_abs().max(1e-12);
            let err = a.0[i].sub(&b.0[i]).max_abs() / scale;
            assert!(err < 1e-13, "layer {i}: rel err {err}");
        }
    }

    #[test]
    fn set_scales_matches_dense_diagonal_rescaling() {
        // With arbitrary (well-conditioned) scales S, the operator must
        // equal the dense U diag(1/(S+γ²)) Uᵀ with U = U_A ⊗ U_G.
        let arch = Arch::new(vec![4, 3], vec![Act::Identity], LossKind::SquaredError);
        let st = build_stats(&arch, 48, 13);
        let gamma = 0.2;
        let mut inv = EkfacInverse::build(&st, gamma);
        let mut rng = Rng::new(14);
        // scales in [0.5, 1.5] keep the rank-deficiency floor inert
        let s = Mat::from_fn(3, 5, |_, _| 0.5 + rng.normal().abs().min(1.0));
        assert!(inv.set_scales(&[s.clone()], gamma));
        let bases = inv.eigenbases().unwrap().to_vec();
        let g = Mat::randn(3, 5, 1.0, &mut rng);
        let got = inv.apply(&Params(vec![g.clone()]));
        let u = kron(&bases[0].ua, &bases[0].ug);
        let vg = vec_mat(&g);
        let proj = u.transpose().matvec(&vg);
        let rescaled: Vec<f64> = proj
            .iter()
            .enumerate()
            .map(|(idx, v)| {
                let (p, q) = (idx % 3, idx / 3); // column-stacking
                v / (s.at(p, q) + gamma * gamma)
            })
            .collect();
        let want = unvec(&u.matvec(&rescaled), 3, 5);
        let err = got.0[0].sub(&want).max_abs() / want.max_abs().max(1e-12);
        assert!(err < 1e-10, "rel err {err}");
    }

    #[test]
    fn structures_without_scales_use_the_default_no_ops() {
        let arch = Arch::new(vec![4, 3], vec![Act::Identity], LossKind::SquaredError);
        let st = build_stats(&arch, 32, 15);
        let mut bd = BlockDiagInverse::build(&st, 0.5);
        assert!(bd.eigenbases().is_none());
        assert!(!bd.set_scales(&[Mat::filled(3, 5, 1.0)], 0.5));
    }

    #[test]
    fn poisoned_factor_panics_naming_the_layer() {
        // NaN-poisoned statistics must be rejected with a message that
        // names the structure and layer, not an opaque unwrap deep in a
        // sort. One layer keeps the build inline on the caller, so the
        // panic payload is observable here.
        let arch = Arch::new(vec![3, 2], vec![Act::Identity], LossKind::SquaredError);
        let mut st = RawStats::zeros(&arch);
        st.aa[0] = Mat::eye(4);
        st.gg[0] = Mat::eye(2);
        st.aa[0].set(0, 1, f64::NAN);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            EkfacInverse::build(&st, 0.1)
        }));
        let payload = r.expect_err("poisoned stats must panic");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("layer 0"), "panic message lacks layer: {msg}");
        assert!(msg.contains("non-finite"), "panic message lacks cause: {msg}");
    }

    #[test]
    fn rank_deficient_factors_stay_finite() {
        let arch = Arch::new(vec![3, 2], vec![Act::Identity], LossKind::SquaredError);
        let mut st = RawStats::zeros(&arch);
        st.aa[0] = Mat::filled(4, 4, 1.0); // rank 1
        st.gg[0] = Mat::filled(2, 2, 0.5); // rank 1
        let mut rng = Rng::new(9);
        let g = Params(vec![Mat::randn(2, 4, 1.0, &mut rng)]);
        for gamma in [0.0, 1e-6, 1.0] {
            let u = EkfacInverse::build(&st, gamma).apply(&g);
            assert!(
                u.0[0].data.iter().all(|v| v.is_finite()),
                "γ={gamma} produced non-finite entries"
            );
        }
    }
}
