//! Forward / backward / linearized-forward passes (paper Algorithm 1 and
//! Appendix C), batched over mini-batches.

use super::{Arch, Params};
use crate::linalg::{KronBasis, Mat};
use crate::rng::Rng;

/// Cached forward-pass quantities for a mini-batch.
///
/// `abars[i]` is `Ā_i = [A_i, 1]` with one case per row — `abars[0]` is
/// the (homogenized) input, and `abars[i]` for `i ≥ 1` the homogenized
/// activities of layer `i`. `ss[i]` holds the pre-activations `S_{i+1}`
/// of layer `i+1` (0-based), so `z = ss[ℓ-1]` are the output natural
/// parameters.
pub struct Fwd {
    pub abars: Vec<Mat>,
    pub ss: Vec<Mat>,
}

impl Fwd {
    /// Output natural parameters `z = s_ℓ`.
    pub fn z(&self) -> &Mat {
        self.ss.last().expect("empty network")
    }
}

/// Stateless forward/backward engine for an [`Arch`].
#[derive(Clone)]
pub struct Net {
    pub arch: Arch,
}

impl Net {
    pub fn new(arch: Arch) -> Net {
        Net { arch }
    }

    /// Forward pass (Algorithm 1, forward half). `x` is `[m, d₀]`.
    pub fn forward(&self, params: &Params, x: &Mat) -> Fwd {
        let l = self.arch.num_layers();
        assert_eq!(params.num_layers(), l);
        assert_eq!(x.cols, self.arch.widths[0], "input width mismatch");
        let mut abars = Vec::with_capacity(l);
        let mut ss = Vec::with_capacity(l);
        abars.push(x.append_ones_col());
        for i in 0..l {
            let s = abars[i].matmul_nt(&params.0[i]); // [m, d_{i+1}]
            if i + 1 < l {
                let act = self.arch.acts[i];
                let a = Mat::from_fn(s.rows, s.cols, |r, c| act.apply(s.at(r, c)));
                abars.push(a.append_ones_col());
            }
            ss.push(s);
        }
        Fwd { abars, ss }
    }

    /// Backward pass from per-case output derivatives `dz` (Algorithm 1,
    /// backward half). Returns the per-case pre-activation derivatives
    /// `gs[i] = G_i` (`[m, d_{i+1}]`, *not* scaled by 1/m).
    pub fn backward(&self, params: &Params, fwd: &Fwd, dz: &Mat) -> Vec<Mat> {
        let l = self.arch.num_layers();
        let mut gs = vec![Mat::zeros(0, 0); l];
        gs[l - 1] = dz.clone();
        for i in (0..l - 1).rev() {
            // dA_i = G_{i+1} * W_{i+1}[:, :d_i]  (drop bias column)
            let w_next = &params.0[i + 1];
            let w_nob = w_next.drop_last_col();
            let da = gs[i + 1].matmul(&w_nob); // [m, d_{i+1 widths}]
            let act = self.arch.acts[i];
            let s = &fwd.ss[i];
            // g_i = dA_i ⊙ φ'(s_i); recompute a from s for the derivative.
            gs[i] = Mat::from_fn(da.rows, da.cols, |r, c| {
                let sv = s.at(r, c);
                da.at(r, c) * act.deriv(sv, act.apply(sv))
            });
        }
        gs
    }

    /// Mean gradient `∇_W h` from cached activations and `gs`:
    /// `DW_i = (1/m) G_iᵀ Ā_{i-1}`.
    pub fn grads_from(&self, fwd: &Fwd, gs: &[Mat]) -> Params {
        let m = fwd.abars[0].rows as f64;
        Params(
            gs.iter()
                .zip(fwd.abars.iter())
                .map(|(g, abar)| g.matmul_tn(abar).scale(1.0 / m))
                .collect(),
        )
    }

    /// Mean loss + gradient on a labelled mini-batch (no ℓ2 term).
    pub fn loss_and_grad(&self, params: &Params, x: &Mat, y: &Mat) -> (f64, Params) {
        let fwd = self.forward(params, x);
        let loss = self.arch.loss.loss(fwd.z(), y);
        let dz = self.arch.loss.dz(fwd.z(), y);
        let gs = self.backward(params, &fwd, &dz);
        (loss, self.grads_from(&fwd, &gs))
    }

    /// Mean loss only.
    pub fn loss(&self, params: &Params, x: &Mat, y: &Mat) -> f64 {
        let fwd = self.forward(params, x);
        self.arch.loss.loss(fwd.z(), y)
    }

    /// Backward pass with targets **sampled from the model's predictive
    /// distribution** (Section 5) — the `gs` this produces are the ones
    /// whose second moments estimate the true-Fisher `G_{i,j}`.
    pub fn sampled_backward(&self, params: &Params, fwd: &Fwd, rng: &mut Rng) -> Vec<Mat> {
        let y = self.arch.loss.sample(fwd.z(), rng);
        let dz = self.arch.loss.dz(fwd.z(), &y);
        self.backward(params, fwd, &dz)
    }

    /// Batch-mean of **squared per-example gradients** projected into
    /// per-layer Kronecker bases (the EKFAC second-moment scales of
    /// George et al. 2018). The per-example weight gradient of layer
    /// `i` for case `n` is the rank-1 outer product
    /// `DW_n = g_n ā_{i-1,n}ᵀ`, so its basis change factors as
    /// `U_Gᵀ DW_n U_A = (U_Gᵀ g_n)(ā_nᵀ U_A)` — the per-example
    /// *vectors* are projected first and only then squared, costing
    /// `O(m·(a+g)·ag)` in total instead of the `O(m·a²g²)` of
    /// materializing `m` weight-shaped per-example gradients:
    ///
    /// `S_i[p,q] = (1/m) Σ_n (G_i U_G)²_{n,p} (Ā_{i-1} U_A)²_{n,q}`.
    ///
    /// `gs` must *not* be scaled by 1/m (the convention of
    /// [`Net::backward`]); one `d_out × (d_in+1)` matrix per layer.
    pub fn grad_sq_in_basis(&self, fwd: &Fwd, gs: &[Mat], bases: &[KronBasis]) -> Vec<Mat> {
        assert_eq!(gs.len(), bases.len(), "grad_sq_in_basis: one basis per layer");
        let m = fwd.abars[0].rows as f64;
        gs.iter()
            .zip(fwd.abars.iter())
            .zip(bases.iter())
            .map(|((g, abar), b)| {
                let gt = g.matmul(&b.ug); // [m, d_out], row n = (U_Gᵀ g_n)ᵀ
                let at = abar.matmul(&b.ua); // [m, d_in+1], row n = (U_Aᵀ ā_n)ᵀ
                gt.hadamard(&gt).matmul_tn(&at.hadamard(&at)).scale(1.0 / m)
            })
            .collect()
    }

    /// Linearized forward pass (the `Jv` of Appendix C): directional
    /// derivative of `z` w.r.t. parameters along `v`, evaluated with the
    /// activations cached in `fwd`. Returns `Jz` of shape `[m, d_ℓ]`.
    pub fn jvp(&self, params: &Params, fwd: &Fwd, v: &Params) -> Mat {
        let l = self.arch.num_layers();
        let m = fwd.abars[0].rows;
        // jabar: derivative of ā_i (homogeneous coord derivative is 0)
        let mut jabar = Mat::zeros(m, self.arch.widths[0] + 1);
        let mut jz = Mat::zeros(0, 0);
        for i in 0..l {
            // js = Ā_{i-1} V_iᵀ + JĀ_{i-1} W_iᵀ
            let mut js = fwd.abars[i].matmul_nt(&v.0[i]);
            let prop = jabar.matmul_nt(&params.0[i]);
            js.axpy(1.0, &prop);
            if i + 1 < l {
                let act = self.arch.acts[i];
                let s = &fwd.ss[i];
                let ja = Mat::from_fn(m, js.cols, |r, c| {
                    let sv = s.at(r, c);
                    js.at(r, c) * act.deriv(sv, act.apply(sv))
                });
                // append zero column for the constant homogeneous coord
                let mut jab = Mat::zeros(m, ja.cols + 1);
                jab.set_block(0, 0, &ja);
                jabar = jab;
            } else {
                jz = js;
            }
        }
        jz
    }

    /// All pairwise exact-Fisher quadratic forms `dᵢᵀ F dⱼ` over the
    /// mini-batch `x` (mean over cases), computed with the Appendix-C
    /// trick: one linearized forward pass per direction, then cheap
    /// `F_R`-weighted inner products. Returns a `k × k` symmetric matrix.
    pub fn fvp_quad(&self, params: &Params, x: &Mat, dirs: &[&Params]) -> Mat {
        let fwd = self.forward(params, x);
        let m = x.rows as f64;
        let jzs: Vec<Mat> = dirs.iter().map(|d| self.jvp(params, &fwd, d)).collect();
        let k = dirs.len();
        let mut q = Mat::zeros(k, k);
        for i in 0..k {
            for j in i..k {
                let v = self.arch.loss.fr_quad(fwd.z(), &jzs[i], &jzs[j]) / m;
                q.set(i, j, v);
                q.set(j, i, v);
            }
        }
        q
    }

    /// Exact Fisher–vector product `F v` over the mini-batch (mean),
    /// via `Jᵀ F_R J v`. Used in tests and the exact-Fisher experiments.
    pub fn fvp(&self, params: &Params, x: &Mat, v: &Params) -> Params {
        let fwd = self.forward(params, x);
        let jz = self.jvp(params, &fwd, v);
        let frjz = self.arch.loss.fr_apply(fwd.z(), &jz);
        let gs = self.backward(params, &fwd, &frjz);
        self.grads_from(&fwd, &gs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Act, LossKind};

    fn tiny_arch(loss: LossKind) -> Arch {
        Arch::new(vec![5, 4, 3], vec![Act::Tanh, Act::Identity], loss)
    }

    fn make_targets(loss: LossKind, rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        match loss {
            LossKind::SoftmaxCe => {
                let mut y = Mat::zeros(rows, cols);
                for r in 0..rows {
                    let k = rng.below(cols);
                    y.set(r, k, 1.0);
                }
                y
            }
            LossKind::SigmoidCe => Mat::from_fn(rows, cols, |_, _| rng.bernoulli(0.5)),
            LossKind::SquaredError => Mat::randn(rows, cols, 1.0, rng),
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        for loss in [LossKind::SigmoidCe, LossKind::SoftmaxCe, LossKind::SquaredError] {
            let arch = tiny_arch(loss);
            let net = Net::new(arch.clone());
            let mut rng = Rng::new(1);
            let params = arch.glorot_init(&mut rng);
            let x = Mat::randn(7, 5, 1.0, &mut rng);
            let y = make_targets(loss, 7, 3, &mut rng);
            let (_, grad) = net.loss_and_grad(&params, &x, &y);
            let eps = 1e-6;
            for li in 0..arch.num_layers() {
                for idx in [0usize, 3, 7] {
                    let (r, c) = (idx / params.0[li].cols, idx % params.0[li].cols);
                    let mut pp = params.clone();
                    pp.0[li].set(r, c, params.0[li].at(r, c) + eps);
                    let mut pm = params.clone();
                    pm.0[li].set(r, c, params.0[li].at(r, c) - eps);
                    let fd = (net.loss(&pp, &x, &y) - net.loss(&pm, &x, &y)) / (2.0 * eps);
                    let g = grad.0[li].at(r, c);
                    assert!(
                        (fd - g).abs() < 1e-5 * (1.0 + g.abs()),
                        "{loss:?} l{li} fd={fd} g={g}"
                    );
                }
            }
        }
    }

    #[test]
    fn jvp_matches_finite_difference() {
        let arch = tiny_arch(LossKind::SquaredError);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(2);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(4, 5, 1.0, &mut rng);
        let v =
            Params(params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
        let fwd = net.forward(&params, &x);
        let jz = net.jvp(&params, &fwd, &v);
        let eps = 1e-6;
        let mut pp = params.clone();
        pp.axpy(eps, &v);
        let mut pm = params.clone();
        pm.axpy(-eps, &v);
        let zp = net.forward(&pp, &x);
        let zm = net.forward(&pm, &x);
        let fd = zp.z().sub(zm.z()).scale(1.0 / (2.0 * eps));
        assert!(fd.sub(&jz).max_abs() < 1e-6, "err={}", fd.sub(&jz).max_abs());
    }

    #[test]
    fn fvp_quad_consistent_with_fvp() {
        for loss in [LossKind::SigmoidCe, LossKind::SoftmaxCe, LossKind::SquaredError] {
            let arch = tiny_arch(loss);
            let net = Net::new(arch.clone());
            let mut rng = Rng::new(3);
            let params = arch.glorot_init(&mut rng);
            let x = Mat::randn(6, 5, 1.0, &mut rng);
            let mk = |rng: &mut Rng| {
                Params(params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, rng)).collect())
            };
            let u = mk(&mut rng);
            let v = mk(&mut rng);
            let q = net.fvp_quad(&params, &x, &[&u, &v]);
            let fu = net.fvp(&params, &x, &u);
            let fv = net.fvp(&params, &x, &v);
            assert!((q.at(0, 0) - u.dot(&fu)).abs() < 1e-9, "{loss:?}");
            assert!((q.at(0, 1) - u.dot(&fv)).abs() < 1e-9, "{loss:?}");
            assert!((q.at(1, 1) - v.dot(&fv)).abs() < 1e-9, "{loss:?}");
            // symmetry of F
            assert!((u.dot(&fv) - v.dot(&fu)).abs() < 1e-9, "{loss:?}");
        }
    }

    #[test]
    fn fisher_is_psd_along_random_directions() {
        let arch = tiny_arch(LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(4);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(5, 5, 1.0, &mut rng);
        for _ in 0..10 {
            let v = Params(
                params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect(),
            );
            let q = net.fvp_quad(&params, &x, &[&v]);
            assert!(q.at(0, 0) >= -1e-12);
        }
    }

    #[test]
    fn grad_sq_in_basis_matches_per_example_outer_products() {
        // Dense check of the projection-first trick: materialize every
        // per-example weight gradient DW_n = g_n ā_nᵀ, project it as a
        // matrix, square, and average. The identity is pure algebra, so
        // it must hold for *arbitrary* (not just orthogonal) bases.
        let arch = tiny_arch(LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(6);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(5, 5, 1.0, &mut rng);
        let fwd = net.forward(&params, &x);
        let gs = net.sampled_backward(&params, &fwd, &mut rng);
        let bases: Vec<KronBasis> = (0..arch.num_layers())
            .map(|i| {
                let (r, c) = arch.weight_shape(i);
                KronBasis {
                    ua: Mat::randn(c, c, 1.0, &mut rng),
                    ug: Mat::randn(r, r, 1.0, &mut rng),
                }
            })
            .collect();
        let got = net.grad_sq_in_basis(&fwd, &gs, &bases);
        let m = x.rows;
        for i in 0..arch.num_layers() {
            let (r, c) = arch.weight_shape(i);
            let mut want = Mat::zeros(r, c);
            for n in 0..m {
                let dw = Mat::from_fn(r, c, |p, q| gs[i].at(n, p) * fwd.abars[i].at(n, q));
                let proj = bases[i].ug.matmul_tn(&dw).matmul(&bases[i].ua);
                want.axpy(1.0 / m as f64, &proj.hadamard(&proj));
            }
            let scale = want.max_abs().max(1e-12);
            let err = got[i].sub(&want).max_abs() / scale;
            assert!(err < 1e-12, "layer {i}: rel err {err}");
        }
    }

    #[test]
    fn sampled_backward_has_zero_mean_gs() {
        // Lemma 4: E[g] = 0 when targets are sampled from the model.
        let arch = tiny_arch(LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(5);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(2, 5, 1.0, &mut rng);
        let fwd = net.forward(&params, &x);
        let mut acc = Mat::zeros(2, 3);
        let n = 20_000;
        for _ in 0..n {
            let gs = net.sampled_backward(&params, &fwd, &mut rng);
            acc.axpy(1.0 / n as f64, &gs[1]);
        }
        assert!(acc.max_abs() < 0.02, "mean g = {}", acc.max_abs());
    }
}
