//! Distributed K-FAC: data-parallel collectives and the sharded
//! inverse-refresh pipeline.
//!
//! The paper's central cost argument (§8) is that storing and inverting the
//! Kronecker-factored curvature is independent of the amount of data used to
//! estimate it. That makes the refresh pipeline shardable: workers all-reduce
//! per-step gradients and Kronecker-factor statistics, the per-layer
//! factorization at each `t_inv` boundary is sharded round-robin by layer
//! index across ranks, and the resulting inverse parts are broadcast — the
//! layout used by distributed ACKTR-style K-FAC implementations.
//!
//! The subsystem is built behind one seam, the [`Collective`] trait, with two
//! transports:
//!
//! * [`local::LocalGroup`] — in-process mpsc channels, for tests and
//!   deterministic multi-rank runs in one process (`--dist local`).
//! * [`tcp::TcpCollective`] — length-prefixed TCP over localhost or a real
//!   network (`--dist tcp`), std-only, with connect retry/backoff and
//!   read/write timeouts.
//!
//! Both share the same star topology implemented by [`Star`]: rank 0 is the
//! hub; every collective op is a deterministic exchange with the hub so that
//! reduction order (and therefore floating-point rounding) is identical on
//! every rank and every run.
//!
//! ## Degraded mode / staleness contract
//!
//! A peer that is slow past the deadline or drops mid-operation is excluded
//! permanently by the hub; the all-reduce keeps serving the survivors (the
//! contributor count shrinks). A refresh interrupted by a death in flight
//! surfaces as an `Err` from [`sharded_build`]; the optimizer then records a
//! stall and keeps stepping on the previous `inv_epoch` — the same staleness
//! contract the async refresh path uses. Shard ownership is re-derived over
//! the live rank set at every `t_inv` boundary (a one-hot liveness
//! all-reduce), so a dead owner's layers migrate to survivors and refreshes
//! resume instead of stalling indefinitely.
//! This module contains no `unsafe` code (enforced by repo lint rule R6).

pub mod backend;
pub mod local;
pub mod tcp;
pub mod trainer;

use std::time::Duration;

use crate::fisher::precond::Preconditioner;
use crate::fisher::stats::RawStats;
use crate::fisher::FisherInverse;

/// Errors surfaced by collective operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DistError {
    /// A peer did not respond within the configured deadline.
    Timeout,
    /// A peer dropped (connection closed / channel disconnected).
    PeerLost(usize),
    /// Transport-level I/O failure.
    Io(String),
    /// Protocol violation (length mismatch, unexpected frame, bad payload).
    Protocol(String),
}

impl std::fmt::Display for DistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistError::Timeout => write!(f, "collective timed out"),
            DistError::PeerLost(r) => write!(f, "peer rank {r} lost"),
            DistError::Io(e) => write!(f, "collective i/o error: {e}"),
            DistError::Protocol(e) => write!(f, "collective protocol error: {e}"),
        }
    }
}

/// A group of cooperating ranks.
///
/// Implementations must be deterministic: the reduction order of
/// `all_reduce_sum` is fixed (rank order), so every rank observes bitwise
/// identical results regardless of arrival timing.
pub trait Collective: Send + Sync {
    /// This worker's rank in `0..size()`.
    fn rank(&self) -> usize;
    /// Number of ranks in the group at startup.
    fn size(&self) -> usize;
    /// Element-wise sum of `buf` across live ranks, written back into `buf`
    /// on every live rank. Returns the number of contributors (shrinks when
    /// peers have been excluded). On `Err`, `buf` is left untouched (the
    /// caller keeps its local values).
    fn all_reduce_sum(&self, buf: &mut [f64]) -> Result<usize, DistError>;
    /// Copies `buf` on `root` into `buf` on every other live rank.
    fn broadcast(&self, root: usize, buf: &mut [f64]) -> Result<(), DistError>;
    /// Blocks until all live ranks have entered the barrier.
    fn barrier(&self) -> Result<(), DistError>;
}

/// Single-process stand-in: rank 0 of a size-1 group; every op is a no-op.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopCollective;

impl Collective for NoopCollective {
    fn rank(&self) -> usize {
        0
    }
    fn size(&self) -> usize {
        1
    }
    fn all_reduce_sum(&self, _buf: &mut [f64]) -> Result<usize, DistError> {
        Ok(1)
    }
    fn broadcast(&self, _root: usize, _buf: &mut [f64]) -> Result<(), DistError> {
        Ok(())
    }
    fn barrier(&self) -> Result<(), DistError> {
        Ok(())
    }
}

/// Per-op deadline for collective exchanges. `KFAC_DIST_TIMEOUT_MS`
/// overrides the 5000 ms default (see docs/env_registry.md).
pub fn default_timeout() -> Duration {
    let ms = std::env::var("KFAC_DIST_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(5000);
    Duration::from_millis(ms)
}

/// One message on a link. Both transports speak this vocabulary.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Frame {
    /// A payload of f64s (reduce contribution, reduce result, broadcast).
    Data(Vec<f64>),
    /// Hub → spoke: the op cannot complete (e.g. the broadcast source died).
    Abort,
    /// Spoke → hub greeting at connect time; payload\[0\] = rank.
    Hello(Vec<f64>),
}

/// Transport-level link failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum LinkError {
    Timeout,
    Lost,
    Io(String),
}

/// A reliable, ordered, framed channel to one peer.
pub(crate) trait Link: Send {
    fn send(&mut self, frame: &Frame) -> Result<(), LinkError>;
    fn recv(&mut self, timeout: Duration) -> Result<Frame, LinkError>;
}

/// Star-topology collective engine shared by both transports.
///
/// Rank 0 (the hub) holds one link per spoke (`links[r - 1]` = link to rank
/// `r`, `None` once that peer has been excluded). Spokes hold exactly one
/// link, to the hub (`links[0]`). All ops are hub-mediated so reduction
/// order is fixed: contributions are added in rank order, making the result
/// bitwise identical on every rank.
pub(crate) struct Star<L: Link> {
    rank: usize,
    size: usize,
    timeout: Duration,
    links: Vec<Option<L>>,
}

impl<L: Link> Star<L> {
    pub(crate) fn new(rank: usize, size: usize, timeout: Duration, links: Vec<Option<L>>) -> Self {
        Star { rank, size, timeout, links }
    }

    pub(crate) fn rank(&self) -> usize {
        self.rank
    }

    pub(crate) fn size(&self) -> usize {
        self.size
    }

    fn map_link_err(&self, peer: usize, e: LinkError) -> DistError {
        match e {
            LinkError::Timeout => DistError::Timeout,
            LinkError::Lost => DistError::PeerLost(peer),
            LinkError::Io(m) => DistError::Io(m),
        }
    }

    /// Hub-side helper: permanently exclude the peer behind `links[idx]`.
    fn kill_link(&mut self, idx: usize) {
        self.links[idx] = None;
    }

    pub(crate) fn all_reduce_sum(&mut self, buf: &mut [f64]) -> Result<usize, DistError> {
        if self.size <= 1 {
            return Ok(1);
        }
        if self.rank == 0 {
            // Accumulate in rank order for deterministic rounding. A link
            // that fails mid-op is excluded permanently; its contribution is
            // simply absent (the count tells the callers how many summed).
            let mut acc = buf.to_vec();
            let mut count = 1usize;
            for idx in 0..self.links.len() {
                let Some(link) = self.links[idx].as_mut() else { continue };
                match link.recv(self.timeout) {
                    Ok(Frame::Data(v)) if v.len() == buf.len() => {
                        for (a, x) in acc.iter_mut().zip(v.iter()) {
                            *a += *x;
                        }
                        count += 1;
                    }
                    _ => self.kill_link(idx),
                }
            }
            let mut reply = acc.clone();
            reply.push(count as f64);
            let reply = Frame::Data(reply);
            for idx in 0..self.links.len() {
                let Some(link) = self.links[idx].as_mut() else { continue };
                if link.send(&reply).is_err() {
                    self.kill_link(idx);
                }
            }
            buf.copy_from_slice(&acc);
            Ok(count)
        } else {
            let link = self.links[0].as_mut().ok_or(DistError::PeerLost(0))?;
            link.send(&Frame::Data(buf.to_vec())).map_err(|e| match e {
                LinkError::Timeout => DistError::Timeout,
                LinkError::Lost => DistError::PeerLost(0),
                LinkError::Io(m) => DistError::Io(m),
            })?;
            match link.recv(self.timeout) {
                Ok(Frame::Data(v)) if v.len() == buf.len() + 1 => {
                    buf.copy_from_slice(&v[..buf.len()]);
                    Ok(v[buf.len()] as usize)
                }
                Ok(Frame::Abort) => Err(DistError::PeerLost(0)),
                Ok(_) => Err(DistError::Protocol("bad all-reduce reply".into())),
                Err(e) => Err(self.map_link_err(0, e)),
            }
        }
    }

    pub(crate) fn broadcast(&mut self, root: usize, buf: &mut [f64]) -> Result<(), DistError> {
        if self.size <= 1 {
            return Ok(());
        }
        if root >= self.size {
            return Err(DistError::Protocol(format!("broadcast root {root} out of range")));
        }
        if root == 0 {
            if self.rank == 0 {
                let frame = Frame::Data(buf.to_vec());
                for idx in 0..self.links.len() {
                    let Some(link) = self.links[idx].as_mut() else { continue };
                    if link.send(&frame).is_err() {
                        self.kill_link(idx);
                    }
                }
                Ok(())
            } else {
                self.recv_broadcast(buf)
            }
        } else if self.rank == root {
            // Source: hand the payload to the hub, which relays it.
            let link = self.links[0].as_mut().ok_or(DistError::PeerLost(0))?;
            link.send(&Frame::Data(buf.to_vec()))
                .map_err(|e| self.map_link_err(0, e))
        } else if self.rank == 0 {
            // Hub: receive from the source, copy locally, relay to the rest.
            let src_idx = root - 1;
            let payload = match self.links[src_idx].as_mut() {
                Some(link) => match link.recv(self.timeout) {
                    Ok(Frame::Data(v)) if v.len() == buf.len() => Some(v),
                    _ => None,
                },
                None => None,
            };
            match payload {
                Some(v) => {
                    buf.copy_from_slice(&v);
                    let frame = Frame::Data(v);
                    for idx in 0..self.links.len() {
                        if idx == src_idx {
                            continue;
                        }
                        let Some(link) = self.links[idx].as_mut() else { continue };
                        if link.send(&frame).is_err() {
                            self.kill_link(idx);
                        }
                    }
                    Ok(())
                }
                None => {
                    // Source is gone: exclude it and tell the other spokes
                    // the op is dead so nobody blocks on a relay that will
                    // never come.
                    self.kill_link(src_idx);
                    for idx in 0..self.links.len() {
                        if idx == src_idx {
                            continue;
                        }
                        let Some(link) = self.links[idx].as_mut() else { continue };
                        if link.send(&Frame::Abort).is_err() {
                            self.kill_link(idx);
                        }
                    }
                    Err(DistError::PeerLost(root))
                }
            }
        } else {
            self.recv_broadcast(buf)
        }
    }

    /// Spoke side of a broadcast: wait for the relayed payload (or Abort).
    fn recv_broadcast(&mut self, buf: &mut [f64]) -> Result<(), DistError> {
        let link = self.links[0].as_mut().ok_or(DistError::PeerLost(0))?;
        match link.recv(self.timeout) {
            Ok(Frame::Data(v)) if v.len() == buf.len() => {
                buf.copy_from_slice(&v);
                Ok(())
            }
            Ok(Frame::Abort) => Err(DistError::PeerLost(usize::MAX)),
            Ok(_) => Err(DistError::Protocol("bad broadcast payload".into())),
            Err(e) => Err(self.map_link_err(0, e)),
        }
    }

    pub(crate) fn barrier(&mut self) -> Result<(), DistError> {
        let mut one = [0.0f64];
        self.all_reduce_sum(&mut one).map(|_| ())
    }
}

/// Builds the Fisher inverse at a `t_inv` boundary with the per-layer
/// factorization sharded round-robin by layer index across the **live**
/// ranks, then broadcasts each layer's part from its owner.
///
/// Ownership is re-derived at every call from a one-hot liveness
/// all-reduce: each rank contributes `1.0` at its own index, so every
/// survivor observes the identical live set and maps layer `i` to
/// `live[i % live.len()]`. When a peer dies its layers are re-assigned
/// to survivors at the next boundary — refreshes keep landing
/// (`inv_epoch` keeps advancing) instead of stalling forever on a dead
/// static owner. With all ranks alive the map coincides with the static
/// `layer % size` assignment, so healthy runs are unchanged.
///
/// Preconditioners that do not support sharding (`layer_part_len` returns
/// `None`) fall back to a replicated local build — deterministic because the
/// statistics were already all-reduced identically on every rank. The same
/// fallback serves a group whose live set has shrunk to this rank alone.
///
/// On `Err` the caller keeps the previous inverse epoch and records a stall
/// (degraded mode); a kill *during* a boundary can still stall that one
/// refresh, but the next boundary reshards around the hole.
pub fn sharded_build(
    precond: &dyn Preconditioner,
    stats: &RawStats,
    gamma: f64,
    coll: &dyn Collective,
) -> Result<Box<dyn FisherInverse + Send>, DistError> {
    let n = coll.size();
    let l = stats.num_layers();
    if n <= 1 {
        return Ok(precond.build(stats, gamma));
    }
    let lens: Vec<Option<usize>> = (0..l).map(|i| precond.layer_part_len(stats, i)).collect();
    if lens.iter().any(|x| x.is_none()) {
        // Unsharded structure: every rank rebuilds from the (identical)
        // reduced statistics.
        return Ok(precond.build(stats, gamma));
    }
    let rank = coll.rank();
    // Liveness probe: one-hot contributions sum to the survivor set, and
    // the fixed reduction order makes it bitwise identical on every rank.
    let mut live = vec![0.0f64; n];
    live[rank] = 1.0;
    coll.all_reduce_sum(&mut live)?;
    let live_ranks: Vec<usize> = (0..n).filter(|&r| live[r] > 0.5).collect();
    if live_ranks.len() <= 1 {
        return Ok(precond.build(stats, gamma));
    }
    let owner = |i: usize| live_ranks[i % live_ranks.len()];
    // Build owned parts first so the broadcast loop below never interleaves
    // local factorization work between collective ops on different ranks.
    let mut parts: Vec<Option<Vec<f64>>> = (0..l)
        .map(|i| {
            if owner(i) == rank {
                Some(precond.build_layer_part(stats, gamma, i))
            } else {
                None
            }
        })
        .collect();
    let mut out: Vec<Vec<f64>> = Vec::with_capacity(l);
    for (i, len) in lens.iter().enumerate() {
        let len = len.expect("checked above");
        let mut buf = match parts[i].take() {
            Some(p) => {
                if p.len() != len {
                    return Err(DistError::Protocol(format!(
                        "layer {i} part length {} != declared {len}",
                        p.len()
                    )));
                }
                p
            }
            None => vec![0.0; len],
        };
        coll.broadcast(owner(i), &mut buf)?;
        out.push(buf);
    }
    precond
        .assemble_parts(stats, gamma, &out)
        .ok_or_else(|| DistError::Protocol("preconditioner failed to assemble parts".into()))
}
