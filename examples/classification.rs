//! Domain example: 10-way digit classification, K-FAC vs SGD+NAG.
//! Reproduces in miniature the paper's claim that K-FAC needs orders of
//! magnitude fewer iterations than SGD with momentum.
//!
//!     cargo run --release --example classification

use kfac::backend::{ModelBackend, RustBackend};
use kfac::data::mnist_like;
use kfac::nn::{Act, Arch};
use kfac::optim::{Kfac, KfacConfig, Sgd, SgdConfig};
use kfac::prelude::*;

fn eval(backend: &mut RustBackend, p: &Params, ds: &Dataset) -> (f64, f64) {
    backend.eval(p, &ds.x, &ds.y)
}

fn main() {
    let ds = mnist_like::classification_dataset(2000, 16, 0);
    let arch = Arch::classifier(&[256, 60, 40, 10], Act::Tanh);
    let iters = 60;
    let batch = 500;

    // --- K-FAC ---
    let mut backend = RustBackend::new(arch.clone());
    let mut p_kfac = arch.sparse_init(&mut Rng::new(1));
    let mut kfac = Kfac::new(&arch, KfacConfig { lambda0: 5.0, t1: 2, ..Default::default() });
    let mut rng = Rng::new(2);
    println!("== K-FAC (block-tridiagonal, momentum) ==");
    for k in 1..=iters {
        let (x, y) = ds.minibatch(batch, &mut rng);
        kfac.step(&mut backend, &mut p_kfac, &x, &y);
        if k % 10 == 0 {
            let (loss, err) = eval(&mut backend, &p_kfac, &ds);
            println!("iter {k:>3}  loss {loss:.4}  error {:.2}%", 100.0 * err);
        }
    }

    // --- SGD + NAG baseline (same iteration budget) ---
    let mut p_sgd = arch.sparse_init(&mut Rng::new(1));
    let mut sgd = Sgd::new(SgdConfig { lr: 0.05, mu_max: 0.99, ..Default::default() });
    let mut rng = Rng::new(2);
    println!("== SGD + Nesterov momentum ==");
    for k in 1..=iters {
        let (x, y) = ds.minibatch(batch, &mut rng);
        sgd.step(&mut backend, &mut p_sgd, &x, &y);
        if k % 10 == 0 {
            let (loss, err) = eval(&mut backend, &p_sgd, &ds);
            println!("iter {k:>3}  loss {loss:.4}  error {:.2}%", 100.0 * err);
        }
    }

    let (_, e_k) = eval(&mut backend, &p_kfac, &ds);
    let (_, e_s) = eval(&mut backend, &p_sgd, &ds);
    println!("\nfinal training error after {iters} iterations:");
    println!("  K-FAC : {:.2}%", 100.0 * e_k);
    println!("  SGD   : {:.2}%", 100.0 * e_s);
}
