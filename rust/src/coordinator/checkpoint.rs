//! Versioned training checkpoints: parameters + full optimizer state +
//! trainer state (RNG stream, Polyak average, counters), serialized to
//! a self-describing little-endian binary format.
//!
//! Layout (version 2):
//!
//! ```text
//! magic    8 bytes  "KFACCKPT"
//! version  u32
//! iter     u64      cases f64    time_s f64
//! rng      4×u64    + optional f64 (Box–Muller spare)
//! params   mat list
//! polyak   optional (xi f64, optional mat list)
//! opt      kind string, then tagged entries:
//!            tag 0 = scalar f64, tag 1 = mat list, tag 2 = string
//! ```
//!
//! Strings are `u64` length + UTF-8 bytes; matrices are `u64 rows`,
//! `u64 cols`, then row-major f64 bits; optionals are a `u8` presence
//! flag. Every f64 is stored as its exact bit pattern, so a resumed run
//! continues the saved trajectory bit-for-bit.
//!
//! Version history: v2 adds the EKFAC re-estimated scale state
//! (`scale_k` / `scale_s` optimizer entries). The wire format is
//! unchanged, but a v1 reader would silently rebuild cached inverses
//! *without* the re-estimated scales and diverge from the saved
//! trajectory, so the version is bumped and mismatched files are
//! rejected (both directions) instead of mis-read. v3 adds the
//! asynchronous-refresh state (`inv_epoch` plus the `pending_*` record
//! of an in-flight inverse build, re-submitted on resume) — again no
//! wire change, only new tagged entries. Snapshots without async state
//! are still written as v2, so synchronous runs stay interchangeable
//! with pre-split readers. v4 adds the incremental-update record
//! (`upd_*`: the stats/γ snapshot of the latest rank-k inverse
//! correction an incremental preconditioner absorbed, replayed on top
//! of the rebuilt base at resume) — written only when such a record is
//! live, so non-incremental runs keep producing v2/v3 files; this build
//! reads v2 through v4.

use crate::linalg::Mat;
use crate::nn::Params;
use crate::optim::{OptState, StateVal};
use std::io::Write;
use std::path::Path;

pub const CHECKPOINT_MAGIC: &[u8; 8] = b"KFACCKPT";
pub const CHECKPOINT_VERSION: u32 = 2;
/// Version written when the optimizer state carries
/// asynchronous-refresh entries (and nothing newer).
pub const CHECKPOINT_VERSION_ASYNC: u32 = 3;
/// Highest version this build writes: v4 when the optimizer state
/// carries an incremental-update record (`upd_*`).
pub const CHECKPOINT_VERSION_INCR: u32 = 4;

/// The version a snapshot of `opt` must be written as: the lowest
/// version whose vocabulary covers the live entries, so runs not using
/// a feature keep producing files readable by older builds (v2 for
/// plain synchronous runs, v3 with async-refresh state, v4 with an
/// incremental-update record).
pub fn version_for(opt: &OptState) -> u32 {
    let incr_keys = ["upd_gamma", "upd_aa"];
    let async_keys = ["inv_epoch", "pending_gamma", "pending_aa"];
    if incr_keys.iter().any(|k| opt.entries.contains_key(*k)) {
        CHECKPOINT_VERSION_INCR
    } else if async_keys.iter().any(|k| opt.entries.contains_key(*k)) {
        CHECKPOINT_VERSION_ASYNC
    } else {
        CHECKPOINT_VERSION
    }
}

/// Every optimizer-state key this build can write (the `set_scalar` /
/// `set_mats` / `set_str` literals in library code). Pinned here so the
/// wire vocabulary is an explicit, reviewed surface: adding a writer key
/// without extending this list (and deciding its version/compat story —
/// see the v2→v3 history above) fails `scripts/repo_lint.py`, which
/// re-extracts the writer literals from source and diffs them against
/// this constant. Keep the list sorted within each section.
pub const KNOWN_OPT_STATE_KEYS: &[&str] = &[
    // Sgd (optim/sgd.rs)
    "t",
    "v",
    // Kfac core (optim/kfac.rs)
    "delta_prev",
    "gamma",
    "k",
    "lambda",
    "precond",
    "stats_aa",
    "stats_aa_off",
    "stats_gg",
    "stats_gg_off",
    "stats_k",
    // Kfac cached-inverse rebuild record (v2)
    "refresh_aa",
    "refresh_aa_off",
    "refresh_gamma",
    "refresh_gg",
    "refresh_gg_off",
    "scale_k",
    "scale_s",
    // Kfac asynchronous / distributed refresh (v3)
    "inv_epoch",
    "pending_aa",
    "pending_aa_off",
    "pending_gamma",
    "pending_gg",
    "pending_gg_off",
    "pending_k",
    "refresh_stalls",
    // Kfac incremental-update record (v4)
    "upd_aa",
    "upd_aa_off",
    "upd_gamma",
    "upd_gg",
    "upd_gg_off",
];

/// A full training snapshot.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub version: u32,
    /// Iterations completed when the snapshot was taken.
    pub iter: usize,
    /// Cumulative training cases processed.
    pub cases: f64,
    /// Cumulative optimizer wall-clock (excludes evaluation).
    pub time_s: f64,
    /// Mini-batch RNG state (xoshiro words + Box–Muller spare).
    pub rng_words: [u64; 4],
    pub rng_spare: Option<f64>,
    /// Network parameters.
    pub params: Params,
    /// Polyak averager: (ξ, averaged parameters if any updates were
    /// absorbed). `None` when averaging was disabled.
    pub polyak: Option<(f64, Option<Params>)>,
    /// Full optimizer state.
    pub opt: OptState,
}

// ---------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_mat(out: &mut Vec<u8>, m: &Mat) {
    put_u64(out, m.rows as u64);
    put_u64(out, m.cols as u64);
    for &v in &m.data {
        put_f64(out, v);
    }
}

fn put_mats(out: &mut Vec<u8>, ms: &[Mat]) {
    put_u64(out, ms.len() as u64);
    for m in ms {
        put_mat(out, m);
    }
}

/// Serialize a checkpoint to bytes.
pub fn to_bytes(ck: &Checkpoint) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(CHECKPOINT_MAGIC);
    put_u32(&mut out, ck.version);
    put_u64(&mut out, ck.iter as u64);
    put_f64(&mut out, ck.cases);
    put_f64(&mut out, ck.time_s);
    for w in ck.rng_words {
        put_u64(&mut out, w);
    }
    match ck.rng_spare {
        Some(v) => {
            out.push(1);
            put_f64(&mut out, v);
        }
        None => out.push(0),
    }
    put_mats(&mut out, &ck.params.0);
    match &ck.polyak {
        Some((xi, avg)) => {
            out.push(1);
            put_f64(&mut out, *xi);
            match avg {
                Some(p) => {
                    out.push(1);
                    put_mats(&mut out, &p.0);
                }
                None => out.push(0),
            }
        }
        None => out.push(0),
    }
    put_str(&mut out, &ck.opt.kind);
    put_u64(&mut out, ck.opt.entries.len() as u64);
    for (key, val) in &ck.opt.entries {
        put_str(&mut out, key);
        match val {
            StateVal::Scalar(v) => {
                out.push(0);
                put_f64(&mut out, *v);
            }
            StateVal::Mats(ms) => {
                out.push(1);
                put_mats(&mut out, ms);
            }
            StateVal::Str(s) => {
                out.push(2);
                put_str(&mut out, s);
            }
        }
    }
    out
}

/// Write a checkpoint, creating parent directories.
pub fn save(path: &Path, ck: &Checkpoint) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    // write-then-rename so a crash mid-write never corrupts the
    // previous checkpoint
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&to_bytes(ck))?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

// ---------------------------------------------------------------------
// reader
// ---------------------------------------------------------------------

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.i + n > self.b.len() {
            return Err(format!(
                "checkpoint truncated: need {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            ));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn len(&mut self, what: &str) -> Result<usize, String> {
        let n = self.u64()? as usize;
        // sanity bound: no field can be longer than the file itself
        if n > self.b.len() {
            return Err(format!("checkpoint corrupt: {what} length {n} exceeds file size"));
        }
        Ok(n)
    }

    fn string(&mut self, what: &str) -> Result<String, String> {
        let n = self.len(what)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| format!("checkpoint corrupt: {what} utf8"))
    }

    fn mat(&mut self) -> Result<Mat, String> {
        let rows = self.len("mat rows")?;
        let cols = self.len("mat cols")?;
        // Fully checked arithmetic: `rows * cols * 8 + i` on attacker-
        // controlled lengths must surface as a descriptive Err, never wrap
        // around and pass the bounds test (or panic in a debug build).
        let n = rows
            .checked_mul(cols)
            .filter(|&n| {
                n.checked_mul(8)
                    .and_then(|b| self.i.checked_add(b))
                    .is_some_and(|end| end <= self.b.len())
            })
            .ok_or_else(|| format!("checkpoint corrupt: mat {rows}x{cols} too large"))?;
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(self.f64()?);
        }
        Ok(Mat::from_vec(rows, cols, data))
    }

    fn mats(&mut self) -> Result<Vec<Mat>, String> {
        let n = self.len("mat count")?;
        let mut out = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            out.push(self.mat()?);
        }
        Ok(out)
    }
}

/// Parse a checkpoint from bytes.
pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint, String> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(8)? != CHECKPOINT_MAGIC {
        return Err("not a kfac checkpoint (bad magic)".to_string());
    }
    let version = r.u32()?;
    if !(CHECKPOINT_VERSION..=CHECKPOINT_VERSION_INCR).contains(&version) {
        return Err(format!(
            "unsupported checkpoint version {version} (this build reads \
             {CHECKPOINT_VERSION}-{CHECKPOINT_VERSION_INCR})"
        ));
    }
    let iter = r.u64()? as usize;
    let cases = r.f64()?;
    let time_s = r.f64()?;
    let rng_words = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    let rng_spare = if r.u8()? == 1 { Some(r.f64()?) } else { None };
    let params = Params(r.mats()?);
    let polyak = if r.u8()? == 1 {
        let xi = r.f64()?;
        let avg = if r.u8()? == 1 { Some(Params(r.mats()?)) } else { None };
        Some((xi, avg))
    } else {
        None
    };
    let kind = r.string("opt kind")?;
    let n_entries = r.len("opt entries")?;
    let mut opt = OptState::new(&kind);
    for _ in 0..n_entries {
        let key = r.string("opt key")?;
        match r.u8()? {
            0 => {
                let v = r.f64()?;
                opt.set_scalar(&key, v);
            }
            1 => {
                let ms = r.mats()?;
                opt.set_mats(&key, ms);
            }
            2 => {
                let s = r.string("opt str value")?;
                opt.set_str(&key, &s);
            }
            t => return Err(format!("checkpoint corrupt: unknown state tag {t}")),
        }
    }
    if r.i != bytes.len() {
        return Err(format!("checkpoint corrupt: {} trailing bytes", bytes.len() - r.i));
    }
    Ok(Checkpoint { version, iter, cases, time_s, rng_words, rng_spare, params, polyak, opt })
}

/// Read a checkpoint from disk.
pub fn load(path: &Path) -> Result<Checkpoint, String> {
    let bytes = std::fs::read(path)
        .map_err(|e| format!("reading checkpoint {}: {e}", path.display()))?;
    from_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut opt = OptState::new("kfac");
        opt.set_scalar("k", 12.0);
        opt.set_scalar("lambda", 3.5e-2);
        opt.set_str("precond", "blktridiag");
        opt.set_mats("stats_aa", vec![Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 5.0])]);
        Checkpoint {
            version: CHECKPOINT_VERSION,
            iter: 12,
            cases: 6144.0,
            time_s: 1.25,
            rng_words: [1, u64::MAX, 42, 7],
            rng_spare: Some(-0.321),
            params: Params(vec![Mat::from_vec(1, 3, vec![0.5, -0.25, 1e-300])]),
            polyak: Some((0.99, Some(Params(vec![Mat::from_vec(1, 3, vec![0.4, -0.2, 0.0])])))),
            opt,
        }
    }

    #[test]
    fn roundtrips_bit_exactly() {
        let ck = sample();
        let bytes = to_bytes(&ck);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back.iter, ck.iter);
        assert_eq!(back.cases.to_bits(), ck.cases.to_bits());
        assert_eq!(back.rng_words, ck.rng_words);
        assert_eq!(back.rng_spare.unwrap().to_bits(), ck.rng_spare.unwrap().to_bits());
        assert!(back.params == ck.params);
        let (xi, avg) = back.polyak.unwrap();
        assert_eq!(xi, 0.99);
        assert!(avg.unwrap() == ck.polyak.clone().unwrap().1.unwrap());
        assert_eq!(back.opt, ck.opt);
    }

    #[test]
    fn roundtrips_through_disk() {
        let ck = sample();
        let path = std::env::temp_dir().join("kfac_ckpt_test/roundtrip.ckpt");
        save(&path, &ck).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.opt, ck.opt);
        assert!(back.params == ck.params);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn absent_options_roundtrip() {
        let mut ck = sample();
        ck.rng_spare = None;
        ck.polyak = None;
        let back = from_bytes(&to_bytes(&ck)).unwrap();
        assert!(back.rng_spare.is_none());
        assert!(back.polyak.is_none());
    }

    #[test]
    fn version_for_classifies_async_state() {
        let ck = sample();
        assert_eq!(version_for(&ck.opt), CHECKPOINT_VERSION, "sync state stays v2");
        let mut with_epoch = ck.opt.clone();
        with_epoch.set_scalar("inv_epoch", 4.0);
        assert_eq!(version_for(&with_epoch), CHECKPOINT_VERSION_ASYNC);
        let mut with_pending = ck.opt.clone();
        with_pending.set_scalar("pending_gamma", 0.5);
        with_pending.set_mats("pending_aa", vec![Mat::eye(2)]);
        assert_eq!(version_for(&with_pending), CHECKPOINT_VERSION_ASYNC);
        // the incremental-update record outranks async state
        let mut with_upd = ck.opt.clone();
        with_upd.set_scalar("upd_gamma", 0.5);
        with_upd.set_mats("upd_aa", vec![Mat::eye(2)]);
        assert_eq!(version_for(&with_upd), CHECKPOINT_VERSION_INCR);
        with_upd.set_scalar("inv_epoch", 4.0);
        assert_eq!(version_for(&with_upd), CHECKPOINT_VERSION_INCR);
    }

    #[test]
    fn v4_checkpoints_roundtrip() {
        let mut ck = sample();
        ck.opt.set_scalar("upd_gamma", 0.25);
        ck.opt.set_mats("upd_aa", vec![Mat::eye(3)]);
        ck.opt.set_mats("upd_gg", vec![Mat::eye(2)]);
        ck.version = version_for(&ck.opt);
        assert_eq!(ck.version, CHECKPOINT_VERSION_INCR);
        let back = from_bytes(&to_bytes(&ck)).unwrap();
        assert_eq!(back.version, CHECKPOINT_VERSION_INCR);
        assert_eq!(back.opt, ck.opt);
    }

    #[test]
    fn v3_checkpoints_roundtrip() {
        let mut ck = sample();
        ck.opt.set_scalar("inv_epoch", 4.0);
        ck.opt.set_scalar("pending_gamma", 0.25);
        ck.opt.set_mats("pending_aa", vec![Mat::eye(3)]);
        ck.version = version_for(&ck.opt);
        assert_eq!(ck.version, CHECKPOINT_VERSION_ASYNC);
        let back = from_bytes(&to_bytes(&ck)).unwrap();
        assert_eq!(back.version, CHECKPOINT_VERSION_ASYNC);
        assert_eq!(back.opt, ck.opt);
    }

    #[test]
    fn key_pin_is_consistent() {
        // the v3/v4-trigger keys must themselves be pinned writer keys
        for k in ["inv_epoch", "pending_gamma", "pending_aa", "upd_gamma", "upd_aa"] {
            assert!(KNOWN_OPT_STATE_KEYS.contains(&k), "version key '{k}' missing from pin");
        }
        // no duplicates (a duplicate would mask a forgotten rename)
        let mut seen = std::collections::BTreeSet::new();
        for k in KNOWN_OPT_STATE_KEYS {
            assert!(seen.insert(*k), "duplicate pinned key '{k}'");
        }
        // every key a sample snapshot writes is pinned
        for k in sample().opt.entries.keys() {
            assert!(KNOWN_OPT_STATE_KEYS.contains(&k.as_str()), "unpinned key '{k}'");
        }
    }

    #[test]
    fn truncation_at_every_64_byte_boundary_errs_without_panic() {
        // Fuzz-ish sweep over both wire versions: a file cut off in the
        // middle of *any* section (header, RNG block, params, polyak,
        // opt entries) must surface a descriptive Err — never a panic,
        // and never a silent success. Strict prefixes can never parse:
        // the entry count is fixed up front and a short read trips
        // either a bounds check or the trailing-bytes check.
        let v2 = to_bytes(&sample());
        let mut ck3 = sample();
        ck3.opt.set_scalar("inv_epoch", 4.0);
        ck3.opt.set_scalar("refresh_stalls", 2.0);
        ck3.opt.set_scalar("pending_gamma", 0.25);
        ck3.opt.set_mats("pending_aa", vec![Mat::eye(3)]);
        ck3.version = version_for(&ck3.opt);
        let v3 = to_bytes(&ck3);
        for bytes in [&v2, &v3] {
            for cut in (0..bytes.len()).step_by(64) {
                let res = from_bytes(&bytes[..cut]);
                assert!(res.is_err(), "prefix of {cut}/{} bytes parsed", bytes.len());
                assert!(!res.unwrap_err().is_empty(), "empty error at cut {cut}");
            }
        }
    }

    #[test]
    fn corrupt_mat_dims_err_instead_of_overflowing() {
        // A length prefix near usize::MAX must fail the checked bounds
        // math in Reader::mat (rows*cols*8 + offset), not wrap around.
        let ck = sample();
        let bytes = to_bytes(&ck);
        // params mat list starts right after magic(8)+version(4)+
        // iter(8)+cases(8)+time(8)+rng(32)+spare flag(1)+spare(8) and
        // its count(8): the first mat's rows field.
        let rows_off = 8 + 4 + 8 + 8 + 8 + 32 + 1 + 8 + 8;
        let mut evil = bytes.clone();
        evil[rows_off..rows_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = from_bytes(&evil).unwrap_err();
        assert!(err.contains("corrupt") || err.contains("truncated"), "got: {err}");
        // and a huge-but-file-bounded rows×cols product overflows the
        // element math, not the parser
        let n = bytes.len() as u64;
        let mut evil2 = bytes;
        evil2[rows_off..rows_off + 8].copy_from_slice(&n.to_le_bytes());
        evil2[rows_off + 8..rows_off + 16].copy_from_slice(&n.to_le_bytes());
        assert!(from_bytes(&evil2).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_bytes(b"").is_err());
        assert!(from_bytes(b"NOTKFACX________").is_err());
        let mut bytes = to_bytes(&sample());
        // future version
        bytes[8] = 99;
        assert!(from_bytes(&bytes).unwrap_err().contains("version"));
        // stale v1 file (pre EKFAC-scale-state): cleanly rejected, not
        // mis-read
        let mut v1 = to_bytes(&sample());
        v1[8..12].copy_from_slice(&1u32.to_le_bytes());
        let err = from_bytes(&v1).unwrap_err();
        assert!(err.contains("version 1"), "unexpected error: {err}");
        // truncation
        let ok = to_bytes(&sample());
        assert!(from_bytes(&ok[..ok.len() - 3]).is_err());
        // trailing garbage
        let mut extended = to_bytes(&sample());
        extended.push(0);
        assert!(from_bytes(&extended).unwrap_err().contains("trailing"));
        // missing file
        assert!(load(Path::new("/nonexistent/kfac.ckpt")).is_err());
    }
}
