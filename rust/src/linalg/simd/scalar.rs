//! The portable micro-kernel: a fixed-bound 4×8 loop nest the compiler
//! unrolls and auto-vectorizes. Always compiled on every architecture —
//! it is the `KFAC_SIMD=0` escape hatch and the reference the explicit
//! SIMD kernels are property-tested against.

use super::MAX_TILE;

/// Micro-tile rows of the scalar kernel.
pub const MR: usize = 4;
/// Micro-tile columns (two 4-wide f64 vectors per row on AVX2 hosts,
/// which is what the auto-vectorizer usually produces from this nest).
pub const NR: usize = 8;

/// `acc[r*NR + c] = Σ_p apanel[p*MR + r] · bpanel[p*NR + c]` for the
/// full (zero-padded) 4×8 tile. Overwrites; no edge variants.
#[inline(always)]
pub(crate) fn micro_4x8(kc: usize, apanel: &[f64], bpanel: &[f64], acc: &mut [f64; MAX_TILE]) {
    debug_assert!(apanel.len() >= kc * MR && bpanel.len() >= kc * NR);
    let mut local = [[0.0f64; NR]; MR];
    for p in 0..kc {
        let av: &[f64] = &apanel[p * MR..p * MR + MR];
        let bv: &[f64] = &bpanel[p * NR..p * NR + NR];
        for r in 0..MR {
            let ar = av[r];
            let row = &mut local[r];
            for c in 0..NR {
                row[c] += ar * bv[c];
            }
        }
    }
    for r in 0..MR {
        acc[r * NR..r * NR + NR].copy_from_slice(&local[r]);
    }
}
