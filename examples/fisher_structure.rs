//! Domain example: inspect the Fisher structure the paper's
//! approximations exploit, on the Figure-2 network (256-20-20-20-20-10).
//! Prints block-level norms of F, F̃, F̃⁻¹ — a text-mode rendition of
//! Figures 2 and 3.
//!
//!     cargo run --release --example fisher_structure

use kfac::experiments::partially_train;
use kfac::fisher::exact::ExactBlocks;
use kfac::linalg::Mat;
use kfac::coordinator::Problem;

fn print_block_map(title: &str, m: &Mat) {
    println!("\n{title} (block-average |entries|, layers 2-5):");
    for r in 0..m.rows {
        print!("   ");
        for c in 0..m.cols {
            print!(" {:>9.2e}", m.at(r, c));
        }
        println!();
    }
}

fn main() {
    println!("# partially training the Figure-2 network with K-FAC…");
    let (backend, params, ds) = partially_train(Problem::MnistClf, 600, 8, 0);
    let x = ds.x.top_rows(150);

    println!("# computing exact F and exact Kronecker factors over the middle 4 layers…");
    let eb = ExactBlocks::compute(backend.net(), &params, &x, 1, 5);
    let f = &eb.f;
    let ktilde = eb.ktilde_dense();

    let err = f.sub(&ktilde);
    println!("\n‖F‖_F = {:.4}   ‖F − F̃‖_F = {:.4}   rel = {:.3}",
        f.frob_norm(), err.frob_norm(), err.frob_norm() / f.frob_norm());

    print_block_map("F (exact Fisher)", &eb.block_avg_abs(f));
    print_block_map("F̃ (Kronecker-factored)", &eb.block_avg_abs(&ktilde));
    print_block_map("|F − F̃|", &eb.block_avg_abs(&err));

    // Figure 3: the inverse is approximately block-tridiagonal.
    let gamma = 0.1;
    let ktilde_inv = eb.ktilde_damped_dense(gamma).inverse();
    print_block_map("F̃⁻¹ (note the tridiagonal dominance)", &eb.block_avg_abs(&ktilde_inv));

    let map = eb.block_avg_abs(&ktilde_inv);
    let mut on_tri = 0.0;
    let mut off_tri = 0.0;
    let (mut n_on, mut n_off) = (0, 0);
    for r in 0..map.rows {
        for c in 0..map.cols {
            if (r as isize - c as isize).abs() <= 1 {
                on_tri += map.at(r, c);
                n_on += 1;
            } else {
                off_tri += map.at(r, c);
                n_off += 1;
            }
        }
    }
    println!(
        "\ntridiagonal-band average / off-band average = {:.1}×",
        (on_tri / n_on as f64) / (off_tri / n_off as f64)
    );
}
