//! Runtime-dispatched SIMD micro-kernels for the dense hot paths.
//!
//! The packed GEMM in [`super::gemm`] funnels every multiply through one
//! register-blocked `MR×NR` micro-kernel; this module owns that kernel.
//! Three implementations share a single contract (compute a full
//! `mr × nr` f64 tile from zero-padded packed panels and overwrite a
//! row-major `mr × nr` scratch tile):
//!
//! - **scalar** — the portable fixed-bound 4×8 loop nest the compiler
//!   auto-vectorizes. Always compiled, on every architecture; it is the
//!   cross-check reference the SIMD kernels are property-tested against.
//! - **avx2** — explicit `std::arch::x86_64` AVX2+FMA intrinsics,
//!   4×8 tiles as eight `__m256d` accumulators (x86_64 only).
//! - **avx512** — AVX-512F intrinsics, 8×8 tiles as eight `__m512d`
//!   accumulators (x86_64 only).
//!
//! Which kernel runs is decided **once per process** (cached in a
//! [`OnceLock`]) from `is_x86_feature_detected!`, overridable with the
//! `KFAC_SIMD` environment variable:
//!
//! ```text
//! KFAC_SIMD=0|off|scalar   force the scalar reference kernel
//! KFAC_SIMD=avx2           force AVX2 (falls back to scalar + warning
//!                          if the host lacks avx2/fma)
//! KFAC_SIMD=avx512         force AVX-512F (same fallback rule)
//! KFAC_SIMD=auto / unset   detect: avx512 > avx2 > scalar
//! ```
//!
//! Any *unknown* value falls back to scalar with a one-time stderr
//! warning instead of panicking (see [`unknown_simd_request_count`]).
//! The chosen kernel and the detected features are logged to stderr
//! once per process.
//!
//! The same dispatch seam serves the eigensolver's memory-bound BLAS-2
//! half: [`fused_tdot2`] / [`fused_apply2`] are the `dlatrd`-style
//! fused correction GEMVs (`w ← A·v − W·(Vᵀv) − V·(Wᵀv)` traffic) —
//! one pass over the rows instead of one strided pass per panel column.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::OnceLock;

mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;

/// Largest `mr` any kernel uses (the AVX-512 tile).
pub const MAX_MR: usize = 8;
/// Largest `nr` any kernel uses.
pub const MAX_NR: usize = 8;
/// Scratch-tile capacity handed to [`Kernel::run`] (`MAX_MR × MAX_NR`).
pub const MAX_TILE: usize = MAX_MR * MAX_NR;

/// Instruction-set family of a [`Kernel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Avx2,
    Avx512,
}

/// A GEMM micro-kernel: per-kernel register-tile geometry plus the tile
/// routine itself. `MR`/`NR` are **per-kernel** constants — the packing
/// layer in [`super::gemm`] reads them from here instead of from crate
/// globals, so kernels with different tile shapes coexist behind one
/// packing/macro-kernel code path.
pub struct Kernel {
    /// Display / `KFAC_SIMD` name.
    pub name: &'static str,
    /// Which implementation [`Kernel::run`] dispatches to.
    pub isa: Isa,
    /// Micro-tile rows (packing granularity of A panels).
    pub mr: usize,
    /// Micro-tile columns (packing granularity of B panels).
    pub nr: usize,
    /// Rough flop throughput relative to the scalar kernel (f64 lanes ×
    /// FMA). Feeds [`crate::par::chunk_for_flops_at_rate`] so parallel
    /// chunking amortizes dispatch against *kernel* speed, not wall
    /// flops.
    pub rate: usize,
}

impl Kernel {
    /// Compute one full `mr × nr` tile: `acc[r*nr + c] = Σ_p
    /// apanel[p*mr + r] · bpanel[p*nr + c]`, overwriting the first
    /// `mr·nr` entries of `acc`. Panels are zero-padded by the packing
    /// layer, so there are no edge variants here; the macro-kernel's
    /// write-back masks ragged tile edges.
    #[inline]
    pub fn run(&self, kc: usize, apanel: &[f64], bpanel: &[f64], acc: &mut [f64; MAX_TILE]) {
        assert!(
            apanel.len() >= kc * self.mr && bpanel.len() >= kc * self.nr,
            "micro-kernel: panels too small for kc={kc}"
        );
        match self.isa {
            Isa::Scalar => scalar::micro_4x8(kc, apanel, bpanel, acc),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                // The dispatch layer only hands out detected kernels,
                // but `run` is safe and the statics are pub, so the
                // feature check must live here (cached atomics — noise
                // next to a kc-deep tile) for this to be sound on a
                // host that lacks the ISA.
                assert!(avx2_available(), "avx2 micro-kernel on a host without avx2+fma");
                // SAFETY: feature presence asserted above; panel
                // extents asserted at function entry.
                unsafe { avx2::micro_4x8(kc, apanel.as_ptr(), bpanel.as_ptr(), acc.as_mut_ptr()) }
            }
            #[cfg(target_arch = "x86_64")]
            Isa::Avx512 => {
                assert!(avx512_available(), "avx512 micro-kernel on a host without avx512f");
                // SAFETY: as above.
                unsafe { avx512::micro_8x8(kc, apanel.as_ptr(), bpanel.as_ptr(), acc.as_mut_ptr()) }
            }
            #[cfg(not(target_arch = "x86_64"))]
            _ => unreachable!("SIMD kernel selected on a non-x86_64 build"),
        }
    }
}

/// The portable reference kernel (always available).
pub static SCALAR: Kernel = Kernel {
    name: "scalar",
    isa: Isa::Scalar,
    mr: scalar::MR,
    nr: scalar::NR,
    rate: 1,
};

#[cfg(target_arch = "x86_64")]
pub static AVX2: Kernel = Kernel { name: "avx2", isa: Isa::Avx2, mr: 4, nr: 8, rate: 4 };

#[cfg(target_arch = "x86_64")]
pub static AVX512: Kernel = Kernel { name: "avx512", isa: Isa::Avx512, mr: 8, nr: 8, rate: 8 };

// ---------------------------------------------------------------------
// feature detection
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// AVX-512 selection also requires avx2+fma so the fused GEMV helpers
/// (which use AVX2 intrinsics) are safe whenever a SIMD kernel is
/// active. Every avx512f part ships avx2/fma in practice.
#[cfg(target_arch = "x86_64")]
fn avx512_available() -> bool {
    is_x86_feature_detected!("avx512f") && avx2_available()
}

#[cfg(not(target_arch = "x86_64"))]
fn avx512_available() -> bool {
    false
}

// ---------------------------------------------------------------------
// selection
// ---------------------------------------------------------------------

static UNKNOWN_REQUESTS: AtomicUsize = AtomicUsize::new(0);
static UNKNOWN_WARNED: AtomicBool = AtomicBool::new(false);
static UNAVAILABLE_WARNED: AtomicBool = AtomicBool::new(false);

/// Process-wide count of `KFAC_SIMD` values that named no known kernel
/// (each fell back to scalar; the first was warned about on stderr).
pub fn unknown_simd_request_count() -> usize {
    UNKNOWN_REQUESTS.load(Ordering::Relaxed)
}

fn note_unknown(spec: &str) {
    UNKNOWN_REQUESTS.fetch_add(1, Ordering::Relaxed);
    if !UNKNOWN_WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "kfac: unknown KFAC_SIMD value {spec:?} (expected 0|scalar|avx2|avx512|auto); \
             falling back to the scalar kernel (warned once per process)"
        );
    }
}

fn note_unavailable(spec: &str) {
    if !UNAVAILABLE_WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "kfac: KFAC_SIMD={spec} requested but the host CPU does not support it; \
             falling back to the scalar kernel (warned once per process)"
        );
    }
}

fn detect_best() -> &'static Kernel {
    #[cfg(target_arch = "x86_64")]
    {
        if avx512_available() {
            return &AVX512;
        }
        if avx2_available() {
            return &AVX2;
        }
    }
    &SCALAR
}

/// Resolve a `KFAC_SIMD` spec (None = unset) to a kernel. Pure except
/// for the one-time warnings; exposed for the dispatch-layer tests,
/// which exercise forced selection and the unknown-value fallback
/// without racing on process environment.
#[doc(hidden)]
pub fn select(spec: Option<&str>) -> &'static Kernel {
    match spec.map(str::trim) {
        None | Some("") | Some("auto") => detect_best(),
        Some("0") | Some("off") | Some("scalar") | Some("none") => &SCALAR,
        Some(req @ ("avx2" | "avx512")) => {
            let found = available_kernels().into_iter().find(|k| k.name == req);
            match found {
                Some(k) => k,
                None => {
                    note_unavailable(req);
                    &SCALAR
                }
            }
        }
        Some(other) => {
            note_unknown(other);
            &SCALAR
        }
    }
}

/// Every kernel the current host can actually execute (scalar first).
/// Benches iterate this to emit per-kernel GFLOP/s entries.
pub fn available_kernels() -> Vec<&'static Kernel> {
    #[allow(unused_mut)] // non-x86_64 builds never push
    let mut ks: Vec<&'static Kernel> = vec![&SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            ks.push(&AVX2);
        }
        if avx512_available() {
            ks.push(&AVX512);
        }
    }
    ks
}

/// The kernel every dispatched GEMM uses, chosen once per process from
/// `KFAC_SIMD` + CPU feature detection and logged to stderr.
pub fn active() -> &'static Kernel {
    static ACTIVE: OnceLock<&'static Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let spec = std::env::var("KFAC_SIMD").ok();
        let k = select(spec.as_deref());
        eprintln!(
            "kfac: gemm micro-kernel = {} {}x{} (detected: avx2+fma={}, avx512f={}; KFAC_SIMD={})",
            k.name,
            k.mr,
            k.nr,
            avx2_available(),
            avx512_available(),
            spec.as_deref().unwrap_or("unset"),
        );
        k
    })
}

/// True when the active kernel may use AVX2+FMA helper routines (the
/// fused eigensolver GEMVs). Guaranteed consistent with [`active`]:
/// `KFAC_SIMD=0` turns these off too, so a forced-scalar run exercises
/// pure scalar arithmetic end to end.
#[cfg(target_arch = "x86_64")]
fn fused_avx_enabled() -> bool {
    active().isa != Isa::Scalar
}

// ---------------------------------------------------------------------
// fused BLAS-2 helpers (the eigensolver's panel-correction traffic)
// ---------------------------------------------------------------------

/// Fused pair of transposed GEMVs, one pass over the rows:
///
/// ```text
/// aw[i] += Σ_r wa[r·lda + i] · v_r      (Wᵀ v)
/// av[i] += Σ_r xa[r·ldb + i] · v_r      (Vᵀ v)      v_r = vcol[r·vstride]
/// ```
///
/// Both row reads are contiguous, so one traversal of W and V replaces
/// the two strided column passes `dlatrd`'s textbook loop makes. Each
/// accumulator still sums in ascending-`r` order: the scalar path is
/// bit-identical to the unfused loops, the AVX2 path differs only by
/// FMA rounding.
pub fn fused_tdot2(
    rows: usize,
    t: usize,
    vcol: &[f64],
    vstride: usize,
    wa: &[f64],
    lda: usize,
    xa: &[f64],
    ldb: usize,
    aw: &mut [f64],
    av: &mut [f64],
) {
    if rows == 0 || t == 0 {
        return;
    }
    assert!(vcol.len() > (rows - 1) * vstride, "fused_tdot2: v column too small");
    assert!(wa.len() >= (rows - 1) * lda + t, "fused_tdot2: W too small");
    assert!(xa.len() >= (rows - 1) * ldb + t, "fused_tdot2: V too small");
    assert!(aw.len() >= t && av.len() >= t, "fused_tdot2: accumulators too small");
    #[cfg(target_arch = "x86_64")]
    {
        if fused_avx_enabled() {
            // SAFETY: extents asserted above; avx2+fma presence is
            // implied by any non-scalar kernel selection.
            unsafe {
                avx2::fused_tdot2(
                    rows,
                    t,
                    vcol.as_ptr(),
                    vstride,
                    wa.as_ptr(),
                    lda,
                    xa.as_ptr(),
                    ldb,
                    aw.as_mut_ptr(),
                    av.as_mut_ptr(),
                );
            }
            return;
        }
    }
    for r in 0..rows {
        let vr = vcol[r * vstride];
        if vr == 0.0 {
            continue;
        }
        let wrow = &wa[r * lda..r * lda + t];
        let xrow = &xa[r * ldb..r * ldb + t];
        for i in 0..t {
            aw[i] += wrow[i] * vr;
            av[i] += xrow[i] * vr;
        }
    }
}

/// Fused pair of GEMVs applying two rank-`t` corrections in one pass:
///
/// ```text
/// p[r·ps] −= Σ_i xa[r·lda + i]·ca[i] + wa[r·ldb + i]·cb[i]
/// ```
///
/// (the `w ← w − V(Wᵀv) − W(Vᵀv)` half of the dlatrd panel update; also
/// reused to bring a panel column up to date before its reflector).
pub fn fused_apply2(
    rows: usize,
    t: usize,
    xa: &[f64],
    lda: usize,
    wa: &[f64],
    ldb: usize,
    ca: &[f64],
    cb: &[f64],
    p: &mut [f64],
    ps: usize,
) {
    if rows == 0 || t == 0 {
        return;
    }
    assert!(xa.len() >= (rows - 1) * lda + t, "fused_apply2: X too small");
    assert!(wa.len() >= (rows - 1) * ldb + t, "fused_apply2: W too small");
    assert!(ca.len() >= t && cb.len() >= t, "fused_apply2: coefficients too small");
    assert!(p.len() > (rows - 1) * ps, "fused_apply2: output too small");
    #[cfg(target_arch = "x86_64")]
    {
        if fused_avx_enabled() {
            // SAFETY: extents asserted above; avx2+fma presence is
            // implied by any non-scalar kernel selection.
            unsafe {
                avx2::fused_apply2(
                    rows,
                    t,
                    xa.as_ptr(),
                    lda,
                    wa.as_ptr(),
                    ldb,
                    ca.as_ptr(),
                    cb.as_ptr(),
                    p.as_mut_ptr(),
                    ps,
                );
            }
            return;
        }
    }
    for r in 0..rows {
        let xrow = &xa[r * lda..r * lda + t];
        let wrow = &wa[r * ldb..r * ldb + t];
        let mut acc = 0.0;
        for i in 0..t {
            acc += xrow[i] * ca[i] + wrow[i] * cb[i];
        }
        p[r * ps] -= acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn forced_selection_by_name() {
        assert_eq!(select(Some("0")).name, "scalar");
        assert_eq!(select(Some("off")).name, "scalar");
        assert_eq!(select(Some("scalar")).name, "scalar");
        assert_eq!(select(Some(" scalar ")).name, "scalar", "spec is trimmed");
        // avx2/avx512 resolve to themselves when the host has them and
        // to scalar (with a one-time warning) when it does not.
        for req in ["avx2", "avx512"] {
            let k = select(Some(req));
            if available_kernels().iter().any(|a| a.name == req) {
                assert_eq!(k.name, req);
            } else {
                assert_eq!(k.name, "scalar");
            }
        }
    }

    #[test]
    fn auto_selection_matches_detection() {
        let auto = select(None);
        assert_eq!(auto.name, detect_best().name);
        assert_eq!(select(Some("auto")).name, auto.name);
        assert_eq!(select(Some("")).name, auto.name);
        // whatever auto picks must be executable here
        assert!(available_kernels().iter().any(|k| k.name == auto.name));
    }

    #[test]
    fn unknown_value_falls_back_to_scalar_without_panicking() {
        let before = unknown_simd_request_count();
        assert_eq!(select(Some("sse9000")).name, "scalar");
        assert_eq!(select(Some("AVX2")).name, "scalar", "names are case-sensitive");
        assert!(
            unknown_simd_request_count() >= before + 2,
            "unknown KFAC_SIMD requests must be counted"
        );
    }

    #[test]
    fn active_is_cached_and_available() {
        let a = active();
        assert!(std::ptr::eq(a, active()), "dispatch must be decided once");
        assert!(available_kernels().iter().any(|k| std::ptr::eq(*k, a)));
    }

    #[test]
    fn kernels_agree_on_a_full_tile() {
        // Micro-level cross-check: every executable kernel's tile equals
        // the scalar kernel's on identically-packed panels.
        let mut rng = Rng::new(11);
        for kc in [1usize, 2, 7, 37, 256, 300] {
            let apanel = randv(kc * MAX_MR, &mut rng);
            let bpanel = randv(kc * MAX_NR, &mut rng);
            for k in available_kernels() {
                let mut got = [f64::NAN; MAX_TILE];
                k.run(kc, &apanel, &bpanel, &mut got);
                // scalar reference at this kernel's geometry
                let mut want = [0.0f64; MAX_TILE];
                for p in 0..kc {
                    for r in 0..k.mr {
                        for c in 0..k.nr {
                            want[r * k.nr + c] += apanel[p * k.mr + r] * bpanel[p * k.nr + c];
                        }
                    }
                }
                for i in 0..k.mr * k.nr {
                    let err = (got[i] - want[i]).abs();
                    let tol = 1e-12 * (1.0 + want[i].abs());
                    assert!(err < tol, "{} kc={kc} slot {i}: {} vs {}", k.name, got[i], want[i]);
                }
            }
        }
    }

    #[test]
    fn tile_geometries_fit_scratch() {
        for k in available_kernels() {
            assert!(k.mr >= 1 && k.mr <= MAX_MR, "{}", k.name);
            assert!(k.nr >= 1 && k.nr <= MAX_NR, "{}", k.name);
            assert!(k.rate >= 1, "{}", k.name);
        }
    }

    #[test]
    fn fused_tdot2_matches_unfused() {
        let mut rng = Rng::new(12);
        let (rows, t, lda, ldb, vstride) = (67usize, 13usize, 20usize, 15usize, 3usize);
        let wa = randv((rows - 1) * lda + t, &mut rng);
        let xa = randv((rows - 1) * ldb + t, &mut rng);
        let vcol = randv((rows - 1) * vstride + 1, &mut rng);
        let mut aw = vec![0.0; t];
        let mut av = vec![0.0; t];
        fused_tdot2(rows, t, &vcol, vstride, &wa, lda, &xa, ldb, &mut aw, &mut av);
        for i in 0..t {
            let mut w_want = 0.0;
            let mut x_want = 0.0;
            for r in 0..rows {
                w_want += wa[r * lda + i] * vcol[r * vstride];
                x_want += xa[r * ldb + i] * vcol[r * vstride];
            }
            assert!((aw[i] - w_want).abs() < 1e-12 * (1.0 + w_want.abs()), "aw[{i}]");
            assert!((av[i] - x_want).abs() < 1e-12 * (1.0 + x_want.abs()), "av[{i}]");
        }
    }

    #[test]
    fn fused_apply2_matches_unfused() {
        let mut rng = Rng::new(13);
        let (rows, t, lda, ldb, ps) = (53usize, 9usize, 11usize, 17usize, 2usize);
        let xa = randv((rows - 1) * lda + t, &mut rng);
        let wa = randv((rows - 1) * ldb + t, &mut rng);
        let ca = randv(t, &mut rng);
        let cb = randv(t, &mut rng);
        let init = randv((rows - 1) * ps + 1, &mut rng);
        let mut p = init.clone();
        fused_apply2(rows, t, &xa, lda, &wa, ldb, &ca, &cb, &mut p, ps);
        for r in 0..rows {
            let mut acc = 0.0;
            for i in 0..t {
                acc += xa[r * lda + i] * ca[i] + wa[r * ldb + i] * cb[i];
            }
            let want = init[r * ps] - acc;
            assert!((p[r * ps] - want).abs() < 1e-12 * (1.0 + want.abs()), "row {r}");
        }
        // untouched lanes between strided outputs
        for r in 0..rows - 1 {
            assert_eq!(p[r * ps + 1], init[r * ps + 1], "stride gap clobbered at {r}");
        }
    }

    #[test]
    fn fused_helpers_handle_degenerate_extents() {
        let mut aw = [0.0f64; 2];
        let mut av = [0.0f64; 2];
        fused_tdot2(0, 2, &[], 1, &[], 2, &[], 2, &mut aw, &mut av);
        fused_tdot2(5, 0, &[0.0; 5], 1, &[], 0, &[], 0, &mut aw, &mut av);
        assert_eq!(aw, [0.0; 2]);
        let mut p = [3.0f64];
        fused_apply2(0, 3, &[], 3, &[], 3, &[0.0; 3], &[0.0; 3], &mut p, 1);
        fused_apply2(1, 0, &[], 0, &[], 0, &[], &[], &mut p, 1);
        assert_eq!(p, [3.0]);
    }
}
