"""AOT lowering: JAX/Pallas programs -> HLO **text** artifacts.

For every model in ``model.REGISTRY`` and every program in
``model.program_specs``, jit-lower to StableHLO, convert to an
XlaComputation with ``return_tuple=True``, and dump the HLO text to
``artifacts/<arch>/<program>.hlo.txt``; finally write
``artifacts/manifest.json`` describing shapes/losses/chunks for the
Rust runtime.

HLO *text* (not serialized ``HloModuleProto``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
pinned xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(md: model.ModelDef, out_dir: str) -> dict:
    """Lower all programs for one model; returns the manifest entry."""
    arch_dir = os.path.join(out_dir, md.name)
    os.makedirs(arch_dir, exist_ok=True)
    programs = {}
    for prog_name, (fn, specs) in model.program_specs(md).items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        rel = f"{md.name}/{prog_name}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        programs[prog_name] = rel
        print(f"  {rel}: {len(text) // 1024} KiB", flush=True)
    return md.manifest_entry(programs)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated arch names (default: all)",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for md in model.REGISTRY:
        if only is not None and md.name not in only:
            continue
        print(f"lowering {md.name} (widths={list(md.widths)})", flush=True)
        entries.append(lower_model(md, args.out))

    manifest = {"version": 1, "archs": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")
    return None


if __name__ == "__main__":
    sys.exit(main())
