//! GEMM property tests: every transpose variant of the packed blocked
//! kernel must agree with a trivially-correct triple-loop reference on
//! ~50 seeded random shapes — including degenerate (m=1, k=1, n=1) and
//! ragged shapes that are not multiples of the MR/NR/MC/KC/NC tile
//! sizes — to 1e-12 *relative Frobenius* error. The same shape battery
//! also pins the runtime-dispatched SIMD micro-kernels against the
//! scalar reference kernel at ≤1e-13 relative Frobenius (the
//! scalar/SIMD equivalence claim CI exercises under `KFAC_SIMD=0` and
//! default dispatch).

use kfac::linalg::{gemm, simd, Mat};
use kfac::rng::Rng;

/// Triple-loop ijp reference GEMM.
fn reference_matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0;
            for p in 0..a.cols {
                s += a.at(i, p) * b.at(p, j);
            }
            c.set(i, j, s);
        }
    }
    c
}

/// ‖got − want‖_F / ‖want‖_F.
fn rel_frob(got: &Mat, want: &Mat) -> f64 {
    got.sub(want).frob_norm() / want.frob_norm().max(1e-300)
}

/// The shape set: fixed edge/tile-boundary cases plus seeded random
/// draws, ~50 total. Random dims reach past the 4×8 micro-tile, the
/// 128-row block and (via the fixed entries) the 256-deep block.
fn shapes() -> Vec<(usize, usize, usize)> {
    let mut shapes = vec![
        // degenerate extents
        (1, 1, 1),
        (1, 1, 17),
        (1, 17, 1),
        (17, 1, 1),
        (1, 40, 64),
        (64, 40, 1),
        (40, 1, 64),
        // micro-tile boundaries (MR = 4, NR = 8)
        (3, 5, 7),
        (4, 5, 8),
        (5, 5, 9),
        (8, 8, 16),
        // block boundaries (MC = 128, KC = 256) and ragged neighbours
        (127, 63, 65),
        (128, 64, 64),
        (129, 65, 63),
        (96, 256, 40),
        (96, 257, 40),
        (130, 300, 66),
        // edge tiles + K-tails for the widest (8×8) micro-kernel:
        // one-past / one-short of the tile on each axis, odd K
        (8, 8, 8),
        (9, 7, 9),
        (7, 9, 9),
        (16, 17, 15),
        (17, 15, 16),
        // K-FAC-shaped: batch × (layer+1) covariance and forward passes
        (257, 200, 257),
        (300, 101, 41),
    ];
    let mut rng = Rng::new(0xC0FFEE);
    while shapes.len() < 50 {
        shapes.push((1 + rng.below(140), 1 + rng.below(140), 1 + rng.below(140)));
    }
    shapes
}

#[test]
fn matmul_matches_reference_on_many_shapes() {
    let mut rng = Rng::new(1);
    for (idx, &(m, k, n)) in shapes().iter().enumerate() {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let want = reference_matmul(&a, &b);
        let err = rel_frob(&a.matmul(&b), &want);
        assert!(err < 1e-12, "shape #{idx} ({m},{k},{n}): rel frob {err}");
    }
}

#[test]
fn matmul_tn_matches_reference_on_many_shapes() {
    let mut rng = Rng::new(2);
    for (idx, &(m, k, n)) in shapes().iter().enumerate() {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let want = reference_matmul(&a, &b);
        // at is k×m, so atᵀ b = a b
        let at = a.transpose();
        let err = rel_frob(&at.matmul_tn(&b), &want);
        assert!(err < 1e-12, "shape #{idx} ({m},{k},{n}): rel frob {err}");
    }
}

#[test]
fn matmul_nt_matches_reference_on_many_shapes() {
    let mut rng = Rng::new(3);
    for (idx, &(m, k, n)) in shapes().iter().enumerate() {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let want = reference_matmul(&a, &b);
        // bt is n×k, so a btᵀ = a b
        let bt = b.transpose();
        let err = rel_frob(&a.matmul_nt(&bt), &want);
        assert!(err < 1e-12, "shape #{idx} ({m},{k},{n}): rel frob {err}");
    }
}

#[test]
fn matvec_matches_reference_on_many_shapes() {
    let mut rng = Rng::new(4);
    for (idx, &(m, k, _)) in shapes().iter().enumerate() {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let v: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let want = reference_matmul(&a, &Mat::from_vec(k, 1, v.clone()));
        let got = Mat::from_vec(m, 1, a.matvec(&v));
        let err = rel_frob(&got, &want);
        assert!(err < 1e-12, "shape #{idx} ({m},{k}): rel frob {err}");
    }
}

#[test]
fn variants_agree_with_each_other() {
    // A ᵀ-consistency triangle on one blocked-path shape: NN, TN and NT
    // must produce bitwise-comparable results within summation roundoff.
    let mut rng = Rng::new(5);
    let (m, k, n) = (150, 270, 90);
    let a = Mat::randn(m, k, 1.0, &mut rng);
    let b = Mat::randn(k, n, 1.0, &mut rng);
    let nn = a.matmul(&b);
    let tn = a.transpose().matmul_tn(&b);
    let nt = a.matmul_nt(&b.transpose());
    assert!(rel_frob(&tn, &nn) < 1e-13);
    assert!(rel_frob(&nt, &nn) < 1e-13);
}

#[test]
fn every_kernel_agrees_with_scalar_on_many_shapes() {
    // Scalar-vs-SIMD equivalence on the full 50-shape battery: each
    // kernel the host can execute runs the packed blocked path (forced,
    // so the small-shape cutoff cannot hide tile-edge handling) and
    // must match the scalar kernel to ≤1e-13 relative Frobenius. This
    // covers masked remainder lanes (m, n not multiples of MR/NR) and
    // K-tail shapes where the packing zero-pad is load-bearing.
    let mut rng = Rng::new(7);
    let scalar = &simd::SCALAR;
    for (idx, &(m, k, n)) in shapes().iter().enumerate() {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let mut want = Mat::zeros(m, n);
        gemm::gemm_blocked_with(scalar, m, n, k, &a.data, k, 1, &b.data, n, 1, &mut want.data);
        for kern in simd::available_kernels() {
            let mut got = Mat::zeros(m, n);
            gemm::gemm_blocked_with(kern, m, n, k, &a.data, k, 1, &b.data, n, 1, &mut got.data);
            let err = rel_frob(&got, &want);
            assert!(
                err < 1e-13,
                "kernel {} shape #{idx} ({m},{k},{n}): rel frob vs scalar {err}"
            );
        }
    }
}

#[test]
fn dispatched_matmul_agrees_with_forced_scalar() {
    // Whatever kernel the process-wide dispatch picked (KFAC_SIMD or
    // auto-detection — the CI matrix runs this under both), the public
    // Mat::matmul path must agree with the forced scalar kernel.
    let mut rng = Rng::new(8);
    for &(m, k, n) in &[(257usize, 200usize, 257usize), (130, 300, 66), (96, 257, 40)] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let got = a.matmul(&b);
        let mut want = Mat::zeros(m, n);
        gemm::gemm_blocked_with(
            &simd::SCALAR,
            m,
            n,
            k,
            &a.data,
            k,
            1,
            &b.data,
            n,
            1,
            &mut want.data,
        );
        let err = rel_frob(&got, &want);
        assert!(err < 1e-13, "({m},{k},{n}): dispatched vs scalar rel frob {err}");
    }
}

#[test]
fn zero_and_identity_special_cases() {
    let mut rng = Rng::new(6);
    let a = Mat::randn(140, 260, 1.0, &mut rng);
    // A · I = A (blocked path: 2·140·260·260 flops)
    let id = Mat::eye(260);
    assert!(rel_frob(&a.matmul(&id), &a) < 1e-15);
    // A · 0 = 0
    let z = Mat::zeros(260, 64);
    assert_eq!(a.matmul(&z).max_abs(), 0.0);
}

#[test]
fn pack_seam_is_bit_identical_to_direct_indexing_on_many_shapes() {
    // The packing layer is the seam the blocked GEMM and im2col share
    // (`linalg::pack`). Packing is pure data movement, so routing it
    // through the `PackSource` trait must reproduce the pre-seam direct
    // slice indexing *bitwise* on the whole shape battery — for the
    // normal, transposed (matmul_tn view: rs/cs swapped) and offset
    // block geometries the GEMM drives it with.
    use kfac::linalg::pack::{self, Strided};
    let (mr, nr) = (4usize, 8usize);
    let mut rng = Rng::new(7);
    for (idx, &(m, k, n)) in shapes().iter().enumerate() {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        // offset sub-block on the larger shapes, full block otherwise
        let (row0, p0, col0) = (m / 3, k / 3, n / 3);
        let (mc, kc, nc) = (m - row0, k - p0, n - col0);
        let panels_b = nc.div_ceil(nr);
        for (rs, cs, src_rows) in [(a.cols, 1, m), (1, a.cols, k)] {
            // (rs, cs) = (cols, 1) is the plain row-major view; (1, cols)
            // is the transposed view matmul_tn packs through. With the
            // transposed view the roles of m and k swap, so clamp the
            // block to the view's extents.
            let (mc_v, kc_v) = if src_rows == m { (mc, kc) } else { (kc, mc) };
            let (row0_v, p0_v) = if src_rows == m { (row0, p0) } else { (p0, row0) };
            let panels_v = mc_v.div_ceil(mr);
            let mut got = vec![f64::NAN; panels_v * kc_v * mr];
            let src = Strided::new(&a.data, rs, cs);
            pack::pack_a(&mut got, mr, &src, row0_v, mc_v, p0_v, kc_v);
            // pre-seam reference: direct slice indexing, same layout
            let mut want = vec![f64::NAN; panels_v * kc_v * mr];
            for ip in 0..panels_v {
                let r0 = ip * mr;
                let rows = mr.min(mc_v - r0);
                for p in 0..kc_v {
                    for r in 0..mr {
                        let slot = ip * kc_v * mr + p * mr + r;
                        want[slot] = if r < rows {
                            a.data[(row0_v + r0 + r) * rs + (p0_v + p) * cs]
                        } else {
                            0.0
                        };
                    }
                }
            }
            for (s, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "shape {idx} A-pack slot {s}");
            }
        }
        let mut got = vec![f64::NAN; panels_b * kc * nr];
        let src = Strided::new(&b.data, b.cols, 1);
        pack::pack_b(&mut got, nr, &src, p0, kc, col0, nc);
        let mut want = vec![f64::NAN; panels_b * kc * nr];
        for jp in 0..panels_b {
            let c0 = jp * nr;
            let cols = nr.min(nc - c0);
            for p in 0..kc {
                for c in 0..nr {
                    let slot = jp * kc * nr + p * nr + c;
                    want[slot] = if c < cols {
                        b.data[(p0 + p) * b.cols + (col0 + c0 + c)]
                    } else {
                        0.0
                    };
                }
            }
        }
        for (s, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "shape {idx} B-pack slot {s}");
        }
    }
}
