//! AVX-512F micro-kernel (x86_64 only; `cfg`-gated with the rest of the
//! x86 tree and selected only after `is_x86_feature_detected!`).
//!
//! The tile is 8×8: one full-width `__m512d` holds the eight B columns,
//! each A row broadcasts into a zmm, and the eight row accumulators use
//! a quarter of the thirty-two zmm registers — leaving the scheduler
//! room to hide the broadcast latency across the unrolled row updates.

use std::arch::x86_64::*;

/// `acc[r*8 + c] = Σ_p apanel[p*8 + r] · bpanel[p*8 + c]`, overwriting
/// the 8×8 tile. Panels are the zero-padded packed layout of
/// `linalg::gemm` with this kernel's MR = NR = 8.
///
/// # Safety
/// Requires avx512f at runtime; `apanel`/`bpanel` must be readable for
/// `kc*8` f64 each and `acc` writable for 64 f64.
#[target_feature(enable = "avx512f")]
pub(crate) unsafe fn micro_8x8(kc: usize, apanel: *const f64, bpanel: *const f64, acc: *mut f64) {
    // SAFETY: the caller guarantees the extents above (both panels
    // advance 8 f64 per k-step, so after kc steps every read stays
    // inside `kc*8`), and `acc` holds the full 64-f64 tile the eight
    // stores cover.
    unsafe {
        let mut c0 = _mm512_setzero_pd();
        let mut c1 = _mm512_setzero_pd();
        let mut c2 = _mm512_setzero_pd();
        let mut c3 = _mm512_setzero_pd();
        let mut c4 = _mm512_setzero_pd();
        let mut c5 = _mm512_setzero_pd();
        let mut c6 = _mm512_setzero_pd();
        let mut c7 = _mm512_setzero_pd();
        let mut ap = apanel;
        let mut bp = bpanel;
        for _ in 0..kc {
            let b = _mm512_loadu_pd(bp);
            c0 = _mm512_fmadd_pd(_mm512_set1_pd(*ap), b, c0);
            c1 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(1)), b, c1);
            c2 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(2)), b, c2);
            c3 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(3)), b, c3);
            c4 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(4)), b, c4);
            c5 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(5)), b, c5);
            c6 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(6)), b, c6);
            c7 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(7)), b, c7);
            ap = ap.add(8);
            bp = bp.add(8);
        }
        _mm512_storeu_pd(acc, c0);
        _mm512_storeu_pd(acc.add(8), c1);
        _mm512_storeu_pd(acc.add(16), c2);
        _mm512_storeu_pd(acc.add(24), c3);
        _mm512_storeu_pd(acc.add(32), c4);
        _mm512_storeu_pd(acc.add(40), c5);
        _mm512_storeu_pd(acc.add(48), c6);
        _mm512_storeu_pd(acc.add(56), c7);
    }
}
