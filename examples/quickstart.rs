//! Quickstart: train a small deep autoencoder with K-FAC in ~30 lines.
//!
//!     cargo run --release --example quickstart

use kfac::prelude::*;

fn main() {
    // 1. Data: synthetic 16×16 digit images, autoencoding targets.
    let ds = kfac::data::mnist_like::autoencoder_dataset(1000, 16, 0);

    // 2. Model: 256-64-16-64-256 tanh autoencoder with sigmoid-CE output.
    let arch = Arch::autoencoder(&[256, 64, 16, 64, 256], Act::Tanh);
    let mut backend = RustBackend::new(arch.clone());
    let mut params = arch.sparse_init(&mut Rng::new(1));

    // 3. Optimizer: K-FAC with the paper's defaults (block-tridiagonal
    //    inverse, momentum, adaptive λ/γ damping). λ₀ scaled to the
    //    short run.
    let mut opt = Kfac::new(&arch, KfacConfig { lambda0: 5.0, ..Default::default() });

    // 4. Train.
    let mut rng = Rng::new(2);
    for k in 1..=60 {
        let (x, y) = ds.minibatch(500, &mut rng);
        let info = opt.step(&mut backend, &mut params, &x, &y);
        if k % 10 == 0 || k == 1 {
            println!(
                "iter {k:>3}  loss {:.4}  |δ| {:.3e}  λ {:.2}  γ {:.3}",
                info.loss, info.delta_norm, info.lambda, info.gamma
            );
        }
    }

    // 5. Evaluate reconstruction error.
    let (loss, err) = backend.eval(&params, &ds.x, &ds.y);
    println!("final: train loss {loss:.4}, reconstruction error {err:.4}");
}
