//! Failure-injection / edge-case robustness of the optimizer stack:
//! degenerate batches, converged gradients, extreme damping, and
//! pathological statistics must not produce NaNs or panics.

use kfac::backend::{ModelBackend, RustBackend};
use kfac::fisher::stats::RawStats;
use kfac::fisher::{BlockDiagInverse, FisherInverse, TridiagInverse};
use kfac::linalg::Mat;
use kfac::nn::{Act, Arch, LossKind, Params};
use kfac::optim::{Kfac, KfacConfig, Optimizer};
use kfac::rng::Rng;

fn tiny() -> (Arch, Params, Mat, Mat) {
    let arch = Arch::new(vec![4, 3, 2], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
    let mut rng = Rng::new(1);
    let p = arch.glorot_init(&mut rng);
    let x = Mat::randn(8, 4, 1.0, &mut rng);
    let mut y = Mat::zeros(8, 2);
    for r in 0..8 {
        y.set(r, r % 2, 1.0);
    }
    (arch, p, x, y)
}

#[test]
fn single_case_minibatch_does_not_panic() {
    let (arch, mut p, x, y) = tiny();
    let mut be = RustBackend::new(arch.clone());
    let mut opt = Kfac::new(&arch, KfacConfig::default());
    let x1 = x.top_rows(1);
    let y1 = y.top_rows(1);
    for _ in 0..5 {
        let info = opt.step(&mut be, &mut p, &x1, &y1);
        assert!(info.loss.is_finite());
        assert!(info.delta_norm.unwrap().is_finite());
    }
}

#[test]
fn near_zero_gradient_produces_near_zero_update() {
    // At a (near-)optimum the α* solve must not blow up: δ → 0.
    let (arch, mut p, x, y) = tiny();
    let mut be = RustBackend::new(arch.clone());
    let mut opt = Kfac::new(&arch, KfacConfig { lambda0: 1.0, ..Default::default() });
    // drive close to optimum first
    for _ in 0..60 {
        opt.step(&mut be, &mut p, &x, &y);
    }
    let info = opt.step(&mut be, &mut p, &x, &y);
    let dn = info.delta_norm.unwrap();
    assert!(dn.is_finite());
    assert!(dn < 10.0, "update exploded near optimum: {dn}");
}

#[test]
fn extreme_damping_values_are_stable() {
    let (arch, p, x, y) = tiny();
    let mut be = RustBackend::new(arch.clone());
    for lambda0 in [1e-8, 1e8] {
        let mut params = p.clone();
        let mut opt = Kfac::new(&arch, KfacConfig { lambda0, ..Default::default() });
        let info = opt.step(&mut be, &mut params, &x, &y);
        assert!(info.loss.is_finite(), "λ0={lambda0}");
        assert!(info.delta_norm.unwrap().is_finite(), "λ0={lambda0}");
        for w in &params.0 {
            assert!(w.data.iter().all(|v| v.is_finite()), "λ0={lambda0}");
        }
    }
}

#[test]
fn rank_deficient_statistics_are_jitter_recovered() {
    // Constant activities (zero variance apart from the bias) make Ā
    // rank-deficient; the jittered Cholesky must still produce finite
    // inverses for both structures.
    let arch = Arch::new(vec![3, 2, 2], vec![Act::Tanh, Act::Identity], LossKind::SquaredError);
    let mut st = RawStats::zeros(&arch);
    // Ā = ones outer product (rank 1), G = rank-1 too
    for aa in st.aa.iter_mut() {
        *aa = Mat::filled(aa.rows, aa.cols, 1.0);
    }
    for gg in st.gg.iter_mut() {
        *gg = Mat::filled(gg.rows, gg.cols, 0.5);
    }
    let mut rng = Rng::new(3);
    let g = Params(vec![Mat::randn(2, 4, 1.0, &mut rng), Mat::randn(2, 3, 1.0, &mut rng)]);
    for gamma in [0.0, 1e-6, 1.0] {
        let bd = BlockDiagInverse::build(&st, gamma);
        let u = bd.apply(&g);
        assert!(u.0.iter().all(|m| m.data.iter().all(|v| v.is_finite())), "γ={gamma}");
        let tri = TridiagInverse::build(&st, gamma);
        let u = tri.apply(&g);
        assert!(u.0.iter().all(|m| m.data.iter().all(|v| v.is_finite())), "γ={gamma}");
    }
}

#[test]
fn momentum_with_identical_directions_falls_back() {
    // If δ0 is exactly parallel to Δ the 2×2 system is singular; the
    // solver must fall back to the 1-D solution rather than NaN.
    let q = Mat::from_vec(2, 2, vec![2.0, 2.0, 2.0, 2.0]);
    // (access through a full step is awkward; test the behaviour
    // indirectly by stepping twice on a quadratic-like problem)
    let _ = q;
    let (arch, mut p, x, y) = tiny();
    let mut be = RustBackend::new(arch.clone());
    let mut opt = Kfac::new(&arch, KfacConfig { t3: 1000, ..Default::default() });
    // two identical steps in a row make Δ and δ0 nearly parallel
    for _ in 0..4 {
        let info = opt.step(&mut be, &mut p, &x, &y);
        assert!(info.alpha.unwrap().is_finite() && info.mu.unwrap().is_finite());
    }
}

#[test]
fn wildly_scaled_inputs_do_not_break_training() {
    let arch = Arch::new(vec![4, 3, 2], vec![Act::Tanh, Act::Identity], LossKind::SquaredError);
    let mut rng = Rng::new(5);
    let mut p = arch.glorot_init(&mut rng);
    let x = Mat::randn(16, 4, 1.0, &mut rng).scale(1e4);
    let y = Mat::randn(16, 2, 1.0, &mut rng).scale(1e-4);
    let mut be = RustBackend::new(arch.clone());
    let l0 = be.loss(&p, &x, &y);
    let mut opt = Kfac::new(&arch, KfacConfig::default());
    for _ in 0..10 {
        let info = opt.step(&mut be, &mut p, &x, &y);
        assert!(info.loss.is_finite());
    }
    assert!(be.loss(&p, &x, &y) <= l0 * 1.001);
}
