//! Figure 2 — quality of the block-wise Kronecker-factored
//! approximation F̃ of the exact Fisher F, for the middle 4 layers of a
//! 256-20-20-20-20-10 tanh classifier on 16×16 digit images, partially
//! trained with K-FAC (the paper's exact setup, on our synthetic
//! digits). Also reproduces the Section-3.1 cumulant analysis: the
//! total approximation error vs the eqn-4 upper bound built from 3rd-
//! and 4th-order cumulants (paper reports 2894.4 vs 4134.6 — same order
//! and bound ≥ error is the reproduction target).
//!
//! Output: block-norm maps + scalars; CSV in results/fig2_blocks.csv.

use kfac::coordinator::Problem;
use kfac::experiments::{partially_train, results_dir, scaled};
use kfac::fisher::exact::ExactBlocks;
use kfac::linalg::Mat;
use kfac::util::write_csv;

fn main() {
    println!("== Figure 2: exact F vs Kronecker-factored F̃ (middle 4 layers) ==");
    let iters = 8; // paper: 7 iterations of batch K-FAC -> ~5% error
    let n = scaled(600, 200);
    println!("# partially training 256-20-20-20-20-10 ({iters} batch iterations, n={n})…");
    let (backend, params, ds) = partially_train(Problem::MnistClf, n, iters, 0);
    let (loss, err) = {
        let net = backend.net();
        let fwd = net.forward(&params, &ds.x);
        (net.arch.loss.loss(fwd.z(), &ds.y), net.arch.loss.error(fwd.z(), &ds.y))
    };
    println!(
        "# after partial training: loss {loss:.4}, classification error {:.1}%",
        err * 100.0
    );

    let m_eval = scaled(300, 100).min(ds.len());
    let x = ds.x.top_rows(m_eval);
    println!("# computing exact F / F̃ over layers 2..5 on {m_eval} cases…");
    let eb = ExactBlocks::compute(backend.net(), &params, &x, 1, 5);
    let f = &eb.f;
    let ktilde = eb.ktilde_dense();
    let diff = f.sub(&ktilde);

    println!(
        "\nfrobenius norms:  ‖F‖ = {:.4}   ‖F̃‖ = {:.4}   ‖F−F̃‖ = {:.4}   rel = {:.4}",
        f.frob_norm(),
        ktilde.frob_norm(),
        diff.frob_norm(),
        diff.frob_norm() / f.frob_norm()
    );

    let map_f = eb.block_avg_abs(f);
    let map_kt = eb.block_avg_abs(&ktilde);
    let map_d = eb.block_avg_abs(&diff);
    let print_map = |name: &str, m: &Mat| {
        println!("\n{name} (block-average |entries|):");
        for r in 0..m.rows {
            print!("  ");
            for c in 0..m.cols {
                print!(" {:>10.3e}", m.at(r, c));
            }
            println!();
        }
    };
    print_map("exact F", &map_f);
    print_map("approx F̃", &map_kt);
    print_map("|F − F̃|", &map_d);

    // --- Section 3.1: total error vs cumulant upper bound ------------
    // err(pair) = E[ā1ā2 g1g2] − E[ā1ā2]E[g1g2]  (entry of F − F̃)
    //           = κ4 + E[ā1]κ3(ā2,g1,g2) + E[ā2]κ3(ā1,g1,g2)   (eqn 3)
    // bound     = |κ4| + |E[ā1]||κ3(ā2,…)| + |E[ā2]||κ3(ā1,…)|  (eqn 4)
    // κ3(ā,g1,g2) = E[ā g1g2] − E[ā]E[g1g2]  (E[g]=0 by Lemma 4).
    // The third moments E[ā ⊗ g gᵀ] are computed exactly per case from
    // the conditional second moment E[g_i g_jᵀ | x] = J_iᵀ F_R J_j.
    println!("\n# computing cumulant decomposition (Section 3.1)…");
    let net = backend.net();
    let d_out = *net.arch.widths.last().unwrap();
    let (lo, hi) = (eb.lo, eb.hi);
    let nb = hi - lo;
    let mut mean_a: Vec<Vec<f64>> =
        (0..nb).map(|i| vec![0.0; net.arch.widths[lo + i] + 1]).collect();
    // t_left[i][j][ka][p][q] = E[ā_i[ka] g_i[p] g_j[q]]; t_right with ā_j.
    let mut t_left: Vec<Vec<Vec<f64>>> = Vec::new();
    let mut t_right: Vec<Vec<Vec<f64>>> = Vec::new();
    for i in 0..nb {
        let (mut row_l, mut row_r) = (Vec::new(), Vec::new());
        for j in 0..nb {
            let gi = net.arch.widths[lo + i + 1];
            let gj = net.arch.widths[lo + j + 1];
            row_l.push(vec![0.0; (net.arch.widths[lo + i] + 1) * gi * gj]);
            row_r.push(vec![0.0; (net.arch.widths[lo + j] + 1) * gi * gj]);
        }
        t_left.push(row_l);
        t_right.push(row_r);
    }
    let m = x.rows;
    let inv_m = 1.0 / m as f64;
    for r in 0..m {
        let xrep = Mat::from_fn(d_out, x.cols, |_, c| x.at(r, c));
        let fwd = net.forward(&params, &xrep);
        let js = net.backward(&params, &fwd, &Mat::eye(d_out));
        let fr = net.arch.loss.fr_matrix(fwd.z().row(0));
        for i in 0..nb {
            for (k, &v) in fwd.abars[lo + i].row(0).iter().enumerate() {
                mean_a[i][k] += inv_m * v;
            }
        }
        for i in 0..nb {
            let abar_i = fwd.abars[lo + i].row(0).to_vec();
            for j in 0..nb {
                let abar_j = fwd.abars[lo + j].row(0).to_vec();
                let frj = fr.matmul(&js[lo + j]);
                let egg = js[lo + i].matmul_tn(&frj); // E[g_i g_jᵀ | x]
                let (gi, gj) = (egg.rows, egg.cols);
                let tl = &mut t_left[i][j];
                for (ka, &av) in abar_i.iter().enumerate() {
                    let base = ka * gi * gj;
                    for p in 0..gi {
                        for q in 0..gj {
                            tl[base + p * gj + q] += inv_m * av * egg.at(p, q);
                        }
                    }
                }
                let tr = &mut t_right[i][j];
                for (kb, &av) in abar_j.iter().enumerate() {
                    let base = kb * gi * gj;
                    for p in 0..gi {
                        for q in 0..gj {
                            tr[base + p * gj + q] += inv_m * av * egg.at(p, q);
                        }
                    }
                }
            }
        }
    }
    let (mut total_err, mut total_bound) = (0.0, 0.0);
    for i in 0..nb {
        for j in 0..nb {
            let da_i = net.arch.widths[lo + i] + 1;
            let da_j = net.arch.widths[lo + j] + 1;
            let gi = net.arch.widths[lo + i + 1];
            let gj = net.arch.widths[lo + j + 1];
            let egg = &eb.gg[i][j];
            for ka in 0..da_i {
                for kb in 0..da_j {
                    let eaa = eb.aa[i][j].at(ka, kb);
                    for p in 0..gi {
                        for q in 0..gj {
                            // dense F uses column-stacked vec: index
                            // (col ka, row p) -> ka*gi + p.
                            let row = eb.offs[i] + ka * gi + p;
                            let col = eb.offs[j] + kb * gj + q;
                            let e4 = f.at(row, col); // E[ā1ā2 g1g2]
                            let err_pair = e4 - eaa * egg.at(p, q);
                            let k3_right = t_right[i][j][kb * gi * gj + p * gj + q]
                                - mean_a[j][kb] * egg.at(p, q);
                            let k3_left = t_left[i][j][ka * gi * gj + p * gj + q]
                                - mean_a[i][ka] * egg.at(p, q);
                            let k4 =
                                err_pair - mean_a[i][ka] * k3_right - mean_a[j][kb] * k3_left;
                            total_err += err_pair.abs();
                            total_bound += k4.abs()
                                + mean_a[i][ka].abs() * k3_right.abs()
                                + mean_a[j][kb].abs() * k3_left.abs();
                        }
                    }
                }
            }
        }
    }
    println!("\nSection 3.1 cumulant analysis over all middle-layer weight pairs:");
    println!("  total |approximation error| = {total_err:.1}   (paper: 2894.4)");
    println!("  eqn-4 cumulant upper bound  = {total_bound:.1}   (paper: 4134.6)");
    println!(
        "  bound/error ratio           = {:.2}   (paper: 1.43)",
        total_bound / total_err
    );
    assert!(total_bound >= total_err * 0.999, "bound must dominate the error");

    let mut rows = Vec::new();
    for r in 0..map_f.rows {
        for c in 0..map_f.cols {
            rows.push(vec![r as f64, c as f64, map_f.at(r, c), map_kt.at(r, c), map_d.at(r, c)]);
        }
    }
    let path = results_dir().join("fig2_blocks.csv");
    write_csv(&path, &["block_i", "block_j", "exact_F", "ktilde", "abs_diff"], &rows).unwrap();
    println!("\nwrote {}", path.display());
}
