//! Minimal data parallelism (a tiny rayon substitute).
//!
//! The K-FAC hot paths that benefit from threads on the Rust side are the
//! dense matmuls in `linalg` (layer-sized GEMMs, covariance updates,
//! preconditioner application). We split the output row range into one
//! contiguous chunk per worker and execute the chunks on a **persistent
//! worker pool** (`num_threads() − 1` long-lived threads plus the
//! caller), so the many mid-sized GEMMs in a K-FAC step do not pay a
//! thread spawn each. While a caller waits for its chunks it *helps* by
//! draining the shared queue, which makes nested parallel calls (e.g. a
//! GEMM inside a per-layer `par_map_send`) deadlock-free; when the queue
//! is empty it parks on the dispatch latch's condvar (bounded wait)
//! rather than busy-spinning a core until the last worker finishes.
//!
//! Set `KFAC_POOL=0` to fall back to the original per-call
//! `std::thread::scope` path, and `KFAC_THREADS=1` to run everything
//! inline on the caller.
//!
//! ## Verification
//!
//! Every synchronization primitive in this file goes through the [`sync`]
//! shim: `std::sync` types normally, `loom::sync` types when compiled
//! with `RUSTFLAGS="--cfg loom"`. The `verify/loom` crate includes this
//! exact source via `#[path]` and model-checks the pool/latch/job-handle
//! protocols (and the epoch-swap [`PendingJob`] seam the async inverse
//! refresh runs) across *all* interleavings loom can reach — see
//! `verify/loom/tests/loom_pool.rs` and the README "Verification
//! matrix". The [`model`] module below is the loom-only test surface.

use std::collections::VecDeque;

/// Synchronization shim: the one place this module names its sync
/// primitives. Production builds use `std::sync`; under `--cfg loom` the
/// same code is model-checked on `loom::sync` replacements. Correctness
/// therefore cannot silently depend on anything loom does not model.
#[cfg(not(loom))]
mod sync {
    pub(super) use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    pub(super) use std::sync::{Arc, Condvar, Mutex, MutexGuard};

    /// Bounded condvar wait (≤500µs). Callers treat this as "maybe
    /// sleep, maybe spurious wake": every wait site re-checks its
    /// predicate and re-drains the queue, so deadlock freedom never
    /// depends on the matching notify being delivered.
    pub(super) fn bounded_wait<T>(cv: &Condvar, guard: MutexGuard<'_, T>) {
        let _unused =
            cv.wait_timeout(guard, std::time::Duration::from_micros(500)).unwrap();
    }
}

/// Loom replacement for the [`sync`] shim (`--cfg loom` builds only).
#[cfg(loom)]
mod sync {
    pub(super) use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    pub(super) use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

    /// Loom models the bounded park as an immediate spurious wakeup:
    /// drop the lock and yield. This is the *weakest* reading of
    /// `Condvar::wait_timeout` (the timeout always fires first), so any
    /// schedule loom passes holds a fortiori when real waits block until
    /// notified or 500µs elapse.
    pub(super) fn bounded_wait<T>(_cv: &Condvar, guard: MutexGuard<'_, T>) {
        drop(guard);
        loom::thread::yield_now();
    }
}

use sync::{bounded_wait, Arc, AtomicBool, AtomicUsize, Condvar, Mutex, Ordering};

/// Number of worker threads to use (cores − 1, at least 1), overridable
/// with the `KFAC_THREADS` environment variable.
pub fn num_threads() -> usize {
    // Deliberately a std atomic even under loom: a process-wide cache of
    // an env lookup, not part of any modeled protocol (loom atomics
    // cannot live in statics).
    static CACHED: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let c = CACHED.load(std::sync::atomic::Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("KFAC_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get().saturating_sub(1).max(1))
                .unwrap_or(1)
        });
    CACHED.store(n, std::sync::atomic::Ordering::Relaxed);
    n
}

/// Chunking heuristic for flop-shaped work (the GEMM macro-kernel and
/// row loops): the smallest chunk of `items` whose cost reaches
/// `TARGET_FLOPS`, so tiny problems run inline on the caller thread and
/// only work that amortizes a dispatch is split across the pool.
pub fn chunk_for_flops(items: usize, flops_per_item: usize) -> usize {
    chunk_for_flops_at_rate(items, flops_per_item, 1)
}

/// Per-kernel variant of [`chunk_for_flops`]: `rate` is the executing
/// kernel's rough flop throughput relative to scalar (see
/// `linalg::simd::Kernel::rate`). A SIMD kernel retires the same flops
/// `rate`× sooner, so the flop budget that amortizes one pool dispatch
/// scales with it — otherwise an AVX-512 GEMM would be sliced into
/// chunks whose wall time is dominated by queue traffic.
pub fn chunk_for_flops_at_rate(items: usize, flops_per_item: usize, rate: usize) -> usize {
    const TARGET_FLOPS: usize = 1 << 16;
    (TARGET_FLOPS.saturating_mul(rate.max(1)) / flops_per_item.max(1)).clamp(1, items.max(1))
}

// ---------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send>;

struct Pool {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    /// Shutdown flag for bounded-lifetime pools (the loom models and the
    /// shutdown test). The process-wide pool never closes — its workers
    /// are detached for the life of the process.
    closed: AtomicBool,
}

impl Pool {
    fn new() -> Pool {
        Pool {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    fn submit(&self, job: Job) {
        self.queue.lock().unwrap().push_back(job);
        self.available.notify_one();
    }

    fn try_pop(&self) -> Option<Job> {
        self.queue.lock().unwrap().pop_front()
    }

    /// Ask every worker to exit once the queue drains. Queued jobs still
    /// run: workers check `closed` only after failing to pop.
    #[cfg(any(test, loom))]
    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        // Taking the queue mutex orders this notify after any worker's
        // pop-then-check, so a worker cannot re-enter the wait having
        // missed both the flag and the wakeup.
        let _guard = self.queue.lock().unwrap();
        self.available.notify_all();
    }

    fn worker_loop(&self) {
        loop {
            let job = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                    if self.closed.load(Ordering::Acquire) {
                        return;
                    }
                    q = self.available.wait(q).unwrap();
                }
            };
            job();
        }
    }
}

/// Completion latch for one `par_ranges` dispatch. The dispatching
/// caller parks on `opened` when the shared queue is empty (instead of
/// burning a core on `yield_now` for the tail of the dispatch); the
/// worker that finishes the last chunk notifies.
struct Latch {
    remaining: AtomicUsize,
    panicked: AtomicBool,
    lock: Mutex<()>,
    opened: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            remaining: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
            lock: Mutex::new(()),
            opened: Condvar::new(),
        }
    }

    fn count_down(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last chunk: wake the (possibly parked) caller. Taking the
            // mutex orders this notify after the caller's done-check,
            // so the wakeup cannot be missed.
            let _guard = self.lock.lock().unwrap();
            self.opened.notify_all();
        }
    }

    fn done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }

    /// Park until `count_down` opens the latch, with a bounded wait so
    /// work enqueued *while parked* (a nested dispatch from another
    /// thread) is still picked up by the caller's help-first drain —
    /// deadlock freedom does not depend on any notification.
    fn park(&self) {
        let guard = self.lock.lock().unwrap();
        if !self.done() {
            bounded_wait(&self.opened, guard);
        }
    }
}

#[cfg(not(loom))]
fn pool_enabled() -> bool {
    !matches!(
        std::env::var("KFAC_POOL").as_deref(),
        Ok("0") | Ok("off") | Ok("false") | Ok("scoped")
    )
}

/// The process-wide pool: `num_threads() − 1` detached workers, spawned
/// lazily on first parallel call. `None` when threads are disabled or
/// `KFAC_POOL=0` selects the scoped fallback.
#[cfg(not(loom))]
fn pool() -> Option<&'static Pool> {
    static POOL: std::sync::OnceLock<Option<&'static Pool>> = std::sync::OnceLock::new();
    *POOL.get_or_init(|| {
        let workers = num_threads();
        if workers <= 1 || !pool_enabled() {
            return None;
        }
        let pool: &'static Pool = Box::leak(Box::new(Pool::new()));
        for w in 0..workers - 1 {
            std::thread::Builder::new()
                .name(format!("kfac-pool-{w}"))
                .spawn(move || pool.worker_loop())
                .expect("spawn kfac pool worker");
        }
        Some(pool)
    })
}

/// Under loom there is no process-wide pool (loom state cannot live in
/// statics across model iterations); the [`model`] module hands explicit
/// per-iteration pools to the code under test instead.
#[cfg(loom)]
fn pool() -> Option<&'static Pool> {
    None
}

// ---------------------------------------------------------------------
// Detached jobs
// ---------------------------------------------------------------------

/// Where a detached job's result (or panic payload) lands. The
/// submitting side parks on `done` when it has to block for the result;
/// the executing side stores under the lock and notifies.
struct JobSlot<T> {
    result: Mutex<Option<std::thread::Result<T>>>,
    done: Condvar,
}

/// Handle to a detached background job started with [`spawn_job`]: a
/// single `FnOnce` dispatched to the persistent pool (or a dedicated
/// thread when the pool is disabled) whose result is collected later —
/// the fire-and-collect counterpart to the fork-join `par_ranges`.
pub struct JobHandle<T> {
    slot: Arc<JobSlot<T>>,
    /// The pool the job was queued on, so `collect` helps drain *that*
    /// queue while blocked (`None` = dedicated-thread job).
    pool: Option<&'static Pool>,
}

/// Dispatch `f` as a detached job and return a handle to its result.
///
/// On the persistent pool the job shares the worker queue with
/// `par_ranges` chunks; nested parallel dispatches *inside* the job are
/// fine (the job runs as an ordinary caller, and the pool's help-first
/// waiting keeps nesting deadlock-free). With the pool disabled
/// (`KFAC_POOL=0` or one thread) the job runs on its own named thread
/// instead, so detached work never blocks the caller either way.
///
/// A panic inside the job is captured and re-raised on the thread that
/// collects the handle; an uncollected panicked job is silently dropped.
pub fn spawn_job<T, F>(f: F) -> JobHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    spawn_job_on(pool(), f)
}

fn spawn_job_on<T, F>(target: Option<&'static Pool>, f: F) -> JobHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let slot = Arc::new(JobSlot { result: Mutex::new(None), done: Condvar::new() });
    let out = Arc::clone(&slot);
    let run = move || {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        *out.result.lock().unwrap() = Some(r);
        out.done.notify_all();
    };
    match target {
        Some(pool) => pool.submit(Box::new(run)),
        None => spawn_detached_thread(run),
    }
    JobHandle { slot, pool: target }
}

#[cfg(not(loom))]
fn spawn_detached_thread(run: impl FnOnce() + Send + 'static) {
    std::thread::Builder::new()
        .name("kfac-job".to_string())
        .spawn(run)
        .expect("spawn kfac job thread");
}

#[cfg(loom)]
fn spawn_detached_thread(run: impl FnOnce() + Send + 'static) {
    loom::thread::spawn(run);
}

fn unwrap_job<T>(r: std::thread::Result<T>) -> T {
    match r {
        Ok(v) => v,
        Err(payload) => std::panic::resume_unwind(payload),
    }
}

impl<T> JobHandle<T> {
    /// Whether the job has finished (its result is ready to collect
    /// without blocking).
    pub fn is_done(&self) -> bool {
        self.slot.result.lock().unwrap().is_some()
    }

    /// Collect the result if the job has finished; hand the handle back
    /// otherwise. Re-raises the job's panic, if it panicked.
    pub fn try_collect(self) -> Result<T, JobHandle<T>> {
        let taken = self.slot.result.lock().unwrap().take();
        match taken {
            Some(r) => Ok(unwrap_job(r)),
            None => Err(self),
        }
    }

    /// Block until the job finishes and return its result. While the
    /// job is still queued behind other pool work, the caller helps
    /// drain the queue (it may execute its own job) instead of idling —
    /// the same discipline as the fork-join wait, so a `collect` under a
    /// busy pool cannot deadlock. Re-raises the job's panic.
    pub fn collect(self) -> T {
        if let Some(pool) = self.pool {
            loop {
                let taken = self.slot.result.lock().unwrap().take();
                if let Some(r) = taken {
                    return unwrap_job(r);
                }
                match pool.try_pop() {
                    Some(job) => job(),
                    None => {
                        // Bounded park: correctness does not depend on
                        // the notify — queued-while-parked work is
                        // picked up on the next drain pass.
                        let guard = self.slot.result.lock().unwrap();
                        if guard.is_none() {
                            bounded_wait(&self.slot.done, guard);
                        }
                    }
                }
            }
        }
        // Dedicated-thread job: a plain condvar wait suffices.
        let mut guard = self.slot.result.lock().unwrap();
        loop {
            match guard.take() {
                Some(r) => return unwrap_job(r),
                None => guard = self.slot.done.wait(guard).unwrap(),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Pending builds (the async inverse-refresh epoch-swap seam)
// ---------------------------------------------------------------------

/// A detached build job tied to the immutable snapshot it reads: the
/// submit half of the epoch-swap protocol `optim::kfac` uses for
/// asynchronous inverse refresh (`KFAC_ASYNC=1`). The snapshot is
/// shared `Arc`-style between the submitting thread (which keeps
/// serving steps, and may checkpoint it) and the builder; [`finish`]
/// hands back the build output, the snapshot, and whether the caller
/// had to stall waiting for the build.
///
/// This seam lives here — not in `optim/kfac.rs` — so the loom suite in
/// `verify/loom` model-checks the *literal* submit/finish code the
/// optimizer runs, not a re-implementation of it.
///
/// [`finish`]: PendingJob::finish
pub struct PendingJob<I, T> {
    handle: JobHandle<T>,
    input: Arc<I>,
    submitted_k: usize,
}

/// Submit `build(&input)` as a detached background job (see
/// [`spawn_job`]) and tie the handle to its input snapshot.
/// `submitted_k` is an opaque caller tag (the step count at submit time)
/// carried through for checkpointing.
pub fn submit_build<I, T, F>(input: Arc<I>, submitted_k: usize, build: F) -> PendingJob<I, T>
where
    I: Send + Sync + 'static,
    T: Send + 'static,
    F: FnOnce(&I) -> T + Send + 'static,
{
    submit_build_on(pool(), input, submitted_k, build)
}

fn submit_build_on<I, T, F>(
    target: Option<&'static Pool>,
    input: Arc<I>,
    submitted_k: usize,
    build: F,
) -> PendingJob<I, T>
where
    I: Send + Sync + 'static,
    T: Send + 'static,
    F: FnOnce(&I) -> T + Send + 'static,
{
    let snap = Arc::clone(&input);
    let handle = spawn_job_on(target, move || build(&snap));
    PendingJob { handle, input, submitted_k }
}

impl<I, T> PendingJob<I, T> {
    /// Whether the build has finished (collecting it will not block).
    pub fn is_done(&self) -> bool {
        self.handle.is_done()
    }

    /// The input snapshot the build reads (shared until [`finish`]
    /// returns it; used to checkpoint an in-flight build).
    ///
    /// [`finish`]: PendingJob::finish
    pub fn input(&self) -> &Arc<I> {
        &self.input
    }

    /// The caller tag recorded at submit time.
    pub fn submitted_k(&self) -> usize {
        self.submitted_k
    }

    /// Block for the build and return `(output, input, stalled)`.
    /// `stalled` records whether the build was still running when the
    /// caller decided to finish it (the async pipeline's stall
    /// counter). Once this returns, the builder's clone of `input` has
    /// been dropped — the caller may `Arc::try_unwrap` it. Re-raises
    /// the build's panic, if it panicked.
    pub fn finish(self) -> (T, Arc<I>, bool) {
        let stalled = !self.handle.is_done();
        let out = self.handle.collect();
        (out, self.input, stalled)
    }
}

/// Run `body(lo, hi)` over a partition of `0..n` into contiguous chunks,
/// one per worker. `min_chunk` bounds splitting overhead: if
/// `n <= min_chunk` (or one worker), runs inline on the caller thread.
pub fn par_ranges<F>(n: usize, min_chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n.div_ceil(min_chunk.max(1))).max(1);
    if workers == 1 || n == 0 {
        body(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    let ranges: Vec<(usize, usize)> = (0..workers)
        .map(|w| (w * chunk, ((w + 1) * chunk).min(n)))
        .filter(|&(lo, hi)| lo < hi)
        .collect();
    if ranges.len() == 1 {
        body(0, n);
        return;
    }
    match pool() {
        Some(pool) => par_ranges_pooled(pool, &ranges, &body),
        None => par_ranges_scoped(&ranges, &body),
    }
}

/// Monomorphized trampoline: recovers the `&F` behind the laundered
/// address. Taking this as a plain `fn` pointer keeps the pool's boxed
/// jobs free of `F` (and of its lifetimes — the `'static` job bound).
fn chunk_trampoline<F>(addr: usize, lo: usize, hi: usize)
where
    F: Fn(usize, usize) + Sync,
{
    // SAFETY: see par_ranges_pooled — `addr` points at a live `F` for
    // the whole dispatch, and `F: Sync` makes shared access sound.
    let f = unsafe { &*(addr as *const F) };
    f(lo, hi);
}

/// Dispatch chunks onto the persistent pool; the caller runs the first
/// chunk itself and then helps drain the queue until its latch opens.
fn par_ranges_pooled<F>(pool: &'static Pool, ranges: &[(usize, usize)], body: &F)
where
    F: Fn(usize, usize) + Sync,
{
    let latch = Arc::new(Latch::new(ranges.len() - 1));
    // Lifetime laundering: jobs on the 'static pool capture the closure
    // address as a plain usize. SAFETY: this function does not return
    // until `latch` confirms every submitted job has finished running
    // `body`, so the reference never dangles, and each job runs exactly
    // once.
    let body_addr = body as *const F as usize;
    let trampoline: fn(usize, usize, usize) = chunk_trampoline::<F>;
    for &(lo, hi) in &ranges[1..] {
        let latch = Arc::clone(&latch);
        pool.submit(Box::new(move || {
            let call = || trampoline(body_addr, lo, hi);
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(call)).is_ok();
            if !ok {
                latch.panicked.store(true, Ordering::Release);
            }
            latch.count_down();
        }));
    }
    // The caller's own chunk must also be panic-guarded: unwinding out
    // of this frame before the latch opens would free the stack slot
    // behind `body_addr` while queued jobs still reference it (UB). So
    // catch, drain the latch, then resume the unwind.
    let (lo0, hi0) = ranges[0];
    let caller_result =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(lo0, hi0)));
    // Help-first wait: execute whatever is queued (ours or an unrelated
    // dispatch) so nested parallel calls cannot deadlock the pool. With
    // the queue empty the caller parks on the latch condvar until the
    // last chunk counts down, instead of spinning on yield_now for the
    // whole tail of the dispatch.
    while !latch.done() {
        match pool.try_pop() {
            Some(job) => job(),
            None => latch.park(),
        }
    }
    if let Err(payload) = caller_result {
        std::panic::resume_unwind(payload);
    }
    assert!(
        !latch.panicked.load(Ordering::Acquire),
        "par_ranges: a worker chunk panicked"
    );
}

/// The original per-call scoped-thread fallback (`KFAC_POOL=0`).
#[cfg(not(loom))]
fn par_ranges_scoped<F>(ranges: &[(usize, usize)], body: &F)
where
    F: Fn(usize, usize) + Sync,
{
    std::thread::scope(|s| {
        for &(lo, hi) in &ranges[1..] {
            s.spawn(move || body(lo, hi));
        }
        let (lo0, hi0) = ranges[0];
        body(lo0, hi0);
    });
}

/// Loom builds model the pooled path only; the scoped fallback (plain
/// `std::thread::scope`, nothing shared but `&F`) degenerates to a
/// serial sweep so `par_ranges` stays callable under `--cfg loom`.
#[cfg(loom)]
fn par_ranges_scoped<F>(ranges: &[(usize, usize)], body: &F)
where
    F: Fn(usize, usize) + Sync,
{
    for &(lo, hi) in ranges {
        body(lo, hi);
    }
}

/// Run `f(rank)` for every rank in `0..n` on long-lived scoped threads
/// (rank 0 on the calling thread), collecting results in rank order.
///
/// Unlike `par_map`, each closure runs for the *whole call* — this is the
/// in-process harness for multi-rank distributed training (`--dist local`),
/// where every rank owns a blocking training loop that must make progress
/// concurrently with its peers. Do not route through the worker pool: the
/// ranks block on collective exchanges with each other, and parking them on
/// pool workers could deadlock a pool smaller than `n`.
#[cfg(not(loom))]
pub fn scoped_ranks<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    if n <= 1 {
        return vec![f(0)];
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let (first, rest) = slots.split_at_mut(1);
        std::thread::scope(|s| {
            for (i, slot) in rest.iter_mut().enumerate() {
                let f = &f;
                s.spawn(move || *slot = Some(f(i + 1)));
            }
            first[0] = Some(f(0));
        });
    }
    slots.into_iter().map(|o| o.expect("scoped_ranks: rank did not finish")).collect()
}

/// Under loom the distributed harness is out of model scope (the collective
/// ranks block on each other, which the bounded-interleaving explorer would
/// deadlock on); keep the symbol callable as a serial sweep.
#[cfg(loom)]
pub fn scoped_ranks<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    (0..n.max(1)).map(f).collect()
}

/// Parallel map over indices `0..n`, collecting results in order.
pub fn par_map<T, F>(n: usize, min_chunk: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        par_ranges(n, min_chunk, |lo, hi| {
            let p = out_ptr; // capture by copy
            for i in lo..hi {
                // SAFETY: ranges from par_ranges are disjoint, so each
                // element is written by exactly one worker.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Parallel map for non-`Default` payloads (results are `Send` only).
pub fn par_map_send<T: Send>(
    n: usize,
    min_chunk: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let ptr = SendPtr(out.as_mut_ptr());
        par_ranges(n, min_chunk, |lo, hi| {
            let p = ptr;
            for i in lo..hi {
                // SAFETY: disjoint ranges; each slot written exactly once.
                unsafe { *p.0.add(i) = Some(f(i)) };
            }
        });
    }
    out.into_iter().map(|o| o.expect("par_map_send: slot not filled")).collect()
}

/// Shared mutable pointer handed to `par_ranges` workers. SAFETY
/// contract for every use in this crate: workers write strictly
/// disjoint index ranges of the pointee, and the owning buffer outlives
/// the dispatch (par_ranges does not return before all chunks finish).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: SendPtr is a raw address with no aliasing claims of its own;
// the disjoint-writes + outlives-the-dispatch contract above is what
// each use site upholds (and what the loom publish models check).
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: shared access is address copying only; dereferences are the
// use sites' obligation under the contract above.
unsafe impl<T> Sync for SendPtr<T> {}

// ---------------------------------------------------------------------
// Loom model surface
// ---------------------------------------------------------------------

/// Loom-only hooks (`--cfg loom`): opaque handles over the private pool
/// and latch so `verify/loom/tests/loom_pool.rs` can drive the *real*
/// submit/help/park/count_down code paths — worker threads run
/// [`Pool::worker_loop`] itself, dispatches go through
/// [`par_ranges_pooled`] itself — under loom's exhaustive scheduler.
/// Never compiled into production builds.
#[cfg(loom)]
pub mod model {
    use super::*;

    /// An explicit, per-model-iteration pool (leaked: loom model
    /// closures need `'static` state, and each iteration builds a
    /// fresh one).
    #[derive(Clone, Copy)]
    pub struct PoolHandle(&'static Pool);

    /// Build a fresh pool. Spawn workers with [`worker`] and terminate
    /// them with [`close`] before the model iteration ends — loom
    /// requires every thread to finish.
    pub fn pool() -> PoolHandle {
        PoolHandle(Box::leak(Box::new(Pool::new())))
    }

    /// Run one worker loop (call from a `loom::thread::spawn`).
    pub fn worker(pool: PoolHandle) {
        pool.0.worker_loop();
    }

    /// Ask the pool's workers to exit once the queue drains.
    pub fn close(pool: PoolHandle) {
        pool.0.close();
    }

    /// [`spawn_job`](super::spawn_job) onto an explicit pool.
    pub fn spawn_job_on<T, F>(pool: PoolHandle, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        super::spawn_job_on(Some(pool.0), f)
    }

    /// [`spawn_job`](super::spawn_job) on a dedicated (loom) thread —
    /// the `KFAC_POOL=0` dedicated-thread path.
    pub fn spawn_job_detached<T, F>(f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        super::spawn_job_on(None, f)
    }

    /// [`submit_build`](super::submit_build) onto an explicit pool (the
    /// epoch-swap protocol under model check).
    pub fn submit_build_on<I, T, F>(
        pool: PoolHandle,
        input: Arc<I>,
        submitted_k: usize,
        build: F,
    ) -> PendingJob<I, T>
    where
        I: Send + Sync + 'static,
        T: Send + 'static,
        F: FnOnce(&I) -> T + Send + 'static,
    {
        super::submit_build_on(Some(pool.0), input, submitted_k, build)
    }

    /// Drive [`par_ranges_pooled`](super::par_ranges_pooled) on an
    /// explicit pool with an explicit chunk count (bypasses the
    /// `num_threads` env heuristics, which loom does not model).
    pub fn par_ranges_on<F>(pool: PoolHandle, n: usize, chunks: usize, body: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let chunk = n.div_ceil(chunks.max(1));
        let ranges: Vec<(usize, usize)> = (0..chunks.max(1))
            .map(|w| (w * chunk, ((w + 1) * chunk).min(n)))
            .filter(|&(lo, hi)| lo < hi)
            .collect();
        if ranges.len() <= 1 {
            body(0, n);
            return;
        }
        par_ranges_pooled(pool.0, &ranges, &body);
    }

    /// Opaque handle over the private [`Latch`] for direct
    /// count_down/park interleaving models.
    #[derive(Clone)]
    pub struct LatchHandle(Arc<Latch>);

    /// A latch expecting `n` count-downs.
    pub fn latch(n: usize) -> LatchHandle {
        LatchHandle(Arc::new(Latch::new(n)))
    }

    impl LatchHandle {
        pub fn count_down(&self) {
            self.0.count_down();
        }

        pub fn done(&self) -> bool {
            self.0.done()
        }

        /// The caller-side wait loop: park (bounded) until open.
        pub fn park_until_done(&self) {
            while !self.0.done() {
                self.0.park();
            }
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn par_ranges_covers_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_ranges(n, 16, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(1000, 8, |i| (i * i) as u64);
        let want: Vec<u64> = (0..1000).map(|i| (i * i) as u64).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn small_n_runs_inline() {
        let got = par_map(3, 1000, |i| i);
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn nested_parallel_calls_complete() {
        // A worker chunk that itself dispatches to the pool must not
        // deadlock (the help-first wait drains the inner jobs).
        let got = par_map(8, 1, |i| {
            let inner = par_map(64, 4, move |j| (i * 64 + j) as u64);
            inner.iter().sum::<u64>()
        });
        let want: Vec<u64> = (0..8u64)
            .map(|i| (0..64u64).map(|j| i * 64 + j).sum())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn repeated_dispatches_reuse_the_pool() {
        // Exercise many small dispatches back-to-back — the shape the
        // persistent pool exists for — and check correctness each time.
        for round in 0..50u64 {
            let got = par_map(97, 4, move |i| i as u64 + round);
            let want: Vec<u64> = (0..97u64).map(|i| i + round).collect();
            assert_eq!(got, want, "round {round}");
        }
    }

    #[test]
    fn parked_wait_wakes_on_completion() {
        // The caller's own chunk finishes instantly while worker chunks
        // sleep 10ms, forcing the empty-queue park each round. Whether
        // woken by count_down's notify or by the bounded 500µs wait,
        // five rounds must finish in ~50ms of sleep plus small
        // scheduling noise — a generous 2s bound still catches a park
        // that fails to wake (which would hang, not merely lag).
        let n = num_threads().max(2);
        let t0 = std::time::Instant::now();
        for _ in 0..5 {
            par_ranges(n, 1, |lo, _hi| {
                if lo != 0 {
                    std::thread::sleep(Duration::from_millis(10));
                }
            });
        }
        assert!(t0.elapsed() < Duration::from_secs(2), "parked dispatch stalled");
    }

    #[test]
    fn nested_dispatch_under_parked_waiters_completes() {
        // Outer chunks park while inner dispatches run; the help-first
        // drain plus bounded park must keep everything live.
        let got = par_map(4, 1, |i| {
            let inner = par_map(200, 8, move |j| {
                if j == 0 {
                    std::thread::sleep(Duration::from_millis(2));
                }
                (i * 200 + j) as u64
            });
            inner.iter().sum::<u64>()
        });
        let want: Vec<u64> = (0..4u64)
            .map(|i| (0..200u64).map(|j| i * 200 + j).sum())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn spawn_job_returns_its_result() {
        let h = spawn_job(|| (0..100u64).sum::<u64>());
        assert_eq!(h.collect(), 4950);
    }

    #[test]
    fn try_collect_eventually_succeeds_and_is_done_agrees() {
        let h = spawn_job(|| {
            std::thread::sleep(Duration::from_millis(5));
            42u64
        });
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !h.is_done() {
            assert!(std::time::Instant::now() < deadline, "job never completed");
            std::thread::sleep(Duration::from_micros(200));
        }
        // once is_done reports true, try_collect must succeed
        match h.try_collect() {
            Ok(v) => assert_eq!(v, 42),
            Err(_) => panic!("is_done was true but try_collect found no result"),
        }
    }

    #[test]
    fn job_panic_surfaces_at_collect() {
        let h = spawn_job(|| -> u64 { panic!("boom in job") });
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.collect()));
        assert!(err.is_err(), "job panic must re-raise on collect");
    }

    #[test]
    fn job_panic_payload_propagates_exactly_once() {
        // The payload re-raised at collect must be the job's own (not a
        // wrapper), delivered exactly once; the pool stays usable after.
        let h = spawn_job(|| -> u64 { std::panic::panic_any(1234_usize) });
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.collect()))
            .expect_err("collect of a panicked job must re-raise");
        let payload = err.downcast_ref::<usize>().copied();
        assert_eq!(payload, Some(1234), "payload must round-trip through the slot");
        // The slot was drained by the failed collect; the pool that ran
        // the panicking job still serves fresh work.
        let h2 = spawn_job(|| 7u64);
        assert_eq!(h2.collect(), 7);
    }

    #[test]
    fn job_drop_without_collect_still_runs() {
        // Dropping the handle abandons the result, not the job: the
        // side effect must still happen (the async refresh relies on
        // fire-and-forget never silently cancelling).
        let ran = Arc::new(AtomicBool::new(false));
        {
            let ran = Arc::clone(&ran);
            let _dropped = spawn_job(move || ran.store(true, Ordering::Release));
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !ran.load(Ordering::Acquire) {
            assert!(std::time::Instant::now() < deadline, "dropped job never ran");
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    #[test]
    fn dropped_panicked_job_is_silent() {
        // An uncollected panicked job must not take the process down or
        // poison the pool for later work.
        drop(spawn_job(|| -> u64 { panic!("dropped panic") }));
        for round in 0..8u64 {
            let h = spawn_job(move || round * 2);
            assert_eq!(h.collect(), round * 2);
        }
    }

    #[test]
    fn pool_close_joins_workers() {
        // A standalone pool (not the process-wide one) drains its queue
        // and its workers exit after close() — the shutdown protocol the
        // loom models rely on to terminate every iteration.
        let pool: &'static Pool = Box::leak(Box::new(Pool::new()));
        let worker = std::thread::spawn(move || pool.worker_loop());
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let hits = Arc::clone(&hits);
            pool.submit(Box::new(move || {
                hits.fetch_add(1, Ordering::AcqRel);
            }));
        }
        pool.close();
        worker.join().expect("worker must exit cleanly after close");
        // close() lets already-queued jobs drain before workers exit.
        assert_eq!(hits.load(Ordering::Acquire), 4);
    }

    #[test]
    fn pending_job_finish_returns_value_input_and_stall_flag() {
        let snap = Arc::new(vec![1u64, 2, 3, 4]);
        let pending = submit_build(Arc::clone(&snap), 17, |v| v.iter().sum::<u64>());
        assert_eq!(pending.submitted_k(), 17);
        assert_eq!(pending.input().as_slice(), &[1, 2, 3, 4]);
        let (sum, returned, stalled) = pending.finish();
        assert_eq!(sum, 10);
        assert!(Arc::ptr_eq(&snap, &returned));
        // `stalled` is a point-in-time observation; either value is
        // legal here, but the type must be a plain bool either way.
        let _: bool = stalled;
    }

    #[test]
    fn pending_job_finish_after_done_reports_no_stall_and_unique_input() {
        let pending = submit_build(Arc::new(5u64), 0, |v| *v * 3);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !pending.is_done() {
            assert!(std::time::Instant::now() < deadline, "build never completed");
            std::thread::sleep(Duration::from_micros(200));
        }
        let (out, input, stalled) = pending.finish();
        assert_eq!(out, 15);
        assert!(!stalled, "finish after is_done must not count as a stall");
        // The builder's clone is dropped before the result is
        // published, so the returned Arc is uniquely owned — the
        // optimizer's try_unwrap at install time depends on this.
        assert_eq!(Arc::try_unwrap(input).expect("input must be uniquely owned"), 5);
    }

    #[test]
    fn job_dispatching_nested_par_ranges_completes() {
        // The detached-job shape the async inverse refresh uses: a
        // background job that itself fans out on the pool, collected
        // while the caller keeps dispatching foreground work.
        let h = spawn_job(|| {
            let inner = par_map(300, 8, |j| (j * j) as u64);
            inner.iter().sum::<u64>()
        });
        for round in 0..10u64 {
            let got = par_map(64, 4, move |i| i as u64 + round);
            assert_eq!(got.iter().sum::<u64>(), (0..64u64).sum::<u64>() + 64 * round);
        }
        let want: u64 = (0..300u64).map(|j| j * j).sum();
        assert_eq!(h.collect(), want);
    }

    #[test]
    fn many_concurrent_jobs_all_complete() {
        let handles: Vec<JobHandle<u64>> =
            (0..16u64).map(|i| spawn_job(move || i * 3 + 1)).collect();
        let got: Vec<u64> = handles.into_iter().map(|h| h.collect()).collect();
        let want: Vec<u64> = (0..16u64).map(|i| i * 3 + 1).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn chunk_for_flops_bounds() {
        // cheap items coalesce, expensive items split singly
        assert_eq!(chunk_for_flops(1000, 1), 1000);
        assert_eq!(chunk_for_flops(1_000_000, 8), (1 << 16) / 8);
        assert_eq!(chunk_for_flops(64, 1 << 20), 1);
        // degenerate inputs stay in range
        assert_eq!(chunk_for_flops(0, 0), 1);
        assert!(chunk_for_flops(5, 0) <= 5);
    }

    #[test]
    fn chunk_rate_scales_the_flop_target() {
        // a rate-r kernel needs r× the flops per chunk
        assert_eq!(chunk_for_flops_at_rate(1_000_000, 8, 1), (1 << 16) / 8);
        assert_eq!(chunk_for_flops_at_rate(1_000_000, 8, 4), 4 * (1 << 16) / 8);
        assert_eq!(chunk_for_flops_at_rate(1_000_000, 8, 8), 8 * (1 << 16) / 8);
        // rate 0 behaves as scalar; bounds still hold
        assert_eq!(chunk_for_flops_at_rate(10, 1, 0), 10);
        assert_eq!(chunk_for_flops_at_rate(64, 1 << 20, 8), 1);
        assert_eq!(chunk_for_flops(1_000_000, 8), chunk_for_flops_at_rate(1_000_000, 8, 1));
    }
}
