#!/usr/bin/env python3
"""Bench-regression gate over BENCH_linalg.json (stdlib only).

CI's `bench-gate` job runs `cargo bench --bench linalg` three times,
merges the runs with per-bench medians (`merge`), compares the result
against the committed `BENCH_baseline.json` (`gate`, fails on >20%
median GFLOP/s regression), and keeps the ROADMAP baseline tables in
lockstep with the baseline file (`check-roadmap`). The very first green
run on main records the baseline (`record` rewrites the `_pending_`
ROADMAP cells and emits `BENCH_baseline.json`); `is-placeholder` is the
bootstrap predicate for that step.

Subcommands:
  merge RUN1 RUN2 ... -o OUT
  gate BASELINE FRESH [--tolerance 0.20] [--summary FILE]
  check-roadmap BASELINE ROADMAP
  record FRESH -o BASELINE [--roadmap ROADMAP]
  is-placeholder BASELINE          (exit 0 iff the bootstrap marker)
"""

import argparse
import json
import statistics
import sys

# ROADMAP table rows -> bench names. The GEMM table holds dispatched
# GFLOP/s for the square shapes; the SymEig table holds blocked and
# scalar-QL-reference medians.
GEMM_ROWS = {
    "256³": "matmul_256x256x256",
    "401³": "matmul_401x401x401",
    "512³": "matmul_512x512x512",
}
EIG_ROWS = {
    "64": ("sym_eig_64", "sym_eig_ql_ref_64"),
    "256": ("sym_eig_256", "sym_eig_ql_ref_256"),
    "512": ("sym_eig_512", "sym_eig_ql_ref_512"),
}
PENDING = "pending"  # substring marking a not-yet-recorded ROADMAP cell


def load(path):
    with open(path) as f:
        return json.load(f)


def is_placeholder(doc):
    return bool(doc.get("placeholder")) or not doc.get("benches")


def by_name(doc):
    return {b["name"]: b for b in doc.get("benches", [])}


def fmt_time(s):
    if s < 1e-6:
        return f"{s * 1e9:.0f} ns"
    if s < 1e-3:
        return f"{s * 1e6:.1f} µs"
    if s < 1.0:
        return f"{s * 1e3:.2f} ms"
    return f"{s:.2f} s"


def fmt_gflops(g):
    return f"{g:.1f}"


def cpu_model():
    """Runner CPU model, so the gate knows when baseline and fresh run
    came from different hardware (GitHub's fleet is heterogeneous and
    absolute GFLOP/s are not comparable across CPU generations)."""
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    return "unknown"


def cmd_merge(args):
    docs = [load(p) for p in args.runs]
    # Union of names across runs, first-run ordering first: repeated
    # runs of one suite median together, while suites with disjoint
    # bench sets (linalg + fisher_ops) concatenate into one report.
    names = []
    for d in docs:
        for b in d.get("benches", []):
            if b["name"] not in names:
                names.append(b["name"])
    merged = []
    for name in names:
        rows = [by_name(d)[name] for d in docs if name in by_name(d)]
        entry = dict(rows[0])
        entry["median_s"] = statistics.median(r["median_s"] for r in rows)
        entry["mean_s"] = statistics.median(r["mean_s"] for r in rows)
        gs = [r["gflops"] for r in rows if r.get("gflops") is not None]
        entry["gflops"] = round(statistics.median(gs), 3) if gs else None
        merged.append(entry)
    out = {
        "threads": docs[0].get("threads", 0),
        "runs": len(docs),
        "cpu": cpu_model(),
        "benches": merged,
    }
    with open(args.output, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"merged {len(docs)} runs x {len(merged)} benches -> {args.output}")
    return 0


def speedup_lines(fresh):
    """Markdown rows showing per-kernel GFLOP/s and the SIMD speedup."""
    lines = [
        "",
        "### SIMD speedup (dispatched vs scalar micro-kernel)",
        "",
        "| shape | scalar | avx2 | avx512 | best/scalar |",
        "|-------|--------|------|--------|-------------|",
    ]
    for n in (256, 401, 512):
        cells = []
        best = None
        scalar = None
        for kern in ("scalar", "avx2", "avx512"):
            b = fresh.get(f"matmul_{n}_{kern}")
            g = b.get("gflops") if b else None
            cells.append(fmt_gflops(g) if g is not None else "n/a")
            if g is not None:
                if kern == "scalar":
                    scalar = g
                else:
                    best = max(best or 0.0, g)
        ratio = f"{best / scalar:.2f}x" if scalar and best else "n/a"
        lines.append(f"| {n}³ | {cells[0]} | {cells[1]} | {cells[2]} | {ratio} |")
    return lines


def cmd_gate(args):
    base_doc = load(args.baseline)
    fresh_doc = load(args.fresh)
    fresh = by_name(fresh_doc)
    lines = ["## Bench gate", ""]
    failed = []

    if is_placeholder(base_doc):
        lines += [
            "`BENCH_baseline.json` is still the bootstrap placeholder — "
            "no gate applied. Fresh medians:",
            "",
            "| bench | median | GFLOP/s |",
            "|-------|--------|---------|",
        ]
        for b in fresh_doc.get("benches", []):
            g = fmt_gflops(b["gflops"]) if b.get("gflops") is not None else "—"
            lines.append(f"| {b['name']} | {fmt_time(b['median_s'])} | {g} |")
        lines += speedup_lines(fresh)
    else:
        base = by_name(base_doc)
        # Absolute GFLOP/s only mean something on the same hardware:
        # GitHub's runner fleet mixes CPU generations, so when the fresh
        # run landed on a different CPU model than the baseline was
        # recorded on, regressions are demoted to warnings instead of
        # failing pushes for hardware reasons.
        base_cpu = base_doc.get("cpu", "unknown")
        fresh_cpu = fresh_doc.get("cpu", "unknown")
        strict = base_cpu == fresh_cpu or "unknown" in (base_cpu, fresh_cpu)
        lines += [
            f"Baseline CPU: `{base_cpu}` · fresh CPU: `{fresh_cpu}`.",
            f"Tolerance: >{args.tolerance * 100:.0f}% median GFLOP/s regression "
            + ("fails." if strict else "WARNS ONLY (different CPU model)."),
            "",
            "| bench | baseline | fresh | Δ | status |",
            "|-------|----------|-------|---|--------|",
        ]
        for name, bb in base.items():
            fb = fresh.get(name)
            if fb is None:
                # a gated (GFLOP/s) bench vanishing means the gate
                # silently stops covering it — that is itself a failure
                # on comparable hardware (rename the baseline entry or
                # re-record instead)
                if bb.get("gflops") is not None and strict:
                    failed.append(f"{name}: gated bench missing from fresh run")
                    lines.append(f"| {name} | — | _missing from fresh run_ | | ❌ missing |")
                else:
                    lines.append(f"| {name} | — | _missing from fresh run_ | | ⚠️ |")
                continue
            if bb.get("gflops") is not None and fb.get("gflops") is not None:
                bg, fg = bb["gflops"], fb["gflops"]
                delta = (fg - bg) / bg if bg else 0.0
                ok = fg >= bg * (1.0 - args.tolerance)
                status = "✅" if ok else ("❌ regression" if strict else "⚠️ (cpu differs)")
                if not ok and strict:
                    failed.append(f"{name}: {bg:.1f} -> {fg:.1f} GFLOP/s ({delta * 100:+.1f}%)")
                lines.append(
                    f"| {name} | {fmt_gflops(bg)} GFLOP/s | {fmt_gflops(fg)} GFLOP/s "
                    f"| {delta * 100:+.1f}% | {status} |"
                )
            else:
                bs, fs = bb["median_s"], fb["median_s"]
                delta = (fs - bs) / bs if bs else 0.0
                # time-only entries (eigensolver, inverses) are reported
                # but not gated: GFLOP/s entries are the contract
                lines.append(
                    f"| {name} | {fmt_time(bs)} | {fmt_time(fs)} | {delta * 100:+.1f}% | (info) |"
                )
        for name in fresh:
            if name not in base:
                lines.append(f"| {name} | _new (no baseline)_ | {fmt_time(fresh[name]['median_s'])} | | ℹ️ |")
        lines += speedup_lines(fresh)

    text = "\n".join(lines) + "\n"
    print(text)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(text)
    if failed:
        print("BENCH GATE FAILED:", file=sys.stderr)
        for f_ in failed:
            print(f"  {f_}", file=sys.stderr)
        return 1
    return 0


def roadmap_cells(line):
    stripped = line.strip()
    if not stripped.startswith("|"):
        return None
    return [c.strip() for c in stripped.strip("|").split("|")]


def expected_cells(base):
    """row-label -> expected ROADMAP cell strings, from the baseline."""
    want = {}
    for label, bench in GEMM_ROWS.items():
        b = base.get(bench)
        if b and b.get("gflops") is not None:
            want[label] = [fmt_gflops(b["gflops"])]
    for label, (blocked, ql) in EIG_ROWS.items():
        bb, qb = base.get(blocked), base.get(ql)
        if bb and qb:
            want[label] = [fmt_time(bb["median_s"]), fmt_time(qb["median_s"])]
    return want


def cmd_check_roadmap(args):
    base_doc = load(args.baseline)
    with open(args.roadmap) as f:
        lines = f.read().splitlines()
    rows = {}
    for line in lines:
        cells = roadmap_cells(line)
        if cells and cells[0] in (GEMM_ROWS.keys() | EIG_ROWS.keys()):
            rows[cells[0]] = cells[1:]
    missing = (GEMM_ROWS.keys() | EIG_ROWS.keys()) - rows.keys()
    if missing:
        print(f"ROADMAP baseline tables are missing rows: {sorted(missing)}", file=sys.stderr)
        return 1

    if is_placeholder(base_doc):
        stale = [lab for lab, cells in rows.items() if PENDING not in " ".join(cells).lower()]
        if stale:
            print(
                "BENCH_baseline.json is the bootstrap placeholder but these ROADMAP "
                f"rows already hold numbers (drifted?): {sorted(stale)}",
                file=sys.stderr,
            )
            return 1
        print("baseline placeholder + pending ROADMAP tables: consistent (bootstrap state)")
        return 0

    want = expected_cells(by_name(base_doc))
    bad = []
    for label, cells in want.items():
        got = rows[label][: len(cells)]
        if got != cells:
            bad.append(f"  row {label}: ROADMAP says {got}, baseline says {cells}")
    if bad:
        print(
            "ROADMAP baseline tables drifted from BENCH_baseline.json "
            "(re-run `scripts/bench_gate.py record`):",
            file=sys.stderr,
        )
        print("\n".join(bad), file=sys.stderr)
        return 1
    print(f"ROADMAP tables match BENCH_baseline.json ({len(want)} rows)")
    return 0


def cmd_record(args):
    fresh_doc = load(args.fresh)
    if is_placeholder(fresh_doc):
        print("refusing to record: fresh results are empty/placeholder", file=sys.stderr)
        return 1
    out = {
        "recorded_from": "first green bench-gate run",
        "threads": fresh_doc.get("threads", 0),
        "runs": fresh_doc.get("runs", 1),
        "cpu": fresh_doc.get("cpu", "unknown"),
        "benches": fresh_doc.get("benches", []),
    }
    with open(args.output, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"recorded {len(out['benches'])} baselines -> {args.output}")

    if args.roadmap:
        want = expected_cells(by_name(fresh_doc))
        with open(args.roadmap) as f:
            lines = f.read().splitlines()
        replaced = 0
        for i, line in enumerate(lines):
            cells = roadmap_cells(line)
            if not cells or cells[0] not in want:
                continue
            new = want[cells[0]]
            # preserve indentation and any cells past the ones we own
            indent = line[: len(line) - len(line.lstrip())]
            tail = cells[1 + len(new) :]
            lines[i] = indent + "| " + " | ".join([cells[0]] + new + tail) + " |"
            replaced += 1
        with open(args.roadmap, "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"rewrote {replaced} ROADMAP baseline rows in {args.roadmap}")
    return 0


def cmd_is_placeholder(args):
    try:
        doc = load(args.baseline)
    except FileNotFoundError:
        return 0  # no baseline at all == needs bootstrapping
    return 0 if is_placeholder(doc) else 1


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    m = sub.add_parser("merge")
    m.add_argument("runs", nargs="+")
    m.add_argument("-o", "--output", required=True)

    g = sub.add_parser("gate")
    g.add_argument("baseline")
    g.add_argument("fresh")
    g.add_argument("--tolerance", type=float, default=0.20)
    g.add_argument("--summary")

    c = sub.add_parser("check-roadmap")
    c.add_argument("baseline")
    c.add_argument("roadmap")

    r = sub.add_parser("record")
    r.add_argument("fresh")
    r.add_argument("-o", "--output", required=True)
    r.add_argument("--roadmap")

    p = sub.add_parser("is-placeholder")
    p.add_argument("baseline")

    args = ap.parse_args()
    return {
        "merge": cmd_merge,
        "gate": cmd_gate,
        "check-roadmap": cmd_check_roadmap,
        "record": cmd_record,
        "is-placeholder": cmd_is_placeholder,
    }[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
