//! # K-FAC: Kronecker-factored Approximate Curvature
//!
//! A production-quality reproduction of *Optimizing Neural Networks with
//! Kronecker-factored Approximate Curvature* (Martens & Grosse, ICML 2015)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1/2 (build time)**: Pallas kernels and JAX compute graphs in
//!   `python/compile/`, AOT-lowered to HLO text artifacts.
//! - **Layer 3 (this crate)**: the K-FAC optimizer itself — online
//!   Kronecker-factored Fisher statistics, block-diagonal and
//!   block-tridiagonal inverse approximations, the full damping /
//!   re-scaling / momentum machinery of the paper — plus every substrate
//!   it needs (dense linear algebra, a feed-forward NN reference
//!   implementation, synthetic datasets, a PJRT runtime for the AOT
//!   artifacts, and a training coordinator).
//!
//! Quick start (pure-Rust backend): see `examples/quickstart.rs`.

// Every `unsafe` operation must sit in an explicit `unsafe {}` block,
// even inside `unsafe fn` — so each dereference/intrinsic carries its
// own `// SAFETY:` justification (enforced by `scripts/repo_lint.py`).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod linalg;
pub mod par;
pub mod rng;
pub mod util;

pub mod nn;

pub mod fisher;

pub mod optim;

pub mod data;

pub mod dist;

pub mod backend;
pub mod runtime;

pub mod coordinator;

pub mod experiments;

/// Convenient re-exports for library users.
pub mod prelude {
    pub use crate::backend::{BatchStats, ModelBackend, RustBackend};
    pub use crate::coordinator::{Event, Problem, TrainReport, TrainSession};
    pub use crate::data::dataset::Dataset;
    pub use crate::dist::{Collective, DistError, NoopCollective};
    pub use crate::fisher::{FisherInverse, PrecondRef, Preconditioner};
    pub use crate::linalg::{KronBasis, Mat};
    pub use crate::nn::{Act, Arch, LossKind, Params};
    pub use crate::optim::kfac::{Kfac, KfacConfig};
    pub use crate::optim::sgd::{Sgd, SgdConfig};
    pub use crate::optim::{BatchSchedule, OptState, Optimizer, StepInfo};
    pub use crate::rng::Rng;
}
