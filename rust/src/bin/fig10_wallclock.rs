//! Figure 10 — training error vs **wall-clock time** on the CURVES,
//! MNIST and FACES autoencoder problems: K-FAC (block-tridiagonal and
//! block-diagonal, with the exponentially increasing batch schedule of
//! Section 13), K-FAC without momentum, and the SGD+NAG baseline.
//!
//! Runs on the PJRT backend (the AOT JAX/Pallas artifacts) by default —
//! set `--backend rust` to use the pure-Rust reference backend, and
//! `--iters / --data` (or KFAC_EXP_SCALE) to shrink the experiment.
//! Each run is cached under results/fig10_<problem>_<variant>.csv, and
//! fig11_periter replots the same runs per-iteration.

use kfac::coordinator::cli::Args;
use kfac::experiments::{scaled, training_curves_fig10};

fn main() {
    let args = Args::from_env();
    let backend = args.get_or("backend", "pjrt");
    let iters = args.get_usize("iters", scaled(80, 20));
    let n_data = args.get_usize("data", scaled(2500, 600));
    println!("== Figure 10: training error vs wall-clock ({backend} backend, {iters} iters, |S|={n_data}) ==");

    let runs = training_curves_fig10(&backend, iters, n_data);

    println!(
        "\n{:>10} {:>18} {:>10} {:>12} {:>12}",
        "problem", "variant", "time_s", "final_err", "err@50%time"
    );
    let mut by_problem: std::collections::BTreeMap<&str, Vec<(String, f64, f64)>> =
        Default::default();
    for (problem, vname, log) in &runs {
        let last = log.last().unwrap();
        let half_t = last.time_s / 2.0;
        let half = log.iter().find(|r| r.time_s >= half_t).unwrap_or(last);
        println!(
            "{:>10} {:>18} {:>10.1} {:>12.5} {:>12.5}",
            problem.name(),
            vname,
            last.time_s,
            last.train_err,
            half.train_err
        );
        by_problem.entry(problem.name()).or_default().push((
            vname.clone(),
            last.time_s,
            last.train_err,
        ));
    }

    // paper-shape check: on each problem, K-FAC (tridiag, momentum)
    // reaches a lower final error than the SGD baseline.
    println!();
    for (pname, rows) in &by_problem {
        let kfac = rows.iter().find(|r| r.0 == "kfac_blktridiag");
        let sgd = rows.iter().find(|r| r.0 == "sgd");
        if let (Some(k), Some(s)) = (kfac, sgd) {
            println!(
                "{pname}: kfac err {:.5} ({:.0}s) vs sgd err {:.5} ({:.0}s)  -> {}",
                k.2,
                k.1,
                s.2,
                s.1,
                if k.2 < s.2 { "kfac wins" } else { "sgd wins (check tuning)" }
            );
        }
    }
    println!("\nper-run CSVs are in results/fig10_*.csv (time_s column = x-axis)");
}
