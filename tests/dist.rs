//! Distributed K-FAC integration tests: collective semantics on both
//! transports, the `ranks=1` bit-identity keystone, 2-rank lockstep,
//! sharded-inverse parity with the plain build, and the fault-injection
//! harness for degraded mode (dropped peers, slow peers, garbage TCP
//! clients, short reads).

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use kfac::backend::{ModelBackend, RustBackend};
use kfac::coordinator::{checkpoint, Event, TrainSession};
use kfac::data::mnist_like;
use kfac::dist::backend::DistBackend;
use kfac::dist::local::LocalGroup;
use kfac::dist::tcp::{TcpCollective, TcpOpts};
use kfac::dist::trainer::{run_local_ranks, run_ranks_with};
use kfac::dist::{sharded_build, Collective, DistError};
use kfac::fisher::{precond, FisherInverse, Preconditioner};
use kfac::nn::{Act, Arch, Params};
use kfac::optim::{BatchSchedule, Kfac, KfacConfig, Optimizer};
use kfac::rng::Rng;

fn assert_params_bit_equal(a: &Params, b: &Params, what: &str) {
    assert_eq!(a.0.len(), b.0.len(), "{what}: layer count");
    for (i, (ma, mb)) in a.0.iter().zip(b.0.iter()).enumerate() {
        assert_eq!(ma.data.len(), mb.data.len(), "{what}: layer {i} size");
        for (j, (va, vb)) in ma.data.iter().zip(mb.data.iter()).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: layer {i} elem {j}: {va} != {vb}"
            );
        }
    }
}

fn small_setup() -> (Arch, kfac::data::Dataset) {
    let arch = Arch::autoencoder(&[64, 24, 8, 24, 64], Act::Tanh);
    let ds = mnist_like::autoencoder_dataset(128, 8, 3);
    (arch, ds)
}

// ---------------------------------------------------------------------------
// Collective semantics (local transport)
// ---------------------------------------------------------------------------

#[test]
fn local_collective_reduce_broadcast_barrier() {
    let results = run_local_ranks(3, |rank, coll| {
        assert_eq!(coll.rank(), rank);
        assert_eq!(coll.size(), 3);
        // all-reduce: [rank+1, 1] summed over ranks 0..3 -> [6, 3], count 3
        let mut buf = [rank as f64 + 1.0, 1.0];
        let count = coll.all_reduce_sum(&mut buf).expect("all_reduce");
        // broadcast from a non-hub root exercises the hub relay path
        let mut b = if rank == 1 { [7.0, 8.0, 9.0] } else { [0.0; 3] };
        coll.broadcast(1, &mut b).expect("broadcast");
        coll.barrier().expect("barrier");
        (buf, count, b)
    });
    for (rank, (buf, count, b)) in results.into_iter().enumerate() {
        assert_eq!(buf, [6.0, 3.0], "rank {rank} reduce result");
        assert_eq!(count, 3, "rank {rank} contributor count");
        assert_eq!(b, [7.0, 8.0, 9.0], "rank {rank} broadcast result");
    }
}

// ---------------------------------------------------------------------------
// Keystone: ranks=1 distributed == single-process, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn ranks_1_distributed_run_is_bit_identical_to_plain_session() {
    // The entire dist stack engaged at size 1 — DistBackend wrapper,
    // KfacConfig::collective, session sharding — must be a no-op: same
    // per-step loss bits, same final params, same OptState snapshot.
    let (arch, ds) = small_setup();
    let seed = 17u64;
    let init = arch.sparse_init(&mut Rng::new(seed));
    // pinned synchronous so the comparison holds on the KFAC_ASYNC=1 leg
    let cfg = || KfacConfig { lambda0: 5.0, t_inv: 4, refresh_async: false, ..Default::default() };
    let ckpt_a = std::env::temp_dir().join("kfac_dist_tests/ident_plain.ckpt");
    let ckpt_b = std::env::temp_dir().join("kfac_dist_tests/ident_dist.ckpt");

    let mut plain_losses: Vec<u64> = Vec::new();
    let plain = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(10)
        .schedule(BatchSchedule::Fixed(64))
        .eval_every(5)
        .eval_rows(64)
        .polyak(0.99)
        .seed(seed)
        .params(init.clone())
        .optimizer(Kfac::new(&arch, cfg()))
        .checkpoint_every(10, &ckpt_a)
        .observer(|e| {
            if let Event::Step { info, .. } = e {
                plain_losses.push(info.loss.to_bits());
            }
        })
        .run();

    let (arch_ref, ds_ref, init_ref, ckpt_ref) = (&arch, &ds, &init, &ckpt_b);
    let mut dist_results = run_local_ranks(1, |rank, coll| {
        assert_eq!(coll.size(), 1);
        let mut inner = RustBackend::new(arch_ref.clone());
        let mut backend = DistBackend::new(&mut inner, coll.clone());
        let mut losses: Vec<u64> = Vec::new();
        let report = TrainSession::for_dataset(arch_ref.clone(), ds_ref)
            .iters(10)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(5)
            .eval_rows(64)
            .polyak(0.99)
            .seed(seed)
            .params(init_ref.clone())
            .optimizer(Kfac::new(arch_ref, KfacConfig { collective: Some(coll), ..cfg() }))
            .backend(&mut backend)
            .shard(rank, 1)
            .checkpoint_every(10, ckpt_ref)
            .observer(|e| {
                if let Event::Step { info, .. } = e {
                    losses.push(info.loss.to_bits());
                }
            })
            .run();
        (report, losses)
    });
    let (dist_report, dist_losses) = dist_results.remove(0);

    assert_eq!(plain_losses, dist_losses, "per-step loss trace diverged at ranks=1");
    assert!(!plain_losses.is_empty(), "no Step events observed");
    assert_params_bit_equal(&plain.params, &dist_report.params, "final params");
    assert!(plain.avg_params == dist_report.avg_params, "Polyak average diverged");
    let cka = checkpoint::load(&ckpt_a).unwrap();
    let ckb = checkpoint::load(&ckpt_b).unwrap();
    let _ = std::fs::remove_file(&ckpt_a);
    let _ = std::fs::remove_file(&ckpt_b);
    assert_eq!(cka.version, ckb.version, "checkpoint version diverged at ranks=1");
    assert_eq!(cka.opt, ckb.opt, "OptState snapshot diverged at ranks=1");
}

// ---------------------------------------------------------------------------
// 2-rank lockstep
// ---------------------------------------------------------------------------

#[test]
fn two_rank_training_stays_in_lockstep_and_learns() {
    // Both ranks see the same schedule and all-reduced curvature, so their
    // parameter trajectories must be bitwise identical with zero parameter
    // synchronization.
    let (arch, ds) = small_setup();
    let seed = 19u64;
    let init = arch.sparse_init(&mut Rng::new(seed));
    let (arch_ref, ds_ref, init_ref) = (&arch, &ds, &init);
    let results = run_local_ranks(2, |rank, coll| {
        let mut inner = RustBackend::new(arch_ref.clone());
        let mut backend = DistBackend::new(&mut inner, coll.clone());
        let cfg = KfacConfig {
            precond: precond::block_diag(),
            lambda0: 5.0,
            t_inv: 4,
            refresh_async: false,
            collective: Some(coll),
            ..Default::default()
        };
        let report = TrainSession::for_dataset(arch_ref.clone(), ds_ref)
            .iters(10)
            .schedule(BatchSchedule::Fixed(64))
            .eval_every(5)
            .eval_rows(64)
            .eval_initial()
            .polyak(0.99)
            .seed(seed)
            .params(init_ref.clone())
            .optimizer(Kfac::new(arch_ref, cfg))
            .backend(&mut backend)
            .shard(rank, 2)
            .run();
        report
    });
    let first_loss = results[0].log.first().unwrap().train_loss;
    let last_loss = results[0].log.last().unwrap().train_loss;
    assert!(last_loss.is_finite() && last_loss < first_loss, "2-rank run failed to learn");
    assert_params_bit_equal(&results[0].params, &results[1].params, "2-rank params");
    assert!(results[0].avg_params == results[1].avg_params, "2-rank Polyak average");
    for (ra, rb) in results[0].log.iter().zip(results[1].log.iter()) {
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "2-rank eval log diverged at iter {}",
            ra.iter
        );
    }
}

// ---------------------------------------------------------------------------
// Sharded inverse parity
// ---------------------------------------------------------------------------

#[test]
fn sharded_build_matches_plain_build_bitwise() {
    // Round-robin factorization + broadcast must install exactly the
    // inverse a single process would have built from the same statistics.
    let arch = Arch::autoencoder(&[16, 8, 4, 8, 16], Act::Tanh);
    let ds = mnist_like::autoencoder_dataset(64, 4, 5);
    let mut backend = RustBackend::new(arch.clone());
    let params = arch.sparse_init(&mut Rng::new(5));
    let (_, grads, stats) = backend.grad_and_stats(&params, &ds.x, &ds.y, 32, 9);
    let p = precond::block_diag();
    let gamma = 0.3;
    let want = p.build(&stats, gamma).apply(&grads);

    for n in [2usize, 3] {
        let (p_ref, stats_ref, grads_ref) = (&p, &stats, &grads);
        let outs = run_ranks_with(LocalGroup::create(n), &|_rank, coll| {
            let inv = sharded_build(p_ref.as_ref(), stats_ref, gamma, coll.as_ref())
                .expect("sharded build");
            inv.apply(grads_ref)
        });
        for (rank, got) in outs.iter().enumerate() {
            assert_params_bit_equal(&want, got, &format!("{n}-rank shard, rank {rank}"));
        }
    }
}

// ---------------------------------------------------------------------------
// Fault injection: dropped peer mid-training (degraded mode)
// ---------------------------------------------------------------------------

#[test]
fn dropped_peer_engages_degraded_mode_and_survivors_stay_consistent() {
    // Rank 2 leaves after 4 steps. Survivors must (a) keep training on
    // all-reduces with a shrunken contributor count, (b) reshard the k=8
    // and k=12 inverse refreshes over the live set {0, 1} — rank 2's
    // layers migrate, the refreshes land, and `inv_epoch` keeps advancing
    // with no recorded stalls — and (c) remain bitwise consistent with
    // each other throughout.
    let arch = Arch::autoencoder(&[16, 8, 4, 8, 16], Act::Tanh);
    let ds = mnist_like::autoencoder_dataset(64, 4, 7);
    let init = arch.sparse_init(&mut Rng::new(7));
    let (arch_ref, ds_ref, init_ref) = (&arch, &ds, &init);
    let group = LocalGroup::create_with_timeout(3, Duration::from_millis(300));
    let results = run_ranks_with(group, &|rank, coll| {
        let mut inner = RustBackend::new(arch_ref.clone());
        let mut backend = DistBackend::new(&mut inner, coll.clone());
        let cfg = KfacConfig {
            precond: precond::block_diag(),
            lambda0: 5.0,
            t_inv: 4,
            t_cov: 1,
            refresh_async: false,
            collective: Some(coll),
            ..Default::default()
        };
        let mut opt = Kfac::new(arch_ref, cfg);
        let mut params = init_ref.clone();
        let steps = if rank == 2 { 4 } else { 12 };
        let mut losses = Vec::new();
        for _ in 0..steps {
            let info = opt.step(&mut backend, &mut params, &ds_ref.x, &ds_ref.y);
            losses.push(info.loss);
        }
        (params, losses, opt.inverse_epoch(), opt.refresh_stalls(), backend.is_detached())
    });
    let (p0, l0, epoch0, stalls0, det0) = &results[0];
    let (p1, l1, epoch1, stalls1, det1) = &results[1];
    assert!(l0.iter().chain(l1.iter()).all(|l| l.is_finite()), "survivor loss went non-finite");
    // epoch tags: bootstrap builds at k=1..3, the k=4 boundary, then the
    // resharded k=8 and k=12 boundaries over the live set = 6 builds.
    // Before dynamic resharding these froze at 4 with 2 stalls (dead
    // static owner); recovery is the point of this pin.
    assert_eq!((*epoch0, *epoch1), (6, 6), "resharded refreshes must keep landing");
    assert_eq!((*stalls0, *stalls1), (0, 0), "no stalls once ownership reshards");
    assert_params_bit_equal(p0, p1, "survivor params");
    assert_eq!(
        l0[4..].iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        l1[4..].iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "survivor loss traces diverged after the drop"
    );
    // the hub excludes the peer; neither survivor detaches
    assert!(!det0 && !det1, "survivors must stay attached to the group");
}

// ---------------------------------------------------------------------------
// Fault injection: peer slower than the deadline
// ---------------------------------------------------------------------------

#[test]
fn slow_peer_is_excluded_at_the_deadline_without_deadlock() {
    let mut group = LocalGroup::create_with_timeout(2, Duration::from_millis(200));
    let c1 = group.pop().unwrap();
    let c0 = group.pop().unwrap();
    std::thread::scope(|s| {
        let slow = s.spawn(move || {
            // miss the hub's 200 ms window
            std::thread::sleep(Duration::from_millis(800));
            let mut buf = [1.0];
            c1.all_reduce_sum(&mut buf)
        });
        let mut buf = [2.0, 3.0];
        let count = c0.all_reduce_sum(&mut buf).expect("hub all_reduce");
        assert_eq!(count, 1, "slow peer must be excluded from the count");
        assert_eq!(buf, [2.0, 3.0], "hub keeps its own contribution");
        let peer = slow.join().unwrap();
        assert!(peer.is_err(), "excluded peer must see an error, got {peer:?}");
    });
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

fn tcp_opts(addr: String) -> TcpOpts {
    TcpOpts {
        addr,
        timeout: Duration::from_millis(2000),
        retries: 10,
        backoff: Duration::from_millis(20),
    }
}

#[test]
fn tcp_collective_round_trips_reduce_and_broadcast() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let opts = tcp_opts(listener.local_addr().unwrap().to_string());
    std::thread::scope(|s| {
        let spoke_opts = opts.clone();
        let spoke = s.spawn(move || {
            let c = TcpCollective::connect(1, 2, &spoke_opts).expect("spoke connect");
            let mut buf = [2.0, 20.0];
            let count = c.all_reduce_sum(&mut buf).expect("spoke all_reduce");
            let mut b = [0.0; 2];
            c.broadcast(0, &mut b).expect("spoke broadcast");
            c.barrier().expect("spoke barrier");
            (buf, count, b)
        });
        let hub = TcpCollective::accept_spokes(listener, 2, &opts).expect("hub accept");
        let mut buf = [1.0, 10.0];
        let count = hub.all_reduce_sum(&mut buf).expect("hub all_reduce");
        let mut b = [5.0, 6.0];
        hub.broadcast(0, &mut b).expect("hub broadcast");
        hub.barrier().expect("hub barrier");
        assert_eq!((buf, count), ([3.0, 30.0], 2), "hub reduce");
        let (sbuf, scount, sb) = spoke.join().unwrap();
        assert_eq!((sbuf, scount), ([3.0, 30.0], 2), "spoke reduce");
        assert_eq!(sb, [5.0, 6.0], "spoke broadcast payload");
    });
}

#[test]
fn tcp_startup_survives_garbage_clients() {
    // A port scanner / stray HTTP client must not poison membership: the
    // hub drops it (bad frame header) and keeps accepting.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = tcp_opts(addr.clone());
    {
        let mut garbage = TcpStream::connect(&addr).unwrap();
        use std::io::Write;
        garbage.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        // dropped here: the hub sees a nonsense tag/length and discards it
    }
    std::thread::scope(|s| {
        let spoke_opts = opts.clone();
        let spoke = s.spawn(move || {
            let c = TcpCollective::connect(1, 2, &spoke_opts).expect("spoke connect");
            let mut buf = [4.0];
            c.all_reduce_sum(&mut buf)
        });
        let hub = TcpCollective::accept_spokes(listener, 2, &opts).expect("hub accept");
        let mut buf = [3.0];
        assert_eq!(hub.all_reduce_sum(&mut buf), Ok(2), "hub reduce past garbage client");
        assert_eq!(buf, [7.0]);
        assert_eq!(spoke.join().unwrap(), Ok(2), "spoke reduce past garbage client");
    });
}

#[test]
fn tcp_dropped_spoke_shrinks_the_reduce_to_survivors() {
    // A spoke that joins and then dies (socket EOF = short read on the
    // hub) is excluded; the hub's reduce keeps serving with count 1 and
    // its buffer untouched.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let opts = TcpOpts {
        timeout: Duration::from_millis(400),
        ..tcp_opts(listener.local_addr().unwrap().to_string())
    };
    std::thread::scope(|s| {
        let spoke_opts = opts.clone();
        let spoke = s.spawn(move || {
            let c = TcpCollective::connect(1, 2, &spoke_opts).expect("spoke connect");
            // joined, then dies without ever participating
            drop(c);
        });
        let hub = TcpCollective::accept_spokes(listener, 2, &opts).expect("hub accept");
        spoke.join().unwrap();
        let mut buf = [1.5, 2.5];
        let count = hub.all_reduce_sum(&mut buf).expect("hub all_reduce");
        assert_eq!(count, 1, "dead spoke must be excluded");
        assert_eq!(buf, [1.5, 2.5], "hub keeps local values when alone");
        // permanently excluded: the next op still succeeds alone
        assert_eq!(hub.all_reduce_sum(&mut buf), Ok(1));
    });
}

// ---------------------------------------------------------------------------
// DistBackend detachment policy
// ---------------------------------------------------------------------------

#[test]
fn orphaned_backend_detaches_and_keeps_local_values() {
    // A spoke whose hub is gone degrades to single-process training:
    // first reduce fails and detaches, later reduces are local no-ops.
    let mut group = LocalGroup::create_with_timeout(2, Duration::from_millis(100));
    let c1 = group.pop().unwrap();
    drop(group); // hub handle gone -> channels disconnected
    let arch = Arch::autoencoder(&[16, 8, 16], Act::Tanh);
    let ds = mnist_like::autoencoder_dataset(32, 4, 11);
    let params = arch.sparse_init(&mut Rng::new(11));
    let mut inner = RustBackend::new(arch.clone());
    let coll: Arc<dyn Collective> = Arc::new(c1);
    let mut backend = DistBackend::new(&mut inner, coll);
    assert!(!backend.is_detached());
    let (loss_a, grads_a) = backend.grad(&params, &ds.x, &ds.y);
    assert!(backend.is_detached(), "dead hub must detach the backend");
    assert_eq!(backend.failures(), 1);
    // detached == local: identical to querying the inner backend directly
    let mut plain = RustBackend::new(arch.clone());
    let (loss_b, grads_b) = plain.grad(&params, &ds.x, &ds.y);
    assert_eq!(loss_a.to_bits(), loss_b.to_bits());
    assert_params_bit_equal(&grads_a, &grads_b, "detached grad");
    let (loss_c, _) = backend.grad(&params, &ds.x, &ds.y);
    assert_eq!(loss_c.to_bits(), loss_b.to_bits(), "detached backend stays local");
    assert_eq!(backend.failures(), 1, "no retries once detached");
}

// ---------------------------------------------------------------------------
// Error type surface
// ---------------------------------------------------------------------------

#[test]
fn dist_errors_render_descriptively() {
    assert_eq!(DistError::Timeout.to_string(), "collective timed out");
    assert_eq!(DistError::PeerLost(3).to_string(), "peer rank 3 lost");
    assert!(DistError::Io("refused".into()).to_string().contains("refused"));
    assert!(DistError::Protocol("bad len".into()).to_string().contains("bad len"));
}
