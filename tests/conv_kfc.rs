//! Dense-checked battery for the convolution layers and the KFC
//! curvature (Grosse & Martens 2016), mirroring the regime
//! `tests/ekfac_scales.rs` uses for EKFAC:
//!
//! - on data constructed to satisfy KFC's assumptions exactly
//!   (rank-one patches, one active spatial position per case), the
//!   factored block `Ω ⊗ Γ` equals the densely materialized
//!   per-example Fisher block to 1e-10 relative — this pins the
//!   `1/m` (sum over positions) Ω scaling and the `1/(mP)` Γ scaling
//!   against the ground-truth definition;
//! - a pointwise conv (1×1 input, 1×1 kernel) is mathematically a
//!   dense layer: a full `kfac_kfc` run on the conv arch is
//!   bit-identical to `kfac_blkdiag` on the equivalent dense arch;
//! - on a real conv net the KFC quadratic form tracks the exact
//!   per-example Fisher quadratic form within a loose multiplicative
//!   band (the factorization is approximate; a positions-count
//!   scaling bug would be off by ~P);
//! - `kfac_kfc` trains the `conv_clf` problem end to end, sync and
//!   async, and its optimizer state round-trips bit-exactly.

use kfac::backend::RustBackend;
use kfac::coordinator::session::Problem;
use kfac::coordinator::TrainSession;
use kfac::fisher::kfc::KfcInverse;
use kfac::fisher::precond;
use kfac::fisher::stats::RawStats;
use kfac::fisher::FisherInverse;
use kfac::linalg::kron::{kron, unvec, vec_mat};
use kfac::linalg::pack::ConvShape;
use kfac::linalg::Mat;
use kfac::nn::net::{Fwd, Net};
use kfac::nn::{Act, Arch, Layer, LossKind, Params};
use kfac::optim::{BatchSchedule, Kfac, KfacConfig, Optimizer};
use kfac::rng::Rng;

/// Densely materialized per-example Fisher block of conv layer `i`:
/// `F = (1/m) Σ_n vec(ΔW_n) vec(ΔW_n)ᵀ` with the rank-P per-example
/// gradient `ΔW_n = Σ_t g_{n,t} ā_{n,t}ᵀ` (weight sharing sums over
/// spatial positions; column-stacking vec).
fn dense_conv_fisher_block(fwd: &Fwd, gs: &[Mat], i: usize) -> Mat {
    let m = fwd.m;
    let p = fwd.abars[i].rows / m;
    let (rows, cols) = (gs[i].cols, fwd.abars[i].cols);
    let n = rows * cols;
    let mut f = Mat::zeros(n, n);
    for case in 0..m {
        let ab = fwd.abars[i].block(case * p, (case + 1) * p, 0, cols);
        let gb = gs[i].block(case * p, (case + 1) * p, 0, rows);
        let dw = gb.matmul_tn(&ab);
        let v = vec_mat(&dw);
        for a in 0..n {
            for b in 0..n {
                let acc = f.at(a, b) + v[a] * v[b] / m as f64;
                f.set(a, b, acc);
            }
        }
    }
    f
}

#[test]
fn kfc_factorization_is_exact_on_data_satisfying_its_assumptions() {
    // ā_{n,t} = a0 for every case and position; g_{n,t} = b_n·g0 at one
    // case-dependent position, zero elsewhere. Then the per-example
    // gradient is rank one along (a0, g0) and the spatial sums
    // factorize, so Ω ⊗ Γ must equal the dense per-example Fisher
    // block exactly — any Ω/Γ normalization slip (1/m vs 1/(mP))
    // breaks this identity by a factor of P.
    let (m, p_pos, ka, dg) = (6usize, 4usize, 3usize, 2usize);
    let a0 = [0.7, -1.3, 1.0]; // last coordinate plays the homogeneous 1
    let g0 = [0.4, 2.0];
    let b: Vec<f64> = (0..m).map(|n| 0.5 + n as f64).collect();
    let abar = Mat::from_fn(m * p_pos, ka, |_, c| a0[c]);
    let g = Mat::from_fn(m * p_pos, dg, |r, c| {
        let (case, t) = (r / p_pos, r % p_pos);
        if t == case % p_pos {
            b[case] * g0[c]
        } else {
            0.0
        }
    });
    let fwd = Fwd { m, abars: vec![abar], ss: Vec::new() };
    let gs = vec![g];
    let st = RawStats::from_batch(&fwd, &gs);
    let f_dense = dense_conv_fisher_block(&fwd, &gs, 0);
    let f_kfc = kron(&st.aa[0], &st.gg[0]);
    let scale = f_dense.max_abs().max(1e-300);
    let err = f_kfc.sub(&f_dense).max_abs() / scale;
    assert!(err < 1e-10, "Ω ⊗ Γ must be exact here, rel err {err}");
}

#[test]
fn kfc_factors_match_their_patchwise_definitions_on_a_real_conv_net() {
    // On genuine forward/backward output (overlapping stride-1 patches,
    // padding, the homogeneous column, P = 16 positions), the vectorized
    // statistics must equal the definitional per-case/per-position sums
    // `Ω = (1/m) Σ_n Σ_t ā ā ᵀ` and `Γ = (1/(mP)) Σ_n Σ_t g g ᵀ`
    // computed by explicit loops.
    let shape = ConvShape { in_h: 4, in_w: 4, in_c: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
    let arch = Arch::from_layers(
        vec![
            Layer::Conv2d { shape, out_c: 3, act: Act::Tanh },
            Layer::Dense { d_in: 48, d_out: 4, act: Act::Identity },
        ],
        LossKind::SoftmaxCe,
    );
    let net = Net::new(arch.clone());
    let mut rng = Rng::new(11);
    let p = arch.glorot_init(&mut rng);
    let x = Mat::randn(64, arch.widths[0], 1.0, &mut rng);
    let fwd = net.forward(&p, &x);
    let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(12));
    let st = RawStats::from_batch(&fwd, &gs);
    let m = fwd.m;
    let p_pos = fwd.abars[0].rows / m;
    assert_eq!(p_pos, shape.positions(), "conv layer must capture one row per position");
    let (ka, dg) = (fwd.abars[0].cols, gs[0].cols);
    let mut omega = Mat::zeros(ka, ka);
    let mut gamma = Mat::zeros(dg, dg);
    for row in 0..m * p_pos {
        for i in 0..ka {
            for j in 0..ka {
                let acc = omega.at(i, j) + fwd.abars[0].at(row, i) * fwd.abars[0].at(row, j);
                omega.set(i, j, acc);
            }
        }
        for i in 0..dg {
            for j in 0..dg {
                let acc = gamma.at(i, j) + gs[0].at(row, i) * gs[0].at(row, j);
                gamma.set(i, j, acc);
            }
        }
    }
    let omega = omega.scale(1.0 / m as f64);
    let gamma = gamma.scale(1.0 / (m * p_pos) as f64);
    let oerr = st.aa[0].sub(&omega).max_abs() / omega.max_abs().max(1e-300);
    let gerr = st.gg[0].sub(&gamma).max_abs() / gamma.max_abs().max(1e-300);
    assert!(oerr < 1e-12, "Ω definition mismatch: rel err {oerr}");
    assert!(gerr < 1e-12, "Γ definition mismatch: rel err {gerr}");
    // the homogeneous corner of Ω is exactly the position count
    assert_eq!(st.aa[0].at(ka - 1, ka - 1), p_pos as f64);
}

#[test]
fn pointwise_conv_kfc_is_bit_identical_to_dense_blkdiag() {
    // A 1×1 conv on a 1×1 spatial grid *is* a dense layer (P = 1, the
    // im2col view is the identity). The whole kfac_kfc trajectory on
    // the conv arch must therefore be bitwise the kfac_blkdiag
    // trajectory on the equivalent dense arch — forward capture,
    // statistics, damped inverses, and updates all reduce exactly.
    let shape = ConvShape { in_h: 1, in_w: 1, in_c: 5, kh: 1, kw: 1, stride: 1, pad: 0 };
    let conv_arch = Arch::from_layers(
        vec![
            Layer::Conv2d { shape, out_c: 4, act: Act::Tanh },
            Layer::Dense { d_in: 4, d_out: 3, act: Act::Identity },
        ],
        LossKind::SoftmaxCe,
    );
    let dense_arch = Arch::new(vec![5, 4, 3], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
    let mut rng = Rng::new(21);
    let init = dense_arch.glorot_init(&mut rng);
    let x = Mat::randn(32, 5, 1.0, &mut rng);
    let y = {
        let net = Net::new(dense_arch.clone());
        let fwd = net.forward(&init, &x);
        // one-hot targets from the model's own argmax keep this test
        // self-contained and deterministic
        let probs = fwd.ss.last().unwrap();
        Mat::from_fn(32, 3, |r, c| {
            let row: Vec<f64> = (0..3).map(|j| probs.at(r, j)).collect();
            let arg = (0..3).max_by(|&a, &b| row[a].total_cmp(&row[b])).unwrap();
            if c == arg {
                1.0
            } else {
                0.0
            }
        })
    };
    let run = |arch: &Arch, pre: kfac::fisher::PrecondRef| {
        let mut backend = RustBackend::new(arch.clone());
        let cfg = KfacConfig { precond: pre, lambda0: 10.0, t_inv: 3, ..Default::default() };
        let mut opt = Kfac::new(arch, cfg);
        let mut params = init.clone();
        let mut losses = Vec::new();
        for _ in 0..8 {
            losses.push(opt.step(&mut backend, &mut params, &x, &y).loss);
        }
        (params, losses)
    };
    let (pc, lc) = run(&conv_arch, precond::from_name("kfc").expect("kfc registered"));
    let (pd, ld) = run(&dense_arch, precond::from_name("blkdiag").unwrap());
    assert_eq!(
        lc.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        ld.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "loss trajectories diverged"
    );
    assert!(pc == pd, "final params diverged");
}

#[test]
fn kfc_inverse_application_matches_dense_kron_on_conv_clf_arch() {
    // Application check at the 1e-8 regime on the CLI-facing conv
    // problem's real architecture.
    let arch = Problem::ConvClf.arch();
    let net = Net::new(arch.clone());
    let mut rng = Rng::new(31);
    let p = arch.glorot_init(&mut rng);
    let x = Mat::randn(24, arch.widths[0], 1.0, &mut rng);
    let fwd = net.forward(&p, &x);
    let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(32));
    let st = RawStats::from_batch(&fwd, &gs);
    let gamma = 0.3;
    let inv = KfcInverse::build(&st, gamma);
    let grads = Params(p.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
    let got = inv.apply(&grads);
    // dense-check the conv layer only — the dense head is covered by
    // the existing blockdiag battery
    let (ad, gd) = kfac::fisher::damping::damped_factors(&st.aa[0], &st.gg[0], gamma);
    let dense = kron(&ad, &gd).inverse();
    let want = unvec(&dense.matvec(&vec_mat(&grads.0[0])), grads.0[0].rows, grads.0[0].cols);
    let err = got.0[0].sub(&want).max_abs();
    assert!(err < 1e-8, "conv layer application err {err}");
}

fn conv_clf_session(async_refresh: bool, iters: usize) -> kfac::coordinator::TrainReport {
    let arch = Problem::ConvClf.arch();
    let cfg = KfacConfig {
        precond: precond::from_name("kfc").expect("kfc registered"),
        lambda0: 15.0,
        refresh_async: async_refresh,
        ..Default::default()
    };
    let opt = Kfac::new(&arch, cfg);
    TrainSession::for_problem(Problem::ConvClf)
        .data(256, 3)
        .iters(iters)
        .schedule(BatchSchedule::Fixed(128))
        .eval_every(5)
        .eval_rows(128)
        .seed(4)
        .optimizer(opt)
        .run()
}

#[test]
fn kfac_kfc_trains_conv_clf_end_to_end_sync() {
    let report = conv_clf_session(false, 20);
    let first = report.log.first().unwrap().train_err;
    let last = report.log.last().unwrap().train_err;
    assert_eq!(report.iters_run, 20);
    assert!(report.log.iter().all(|r| r.train_loss.is_finite()), "loss went non-finite");
    assert!(last < first, "conv_clf error did not decrease: {first} -> {last}");
}

#[test]
fn kfac_kfc_trains_conv_clf_end_to_end_async() {
    // KFAC_ASYNC=1 equivalent: epoch-tagged background rebuilds. The
    // staleness contract makes the trajectory different from sync, but
    // it must still train.
    let report = conv_clf_session(true, 20);
    let first = report.log.first().unwrap().train_err;
    let last = report.log.last().unwrap().train_err;
    assert_eq!(report.iters_run, 20);
    assert!(report.log.iter().all(|r| r.train_loss.is_finite()), "loss went non-finite");
    assert!(last <= first, "conv_clf (async) error increased: {first} -> {last}");
}

#[test]
fn kfc_state_roundtrips_bit_exact_on_conv_arch() {
    // KFC introduces no new optimizer-state keys: the standard KFACCKPT
    // snapshot restores a conv-arch kfac_kfc run bit-exactly
    // mid-interval (same contract `tests/session.rs` pins for dense).
    let arch = Problem::ConvClf.arch();
    let ds = kfac::data::mnist_like::classification_dataset(64, 16, 5);
    let mut backend = RustBackend::new(arch.clone());
    let cfg = KfacConfig {
        precond: precond::from_name("kfc").unwrap(),
        lambda0: 10.0,
        t_inv: 4,
        ..Default::default()
    };
    let mut opt_a = Kfac::new(&arch, cfg.clone());
    let mut params_a = arch.sparse_init(&mut Rng::new(6));
    for _ in 0..6 {
        opt_a.step(&mut backend, &mut params_a, &ds.x, &ds.y);
    }
    let snapshot = opt_a.state();
    let mut params_b = params_a.clone();
    let mut opt_b = Kfac::new(&arch, cfg);
    opt_b.load_state(&snapshot).expect("conv-arch kfc state loads");
    for s in 0..5 {
        let ia = opt_a.step(&mut backend, &mut params_a, &ds.x, &ds.y);
        let ib = opt_b.step(&mut backend, &mut params_b, &ds.x, &ds.y);
        assert_eq!(ia.loss.to_bits(), ib.loss.to_bits(), "loss diverged at step {s}");
        assert!(params_a == params_b, "params diverged at step {s}");
    }
}
