//! Symmetric eigendecomposition and PSD matrix functions.
//!
//! The inverse-refresh pipeline (paper §6.3/§8: two eigendecompositions
//! per layer per `T₃` refresh for the tridiagonal and EKFAC paths)
//! funnels through this module, so it offers three paths with one
//! contract (`A = V diag(w) Vᵀ`, `w` ascending):
//!
//! - **Blocked** ([`SymEig::new_blocked`], the `n > 24` production
//!   path): Householder tridiagonalization in panels of [`NB`] columns
//!   (the LAPACK `dsytrd`/`dlatrd` decomposition), with each panel's
//!   rank-2b trailing update lowered onto two pool-parallel
//!   [`gemm`] calls and the orthogonal factor accumulated per panel in
//!   compact-WY form (`Q ← Q(I − V T Vᵀ)`, two more GEMMs). The
//!   implicit-shift QL stage records each step's plane rotations and
//!   applies them to the eigenvector rows in parallel over
//!   [`par::par_ranges`]. The panel's memory-bound correction GEMVs
//!   (`w ← A·v − W·(Vᵀv) − V·(Wᵀv)` traffic) are fused into single
//!   row passes through the SIMD dispatch seam ([`simd::fused_tdot2`],
//!   [`simd::fused_apply2`]).
//! - **Unblocked QL** ([`SymEig::new_ql`]): the classic scalar
//!   tred2/tql2 pair (EISPACK/NR layout), kept as the reference the
//!   blocked path is property-tested against at 1e-9.
//! - **Jacobi** ([`SymEig::new_jacobi`]): cyclic Jacobi with threshold
//!   sweeps — the `n ≤ 24` dispatch target, the independent
//!   cross-check, and the fallback when tql2 exhausts its iteration
//!   budget on a pathological spectrum (instead of aborting a whole
//!   training run; see [`tql2_fallback_count`]).
//!
//! All paths are deterministic and thread-count-invariant: parallel
//! loops only partition disjoint row ranges, so `KFAC_THREADS=1` and
//! `KFAC_POOL=0` produce bit-identical decompositions.

use super::{gemm, simd, Mat};
use crate::par::{self, SendPtr};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Panel width of the blocked Householder reduction: wide enough that
/// the rank-2b trailing GEMMs dominate the panel's BLAS-2 work, small
/// enough that the panel stays cache-resident.
pub const NB: usize = 32;

/// Largest size routed to cyclic Jacobi by [`SymEig::new`].
const JACOBI_MAX: usize = 24;

/// tql2 gives up on an eigenvalue after this many implicit-shift
/// iterations and the caller falls back to Jacobi.
const TQL2_MAX_ITER: usize = 50;

/// Grain for the O(n) Jacobi rotation loops: far above any factor size
/// K-FAC actually inverts, so the row/column sweeps only split across
/// the pool for very large fallback matrices where an O(n) loop
/// amortizes a dispatch.
const ROT_MIN_CHUNK: usize = 2048;

static TQL2_FALLBACKS: AtomicUsize = AtomicUsize::new(0);
static TQL2_FALLBACK_LOGGED: AtomicBool = AtomicBool::new(false);

/// Process-wide count of QL→Jacobi fallbacks (pathological spectra
/// that exhausted tql2's iteration budget). Observers/metrics code can
/// poll this; the first occurrence is also logged to stderr once.
pub fn tql2_fallback_count() -> usize {
    TQL2_FALLBACKS.load(Ordering::Relaxed)
}

fn note_tql2_fallback(n: usize) {
    TQL2_FALLBACKS.fetch_add(1, Ordering::Relaxed);
    if !TQL2_FALLBACK_LOGGED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "kfac: tql2 exhausted its iteration budget on an n={n} matrix; \
             falling back to the Jacobi eigensolver (logged once per process, \
             see linalg::eig::tql2_fallback_count)"
        );
    }
}

/// Eigendecomposition `A = V diag(w) Vᵀ` of a symmetric matrix.
pub struct SymEig {
    /// Eigenvalues, ascending.
    pub w: Vec<f64>,
    /// Eigenvectors as **columns** of `v`.
    pub v: Mat,
}

impl SymEig {
    /// Symmetric eigendecomposition. Blocked, pool-parallel Householder
    /// tridiagonalization + implicit-shift QL for matrices big enough
    /// for Jacobi's constant to hurt; cyclic Jacobi below that. Rejects
    /// non-finite input with a descriptive panic (the per-layer inverse
    /// builders name the offending layer before calling in here).
    pub fn new(a: &Mat) -> SymEig {
        assert!(a.is_square(), "eig: non-square");
        assert!(
            a.all_finite(),
            "SymEig::new: non-finite entries in a {}x{} matrix \
             (NaN/Inf-poisoned curvature statistics?)",
            a.rows,
            a.cols
        );
        if a.rows > JACOBI_MAX {
            Self::new_blocked(a)
        } else {
            Self::new_jacobi(a)
        }
    }

    // -----------------------------------------------------------------
    // blocked path
    // -----------------------------------------------------------------

    /// Blocked Householder tridiagonalization (panels of [`NB`]
    /// columns, rank-2b trailing updates as two GEMMs, compact-WY
    /// accumulation of `Q`) followed by implicit-shift QL with
    /// row-parallel rotation application. Falls back to Jacobi on the
    /// original matrix if QL exhausts its iteration budget.
    pub fn new_blocked(a: &Mat) -> SymEig {
        match Self::try_blocked(a, TQL2_MAX_ITER) {
            Ok(e) => e,
            Err(()) => Self::fallback_to_jacobi(a),
        }
    }

    /// Test hook: the blocked path with an explicit tql2 iteration cap
    /// (cap 0 deterministically exercises the Jacobi fallback).
    #[doc(hidden)]
    pub fn new_blocked_with_iter_cap(a: &Mat, max_iter: usize) -> SymEig {
        match Self::try_blocked(a, max_iter) {
            Ok(e) => e,
            Err(()) => Self::fallback_to_jacobi(a),
        }
    }

    fn fallback_to_jacobi(a: &Mat) -> SymEig {
        note_tql2_fallback(a.rows);
        Self::new_jacobi(a)
    }

    fn try_blocked(a: &Mat, max_iter: usize) -> Result<SymEig, ()> {
        assert!(a.is_square(), "eig: non-square");
        let n = a.rows;
        if n == 0 {
            return Ok(SymEig { w: Vec::new(), v: Mat::zeros(0, 0) });
        }
        if n <= 2 {
            // already tridiagonal
            let mut z = Mat::eye(n);
            let mut d: Vec<f64> = (0..n).map(|i| a.at(i, i)).collect();
            let mut e = vec![0.0f64; n];
            if n == 2 {
                e[0] = 0.5 * (a.at(0, 1) + a.at(1, 0));
            }
            tql2(&mut d, &mut e, &mut z, max_iter)?;
            return Ok(Self::sorted(d, z));
        }

        let mut z = a.symmetrize();
        // Householder vectors: column k in rows k+1..n with a stored
        // unit at (k+1, k); taus alongside.
        let mut vs = Mat::zeros(n, n);
        let mut taus = vec![0.0f64; n];
        let mut d = vec![0.0f64; n];
        // e[i] = subdiagonal T[i+1, i]; e[n-1] stays 0.
        let mut e = vec![0.0f64; n];

        let mut k0 = 0;
        while k0 < n - 2 {
            let bp = NB.min(n - 2 - k0);
            // W panel (dlatrd): column j holds w_j on rows k0+j+1..n.
            let mut w = Mat::zeros(n, bp);
            for j in 0..bp {
                let k = k0 + j;
                // (1) bring column k up to date with the panel's
                // earlier rank-2 corrections:
                //   z[r,k] -= Σ_t V[r,t]·W[k,t] + W[r,t]·V[k,t]
                // applied as ONE fused pass over the rows (both rank-j
                // corrections per row, contiguous panel-row reads)
                // through the SIMD dispatch seam.
                if j > 0 {
                    let wc = w.cols;
                    simd::fused_apply2(
                        n - k,
                        j,
                        &vs.data[k * n + k0..],
                        n,
                        &w.data[k * wc..],
                        wc,
                        &w.data[k * wc..k * wc + j],
                        &vs.data[k * n + k0..k * n + k0 + j],
                        &mut z.data[k * n + k..],
                        n,
                    );
                }
                d[k] = z.at(k, k);
                // (2) reflector annihilating z[k+2.., k]
                let (beta, tau) = make_householder(&z, &mut vs, k);
                e[k] = beta;
                taus[k] = tau;
                // (3) w_j = τ(Z v − V(Wᵀv) − W(Vᵀv)) − ½τ(wᵀv)v
                compute_w_column(&z, &vs, &mut w, k0, j, k, tau);
            }
            // (4) rank-2b trailing update, two GEMMs straight into z:
            //   z[kend.., kend..] -= V₂W₂ᵀ + W₂V₂ᵀ
            let kend = k0 + bp;
            trailing_update(&mut z, &vs, &w, k0, bp, kend);
            k0 = kend;
        }
        d[n - 2] = z.at(n - 2, n - 2);
        d[n - 1] = z.at(n - 1, n - 1);
        e[n - 2] = 0.5 * (z.at(n - 1, n - 2) + z.at(n - 2, n - 1));
        e[n - 1] = 0.0;

        let mut q = accumulate_q(&vs, &taus, n);
        tql2(&mut d, &mut e, &mut q, max_iter)?;
        Ok(Self::sorted(d, q))
    }

    // -----------------------------------------------------------------
    // unblocked QL reference
    // -----------------------------------------------------------------

    /// tred2: reduce symmetric `a` to tridiagonal (d, e) with accumulated
    /// orthogonal transform in `z`; then tql2: implicit-shift QL on the
    /// tridiagonal, rotating `z`'s columns into eigenvectors. This is
    /// the scalar reference implementation the blocked path is tested
    /// against; on tql2 iteration exhaustion it falls back to Jacobi on
    /// the original matrix instead of panicking mid-training.
    pub fn new_ql(a: &Mat) -> SymEig {
        match Self::try_ql(a, TQL2_MAX_ITER) {
            Ok(e) => e,
            Err(()) => Self::fallback_to_jacobi(a),
        }
    }

    /// Test hook: the unblocked path with an explicit tql2 iteration cap.
    #[doc(hidden)]
    pub fn new_ql_with_iter_cap(a: &Mat, max_iter: usize) -> SymEig {
        match Self::try_ql(a, max_iter) {
            Ok(e) => e,
            Err(()) => Self::fallback_to_jacobi(a),
        }
    }

    fn try_ql(a: &Mat, max_iter: usize) -> Result<SymEig, ()> {
        assert!(a.is_square(), "eig: non-square");
        let n = a.rows;
        if n == 0 {
            return Ok(SymEig { w: Vec::new(), v: Mat::zeros(0, 0) });
        }
        let mut z = a.symmetrize();
        let mut d = vec![0.0f64; n];
        let mut e = vec![0.0f64; n];

        // --- tred2 (Householder reduction, EISPACK/NR layout) ---
        for i in (1..n).rev() {
            let l = i - 1;
            let mut h = 0.0;
            if l > 0 {
                let mut scale = 0.0;
                for k in 0..=l {
                    scale += z.at(i, k).abs();
                }
                if scale == 0.0 {
                    e[i] = z.at(i, l);
                } else {
                    for k in 0..=l {
                        let v = z.at(i, k) / scale;
                        z.set(i, k, v);
                        h += v * v;
                    }
                    let mut f = z.at(i, l);
                    let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                    e[i] = scale * g;
                    h -= f * g;
                    z.set(i, l, f - g);
                    f = 0.0;
                    for j in 0..=l {
                        z.set(j, i, z.at(i, j) / h);
                        let mut g = 0.0;
                        for k in 0..=j {
                            g += z.at(j, k) * z.at(i, k);
                        }
                        for k in (j + 1)..=l {
                            g += z.at(k, j) * z.at(i, k);
                        }
                        e[j] = g / h;
                        f += e[j] * z.at(i, j);
                    }
                    let hh = f / (h + h);
                    for j in 0..=l {
                        let f = z.at(i, j);
                        let g = e[j] - hh * f;
                        e[j] = g;
                        for k in 0..=j {
                            let v = z.at(j, k) - (f * e[k] + g * z.at(i, k));
                            z.set(j, k, v);
                        }
                    }
                }
            } else {
                e[i] = z.at(i, l);
            }
            d[i] = h;
        }
        d[0] = 0.0;
        e[0] = 0.0;
        for i in 0..n {
            if d[i] != 0.0 {
                // accumulate transform
                for j in 0..i {
                    let mut g = 0.0;
                    for k in 0..i {
                        g += z.at(i, k) * z.at(k, j);
                    }
                    for k in 0..i {
                        let v = z.at(k, j) - g * z.at(k, i);
                        z.set(k, j, v);
                    }
                }
            }
            d[i] = z.at(i, i);
            z.set(i, i, 1.0);
            for j in 0..i {
                z.set(j, i, 0.0);
                z.set(i, j, 0.0);
            }
        }

        // shift to e[i] = subdiag(i, i+1), then QL
        for i in 1..n {
            e[i - 1] = e[i];
        }
        e[n - 1] = 0.0;
        tql2(&mut d, &mut e, &mut z, max_iter)?;
        Ok(Self::sorted(d, z))
    }

    // -----------------------------------------------------------------
    // Jacobi
    // -----------------------------------------------------------------

    /// Cyclic Jacobi with threshold sweeps. `a` must be symmetric. The
    /// per-rotation row/column updates run over `par::par_ranges`
    /// (inert below `ROT_MIN_CHUNK` rows, so the usual layer-sized
    /// inputs stay inline on the caller).
    pub fn new_jacobi(a: &Mat) -> SymEig {
        assert!(a.is_square(), "eig: non-square");
        let n = a.rows;
        let mut m = a.symmetrize();
        let mut v = Mat::eye(n);
        if n <= 1 {
            return SymEig { w: (0..n).map(|i| m.at(i, i)).collect(), v };
        }
        let max_sweeps = 64;
        for _sweep in 0..max_sweeps {
            // off-diagonal Frobenius norm
            let mut off = 0.0;
            for r in 0..n {
                for c in (r + 1)..n {
                    off += m.at(r, c) * m.at(r, c);
                }
            }
            let scale = m.frob_norm().max(1e-300);
            if off.sqrt() <= 1e-14 * scale {
                break;
            }
            for p in 0..n - 1 {
                for q in p + 1..n {
                    let apq = m.at(p, q);
                    if apq.abs() <= 1e-300 {
                        continue;
                    }
                    let app = m.at(p, p);
                    let aqq = m.at(q, q);
                    // rotation angle
                    let theta = 0.5 * (aqq - app) / apq;
                    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    rotate_cols(&mut m, p, q, c, s);
                    rotate_rows(&mut m, p, q, c, s);
                    // accumulate eigenvectors
                    rotate_cols(&mut v, p, q, c, s);
                }
            }
        }
        // extract + sort ascending
        let d: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
        Self::sorted(d, v)
    }

    /// Sort the spectrum ascending (total order, so NaN-poisoned input
    /// degrades to a garbage-but-ordered result instead of a panic) and
    /// permute the eigenvector columns to match.
    fn sorted(d: Vec<f64>, z: Mat) -> SymEig {
        let n = d.len();
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&i, &j| d[i].total_cmp(&d[j]));
        let w: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
        let mut vs = Mat::zeros(n, n);
        for (new_c, &old_c) in idx.iter().enumerate() {
            for r in 0..n {
                vs.set(r, new_c, z.at(r, old_c));
            }
        }
        SymEig { w, v: vs }
    }

    // -----------------------------------------------------------------
    // spectral functions
    // -----------------------------------------------------------------

    /// Apply a scalar function to the spectrum: `V f(diag(w)) Vᵀ`. The
    /// column rescaling runs row-parallel; the reconstruction GEMM is
    /// pool-parallel already.
    pub fn matrix_fn(&self, f: impl Fn(f64) -> f64) -> Mat {
        let n = self.w.len();
        let fw: Vec<f64> = self.w.iter().map(|&w| f(w)).collect();
        let mut vf = self.v.clone();
        {
            let ptr = SendPtr(vf.data.as_mut_ptr());
            let chunk = par::chunk_for_flops(n, n.max(1));
            par::par_ranges(n, chunk, |lo, hi| {
                for r in lo..hi {
                    // SAFETY: disjoint row ranges from par_ranges.
                    let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(r * n), n) };
                    for (c, rv) in row.iter_mut().enumerate() {
                        *rv *= fw[c];
                    }
                }
            });
        }
        vf.matmul_nt(&self.v).symmetrize()
    }

    /// PSD square root (negative eigenvalues clamped to 0).
    pub fn sqrt_psd(&self) -> Mat {
        self.matrix_fn(|w| w.max(0.0).sqrt())
    }

    /// PSD inverse square root with floor `eps` on eigenvalues.
    pub fn inv_sqrt_psd(&self, eps: f64) -> Mat {
        self.matrix_fn(|w| 1.0 / w.max(eps).sqrt())
    }

    /// Reconstruct the matrix (round-trip check).
    pub fn reconstruct(&self) -> Mat {
        self.matrix_fn(|w| w)
    }
}

/// Top-`k` eigenpairs (by |λ|) of a symmetric matrix via deterministic
/// subspace iteration — the O(n²·k·iters) workhorse of the iterative
/// K-FAC rank-k Woodbury correction, where a full O(n³) [`SymEig`] of
/// the drift matrix would defeat the point of not refactorizing.
///
/// Returns `(lambdas, vectors)` with `vectors` an `n×k'` matrix whose
/// columns are orthonormal eigenvector estimates and `lambdas[j]` the
/// matching Rayleigh quotients, ordered by descending `|λ|`. `k'` may
/// be below `k`: pairs whose `|λ|` falls under `tol_rel · max|λ|` (or
/// whose subspace direction degenerates) are dropped, so a zero matrix
/// yields `k' = 0`.
///
/// Deterministic by construction: the start subspace is the identity
/// columns at the `k` largest-|diagonal| entries (ties broken by
/// index), the iteration count is fixed, and the final Rayleigh–Ritz
/// rotation uses [`SymEig`] on a `k×k` projection — no randomness, so
/// checkpoint replay reproduces results bit-for-bit.
pub fn sym_topk(m: &Mat, k: usize, iters: usize, tol_rel: f64) -> (Vec<f64>, Mat) {
    assert_eq!(m.rows, m.cols, "sym_topk: matrix must be square");
    let n = m.rows;
    let k = k.min(n);
    if k == 0 {
        return (Vec::new(), Mat::zeros(n, 0));
    }
    // Start subspace: unit vectors at the k largest-|diagonal| indices.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        m.at(b, b).abs().partial_cmp(&m.at(a, a).abs()).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut cols: Vec<Vec<f64>> = order[..k]
        .iter()
        .map(|&i| {
            let mut e = vec![0.0; n];
            e[i] = 1.0;
            e
        })
        .collect();
    let orthonormalize = |cols: &mut Vec<Vec<f64>>| {
        let mut kept: Vec<Vec<f64>> = Vec::with_capacity(cols.len());
        for c in cols.drain(..) {
            let mut c = c;
            for b in &kept {
                let d: f64 = c.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
                for (x, y) in c.iter_mut().zip(b.iter()) {
                    *x -= d * y;
                }
            }
            let nrm = c.iter().map(|x| x * x).sum::<f64>().sqrt();
            if nrm > 1e-300 {
                for x in c.iter_mut() {
                    *x /= nrm;
                }
                kept.push(c);
            }
        }
        *cols = kept;
    };
    for _ in 0..iters {
        let mut next: Vec<Vec<f64>> = cols.iter().map(|c| m.matvec(c)).collect();
        orthonormalize(&mut next);
        if next.is_empty() {
            return (Vec::new(), Mat::zeros(n, 0));
        }
        cols = next;
    }
    // Rayleigh–Ritz: eigendecompose the k×k projection Vᵀ M V and
    // rotate the subspace into eigenvector estimates.
    let kk = cols.len();
    let mv: Vec<Vec<f64>> = cols.iter().map(|c| m.matvec(c)).collect();
    let mut proj = Mat::zeros(kk, kk);
    for p in 0..kk {
        for q in 0..kk {
            let d: f64 = cols[p].iter().zip(mv[q].iter()).map(|(x, y)| x * y).sum();
            proj.set(p, q, d);
        }
    }
    let e = SymEig::new(&proj.symmetrize());
    let mut ritz: Vec<(f64, Vec<f64>)> = (0..kk)
        .map(|j| {
            let mut v = vec![0.0; n];
            for (p, c) in cols.iter().enumerate() {
                let w = e.v.at(p, j);
                for (vi, ci) in v.iter_mut().zip(c.iter()) {
                    *vi += w * ci;
                }
            }
            (e.w[j], v)
        })
        .collect();
    ritz.sort_by(|a, b| b.0.abs().partial_cmp(&a.0.abs()).unwrap_or(std::cmp::Ordering::Equal));
    let lmax = ritz.first().map(|(l, _)| l.abs()).unwrap_or(0.0);
    ritz.retain(|(l, _)| l.abs() > tol_rel * lmax && l.abs() > 1e-300);
    let lambdas: Vec<f64> = ritz.iter().map(|(l, _)| *l).collect();
    let mut vecs = Mat::zeros(n, lambdas.len());
    for (j, (_, v)) in ritz.iter().enumerate() {
        for (i, &vi) in v.iter().enumerate() {
            vecs.set(i, j, vi);
        }
    }
    (lambdas, vecs)
}

// ---------------------------------------------------------------------
// shared tql2 core
// ---------------------------------------------------------------------

/// Implicit-shift QL on a tridiagonal (`d` diagonal, `e[i]` the
/// subdiagonal `T[i+1,i]`, `e[n-1]` ignored), rotating `z`'s columns
/// into eigenvectors. Each QL step's plane rotations are recorded and
/// then applied to `z`'s rows in one parallel pass (identical
/// per-element arithmetic to the scalar version, so results are
/// bit-identical at any thread count). `Err` on iteration exhaustion —
/// the callers fall back to Jacobi on the original matrix.
fn tql2(d: &mut [f64], e: &mut [f64], z: &mut Mat, max_iter: usize) -> Result<(), ()> {
    let n = d.len();
    if n == 0 {
        return Ok(());
    }
    let mut rots: Vec<(usize, f64, f64)> = Vec::new();
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small subdiagonal element
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > max_iter {
                return Err(());
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r } else { -r };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            rots.clear();
            for i in (l..m).rev() {
                let f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                rots.push((i, c, s));
            }
            apply_rotations(z, &rots);
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

/// Apply a QL step's recorded plane rotations (in order) to every row
/// of `z`, rows split across the pool.
fn apply_rotations(z: &mut Mat, rots: &[(usize, f64, f64)]) {
    if rots.is_empty() {
        return;
    }
    let rows = z.rows;
    let cols = z.cols;
    let ptr = SendPtr(z.data.as_mut_ptr());
    let chunk = par::chunk_for_flops(rows, 6 * rots.len());
    par::par_ranges(rows, chunk, |lo, hi| {
        for k in lo..hi {
            // SAFETY: disjoint row ranges from par_ranges.
            let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(k * cols), cols) };
            for &(i, c, s) in rots {
                let f = row[i + 1];
                let zi = row[i];
                row[i + 1] = s * zi + c * f;
                row[i] = c * zi - s * f;
            }
        }
    });
}

// ---------------------------------------------------------------------
// blocked-reduction helpers
// ---------------------------------------------------------------------

/// Generate the Householder reflector annihilating `z[k+2.., k]`:
/// `H = I − τ v vᵀ` with `v` stored in `vs[k+1.., k]` (`v[0] = 1`),
/// `H x = β e₁`. Returns `(β, τ)`; `τ = 0` means the column is already
/// reduced. Norms are computed with max-abs scaling so spectra spanning
/// 1e±150 neither overflow nor underflow.
fn make_householder(z: &Mat, vs: &mut Mat, k: usize) -> (f64, f64) {
    let n = z.rows;
    let alpha = z.at(k + 1, k);
    let mut scale = 0.0f64;
    for r in (k + 2)..n {
        let v = z.at(r, k).abs();
        if v > scale {
            scale = v;
        }
    }
    if scale == 0.0 {
        // tail already zero: H = I
        vs.set(k + 1, k, 1.0);
        return (alpha, 0.0);
    }
    let mut ssq = 0.0f64;
    for r in (k + 2)..n {
        let v = z.at(r, k) / scale;
        ssq += v * v;
    }
    let xnorm = scale * ssq.sqrt();
    let norm = alpha.hypot(xnorm);
    let beta = if alpha >= 0.0 { -norm } else { norm };
    let tau = (beta - alpha) / beta;
    let inv = 1.0 / (alpha - beta);
    vs.set(k + 1, k, 1.0);
    for r in (k + 2)..n {
        vs.set(r, k, z.at(r, k) * inv);
    }
    (beta, tau)
}

/// Compute panel column `j` of `W` (dlatrd):
/// `w = τ(Z₂₂ v − V(Wᵀv) − W(Vᵀv))`, then `w += −½τ(wᵀv)·v`, stored in
/// `w[k+1.., j]`. The symmetric matvec `Z₂₂ v` runs through the
/// pool-parallel GEMM; the memory-bound correction GEMVs run as two
/// fused row passes over the panel (see [`simd::fused_tdot2`] /
/// [`simd::fused_apply2`]) so the BLAS-2 half of the panel reduction
/// traverses V and W once instead of once per panel column.
fn compute_w_column(z: &Mat, vs: &Mat, w: &mut Mat, k0: usize, j: usize, k: usize, tau: f64) {
    if tau == 0.0 {
        return; // H = I contributes nothing; the column stays zero
    }
    let n = z.rows;
    let m = n - k - 1;
    let mut p = vec![0.0f64; m];
    // p = z[k+1.., k+1..] · vs[k+1.., k]  (full symmetric block: the
    // trailing block is untouched since panel start, so both triangles
    // are valid)
    gemm::gemm_strided(
        m,
        1,
        m,
        &z.data[(k + 1) * n + (k + 1)..],
        n,
        1,
        &vs.data[(k + 1) * n + k..],
        n,
        1,
        &mut p,
    );
    if j > 0 {
        // corrections for the panel's earlier (not yet applied)
        // updates, as the two fused dlatrd GEMV passes from the SIMD
        // dispatch seam: one traversal of the panel rows computes BOTH
        // cw = W₂ᵀv and cv = V₂ᵀv (contiguous row reads instead of one
        // strided column sweep per panel column), and a second fused
        // traversal applies p −= V₂·cw + W₂·cv.
        let mut cw = vec![0.0f64; j];
        let mut cv = vec![0.0f64; j];
        let rows = n - k - 1;
        let wc = w.cols;
        simd::fused_tdot2(
            rows,
            j,
            &vs.data[(k + 1) * n + k..],
            n,
            &w.data[(k + 1) * wc..],
            wc,
            &vs.data[(k + 1) * n + k0..],
            n,
            &mut cw,
            &mut cv,
        );
        simd::fused_apply2(
            rows,
            j,
            &vs.data[(k + 1) * n + k0..],
            n,
            &w.data[(k + 1) * wc..],
            wc,
            &cw,
            &cv,
            &mut p,
            1,
        );
    }
    let mut dot = 0.0;
    for (r, pv) in p.iter_mut().enumerate() {
        *pv *= tau;
        dot += *pv * vs.at(k + 1 + r, k);
    }
    let alpha = -0.5 * tau * dot;
    for (r, pv) in p.iter().enumerate() {
        w.set(k + 1 + r, j, *pv + alpha * vs.at(k + 1 + r, k));
    }
}

/// Rank-2b trailing update after a panel:
/// `z[kend.., kend..] −= V₂W₂ᵀ + W₂V₂ᵀ`, as two strided-output GEMMs
/// writing straight into `z` (no staging copy of the trailing block).
fn trailing_update(z: &mut Mat, vs: &Mat, w: &Mat, k0: usize, bp: usize, kend: usize) {
    let n = z.rows;
    let m = n - kend;
    if m == 0 {
        return;
    }
    // negate W's trailing rows once so both products accumulate with +=
    let mut wn = Mat::zeros(m, bp);
    for r in 0..m {
        for t in 0..bp {
            wn.set(r, t, -w.at(kend + r, t));
        }
    }
    // z += V₂ · (−W₂)ᵀ
    gemm::gemm_strided_into(
        m,
        m,
        bp,
        &vs.data[kend * n + k0..],
        n,
        1,
        &wn.data,
        1,
        bp,
        &mut z.data[kend * n + kend..],
        n,
    );
    // z += (−W₂) · V₂ᵀ
    gemm::gemm_strided_into(
        m,
        m,
        bp,
        &wn.data,
        bp,
        1,
        &vs.data[kend * n + k0..],
        1,
        n,
        &mut z.data[kend * n + kend..],
        n,
    );
}

/// Form `Q = H₀ H₁ … H_{n−3}` panel-by-panel in compact-WY form:
/// `Q ← Q (I − V_p T_p V_pᵀ)` — two big GEMMs per panel. `V_p`'s rows
/// `0..=k0` are structurally zero, so both GEMMs restrict to Q's
/// columns `k0+1..n` (the others are provably unchanged), saving about
/// half the accumulation flops across the panel sweep.
fn accumulate_q(vs: &Mat, taus: &[f64], n: usize) -> Mat {
    let mut q = Mat::eye(n);
    if n <= 2 {
        return q;
    }
    let mut k0 = 0;
    while k0 < n - 2 {
        let bp = NB.min(n - 2 - k0);
        let t = build_t(vs, taus, k0, bp, n);
        // the active part of the panel: rows k0+1..n of V_p
        let vp = vs.block(k0 + 1, n, k0, k0 + bp); // (n−k0−1) × bp
        let ma = n - k0 - 1;
        // y = Q[:, k0+1..] · vp  (n × bp)
        let mut y = Mat::zeros(n, bp);
        gemm::gemm_strided(n, bp, ma, &q.data[k0 + 1..], n, 1, &vp.data, bp, 1, &mut y.data);
        let y = y.matmul(&t).scale(-1.0);
        // Q[:, k0+1..] += y · vpᵀ
        gemm::gemm_strided_into(
            n,
            ma,
            bp,
            &y.data,
            bp,
            1,
            &vp.data,
            1,
            bp,
            &mut q.data[k0 + 1..],
            n,
        );
        k0 += bp;
    }
    q
}

/// The triangular factor of the compact-WY representation (LAPACK
/// `larft`, forward/columnwise): `H_{k0} … H_{k0+bp−1} = I − V T Vᵀ`
/// with upper-triangular `T`, `T[j,j] = τ_j` and
/// `T[0..j, j] = −τ_j · T[0..j, 0..j] · (Vᵀ v_j)`.
fn build_t(vs: &Mat, taus: &[f64], k0: usize, bp: usize, n: usize) -> Mat {
    let mut t = Mat::zeros(bp, bp);
    for j in 0..bp {
        let k = k0 + j;
        let tj = taus[k];
        if tj == 0.0 {
            continue; // H_j = I: its T column is zero
        }
        if j > 0 {
            let mut h = vec![0.0f64; j];
            for (tc, hv) in h.iter_mut().enumerate() {
                let mut acc = 0.0;
                // v_j is supported on rows k+1..n, a subset of v_tc's
                // support, so this range covers the whole product
                for r in (k + 1)..n {
                    acc += vs.at(r, k0 + tc) * vs.at(r, k);
                }
                *hv = acc;
            }
            for row in 0..j {
                let mut acc = 0.0;
                for cc in row..j {
                    acc += t.at(row, cc) * h[cc];
                }
                t.set(row, j, -tj * acc);
            }
        }
        t.set(j, j, tj);
    }
    t
}

/// Rotate columns (p, q) of `m` by the (c, s) plane rotation across all
/// rows, rows split across the pool for very large matrices.
fn rotate_cols(m: &mut Mat, p: usize, q: usize, c: f64, s: f64) {
    let rows = m.rows;
    let cols = m.cols;
    let ptr = SendPtr(m.data.as_mut_ptr());
    par::par_ranges(rows, ROT_MIN_CHUNK, |lo, hi| {
        for k in lo..hi {
            // SAFETY: disjoint row ranges from par_ranges.
            let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(k * cols), cols) };
            let mkp = row[p];
            let mkq = row[q];
            row[p] = c * mkp - s * mkq;
            row[q] = s * mkp + c * mkq;
        }
    });
}

/// Rotate rows (p, q) of `m`; workers touch disjoint column ranges of
/// the two shared rows.
fn rotate_rows(m: &mut Mat, p: usize, q: usize, c: f64, s: f64) {
    let cols = m.cols;
    let ptr = SendPtr(m.data.as_mut_ptr());
    par::par_ranges(cols, ROT_MIN_CHUNK, |lo, hi| {
        for k in lo..hi {
            // SAFETY: chunks cover disjoint columns k of rows p and q.
            unsafe {
                let ip = ptr.0.add(p * cols + k);
                let iq = ptr.0.add(q * cols + k);
                let mpk = *ip;
                let mqk = *iq;
                *ip = c * mpk - s * mqk;
                *iq = s * mpk + c * mqk;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::spd_inverse;
    use crate::rng::Rng;

    fn random_sym(n: usize, rng: &mut Rng) -> Mat {
        Mat::randn(n, n, 1.0, rng).symmetrize()
    }

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let x = Mat::randn(n + 3, n, 1.0, rng);
        x.matmul_tn(&x).add_diag(0.3)
    }

    #[test]
    fn sym_topk_matches_dense_extremes() {
        // Top-k by |λ| of a matrix with a known, well-gapped spectrum
        // (random orthogonal conjugation of a fixed diagonal) vs the
        // full solver. The gap makes 40 subspace iterations converge to
        // well below the assertion tolerances.
        let mut rng = Rng::new(31);
        for n in [6, 14, 30] {
            let q = SymEig::new(&random_sym(n, &mut rng)).v;
            let mut spec = vec![0.0; n];
            let big = [9.0, -7.0, 4.0];
            for (i, s) in spec.iter_mut().enumerate() {
                *s = big.get(i).copied().unwrap_or(0.4 / (i + 1) as f64);
            }
            let mut d = Mat::zeros(n, n);
            for (i, s) in spec.iter().enumerate() {
                d.set(i, i, *s);
            }
            let a = q.matmul(&d).matmul_nt(&q).symmetrize();
            let k = 3;
            let (lam, vecs) = sym_topk(&a, k, 40, 1e-12);
            assert_eq!(lam.len(), k, "n={n}");
            for j in 0..k {
                assert!(
                    (lam[j] - big[j]).abs() < 1e-8 * (1.0 + big[0].abs()),
                    "n={n} j={j}: {} vs {}",
                    lam[j],
                    big[j]
                );
                // residual ‖Av − λv‖ small
                let v: Vec<f64> = (0..n).map(|i| vecs.at(i, j)).collect();
                let av = a.matvec(&v);
                let res: f64 = av
                    .iter()
                    .zip(v.iter())
                    .map(|(x, y)| (x - lam[j] * y).powi(2))
                    .sum::<f64>()
                    .sqrt();
                assert!(res < 1e-7 * (1.0 + big[0].abs()), "n={n} j={j} res={res}");
            }
        }
    }

    #[test]
    fn sym_topk_rank_deficient_and_zero() {
        // A rank-2 matrix yields exactly 2 pairs even when k=4; a zero
        // matrix yields none.
        let mut rng = Rng::new(32);
        let u = Mat::randn(9, 2, 1.0, &mut rng);
        let low = u.matmul_nt(&u); // rank 2 PSD
        let (lam, vecs) = sym_topk(&low, 4, 40, 1e-10);
        assert_eq!(lam.len(), 2);
        let rec = {
            let mut r = Mat::zeros(9, 9);
            for j in 0..2 {
                for i in 0..9 {
                    for i2 in 0..9 {
                        let v = r.at(i, i2) + lam[j] * vecs.at(i, j) * vecs.at(i2, j);
                        r.set(i, i2, v);
                    }
                }
            }
            r
        };
        assert!(rec.sub(&low).max_abs() < 1e-8 * (1.0 + low.max_abs()));
        let (lz, _) = sym_topk(&Mat::zeros(6, 6), 3, 20, 1e-10);
        assert!(lz.is_empty());
    }

    #[test]
    fn sym_topk_is_deterministic() {
        let mut rng = Rng::new(33);
        let a = random_sym(17, &mut rng);
        let (l1, v1) = sym_topk(&a, 4, 30, 1e-12);
        let (l2, v2) = sym_topk(&a, 4, 30, 1e-12);
        assert_eq!(l1.len(), l2.len());
        for (a1, a2) in l1.iter().zip(l2.iter()) {
            assert_eq!(a1.to_bits(), a2.to_bits());
        }
        for i in 0..v1.rows {
            for j in 0..v1.cols {
                assert_eq!(v1.at(i, j).to_bits(), v2.at(i, j).to_bits());
            }
        }
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 3, 8, 25] {
            let a = random_sym(n, &mut rng);
            let e = SymEig::new(&a);
            let rec_err = e.reconstruct().sub(&a).max_abs();
            assert!(rec_err < 1e-9 * (1.0 + a.max_abs()), "n={n} err={rec_err}");
            let orth = e.v.matmul_tn(&e.v).sub(&Mat::eye(n)).max_abs();
            assert!(orth < 1e-10, "n={n} orth={orth}");
        }
    }

    #[test]
    fn eigenvalues_sorted_and_match_trace() {
        let mut rng = Rng::new(2);
        let a = random_sym(10, &mut rng);
        let e = SymEig::new(&a);
        for i in 1..e.w.len() {
            assert!(e.w[i] >= e.w[i - 1]);
        }
        let tr: f64 = e.w.iter().sum();
        assert!((tr - a.trace()).abs() < 1e-9);
    }

    #[test]
    fn sqrt_squares_back() {
        let mut rng = Rng::new(3);
        let a = random_spd(9, &mut rng);
        let s = SymEig::new(&a).sqrt_psd();
        assert!(s.matmul(&s).sub(&a).max_abs() < 1e-8);
    }

    #[test]
    fn inv_sqrt_matches_cholesky_inverse() {
        let mut rng = Rng::new(4);
        let a = random_spd(7, &mut rng);
        let is = SymEig::new(&a).inv_sqrt_psd(1e-14);
        let inv_via_eig = is.matmul(&is);
        let inv_via_chol = spd_inverse(&a);
        assert!(inv_via_eig.sub(&inv_via_chol).max_abs() < 1e-7);
    }

    #[test]
    fn ql_matches_jacobi() {
        let mut rng = Rng::new(77);
        for n in [2usize, 5, 17, 40, 73] {
            let a = random_sym(n, &mut rng);
            let ql = SymEig::new_ql(&a);
            let ja = SymEig::new_jacobi(&a);
            for i in 0..n {
                assert!(
                    (ql.w[i] - ja.w[i]).abs() < 1e-9 * (1.0 + a.max_abs()),
                    "n={n} eigenvalue {i}: {} vs {}",
                    ql.w[i],
                    ja.w[i]
                );
            }
            // reconstruction + orthogonality for the QL path
            assert!(ql.reconstruct().sub(&a).max_abs() < 1e-9 * (1.0 + a.max_abs()));
            assert!(ql.v.matmul_tn(&ql.v).sub(&Mat::eye(n)).max_abs() < 1e-9);
        }
    }

    #[test]
    fn blocked_matches_jacobi_and_ql() {
        let mut rng = Rng::new(91);
        // sizes straddle panel boundaries (NB = 32): below, ragged,
        // exact multiples, multi-panel
        for n in [1usize, 2, 3, 5, 17, 25, 31, 32, 33, 40, 64, 65, 73, 96] {
            let a = random_sym(n, &mut rng);
            let bl = SymEig::new_blocked(&a);
            let ql = SymEig::new_ql(&a);
            let ja = SymEig::new_jacobi(&a);
            let scale = 1.0 + a.max_abs();
            for i in 0..n {
                assert!(
                    (bl.w[i] - ja.w[i]).abs() < 1e-9 * scale,
                    "n={n} eigenvalue {i}: blocked={} jacobi={}",
                    bl.w[i],
                    ja.w[i]
                );
                assert!((bl.w[i] - ql.w[i]).abs() < 1e-9 * scale, "n={n} vs ql {i}");
            }
            assert!(bl.reconstruct().sub(&a).max_abs() < 1e-9 * scale, "n={n} rec");
            assert!(bl.v.matmul_tn(&bl.v).sub(&Mat::eye(n)).max_abs() < 1e-9, "n={n} orth");
        }
    }

    #[test]
    fn blocked_eigenpairs_satisfy_definition() {
        for seed in 0..10u64 {
            let mut rng = Rng::new(500 + seed);
            let n = 25 + rng.below(60);
            let a = random_sym(n, &mut rng);
            let e = SymEig::new_blocked(&a);
            for i in [0, n / 2, n - 1] {
                let vi: Vec<f64> = (0..n).map(|r| e.v.at(r, i)).collect();
                let av = a.matvec(&vi);
                for r in 0..n {
                    assert!(
                        (av[r] - e.w[i] * vi[r]).abs() < 1e-8 * (1.0 + a.max_abs()),
                        "seed={seed} n={n} pair {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn ql_handles_degenerate_spectra() {
        // repeated eigenvalues and zero rows
        let mut a = Mat::eye(10).scale(3.0);
        a.set(9, 9, 0.0);
        let e = SymEig::new_ql(&a);
        assert!((e.w[0] - 0.0).abs() < 1e-12);
        assert!((e.w[9] - 3.0).abs() < 1e-12);
        assert!(e.reconstruct().sub(&a).max_abs() < 1e-10);
        // rank-1
        let v = Mat::from_fn(8, 1, |r, _| (r + 1) as f64);
        let r1 = v.matmul_nt(&v);
        let e = SymEig::new_ql(&r1);
        assert!(e.reconstruct().sub(&r1).max_abs() < 1e-8 * r1.max_abs());
    }

    #[test]
    fn blocked_handles_degenerate_spectra() {
        // already-diagonal input: every reflector is trivial (τ = 0)
        let mut a = Mat::eye(40).scale(3.0);
        a.set(39, 39, 0.0);
        let e = SymEig::new_blocked(&a);
        assert!((e.w[0] - 0.0).abs() < 1e-12);
        assert!((e.w[39] - 3.0).abs() < 1e-12);
        assert!(e.reconstruct().sub(&a).max_abs() < 1e-10);
        // rank-1, multi-panel size
        let v = Mat::from_fn(70, 1, |r, _| (r % 9) as f64 - 4.0);
        let r1 = v.matmul_nt(&v);
        let e = SymEig::new_blocked(&r1);
        assert!(e.reconstruct().sub(&r1).max_abs() < 1e-8 * r1.max_abs());
        assert!(e.v.matmul_tn(&e.v).sub(&Mat::eye(70)).max_abs() < 1e-9);
    }

    #[test]
    fn property_eig_many_seeds() {
        for seed in 0..15 {
            let mut rng = Rng::new(100 + seed);
            let n = 1 + rng.below(20);
            let a = random_sym(n, &mut rng);
            let e = SymEig::new(&a);
            // A v_i = w_i v_i for each eigenpair
            for i in 0..n {
                let vi: Vec<f64> = (0..n).map(|r| e.v.at(r, i)).collect();
                let av = a.matvec(&vi);
                for r in 0..n {
                    assert!(
                        (av[r] - e.w[i] * vi[r]).abs() < 1e-8 * (1.0 + a.max_abs()),
                        "seed={seed} n={n} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn exhausted_ql_falls_back_to_jacobi() {
        let mut rng = Rng::new(92);
        let a = random_sym(30, &mut rng);
        let scale = 1.0 + a.max_abs();
        let before = tql2_fallback_count();
        // cap 0 deterministically exhausts the first QL step
        let e = SymEig::new_ql_with_iter_cap(&a, 0);
        assert!(tql2_fallback_count() >= before + 1, "fallback not counted");
        assert!(e.reconstruct().sub(&a).max_abs() < 1e-9 * scale, "ql fallback rec");
        assert!(e.v.matmul_tn(&e.v).sub(&Mat::eye(30)).max_abs() < 1e-9);
        let e = SymEig::new_blocked_with_iter_cap(&a, 0);
        assert!(e.reconstruct().sub(&a).max_abs() < 1e-9 * scale, "blocked fallback rec");
        assert!(e.v.matmul_tn(&e.v).sub(&Mat::eye(30)).max_abs() < 1e-9);
    }

    #[test]
    fn non_finite_input_is_rejected() {
        let mut a = Mat::eye(30);
        a.set(1, 2, f64::NAN);
        a.set(2, 1, f64::NAN);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| SymEig::new(&a)));
        assert!(r.is_err(), "NaN input must be rejected by SymEig::new");
    }
}
