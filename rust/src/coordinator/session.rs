//! The training session: a builder-style API that composes a dataset,
//! a [`ModelBackend`], and any [`Optimizer`] into the paper's training
//! protocol — Polyak-style iterate averaging with the reported error
//! being the min over {current, averaged} (Section 13), wall-clock
//! accounting that excludes evaluation overhead, streaming metric
//! callbacks, and versioned checkpoint save/resume.
//!
//! ```no_run
//! use kfac::coordinator::TrainSession;
//! use kfac::coordinator::session::Problem;
//!
//! let report = TrainSession::for_problem(Problem::MnistAe)
//!     .data(4000, 0)
//!     .iters(200)
//!     .polyak(0.99)
//!     .checkpoint_every(50, "results/mnist_ae.ckpt")
//!     .run();
//! println!("final err {}", report.log.last().unwrap().train_err);
//! ```

use crate::backend::{ModelBackend, RustBackend};
use crate::bench::Timer;
use crate::coordinator::checkpoint::{self, Checkpoint};
use crate::data::{curves_like, faces_like, mnist_like, Dataset};
use crate::linalg::pack::ConvShape;
use crate::linalg::Mat;
use crate::nn::{Act, Arch, Layer, LossKind, Params};
use crate::optim::{BatchSchedule, Kfac, KfacConfig, Optimizer, PolyakAverager, StepInfo};
use crate::rng::Rng;
use std::path::PathBuf;

/// The paper's three benchmark problems, the small classifier used by
/// the Fisher-structure figures, and a small conv classifier exercising
/// the KFC curvature (Grosse & Martens 2016).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Problem {
    MnistAe,
    CurvesAe,
    FacesAe,
    MnistClf,
    ConvClf,
}

impl Problem {
    pub fn name(self) -> &'static str {
        match self {
            Problem::MnistAe => "mnist_ae",
            Problem::CurvesAe => "curves_ae",
            Problem::FacesAe => "faces_ae",
            Problem::MnistClf => "mnist_clf",
            Problem::ConvClf => "conv_clf",
        }
    }

    pub fn from_name(s: &str) -> Option<Problem> {
        Some(match s {
            "mnist_ae" => Problem::MnistAe,
            "curves_ae" => Problem::CurvesAe,
            "faces_ae" => Problem::FacesAe,
            "mnist_clf" => Problem::MnistClf,
            "conv_clf" => Problem::ConvClf,
            _ => return None,
        })
    }

    /// Default (scaled-down from the paper; see DESIGN.md) architecture.
    pub fn arch(self) -> Arch {
        match self {
            // paper: 784-1000-500-250-30 (mirrored); ours is ~0.4×
            Problem::MnistAe => {
                Arch::autoencoder(&[784, 400, 200, 100, 30, 100, 200, 400, 784], Act::Tanh)
            }
            // paper: 784-400-200-100-50-25-6 (mirrored), kept at ~0.5×
            Problem::CurvesAe => Arch::autoencoder(
                &[784, 200, 100, 50, 25, 12, 6, 12, 25, 50, 100, 200, 784],
                Act::Tanh,
            ),
            // paper: 625-2000-1000-500-30; ours ~0.25×, Gaussian output
            Problem::FacesAe => Arch::autoencoder_gaussian(
                &[625, 500, 250, 125, 30, 125, 250, 500, 625],
                Act::Tanh,
            ),
            // the Figure-2 network: 16×16 MNIST, 256-20-20-20-20-10 tanh
            Problem::MnistClf => Arch::classifier(&[256, 20, 20, 20, 20, 10], Act::Tanh),
            // 16×16 MNIST again, but through a strided conv front end:
            // conv 5×5/2 (6 maps) → 8×8×6 → dense softmax head
            Problem::ConvClf => {
                let shape =
                    ConvShape { in_h: 16, in_w: 16, in_c: 1, kh: 5, kw: 5, stride: 2, pad: 2 };
                Arch::from_layers(
                    vec![
                        Layer::Conv2d { shape, out_c: 6, act: Act::Tanh },
                        Layer::Dense { d_in: 384, d_out: 10, act: Act::Identity },
                    ],
                    LossKind::SoftmaxCe,
                )
            }
        }
    }

    /// Generate the synthetic dataset (see `data::*`).
    pub fn dataset(self, n: usize, seed: u64) -> Dataset {
        match self {
            Problem::MnistAe => mnist_like::autoencoder_dataset(n, 28, seed),
            Problem::CurvesAe => curves_like::autoencoder_dataset(n, 28, seed),
            Problem::FacesAe => faces_like::autoencoder_dataset(n, 25, seed),
            Problem::MnistClf => mnist_like::classification_dataset(n, 16, seed),
            Problem::ConvClf => mnist_like::classification_dataset(n, 16, seed),
        }
    }
}

/// One logged evaluation point.
#[derive(Clone, Copy, Debug)]
pub struct LogRow {
    pub iter: usize,
    /// Cumulative training cases processed.
    pub cases: f64,
    /// Cumulative optimizer wall-clock (excludes evaluation).
    pub time_s: f64,
    /// Mini-batch regularized objective at this iteration (NaN on the
    /// pre-training row emitted by `eval_initial`).
    pub batch_loss: f64,
    /// Training-set error (min over current/averaged params).
    pub train_err: f64,
    /// Training-set loss (same min rule).
    pub train_loss: f64,
}

/// A streamed training event, delivered to the session observer.
#[derive(Clone, Debug)]
pub enum Event {
    /// An optimizer step completed.
    Step { iter: usize, batch: usize, info: StepInfo },
    /// An evaluation point was logged.
    Eval { row: LogRow },
    /// A checkpoint was written.
    Checkpoint { iter: usize, path: PathBuf },
}

/// What `run` returns: the evaluation log and the final parameters.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub log: Vec<LogRow>,
    /// Parameters after the last iteration.
    pub params: Params,
    /// The Polyak-averaged parameters, when averaging was enabled and
    /// at least one update was absorbed.
    pub avg_params: Option<Params>,
    /// Iterations executed by this call (0 when resuming a finished run).
    pub iters_run: usize,
}

enum DataSource<'a> {
    Borrowed(&'a Dataset),
    Owned(Dataset),
    Lazy { problem: Problem, n: usize, seed: u64 },
}

/// Builder for a training run. See the module docs for an example; all
/// knobs default to the paper's evaluation protocol.
pub struct TrainSession<'a> {
    arch: Arch,
    data: DataSource<'a>,
    optimizer: Option<Box<dyn Optimizer + 'a>>,
    backend: Option<&'a mut dyn ModelBackend>,
    params: Option<Params>,
    iters: usize,
    schedule: BatchSchedule,
    seed: u64,
    eval_every: usize,
    eval_rows: usize,
    eval_initial: bool,
    polyak: Option<f64>,
    verbose: bool,
    observer: Option<Box<dyn FnMut(&Event) + 'a>>,
    checkpoint: Option<(PathBuf, usize)>,
    resume: Option<PathBuf>,
    shard: Option<(usize, usize)>,
}

impl<'a> TrainSession<'a> {
    fn with_arch_and_data(arch: Arch, data: DataSource<'a>) -> TrainSession<'a> {
        TrainSession {
            arch,
            data,
            optimizer: None,
            backend: None,
            params: None,
            iters: 100,
            schedule: BatchSchedule::Fixed(256),
            seed: 0,
            eval_every: 5,
            eval_rows: 1000,
            eval_initial: false,
            polyak: Some(0.99),
            verbose: false,
            observer: None,
            checkpoint: None,
            resume: None,
            shard: None,
        }
    }

    /// Start a session on one of the paper's benchmark problems; the
    /// synthetic dataset is generated at `run` time (size/seed set via
    /// [`TrainSession::data`]).
    pub fn for_problem(problem: Problem) -> TrainSession<'static> {
        TrainSession::with_arch_and_data(
            problem.arch(),
            DataSource::Lazy { problem, n: 4000, seed: 0 },
        )
    }

    /// Start a session on a caller-provided dataset and architecture.
    pub fn for_dataset(arch: Arch, ds: &'a Dataset) -> TrainSession<'a> {
        TrainSession::with_arch_and_data(arch, DataSource::Borrowed(ds))
    }

    /// Like [`TrainSession::for_dataset`] but taking ownership.
    pub fn for_owned_dataset(arch: Arch, ds: Dataset) -> TrainSession<'a> {
        TrainSession::with_arch_and_data(arch, DataSource::Owned(ds))
    }

    /// The architecture this session trains (for constructing
    /// optimizers and initial parameters).
    pub fn arch(&self) -> &Arch {
        &self.arch
    }

    /// Dataset size and generation seed for [`TrainSession::for_problem`]
    /// sessions (no-op for caller-provided datasets).
    pub fn data(mut self, n: usize, seed: u64) -> Self {
        if let DataSource::Lazy { problem, .. } = self.data {
            self.data = DataSource::Lazy { problem, n, seed };
        }
        self
    }

    /// Number of training iterations (default 100).
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }

    /// Mini-batch schedule (default: fixed 256).
    pub fn schedule(mut self, schedule: BatchSchedule) -> Self {
        self.schedule = schedule;
        self
    }

    /// Seed for mini-batch sampling and default parameter init.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Evaluate (and log) every this many iterations (default 5).
    pub fn eval_every(mut self, every: usize) -> Self {
        self.eval_every = every;
        self
    }

    /// Rows of the training set used for error evaluation (default 1000).
    pub fn eval_rows(mut self, rows: usize) -> Self {
        self.eval_rows = rows;
        self
    }

    /// Also evaluate before the first iteration (an `iter = 0` row with
    /// `batch_loss = NaN`).
    pub fn eval_initial(mut self) -> Self {
        self.eval_initial = true;
        self
    }

    /// Polyak averaging decay ξ (paper: 0.99, the default).
    pub fn polyak(mut self, xi: f64) -> Self {
        self.polyak = Some(xi);
        self
    }

    /// Disable Polyak averaging.
    pub fn no_polyak(mut self) -> Self {
        self.polyak = None;
        self
    }

    /// Print an evaluation line at every logged point.
    pub fn verbose(mut self, verbose: bool) -> Self {
        self.verbose = verbose;
        self
    }

    /// Initial parameters (default: sparse init from `seed ^ 0xA5`).
    pub fn params(mut self, params: Params) -> Self {
        self.params = Some(params);
        self
    }

    /// The optimizer to drive (default: K-FAC with the paper's
    /// configuration). Construct it against [`TrainSession::arch`].
    pub fn optimizer(self, opt: impl Optimizer + 'a) -> Self {
        self.optimizer_boxed(Box::new(opt))
    }

    /// Type-erased form of [`TrainSession::optimizer`].
    pub fn optimizer_boxed(mut self, opt: Box<dyn Optimizer + 'a>) -> Self {
        self.optimizer = Some(opt);
        self
    }

    /// Run on a caller-provided backend (e.g. PJRT) instead of the
    /// default pure-Rust backend.
    pub fn backend(mut self, backend: &'a mut dyn ModelBackend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Streaming metric callback, invoked on every step, evaluation,
    /// and checkpoint.
    pub fn observer(mut self, f: impl FnMut(&Event) + 'a) -> Self {
        self.observer = Some(Box::new(f));
        self
    }

    /// Write a checkpoint to `path` every `every` iterations (and at
    /// the final iteration). The file is atomically replaced each time.
    pub fn checkpoint_every(mut self, every: usize, path: impl Into<PathBuf>) -> Self {
        self.checkpoint = Some((path.into(), every.max(1)));
        self
    }

    /// Resume from a checkpoint written by a session with the same
    /// architecture, optimizer configuration and schedule: training
    /// continues bit-exactly where the checkpoint left off.
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume = Some(path.into());
        self
    }

    /// Data-parallel worker shard: rank `rank` of `ranks` trains on its
    /// contiguous slice of each (identically sampled) global minibatch.
    /// Every rank runs the same schedule/seed, so the global batch is
    /// identical across the group and the union of the slices covers it
    /// exactly; the optimizer's `DistBackend`/`Collective` plumbing then
    /// averages the per-slice quantities back into global ones. `ranks
    /// <= 1` is a no-op — the bit-identity contract with single-process
    /// training.
    pub fn shard(mut self, rank: usize, ranks: usize) -> Self {
        assert!(rank < ranks.max(1), "shard rank {rank} out of range for {ranks} ranks");
        self.shard = Some((rank, ranks));
        self
    }

    /// Run training. Panics on checkpoint/configuration errors — use
    /// [`TrainSession::try_run`] to handle them.
    pub fn run(self) -> TrainReport {
        self.try_run().unwrap_or_else(|e| panic!("TrainSession::run: {e}"))
    }

    /// Run training, surfacing checkpoint/configuration errors.
    pub fn try_run(self) -> Result<TrainReport, String> {
        let TrainSession {
            arch,
            data,
            optimizer,
            backend,
            params,
            iters,
            schedule,
            seed,
            eval_every,
            eval_rows,
            eval_initial,
            polyak,
            verbose,
            mut observer,
            checkpoint: checkpoint_cfg,
            resume,
            shard,
        } = self;

        let owned_ds;
        let ds: &Dataset = match &data {
            DataSource::Borrowed(d) => d,
            DataSource::Owned(d) => d,
            DataSource::Lazy { problem, n, seed } => {
                owned_ds = problem.dataset(*n, *seed);
                &owned_ds
            }
        };
        if ds.is_empty() {
            return Err("empty dataset".to_string());
        }
        if ds.x.cols != arch.widths[0] || ds.y.cols != *arch.widths.last().unwrap() {
            return Err(format!(
                "dataset shape ({} -> {}) does not match arch {:?}",
                ds.x.cols, ds.y.cols, arch.widths
            ));
        }

        let mut owned_backend;
        let backend: &mut dyn ModelBackend = match backend {
            Some(b) => b,
            None => {
                owned_backend = RustBackend::new(arch.clone());
                &mut owned_backend
            }
        };
        if backend.arch().widths != arch.widths {
            return Err(format!(
                "backend arch {:?} does not match session arch {:?}",
                backend.arch().widths,
                arch.widths
            ));
        }

        let mut opt: Box<dyn Optimizer + 'a> = match optimizer {
            Some(o) => o,
            None => Box::new(Kfac::new(&arch, KfacConfig::default())),
        };
        let mut params = match params {
            Some(p) => p,
            None => arch.sparse_init(&mut Rng::new(seed ^ 0xA5)),
        };
        let mut rng = Rng::new(seed ^ 0x5EED);
        let mut avg = polyak.map(PolyakAverager::new);
        let mut k0 = 0usize;
        let mut cases = 0.0f64;
        let mut train_time = 0.0f64;

        if let Some(path) = &resume {
            let ck = checkpoint::load(path)?;
            if ck.params.0.len() != arch.num_layers() {
                return Err(format!(
                    "checkpoint has {} layers, arch {:?} needs {}",
                    ck.params.0.len(),
                    arch.widths,
                    arch.num_layers()
                ));
            }
            for (i, w) in ck.params.0.iter().enumerate() {
                if (w.rows, w.cols) != arch.weight_shape(i) {
                    return Err(format!(
                        "checkpoint layer {i} is {}x{}, arch {:?} needs {:?}",
                        w.rows,
                        w.cols,
                        arch.widths,
                        arch.weight_shape(i)
                    ));
                }
            }
            if ck.opt.kind != opt.name() {
                return Err(format!(
                    "checkpoint was taken with optimizer '{}', session uses '{}'",
                    ck.opt.kind,
                    opt.name()
                ));
            }
            opt.load_state(&ck.opt)?;
            params = ck.params;
            k0 = ck.iter;
            cases = ck.cases;
            train_time = ck.time_s;
            rng = Rng::from_state(ck.rng_words, ck.rng_spare);
            avg = ck.polyak.map(|(xi, a)| PolyakAverager::restore(xi, a));
        }

        let eval_rows = eval_rows.min(ds.len()).max(1);
        let eval_x = ds.x.top_rows(eval_rows);
        let eval_y = ds.y.top_rows(eval_rows);
        let eval_every = eval_every.max(1);

        let mut log = Vec::new();
        if eval_initial && k0 == 0 {
            let row = eval_row(
                backend,
                &params,
                avg.as_ref(),
                &eval_x,
                &eval_y,
                0,
                cases,
                train_time,
                f64::NAN,
            );
            print_row(verbose, 0, &row);
            if let Some(obs) = observer.as_mut() {
                obs(&Event::Eval { row });
            }
            log.push(row);
        }

        for k in (k0 + 1)..=iters {
            let m = schedule.size(k);
            let (x, y) = ds.minibatch(m, &mut rng);
            // Data-parallel shard: every rank samples the identical global
            // batch (same seed/schedule) and trains on its contiguous
            // slice; `cases` stays global. Tiny batches (m < ranks) are
            // left whole rather than handing some rank zero rows.
            let (x, y) = match shard {
                Some((r, n)) if n > 1 && m >= n => {
                    let lo = r * m / n;
                    let hi = (r + 1) * m / n;
                    (x.block(lo, hi, 0, x.cols), y.block(lo, hi, 0, y.cols))
                }
                _ => (x, y),
            };
            let t = Timer::start();
            let info = opt.step(backend, &mut params, &x, &y);
            train_time += t.elapsed_s();
            cases += m as f64;
            if let Some(a) = avg.as_mut() {
                a.update(&params);
            }
            if let Some(obs) = observer.as_mut() {
                obs(&Event::Step { iter: k, batch: m, info });
            }

            if k % eval_every == 0 || k == iters || k == 1 {
                let row = eval_row(
                    backend,
                    &params,
                    avg.as_ref(),
                    &eval_x,
                    &eval_y,
                    k,
                    cases,
                    train_time,
                    info.loss,
                );
                print_row(verbose, m, &row);
                if let Some(obs) = observer.as_mut() {
                    obs(&Event::Eval { row });
                }
                log.push(row);
            }

            if let Some((path, every)) = &checkpoint_cfg {
                if k % every == 0 || k == iters {
                    let (rng_words, rng_spare) = rng.state();
                    // a mid-flight async build is checkpointed by its
                    // inputs (see Kfac::state), so the snapshot never
                    // blocks on the background job
                    let opt_state = opt.state();
                    let ck = Checkpoint {
                        version: checkpoint::version_for(&opt_state),
                        iter: k,
                        cases,
                        time_s: train_time,
                        rng_words,
                        rng_spare,
                        params: params.clone(),
                        polyak: avg.as_ref().map(|a| (a.xi, a.get().cloned())),
                        opt: opt_state,
                    };
                    checkpoint::save(path, &ck)
                        .map_err(|e| format!("writing checkpoint {}: {e}", path.display()))?;
                    if let Some(obs) = observer.as_mut() {
                        obs(&Event::Checkpoint { iter: k, path: path.clone() });
                    }
                }
            }
        }

        let avg_params = avg.as_ref().and_then(|a| a.get().cloned());
        Ok(TrainReport { log, params, avg_params, iters_run: iters.saturating_sub(k0) })
    }
}

/// One evaluation point: min over {current, averaged} parameters
/// (paper Section 13). Total in the averager — an empty average (no
/// updates yet) simply falls back to the current parameters.
#[allow(clippy::too_many_arguments)]
fn eval_row(
    backend: &mut dyn ModelBackend,
    params: &Params,
    avg: Option<&PolyakAverager>,
    eval_x: &Mat,
    eval_y: &Mat,
    iter: usize,
    cases: f64,
    time_s: f64,
    batch_loss: f64,
) -> LogRow {
    let (mut loss, mut err) = backend.eval(params, eval_x, eval_y);
    if let Some(a) = avg {
        if let Some(ap) = a.get() {
            let (al, ae) = backend.eval(ap, eval_x, eval_y);
            if ae < err {
                err = ae;
                loss = al;
            }
        }
    }
    LogRow { iter, cases, time_s, batch_loss, train_err: err, train_loss: loss }
}

fn print_row(verbose: bool, m: usize, row: &LogRow) {
    if verbose {
        println!(
            "iter {:>5}  m={:>6}  time={:>8.2}s  loss={:.5}  err={:.5}",
            row.iter, m, row.time_s, row.train_loss, row.train_err
        );
    }
}

/// Write a training log as CSV.
pub fn log_to_csv(path: &std::path::Path, log: &[LogRow]) -> std::io::Result<()> {
    crate::util::write_csv(
        path,
        &["iter", "cases", "time_s", "batch_loss", "train_err", "train_loss"],
        &log.iter()
            .map(|r| {
                vec![r.iter as f64, r.cases, r.time_s, r.batch_loss, r.train_err, r.train_loss]
            })
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Sgd;
    use crate::optim::SgdConfig;

    #[test]
    fn problems_have_consistent_arch_and_data() {
        let all = [
            Problem::MnistAe,
            Problem::CurvesAe,
            Problem::FacesAe,
            Problem::MnistClf,
            Problem::ConvClf,
        ];
        for p in all {
            let arch = p.arch();
            let ds = p.dataset(20, 1);
            assert_eq!(ds.x.cols, arch.widths[0], "{p:?} input width");
            assert_eq!(ds.y.cols, *arch.widths.last().unwrap(), "{p:?} target width");
            assert_eq!(Problem::from_name(p.name()), Some(p));
        }
        assert!(Problem::ConvClf.arch().has_conv(), "conv_clf must exercise a conv layer");
    }

    #[test]
    fn kfac_session_reduces_error_on_small_autoencoder() {
        // Small end-to-end smoke: 16x16 digit autoencoder, rust backend.
        let arch = Arch::autoencoder(&[256, 32, 8, 32, 256], Act::Tanh);
        let ds = mnist_like::autoencoder_dataset(256, 16, 3);
        let opt = Kfac::new(&arch, KfacConfig { lambda0: 15.0, ..KfacConfig::block_diag() });
        let report = TrainSession::for_dataset(arch.clone(), &ds)
            .iters(25)
            .schedule(BatchSchedule::Fixed(128))
            .eval_every(5)
            .eval_rows(128)
            .polyak(0.99)
            .seed(2)
            .params(arch.sparse_init(&mut Rng::new(1)))
            .optimizer(opt)
            .run();
        let first = report.log.first().unwrap().train_err;
        let last = report.log.last().unwrap().train_err;
        assert!(last < first, "err did not decrease: {first} -> {last}");
        assert_eq!(report.iters_run, 25);
        assert!(report.avg_params.is_some());
    }

    #[test]
    fn default_optimizer_is_kfac_and_runs() {
        let arch = Arch::autoencoder(&[64, 12, 64], Act::Tanh);
        let ds = mnist_like::autoencoder_dataset(64, 8, 1);
        let report = TrainSession::for_dataset(arch, &ds)
            .iters(2)
            .schedule(BatchSchedule::Fixed(32))
            .eval_rows(32)
            .run();
        assert!(!report.log.is_empty());
        assert!(report.log.last().unwrap().train_loss.is_finite());
    }

    #[test]
    fn observer_streams_steps_and_evals() {
        let arch = Arch::autoencoder(&[64, 12, 64], Act::Tanh);
        let ds = mnist_like::autoencoder_dataset(64, 8, 2);
        let mut steps = 0usize;
        let mut evals = 0usize;
        let _ = TrainSession::for_dataset(arch.clone(), &ds)
            .iters(4)
            .schedule(BatchSchedule::Fixed(32))
            .eval_every(2)
            .eval_rows(32)
            .optimizer(Sgd::new(SgdConfig::default()))
            .observer(|e| match e {
                Event::Step { info, .. } => {
                    assert!(info.loss.is_finite());
                    steps += 1;
                }
                Event::Eval { .. } => evals += 1,
                Event::Checkpoint { .. } => {}
            })
            .run();
        assert_eq!(steps, 4);
        assert_eq!(evals, 3, "evals at k = 1, 2, 4");
    }

    #[test]
    fn zero_iteration_run_with_polyak_and_initial_eval_is_total() {
        // The averaged-eval branch must not panic when the averager has
        // absorbed no updates (satellite fix: total eval).
        let arch = Arch::autoencoder(&[64, 12, 64], Act::Tanh);
        let ds = mnist_like::autoencoder_dataset(64, 8, 3);
        let report = TrainSession::for_dataset(arch, &ds)
            .iters(0)
            .polyak(0.99)
            .eval_initial()
            .eval_rows(32)
            .run();
        assert_eq!(report.log.len(), 1);
        let row = report.log[0];
        assert_eq!(row.iter, 0);
        assert!(row.batch_loss.is_nan());
        assert!(row.train_err.is_finite());
        assert!(report.avg_params.is_none());
        assert_eq!(report.iters_run, 0);
    }

    #[test]
    fn mismatched_dataset_is_rejected() {
        let arch = Arch::autoencoder(&[64, 12, 64], Act::Tanh);
        let ds = mnist_like::autoencoder_dataset(32, 16, 1); // 256 cols
        let err = TrainSession::for_dataset(arch, &ds).iters(1).try_run().unwrap_err();
        assert!(err.contains("does not match arch"), "{err}");
    }
}
