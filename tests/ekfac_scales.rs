//! Dense-checked battery for the EKFAC scale re-estimation subsystem
//! (George et al. 2018, via PAPERS.md):
//!
//! - the projection-first per-example gradient second moments
//!   (`ModelBackend::grad_sq_in_basis`) equal the diagonal of the
//!   densely materialized per-example Fisher block in the Kronecker
//!   eigenbasis, to 1e-10 relative;
//! - re-estimated scales **weakly improve** the Frobenius distance to
//!   the per-layer Fisher block versus K-FAC's eigenvalue-product
//!   scales (Prop. 1: the second-moment diagonal is the
//!   Frobenius-optimal diagonal for the basis);
//! - on a single batch at γ = 0 the scales are consistent with the
//!   analytic exact Fisher of `fisher/exact.rs`, and the rescaled
//!   inverse matches its dense eigenbasis application;
//! - the optimizer's running scale state survives the serialized
//!   KFACCKPT wire format bit-exactly (the TrainSession-on-disk path
//!   and v1 rejection are covered in `tests/session.rs`).

use kfac::backend::{ModelBackend, RustBackend};
use kfac::coordinator::checkpoint;
use kfac::fisher::exact::ExactBlocks;
use kfac::fisher::stats::RawStats;
use kfac::fisher::{EkfacInverse, FisherInverse};
use kfac::linalg::kron::{kron, vec_mat};
use kfac::linalg::{Mat, SymEig};
use kfac::nn::net::{Fwd, Net};
use kfac::nn::{Act, Arch, LossKind, Params};
use kfac::optim::{Kfac, KfacConfig, Optimizer};
use kfac::rng::Rng;

/// Densely materialized per-example Fisher block of layer `i`:
/// `F = (1/m) Σ_n vec(g_n ā_nᵀ) vec(g_n ā_nᵀ)ᵀ` (column-stacking vec).
fn dense_fisher_block(fwd: &Fwd, gs: &[Mat], i: usize) -> Mat {
    let m = fwd.abars[0].rows;
    let (rows, cols) = (gs[i].cols, fwd.abars[i].cols);
    let n = rows * cols;
    let mut f = Mat::zeros(n, n);
    for case in 0..m {
        let dw = Mat::from_fn(rows, cols, |p, q| gs[i].at(case, p) * fwd.abars[i].at(case, q));
        let v = vec_mat(&dw);
        for a in 0..n {
            for b in 0..n {
                let acc = f.at(a, b) + v[a] * v[b] / m as f64;
                f.set(a, b, acc);
            }
        }
    }
    f
}

/// Diagonal of `(U_A ⊗ U_G)ᵀ F (U_A ⊗ U_G)` reshaped weight-like
/// (`d_out × (d_in+1)`): coordinate `q·d_out + p` lands at `(p, q)`.
fn diag_in_basis(f: &Mat, ua: &Mat, ug: &Mat) -> Mat {
    let u = kron(ua, ug);
    let d = u.transpose().matmul(f).matmul(&u);
    Mat::from_fn(ug.rows, ua.rows, |p, q| d.at(q * ug.rows + p, q * ug.rows + p))
}

/// Embed a weight-shaped scale matrix as the dense diagonal it denotes.
fn embed_diag(s: &Mat) -> Mat {
    let n = s.rows * s.cols;
    let mut d = Mat::zeros(n, n);
    for q in 0..s.cols {
        for p in 0..s.rows {
            let idx = q * s.rows + p;
            d.set(idx, idx, s.at(p, q));
        }
    }
    d
}

fn three_layer_setup(seed: u64, m: usize) -> (Net, Params, Mat) {
    let arch = Arch::new(
        vec![5, 4, 3],
        vec![Act::Tanh, Act::Identity],
        LossKind::SoftmaxCe,
    );
    let net = Net::new(arch.clone());
    let mut rng = Rng::new(seed);
    let p = arch.glorot_init(&mut rng);
    let x = Mat::randn(m, 5, 1.0, &mut rng);
    (net, p, x)
}

#[test]
fn reestimated_scales_match_dense_fisher_block_diagonal() {
    // Acceptance: the projection-first second moments equal the dense
    // per-example Fisher block's eigenbasis diagonal to 1e-10 rel, on
    // a seeded single batch.
    let (net, p, x) = three_layer_setup(1, 24);
    let fwd = net.forward(&p, &x);
    let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(7));
    let st = RawStats::from_batch(&fwd, &gs);
    let inv = EkfacInverse::build(&st, 0.5);
    let bases = inv.eigenbases().expect("ekfac exposes its bases");
    let scales = net.grad_sq_in_basis(&fwd, &gs, bases);
    for i in 0..net.arch.num_layers() {
        let f = dense_fisher_block(&fwd, &gs, i);
        let want = diag_in_basis(&f, &bases[i].ua, &bases[i].ug);
        let scale = want.max_abs().max(1e-300);
        let err = scales[i].sub(&want).max_abs() / scale;
        assert!(err < 1e-10, "layer {i}: rel err {err}");
    }
}

#[test]
fn reestimated_scales_weakly_improve_frobenius_distance() {
    // George et al. Prop. 1: among diagonal rescalings of a fixed
    // orthonormal basis U, the second-moment diagonal minimizes the
    // Frobenius distance to F — so it is never worse than K-FAC's
    // eigenvalue-product scales, and strictly better whenever the
    // Kronecker factorization is not exact.
    let (net, p, x) = three_layer_setup(2, 32);
    let fwd = net.forward(&p, &x);
    let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(9));
    let st = RawStats::from_batch(&fwd, &gs);
    let inv = EkfacInverse::build(&st, 0.0);
    let bases = inv.eigenbases().unwrap();
    let scales = net.grad_sq_in_basis(&fwd, &gs, bases);
    let mut total_re = 0.0;
    let mut total_prod = 0.0;
    for i in 0..net.arch.num_layers() {
        let f = dense_fisher_block(&fwd, &gs, i);
        let u = kron(&bases[i].ua, &bases[i].ug);
        let dist = |s: &Mat| {
            let approx = u.matmul(&embed_diag(s)).matmul(&u.transpose());
            f.sub(&approx).frob_norm()
        };
        let ea = SymEig::new(&st.aa[i]);
        let eg = SymEig::new(&st.gg[i]);
        let products = Mat::from_fn(eg.w.len(), ea.w.len(), |pp, q| {
            eg.w[pp].max(0.0) * ea.w[q].max(0.0)
        });
        let d_re = dist(&scales[i]);
        let d_prod = dist(&products);
        assert!(
            d_re <= d_prod + 1e-9 * (1.0 + d_prod),
            "layer {i}: re-estimated {d_re} worse than products {d_prod}"
        );
        total_re += d_re;
        total_prod += d_prod;
    }
    assert!(
        total_re < total_prod,
        "no strict improvement anywhere: {total_re} vs {total_prod}"
    );
}

#[test]
fn scales_consistent_with_exact_fisher_at_gamma_zero() {
    // Single-batch cross-validation against fisher/exact.rs: the
    // model-sampled second moments converge (in the sampling
    // expectation) to the diagonal of the *analytic* exact Fisher
    // block in the same basis, and at γ = 0 the rescaled inverse
    // matches the dense eigenbasis application of that diagonal.
    let arch = Arch::new(
        vec![4, 3, 2],
        vec![Act::Tanh, Act::Identity],
        LossKind::SquaredError,
    );
    let net = Net::new(arch.clone());
    let mut rng = Rng::new(3);
    let p = arch.glorot_init(&mut rng);
    let x = Mat::randn(12, 4, 1.0, &mut rng);
    let eb = ExactBlocks::compute(&net, &p, &x, 0, 2);
    let fwd = net.forward(&p, &x);
    let gs0 = net.sampled_backward(&p, &fwd, &mut Rng::new(11));
    let st = RawStats::from_batch(&fwd, &gs0);
    let mut inv = EkfacInverse::build(&st, 0.0);
    let bases = inv.eigenbases().unwrap().to_vec();

    // Monte-Carlo over the model's target distribution, averaged over
    // the fixed batch (matching ExactBlocks' per-row average).
    let layer = 0usize;
    let (rows, cols) = arch.weight_shape(layer);
    let mut s_mc = Mat::zeros(rows, cols);
    let nsamp = 6000;
    let mut srng = Rng::new(13);
    for _ in 0..nsamp {
        let gs = net.sampled_backward(&p, &fwd, &mut srng);
        let s = net.grad_sq_in_basis(&fwd, &gs, &bases);
        s_mc.axpy(1.0 / nsamp as f64, &s[layer]);
    }
    let f_exact = eb.f.block(
        eb.offs[layer],
        eb.offs[layer] + eb.sizes[layer],
        eb.offs[layer],
        eb.offs[layer] + eb.sizes[layer],
    );
    let exact = diag_in_basis(&f_exact, &bases[layer].ua, &bases[layer].ug);
    let scale = exact.max_abs().max(1e-300);
    let err = s_mc.sub(&exact).max_abs() / scale;
    assert!(err < 0.2, "MC scales vs exact Fisher diagonal: rel err {err}");

    // γ = 0 application check: swap in the exact diagonal and compare
    // against the dense U D⁻¹ Uᵀ (replicating the implementation's
    // rank-deficiency floor, which is inert on full-rank spectra).
    let exact_last = diag_in_basis(
        &eb.f.block(eb.offs[1], eb.offs[1] + eb.sizes[1], eb.offs[1], eb.offs[1] + eb.sizes[1]),
        &bases[1].ua,
        &bases[1].ug,
    );
    assert!(inv.set_scales(&[exact.clone(), exact_last.clone()], 0.0));
    let g = Params(vec![
        Mat::randn(rows, cols, 1.0, &mut rng),
        Mat::randn(2, 4, 1.0, &mut rng),
    ]);
    let got = inv.apply(&g);
    for (i, exact_i) in [exact, exact_last].iter().enumerate() {
        let u = kron(&bases[i].ua, &bases[i].ug);
        let floor = (1e-13 * exact_i.max_abs()).max(1e-300);
        let inv_d = exact_i.map(|v| 1.0 / v.max(0.0).max(floor));
        let vg = vec_mat(&g.0[i]);
        let proj = u.transpose().matvec(&vg);
        let rescaled: Vec<f64> = proj
            .iter()
            .enumerate()
            .map(|(idx, v)| v * inv_d.at(idx % exact_i.rows, idx / exact_i.rows))
            .collect();
        let back = u.matvec(&rescaled);
        let want = kfac::linalg::kron::unvec(&back, exact_i.rows, exact_i.cols);
        let rel = got.0[i].sub(&want).max_abs() / want.max_abs().max(1e-300);
        assert!(rel < 1e-8, "layer {i}: γ=0 dense application rel err {rel}");
    }
}

#[test]
fn backend_scales_agree_with_net_level_projection() {
    // The ModelBackend seam computes the same quantity the Net-level
    // dense checks above verify: τ₁ sub-batch + model-sampled targets
    // seeded by `seed`, deterministically.
    let (net, p, x) = three_layer_setup(4, 16);
    let mut be = RustBackend::new(net.arch.clone());
    let y = Mat::zeros(16, 3);
    let fwd = net.forward(&p, &x);
    let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(5));
    let st = RawStats::from_batch(&fwd, &gs);
    let inv = EkfacInverse::build(&st, 0.3);
    let bases = inv.eigenbases().unwrap().to_vec();
    let rows = 10usize;
    let from_backend = be.grad_sq_in_basis(&p, &x, &y, rows, 21, &bases);
    let xs = x.top_rows(rows);
    let sfwd = net.forward(&p, &xs);
    let sgs = net.sampled_backward(&p, &sfwd, &mut Rng::new(21));
    let want = net.grad_sq_in_basis(&sfwd, &sgs, &bases);
    for (i, (a, b)) in from_backend.iter().zip(want.iter()).enumerate() {
        assert!(a.sub(b).max_abs() == 0.0, "layer {i}: backend deviates");
        assert_eq!((a.rows, a.cols), net.arch.weight_shape(i));
    }
}

#[test]
fn kfac_scale_state_survives_the_wire_format_bit_exactly() {
    // Acceptance: checkpoint resume with live re-estimated scale state
    // is bit-exact. Unlike the in-memory snapshot test in
    // rust/src/optim/kfac.rs, this routes the optimizer state through
    // the serialized KFACCKPT v2 byte format before restoring (the
    // full TrainSession-on-disk path is exercised in tests/session.rs).
    let arch = Arch::new(
        vec![7, 5, 3],
        vec![Act::Tanh, Act::Identity],
        LossKind::SoftmaxCe,
    );
    let mut rng = Rng::new(19);
    let mut params_a = arch.sparse_init(&mut rng);
    let x = Mat::randn(48, 7, 1.0, &mut rng);
    let mut y = Mat::zeros(48, 3);
    for r in 0..48 {
        y.set(r, r % 3, 1.0);
    }
    let mut backend = RustBackend::new(arch.clone());
    // rebuilds at k ≤ 3 and k = 5 (resetting the scale epoch), scale
    // refresh at k = 6: the k = 8 snapshot is mid-refresh-interval
    let cfg = KfacConfig {
        lambda0: 8.0,
        t_inv: 5,
        t_scale: 3,
        refresh_async: false,
        ..KfacConfig::ekfac()
    };
    let mut opt_a = Kfac::new(&arch, cfg.clone());
    for _ in 0..8 {
        opt_a.step(&mut backend, &mut params_a, &x, &y);
    }
    let snap = opt_a.state();
    assert!(snap.mats("scale_s").is_some(), "running scale state must checkpoint");
    // round-trip the state through the serialized checkpoint bytes
    let ck = checkpoint::Checkpoint {
        version: checkpoint::CHECKPOINT_VERSION,
        iter: 8,
        cases: 0.0,
        time_s: 0.0,
        rng_words: [1, 2, 3, 4],
        rng_spare: None,
        params: params_a.clone(),
        polyak: None,
        opt: snap,
    };
    let back = checkpoint::from_bytes(&checkpoint::to_bytes(&ck)).expect("wire roundtrip");
    assert_eq!(back.opt.mats("scale_s"), ck.opt.mats("scale_s"), "scale mats changed on the wire");
    let mut params_b = back.params;
    let mut opt_b = Kfac::new(&arch, cfg);
    opt_b.load_state(&back.opt).expect("state loads");
    for s in 0..4 {
        let ia = opt_a.step(&mut backend, &mut params_a, &x, &y);
        let ib = opt_b.step(&mut backend, &mut params_b, &x, &y);
        assert_eq!(ia.loss.to_bits(), ib.loss.to_bits(), "loss diverged at step {s}");
        assert!(params_a == params_b, "params diverged at step {s}");
    }
}
