"""Layer-2 model programs vs jax autodiff / analytic oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, prng

RNG = np.random.default_rng(1)


def make_params(md):
    return [
        jnp.asarray(RNG.standard_normal(s).astype(np.float32) * 0.3)
        for s in md.weight_shapes()
    ]


def make_batch(md, m):
    x = jnp.asarray(RNG.standard_normal((m, md.widths[0])).astype(np.float32))
    if md.loss == "softmax_ce":
        idx = RNG.integers(0, md.widths[-1], size=m)
        y = jnp.asarray(np.eye(md.widths[-1], dtype=np.float32)[idx])
    elif md.loss == "sigmoid_ce":
        y = jnp.asarray(
            (RNG.uniform(size=(m, md.widths[-1])) < 0.5).astype(np.float32)
        )
    else:
        y = jnp.asarray(RNG.standard_normal((m, md.widths[-1])).astype(np.float32))
    return x, y


def pure_loss(md, params, x, y):
    """Reference mean loss via plain jnp (no pallas) for jax.grad."""
    a = jnp.concatenate([x, jnp.ones((x.shape[0], 1), jnp.float32)], 1)
    for i in range(md.num_layers):
        s = a @ params[i].T
        if i + 1 < md.num_layers:
            assert md.acts[i] == "tanh"
            a = jnp.concatenate(
                [jnp.tanh(s), jnp.ones((s.shape[0], 1), jnp.float32)], 1
            )
    return jnp.sum(model.per_case_loss(md, s, y))


@pytest.mark.parametrize("name", ["tiny_ae", "tiny_clf"])
def test_manual_backward_matches_jax_grad(name):
    md = model.by_name(name)
    params = make_params(md)
    x, y = make_batch(md, 12)
    w = jnp.ones(12, jnp.float32)
    outs = model.make_grad(md)(*params, x, y, w)
    loss, _err, dws = outs[0], outs[1], outs[2:]
    want_loss = pure_loss(md, params, x, y)
    np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-4)
    want_grads = jax.grad(lambda p: pure_loss(md, p, x, y))(params)
    for got, want in zip(dws, want_grads):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-4
        )


def test_mask_drops_rows_exactly():
    md = model.by_name("tiny_ae")
    params = make_params(md)
    x, y = make_batch(md, 10)
    w = jnp.asarray(np.array([1] * 6 + [0] * 4, np.float32))
    full = model.make_grad(md)(*params, x, y, w)
    sub = model.make_grad(md)(
        *params, x[:6].repeat(1, axis=0), y[:6], jnp.ones(6, jnp.float32)
    ) if False else None
    # recompute on the first 6 rows only (fresh shapes)
    x6 = jnp.concatenate([x[:6], jnp.zeros((4, x.shape[1]))], 0).astype(jnp.float32)
    y6 = jnp.concatenate([y[:6], jnp.zeros((4, y.shape[1]))], 0).astype(jnp.float32)
    again = model.make_grad(md)(*params, x6, y6, w)
    # masked rows' contents must not matter
    np.testing.assert_allclose(float(full[0]), float(again[0]), rtol=1e-5)
    for a, b in zip(full[2:], again[2:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_grad_stats_shapes_and_psd():
    md = model.by_name("tiny_ae")
    params = make_params(md)
    x, y = make_batch(md, 16)
    w = jnp.ones(16, jnp.float32)
    outs = model.make_grad_stats(md)(*params, x, y, w, jnp.int32(7))
    l = md.num_layers
    k = 2 + l
    aa = outs[k : k + l]
    aa_off = outs[k + l : k + 2 * l - 1]
    gg = outs[k + 2 * l - 1 : k + 3 * l - 1]
    gg_off = outs[k + 3 * l - 1 :]
    assert len(gg_off) == l - 1
    for i in range(l):
        d_in, d_out = md.widths[i] + 1, md.widths[i + 1]
        assert aa[i].shape == (d_in, d_in)
        assert gg[i].shape == (d_out, d_out)
        # symmetric PSD (as a sum of outer products)
        m = np.asarray(aa[i]) / 16.0
        np.testing.assert_allclose(m, m.T, atol=1e-5)
        evals = np.linalg.eigvalsh(m)
        assert evals.min() > -1e-5
        # homogeneous corner: sum of weights
        np.testing.assert_allclose(m[-1, -1], 1.0, rtol=1e-5)
    for i in range(l - 1):
        assert aa_off[i].shape == (md.widths[i] + 1, md.widths[i + 1] + 1)
        assert gg_off[i].shape == (md.widths[i + 1], md.widths[i + 2])


def test_gg_seed_determinism_and_variation():
    md = model.by_name("tiny_clf")
    params = make_params(md)
    x, y = make_batch(md, 16)
    w = jnp.ones(16, jnp.float32)
    f = model.make_grad_stats(md)
    a = f(*params, x, y, w, jnp.int32(3))
    b = f(*params, x, y, w, jnp.int32(3))
    c = f(*params, x, y, w, jnp.int32(4))
    l = md.num_layers
    # outs layout: loss, err, dW×l, aa×l, aa_off×(l−1), gg×l, gg_off×(l−1)
    gg_idx = 2 + l + l + (l - 1) + l - 1  # last gg block
    np.testing.assert_array_equal(np.asarray(a[gg_idx]), np.asarray(b[gg_idx]))
    assert np.abs(np.asarray(a[gg_idx]) - np.asarray(c[gg_idx])).max() > 0


def test_fvp_matches_finite_difference_quadratic():
    md = model.by_name("tiny_clf")
    params = make_params(md)
    x, _ = make_batch(md, 8)
    w = jnp.ones(8, jnp.float32)
    v = [jnp.asarray(RNG.standard_normal(p.shape).astype(np.float32)) for p in params]
    u = [jnp.asarray(RNG.standard_normal(p.shape).astype(np.float32)) for p in params]
    vfv, vfu, ufu = model.make_fvp2(md)(*params, x, w, *v, *u)
    # oracle: F = J^T F_R J with J from jax.jacfwd of z(params)
    def zfun(flat):
        ps, off = [], 0
        for p in params:
            n = p.size
            ps.append(flat[off : off + n].reshape(p.shape))
            off += n
        a = jnp.concatenate([x, jnp.ones((x.shape[0], 1), jnp.float32)], 1)
        for i in range(md.num_layers):
            s = a @ ps[i].T
            if i + 1 < md.num_layers:
                a = jnp.concatenate(
                    [jnp.tanh(s), jnp.ones((s.shape[0], 1), jnp.float32)], 1
                )
        return s

    flat = jnp.concatenate([p.reshape(-1) for p in params])
    vflat = jnp.concatenate([p.reshape(-1) for p in v])
    uflat = jnp.concatenate([p.reshape(-1) for p in u])
    _, jzv = jax.jvp(zfun, (flat,), (vflat,))
    _, jzu = jax.jvp(zfun, (flat,), (uflat,))
    z = zfun(flat)
    want_vfv = model.fr_quad_sum(md, z, jzv, jzv, w)
    want_vfu = model.fr_quad_sum(md, z, jzv, jzu, w)
    want_ufu = model.fr_quad_sum(md, z, jzu, jzu, w)
    np.testing.assert_allclose(float(vfv), float(want_vfv), rtol=1e-3)
    np.testing.assert_allclose(float(vfu), float(want_vfu), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(ufu), float(want_ufu), rtol=1e-3)


def test_prng_uniform_stats():
    u = np.asarray(prng.uniform(jnp.int32(5), (20000,)))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.02
    assert abs(u.var() - 1.0 / 12.0) < 0.01
    # different seeds decorrelate
    v = np.asarray(prng.uniform(jnp.int32(6), (20000,)))
    assert abs(np.corrcoef(u, v)[0, 1]) < 0.05


def test_prng_normal_and_samplers():
    z = np.asarray(prng.normal(jnp.int32(2), (20000,)))
    assert abs(z.mean()) < 0.05 and abs(z.std() - 1.0) < 0.05
    p = jnp.full((20000,), 0.3, jnp.float32)
    b = np.asarray(prng.bernoulli(jnp.int32(3), p))
    assert abs(b.mean() - 0.3) < 0.02
    logits = jnp.asarray(np.log(np.array([[0.2, 0.5, 0.3]], np.float32)))
    oh = np.asarray(
        prng.categorical_onehot(jnp.int32(4), jnp.tile(logits, (20000, 1)))
    )
    # Note: identical logits rows still get independent draws (the hash
    # counter runs over all elements).
    freq = oh.mean(axis=0)
    np.testing.assert_allclose(freq, [0.2, 0.5, 0.3], atol=0.03)
