//! EKFAC — diagonal rescaling in the Kronecker eigenbasis (George et
//! al. 2018, "Fast Approximate Natural Gradient Descent in a
//! Kronecker-factored Eigenbasis").
//!
//! Each block's Kronecker factors are eigendecomposed,
//! `Ā = U_A S_A U_Aᵀ` and `G = U_G S_G U_Gᵀ`, and the preconditioner is
//! a *diagonal* operator in the induced eigenbasis `U_A ⊗ U_G`:
//!
//! `U = U_G [ (U_Gᵀ V U_A) ⊘ D ] U_Aᵀ`,  `D_{pq} = s^G_p s^A_q + γ²`.
//!
//! With the eigenvalue-product scales used here this is algebraically
//! the **exact** Tikhonov-damped block inverse `(Ā ⊗ G + γ²I)⁻¹`
//! (paper eqn. 6) — the damping lives in the eigenbasis instead of
//! being factored onto `Ā` and `G` — computed with two
//! eigendecompositions per refresh and four layer-sized GEMMs per
//! apply. At `γ = 0` it coincides with the block-diagonal inverse
//! `G⁻¹ V Ā⁻¹`. The eigenbasis is also the natural seam for the full
//! EKFAC scale re-estimation (second moments of projected per-example
//! gradients), which needs per-example gradient access from the
//! backend and is left as a roadmap item.

use super::stats::RawStats;
use super::FisherInverse;
use crate::linalg::{Mat, SymEig};
use crate::nn::Params;

/// Cached Kronecker eigenbases and inverse diagonal scales.
pub struct EkfacInverse {
    /// Per layer: eigenvectors of `Ā_{i-1,i-1}` (columns), `(d+1)²`.
    ua: Vec<Mat>,
    /// Per layer: eigenvectors of `G_{i,i}` (columns), `d²`.
    ug: Vec<Mat>,
    /// Per layer: `1 / D` with `D_{pq} = s^G_p s^A_q + γ²`, shaped like
    /// the layer's weight matrix (`d_out × (d_in+1)`).
    inv_scale: Vec<Mat>,
}

impl EkfacInverse {
    /// Build from factor statistics with damping strength `γ` (added as
    /// `γ²` to the eigenvalue products — exact Tikhonov, not factored).
    /// Layer eigendecompositions run in parallel.
    pub fn build(stats: &RawStats, gamma: f64) -> EkfacInverse {
        let l = stats.num_layers();
        let damp = gamma * gamma;
        let parts = crate::par::par_map_send(l, 1, |i| {
            let ea = SymEig::new(&stats.aa[i]);
            let eg = SymEig::new(&stats.gg[i]);
            // Guard rank-deficient spectra: floor the denominator at a
            // tiny fraction of the largest eigenvalue product so γ = 0
            // on singular factors stays finite (jitter-style recovery).
            let max_a = ea.w.iter().fold(0.0f64, |m, &w| m.max(w.abs()));
            let max_g = eg.w.iter().fold(0.0f64, |m, &w| m.max(w.abs()));
            let floor = (1e-13 * max_a * max_g).max(1e-300);
            let mut inv_scale = Mat::zeros(eg.w.len(), ea.w.len());
            for p in 0..eg.w.len() {
                for q in 0..ea.w.len() {
                    let d = eg.w[p].max(0.0) * ea.w[q].max(0.0) + damp;
                    inv_scale.set(p, q, 1.0 / d.max(floor));
                }
            }
            (ea.v, eg.v, inv_scale)
        });
        let mut ua = Vec::with_capacity(l);
        let mut ug = Vec::with_capacity(l);
        let mut inv_scale = Vec::with_capacity(l);
        for (a, g, s) in parts {
            ua.push(a);
            ug.push(g);
            inv_scale.push(s);
        }
        EkfacInverse { ua, ug, inv_scale }
    }
}

impl FisherInverse for EkfacInverse {
    fn apply(&self, grads: &Params) -> Params {
        Params(
            grads
                .0
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    // project into the eigenbasis, rescale, project back
                    let proj = self.ug[i].matmul_tn(v).matmul(&self.ua[i]);
                    let scaled = proj.hadamard(&self.inv_scale[i]);
                    self.ug[i].matmul(&scaled).matmul_nt(&self.ua[i])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::blockdiag::BlockDiagInverse;
    use crate::fisher::stats::KfacStats;
    use crate::linalg::kron::{kron, unvec, vec_mat};
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    fn build_stats(arch: &Arch, m: usize, seed: u64) -> RawStats {
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(seed);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(m, arch.widths[0], 1.0, &mut rng);
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        let mut st = KfacStats::new(arch);
        st.update(&RawStats::from_batch(&fwd, &gs));
        st.s
    }

    #[test]
    fn matches_dense_exact_tikhonov_inverse() {
        // (Ā⊗G + γ²I)⁻¹ vec(V) against a dense inverse, per layer.
        let arch = Arch::new(
            vec![5, 4, 3],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let st = build_stats(&arch, 64, 1);
        let gamma = 0.6;
        let inv = EkfacInverse::build(&st, gamma);
        let mut rng = Rng::new(2);
        let grads = Params(
            (0..arch.num_layers())
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::randn(r, c, 1.0, &mut rng)
                })
                .collect(),
        );
        let got = inv.apply(&grads);
        for i in 0..arch.num_layers() {
            let dense = kron(&st.aa[i], &st.gg[i]).add_diag(gamma * gamma).inverse();
            let want = unvec(
                &dense.matvec(&vec_mat(&grads.0[i])),
                grads.0[i].rows,
                grads.0[i].cols,
            );
            let err = got.0[i].sub(&want).max_abs();
            assert!(err < 1e-7, "layer {i} err={err}");
        }
    }

    #[test]
    fn agrees_with_blockdiag_at_zero_damping() {
        // At γ = 0 (full-rank factors) the eigenbasis rescaling is
        // exactly G⁻¹ V Ā⁻¹.
        let arch = Arch::new(vec![6, 4], vec![Act::Identity], LossKind::SquaredError);
        let st = build_stats(&arch, 100, 3);
        let ek = EkfacInverse::build(&st, 0.0);
        let bd = BlockDiagInverse::build(&st, 0.0);
        let mut rng = Rng::new(4);
        let g = Params(vec![Mat::randn(4, 7, 1.0, &mut rng)]);
        let a = ek.apply(&g);
        let b = bd.apply(&g);
        let scale = b.0[0].max_abs().max(1e-12);
        let err = a.0[0].sub(&b.0[0]).max_abs() / scale;
        assert!(err < 1e-6, "rel err {err}");
    }

    #[test]
    fn preconditioner_is_positive_definite() {
        // ⟨g, F⁻¹g⟩ > 0 for random non-zero g (the inverse of an SPD
        // operator is SPD).
        let arch = Arch::new(
            vec![5, 4, 3],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let st = build_stats(&arch, 64, 5);
        let inv = EkfacInverse::build(&st, 0.3);
        let mut rng = Rng::new(6);
        for _ in 0..10 {
            let g = Params(
                (0..arch.num_layers())
                    .map(|i| {
                        let (r, c) = arch.weight_shape(i);
                        Mat::randn(r, c, 1.0, &mut rng)
                    })
                    .collect(),
            );
            let q = g.dot(&inv.apply(&g));
            assert!(q > 0.0, "quadratic form not positive: {q}");
        }
    }

    #[test]
    fn larger_gamma_shrinks_update() {
        let arch = Arch::new(vec![6, 4], vec![Act::Identity], LossKind::SquaredError);
        let st = build_stats(&arch, 48, 7);
        let mut rng = Rng::new(8);
        let g = Params(vec![Mat::randn(4, 7, 1.0, &mut rng)]);
        let small = EkfacInverse::build(&st, 1e-3).apply(&g);
        let large = EkfacInverse::build(&st, 10.0).apply(&g);
        assert!(large.norm_sq() < small.norm_sq());
    }

    #[test]
    fn rank_deficient_factors_stay_finite() {
        let arch = Arch::new(vec![3, 2], vec![Act::Identity], LossKind::SquaredError);
        let mut st = RawStats::zeros(&arch);
        st.aa[0] = Mat::filled(4, 4, 1.0); // rank 1
        st.gg[0] = Mat::filled(2, 2, 0.5); // rank 1
        let mut rng = Rng::new(9);
        let g = Params(vec![Mat::randn(2, 4, 1.0, &mut rng)]);
        for gamma in [0.0, 1e-6, 1.0] {
            let u = EkfacInverse::build(&st, gamma).apply(&g);
            assert!(
                u.0[0].data.iter().all(|v| v.is_finite()),
                "γ={gamma} produced non-finite entries"
            );
        }
    }
}
