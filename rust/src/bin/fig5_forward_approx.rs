//! Figure 5 — how well F̌ (block-diagonal) and F̂ (inverse-tridiagonal)
//! approximate F̃ in the *forward* direction. The paper's finding: F̌
//! only captures the diagonal blocks (a poor forward approximation),
//! while F̂ reproduces even the off-tridiagonal blocks of F̃ almost
//! exactly.
//!
//! Output: per-variant error maps + Frobenius summary;
//! results/fig5_forward.csv.

use kfac::coordinator::Problem;
use kfac::experiments::{partially_train, results_dir, scaled};
use kfac::fisher::exact::ExactBlocks;
use kfac::util::write_csv;

fn main() {
    println!("== Figure 5: F̌ and F̂ vs F̃ (forward approximation) ==");
    let (backend, params, ds) = partially_train(Problem::MnistClf, scaled(600, 200), 8, 0);
    let x = ds.x.top_rows(scaled(300, 100).min(ds.len()));
    let eb = ExactBlocks::compute(backend.net(), &params, &x, 1, 5);
    let gamma = 0.3;

    let ktilde = eb.ktilde_damped_dense(gamma);
    let fcheck = eb.fcheck_dense(gamma);
    let fhat = eb.fhat_inv_dense(gamma).inverse();

    let d_check = fcheck.sub(&ktilde);
    let d_hat = fhat.sub(&ktilde);
    println!("\n‖F̃‖_F = {:.4}", ktilde.frob_norm());
    println!(
        "‖F̌ − F̃‖_F = {:.4}  (rel {:.4})",
        d_check.frob_norm(),
        d_check.frob_norm() / ktilde.frob_norm()
    );
    println!(
        "‖F̂ − F̃‖_F = {:.4}  (rel {:.4})",
        d_hat.frob_norm(),
        d_hat.frob_norm() / ktilde.frob_norm()
    );

    let map_c = eb.block_avg_abs(&d_check);
    let map_h = eb.block_avg_abs(&d_hat);
    for (name, m) in [("|F̌ − F̃|", &map_c), ("|F̂ − F̃|", &map_h)] {
        println!("\n{name} (block-average |entries|):");
        for r in 0..m.rows {
            print!("  ");
            for c in 0..m.cols {
                print!(" {:>10.3e}", m.at(r, c));
            }
            println!();
        }
    }

    // structural checks from the paper:
    // F̌ is exact on the diagonal blocks; F̂ on the tridiagonal blocks,
    // and very good even off the band.
    let nb = map_c.rows;
    for i in 0..nb {
        assert!(map_c.at(i, i) < 1e-8, "F̌ must match diagonal blocks");
        assert!(map_h.at(i, i) < 1e-6, "F̂ must match diagonal blocks");
        if i + 1 < nb {
            assert!(map_h.at(i, i + 1) < 1e-6, "F̂ must match tridiagonal blocks");
        }
    }
    assert!(
        d_hat.frob_norm() < 0.5 * d_check.frob_norm(),
        "F̂ should be a much better forward approximation than F̌"
    );
    println!("\nOK: F̂ matches F̃ on the tridiagonal blocks exactly and approximates the rest well");

    let mut rows = Vec::new();
    for r in 0..nb {
        for c in 0..nb {
            rows.push(vec![r as f64, c as f64, map_c.at(r, c), map_h.at(r, c)]);
        }
    }
    let path = results_dir().join("fig5_forward.csv");
    write_csv(&path, &["block_i", "block_j", "fcheck_err", "fhat_err"], &rows).unwrap();
    println!("wrote {}", path.display());
}
