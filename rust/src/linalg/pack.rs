//! The packing seam shared by dense GEMM and convolution lowering.
//!
//! The blocked GEMM in [`gemm`](super::gemm) never reads its operands
//! directly in the hot loop — it copies `mr`-row / `nr`-column panels
//! into contiguous scratch first. That copy is pure data movement, so
//! the *description* of where element `(r, c)` of an operand lives is
//! the only thing the packers need: the [`PackSource`] trait. Two
//! sources implement it:
//!
//! - [`Strided`] — the classic `data[r·rs + c·cs]` view that serves
//!   every dense transpose variant (this is exactly the indexing the
//!   packers used before the seam was extracted, so the dense path is
//!   bit-identical: packing performs no arithmetic on the values);
//! - [`Im2col`] — a *virtual* patch matrix for convolution: row
//!   `n·P + t` is the receptive-field patch of case `n` at output
//!   position `t`, flattened `(ky, kx, c)`-major with a trailing
//!   homogeneous coordinate, and out-of-bounds (padding) taps read as
//!   zero. Conv forward/backward lower onto the existing packed SIMD
//!   GEMM through this view — no new kernels.
//!
//! Layout convention is NHWC: a flat feature vector indexes as
//! `(y·w + x)·c_in + c`, which makes the `[m·P, c_out]` GEMM output
//! *be* the `[m, P·c_out]` flat activation matrix (free reshape).

use super::Mat;

/// Anything the GEMM packers can read an `f64` element from.
///
/// `at(r, c)` must be pure (same value on every call) and cheap; the
/// packers call it once per packed element.
pub trait PackSource: Sync {
    fn at(&self, r: usize, c: usize) -> f64;
}

/// Stride-described view of a dense slice: element `(r, c)` lives at
/// `data[r·rs + c·cs]`. `rs`/`cs` encode all four transpose variants.
#[derive(Clone, Copy)]
pub struct Strided<'a> {
    pub data: &'a [f64],
    pub rs: usize,
    pub cs: usize,
}

impl<'a> Strided<'a> {
    pub fn new(data: &'a [f64], rs: usize, cs: usize) -> Strided<'a> {
        Strided { data, rs, cs }
    }
}

impl PackSource for Strided<'_> {
    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.rs + c * self.cs]
    }
}

/// Static shape of a 2-D convolution over NHWC-flattened inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvShape {
    /// Panic unless the shape yields at least one output position.
    pub fn validate(&self) {
        assert!(self.stride >= 1, "conv: stride must be >= 1");
        assert!(self.in_c >= 1 && self.kh >= 1 && self.kw >= 1, "conv: degenerate kernel");
        assert!(
            self.in_h + 2 * self.pad >= self.kh && self.in_w + 2 * self.pad >= self.kw,
            "conv: kernel larger than padded input"
        );
    }

    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Number of output spatial positions `P`.
    pub fn positions(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// Receptive-field patch length `K = c_in·kh·kw` (without the
    /// homogeneous coordinate).
    pub fn patch_len(&self) -> usize {
        self.in_c * self.kh * self.kw
    }

    /// Flat input width `h·w·c_in`.
    pub fn in_dim(&self) -> usize {
        self.in_h * self.in_w * self.in_c
    }

    /// Flat output width `out_h·out_w·c_out` for `c_out` channels.
    pub fn out_dim(&self, out_c: usize) -> usize {
        self.positions() * out_c
    }

    /// Map a patch row/column to the flat input index of the tap it
    /// reads, or `None` for a padding tap. Row `r = n·P + t`, column
    /// `c = (ky·kw + kx)·c_in + ic`.
    #[inline]
    fn tap(&self, r: usize, c: usize) -> Option<usize> {
        let p = self.positions();
        let (n, pos) = (r / p, r % p);
        let (oy, ox) = (pos / self.out_w(), pos % self.out_w());
        let ic = c % self.in_c;
        let kxy = c / self.in_c;
        let (ky, kx) = (kxy / self.kw, kxy % self.kw);
        let iy = oy * self.stride + ky;
        let ix = ox * self.stride + kx;
        if iy < self.pad || ix < self.pad {
            return None;
        }
        let (iy, ix) = (iy - self.pad, ix - self.pad);
        if iy >= self.in_h || ix >= self.in_w {
            return None;
        }
        Some(n * self.in_dim() + (iy * self.in_w + ix) * self.in_c + ic)
    }
}

/// Virtual im2col patch matrix: shape `[m·P, K+1]` over a flat
/// `[m, h·w·c_in]` NHWC input. The last column is the homogeneous
/// coordinate (`1.0` in a forward pass, `0.0` for tangents — the
/// derivative of a constant), padding taps read as `0.0`.
#[derive(Clone, Copy)]
pub struct Im2col<'a> {
    pub data: &'a [f64],
    pub shape: ConvShape,
    pub homog: f64,
}

impl<'a> Im2col<'a> {
    pub fn new(x: &'a Mat, shape: ConvShape, homog: f64) -> Im2col<'a> {
        assert_eq!(x.cols, shape.in_dim(), "im2col: input width mismatch");
        Im2col { data: &x.data, shape, homog }
    }
}

impl PackSource for Im2col<'_> {
    #[inline]
    fn at(&self, r: usize, c: usize) -> f64 {
        if c == self.shape.patch_len() {
            return self.homog;
        }
        match self.shape.tap(r, c) {
            Some(idx) => self.data[idx],
            None => 0.0,
        }
    }
}

/// Materialize the im2col patch matrix `[m·P, K+1]` for a batch `x` of
/// shape `[m, h·w·c_in]`. The homogeneous column takes the value
/// `homog` in every row.
pub fn im2col(x: &Mat, shape: ConvShape, homog: f64) -> Mat {
    let src = Im2col::new(x, shape, homog);
    let rows = x.rows * shape.positions();
    let cols = shape.patch_len() + 1;
    Mat::from_fn(rows, cols, |r, c| src.at(r, c))
}

/// Adjoint of patch extraction: scatter-add a patch-space gradient
/// `dpatch` (`[m·P, K]`, homogeneous column already dropped) back to
/// flat input space `[m, h·w·c_in]`. Padding taps are discarded —
/// exactly the taps [`Im2col`] reads as zero.
pub fn col2im_acc(dpatch: &Mat, shape: ConvShape, m: usize) -> Mat {
    let p = shape.positions();
    let kl = shape.patch_len();
    assert_eq!(dpatch.rows, m * p, "col2im: row count mismatch");
    assert_eq!(dpatch.cols, kl, "col2im: patch length mismatch");
    let mut out = Mat::zeros(m, shape.in_dim());
    for r in 0..dpatch.rows {
        let row = dpatch.row(r);
        for (c, &v) in row.iter().enumerate() {
            if let Some(idx) = shape.tap(r, c) {
                out.data[idx] += v;
            }
        }
    }
    out
}

/// Pack an `mc × kc` block of a source (rows `row0..`, depth `p0..`)
/// into `mr`-row panels: `dst[panel][p*mr + r]`, zero-padding the last
/// panel. Pure data movement — for a [`Strided`] source this performs
/// exactly the loads the pre-seam GEMM packer performed.
pub fn pack_a<S: PackSource>(
    dst: &mut [f64],
    mr: usize,
    a: &S,
    row0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
) {
    let panels = mc.div_ceil(mr);
    for ip in 0..panels {
        let panel = &mut dst[ip * kc * mr..(ip + 1) * kc * mr];
        let r0 = ip * mr;
        let rows = mr.min(mc - r0);
        for p in 0..kc {
            let slot = &mut panel[p * mr..p * mr + mr];
            for r in 0..rows {
                slot[r] = a.at(row0 + r0 + r, p0 + p);
            }
            for s in slot.iter_mut().skip(rows) {
                *s = 0.0;
            }
        }
    }
}

/// Pack a `kc × nc` block of a source (depth `p0..`, cols `col0..`)
/// into `nr`-column panels: `dst[panel][p*nr + c]`, zero-padding the
/// last panel.
pub fn pack_b<S: PackSource>(
    dst: &mut [f64],
    nr: usize,
    b: &S,
    p0: usize,
    kc: usize,
    col0: usize,
    nc: usize,
) {
    let panels = nc.div_ceil(nr);
    for jp in 0..panels {
        let panel = &mut dst[jp * kc * nr..(jp + 1) * kc * nr];
        let c0 = jp * nr;
        let cols = nr.min(nc - c0);
        for p in 0..kc {
            let slot = &mut panel[p * nr..p * nr + nr];
            for c in 0..cols {
                slot[c] = b.at(p0 + p, col0 + c0 + c);
            }
            for s in slot.iter_mut().skip(cols) {
                *s = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Naive patch extraction: loop over every (case, position, tap)
    /// with explicit bounds checks, independent of `ConvShape::tap`.
    fn naive_patches(x: &Mat, s: ConvShape, homog: f64) -> Mat {
        let (oh, ow) = (s.out_h(), s.out_w());
        let mut out = Mat::zeros(x.rows * oh * ow, s.patch_len() + 1);
        for n in 0..x.rows {
            for oy in 0..oh {
                for ox in 0..ow {
                    let r = (n * oh + oy) * ow + ox;
                    for ky in 0..s.kh {
                        for kx in 0..s.kw {
                            for ic in 0..s.in_c {
                                let c = (ky * s.kw + kx) * s.in_c + ic;
                                let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                                let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                                let v = if iy < 0
                                    || ix < 0
                                    || iy >= s.in_h as isize
                                    || ix >= s.in_w as isize
                                {
                                    0.0
                                } else {
                                    x.at(n, (iy as usize * s.in_w + ix as usize) * s.in_c + ic)
                                };
                                out.set(r, c, v);
                            }
                        }
                    }
                    out.set(r, s.patch_len(), homog);
                }
            }
        }
        out
    }

    fn shapes_under_test() -> Vec<ConvShape> {
        vec![
            // odd stride + padding
            ConvShape { in_h: 7, in_w: 5, in_c: 3, kh: 3, kw: 3, stride: 3, pad: 1 },
            // 1×1 kernel (pure channel mixing)
            ConvShape { in_h: 4, in_w: 6, in_c: 2, kh: 1, kw: 1, stride: 1, pad: 0 },
            // kernel = input size (collapses to a dense layer per channel)
            ConvShape { in_h: 5, in_w: 4, in_c: 2, kh: 5, kw: 4, stride: 1, pad: 0 },
            // stride 2, asymmetric kernel, padding
            ConvShape { in_h: 8, in_w: 8, in_c: 1, kh: 3, kw: 2, stride: 2, pad: 2 },
            // padding larger than needed on one side
            ConvShape { in_h: 3, in_w: 3, in_c: 4, kh: 3, kw: 3, stride: 1, pad: 1 },
        ]
    }

    #[test]
    fn im2col_matches_naive_patch_extraction() {
        let mut rng = Rng::new(42);
        for s in shapes_under_test() {
            s.validate();
            let x = Mat::randn(3, s.in_dim(), 1.0, &mut rng);
            for homog in [1.0, 0.0] {
                let got = im2col(&x, s, homog);
                let want = naive_patches(&x, s, homog);
                assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{s:?}");
                for (a, b) in got.data.iter().zip(want.data.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{s:?}");
                }
            }
        }
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> = <x, col2im(y)> for the non-homogeneous
        // columns — patch extraction is linear, col2im is its adjoint.
        let mut rng = Rng::new(7);
        for s in shapes_under_test() {
            let m = 2;
            let x = Mat::randn(m, s.in_dim(), 1.0, &mut rng);
            let y = Mat::randn(m * s.positions(), s.patch_len(), 1.0, &mut rng);
            let px = im2col(&x, s, 0.0);
            let mut lhs = 0.0;
            for r in 0..y.rows {
                for c in 0..y.cols {
                    lhs += px.at(r, c) * y.at(r, c);
                }
            }
            let back = col2im_acc(&y, s, m);
            let rhs = back.dot(&x);
            assert!((lhs - rhs).abs() < 1e-10, "{s:?}: lhs={lhs} rhs={rhs}");
        }
    }

    #[test]
    fn strided_pack_matches_pre_seam_indexing() {
        // The exact loads the packers performed before the seam was
        // extracted, written against the raw slice: a[(row)*ars + col].
        let mut rng = Rng::new(3);
        let (mr, nr) = (4usize, 8usize);
        for &(rows, cols, rs, cs) in
            &[(13usize, 9usize, 9usize, 1usize), (9, 13, 1, 9), (16, 8, 8, 1)]
        {
            let data: Vec<f64> = (0..rows * cols).map(|_| rng.normal()).collect();
            let (row0, mc, p0, kc) = (4usize, rows - 4, 2usize, cols.min(6));
            let mut got = vec![0.0; mc.div_ceil(mr) * mr * kc];
            pack_a(&mut got, mr, &Strided::new(&data, rs, cs), row0, mc, p0, kc);
            let mut want = vec![0.0; got.len()];
            for ip in 0..mc.div_ceil(mr) {
                let r0 = ip * mr;
                let live = mr.min(mc - r0);
                for p in 0..kc {
                    for r in 0..live {
                        want[ip * kc * mr + p * mr + r] =
                            data[(row0 + r0 + r) * rs + (p0 + p) * cs];
                    }
                }
            }
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(want.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            let (c0, nc) = (1usize, cols - 1);
            let (bp0, bkc) = (3usize, rows - 3);
            let mut gotb = vec![0.0; nc.div_ceil(nr) * nr * bkc];
            pack_b(&mut gotb, nr, &Strided::new(&data, rs, cs), bp0, bkc, c0, nc);
            let mut wantb = vec![0.0; gotb.len()];
            for jp in 0..nc.div_ceil(nr) {
                let j0 = jp * nr;
                let live = nr.min(nc - j0);
                for p in 0..bkc {
                    for c in 0..live {
                        wantb[jp * bkc * nr + p * nr + c] =
                            data[(bp0 + p) * rs + (c0 + j0 + c) * cs];
                    }
                }
            }
            for (a, b) in gotb.iter().zip(wantb.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn im2col_source_packs_like_materialized_matrix() {
        // Packing the virtual im2col view must equal materializing the
        // patch matrix first and packing that — the seam contract.
        let mut rng = Rng::new(11);
        let s = ConvShape { in_h: 6, in_w: 5, in_c: 2, kh: 3, kw: 2, stride: 2, pad: 1 };
        let x = Mat::randn(3, s.in_dim(), 1.0, &mut rng);
        let mat = im2col(&x, s, 1.0);
        let virt = Im2col::new(&x, s, 1.0);
        let (mr, kc) = (4usize, mat.cols);
        let mc = mat.rows;
        let mut from_virt = vec![0.0; mc.div_ceil(mr) * mr * kc];
        let mut from_mat = vec![0.0; from_virt.len()];
        pack_a(&mut from_virt, mr, &virt, 0, mc, 0, kc);
        pack_a(&mut from_mat, mr, &Strided::new(&mat.data, mat.cols, 1), 0, mc, 0, kc);
        for (a, b) in from_virt.iter().zip(from_mat.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn conv_shape_edge_geometry() {
        let s = ConvShape { in_h: 5, in_w: 4, in_c: 2, kh: 5, kw: 4, stride: 1, pad: 0 };
        assert_eq!((s.out_h(), s.out_w()), (1, 1));
        assert_eq!(s.patch_len(), 40);
        let s = ConvShape { in_h: 16, in_w: 16, in_c: 1, kh: 5, kw: 5, stride: 2, pad: 2 };
        assert_eq!((s.out_h(), s.out_w()), (8, 8));
        assert_eq!(s.out_dim(6), 8 * 8 * 6);
    }
}
