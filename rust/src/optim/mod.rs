//! Optimizers: the open [`Optimizer`] trait with its unified
//! [`StepInfo`] diagnostics and checkpointable [`OptState`], the K-FAC
//! algorithm (paper Algorithm 2) and the SGD-with-Nesterov-momentum
//! baseline of Sutskever et al. (2013) that implement it, plus
//! mini-batch-size schedules and Polyak-style iterate averaging.

pub mod kfac;
pub mod optimizer;
pub mod polyak;
pub mod schedule;
pub mod sgd;

pub use kfac::{Kfac, KfacConfig};
pub use optimizer::{OptState, Optimizer, StateVal, StepInfo};
pub use polyak::PolyakAverager;
pub use schedule::BatchSchedule;
pub use sgd::{Sgd, SgdConfig};
