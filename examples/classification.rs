//! Domain example: 10-way digit classification, K-FAC vs SGD+NAG,
//! both driven through the same `TrainSession` API (the optimizers are
//! interchangeable behind the `Optimizer` trait). Reproduces in
//! miniature the paper's claim that K-FAC needs orders of magnitude
//! fewer iterations than SGD with momentum.
//!
//!     cargo run --release --example classification

use kfac::coordinator::{Event, TrainSession};
use kfac::prelude::*;

fn run(name: &str, ds: &Dataset, arch: &Arch, opt: Box<dyn Optimizer>) -> Params {
    println!("== {name} ==");
    let report = TrainSession::for_dataset(arch.clone(), ds)
        .iters(60)
        .schedule(BatchSchedule::Fixed(500))
        .seed(2)
        .eval_every(10)
        .eval_rows(ds.len())
        .no_polyak()
        .params(arch.sparse_init(&mut Rng::new(1)))
        .optimizer_boxed(opt)
        .observer(|e| {
            if let Event::Eval { row } = e {
                if row.iter > 1 {
                    println!(
                        "iter {:>3}  loss {:.4}  error {:.2}%",
                        row.iter,
                        row.train_loss,
                        100.0 * row.train_err
                    );
                }
            }
        })
        .run();
    report.params
}

fn main() {
    let ds = kfac::data::mnist_like::classification_dataset(2000, 16, 0);
    let arch = Arch::classifier(&[256, 60, 40, 10], Act::Tanh);

    let p_kfac = run(
        "K-FAC (block-tridiagonal, momentum)",
        &ds,
        &arch,
        Box::new(Kfac::new(&arch, KfacConfig { lambda0: 5.0, t1: 2, ..Default::default() })),
    );
    let p_sgd = run(
        "SGD + Nesterov momentum",
        &ds,
        &arch,
        Box::new(Sgd::new(SgdConfig { lr: 0.05, mu_max: 0.99, ..Default::default() })),
    );

    let mut backend = RustBackend::new(arch);
    let (_, e_k) = backend.eval(&p_kfac, &ds.x, &ds.y);
    let (_, e_s) = backend.eval(&p_sgd, &ds.x, &ds.y);
    println!("\nfinal training error after 60 iterations:");
    println!("  K-FAC : {:.2}%", 100.0 * e_k);
    println!("  SGD   : {:.2}%", 100.0 * e_s);
}
