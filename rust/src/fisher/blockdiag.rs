//! Block-diagonal inverse approximation `F̌⁻¹` (paper Section 4.2).
//!
//! `F̌ = diag(Ā₀₀⊗G₁₁, …)`, so with the Kronecker inverse identity the
//! update proposal is computed layer-wise as
//! `U_i = G_{i,i}⁻¹ V_i Ā_{i-1,i-1}⁻¹` — two layer-sized GEMMs per
//! layer, never materializing anything bigger than a factor matrix.
//! The factor inverses are refreshed only every `T₃` iterations by the
//! optimizer; applying the cached inverse is cheap.

use super::damping::damped_factors;
use super::stats::RawStats;
use super::FisherInverse;
use crate::linalg::chol::spd_inverse;
use crate::linalg::Mat;
use crate::nn::Params;

/// Cached inverses of the damped Kronecker factors.
pub struct BlockDiagInverse {
    pub ainv: Vec<Mat>,
    pub ginv: Vec<Mat>,
}

impl BlockDiagInverse {
    /// Build from factor statistics with factored-Tikhonov strength `γ`.
    /// Layer factorizations run in parallel (paper §8: task 5 is
    /// parallelizable across layers).
    pub fn build(stats: &RawStats, gamma: f64) -> BlockDiagInverse {
        let l = stats.num_layers();
        let pairs = crate::par::par_map_send(l, 1, |i| {
            super::check_factors_finite("blkdiag", i, &stats.aa[i], &stats.gg[i]);
            let (ad, gd) = damped_factors(&stats.aa[i], &stats.gg[i], gamma);
            (spd_inverse(&ad), spd_inverse(&gd))
        });
        let (ainv, ginv) = pairs.into_iter().unzip();
        BlockDiagInverse { ainv, ginv }
    }
}

impl FisherInverse for BlockDiagInverse {
    fn apply(&self, grads: &Params) -> Params {
        Params(
            grads
                .0
                .iter()
                .enumerate()
                .map(|(i, v)| self.ginv[i].matmul(&v.matmul(&self.ainv[i])))
                .collect(),
        )
    }
}

/// Ablation variant: the **exact** Tikhonov damping of eqn. 6 —
/// `(Ā ⊗ G + γ² I ⊗ I)⁻¹` per block, inverted with the Appendix-B
/// machinery (a sum of Kronecker products no longer factorizes). The
/// paper reports the *factored* approximation (eqn. 7) often works
/// better in practice despite being motivated purely computationally;
/// this struct exists so that claim can be tested/ablated.
pub struct ExactTikhonovBlockDiag {
    blocks: Vec<crate::linalg::KronPairInverse>,
}

impl ExactTikhonovBlockDiag {
    /// `γ²` plays the role of `(λ+η)` in eqn. 6.
    pub fn build(stats: &RawStats, gamma: f64) -> ExactTikhonovBlockDiag {
        let l = stats.num_layers();
        let blocks = crate::par::par_map_send(l, 1, |i| {
            let id_a = Mat::eye(stats.aa[i].rows).scale(gamma * gamma);
            let id_g = Mat::eye(stats.gg[i].rows);
            crate::linalg::KronPairInverse::new(&stats.aa[i], &stats.gg[i], &id_a, &id_g, 1.0)
        });
        ExactTikhonovBlockDiag { blocks }
    }
}

impl FisherInverse for ExactTikhonovBlockDiag {
    fn apply(&self, grads: &Params) -> Params {
        Params(grads.0.iter().zip(self.blocks.iter()).map(|(v, b)| b.apply(v)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::stats::KfacStats;
    use crate::linalg::kron::{kron, unvec, vec_mat};
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    fn build_stats(net: &Net, p: &Params, x: &Mat, seed: u64) -> KfacStats {
        let fwd = net.forward(p, x);
        let gs = net.sampled_backward(p, &fwd, &mut Rng::new(seed));
        let mut st = KfacStats::new(&net.arch);
        st.update(&RawStats::from_batch(&fwd, &gs));
        st
    }

    #[test]
    fn apply_matches_dense_kron_inverse() {
        let arch = Arch::new(
            vec![5, 4, 3],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(64, 5, 1.0, &mut rng);
        let st = build_stats(&net, &p, &x, 2);
        let gamma = 0.1;
        let inv = BlockDiagInverse::build(&st.s, gamma);
        let grads = Params(p.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
        let got = inv.apply(&grads);
        // Dense check per layer: (Ā_d ⊗ G_d)^{-1} vec(V) = vec(U)
        for i in 0..arch.num_layers() {
            let (ad, gd) = damped_factors(&st.s.aa[i], &st.s.gg[i], gamma);
            let dense = kron(&ad, &gd).inverse();
            let want = unvec(
                &dense.matvec(&vec_mat(&grads.0[i])),
                grads.0[i].rows,
                grads.0[i].cols,
            );
            let err = got.0[i].sub(&want).max_abs();
            assert!(err < 1e-7, "layer {i} err={err}");
        }
    }

    #[test]
    fn preconditioning_identity_when_factors_identity() {
        // If Ā = I and G = I (γ=0), the update proposal is the gradient.
        let arch = Arch::new(vec![3, 2], vec![Act::Identity], LossKind::SquaredError);
        let mut st = RawStats::zeros(&arch);
        st.aa[0] = Mat::eye(4);
        st.gg[0] = Mat::eye(2);
        let inv = BlockDiagInverse::build(&st, 0.0);
        let mut rng = Rng::new(3);
        let g = Params(vec![Mat::randn(2, 4, 1.0, &mut rng)]);
        let u = inv.apply(&g);
        assert!(u.0[0].sub(&g.0[0]).max_abs() < 1e-10);
    }

    #[test]
    fn exact_tikhonov_matches_dense() {
        // (Ā⊗G + γ²I)⁻¹ vec(V) against a dense inverse.
        let arch = Arch::new(vec![5, 4], vec![Act::Identity], LossKind::SquaredError);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(8);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(48, 5, 1.0, &mut rng);
        let st = build_stats(&net, &p, &x, 9);
        let gamma = 0.6;
        let inv = ExactTikhonovBlockDiag::build(&st.s, gamma);
        let g = Params(vec![Mat::randn(4, 6, 1.0, &mut rng)]);
        let got = inv.apply(&g);
        let dense =
            kron(&st.s.aa[0], &st.s.gg[0]).add_diag(gamma * gamma).inverse();
        let want = unvec(&dense.matvec(&vec_mat(&g.0[0])), 4, 6);
        assert!(got.0[0].sub(&want).max_abs() < 1e-7);
    }

    #[test]
    fn larger_gamma_shrinks_update() {
        let arch = Arch::new(vec![6, 4], vec![Act::Identity], LossKind::SquaredError);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(4);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(32, 6, 1.0, &mut rng);
        let st = build_stats(&net, &p, &x, 5);
        let g = Params(vec![Mat::randn(4, 7, 1.0, &mut rng)]);
        let small = BlockDiagInverse::build(&st.s, 1e-3).apply(&g);
        let large = BlockDiagInverse::build(&st.s, 10.0).apply(&g);
        assert!(large.norm_sq() < small.norm_sq());
    }
}
