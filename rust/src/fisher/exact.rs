//! Exact Fisher and exact Kronecker-factored approximation over a layer
//! range, for small networks (the substrate of Figures 2, 3, 5, 6).
//!
//! The exact Fisher is `F = E_x[ Jᵀ F_R J ]` where `J = dz/dθ` (per-case
//! output Jacobian) and `F_R` the predictive-distribution Fisher — the
//! expectation over targets is done **analytically**, so these are the
//! true quantities, not Monte-Carlo estimates. The per-case Jacobians
//! are obtained by back-propagating each of the `d_out` unit vectors,
//! using the batched backward pass over a row-replicated input.

use super::damping::damped_factors;
use crate::linalg::kron::kron;
use crate::linalg::Mat;
use crate::nn::net::Net;
use crate::nn::{LossKind, Params};

impl LossKind {
    /// Dense `F_R(z)` for a single output row `z`.
    pub fn fr_matrix(self, z: &[f64]) -> Mat {
        let d = z.len();
        match self {
            LossKind::SquaredError => Mat::eye(d),
            LossKind::SigmoidCe => {
                let mut m = Mat::zeros(d, d);
                for i in 0..d {
                    let p = 1.0 / (1.0 + (-z[i]).exp());
                    m.set(i, i, p * (1.0 - p));
                }
                m
            }
            LossKind::SoftmaxCe => {
                let mx = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let exps: Vec<f64> = z.iter().map(|v| (v - mx).exp()).collect();
                let sum: f64 = exps.iter().sum();
                let p: Vec<f64> = exps.iter().map(|e| e / sum).collect();
                Mat::from_fn(d, d, |i, j| {
                    if i == j {
                        p[i] * (1.0 - p[i])
                    } else {
                        -p[i] * p[j]
                    }
                })
            }
        }
    }
}

/// Exact Fisher `F` and exact Kronecker factors `Ā_{i,j}`, `G_{i,j}`
/// over layers `lo..hi` (0-based, half-open), averaged over the rows of
/// the supplied input batch.
pub struct ExactBlocks {
    pub lo: usize,
    pub hi: usize,
    /// `W_i` shapes for layers in range.
    pub shapes: Vec<(usize, usize)>,
    /// Per-block parameter counts and offsets into the dense matrices.
    pub sizes: Vec<usize>,
    pub offs: Vec<usize>,
    /// Exact Fisher over the range.
    pub f: Mat,
    /// `aa[i][j] = Ā_{lo+i-1, lo+j-1}` (input-side second moments).
    pub aa: Vec<Vec<Mat>>,
    /// `gg[i][j] = G_{lo+i, lo+j}` (exact, expectation over the model).
    pub gg: Vec<Vec<Mat>>,
}

impl ExactBlocks {
    pub fn compute(net: &Net, params: &Params, x: &Mat, lo: usize, hi: usize) -> ExactBlocks {
        let l = net.arch.num_layers();
        assert!(lo < hi && hi <= l);
        let nb = hi - lo;
        let d_out = *net.arch.widths.last().unwrap();
        let shapes: Vec<(usize, usize)> = (lo..hi).map(|i| net.arch.weight_shape(i)).collect();
        let sizes: Vec<usize> = shapes.iter().map(|(r, c)| r * c).collect();
        let offs: Vec<usize> = sizes
            .iter()
            .scan(0usize, |acc, s| {
                let o = *acc;
                *acc += s;
                Some(o)
            })
            .collect();
        let total: usize = sizes.iter().sum();

        let mut f = Mat::zeros(total, total);
        let mut aa =
            vec![vec![Mat::zeros(0, 0); nb]; nb];
        let mut gg = vec![vec![Mat::zeros(0, 0); nb]; nb];
        for i in 0..nb {
            for j in 0..nb {
                let (ri, _) = (net.arch.widths[lo + i] + 1, 0);
                let rj = net.arch.widths[lo + j] + 1;
                aa[i][j] = Mat::zeros(ri, rj);
                gg[i][j] = Mat::zeros(net.arch.widths[lo + i + 1], net.arch.widths[lo + j + 1]);
            }
        }

        let m = x.rows;
        let inv_m = 1.0 / m as f64;
        for r in 0..m {
            // Replicate the case d_out times, backprop from dz = I.
            let xrep = Mat::from_fn(d_out, x.cols, |_, c| x.at(r, c));
            let fwd = net.forward(params, &xrep);
            let dz = Mat::eye(d_out);
            let js = net.backward(params, &fwd, &dz); // js[i]: [d_out, d_{i+1}]
            let z_row = fwd.z().row(0).to_vec();
            let fr = net.arch.loss.fr_matrix(&z_row);

            // Factors.
            for i in 0..nb {
                let abar_i = fwd.abars[lo + i].row(0);
                for j in 0..nb {
                    let abar_j = fwd.abars[lo + j].row(0);
                    // aa[i][j] += abar_i abar_jᵀ / m
                    for (ri, &ai) in abar_i.iter().enumerate() {
                        let row = aa[i][j].row_mut(ri);
                        for (cj, &aj) in abar_j.iter().enumerate() {
                            row[cj] += inv_m * ai * aj;
                        }
                    }
                    // gg[i][j] += js_iᵀ F_R js_j / m
                    let frj = fr.matmul(&js[lo + j]);
                    let gij = js[lo + i].matmul_tn(&frj);
                    gg[i][j].axpy(inv_m, &gij);
                }
            }

            // Jacobian over the range, column-stacked per block:
            // vec(DW)[c*rows + rr] with DW = g ābarᵀ  =>  J[k, off + c*rows+rr]
            //   = ābar[c] * js[k, rr].
            let mut jmat = Mat::zeros(d_out, total);
            for (bi, li) in (lo..hi).enumerate() {
                let abar = fwd.abars[li].row(0).to_vec();
                let (rows, cols) = shapes[bi];
                let off = offs[bi];
                for k in 0..d_out {
                    let jrow = jmat.row_mut(k);
                    for c in 0..cols {
                        let ac = abar[c];
                        if ac == 0.0 {
                            continue;
                        }
                        let base = off + c * rows;
                        for rr in 0..rows {
                            jrow[base + rr] = ac * js[li].at(k, rr);
                        }
                    }
                }
            }
            // F += Jᵀ F_R J / m
            let frj = fr.matmul(&jmat);
            let fx = jmat.matmul_tn(&frj);
            f.axpy(inv_m, &fx);
        }

        ExactBlocks { lo, hi, shapes, sizes, offs, f, aa, gg }
    }

    fn assemble(&self, block: impl Fn(usize, usize) -> Option<Mat>) -> Mat {
        let total: usize = self.sizes.iter().sum();
        let mut out = Mat::zeros(total, total);
        let nb = self.sizes.len();
        for i in 0..nb {
            for j in 0..nb {
                if let Some(b) = block(i, j) {
                    assert_eq!((b.rows, b.cols), (self.sizes[i], self.sizes[j]));
                    out.set_block(self.offs[i], self.offs[j], &b);
                }
            }
        }
        out
    }

    /// Dense `F̃` (Khatri–Rao of the exact factors, eqn. 1).
    pub fn ktilde_dense(&self) -> Mat {
        self.assemble(|i, j| Some(kron(&self.aa[i][j], &self.gg[i][j])))
    }

    /// Dense block-diagonal `F̌` with factored Tikhonov strength `γ`
    /// applied to the diagonal factors (γ = 0 for the raw version).
    pub fn fcheck_dense(&self, gamma: f64) -> Mat {
        self.assemble(|i, j| {
            (i == j).then(|| {
                let (ad, gd) = damped_factors(&self.aa[i][i], &self.gg[i][i], gamma);
                kron(&ad, &gd)
            })
        })
    }

    /// Dense `F̂⁻¹ = Ξᵀ Λ Ξ` (block-tridiagonal inverse), with factored
    /// Tikhonov strength `γ` on the diagonal factors.
    pub fn fhat_inv_dense(&self, gamma: f64) -> Mat {
        let nb = self.sizes.len();
        let damped: Vec<(Mat, Mat)> = (0..nb)
            .map(|i| damped_factors(&self.aa[i][i], &self.gg[i][i], gamma))
            .collect();
        let total: usize = self.sizes.iter().sum();
        let mut psis = Vec::new();
        for i in 0..nb - 1 {
            let fnext_inv = kron(&damped[i + 1].0, &damped[i + 1].1).inverse();
            let foff = kron(&self.aa[i][i + 1], &self.gg[i][i + 1]);
            psis.push(foff.matmul(&fnext_inv));
        }
        let mut xi = Mat::eye(total);
        for i in 0..nb - 1 {
            xi.set_block(self.offs[i], self.offs[i + 1], &psis[i].scale(-1.0));
        }
        let mut lam = Mat::zeros(total, total);
        for i in 0..nb {
            let fii = kron(&damped[i].0, &damped[i].1);
            let sig = if i + 1 < nb {
                let fnext = kron(&damped[i + 1].0, &damped[i + 1].1);
                fii.sub(&psis[i].matmul(&fnext).matmul_nt(&psis[i]))
            } else {
                fii
            };
            lam.set_block(self.offs[i], self.offs[i], &sig.inverse());
        }
        xi.transpose().matmul(&lam).matmul(&xi)
    }

    /// Dense damped `F̃` (diagonal factors damped, off-diagonal blocks raw).
    pub fn ktilde_damped_dense(&self, gamma: f64) -> Mat {
        self.assemble(|i, j| {
            if i == j {
                let (ad, gd) = damped_factors(&self.aa[i][i], &self.gg[i][i], gamma);
                Some(kron(&ad, &gd))
            } else {
                Some(kron(&self.aa[i][j], &self.gg[i][j]))
            }
        })
    }

    /// `nb × nb` map of average |entries| per block of `m` — the paper's
    /// Figure 3 right panel.
    pub fn block_avg_abs(&self, m: &Mat) -> Mat {
        let nb = self.sizes.len();
        Mat::from_fn(nb, nb, |i, j| {
            let b = m.block(
                self.offs[i],
                self.offs[i] + self.sizes[i],
                self.offs[j],
                self.offs[j] + self.sizes[j],
            );
            b.data.iter().map(|v| v.abs()).sum::<f64>() / (b.rows * b.cols) as f64
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Act, Arch};
    use crate::rng::Rng;

    fn setup() -> (Net, Params, Mat) {
        let arch = Arch::new(
            vec![6, 5, 4, 3],
            vec![Act::Tanh, Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(12, 6, 1.0, &mut rng);
        (Net::new(arch), p, x)
    }

    #[test]
    fn exact_fisher_matches_fvp_quadratic_forms() {
        let (net, p, x) = setup();
        let eb = ExactBlocks::compute(&net, &p, &x, 0, 3);
        let mut rng = Rng::new(2);
        // Random direction over all layers; quadratic form through the
        // dense F must match the Appendix-C jvp computation.
        for _ in 0..5 {
            let v =
                Params(p.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
            let q = net.fvp_quad(&p, &x, &[&v]).at(0, 0);
            // dense: vᵀ F v with column-stacked vec per block
            let mut vv = vec![0.0; eb.f.rows];
            for (bi, w) in v.0.iter().enumerate() {
                let vb = crate::linalg::kron::vec_mat(w);
                vv[eb.offs[bi]..eb.offs[bi] + vb.len()].copy_from_slice(&vb);
            }
            let fv = eb.f.matvec(&vv);
            let dense_q: f64 = vv.iter().zip(fv.iter()).map(|(a, b)| a * b).sum();
            assert!(
                (q - dense_q).abs() < 1e-8 * (1.0 + q.abs()),
                "q={q} dense={dense_q}"
            );
        }
    }

    #[test]
    fn exact_fisher_is_symmetric_psd() {
        let (net, p, x) = setup();
        let eb = ExactBlocks::compute(&net, &p, &x, 1, 3);
        assert!(eb.f.sub(&eb.f.transpose()).max_abs() < 1e-10);
        let eig = crate::linalg::SymEig::new(&eb.f);
        assert!(eig.w[0] > -1e-10, "min eig {}", eig.w[0]);
    }

    #[test]
    fn gg_matches_monte_carlo_sampled_targets() {
        let (net, p, x) = setup();
        let eb = ExactBlocks::compute(&net, &p, &x, 0, 3);
        // Monte-Carlo estimate of G_{1,1} via sampled-target backward.
        let mut rng = Rng::new(3);
        let fwd = net.forward(&p, &x);
        let mut mc = Mat::zeros(eb.gg[1][1].rows, eb.gg[1][1].cols);
        let n = 4000;
        for _ in 0..n {
            let gs = net.sampled_backward(&p, &fwd, &mut rng);
            mc.axpy(1.0 / (n as f64 * x.rows as f64), &gs[1].matmul_tn(&gs[1]));
        }
        let err = mc.sub(&eb.gg[1][1]).max_abs();
        let scale = eb.gg[1][1].max_abs().max(1e-6);
        assert!(err / scale < 0.15, "rel err {}", err / scale);
    }

    #[test]
    fn ktilde_diag_blocks_are_kron_of_factors() {
        let (net, p, x) = setup();
        let eb = ExactBlocks::compute(&net, &p, &x, 0, 3);
        let kt = eb.ktilde_dense();
        let b0 = kt.block(0, eb.sizes[0], 0, eb.sizes[0]);
        let want = kron(&eb.aa[0][0], &eb.gg[0][0]);
        assert!(b0.sub(&want).max_abs() < 1e-12);
    }

    #[test]
    fn fhat_inverse_agrees_with_ktilde_on_tridiagonal() {
        let (net, p, x) = setup();
        let eb = ExactBlocks::compute(&net, &p, &x, 0, 3);
        let gamma = 0.1;
        let fhat = eb.fhat_inv_dense(gamma).inverse();
        let ktd = eb.ktilde_damped_dense(gamma);
        let nb = eb.sizes.len();
        for i in 0..nb {
            for j in 0..nb {
                if (i as isize - j as isize).abs() <= 1 {
                    let bi = fhat.block(
                        eb.offs[i],
                        eb.offs[i] + eb.sizes[i],
                        eb.offs[j],
                        eb.offs[j] + eb.sizes[j],
                    );
                    let bj = ktd.block(
                        eb.offs[i],
                        eb.offs[i] + eb.sizes[i],
                        eb.offs[j],
                        eb.offs[j] + eb.sizes[j],
                    );
                    let rel = bi.sub(&bj).max_abs() / bj.max_abs().max(1e-12);
                    assert!(rel < 1e-6, "block ({i},{j}) rel={rel}");
                }
            }
        }
    }
}
