//! Shared harness code for the figure-regeneration binaries
//! (`rust/src/bin/fig*.rs`). Each paper figure maps to one binary; the
//! common machinery — running a set of optimizer variants on a problem
//! and collecting training curves, and partially training a network to
//! a given iteration for the structure/damping experiments — lives here.

use crate::backend::{ModelBackend, RustBackend};
use crate::coordinator::trainer::{log_to_csv, LogRow, Optimizer, Problem, TrainConfig, Trainer};
use crate::fisher::InverseKind;
use crate::nn::Params;
use crate::optim::{KfacConfig, SgdConfig};
use crate::rng::Rng;
use std::path::PathBuf;

/// A named optimizer variant for comparison plots.
pub struct Variant {
    pub name: String,
    pub optimizer: Optimizer,
}

impl Variant {
    pub fn kfac(name: &str, inverse: InverseKind, momentum: bool, lambda0: f64) -> Variant {
        // λ adapted every iteration: the figure runs are 1–2 orders of
        // magnitude shorter than the paper's, so the LM rule must settle
        // within tens of iterations rather than hundreds (T₁ = 5 with
        // λ₀ = 150 would leave the runs over-damped throughout).
        let mut cfg = KfacConfig { inverse, lambda0, t1: 1, ..Default::default() };
        cfg.momentum = momentum;
        Variant { name: name.to_string(), optimizer: Optimizer::Kfac(cfg) }
    }

    pub fn sgd(name: &str, lr: f64, mu_max: f64) -> Variant {
        Variant {
            name: name.to_string(),
            optimizer: Optimizer::Sgd(SgdConfig { lr, mu_max, ..Default::default() }),
        }
    }
}

/// Results directory (override with KFAC_RESULTS_DIR).
pub fn results_dir() -> PathBuf {
    std::env::var("KFAC_RESULTS_DIR").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("results"))
}

/// Scale factor for experiment sizes (override with KFAC_EXP_SCALE, in
/// (0, 1]; smaller = faster smoke runs).
pub fn exp_scale() -> f64 {
    std::env::var("KFAC_EXP_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(1.0)
}

/// Scale a count by `exp_scale`, with a floor.
pub fn scaled(n: usize, floor: usize) -> usize {
    ((n as f64 * exp_scale()) as usize).max(floor)
}

/// Run one variant on one problem with a fresh backend/params and
/// return the log; also writes `results/<tag>.csv`.
pub fn run_variant(
    problem: Problem,
    ds: &crate::data::Dataset,
    cfg: &TrainConfig,
    variant: Variant,
    seed: u64,
    tag: &str,
) -> Vec<LogRow> {
    let arch = problem.arch();
    let mut backend = RustBackend::new(arch.clone());
    run_variant_with_backend(&mut backend, ds, cfg, variant, seed, tag)
}

/// Same, but with a caller-supplied backend (e.g. PJRT).
pub fn run_variant_with_backend(
    backend: &mut dyn ModelBackend,
    ds: &crate::data::Dataset,
    cfg: &TrainConfig,
    variant: Variant,
    seed: u64,
    tag: &str,
) -> Vec<LogRow> {
    let arch = backend.arch().clone();
    let mut params = arch.sparse_init(&mut Rng::new(seed));
    let log = Trainer::new(cfg.clone(), ds).run(backend, &mut params, variant.optimizer, true);
    let path = results_dir().join(format!("{tag}.csv"));
    if let Err(e) = log_to_csv(&path, &log) {
        eprintln!("warning: could not write {}: {e}", path.display());
    }
    log
}

/// Parse a training-log CSV back into rows (cache hits for re-plotting
/// binaries like fig11 that reuse fig10's runs).
pub fn load_log(tag: &str) -> Option<Vec<LogRow>> {
    let path = results_dir().join(format!("{tag}.csv"));
    let (header, rows) = crate::util::read_csv(&path).ok()?;
    if header != ["iter", "cases", "time_s", "batch_loss", "train_err", "train_loss"] {
        return None;
    }
    Some(
        rows.into_iter()
            .map(|r| LogRow {
                iter: r[0] as usize,
                cases: r[1],
                time_s: r[2],
                batch_loss: r[3],
                train_err: r[4],
                train_loss: r[5],
            })
            .collect(),
    )
}

/// Run-or-load: reuse `results/<tag>.csv` when present (so e.g. fig11
/// can replot fig10's runs without re-training).
pub fn cached_run(tag: &str, f: impl FnOnce() -> Vec<LogRow>) -> Vec<LogRow> {
    if let Some(log) = load_log(tag) {
        println!("# {tag}: loaded cached results/{tag}.csv ({} rows)", log.len());
        return log;
    }
    f()
}

/// The Figure 10/11 experiment: all three problems × optimizer variants
/// with the paper's exponentially increasing batch schedule for K-FAC
/// and a fixed batch for the SGD baseline. Returns
/// (problem, variant, log) triples; each run is cached by tag.
pub fn training_curves_fig10(
    backend_kind: &str,
    iters: usize,
    n_data: usize,
) -> Vec<(Problem, String, Vec<LogRow>)> {
    use crate::optim::BatchSchedule;
    let mut out = Vec::new();
    for problem in [Problem::CurvesAe, Problem::MnistAe, Problem::FacesAe] {
        let ds = problem.dataset(n_data, 0);
        let m1 = 250.min(n_data);
        let exp_sched = BatchSchedule::exponential_reaching(m1, n_data, (iters * 3 / 4).max(2));
        let variants: Vec<(String, Variant, BatchSchedule)> = vec![
            (
                "kfac_blktridiag".into(),
                Variant::kfac("blktridiag", InverseKind::BlockTridiag, true, 5.0),
                exp_sched.clone(),
            ),
            (
                "kfac_blkdiag".into(),
                Variant::kfac("blkdiag", InverseKind::BlockDiag, true, 5.0),
                exp_sched.clone(),
            ),
            (
                "kfac_nomom".into(),
                Variant::kfac("nomom", InverseKind::BlockTridiag, false, 5.0),
                BatchSchedule::Fixed(500.min(n_data)),
            ),
            ("sgd".into(), Variant::sgd("sgd", 0.02, 0.99), BatchSchedule::Fixed(500.min(n_data))),
        ];
        for (vname, variant, schedule) in variants {
            let tag = format!("fig10_{}_{vname}", problem.name());
            let cfg = TrainConfig {
                iters,
                schedule,
                seed: 0,
                eval_every: 5,
                eval_rows: 1000.min(n_data),
                polyak: Some(0.99),
            };
            let log = cached_run(&tag, || {
                println!("# running {tag} ({backend_kind} backend)…");
                match backend_kind {
                    "pjrt" => {
                        let dir = PathBuf::from(
                            std::env::var("KFAC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
                        );
                        match crate::backend::PjrtBackend::new(&dir, problem.name()) {
                            Ok(mut b) => {
                                run_variant_with_backend(&mut b, &ds, &cfg, variant, 1, &tag)
                            }
                            Err(e) => {
                                eprintln!("# pjrt unavailable ({e:#}); falling back to rust");
                                run_variant(problem, &ds, &cfg, variant, 1, &tag)
                            }
                        }
                    }
                    _ => run_variant(problem, &ds, &cfg, variant, 1, &tag),
                }
            });
            out.push((problem, vname, log));
        }
    }
    out
}

/// Partially train a network with K-FAC (rust backend, batch mode) and
/// return (params, backend) — the setup used by Figures 2/3/5/6/7,
/// which examine quantities "at iteration N" of a K-FAC run.
pub fn partially_train(
    problem: Problem,
    n_data: usize,
    iters: usize,
    seed: u64,
) -> (RustBackend, Params, crate::data::Dataset) {
    let arch = problem.arch();
    let ds = problem.dataset(n_data, seed);
    let mut backend = RustBackend::new(arch.clone());
    let mut params = arch.sparse_init(&mut Rng::new(seed ^ 0xA5));
    let cfg = TrainConfig {
        iters,
        schedule: crate::optim::BatchSchedule::Fixed(n_data),
        eval_every: usize::MAX,
        eval_rows: 1,
        polyak: None,
        seed,
    };
    let kcfg = KfacConfig { lambda0: 15.0, ..Default::default() };
    let _ = Trainer::new(cfg, &ds).run(&mut backend, &mut params, Optimizer::Kfac(kcfg), false);
    (backend, params, ds)
}
