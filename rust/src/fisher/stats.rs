//! Fisher-factor statistics (paper Section 5).
//!
//! Per mini-batch we form the second moments
//!
//! - `aa[i]   = Ā_{i-1,i-1} = E[ā_{i-1} ā_{i-1}ᵀ]` (input side of layer i),
//! - `aa_off[i] = Ā_{i-1,i} = E[ā_{i-1} ā_iᵀ]`,
//! - `gg[i]   = G_{i,i} = E[g_i g_iᵀ]`,
//! - `gg_off[i] = G_{i,i+1} = E[g_i g_{i+1}ᵀ]`,
//!
//! with the `g` statistics computed from a backward pass whose targets
//! are **sampled from the model's predictive distribution** (so they
//! estimate the standard Fisher, not the empirical one), and maintain
//! exponentially-decayed running averages with
//! `ε = min(1 − 1/k, 0.95)` — the estimate therefore depends on much
//! more data than a single mini-batch, which is the key practical
//! advantage over HF-style methods the paper emphasizes.

use crate::linalg::Mat;
use crate::nn::net::Fwd;
use crate::nn::Arch;

/// Second-moment statistics from a single mini-batch (or an EMA of them;
/// the two share a shape).
#[derive(Clone, Debug)]
pub struct RawStats {
    /// `Ā_{i-1,i-1}`, shape `(d_{i-1}+1)²`, for each layer i (0-based).
    pub aa: Vec<Mat>,
    /// `Ā_{i-1,i}`, shape `(d_{i-1}+1) × (d_i+1)`, for i = 0..ℓ-2.
    pub aa_off: Vec<Mat>,
    /// `G_{i,i}`, shape `d_i²`.
    pub gg: Vec<Mat>,
    /// `G_{i,i+1}`, shape `d_i × d_{i+1}`, for i = 0..ℓ-2.
    pub gg_off: Vec<Mat>,
}

impl RawStats {
    /// Compute from cached forward activations and (sampled-target)
    /// backward derivatives. `gs[i]` must *not* be scaled by 1/m.
    pub fn from_batch(fwd: &Fwd, gs: &[Mat]) -> RawStats {
        let m = fwd.abars[0].rows as f64;
        let l = gs.len();
        let scale = 1.0 / m;
        let aa: Vec<Mat> =
            fwd.abars.iter().map(|ab| ab.matmul_tn(ab).scale(scale).symmetrize()).collect();
        let gg: Vec<Mat> = gs.iter().map(|g| g.matmul_tn(g).scale(scale).symmetrize()).collect();
        let aa_off: Vec<Mat> = (0..l - 1)
            .map(|i| fwd.abars[i].matmul_tn(&fwd.abars[i + 1]).scale(scale))
            .collect();
        let gg_off: Vec<Mat> =
            (0..l - 1).map(|i| gs[i].matmul_tn(&gs[i + 1]).scale(scale)).collect();
        RawStats { aa, aa_off, gg, gg_off }
    }

    /// Zero-initialized stats for an architecture.
    pub fn zeros(arch: &Arch) -> RawStats {
        let l = arch.num_layers();
        let aa = (0..l).map(|i| Mat::zeros(arch.widths[i] + 1, arch.widths[i] + 1)).collect();
        let gg = (0..l).map(|i| Mat::zeros(arch.widths[i + 1], arch.widths[i + 1])).collect();
        let aa_off =
            (0..l - 1).map(|i| Mat::zeros(arch.widths[i] + 1, arch.widths[i + 1] + 1)).collect();
        let gg_off =
            (0..l - 1).map(|i| Mat::zeros(arch.widths[i + 1], arch.widths[i + 2])).collect();
        RawStats { aa, aa_off, gg, gg_off }
    }

    pub fn num_layers(&self) -> usize {
        self.aa.len()
    }
}

/// Online exponentially-decayed estimates of the factor statistics.
#[derive(Clone, Debug)]
pub struct KfacStats {
    pub s: RawStats,
    /// Number of updates absorbed so far.
    pub k: usize,
}

impl KfacStats {
    pub fn new(arch: &Arch) -> KfacStats {
        KfacStats { s: RawStats::zeros(arch), k: 0 }
    }

    /// The paper's decay schedule: `ε = min(1 − 1/k, 0.95)`.
    pub fn epsilon(k: usize) -> f64 {
        (1.0 - 1.0 / k as f64).min(0.95)
    }

    /// Fold in one mini-batch estimate.
    pub fn update(&mut self, batch: &RawStats) {
        self.k += 1;
        let eps = Self::epsilon(self.k);
        let blend = |dst: &mut Vec<Mat>, src: &Vec<Mat>| {
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                d.ema(eps, 1.0 - eps, s);
            }
        };
        blend(&mut self.s.aa, &batch.aa);
        blend(&mut self.s.aa_off, &batch.aa_off);
        blend(&mut self.s.gg, &batch.gg);
        blend(&mut self.s.gg_off, &batch.gg_off);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    fn setup() -> (Net, crate::nn::Params, Mat) {
        let arch = Arch::new(
            vec![6, 5, 4, 3],
            vec![Act::Tanh, Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(32, 6, 1.0, &mut rng);
        (Net::new(arch), p, x)
    }

    #[test]
    fn shapes_match_arch() {
        let (net, p, x) = setup();
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(2));
        let st = RawStats::from_batch(&fwd, &gs);
        assert_eq!(st.aa.len(), 3);
        assert_eq!(st.aa[0].rows, 7);
        assert_eq!(st.aa[2].rows, 5);
        assert_eq!(st.gg[0].rows, 5);
        assert_eq!(st.gg[2].rows, 3);
        assert_eq!((st.aa_off[0].rows, st.aa_off[0].cols), (7, 6));
        assert_eq!((st.gg_off[1].rows, st.gg_off[1].cols), (4, 3));
        let z = RawStats::zeros(&net.arch);
        for (a, b) in z.aa.iter().zip(st.aa.iter()) {
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        }
        for (a, b) in z.gg_off.iter().zip(st.gg_off.iter()) {
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        }
    }

    #[test]
    fn aa_is_psd_with_unit_corner() {
        let (net, p, x) = setup();
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(3));
        let st = RawStats::from_batch(&fwd, &gs);
        for aa in &st.aa {
            // homogeneous coordinate: E[1·1] = 1 in the bottom-right corner
            assert!((aa.at(aa.rows - 1, aa.cols - 1) - 1.0).abs() < 1e-12);
            // PSD: vᵀ A v >= 0 for random v
            let mut rng = Rng::new(9);
            for _ in 0..5 {
                let v: Vec<f64> = (0..aa.rows).map(|_| rng.normal()).collect();
                let av = aa.matvec(&v);
                let q: f64 = v.iter().zip(av.iter()).map(|(a, b)| a * b).sum();
                assert!(q >= -1e-10);
            }
        }
    }

    #[test]
    fn ema_schedule() {
        assert!((KfacStats::epsilon(1) - 0.0).abs() < 1e-15);
        assert!((KfacStats::epsilon(2) - 0.5).abs() < 1e-15);
        assert!((KfacStats::epsilon(100) - 0.95).abs() < 1e-15);
    }

    #[test]
    fn first_update_takes_batch_value() {
        let (net, p, x) = setup();
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(4));
        let st = RawStats::from_batch(&fwd, &gs);
        let mut ema = KfacStats::new(&net.arch);
        ema.update(&st);
        assert!(ema.s.aa[0].sub(&st.aa[0]).max_abs() < 1e-14);
        assert!(ema.s.gg[1].sub(&st.gg[1]).max_abs() < 1e-14);
    }
}
