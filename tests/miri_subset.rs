//! The scoped unit subset the Miri CI lane interprets — small shapes
//! exercising every raw-pointer pattern in the crate (GEMM packing +
//! strided stores, `SendPtr` disjoint-row writes, the pool's laundered
//! dispatch, the blocked eigensolver's panel pointers, the fused BLAS-2
//! helpers) plus a checkpoint byte roundtrip. Everything here also runs
//! natively as part of `cargo test`.
//!
//! Under Miri (see the `miri` CI job) run with `KFAC_SIMD=0` (Miri
//! cannot interpret AVX intrinsics), `KFAC_THREADS=2` (bound the pool),
//! and `KFAC_MIRI_SUBSET=1` — the flag that makes the wall-clock-heavy
//! training smoke below skip itself (a Miri step takes minutes, and the
//! pointer patterns it would cover are already exercised above).

use kfac::coordinator::checkpoint;
use kfac::linalg::simd;
use kfac::linalg::{gemm, Mat, SymEig};
use kfac::nn::{Act, Arch, LossKind};
use kfac::optim::{Kfac, KfacConfig, OptState, Optimizer};
use kfac::par;
use kfac::rng::Rng;
use std::sync::Arc;

fn miri_scope() -> bool {
    std::env::var("KFAC_MIRI_SUBSET").as_deref() == Ok("1")
}

/// Reference triple loop: `C += op(A)·op(B)` with stride-described
/// operands, same contract as `gemm_strided_into_with`.
#[allow(clippy::too_many_arguments)]
fn gemm_ref(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    b: &[f64],
    brs: usize,
    bcs: usize,
    c: &mut [f64],
    ldc: usize,
) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * ars + p * acs] * b[p * brs + j * bcs];
            }
            c[i * ldc + j] += acc;
        }
    }
}

fn fill(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let m = Mat::randn(1, len, 1.0, &mut rng);
    m.data
}

#[test]
fn gemm_blocked_scalar_matches_reference_small() {
    // Forces the packed pack+macro-kernel path (no flop cutoff), so the
    // scratch-tile pointer writes and masked MR/NR edges run under Miri
    // on shapes that don't divide the 4-row strips evenly.
    for &(m, n, k) in &[(9usize, 10usize, 11usize), (17, 13, 5), (4, 8, 3)] {
        let a = fill(m * k, 1);
        let b = fill(k * n, 2);
        let mut c = vec![0.0; m * n];
        gemm::gemm_blocked_with(&simd::SCALAR, m, n, k, &a, k, 1, &b, n, 1, &mut c);
        let mut want = vec![0.0; m * n];
        gemm_ref(m, n, k, &a, k, 1, &b, n, 1, &mut want, n);
        for (i, (g, w)) in c.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-12, "({m}x{n}x{k}) entry {i}: {g} vs {w}");
        }
        // transposed-A variant (column strides) through the same packer
        let at = fill(k * m, 3); // k×m row-major, read as op(A) = m×k
        let mut ct = vec![0.0; m * n];
        gemm::gemm_blocked_with(&simd::SCALAR, m, n, k, &at, 1, m, &b, n, 1, &mut ct);
        let mut wt = vec![0.0; m * n];
        gemm_ref(m, n, k, &at, 1, m, &b, n, 1, &mut wt, n);
        for (i, (g, w)) in ct.iter().zip(&wt).enumerate() {
            assert!((g - w).abs() <= 1e-12, "op(A) ({m}x{n}x{k}) entry {i}: {g} vs {w}");
        }
    }
}

#[test]
fn gemm_strided_output_leaves_row_gaps_untouched() {
    let (m, n, k) = (6usize, 5usize, 7usize);
    let ldc = n + 3;
    let a = fill(m * k, 4);
    let b = fill(k * n, 5);
    let sentinel = -1234.5;
    let mut c = vec![sentinel; m * ldc];
    for r in 0..m {
        for j in 0..n {
            c[r * ldc + j] = 0.0;
        }
    }
    gemm::gemm_strided_into_with(&simd::SCALAR, m, n, k, &a, k, 1, &b, n, 1, &mut c, ldc);
    let mut want = vec![0.0; m * n];
    gemm_ref(m, n, k, &a, k, 1, &b, n, 1, &mut want, n);
    for r in 0..m {
        for j in 0..n {
            let (g, w) = (c[r * ldc + j], want[r * n + j]);
            assert!((g - w).abs() <= 1e-12, "({r},{j}): {g} vs {w}");
        }
        for j in n..ldc {
            assert_eq!(c[r * ldc + j], sentinel, "gap ({r},{j}) clobbered");
        }
    }
}

#[test]
fn par_primitives_under_interpreter() {
    // SendPtr disjoint writes + the laundered pooled dispatch, at sizes
    // an interpreter finishes quickly.
    let got = par::par_map(64, 4, |i| (i * i) as u64);
    let want: Vec<u64> = (0..64).map(|i| (i * i) as u64).collect();
    assert_eq!(got, want);

    let strings = par::par_map_send(16, 2, |i| format!("s{i}"));
    assert_eq!(strings[15], "s15");

    // nested dispatch (help-first drain) under the interpreter
    let nested = par::par_map(4, 1, |i| {
        par::par_map(16, 4, move |j| (i * 16 + j) as u64).iter().sum::<u64>()
    });
    let nwant: Vec<u64> = (0..4u64).map(|i| (0..16u64).map(|j| i * 16 + j).sum()).collect();
    assert_eq!(nested, nwant);

    // detached job + the pending-build seam
    let h = par::spawn_job(|| (0..50u64).sum::<u64>());
    assert_eq!(h.collect(), 1225);
    let pending = par::submit_build(Arc::new(vec![2u64, 3, 5]), 9, |v| v.iter().product::<u64>());
    let (out, input, _stalled) = pending.finish();
    assert_eq!(out, 30);
    assert_eq!(Arc::try_unwrap(input).expect("unique after finish"), vec![2, 3, 5]);
}

#[test]
fn blocked_eigensolver_small_shape() {
    // n = 26 > JACOBI_MAX forces the blocked Householder path (panel
    // pointers, fused helpers, rotation application) — the code the
    // Miri lane exists to interpret. Reconstruction check keeps it
    // self-validating.
    let n = 26;
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 1.0 / (1.0 + (i as f64 - j as f64).abs());
            a.set(i, j, v + if i == j { 2.0 } else { 0.0 });
        }
    }
    let e = SymEig::new_blocked(&a);
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += e.v.at(i, k) * e.w[k] * e.v.at(j, k);
            }
            assert!((acc - a.at(i, j)).abs() <= 1e-8, "recon ({i},{j}): {acc} vs {}", a.at(i, j));
        }
    }
    for k in 1..n {
        assert!(e.w[k] >= e.w[k - 1], "eigenvalues not ascending at {k}");
    }
}

#[test]
fn fused_helpers_match_reference_small() {
    let (rows, t) = (5usize, 7usize);
    let (lda, ldb) = (t + 2, t + 1);
    let vcol = fill(rows * 2, 6);
    let wa = fill((rows - 1) * lda + t, 7);
    let xa = fill((rows - 1) * ldb + t, 8);

    let mut aw = vec![0.1; t];
    let mut av = vec![0.2; t];
    simd::fused_tdot2(rows, t, &vcol, 2, &wa, lda, &xa, ldb, &mut aw, &mut av);
    for i in 0..t {
        let (mut sw, mut sv) = (0.1, 0.2);
        for r in 0..rows {
            sw += wa[r * lda + i] * vcol[r * 2];
            sv += xa[r * ldb + i] * vcol[r * 2];
        }
        assert!((aw[i] - sw).abs() <= 1e-12, "tdot aw[{i}]: {} vs {sw}", aw[i]);
        assert!((av[i] - sv).abs() <= 1e-12, "tdot av[{i}]: {} vs {sv}", av[i]);
    }

    let ca = fill(t, 9);
    let cb = fill(t, 10);
    let ps = 3usize;
    let mut p = vec![0.5; (rows - 1) * ps + 1];
    let p0 = p.clone();
    simd::fused_apply2(rows, t, &xa, ldb, &wa, lda, &ca, &cb, &mut p, ps);
    for r in 0..rows {
        let mut acc = 0.0;
        for i in 0..t {
            acc += xa[r * ldb + i] * ca[i] + wa[r * lda + i] * cb[i];
        }
        let want = p0[r * ps] - acc;
        assert!((p[r * ps] - want).abs() <= 1e-12, "apply p[{r}]: {} vs {want}", p[r * ps]);
    }
}

#[test]
fn checkpoint_bytes_roundtrip() {
    let mut opt = OptState::new("kfac");
    opt.set_str("precond", "blkdiag");
    opt.set_scalar("k", 3.0);
    opt.set_scalar("lambda", 1.5e-2);
    opt.set_mats("stats_aa", vec![Mat::from_vec(2, 2, vec![1.0, 0.5, 0.5, 2.0])]);
    let ck = checkpoint::Checkpoint {
        version: checkpoint::version_for(&opt),
        iter: 3,
        cases: 96.0,
        time_s: 0.5,
        rng_words: [9, 8, 7, u64::MAX],
        rng_spare: None,
        params: kfac::nn::Params(vec![Mat::from_vec(2, 3, vec![0.1; 6])]),
        polyak: None,
        opt,
    };
    assert_eq!(ck.version, checkpoint::CHECKPOINT_VERSION);
    let back = checkpoint::from_bytes(&checkpoint::to_bytes(&ck)).unwrap();
    assert_eq!(back.opt, ck.opt);
    assert!(back.params == ck.params);
    assert_eq!(back.rng_words, ck.rng_words);
}

#[test]
fn training_step_smoke() {
    if miri_scope() {
        // A full K-FAC step (eigendecompositions per layer per refresh)
        // takes minutes under an interpreter; its pointer patterns are
        // covered shape-by-shape by the tests above.
        return;
    }
    let arch = Arch::new(vec![4, 3, 2], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
    let mut rng = Rng::new(11);
    let mut p = arch.glorot_init(&mut rng);
    let x = Mat::randn(8, 4, 1.0, &mut rng);
    let mut y = Mat::zeros(8, 2);
    for r in 0..8 {
        y.set(r, r % 2, 1.0);
    }
    let mut be = kfac::backend::RustBackend::new(arch.clone());
    let mut opt = Kfac::new(&arch, KfacConfig::default());
    for _ in 0..3 {
        let info = opt.step(&mut be, &mut p, &x, &y);
        assert!(info.loss.is_finite());
    }
}
