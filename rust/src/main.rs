//! `kfac` CLI — train the paper's benchmark problems with K-FAC or the
//! SGD baseline, on either the pure-Rust backend or the AOT/PJRT
//! backend.
//!
//! Examples:
//!   kfac train --problem mnist_ae --iters 200 --batch 1000
//!   kfac train --problem curves_ae --optimizer sgd --lr 0.05
//!   kfac train --problem mnist_ae --backend pjrt --artifacts artifacts
//!   kfac list-archs --artifacts artifacts

use kfac::backend::{ModelBackend, PjrtBackend, RustBackend};
use kfac::coordinator::cli::Args;
use kfac::coordinator::trainer::{log_to_csv, Optimizer, Problem, TrainConfig, Trainer};
use kfac::fisher::InverseKind;
use kfac::optim::{BatchSchedule, KfacConfig, SgdConfig};
use kfac::rng::Rng;
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("train") => train(&args),
        Some("list-archs") => list_archs(&args),
        _ => {
            eprintln!(
                "usage: kfac <command> [options]\n\
                 commands:\n\
                 \x20 train        --problem mnist_ae|curves_ae|faces_ae|mnist_clf\n\
                 \x20              --optimizer kfac|kfac_blkdiag|sgd  --iters N --batch M\n\
                 \x20              --data N --seed S --no-momentum --lambda0 L --lr E\n\
                 \x20              --backend rust|pjrt --artifacts DIR --out results/train.csv\n\
                 \x20              --exp-schedule  (exponential batch schedule, paper §13)\n\
                 \x20 list-archs   --artifacts DIR"
            );
            std::process::exit(2);
        }
    }
}

fn list_archs(args: &Args) {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    match kfac::runtime::Manifest::load(&dir) {
        Ok(m) => {
            for a in &m.archs {
                println!(
                    "{:<12} widths={:?} loss={} chunk={} programs={:?}",
                    a.name,
                    a.widths,
                    a.loss.name(),
                    a.chunk,
                    a.programs.keys().collect::<Vec<_>>()
                );
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn train(args: &Args) {
    let problem = Problem::from_name(&args.get_or("problem", "mnist_ae"))
        .expect("unknown --problem");
    let iters = args.get_usize("iters", 100);
    let n_data = args.get_usize("data", 4000);
    let seed = args.get_usize("seed", 0) as u64;
    let batch = args.get_usize("batch", 500);
    let schedule = if args.get_flag("exp-schedule") {
        BatchSchedule::exponential_reaching(batch, n_data, (iters * 3 / 4).max(2))
    } else {
        BatchSchedule::Fixed(batch)
    };

    let optimizer = match args.get_or("optimizer", "kfac").as_str() {
        "kfac" | "kfac_blktridiag" => Optimizer::Kfac(KfacConfig {
            inverse: InverseKind::BlockTridiag,
            momentum: !args.get_flag("no-momentum"),
            lambda0: args.get_f64("lambda0", 150.0),
            ..Default::default()
        }),
        "kfac_blkdiag" => Optimizer::Kfac(KfacConfig {
            inverse: InverseKind::BlockDiag,
            momentum: !args.get_flag("no-momentum"),
            lambda0: args.get_f64("lambda0", 150.0),
            ..Default::default()
        }),
        "sgd" => Optimizer::Sgd(SgdConfig {
            lr: args.get_f64("lr", 0.02),
            mu_max: args.get_f64("mu-max", 0.99),
            ..Default::default()
        }),
        other => {
            eprintln!("unknown --optimizer {other}");
            std::process::exit(2);
        }
    };

    println!("# generating {} dataset (n={n_data})…", problem.name());
    let ds = problem.dataset(n_data, seed);
    let arch = problem.arch();
    println!("# arch {:?} ({} params)", arch.widths, arch.num_params());
    let cfg = TrainConfig {
        iters,
        schedule,
        seed,
        eval_every: args.get_usize("eval-every", 10),
        eval_rows: args.get_usize("eval-rows", 1000),
        polyak: Some(0.99),
    };

    let mut params = arch.sparse_init(&mut Rng::new(seed ^ 0xA5));
    let log = match args.get_or("backend", "rust").as_str() {
        "rust" => {
            let mut backend = RustBackend::new(arch.clone());
            Trainer::new(cfg, &ds).run(&mut backend, &mut params, optimizer, true)
        }
        "pjrt" => {
            let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
            let mut backend = PjrtBackend::new(&dir, problem.name()).unwrap_or_else(|e| {
                eprintln!("error loading artifacts: {e:#}");
                std::process::exit(1);
            });
            assert_eq!(
                backend.arch().widths,
                arch.widths,
                "artifact arch mismatch — re-run `make artifacts`"
            );
            Trainer::new(cfg, &ds).run(&mut backend, &mut params, optimizer, true)
        }
        other => {
            eprintln!("unknown --backend {other}");
            std::process::exit(2);
        }
    };

    let _ = params; // final parameters could be serialized here
    if let Some(out) = args.get("out") {
        log_to_csv(&PathBuf::from(out), &log).expect("writing log CSV");
        println!("# wrote {out}");
    }
    let last = log.last().expect("no log rows");
    println!(
        "# done: iters={} time={:.1}s final train_err={:.5} train_loss={:.5}",
        last.iter, last.time_s, last.train_err, last.train_loss
    );
}
