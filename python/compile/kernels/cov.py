"""Weighted second-moment kernel: ``(w * x)^T y`` (sums, not means).

These are the Fisher-factor statistics of paper Section 5
(``A_{i,j} = E[abar_i abar_j^T]``, ``G_{i,j} = E[g_i g_j^T]``), computed
as weighted sums so the Rust coordinator can combine fixed-shape chunks
exactly and divide by the true row count.
"""

from . import matmul


def cov(x, y, w):
    """``(x * w[:, None]).T @ y`` via the tiled GEMM kernel."""
    assert x.shape[0] == y.shape[0] == w.shape[0]
    xw = x * w[:, None]
    return matmul.matmul_tn(xw, y)
