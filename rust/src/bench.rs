//! Tiny criterion-style benchmark harness (no external crates).
//!
//! Our `[[bench]]` targets use `harness = false` and call into this
//! module: each benchmark warms up, then runs timed iterations until a
//! wall-clock budget is spent, and reports mean / median / stddev /
//! throughput in a stable, greppable format. The figure-level
//! experiment binaries use [`Timer`] directly.

use std::time::{Duration, Instant};

/// Simple wall-clock stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<40} iters={:<6} mean={:>12} median={:>12} stddev={:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
            fmt_time(self.stddev_s),
        );
    }

    /// Report with a derived throughput (e.g. GFLOP/s).
    pub fn report_throughput(&self, unit: &str, per_iter: f64) {
        println!(
            "bench {:<40} iters={:<6} mean={:>12} median={:>12} {:>10.3} {unit}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
            per_iter / self.median_s / 1e9,
        );
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark `f`, auto-scaling iteration count to the time budget.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // Warmup + calibration: run once to estimate cost.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_secs_f64().max(1e-9);
    let target_iters = ((budget.as_secs_f64() / first) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(target_iters);
    let hard_deadline = Instant::now() + budget.mul_f64(2.0);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if Instant::now() > hard_deadline {
            break;
        }
    }
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: crate::util::mean(&samples),
        median_s: crate::util::median(&samples),
        stddev_s: crate::util::stddev(&samples),
    };
    res.report();
    res
}

/// Default per-benchmark budget (override with KFAC_BENCH_BUDGET_MS).
pub fn default_budget() -> Duration {
    let ms = std::env::var("KFAC_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(1500);
    Duration::from_millis(ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
