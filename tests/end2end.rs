//! End-to-end training behaviour (rust backend) through the
//! `TrainSession` API: K-FAC optimizes the paper's problem family,
//! beats SGD per-iteration, and every registered preconditioner trains
//! stably through the `Preconditioner` seam.

use kfac::coordinator::{LogRow, TrainSession};
use kfac::data::mnist_like;
use kfac::fisher::precond;
use kfac::nn::{Act, Arch};
use kfac::optim::{BatchSchedule, Kfac, KfacConfig, Optimizer, Sgd, SgdConfig};
use kfac::rng::Rng;

fn small_ae_setup() -> (Arch, kfac::data::Dataset) {
    let arch = Arch::autoencoder(&[256, 40, 12, 40, 256], Act::Tanh);
    let ds = mnist_like::autoencoder_dataset(512, 16, 11);
    (arch, ds)
}

fn run(
    arch: &Arch,
    ds: &kfac::data::Dataset,
    opt: Box<dyn Optimizer>,
    iters: usize,
    seed: u64,
) -> Vec<LogRow> {
    TrainSession::for_dataset(arch.clone(), ds)
        .iters(iters)
        .schedule(BatchSchedule::Fixed(256))
        .eval_every(iters)
        .eval_rows(256)
        .polyak(0.99)
        .seed(seed)
        .params(arch.sparse_init(&mut Rng::new(seed)))
        .optimizer_boxed(opt)
        .run()
        .log
}

#[test]
fn kfac_beats_sgd_per_iteration_on_autoencoder() {
    let (arch, ds) = small_ae_setup();
    let iters = 40;
    // λ₀ scaled down and adapted every iteration: a 40-iteration run is
    // far shorter than the paper's, so the LM rule needs to move fast.
    // margin-sensitive comparison: pin the deterministic synchronous
    // refresh so the KFAC_ASYNC=1 CI leg measures the same trajectory
    let kfac_cfg = KfacConfig { lambda0: 2.0, t1: 1, refresh_async: false, ..Default::default() };
    let k = run(&arch, &ds, Box::new(Kfac::new(&arch, kfac_cfg)), iters, 1);
    // modestly-tuned SGD baseline (lr from a small grid; larger diverges)
    let mut best_sgd = f64::INFINITY;
    for lr in [0.003, 0.01, 0.03] {
        let s = run(
            &arch,
            &ds,
            Box::new(Sgd::new(SgdConfig { lr, ..Default::default() })),
            iters,
            1,
        );
        best_sgd = best_sgd.min(s.last().unwrap().train_err);
    }
    let kfac_err = k.last().unwrap().train_err;
    assert!(
        kfac_err < best_sgd * 0.8,
        "after {iters} iters: kfac {kfac_err} vs best sgd {best_sgd}"
    );
}

#[test]
fn classifier_reaches_low_training_error() {
    // the Figure-2 setup: 256-20-20-20-20-10 on 16×16 digits, batch mode;
    // the paper reports 5% error after 7 iterations and 0% after 22 —
    // our synthetic digits are easier, so just require a large drop.
    let arch = Arch::classifier(&[256, 20, 20, 20, 20, 10], Act::Tanh);
    let ds = mnist_like::classification_dataset(256, 16, 5);
    let kcfg = KfacConfig { lambda0: 15.0, refresh_async: false, ..Default::default() };
    let opt = Kfac::new(&arch, kcfg);
    let report = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(30)
        .schedule(BatchSchedule::Fixed(256))
        .eval_every(5)
        .eval_rows(256)
        .no_polyak()
        .seed(3)
        .params(arch.sparse_init(&mut Rng::new(2)))
        .optimizer(opt)
        .run();
    let first = report.log.first().unwrap().train_err;
    let last = report.log.last().unwrap().train_err;
    assert!(first > 0.5, "initial error should be near chance, got {first}");
    assert!(last < 0.1, "final training error too high: {last}");
}

#[test]
fn momentum_accelerates_batch_optimization() {
    // Section 7 / Figure 9: momentum helps in low-noise (full-batch) mode.
    let (arch, ds) = small_ae_setup();
    let sync_cfg = || KfacConfig { lambda0: 15.0, refresh_async: false, ..Default::default() };
    let with = run(&arch, &ds, Box::new(Kfac::new(&arch, sync_cfg())), 25, 7);
    let without = run(&arch, &ds, Box::new(Kfac::new(&arch, sync_cfg().no_momentum())), 25, 7);
    let w = with.last().unwrap().train_err;
    let wo = without.last().unwrap().train_err;
    assert!(
        w < wo * 1.05,
        "momentum should not hurt materially: with {w} vs without {wo}"
    );
}

#[test]
fn exponential_batch_schedule_runs_and_learns() {
    let (arch, ds) = small_ae_setup();
    let kcfg = KfacConfig { lambda0: 15.0, refresh_async: false, ..Default::default() };
    let opt = Kfac::new(&arch, kcfg);
    let report = TrainSession::for_dataset(arch.clone(), &ds)
        .iters(15)
        .schedule(BatchSchedule::exponential_reaching(64, 512, 10))
        .eval_every(15)
        .eval_rows(256)
        .eval_initial()
        .polyak(0.99)
        .seed(5)
        .params(arch.sparse_init(&mut Rng::new(4)))
        .optimizer(opt)
        .run();
    // the eval_initial row is the untrained baseline
    let first = report.log.first().unwrap();
    assert_eq!(first.iter, 0);
    let last = report.log.last().unwrap();
    assert!(last.train_err < first.train_err, "err {} -> {}", first.train_err, last.train_err);
    assert!(last.train_loss < first.train_loss);
    // schedule actually grew the batches
    assert!(last.cases > 15.0 * 64.0);
}

#[test]
fn all_registered_preconditioners_train_stably() {
    let (arch, ds) = small_ae_setup();
    for p in [precond::block_diag(), precond::block_tridiag(), precond::ekfac()] {
        let name = p.name().to_string();
        let log = run(
            &arch,
            &ds,
            Box::new(Kfac::new(
                &arch,
                KfacConfig { precond: p, lambda0: 15.0, ..Default::default() },
            )),
            15,
            9,
        );
        for row in &log {
            assert!(row.train_loss.is_finite(), "{name} diverged");
        }
    }
}
