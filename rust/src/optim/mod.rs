//! Optimizers: the K-FAC algorithm (paper Algorithm 2) and the
//! SGD-with-Nesterov-momentum baseline of Sutskever et al. (2013) used
//! in the paper's evaluation, plus mini-batch-size schedules and
//! Polyak-style iterate averaging.

pub mod kfac;
pub mod polyak;
pub mod schedule;
pub mod sgd;

pub use kfac::{Kfac, KfacConfig, StepInfo};
pub use polyak::PolyakAverager;
pub use schedule::BatchSchedule;
pub use sgd::{Sgd, SgdConfig};
