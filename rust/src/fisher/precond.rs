//! The open preconditioner seam.
//!
//! K-FAC's outer loop (statistics → damped inverse → rescaled update)
//! is generic over the curvature structure used for the update
//! proposal. [`Preconditioner`] is the factory interface the optimizer
//! calls at every inverse refresh: given the current factor statistics
//! and a damping strength γ, build a [`FisherInverse`] it can apply to
//! gradients until the next refresh.
//!
//! The paper's two structures (block-diagonal §4.2, block-tridiagonal
//! §4.3) and the EKFAC eigenbasis-diagonal structure (George et al.
//! 2018) ship as built-in implementations; external code can implement
//! the trait and (optionally) [`register`] instances under a name so
//! CLIs and config files can select them.

use super::blockdiag::BlockDiagInverse;
use super::ekfac::EkfacInverse;
use super::kfc::KfcPrecond;
use super::stats::RawStats;
use super::tridiag::TridiagInverse;
use super::FisherInverse;
use std::sync::{Arc, Mutex, OnceLock};

/// Shared handle to a (stateless) preconditioner factory.
pub type PrecondRef = Arc<dyn Preconditioner + Send + Sync>;

/// Factory for approximate Fisher inverses: the structure-specific
/// part of a K-FAC-family optimizer.
pub trait Preconditioner {
    /// Stable identifier (used by CLIs, logs and the registry).
    fn name(&self) -> &str;

    /// Build the approximate inverse from factor statistics with
    /// damping strength `gamma`. Must be deterministic in its inputs —
    /// checkpoint resume rebuilds cached inverses through this method
    /// and relies on bit-identical results.
    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send>;

    /// Flat length of layer `layer`'s independently-buildable part, or
    /// `None` if this structure cannot shard its build per layer (the
    /// default). When `Some` for every layer, `dist::sharded_build` splits
    /// the refresh round-robin across ranks via `build_layer_part` /
    /// `assemble_parts`; otherwise every rank falls back to a replicated
    /// `build` from the (identical, already all-reduced) statistics.
    fn layer_part_len(&self, stats: &RawStats, layer: usize) -> Option<usize> {
        let _ = (stats, layer);
        None
    }

    /// Factorize layer `layer` only, returning exactly
    /// `layer_part_len(stats, layer)` f64s. Must be bitwise identical to
    /// the corresponding slice of a full `build` — resume and the
    /// `ranks=1` equivalence contract depend on it.
    fn build_layer_part(&self, stats: &RawStats, gamma: f64, layer: usize) -> Vec<f64> {
        let _ = (stats, gamma, layer);
        Vec::new()
    }

    /// Reassemble a full inverse from one part per layer (each produced by
    /// `build_layer_part` on some rank and broadcast). Returns `None` when
    /// the structure does not support sharding or a part is malformed.
    fn assemble_parts(
        &self,
        stats: &RawStats,
        gamma: f64,
        parts: &[Vec<f64>],
    ) -> Option<Box<dyn FisherInverse + Send>> {
        let _ = (stats, gamma, parts);
        None
    }
}

/// `F̌⁻¹` — block-diagonal (paper §4.2), factored Tikhonov damping.
pub struct BlockDiagPrecond;

impl Preconditioner for BlockDiagPrecond {
    fn name(&self) -> &str {
        "blkdiag"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        Box::new(BlockDiagInverse::build(stats, gamma))
    }

    fn layer_part_len(&self, stats: &RawStats, layer: usize) -> Option<usize> {
        let a = stats.aa[layer].rows;
        let g = stats.gg[layer].rows;
        Some(a * a + g * g)
    }

    fn build_layer_part(&self, stats: &RawStats, gamma: f64, layer: usize) -> Vec<f64> {
        // Mirrors BlockDiagInverse::build's per-layer closure exactly so a
        // sharded refresh is bitwise identical to a replicated one.
        super::check_factors_finite("blkdiag", layer, &stats.aa[layer], &stats.gg[layer]);
        let (ad, gd) = super::damping::damped_factors(&stats.aa[layer], &stats.gg[layer], gamma);
        let ainv = crate::linalg::chol::spd_inverse(&ad);
        let ginv = crate::linalg::chol::spd_inverse(&gd);
        let mut out = ainv.data;
        out.extend_from_slice(&ginv.data);
        out
    }

    fn assemble_parts(
        &self,
        stats: &RawStats,
        _gamma: f64,
        parts: &[Vec<f64>],
    ) -> Option<Box<dyn FisherInverse + Send>> {
        if parts.len() != stats.num_layers() {
            return None;
        }
        let mut ainv = Vec::with_capacity(parts.len());
        let mut ginv = Vec::with_capacity(parts.len());
        for (layer, part) in parts.iter().enumerate() {
            let a = stats.aa[layer].rows;
            let g = stats.gg[layer].rows;
            if part.len() != a * a + g * g {
                return None;
            }
            ainv.push(crate::linalg::Mat::from_vec(a, a, part[..a * a].to_vec()));
            ginv.push(crate::linalg::Mat::from_vec(g, g, part[a * a..].to_vec()));
        }
        Some(Box::new(BlockDiagInverse { ainv, ginv }))
    }
}

/// `F̂⁻¹` — block-tridiagonal (paper §4.3), factored Tikhonov damping.
pub struct TridiagPrecond;

impl Preconditioner for TridiagPrecond {
    fn name(&self) -> &str {
        "blktridiag"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        Box::new(TridiagInverse::build(stats, gamma))
    }
}

/// EKFAC — diagonal rescaling in the Kronecker eigenbasis with exact
/// (eigenbasis) Tikhonov damping.
pub struct EkfacPrecond;

impl Preconditioner for EkfacPrecond {
    fn name(&self) -> &str {
        "ekfac"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        Box::new(EkfacInverse::build(stats, gamma))
    }
}

/// The block-diagonal preconditioner (paper §4.2).
pub fn block_diag() -> PrecondRef {
    Arc::new(BlockDiagPrecond)
}

/// The block-tridiagonal preconditioner (paper §4.3, the default).
pub fn block_tridiag() -> PrecondRef {
    Arc::new(TridiagPrecond)
}

/// The EKFAC eigenbasis-diagonal preconditioner.
pub fn ekfac() -> PrecondRef {
    Arc::new(EkfacPrecond)
}

/// The KFC preconditioner (Grosse & Martens 2016): block-diagonal with
/// conv-aware Kronecker factor semantics.
pub fn kfc() -> PrecondRef {
    Arc::new(KfcPrecond)
}

fn registry() -> &'static Mutex<Vec<PrecondRef>> {
    static REG: OnceLock<Mutex<Vec<PrecondRef>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(vec![block_diag(), block_tridiag(), ekfac(), kfc()]))
}

/// Register a preconditioner under its `name()`, replacing any
/// previous registration with the same name.
pub fn register(p: PrecondRef) {
    let mut reg = registry().lock().unwrap();
    let name = p.name().to_string();
    reg.retain(|q| q.name() != name);
    reg.push(p);
}

/// Look up a registered preconditioner by name.
pub fn from_name(name: &str) -> Option<PrecondRef> {
    registry().lock().unwrap().iter().find(|p| p.name() == name).cloned()
}

/// Names of all registered preconditioners (for CLI help/errors).
pub fn names() -> Vec<String> {
    registry().lock().unwrap().iter().map(|p| p.name().to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::stats::KfacStats;
    use crate::linalg::Mat;
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind, Params};
    use crate::rng::Rng;

    fn toy_stats() -> (Arch, RawStats) {
        let arch = Arch::new(
            vec![5, 4, 3],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(64, 5, 1.0, &mut rng);
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        let mut st = KfacStats::new(&arch);
        st.update(&crate::fisher::RawStats::from_batch(&fwd, &gs));
        (arch, st.s)
    }

    #[test]
    fn builtins_are_registered() {
        for name in ["blkdiag", "blktridiag", "ekfac", "kfc"] {
            let p = from_name(name).unwrap_or_else(|| panic!("{name} not registered"));
            assert_eq!(p.name(), name);
        }
        assert!(from_name("nonexistent").is_none());
        let all = names();
        assert!(all.iter().any(|n| n == "ekfac"), "names() missing ekfac: {all:?}");
    }

    #[test]
    fn every_builtin_builds_a_working_inverse() {
        let (arch, stats) = toy_stats();
        let mut rng = Rng::new(2);
        let grads = Params(
            (0..arch.num_layers())
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::randn(r, c, 1.0, &mut rng)
                })
                .collect(),
        );
        for p in [block_diag(), block_tridiag(), ekfac(), kfc()] {
            let inv = p.build(&stats, 0.5);
            let u = inv.apply(&grads);
            assert_eq!(u.0.len(), grads.0.len(), "{}", p.name());
            assert!(
                u.0.iter().all(|m| m.data.iter().all(|v| v.is_finite())),
                "{} produced non-finite update",
                p.name()
            );
            // descent-direction sanity: ⟨g, F⁻¹g⟩ > 0
            assert!(grads.dot(&u) > 0.0, "{} not positive definite", p.name());
        }
    }

    #[test]
    fn external_preconditioners_plug_in() {
        // The seam is open: a custom structure registers and resolves
        // like the built-ins.
        struct IdentityInverse;
        impl FisherInverse for IdentityInverse {
            fn apply(&self, grads: &Params) -> Params {
                grads.clone()
            }
        }
        struct IdentityPrecond;
        impl Preconditioner for IdentityPrecond {
            fn name(&self) -> &str {
                "identity-test"
            }
            fn build(&self, _stats: &RawStats, _gamma: f64) -> Box<dyn FisherInverse + Send> {
                Box::new(IdentityInverse)
            }
        }
        register(Arc::new(IdentityPrecond));
        let p = from_name("identity-test").expect("custom preconditioner registered");
        let (_, stats) = toy_stats();
        let mut rng = Rng::new(3);
        let g = Params(vec![Mat::randn(4, 6, 1.0, &mut rng)]);
        let u = p.build(&stats, 1.0).apply(&g);
        assert!(u.0[0].sub(&g.0[0]).max_abs() < 1e-15);
    }
}
