//! Curvature-structure frontier — quality vs wall-clock for every
//! registered preconditioner on one shared problem.
//!
//! For each structure in the registry (`blkdiag`, `blktridiag`,
//! `ekfac`, `kfc`, `kpsvd`, `ikfac`, plus anything user-registered)
//! the harness records, on a tiny all-dense autoencoder:
//!
//! - **refresh ms** — median cost of a full inverse build;
//! - **apply ms** — median cost of preconditioning one gradient;
//! - **dense residual** — `‖T·M − I‖_F / √n` where `M` is the
//!   column-by-column densified inverse action and `T` the damped
//!   Khatri–Rao target assembled from the same statistics (diagonal
//!   blocks `Ā_i⊗G_i`, adjacent off blocks `Ā_{i,i+1}⊗G_{i,i+1}`, plus
//!   `γ²I`) — small enough here to measure exactly;
//! - **loss trajectory** — a short K-FAC run through the optimizer
//!   seam, identical seeds/init/batches across structures.
//!
//! Structures whose `check_arch` rejects the problem are recorded as
//! skipped with their own reason. Results go to `KFAC_FRONTIER_JSON`
//! (default `BENCH_frontier.json`); the CI `frontier-smoke` step runs
//! this at tiny scale and uploads the artifact.

use kfac::backend::{ModelBackend, RustBackend};
use kfac::bench::{bench, default_budget};
use kfac::data::mnist_like;
use kfac::fisher::kpsvd::KpsvdPrecond;
use kfac::fisher::stats::KfacStats;
use kfac::fisher::{precond, PrecondRef, RawStats};
use kfac::linalg::kron::kron;
use kfac::linalg::Mat;
use kfac::nn::{Act, Arch, Params};
use kfac::optim::{Kfac, KfacConfig, Optimizer};
use kfac::rng::Rng;
use std::io::Write as _;

/// Densify the inverse action: column k of the returned matrix is
/// `inv.apply(e_k)` under the global column-stacked indexing
/// `offs[l] + c·d_out + r` (the same vec convention as
/// `fisher::exact::ExactBlocks`).
fn densify(
    inv: &dyn kfac::fisher::FisherInverse,
    shapes: &[(usize, usize)],
    offs: &[usize],
    n: usize,
) -> Mat {
    let mut m = Mat::zeros(n, n);
    for (l, &(rows, cols)) in shapes.iter().enumerate() {
        for c in 0..cols {
            for r in 0..rows {
                let mut e = Params(
                    shapes.iter().map(|&(rr, cc)| Mat::zeros(rr, cc)).collect::<Vec<_>>(),
                );
                e.0[l].set(r, c, 1.0);
                let y = inv.apply(&e);
                let col = offs[l] + c * rows + r;
                for (l2, &(rows2, cols2)) in shapes.iter().enumerate() {
                    for c2 in 0..cols2 {
                        for r2 in 0..rows2 {
                            m.set(offs[l2] + c2 * rows2 + r2, col, y.0[l2].at(r2, c2));
                        }
                    }
                }
            }
        }
    }
    m
}

/// Dense damped Khatri–Rao target from the optimizer's statistics:
/// diagonal blocks `Ā_i⊗G_i`, adjacent off-diagonal blocks
/// `Ā_{i,i+1}⊗G_{i,i+1}` (and transposes), plus `γ²` on the diagonal.
fn dense_target(stats: &RawStats, offs: &[usize], n: usize, gamma: f64) -> Mat {
    let mut t = Mat::zeros(n, n);
    let set_block = |t: &mut Mat, ro: usize, co: usize, b: &Mat| {
        for r in 0..b.rows {
            for c in 0..b.cols {
                t.set(ro + r, co + c, b.at(r, c));
            }
        }
    };
    for i in 0..stats.num_layers() {
        let d = kron(&stats.aa[i], &stats.gg[i]);
        set_block(&mut t, offs[i], offs[i], &d);
    }
    for i in 0..stats.aa_off.len() {
        let o = kron(&stats.aa_off[i], &stats.gg_off[i]);
        set_block(&mut t, offs[i], offs[i + 1], &o);
        set_block(&mut t, offs[i + 1], offs[i], &o.transpose());
    }
    t.add_diag(gamma * gamma)
}

fn residual(inv: &dyn kfac::fisher::FisherInverse, t: &Mat, arch: &Arch) -> f64 {
    let shapes: Vec<(usize, usize)> = (0..arch.num_layers()).map(|i| arch.weight_shape(i)).collect();
    let mut offs = Vec::with_capacity(shapes.len());
    let mut n = 0usize;
    for &(r, c) in &shapes {
        offs.push(n);
        n += r * c;
    }
    let m = densify(inv, &shapes, &offs, n);
    let tm = t.matmul(&m);
    let mut err = 0.0f64;
    for r in 0..n {
        for c in 0..n {
            let want = if r == c { 1.0 } else { 0.0 };
            err += (tm.at(r, c) - want).powi(2);
        }
    }
    (err / n as f64).sqrt()
}

/// Short K-FAC run through the optimizer seam: identical seeds, init
/// and (full-batch) data for every structure.
fn trajectory(
    pre: PrecondRef,
    arch: &Arch,
    x: &Mat,
    y: &Mat,
    iters: usize,
) -> Result<Vec<f64>, String> {
    let cfg = KfacConfig {
        precond: pre,
        lambda0: 10.0,
        t_inv: 5,
        refresh_async: false,
        ..Default::default()
    };
    let mut opt = Kfac::try_new(arch, cfg)?;
    let mut backend = RustBackend::new(arch.clone());
    let mut params = arch.sparse_init(&mut Rng::new(0xA5));
    let mut losses = Vec::with_capacity(iters);
    for _ in 0..iters {
        losses.push(opt.step(&mut backend, &mut params, x, y).loss);
    }
    Ok(losses)
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

fn main() {
    println!("== Curvature-structure frontier ==");
    let arch = Arch::autoencoder(&[16, 8, 4, 8, 16], Act::Tanh);
    let n_data = kfac::experiments::scaled(256, 64);
    let ds = mnist_like::autoencoder_dataset(n_data, 4, 0);
    let iters = kfac::experiments::scaled(16, 10);
    let gamma = 0.5;
    let budget = default_budget();

    let mut backend = RustBackend::new(arch.clone());
    let params = arch.sparse_init(&mut Rng::new(1));
    let (_, grad, raw) = backend.grad_and_stats(&params, &ds.x, &ds.y, ds.x.rows, 7);
    let mut stats = KfacStats::new(&arch);
    stats.update(&raw);

    let shapes: Vec<(usize, usize)> = (0..arch.num_layers()).map(|i| arch.weight_shape(i)).collect();
    let mut offs = Vec::with_capacity(shapes.len());
    let mut n = 0usize;
    for &(r, c) in &shapes {
        offs.push(n);
        n += r * c;
    }
    let target = dense_target(&stats.s, &offs, n, gamma);
    println!("problem: tiny_ae {:?} ({n} params), gamma={gamma}", arch.widths);

    struct Row {
        name: String,
        refresh_ms: f64,
        apply_ms: f64,
        dense_residual: f64,
        loss: Vec<f64>,
    }
    let mut rows: Vec<Row> = Vec::new();
    let mut skipped: Vec<(String, String)> = Vec::new();

    for name in precond::names() {
        let p = precond::from_name(&name).expect("registered name resolves");
        if let Err(reason) = p.check_arch(&arch) {
            println!("  {name}: skipped ({reason})");
            skipped.push((name, reason));
            continue;
        }
        let rb = bench(&format!("{name}_refresh(tiny_ae)"), budget, || {
            std::hint::black_box(p.build(&stats.s, gamma));
        });
        let inv = p.build(&stats.s, gamma);
        let ra = bench(&format!("{name}_apply(tiny_ae)"), budget, || {
            std::hint::black_box(inv.apply(&grad));
        });
        let res = residual(inv.as_ref(), &target, &arch);
        let loss = match trajectory(p.clone(), &arch, &ds.x, &ds.y, iters) {
            Ok(l) => l,
            Err(e) => {
                println!("  {name}: skipped ({e})");
                skipped.push((name, e));
                continue;
            }
        };
        println!(
            "  {name}: refresh {:.3}ms apply {:.3}ms residual {res:.4e} \
             loss {:.5} -> {:.5}",
            rb.median_s * 1e3,
            ra.median_s * 1e3,
            loss.first().copied().unwrap_or(f64::NAN),
            loss.last().copied().unwrap_or(f64::NAN),
        );
        rows.push(Row {
            name,
            refresh_ms: rb.median_s * 1e3,
            apply_ms: ra.median_s * 1e3,
            dense_residual: res,
            loss,
        });
    }

    // KPSVD rank sweep: on the exactly-Kronecker-rank-2 damped target
    // the R=2 fit must be at least as good as R=1 (which is bitwise the
    // factored-Tikhonov block-diagonal inverse).
    let r1 = KpsvdPrecond::new(1).build(&stats.s, gamma);
    let r2 = KpsvdPrecond::new(2).build(&stats.s, gamma);
    let res1 = residual(r1.as_ref(), &target, &arch);
    let res2 = residual(r2.as_ref(), &target, &arch);
    println!("  kpsvd rank sweep: R=1 residual {res1:.4e}, R=2 residual {res2:.4e}");
    assert!(
        res2 <= res1 + 1e-9,
        "kpsvd R=2 must fit the damped target at least as well as R=1: {res2} vs {res1}"
    );

    let path = std::env::var("KFAC_FRONTIER_JSON")
        .unwrap_or_else(|_| "BENCH_frontier.json".to_string());
    let mut f = std::fs::File::create(&path).expect("creating frontier json");
    writeln!(f, "{{").unwrap();
    writeln!(f, "  \"problem\": \"tiny_ae {:?}\",", arch.widths).unwrap();
    writeln!(f, "  \"params\": {n},").unwrap();
    writeln!(f, "  \"gamma\": {gamma},").unwrap();
    writeln!(f, "  \"kpsvd_residual_r1\": {},", json_f64(res1)).unwrap();
    writeln!(f, "  \"kpsvd_residual_r2\": {},", json_f64(res2)).unwrap();
    writeln!(f, "  \"structures\": [").unwrap();
    for (i, row) in rows.iter().enumerate() {
        let sep = if i + 1 == rows.len() { "" } else { "," };
        let loss: Vec<String> = row.loss.iter().map(|&l| json_f64(l)).collect();
        writeln!(
            f,
            "    {{\"name\": \"{}\", \"refresh_ms\": {}, \"apply_ms\": {}, \
             \"dense_residual\": {}, \"loss\": [{}]}}{sep}",
            row.name,
            json_f64(row.refresh_ms),
            json_f64(row.apply_ms),
            json_f64(row.dense_residual),
            loss.join(", ")
        )
        .unwrap();
    }
    writeln!(f, "  ],").unwrap();
    writeln!(f, "  \"skipped\": [").unwrap();
    for (i, (name, reason)) in skipped.iter().enumerate() {
        let sep = if i + 1 == skipped.len() { "" } else { "," };
        let reason = reason.replace('\\', "\\\\").replace('"', "\\\"");
        writeln!(f, "    {{\"name\": \"{name}\", \"reason\": \"{reason}\"}}{sep}").unwrap();
    }
    writeln!(f, "  ]").unwrap();
    writeln!(f, "}}").unwrap();
    println!("wrote {path} ({} structures, {} skipped)", rows.len(), skipped.len());
}
