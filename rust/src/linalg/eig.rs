//! Symmetric eigendecomposition (cyclic Jacobi) and PSD matrix functions.
//!
//! The block-tridiagonal inverse approximation (paper §4.3 + Appendix B)
//! needs symmetric eigendecompositions and inverse square roots of the
//! damped Kronecker factors. Jacobi is simple, numerically excellent for
//! symmetric matrices, and O(n³) with a modest constant — fine for the
//! layer-sized (≤ ~800) matrices K-FAC inverts, especially since
//! inverses are only refreshed every `T₃` iterations.

use super::Mat;

/// Eigendecomposition `A = V diag(w) Vᵀ` of a symmetric matrix.
pub struct SymEig {
    /// Eigenvalues, ascending.
    pub w: Vec<f64>,
    /// Eigenvectors as **columns** of `v`.
    pub v: Mat,
}

impl SymEig {
    /// Symmetric eigendecomposition. Householder tridiagonalization +
    /// implicit-shift QL (the classic tred2/tql2 pair) for matrices big
    /// enough for Jacobi's constant to hurt; cyclic Jacobi below that
    /// (and as the reference implementation the QL path is tested
    /// against).
    pub fn new(a: &Mat) -> SymEig {
        if a.rows > 24 {
            Self::new_ql(a)
        } else {
            Self::new_jacobi(a)
        }
    }

    /// tred2: reduce symmetric `a` to tridiagonal (d, e) with accumulated
    /// orthogonal transform in `z`; then tql2: implicit-shift QL on the
    /// tridiagonal, rotating `z`'s columns into eigenvectors.
    pub fn new_ql(a: &Mat) -> SymEig {
        assert!(a.is_square(), "eig: non-square");
        let n = a.rows;
        let mut z = a.symmetrize();
        let mut d = vec![0.0f64; n];
        let mut e = vec![0.0f64; n];

        // --- tred2 (Householder reduction, EISPACK/NR layout) ---
        for i in (1..n).rev() {
            let l = i - 1;
            let mut h = 0.0;
            if l > 0 {
                let mut scale = 0.0;
                for k in 0..=l {
                    scale += z.at(i, k).abs();
                }
                if scale == 0.0 {
                    e[i] = z.at(i, l);
                } else {
                    for k in 0..=l {
                        let v = z.at(i, k) / scale;
                        z.set(i, k, v);
                        h += v * v;
                    }
                    let mut f = z.at(i, l);
                    let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                    e[i] = scale * g;
                    h -= f * g;
                    z.set(i, l, f - g);
                    f = 0.0;
                    for j in 0..=l {
                        z.set(j, i, z.at(i, j) / h);
                        let mut g = 0.0;
                        for k in 0..=j {
                            g += z.at(j, k) * z.at(i, k);
                        }
                        for k in (j + 1)..=l {
                            g += z.at(k, j) * z.at(i, k);
                        }
                        e[j] = g / h;
                        f += e[j] * z.at(i, j);
                    }
                    let hh = f / (h + h);
                    for j in 0..=l {
                        let f = z.at(i, j);
                        let g = e[j] - hh * f;
                        e[j] = g;
                        for k in 0..=j {
                            let v = z.at(j, k) - (f * e[k] + g * z.at(i, k));
                            z.set(j, k, v);
                        }
                    }
                }
            } else {
                e[i] = z.at(i, l);
            }
            d[i] = h;
        }
        d[0] = 0.0;
        e[0] = 0.0;
        for i in 0..n {
            if d[i] != 0.0 {
                // accumulate transform
                for j in 0..i {
                    let mut g = 0.0;
                    for k in 0..i {
                        g += z.at(i, k) * z.at(k, j);
                    }
                    for k in 0..i {
                        let v = z.at(k, j) - g * z.at(k, i);
                        z.set(k, j, v);
                    }
                }
            }
            d[i] = z.at(i, i);
            z.set(i, i, 1.0);
            for j in 0..i {
                z.set(j, i, 0.0);
                z.set(i, j, 0.0);
            }
        }

        // --- tql2 (implicit-shift QL with eigenvector accumulation) ---
        for i in 1..n {
            e[i - 1] = e[i];
        }
        e[n - 1] = 0.0;
        for l in 0..n {
            let mut iter = 0;
            loop {
                // find small subdiagonal element
                let mut m = l;
                while m + 1 < n {
                    let dd = d[m].abs() + d[m + 1].abs();
                    if e[m].abs() <= f64::EPSILON * dd {
                        break;
                    }
                    m += 1;
                }
                if m == l {
                    break;
                }
                iter += 1;
                assert!(iter <= 50, "tql2: too many iterations");
                let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
                let mut r = g.hypot(1.0);
                let sign_r = if g >= 0.0 { r } else { -r };
                g = d[m] - d[l] + e[l] / (g + sign_r);
                let (mut s, mut c) = (1.0f64, 1.0f64);
                let mut p = 0.0f64;
                for i in (l..m).rev() {
                    let mut f = s * e[i];
                    let b = c * e[i];
                    r = f.hypot(g);
                    e[i + 1] = r;
                    if r == 0.0 {
                        d[i + 1] -= p;
                        e[m] = 0.0;
                        break;
                    }
                    s = f / r;
                    c = g / r;
                    g = d[i + 1] - p;
                    r = (d[i] - g) * s + 2.0 * c * b;
                    p = s * r;
                    d[i + 1] = g + p;
                    g = c * r - b;
                    // accumulate eigenvectors
                    for k in 0..n {
                        f = z.at(k, i + 1);
                        let v1 = s * z.at(k, i) + c * f;
                        let v0 = c * z.at(k, i) - s * f;
                        z.set(k, i + 1, v1);
                        z.set(k, i, v0);
                    }
                }
                if r == 0.0 && m > l {
                    continue;
                }
                d[l] -= p;
                e[l] = g;
                e[m] = 0.0;
            }
        }

        // sort ascending (tql2 output is unordered in general)
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
        let w: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
        let mut vs = Mat::zeros(n, n);
        for (new_c, &old_c) in idx.iter().enumerate() {
            for r in 0..n {
                vs.set(r, new_c, z.at(r, old_c));
            }
        }
        SymEig { w, v: vs }
    }

    /// Cyclic Jacobi with threshold sweeps. `a` must be symmetric.
    pub fn new_jacobi(a: &Mat) -> SymEig {
        assert!(a.is_square(), "eig: non-square");
        let n = a.rows;
        let mut m = a.symmetrize();
        let mut v = Mat::eye(n);
        if n <= 1 {
            return SymEig { w: (0..n).map(|i| m.at(i, i)).collect(), v };
        }
        let max_sweeps = 64;
        for _sweep in 0..max_sweeps {
            // off-diagonal Frobenius norm
            let mut off = 0.0;
            for r in 0..n {
                for c in (r + 1)..n {
                    off += m.at(r, c) * m.at(r, c);
                }
            }
            let scale = m.frob_norm().max(1e-300);
            if off.sqrt() <= 1e-14 * scale {
                break;
            }
            for p in 0..n - 1 {
                for q in p + 1..n {
                    let apq = m.at(p, q);
                    if apq.abs() <= 1e-300 {
                        continue;
                    }
                    let app = m.at(p, p);
                    let aqq = m.at(q, q);
                    // rotation angle
                    let theta = 0.5 * (aqq - app) / apq;
                    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    // apply rotation to rows/cols p,q of m
                    for k in 0..n {
                        let mkp = m.at(k, p);
                        let mkq = m.at(k, q);
                        m.set(k, p, c * mkp - s * mkq);
                        m.set(k, q, s * mkp + c * mkq);
                    }
                    for k in 0..n {
                        let mpk = m.at(p, k);
                        let mqk = m.at(q, k);
                        m.set(p, k, c * mpk - s * mqk);
                        m.set(q, k, s * mpk + c * mqk);
                    }
                    // accumulate eigenvectors
                    for k in 0..n {
                        let vkp = v.at(k, p);
                        let vkq = v.at(k, q);
                        v.set(k, p, c * vkp - s * vkq);
                        v.set(k, q, s * vkp + c * vkq);
                    }
                }
            }
        }
        // extract + sort ascending
        let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m.at(i, i), i)).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let w: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        let mut vs = Mat::zeros(n, n);
        for (new_c, &(_, old_c)) in pairs.iter().enumerate() {
            for r in 0..n {
                vs.set(r, new_c, v.at(r, old_c));
            }
        }
        SymEig { w, v: vs }
    }

    /// Apply a scalar function to the spectrum: `V f(diag(w)) Vᵀ`.
    pub fn matrix_fn(&self, f: impl Fn(f64) -> f64) -> Mat {
        let n = self.w.len();
        // V * diag(f(w))
        let mut vf = self.v.clone();
        for r in 0..n {
            for c in 0..n {
                vf.set(r, c, vf.at(r, c) * f(self.w[c]));
            }
        }
        vf.matmul_nt(&self.v).symmetrize()
    }

    /// PSD square root (negative eigenvalues clamped to 0).
    pub fn sqrt_psd(&self) -> Mat {
        self.matrix_fn(|w| w.max(0.0).sqrt())
    }

    /// PSD inverse square root with floor `eps` on eigenvalues.
    pub fn inv_sqrt_psd(&self, eps: f64) -> Mat {
        self.matrix_fn(|w| 1.0 / w.max(eps).sqrt())
    }

    /// Reconstruct the matrix (round-trip check).
    pub fn reconstruct(&self) -> Mat {
        self.matrix_fn(|w| w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::spd_inverse;
    use crate::rng::Rng;

    fn random_sym(n: usize, rng: &mut Rng) -> Mat {
        Mat::randn(n, n, 1.0, rng).symmetrize()
    }

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let x = Mat::randn(n + 3, n, 1.0, rng);
        x.matmul_tn(&x).add_diag(0.3)
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 3, 8, 25] {
            let a = random_sym(n, &mut rng);
            let e = SymEig::new(&a);
            let rec_err = e.reconstruct().sub(&a).max_abs();
            assert!(rec_err < 1e-9 * (1.0 + a.max_abs()), "n={n} err={rec_err}");
            let orth = e.v.matmul_tn(&e.v).sub(&Mat::eye(n)).max_abs();
            assert!(orth < 1e-10, "n={n} orth={orth}");
        }
    }

    #[test]
    fn eigenvalues_sorted_and_match_trace() {
        let mut rng = Rng::new(2);
        let a = random_sym(10, &mut rng);
        let e = SymEig::new(&a);
        for i in 1..e.w.len() {
            assert!(e.w[i] >= e.w[i - 1]);
        }
        let tr: f64 = e.w.iter().sum();
        assert!((tr - a.trace()).abs() < 1e-9);
    }

    #[test]
    fn sqrt_squares_back() {
        let mut rng = Rng::new(3);
        let a = random_spd(9, &mut rng);
        let s = SymEig::new(&a).sqrt_psd();
        assert!(s.matmul(&s).sub(&a).max_abs() < 1e-8);
    }

    #[test]
    fn inv_sqrt_matches_cholesky_inverse() {
        let mut rng = Rng::new(4);
        let a = random_spd(7, &mut rng);
        let is = SymEig::new(&a).inv_sqrt_psd(1e-14);
        let inv_via_eig = is.matmul(&is);
        let inv_via_chol = spd_inverse(&a);
        assert!(inv_via_eig.sub(&inv_via_chol).max_abs() < 1e-7);
    }

    #[test]
    fn ql_matches_jacobi() {
        let mut rng = Rng::new(77);
        for n in [2usize, 5, 17, 40, 73] {
            let a = random_sym(n, &mut rng);
            let ql = SymEig::new_ql(&a);
            let ja = SymEig::new_jacobi(&a);
            for i in 0..n {
                assert!(
                    (ql.w[i] - ja.w[i]).abs() < 1e-9 * (1.0 + a.max_abs()),
                    "n={n} eigenvalue {i}: {} vs {}",
                    ql.w[i],
                    ja.w[i]
                );
            }
            // reconstruction + orthogonality for the QL path
            assert!(ql.reconstruct().sub(&a).max_abs() < 1e-9 * (1.0 + a.max_abs()));
            assert!(ql.v.matmul_tn(&ql.v).sub(&Mat::eye(n)).max_abs() < 1e-9);
        }
    }

    #[test]
    fn ql_handles_degenerate_spectra() {
        // repeated eigenvalues and zero rows
        let mut a = Mat::eye(10).scale(3.0);
        a.set(9, 9, 0.0);
        let e = SymEig::new_ql(&a);
        assert!((e.w[0] - 0.0).abs() < 1e-12);
        assert!((e.w[9] - 3.0).abs() < 1e-12);
        assert!(e.reconstruct().sub(&a).max_abs() < 1e-10);
        // rank-1
        let v = Mat::from_fn(8, 1, |r, _| (r + 1) as f64);
        let r1 = v.matmul_nt(&v);
        let e = SymEig::new_ql(&r1);
        assert!(e.reconstruct().sub(&r1).max_abs() < 1e-8 * r1.max_abs());
    }

    #[test]
    fn property_eig_many_seeds() {
        for seed in 0..15 {
            let mut rng = Rng::new(100 + seed);
            let n = 1 + rng.below(20);
            let a = random_sym(n, &mut rng);
            let e = SymEig::new(&a);
            // A v_i = w_i v_i for each eigenpair
            for i in 0..n {
                let vi: Vec<f64> = (0..n).map(|r| e.v.at(r, i)).collect();
                let av = a.matvec(&vi);
                for r in 0..n {
                    assert!(
                        (av[r] - e.w[i] * vi[r]).abs() < 1e-8 * (1.0 + a.max_abs()),
                        "seed={seed} n={n} i={i}"
                    );
                }
            }
        }
    }
}
