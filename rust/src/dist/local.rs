//! In-process channel transport: a rank group connected by mpsc channels.
//!
//! Used by `--dist local` (one OS thread per rank, see [`super::trainer`])
//! and by the fault-injection tests — dropping a `LocalCollective` maps to
//! the same `Lost` link errors a closed TCP socket produces, so degraded
//! mode is exercised deterministically without sockets.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::Duration;

use super::{Collective, DistError, Frame, Link, LinkError, Star};

/// One end of a hub↔spoke channel pair.
pub(crate) struct ChanLink {
    tx: Sender<Frame>,
    rx: Receiver<Frame>,
}

impl Link for ChanLink {
    fn send(&mut self, frame: &Frame) -> Result<(), LinkError> {
        self.tx.send(frame.clone()).map_err(|_| LinkError::Lost)
    }

    fn recv(&mut self, timeout: Duration) -> Result<Frame, LinkError> {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => Ok(f),
            Err(RecvTimeoutError::Timeout) => Err(LinkError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(LinkError::Lost),
        }
    }
}

/// A size-`n` in-process group. Obtain one [`LocalCollective`] per rank
/// from [`LocalGroup::create`] and hand each to its worker thread.
pub struct LocalGroup;

impl LocalGroup {
    /// Create an `n`-rank group with the default per-op deadline
    /// (`KFAC_DIST_TIMEOUT_MS`). `colls[r]` is rank `r`'s handle.
    pub fn create(n: usize) -> Vec<LocalCollective> {
        Self::create_with_timeout(n, super::default_timeout())
    }

    /// Like [`create`](Self::create) with an explicit deadline — the
    /// fault-injection tests use short deadlines to exercise exclusion.
    pub fn create_with_timeout(n: usize, timeout: Duration) -> Vec<LocalCollective> {
        assert!(n >= 1, "LocalGroup needs at least one rank");
        // Per spoke r: an "up" channel (r -> hub) and a "down" channel
        // (hub -> r). The hub's link to r sends on down / receives on up.
        let mut hub_links: Vec<Option<ChanLink>> = Vec::with_capacity(n.saturating_sub(1));
        let mut spokes: Vec<LocalCollective> = Vec::with_capacity(n);
        let mut spoke_links: Vec<ChanLink> = Vec::with_capacity(n.saturating_sub(1));
        for _ in 1..n {
            let (up_tx, up_rx) = mpsc::channel();
            let (down_tx, down_rx) = mpsc::channel();
            hub_links.push(Some(ChanLink { tx: down_tx, rx: up_rx }));
            spoke_links.push(ChanLink { tx: up_tx, rx: down_rx });
        }
        spokes.push(LocalCollective { inner: Mutex::new(Star::new(0, n, timeout, hub_links)) });
        for (r, link) in spoke_links.into_iter().enumerate() {
            spokes.push(LocalCollective {
                inner: Mutex::new(Star::new(r + 1, n, timeout, vec![Some(link)])),
            });
        }
        spokes
    }
}

/// One rank's handle to an in-process group.
pub struct LocalCollective {
    inner: Mutex<Star<ChanLink>>,
}

impl Collective for LocalCollective {
    fn rank(&self) -> usize {
        self.inner.lock().unwrap().rank()
    }

    fn size(&self) -> usize {
        self.inner.lock().unwrap().size()
    }

    fn all_reduce_sum(&self, buf: &mut [f64]) -> Result<usize, DistError> {
        self.inner.lock().unwrap().all_reduce_sum(buf)
    }

    fn broadcast(&self, root: usize, buf: &mut [f64]) -> Result<(), DistError> {
        self.inner.lock().unwrap().broadcast(root, buf)
    }

    fn barrier(&self) -> Result<(), DistError> {
        self.inner.lock().unwrap().barrier()
    }
}
