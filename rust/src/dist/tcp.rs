//! Length-prefixed TCP transport (std-only, no external deps).
//!
//! Wire format per frame: `[tag: u8][len: u64 LE][len × f64 LE]` with tags
//! `0 = Data`, `1 = Abort`, `2 = Hello` (len 1, payload\[0\] = sender rank).
//!
//! Topology matches [`super::Star`]: rank 0 binds the listen address and
//! accepts one connection per spoke; spokes connect with retry/backoff
//! (listener races at startup are expected — rank 0 may come up last).
//! Startup requires full membership; after that, a peer that times out or
//! drops is excluded permanently and training degrades per the staleness
//! contract in `super`.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::{Collective, DistError, Frame, Link, LinkError, Star};

/// Largest accepted frame payload (in f64s): a sanity cap so a corrupt
/// length prefix fails fast instead of attempting a huge allocation.
const MAX_FRAME_LEN: u64 = 1 << 28;

const TAG_DATA: u8 = 0;
const TAG_ABORT: u8 = 1;
const TAG_HELLO: u8 = 2;

/// Connection parameters for a TCP group.
#[derive(Debug, Clone)]
pub struct TcpOpts {
    /// Rank 0's listen address; spokes connect to it.
    pub addr: String,
    /// Per-op read/write deadline.
    pub timeout: Duration,
    /// Spoke connect attempts before giving up.
    pub retries: u32,
    /// Initial delay between connect attempts (doubles, capped at 1 s).
    pub backoff: Duration,
}

impl TcpOpts {
    /// Read options from the environment: `KFAC_DIST_ADDR`
    /// (default `127.0.0.1:17199`), `KFAC_DIST_RETRIES` (default 40),
    /// `KFAC_DIST_BACKOFF_MS` (default 50) and `KFAC_DIST_TIMEOUT_MS`
    /// via [`super::default_timeout`]. See docs/env_registry.md.
    pub fn from_env() -> TcpOpts {
        let addr = std::env::var("KFAC_DIST_ADDR")
            .ok()
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "127.0.0.1:17199".to_string());
        let retries = std::env::var("KFAC_DIST_RETRIES")
            .ok()
            .and_then(|v| v.parse::<u32>().ok())
            .unwrap_or(40);
        let backoff_ms = std::env::var("KFAC_DIST_BACKOFF_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(50);
        TcpOpts {
            addr,
            timeout: super::default_timeout(),
            retries,
            backoff: Duration::from_millis(backoff_ms),
        }
    }
}

/// One framed TCP connection to a peer.
pub(crate) struct TcpLink {
    stream: TcpStream,
}

impl TcpLink {
    fn new(stream: TcpStream, timeout: Duration) -> std::io::Result<TcpLink> {
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        Ok(TcpLink { stream })
    }

    fn write_frame(&mut self, tag: u8, payload: &[f64]) -> Result<(), LinkError> {
        let mut bytes = Vec::with_capacity(9 + payload.len() * 8);
        bytes.push(tag);
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        for v in payload {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&bytes).map_err(map_io)
    }

    fn read_exact(&mut self, buf: &mut [u8]) -> Result<(), LinkError> {
        self.stream.read_exact(buf).map_err(map_io)
    }

    fn read_frame(&mut self) -> Result<Frame, LinkError> {
        let mut head = [0u8; 9];
        self.read_exact(&mut head)?;
        let tag = head[0];
        let len = u64::from_le_bytes(head[1..9].try_into().expect("9-byte header"));
        if len > MAX_FRAME_LEN {
            return Err(LinkError::Io(format!("frame length {len} exceeds sanity cap")));
        }
        let mut payload = vec![0.0f64; len as usize];
        let mut word = [0u8; 8];
        for v in payload.iter_mut() {
            self.read_exact(&mut word)?;
            *v = f64::from_le_bytes(word);
        }
        match tag {
            TAG_DATA => Ok(Frame::Data(payload)),
            TAG_ABORT => Ok(Frame::Abort),
            TAG_HELLO => Ok(Frame::Hello(payload)),
            t => Err(LinkError::Io(format!("unknown frame tag {t}"))),
        }
    }
}

fn map_io(e: std::io::Error) -> LinkError {
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => LinkError::Timeout,
        ErrorKind::UnexpectedEof
        | ErrorKind::ConnectionReset
        | ErrorKind::BrokenPipe
        | ErrorKind::ConnectionAborted => LinkError::Lost,
        _ => LinkError::Io(e.to_string()),
    }
}

impl Link for TcpLink {
    fn send(&mut self, frame: &Frame) -> Result<(), LinkError> {
        match frame {
            Frame::Data(v) => self.write_frame(TAG_DATA, v),
            Frame::Abort => self.write_frame(TAG_ABORT, &[]),
            Frame::Hello(v) => self.write_frame(TAG_HELLO, v),
        }
    }

    fn recv(&mut self, _timeout: Duration) -> Result<Frame, LinkError> {
        // The per-op deadline is enforced by the socket read timeout set
        // at connect time (`TcpLink::new`).
        self.read_frame()
    }
}

/// One rank's handle to a TCP group.
pub struct TcpCollective {
    inner: Mutex<Star<TcpLink>>,
}

impl TcpCollective {
    /// Join a `size`-rank group as `rank`. Rank 0 binds `opts.addr` and
    /// waits (up to the deadline window) for every spoke's `Hello`;
    /// spokes connect with retry/backoff. Startup requires full
    /// membership — a missing rank is a setup error, not degraded mode.
    pub fn connect(rank: usize, size: usize, opts: &TcpOpts) -> Result<TcpCollective, DistError> {
        if rank >= size {
            return Err(DistError::Protocol(format!("rank {rank} out of range for size {size}")));
        }
        if rank == 0 {
            let listener = TcpListener::bind(&opts.addr)
                .map_err(|e| DistError::Io(format!("bind {}: {e}", opts.addr)))?;
            Self::accept_spokes(listener, size, opts)
        } else {
            let addr: SocketAddr = opts
                .addr
                .parse()
                .map_err(|e| DistError::Io(format!("bad address {}: {e}", opts.addr)))?;
            let mut delay = opts.backoff;
            let mut last_err = String::new();
            for attempt in 0..=opts.retries {
                match TcpStream::connect_timeout(&addr, opts.timeout) {
                    Ok(stream) => {
                        let mut link = TcpLink::new(stream, opts.timeout)
                            .map_err(|e| DistError::Io(e.to_string()))?;
                        link.send(&Frame::Hello(vec![rank as f64]))
                            .map_err(|e| DistError::Io(format!("hello: {e:?}")))?;
                        let star = Star::new(rank, size, opts.timeout, vec![Some(link)]);
                        return Ok(TcpCollective { inner: Mutex::new(star) });
                    }
                    Err(e) => {
                        last_err = e.to_string();
                        if attempt < opts.retries {
                            std::thread::sleep(delay);
                            delay = (delay * 2).min(Duration::from_secs(1));
                        }
                    }
                }
            }
            Err(DistError::Io(format!(
                "connect {} failed after {} attempts: {last_err}",
                opts.addr,
                opts.retries + 1
            )))
        }
    }

    /// Hub setup from an already-bound listener (tests bind port 0 to get
    /// an ephemeral address, then hand the listener in here).
    pub fn accept_spokes(
        listener: TcpListener,
        size: usize,
        opts: &TcpOpts,
    ) -> Result<TcpCollective, DistError> {
        let mut links: Vec<Option<TcpLink>> = (1..size).map(|_| None).collect();
        if size > 1 {
            listener
                .set_nonblocking(true)
                .map_err(|e| DistError::Io(format!("set_nonblocking: {e}")))?;
            // Generous membership window: every spoke retries across
            // opts.retries * backoff, so mirror that here.
            let window = opts.timeout + opts.backoff * opts.retries.max(1);
            let deadline = Instant::now() + window;
            let mut joined = 0usize;
            while joined + 1 < size {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // A client that sends garbage (port scanner, stray
                        // connection) is dropped; keep accepting.
                        if let Ok(mut link) = TcpLink::new(stream, opts.timeout) {
                            if let Ok(Frame::Hello(p)) = link.read_frame() {
                                if p.len() == 1 && p[0].fract() == 0.0 && p[0] >= 1.0 {
                                    let r = p[0] as usize;
                                    if r < size && links[r - 1].is_none() {
                                        links[r - 1] = Some(link);
                                        joined += 1;
                                    }
                                }
                            }
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        if Instant::now() >= deadline {
                            let missing: Vec<usize> = links
                                .iter()
                                .enumerate()
                                .filter(|(_, l)| l.is_none())
                                .map(|(i, _)| i + 1)
                                .collect();
                            return Err(DistError::Io(format!(
                                "startup: ranks {missing:?} never joined"
                            )));
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(DistError::Io(format!("accept: {e}"))),
                }
            }
        }
        let star = Star::new(0, size, opts.timeout, links);
        Ok(TcpCollective { inner: Mutex::new(star) })
    }
}

impl Collective for TcpCollective {
    fn rank(&self) -> usize {
        self.inner.lock().unwrap().rank()
    }

    fn size(&self) -> usize {
        self.inner.lock().unwrap().size()
    }

    fn all_reduce_sum(&self, buf: &mut [f64]) -> Result<usize, DistError> {
        self.inner.lock().unwrap().all_reduce_sum(buf)
    }

    fn broadcast(&self, root: usize, buf: &mut [f64]) -> Result<(), DistError> {
        self.inner.lock().unwrap().broadcast(root, buf)
    }

    fn barrier(&self) -> Result<(), DistError> {
        self.inner.lock().unwrap().barrier()
    }
}
