//! In-process multi-rank harness (`--dist local`).
//!
//! Spawns one OS thread per rank, wires them into a [`LocalGroup`], and
//! hands each rank its [`Collective`] handle. Used by `main.rs` for
//! single-machine multi-worker runs and by the integration tests for the
//! `ranks=1` bit-identity and multi-rank lockstep contracts.

use std::sync::{Arc, Mutex};

use super::local::LocalGroup;
use super::Collective;

/// Run `f(rank, collective)` on `n` concurrent ranks (rank 0 on the calling
/// thread) and return the per-rank results in rank order.
pub fn run_local_ranks<T: Send>(
    n: usize,
    f: impl Fn(usize, Arc<dyn Collective>) -> T + Sync,
) -> Vec<T> {
    run_ranks_with(LocalGroup::create(n), &f)
}

/// Like [`run_local_ranks`] but over an explicit pre-built group — the
/// fault-injection tests pass `LocalGroup::create_with_timeout` groups so
/// slow peers get excluded quickly.
pub fn run_ranks_with<C: Collective + 'static, T: Send>(
    colls: Vec<C>,
    f: &(impl Fn(usize, Arc<dyn Collective>) -> T + Sync),
) -> Vec<T> {
    let n = colls.len();
    // Hand each rank its own handle through a take-once slot: the closure
    // below is `Fn` (shared across threads), so it cannot move out of a
    // plain Vec.
    let slots: Vec<Mutex<Option<Arc<dyn Collective>>>> = colls
        .into_iter()
        .map(|c| Mutex::new(Some(Arc::new(c) as Arc<dyn Collective>)))
        .collect();
    crate::par::scoped_ranks(n, |rank| {
        let coll = slots[rank]
            .lock()
            .unwrap()
            .take()
            .expect("run_ranks_with: rank handle already taken");
        f(rank, coll)
    })
}
