//! Fisher-factor statistics (paper Section 5).
//!
//! Per mini-batch we form the second moments
//!
//! - `aa[i]   = Ā_{i-1,i-1} = E[ā_{i-1} ā_{i-1}ᵀ]` (input side of layer i),
//! - `aa_off[i] = Ā_{i-1,i} = E[ā_{i-1} ā_iᵀ]`,
//! - `gg[i]   = G_{i,i} = E[g_i g_iᵀ]`,
//! - `gg_off[i] = G_{i,i+1} = E[g_i g_{i+1}ᵀ]`,
//!
//! with the `g` statistics computed from a backward pass whose targets
//! are **sampled from the model's predictive distribution** (so they
//! estimate the standard Fisher, not the empirical one), and maintain
//! exponentially-decayed running averages with
//! `ε = min(1 − 1/k, 0.95)` — the estimate therefore depends on much
//! more data than a single mini-batch, which is the key practical
//! advantage over HF-style methods the paper emphasizes.
//!
//! Each layer contributes its own factor-pair semantics. Dense layers
//! are the paper's `(E[ā āᵀ], E[g gᵀ])`. Conv layers follow Grosse &
//! Martens 2016 (KFC): the input factor is the **patch** second moment
//! `Ω = E[Σ_t ā_t ā_tᵀ]` over the `P` im2col rows of each case (sum
//! over positions, mean over cases — scale `1/m`), the gradient factor
//! the **spatially averaged** `Γ = E[(1/P) Σ_t g_t g_tᵀ]` (scale
//! `1/(m·P)`), so `F ≈ Ω ⊗ Γ` per conv block. Because the layer-local
//! row counts already encode `P` (`abars[i]`/`gs[i]` have `m·P` rows),
//! both reduce to the dense formulas bit-identically when `P = 1`.
//! Off-diagonal (tridiagonal) factors are only defined between
//! adjacent dense layers; pairs involving a conv layer keep their
//! deterministic shape but stay zero, which makes the tridiagonal
//! structure degrade gracefully to block-diagonal there.

use crate::linalg::Mat;
use crate::nn::net::Fwd;
use crate::nn::Arch;

/// Second-moment statistics from a single mini-batch (or an EMA of them;
/// the two share a shape).
#[derive(Clone, Debug)]
pub struct RawStats {
    /// `Ā_{i-1,i-1}`, shape `(d_{i-1}+1)²`, for each layer i (0-based).
    pub aa: Vec<Mat>,
    /// `Ā_{i-1,i}`, shape `(d_{i-1}+1) × (d_i+1)`, for i = 0..ℓ-2.
    pub aa_off: Vec<Mat>,
    /// `G_{i,i}`, shape `d_i²`.
    pub gg: Vec<Mat>,
    /// `G_{i,i+1}`, shape `d_i × d_{i+1}`, for i = 0..ℓ-2.
    pub gg_off: Vec<Mat>,
}

impl RawStats {
    /// Compute from cached forward activations and (sampled-target)
    /// backward derivatives. `gs[i]` must *not* be scaled by 1/m.
    ///
    /// Layer semantics come from the row counts the forward pass
    /// cached: a dense layer's `abars[i]`/`gs[i]` have `m` rows, a conv
    /// layer's `m·P` (one per case × output position). The diagonal
    /// factors are `Ω_i = (1/m) Āᵢᵀ Āᵢ` (patch sum for conv) and
    /// `Γ_i = (1/(m·P)) Gᵢᵀ Gᵢ` (spatial average; `P = 1` dense).
    /// Off-diagonal factors are only formed between adjacent layers
    /// whose rows are per-case (`m` rows each); any pair touching a
    /// conv layer stays zero at its deterministic shape.
    pub fn from_batch(fwd: &Fwd, gs: &[Mat]) -> RawStats {
        let m = fwd.m as f64;
        let l = gs.len();
        let scale = 1.0 / m;
        let aa: Vec<Mat> =
            fwd.abars.iter().map(|ab| ab.matmul_tn(ab).scale(scale).symmetrize()).collect();
        let gg: Vec<Mat> = gs
            .iter()
            .map(|g| g.matmul_tn(g).scale(1.0 / g.rows as f64).symmetrize())
            .collect();
        let per_case = |i: usize| fwd.abars[i].rows == fwd.m && gs[i].rows == fwd.m;
        let aa_off: Vec<Mat> = (0..l - 1)
            .map(|i| {
                if per_case(i) && per_case(i + 1) {
                    fwd.abars[i].matmul_tn(&fwd.abars[i + 1]).scale(scale)
                } else {
                    Mat::zeros(fwd.abars[i].cols, fwd.abars[i + 1].cols)
                }
            })
            .collect();
        let gg_off: Vec<Mat> = (0..l - 1)
            .map(|i| {
                if per_case(i) && per_case(i + 1) {
                    gs[i].matmul_tn(&gs[i + 1]).scale(scale)
                } else {
                    Mat::zeros(gs[i].cols, gs[i + 1].cols)
                }
            })
            .collect();
        RawStats { aa, aa_off, gg, gg_off }
    }

    /// Zero-initialized stats for an architecture. Shapes follow each
    /// layer's Kronecker factor dims (`Arch::factor_dims`): dense
    /// `(d+1, d')`, conv `(K+1, out_c)`.
    pub fn zeros(arch: &Arch) -> RawStats {
        let l = arch.num_layers();
        let fd: Vec<(usize, usize)> = (0..l).map(|i| arch.factor_dims(i)).collect();
        let aa = fd.iter().map(|&(a, _)| Mat::zeros(a, a)).collect();
        let gg = fd.iter().map(|&(_, g)| Mat::zeros(g, g)).collect();
        let aa_off = (0..l - 1).map(|i| Mat::zeros(fd[i].0, fd[i + 1].0)).collect();
        let gg_off = (0..l - 1).map(|i| Mat::zeros(fd[i].1, fd[i + 1].1)).collect();
        RawStats { aa, aa_off, gg, gg_off }
    }

    pub fn num_layers(&self) -> usize {
        self.aa.len()
    }

    /// All factor matrices in a fixed, stable order (`aa`, `aa_off`, `gg`,
    /// `gg_off`) — the order the flat serialize/reduce view below relies
    /// on. Distributed workers all-reduce this view, so the order must
    /// match on every rank (it is a pure function of the architecture).
    pub fn mats(&self) -> impl Iterator<Item = &Mat> {
        self.aa.iter().chain(self.aa_off.iter()).chain(self.gg.iter()).chain(self.gg_off.iter())
    }

    /// Mutable counterpart of [`mats`](Self::mats), same order.
    pub fn mats_mut(&mut self) -> impl Iterator<Item = &mut Mat> {
        self.aa
            .iter_mut()
            .chain(self.aa_off.iter_mut())
            .chain(self.gg.iter_mut())
            .chain(self.gg_off.iter_mut())
    }

    /// Total element count of the flat view.
    pub fn flat_len(&self) -> usize {
        self.mats().map(|m| m.data.len()).sum()
    }

    /// Serialize every factor matrix into `out` (length `flat_len()`), in
    /// [`mats`](Self::mats) order.
    pub fn write_flat(&self, out: &mut [f64]) {
        let mut i = 0;
        for m in self.mats() {
            out[i..i + m.data.len()].copy_from_slice(&m.data);
            i += m.data.len();
        }
        assert_eq!(i, out.len(), "write_flat: buffer length != flat_len()");
    }

    /// Inverse of [`write_flat`](Self::write_flat): load every factor
    /// matrix from `src` (shapes are unchanged; only data is read).
    pub fn read_flat(&mut self, src: &[f64]) {
        let mut i = 0;
        for m in self.mats_mut() {
            m.data.copy_from_slice(&src[i..i + m.data.len()]);
            i += m.data.len();
        }
        assert_eq!(i, src.len(), "read_flat: buffer length != flat_len()");
    }

    /// Elementwise difference `self − base` over every factor matrix —
    /// the "factor drift" the incremental-update capability
    /// (`FisherInverse::update`) consumes. Shapes must match (same
    /// architecture).
    pub fn delta_from(&self, base: &RawStats) -> RawStats {
        let diff = |xs: &[Mat], ys: &[Mat]| -> Vec<Mat> {
            assert_eq!(xs.len(), ys.len(), "delta_from: layer count mismatch");
            xs.iter().zip(ys.iter()).map(|(x, y)| x.sub(y)).collect()
        };
        RawStats {
            aa: diff(&self.aa, &base.aa),
            aa_off: diff(&self.aa_off, &base.aa_off),
            gg: diff(&self.gg, &base.gg),
            gg_off: diff(&self.gg_off, &base.gg_off),
        }
    }
}

/// Online exponentially-decayed estimates of the factor statistics.
#[derive(Clone, Debug)]
pub struct KfacStats {
    pub s: RawStats,
    /// Number of updates absorbed so far.
    pub k: usize,
}

impl KfacStats {
    pub fn new(arch: &Arch) -> KfacStats {
        KfacStats { s: RawStats::zeros(arch), k: 0 }
    }

    /// The paper's decay schedule: `ε = min(1 − 1/k, 0.95)`.
    pub fn epsilon(k: usize) -> f64 {
        (1.0 - 1.0 / k as f64).min(0.95)
    }

    /// Decay schedule when statistics are only folded in every `t_cov`
    /// steps. The per-step schedule retains a fraction `0.95ⁿ` of an old
    /// batch after n further steps; updating once per `t_cov` steps must
    /// match that *per step*, so the asymptotic cap becomes
    /// `0.95^t_cov` — n/t_cov strided updates then retain
    /// `(0.95^t_cov)^(n/t_cov) = 0.95ⁿ`, the same stationary weighting
    /// as per-step accumulation. Naively reusing the per-step cap would
    /// silently stretch the statistics' memory by a factor of `t_cov`.
    /// The warmup term `1 − 1/k` is already expressed in *updates*, not
    /// steps (it makes the EMA an exact running mean of its first
    /// batches), so it stays unscaled.
    pub fn epsilon_for_period(k: usize, t_cov: usize) -> f64 {
        let cap = if t_cov <= 1 { 0.95 } else { 0.95f64.powi(t_cov as i32) };
        (1.0 - 1.0 / k as f64).min(cap)
    }

    /// Fold in one mini-batch estimate (per-step accumulation).
    pub fn update(&mut self, batch: &RawStats) {
        self.update_with_period(batch, 1);
    }

    /// Fold in one mini-batch estimate collected every `t_cov` steps,
    /// with the decay scaled so the stationary statistics match
    /// per-step accumulation in expectation. `t_cov = 1` is bit-exactly
    /// the original per-step update.
    pub fn update_with_period(&mut self, batch: &RawStats, t_cov: usize) {
        self.k += 1;
        let eps = Self::epsilon_for_period(self.k, t_cov);
        let blend = |dst: &mut Vec<Mat>, src: &Vec<Mat>| {
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                d.ema(eps, 1.0 - eps, s);
            }
        };
        blend(&mut self.s.aa, &batch.aa);
        blend(&mut self.s.aa_off, &batch.aa_off);
        blend(&mut self.s.gg, &batch.gg);
        blend(&mut self.s.gg_off, &batch.gg_off);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    fn setup() -> (Net, crate::nn::Params, Mat) {
        let arch = Arch::new(
            vec![6, 5, 4, 3],
            vec![Act::Tanh, Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(32, 6, 1.0, &mut rng);
        (Net::new(arch), p, x)
    }

    #[test]
    fn shapes_match_arch() {
        let (net, p, x) = setup();
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(2));
        let st = RawStats::from_batch(&fwd, &gs);
        assert_eq!(st.aa.len(), 3);
        assert_eq!(st.aa[0].rows, 7);
        assert_eq!(st.aa[2].rows, 5);
        assert_eq!(st.gg[0].rows, 5);
        assert_eq!(st.gg[2].rows, 3);
        assert_eq!((st.aa_off[0].rows, st.aa_off[0].cols), (7, 6));
        assert_eq!((st.gg_off[1].rows, st.gg_off[1].cols), (4, 3));
        let z = RawStats::zeros(&net.arch);
        for (a, b) in z.aa.iter().zip(st.aa.iter()) {
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        }
        for (a, b) in z.gg_off.iter().zip(st.gg_off.iter()) {
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        }
    }

    #[test]
    fn flat_view_roundtrips_bitwise() {
        let (net, p, x) = setup();
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(4));
        let st = RawStats::from_batch(&fwd, &gs);
        let mut flat = vec![0.0; st.flat_len()];
        st.write_flat(&mut flat);
        assert_eq!(flat.len(), st.mats().map(|m| m.data.len()).sum::<usize>());
        let mut back = RawStats::zeros(&net.arch);
        back.read_flat(&flat);
        for (a, b) in st.mats().zip(back.mats()) {
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn aa_is_psd_with_unit_corner() {
        let (net, p, x) = setup();
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(3));
        let st = RawStats::from_batch(&fwd, &gs);
        for aa in &st.aa {
            // homogeneous coordinate: E[1·1] = 1 in the bottom-right corner
            assert!((aa.at(aa.rows - 1, aa.cols - 1) - 1.0).abs() < 1e-12);
            // PSD: vᵀ A v >= 0 for random v
            let mut rng = Rng::new(9);
            for _ in 0..5 {
                let v: Vec<f64> = (0..aa.rows).map(|_| rng.normal()).collect();
                let av = aa.matvec(&v);
                let q: f64 = v.iter().zip(av.iter()).map(|(a, b)| a * b).sum();
                assert!(q >= -1e-10);
            }
        }
    }

    #[test]
    fn conv_stats_shapes_scaling_and_zero_off_factors() {
        use crate::linalg::pack::ConvShape;
        use crate::nn::Layer;
        let shape = ConvShape { in_h: 4, in_w: 4, in_c: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
        let arch = Arch::from_layers(
            vec![
                Layer::Conv2d { shape, out_c: 3, act: Act::Tanh },
                Layer::Dense { d_in: 48, d_out: 5, act: Act::Identity },
            ],
            LossKind::SoftmaxCe,
        );
        let mut rng = Rng::new(13);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(8, 32, 1.0, &mut rng);
        let net = Net::new(arch.clone());
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        let st = RawStats::from_batch(&fwd, &gs);
        // shapes agree with the factor-dims template
        let z = RawStats::zeros(&arch);
        for (a, b) in z.mats().zip(st.mats()) {
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        }
        // Ω is the patch second moment: homogeneous corner = P = 16
        let omega = &st.aa[0];
        assert_eq!(omega.rows, 2 * 3 * 3 + 1);
        let p_count = shape.positions() as f64;
        assert!((omega.at(omega.rows - 1, omega.cols - 1) - p_count).abs() < 1e-9);
        // Γ is spatially averaged: same order of magnitude as a dense g
        assert_eq!(st.gg[0].rows, 3);
        // off-factors touching the conv layer are identically zero
        assert_eq!(st.aa_off[0].max_abs(), 0.0);
        assert_eq!(st.gg_off[0].max_abs(), 0.0);
        // dense head keeps the per-case semantics (unit homog corner)
        let aad = &st.aa[1];
        assert!((aad.at(aad.rows - 1, aad.cols - 1) - 1.0).abs() < 1e-12);
        // flat round-trip still works on the mixed-arch shapes
        let mut flat = vec![0.0; st.flat_len()];
        st.write_flat(&mut flat);
        let mut back = RawStats::zeros(&arch);
        back.read_flat(&flat);
        for (a, b) in st.mats().zip(back.mats()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn ema_schedule() {
        assert!((KfacStats::epsilon(1) - 0.0).abs() < 1e-15);
        assert!((KfacStats::epsilon(2) - 0.5).abs() < 1e-15);
        assert!((KfacStats::epsilon(100) - 0.95).abs() < 1e-15);
    }

    #[test]
    fn period_schedule_scales_the_cap_only() {
        // t_cov ≤ 1 is bit-exactly the per-step schedule.
        for k in [1usize, 2, 3, 19, 20, 100] {
            let per_step = KfacStats::epsilon(k).to_bits();
            assert_eq!(KfacStats::epsilon_for_period(k, 1).to_bits(), per_step);
            assert_eq!(KfacStats::epsilon_for_period(k, 0).to_bits(), per_step);
        }
        // the asymptotic cap compounds per skipped step…
        assert!((KfacStats::epsilon_for_period(100, 3) - 0.95f64.powi(3)).abs() < 1e-15);
        assert!((KfacStats::epsilon_for_period(100, 5) - 0.95f64.powi(5)).abs() < 1e-15);
        // …while the warmup (counted in updates) is unchanged
        assert!((KfacStats::epsilon_for_period(1, 5) - 0.0).abs() < 1e-15);
        assert!((KfacStats::epsilon_for_period(2, 5) - 0.5).abs() < 1e-15);
    }

    fn const_stats(arch: &Arch, v: f64) -> RawStats {
        let mut st = RawStats::zeros(arch);
        for m in st
            .aa
            .iter_mut()
            .chain(st.aa_off.iter_mut())
            .chain(st.gg.iter_mut())
            .chain(st.gg_off.iter_mut())
        {
            *m = Mat::filled(m.rows, m.cols, v);
        }
        st
    }

    #[test]
    fn update_with_period_one_is_bitwise_update() {
        let (net, p, x) = setup();
        let mut rng = Rng::new(7);
        let mut a = KfacStats::new(&net.arch);
        let mut b = KfacStats::new(&net.arch);
        for _ in 0..5 {
            let fwd = net.forward(&p, &x);
            let gs = net.sampled_backward(&p, &fwd, &mut rng);
            let st = RawStats::from_batch(&fwd, &gs);
            a.update(&st);
            b.update_with_period(&st, 1);
        }
        assert_eq!(a.k, b.k);
        for (ma, mb) in a.s.aa.iter().chain(a.s.gg.iter()).zip(b.s.aa.iter().chain(b.s.gg.iter())) {
            for (va, vb) in ma.data.iter().zip(mb.data.iter()) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn strided_updates_match_per_step_stationary_decay() {
        // The satellite bugfix: statistics folded in every t_cov steps
        // must decay old data at the same *per-step* rate as per-step
        // accumulation. Feed a constant c, then switch to d: after n
        // further steps both schedules must retain (c−d)·0.95ⁿ, while
        // the naive (unscaled) strided EMA retains (c−d)·0.95^(n/t) —
        // i.e. remembers t× too long.
        let arch = Arch::new(vec![4, 3, 2], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
        let (c, d, t, n) = (3.0, 1.0, 3usize, 30usize);
        let bc = const_stats(&arch, c);
        let bd = const_stats(&arch, d);
        let mut per_step = KfacStats::new(&arch);
        let mut strided = KfacStats::new(&arch);
        let mut naive = KfacStats::new(&arch);
        // warm past every schedule's cap; the EMA of a constant is c exactly
        for _ in 0..30 {
            per_step.update(&bc);
            strided.update_with_period(&bc, t);
            naive.update(&bc);
        }
        for i in 0..n {
            per_step.update(&bd);
            if (i + 1) % t == 0 {
                strided.update_with_period(&bd, t);
                naive.update(&bd); // unscaled decay at the strided cadence
            }
        }
        let p = per_step.s.gg[0].at(0, 0);
        let s = strided.s.gg[0].at(0, 0);
        let nv = naive.s.gg[0].at(0, 0);
        let want = d + (c - d) * 0.95f64.powi(n as i32);
        assert!((p - want).abs() < 1e-12, "per-step {p} vs analytic {want}");
        assert!((s - want).abs() < 1e-12, "strided {s} vs analytic {want}");
        let naive_want = d + (c - d) * 0.95f64.powi((n / t) as i32);
        assert!((nv - naive_want).abs() < 1e-12);
        assert!(
            (nv - p).abs() > 0.5,
            "naive strided EMA should visibly over-remember: {nv} vs {p}"
        );
    }

    #[test]
    fn strided_ema_dense_checks_against_exact_fisher_blocks() {
        // Dense check against fisher/exact.rs: a t_cov = 3 strided EMA
        // over sampled-target batches must still converge to the exact
        // Ā/G blocks. Ā is deterministic given x (exact immediately);
        // G is Monte-Carlo with effective sample size ≈ 13 updates ×
        // 64 rows, so the bound is generous.
        let arch = Arch::new(
            vec![6, 5, 4, 3],
            vec![Act::Tanh, Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let mut rng = Rng::new(11);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(64, 6, 1.0, &mut rng);
        let net = Net::new(arch);
        let eb = crate::fisher::exact::ExactBlocks::compute(&net, &p, &x, 0, 3);
        let t_cov = 3usize;
        let mut ema = KfacStats::new(&net.arch);
        let fwd = net.forward(&p, &x);
        for _ in 0..200 {
            let gs = net.sampled_backward(&p, &fwd, &mut rng);
            let st = RawStats::from_batch(&fwd, &gs);
            ema.update_with_period(&st, t_cov);
        }
        for i in 0..3 {
            let aa_err = ema.s.aa[i].sub(&eb.aa[i][i]).max_abs();
            let aa_scale = eb.aa[i][i].max_abs().max(1e-6);
            assert!(aa_err / aa_scale < 1e-10, "aa[{i}] rel err {}", aa_err / aa_scale);
            let gg_err = ema.s.gg[i].sub(&eb.gg[i][i]).max_abs();
            let gg_scale = eb.gg[i][i].max_abs().max(1e-6);
            assert!(gg_err / gg_scale < 0.35, "gg[{i}] rel err {}", gg_err / gg_scale);
        }
    }

    #[test]
    fn first_update_takes_batch_value() {
        let (net, p, x) = setup();
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut Rng::new(4));
        let st = RawStats::from_batch(&fwd, &gs);
        let mut ema = KfacStats::new(&net.arch);
        ema.update(&st);
        assert!(ema.s.aa[0].sub(&st.aa[0]).max_abs() < 1e-14);
        assert!(ema.s.gg[1].sub(&st.gg[1]).max_abs() < 1e-14);
    }
}
