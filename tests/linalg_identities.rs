//! Identity-based property tests for the dense linear-algebra substrate
//! beyond GEMM, on seeded SPD inputs of sizes 1..64:
//!
//! - `Cholesky`: `L Lᵀ = A`, and `A x = b` solves round-trip;
//! - `SymEig`: `Q Λ Qᵀ = A` and `Qᵀ Q = I` (the blocked production
//!   path cross-checked against the scalar QL and Jacobi references at
//!   1e-9 on every size), plus seeded boundary tests at sizes 23–26
//!   straddling the `n > 24` dispatch switch (including degenerate
//!   spectra);
//! - `KronPairInverse`: `(A ⊗ B ± C ⊗ D)` applied to the structured
//!   inverse's output round-trips the input.

use kfac::linalg::kron::kron_apply;
use kfac::linalg::{Cholesky, KronPairInverse, Mat, SymEig};
use kfac::rng::Rng;

/// Seeded SPD matrix: Xᵀ X / n + diag·I with a tall X.
fn random_spd(n: usize, diag: f64, rng: &mut Rng) -> Mat {
    let x = Mat::randn(n + 4, n, 1.0, rng);
    x.matmul_tn(&x).scale(1.0 / n as f64).add_diag(diag)
}

/// Size sweep: every power-of-two boundary plus seeded odd sizes in 1..64.
fn sizes(rng: &mut Rng) -> Vec<usize> {
    let mut s = vec![1, 2, 3, 4, 5, 7, 8, 13, 16, 24, 25, 31, 32, 33, 48, 64];
    for _ in 0..8 {
        s.push(1 + rng.below(64));
    }
    s
}

#[test]
fn cholesky_reconstructs_l_lt() {
    let mut rng = Rng::new(11);
    for n in sizes(&mut rng) {
        let a = random_spd(n, 0.5, &mut rng);
        let c = Cholesky::new(&a).expect("SPD input must factor");
        let rec = c.l.matmul_nt(&c.l);
        let err = rec.sub(&a).max_abs() / (1.0 + a.max_abs());
        assert!(err < 1e-10, "n={n}: LLᵀ reconstruction err {err}");
        // L must be lower-triangular with positive diagonal
        for r in 0..n {
            assert!(c.l.at(r, r) > 0.0, "n={n}: nonpositive pivot at {r}");
            for col in (r + 1)..n {
                assert_eq!(c.l.at(r, col), 0.0, "n={n}: L not lower-triangular");
            }
        }
    }
}

#[test]
fn cholesky_solve_roundtrips() {
    let mut rng = Rng::new(12);
    for n in sizes(&mut rng) {
        let a = random_spd(n, 0.5, &mut rng);
        let c = Cholesky::new(&a).unwrap();
        let b = Mat::randn(n, 3, 1.0, &mut rng);
        let x = c.solve(&b);
        let resid = a.matmul(&x).sub(&b).max_abs();
        assert!(resid < 1e-8 * (1.0 + b.max_abs()), "n={n}: residual {resid}");
    }
}

#[test]
fn symeig_reconstructs_and_is_orthogonal() {
    let mut rng = Rng::new(13);
    for n in sizes(&mut rng) {
        // symmetric (not necessarily definite) input exercises both the
        // Jacobi (n ≤ 24) and the tred2/tql2 path (n > 24)
        let a = Mat::randn(n, n, 1.0, &mut rng).symmetrize();
        let e = SymEig::new(&a);
        let rec_err = e.reconstruct().sub(&a).max_abs() / (1.0 + a.max_abs());
        assert!(rec_err < 1e-9, "n={n}: QΛQᵀ reconstruction err {rec_err}");
        let orth = e.v.matmul_tn(&e.v).sub(&Mat::eye(n)).max_abs();
        assert!(orth < 1e-9, "n={n}: QᵀQ − I = {orth}");
        // ascending spectrum, matching trace
        for i in 1..n {
            assert!(e.w[i] >= e.w[i - 1], "n={n}: spectrum not sorted");
        }
        let tr: f64 = e.w.iter().sum();
        assert!((tr - a.trace()).abs() < 1e-8 * (1.0 + a.trace().abs()), "n={n}: trace");
    }
}

#[test]
fn symeig_ql_and_jacobi_agree_across_dispatch_boundary() {
    // `SymEig::new` switches from cyclic Jacobi to the blocked
    // tridiagonalizer at n > 24; all three paths must agree on the
    // spectrum and reconstruct `Q Λ Qᵀ = A` at the sizes straddling
    // the switch.
    for n in [23usize, 24, 25, 26] {
        for seed in 0..3u64 {
            let mut mrng = Rng::new(1_000 * n as u64 + seed);
            let a = Mat::randn(n, n, 1.0, &mut mrng).symmetrize();
            let bl = SymEig::new_blocked(&a);
            let ql = SymEig::new_ql(&a);
            let ja = SymEig::new_jacobi(&a);
            let scale = 1.0 + a.max_abs();
            for i in 0..n {
                assert!(
                    (ql.w[i] - ja.w[i]).abs() < 1e-9 * scale,
                    "n={n} seed={seed} eigenvalue {i}: ql={} jacobi={}",
                    ql.w[i],
                    ja.w[i]
                );
                assert!(
                    (bl.w[i] - ja.w[i]).abs() < 1e-9 * scale,
                    "n={n} seed={seed} eigenvalue {i}: blocked={} jacobi={}",
                    bl.w[i],
                    ja.w[i]
                );
            }
            for e in [&bl, &ql, &ja] {
                assert!(e.reconstruct().sub(&a).max_abs() < 1e-9 * scale, "n={n} seed={seed}");
                assert!(e.v.matmul_tn(&e.v).sub(&Mat::eye(n)).max_abs() < 1e-9, "n={n}");
            }
            // the dispatching front door reconstructs too, whichever
            // path it picked
            let e = SymEig::new(&a);
            assert!(e.reconstruct().sub(&a).max_abs() < 1e-9 * scale, "n={n} dispatch");
        }
    }
}

#[test]
fn symeig_blocked_matches_references_across_sizes() {
    // The blocked, pool-parallel path against both scalar references on
    // the full size sweep (panel boundaries at NB = 32 included).
    let mut rng = Rng::new(19);
    for n in sizes(&mut rng) {
        let a = Mat::randn(n, n, 1.0, &mut rng).symmetrize();
        let bl = SymEig::new_blocked(&a);
        let ql = SymEig::new_ql(&a);
        let ja = SymEig::new_jacobi(&a);
        let scale = 1.0 + a.max_abs();
        for i in 0..n {
            assert!(
                (bl.w[i] - ql.w[i]).abs() < 1e-9 * scale,
                "n={n} eigenvalue {i}: blocked={} ql={}",
                bl.w[i],
                ql.w[i]
            );
            assert!((bl.w[i] - ja.w[i]).abs() < 1e-9 * scale, "n={n} vs jacobi {i}");
        }
        assert!(
            bl.reconstruct().sub(&a).max_abs() < 1e-9 * scale,
            "n={n}: blocked-path reconstruction error"
        );
        assert!(
            bl.v.matmul_tn(&bl.v).sub(&Mat::eye(n)).max_abs() < 1e-9,
            "n={n}: blocked orthogonality"
        );
    }
}

#[test]
fn symeig_boundary_sizes_handle_degenerate_spectra() {
    // Repeated eigenvalues (including a zero cluster) at the dispatch
    // boundary: both paths must recover the multiset of eigenvalues and
    // reconstruct A, even though individual eigenvectors are not unique.
    let mut rng = Rng::new(17);
    for n in [23usize, 24, 25, 26] {
        // random orthogonal Q from a helper eigendecomposition
        let q = SymEig::new_jacobi(&Mat::randn(n, n, 1.0, &mut rng).symmetrize()).v;
        // spectrum with heavy multiplicities: 0 (×3), 1.5, and 4.0
        let w: Vec<f64> = (0..n)
            .map(|i| match i {
                0..=2 => 0.0,
                i if i < n / 2 => 1.5,
                _ => 4.0,
            })
            .collect();
        let qd = Mat::from_fn(n, n, |r, c| q.at(r, c) * w[c]);
        let a = qd.matmul_nt(&q).symmetrize(); // Q diag(w) Qᵀ
        let ql = SymEig::new_ql(&a);
        let ja = SymEig::new_jacobi(&a);
        let scale = 1.0 + a.max_abs();
        for i in 0..n {
            // sorted spectra must agree with the construction and with
            // each other
            let mut sorted = w.clone();
            sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
            assert!((ql.w[i] - sorted[i]).abs() < 1e-8 * scale, "n={n} ql eigenvalue {i}");
            assert!((ql.w[i] - ja.w[i]).abs() < 1e-8 * scale, "n={n} eigenvalue {i}");
        }
        for e in [&ql, &ja] {
            assert!(e.reconstruct().sub(&a).max_abs() < 1e-8 * scale, "n={n} degenerate");
            assert!(e.v.matmul_tn(&e.v).sub(&Mat::eye(n)).max_abs() < 1e-8, "n={n} orth");
        }
    }
}

#[test]
fn kron_pair_inverse_roundtrips_sum() {
    let mut rng = Rng::new(14);
    for seed in 0..10u64 {
        let mut sr = Rng::new(1000 + seed);
        let na = 1 + sr.below(64);
        let nb = 1 + sr.below(64);
        let a = random_spd(na, 0.8, &mut rng);
        let b = random_spd(nb, 0.8, &mut rng);
        let c = random_spd(na, 0.1, &mut rng);
        let d = random_spd(nb, 0.1, &mut rng);
        let kpi = KronPairInverse::new(&a, &b, &c, &d, 1.0);
        let x = Mat::randn(nb, na, 1.0, &mut rng);
        let y = kpi.apply(&x);
        // (A⊗B + C⊗D) y must give back x, applied via the vec-trick
        let back = kron_apply(&a, &b, &y).add(&kron_apply(&c, &d, &y));
        let err = back.sub(&x).max_abs() / (1.0 + x.max_abs());
        assert!(err < 1e-6, "seed={seed} na={na} nb={nb}: roundtrip err {err}");
    }
}

#[test]
fn kron_pair_inverse_roundtrips_difference() {
    let mut rng = Rng::new(15);
    for seed in 0..10u64 {
        let mut sr = Rng::new(2000 + seed);
        let na = 1 + sr.below(64);
        let nb = 1 + sr.below(64);
        let a = random_spd(na, 1.0, &mut rng);
        let b = random_spd(nb, 1.0, &mut rng);
        // C ⊗ D a strict contraction of A ⊗ B keeps the difference PD
        let c = a.scale(0.3);
        let d = b.scale(0.4);
        let kpi = KronPairInverse::new(&a, &b, &c, &d, -1.0);
        let x = Mat::randn(nb, na, 1.0, &mut rng);
        let y = kpi.apply(&x);
        let back = kron_apply(&a, &b, &y).sub(&kron_apply(&c, &d, &y));
        let err = back.sub(&x).max_abs() / (1.0 + x.max_abs());
        assert!(err < 1e-6, "seed={seed} na={na} nb={nb}: roundtrip err {err}");
    }
}
