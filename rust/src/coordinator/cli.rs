//! Dependency-free CLI argument parsing: positional subcommand plus
//! `--key value` / `--key=value` / bare `--flag` options.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.options.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --problem mnist_ae --iters=200 --momentum --seed 7");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("problem"), Some("mnist_ae"));
        assert_eq!(a.get_usize("iters", 0), 200);
        assert!(a.get_flag("momentum"));
        assert_eq!(a.get_usize("seed", 0), 7);
        assert_eq!(a.get_f64("lr", 0.5), 0.5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --quick");
        assert!(a.get_flag("quick"));
    }
}
