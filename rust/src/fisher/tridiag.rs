//! Block-tridiagonal inverse approximation `F̂⁻¹` (paper Section 4.3).
//!
//! `F̂` is defined to agree with `F̃` on the tridiagonal blocks while
//! having a block-tridiagonal inverse — equivalently, the distribution
//! over `vec(DW_i)` is modelled as a directed Gaussian graphical model
//! chain from the top layer down. The Cholesky-of-precision identity
//! gives `F̂⁻¹ = Ξᵀ Λ Ξ` with
//!
//! - `Ψ_{i,i+1} = F̃_{i,i+1} F̃_{i+1,i+1}⁻¹ = Ψ^Ā_{i-1,i} ⊗ Ψ^G_{i,i+1}`
//!   (the DGGM regression coefficients, themselves Kronecker-factored),
//! - `Σ_{i|i+1} = F̃_{i,i} − Ψ (F̃_{i+1,i+1}) Ψᵀ` (conditional
//!   covariances — a **difference of Kronecker products**, inverted with
//!   the cached Appendix-B factorization [`KronPairInverse`]),
//! - `Ξ` unit upper block-bidiagonal with `-Ψ_{i,i+1}` above the diagonal.
//!
//! Applying `F̂⁻¹` to a gradient therefore costs a handful of
//! layer-sized GEMMs — same order as the block-diagonal version, with a
//! ~2× constant.

use super::damping::damped_factors;
use super::stats::RawStats;
use super::FisherInverse;
use crate::linalg::chol::spd_inverse;
use crate::linalg::{KronPairInverse, Mat};
use crate::nn::Params;

enum LambdaBlock {
    /// `Σ_{i|i+1}⁻¹` via the Appendix-B factorization.
    Diff(KronPairInverse),
    /// Final block `Σ_ℓ⁻¹ = Ā⁻¹ ⊗ G⁻¹`.
    Kron { ainv: Mat, ginv: Mat },
}

/// Cached block-tridiagonal inverse.
pub struct TridiagInverse {
    /// `Ψ^Ā_{i-1,i} = Ā_{i-1,i} Ā_{i,i}⁻¹`, for block pairs (i, i+1).
    psi_a: Vec<Mat>,
    /// `Ψ^G_{i,i+1} = G_{i,i+1} G_{i+1,i+1}⁻¹`.
    psi_g: Vec<Mat>,
    lambda: Vec<LambdaBlock>,
}

impl TridiagInverse {
    /// Build from factor statistics with factored-Tikhonov strength `γ`.
    /// Damping is applied to the diagonal-block factors (as in the
    /// paper's Figure 3/6 computations); the off-diagonal factors are
    /// used as-is.
    pub fn build(stats: &RawStats, gamma: f64) -> TridiagInverse {
        let l = stats.num_layers();
        // Damped diagonal factors (with the per-layer poisoned-stats
        // guard), computed across the pool like the stages below.
        let damped: Vec<(Mat, Mat)> = crate::par::par_map_send(l, 1, |i| {
            super::check_factors_finite("blktridiag", i, &stats.aa[i], &stats.gg[i]);
            damped_factors(&stats.aa[i], &stats.gg[i], gamma)
        });
        // Ψ factors for each adjacent pair (i, i+1), i = 0..l-2; each pair
        // needs the *next* block's damped-factor inverses — computed in
        // parallel across pairs (paper §8: task 5 parallelizes across
        // layers).
        let psi: Vec<(Mat, Mat)> = crate::par::par_map_send(l - 1, 1, |i| {
            let ainv_next = spd_inverse(&damped[i + 1].0);
            let ginv_next = spd_inverse(&damped[i + 1].1);
            (stats.aa_off[i].matmul(&ainv_next), stats.gg_off[i].matmul(&ginv_next))
        });
        let (psi_a, psi_g): (Vec<Mat>, Vec<Mat>) = psi.into_iter().unzip();
        // Λ blocks (the expensive eigendecompositions), in parallel.
        let lambda = crate::par::par_map_send(l, 1, |i| {
            if i + 1 < l {
                // Σ_{i|i+1} = Ā_d[i] ⊗ G_d[i] − (Ψ^Ā Ā_d[i+1] Ψ^Āᵀ) ⊗ (Ψ^G G_d[i+1] Ψ^Gᵀ)
                let c = psi_a[i].matmul(&damped[i + 1].0).matmul_nt(&psi_a[i]).symmetrize();
                let d = psi_g[i].matmul(&damped[i + 1].1).matmul_nt(&psi_g[i]).symmetrize();
                LambdaBlock::Diff(KronPairInverse::new(&damped[i].0, &damped[i].1, &c, &d, -1.0))
            } else {
                LambdaBlock::Kron {
                    ainv: spd_inverse(&damped[i].0),
                    ginv: spd_inverse(&damped[i].1),
                }
            }
        });
        TridiagInverse { psi_a, psi_g, lambda }
    }

    /// `u = Ξ v`:  `U_i = V_i − Ψ^G_{i,i+1} V_{i+1} Ψ^Ā_{i-1,i}ᵀ`, `U_ℓ = V_ℓ`.
    fn xi_apply(&self, v: &[Mat]) -> Vec<Mat> {
        let l = v.len();
        (0..l)
            .map(|i| {
                if i + 1 < l {
                    let corr = self.psi_g[i].matmul(&v[i + 1]).matmul_nt(&self.psi_a[i]);
                    v[i].sub(&corr)
                } else {
                    v[i].clone()
                }
            })
            .collect()
    }

    /// `u = Ξᵀ v`: `U_i = V_i − Ψ^G_{i-1,i}ᵀ V_{i-1} Ψ^Ā_{i-2,i-1}`, `U_1 = V_1`.
    fn xi_t_apply(&self, v: &[Mat]) -> Vec<Mat> {
        let l = v.len();
        (0..l)
            .map(|i| {
                if i >= 1 {
                    let corr = self.psi_g[i - 1].matmul_tn(&v[i - 1]).matmul(&self.psi_a[i - 1]);
                    v[i].sub(&corr)
                } else {
                    v[i].clone()
                }
            })
            .collect()
    }

    /// `u = Λ v` (block-wise conditional-precision application).
    fn lambda_apply(&self, v: &[Mat]) -> Vec<Mat> {
        v.iter()
            .zip(self.lambda.iter())
            .map(|(vi, lb)| match lb {
                LambdaBlock::Diff(kpi) => kpi.apply(vi),
                LambdaBlock::Kron { ainv, ginv } => ginv.matmul(&vi.matmul(ainv)),
            })
            .collect()
    }
}

impl FisherInverse for TridiagInverse {
    /// `F̂⁻¹ v = Ξᵀ Λ Ξ v`.
    fn apply(&self, grads: &Params) -> Params {
        let v1 = self.xi_apply(&grads.0);
        let v2 = self.lambda_apply(&v1);
        Params(self.xi_t_apply(&v2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::stats::KfacStats;
    use crate::linalg::kron::{kron, unvec, vec_mat};
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    /// Build EMA'd stats on a real network so off-diagonal factors are
    /// genuinely correlated (random matrices wouldn't exercise PSD-ness
    /// of Σ).
    fn setup(seed: u64) -> (Arch, RawStats, Params) {
        let arch = Arch::new(
            vec![5, 4, 3, 2],
            vec![Act::Tanh, Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(seed);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(200, 5, 1.0, &mut rng);
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        let mut st = KfacStats::new(&arch);
        st.update(&RawStats::from_batch(&fwd, &gs));
        (arch, st.s, p)
    }

    /// Dense F̂⁻¹ from the paper's ΞᵀΛΞ formula built with dense kron
    /// blocks, for cross-checking the structured apply.
    fn dense_fhat_inv(stats: &RawStats, gamma: f64) -> (Mat, Vec<usize>) {
        let l = stats.num_layers();
        let damped: Vec<(Mat, Mat)> =
            (0..l).map(|i| damped_factors(&stats.aa[i], &stats.gg[i], gamma)).collect();
        let sizes: Vec<usize> =
            (0..l).map(|i| damped[i].0.rows * damped[i].1.rows).collect();
        let total: usize = sizes.iter().sum();
        let offs: Vec<usize> = sizes
            .iter()
            .scan(0, |acc, s| {
                let o = *acc;
                *acc += s;
                Some(o)
            })
            .collect();
        // Ψ_{i,i+1} dense
        let mut psis = Vec::new();
        for i in 0..l - 1 {
            let ainv = spd_inverse(&damped[i + 1].0);
            let ginv = spd_inverse(&damped[i + 1].1);
            let pa = stats.aa_off[i].matmul(&ainv);
            let pg = stats.gg_off[i].matmul(&ginv);
            psis.push(kron(&pa, &pg));
        }
        // Ξ dense
        let mut xi = Mat::eye(total);
        for i in 0..l - 1 {
            let neg = psis[i].scale(-1.0);
            xi.set_block(offs[i], offs[i + 1], &neg);
        }
        // Λ dense
        let mut lam = Mat::zeros(total, total);
        for i in 0..l {
            let fii = kron(&damped[i].0, &damped[i].1);
            let sig = if i + 1 < l {
                let fnext = kron(&damped[i + 1].0, &damped[i + 1].1);
                fii.sub(&psis[i].matmul(&fnext).matmul_nt(&psis[i]))
            } else {
                fii
            };
            lam.set_block(offs[i], offs[i], &sig.inverse());
        }
        (xi.transpose().matmul(&lam).matmul(&xi), offs)
    }

    #[test]
    fn structured_apply_matches_dense_formula() {
        let (arch, stats, p) = setup(1);
        let gamma = 0.3;
        let tri = TridiagInverse::build(&stats, gamma);
        let (dense_inv, offs) = dense_fhat_inv(&stats, gamma);
        let mut rng = Rng::new(7);
        let grads =
            Params(p.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
        let got = tri.apply(&grads);
        // Assemble vec(grads) in block order (column-stacked per block).
        let l = arch.num_layers();
        let mut v = vec![0.0; dense_inv.rows];
        for i in 0..l {
            let vi = vec_mat(&grads.0[i]);
            v[offs[i]..offs[i] + vi.len()].copy_from_slice(&vi);
        }
        let uv = dense_inv.matvec(&v);
        for i in 0..l {
            let (r, c) = (grads.0[i].rows, grads.0[i].cols);
            let want = unvec(&uv[offs[i]..offs[i] + r * c], r, c);
            let err = got.0[i].sub(&want).max_abs();
            let scale = want.max_abs().max(1e-12);
            assert!(err / scale < 1e-6, "block {i} rel err={}", err / scale);
        }
    }

    #[test]
    fn fhat_agrees_with_ftilde_on_tridiagonal_blocks() {
        // By construction (paper §4.3): inverting the dense F̂⁻¹ must
        // reproduce the damped F̃'s tridiagonal blocks exactly.
        let (arch, stats, _) = setup(2);
        let gamma = 0.4;
        let (dense_inv, offs) = dense_fhat_inv(&stats, gamma);
        let fhat = dense_inv.inverse();
        let l = arch.num_layers();
        let damped: Vec<(Mat, Mat)> =
            (0..l).map(|i| damped_factors(&stats.aa[i], &stats.gg[i], gamma)).collect();
        // diagonal blocks
        for i in 0..l {
            let want = kron(&damped[i].0, &damped[i].1);
            let got = fhat.block(offs[i], offs[i] + want.rows, offs[i], offs[i] + want.cols);
            let err = got.sub(&want).max_abs() / want.max_abs();
            assert!(err < 1e-6, "diag block {i} rel err={err}");
        }
        // off-diagonal (tridiagonal) blocks: F̃_{i,i+1} = Ā_off ⊗ G_off
        for i in 0..l - 1 {
            let want = kron(&stats.aa_off[i], &stats.gg_off[i]);
            let got = fhat.block(
                offs[i],
                offs[i] + want.rows,
                offs[i + 1],
                offs[i + 1] + want.cols,
            );
            let err = got.sub(&want).max_abs() / want.max_abs().max(1e-12);
            assert!(err < 1e-5, "off block {i} rel err={err}");
        }
    }

    #[test]
    fn reduces_to_blockdiag_when_off_factors_zero() {
        let (arch, mut stats, p) = setup(3);
        for m in stats.aa_off.iter_mut() {
            *m = Mat::zeros(m.rows, m.cols);
        }
        for m in stats.gg_off.iter_mut() {
            *m = Mat::zeros(m.rows, m.cols);
        }
        let gamma = 0.2;
        let tri = TridiagInverse::build(&stats, gamma);
        let bd = crate::fisher::BlockDiagInverse::build(&stats, gamma);
        let mut rng = Rng::new(9);
        let grads =
            Params(p.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
        let a = tri.apply(&grads);
        let b = crate::fisher::FisherInverse::apply(&bd, &grads);
        let _ = arch;
        for i in 0..a.0.len() {
            let err = a.0[i].sub(&b.0[i]).max_abs();
            assert!(err < 1e-8, "block {i} err={err}");
        }
    }
}
