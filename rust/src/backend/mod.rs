//! Model-compute backends.
//!
//! The K-FAC coordinator (Layer 3) is generic over *where* the per-batch
//! heavy compute happens, via [`ModelBackend`]:
//!
//! - [`RustBackend`] — the pure-Rust `nn` substrate (f64). Used by the
//!   structure experiments, tests, and as a fallback; also the oracle
//!   the PJRT path is cross-validated against.
//! - [`PjrtBackend`](crate::backend::pjrt::PjrtBackend) — executes the
//!   AOT-compiled JAX/Pallas HLO artifacts through the PJRT CPU client
//!   (`rust/src/runtime`). This is the "request path": Python never
//!   runs at training time.

pub mod pjrt;
pub mod rust_backend;

pub use pjrt::PjrtBackend;
pub use rust_backend::RustBackend;

use crate::fisher::stats::RawStats;
use crate::linalg::{KronBasis, Mat};
use crate::nn::{Arch, Params};

/// Per-batch second-moment statistics (alias of the Fisher-factor raw
/// statistics; see [`RawStats`]).
pub type BatchStats = RawStats;

/// The compute interface the optimizer drives.
///
/// All losses/gradients are **means over the mini-batch** and exclude
/// the ℓ2 term (the optimizer owns `η`). `x`/`y` have one case per row.
pub trait ModelBackend {
    fn arch(&self) -> &Arch;

    /// Mean loss `h(θ)` on the batch (no ℓ2).
    fn loss(&mut self, p: &Params, x: &Mat, y: &Mat) -> f64;

    /// (mean loss, mean reported error) — reconstruction error for
    /// autoencoders/regression, 0/1 error for classification.
    fn eval(&mut self, p: &Params, x: &Mat, y: &Mat) -> (f64, f64);

    /// Mean loss + gradient.
    fn grad(&mut self, p: &Params, x: &Mat, y: &Mat) -> (f64, Params);

    /// Mean loss + gradient on the full batch, plus Fisher-factor
    /// statistics computed on the first `stats_rows` rows (the τ₁ subset
    /// of Section 8) with model-sampled targets seeded by `seed`.
    fn grad_and_stats(
        &mut self,
        p: &Params,
        x: &Mat,
        y: &Mat,
        stats_rows: usize,
        seed: u64,
    ) -> (f64, Params, BatchStats);

    /// Pairwise exact-Fisher quadratic forms `dᵢᵀ F dⱼ` over the first
    /// `fvp_rows` rows of `x` (the τ₂ subset), as a `k×k` matrix
    /// (Appendix C trick; no damping terms included).
    fn fvp_quad(&mut self, p: &Params, x: &Mat, fvp_rows: usize, dirs: &[&Params]) -> Mat;

    /// Batch-mean of **squared per-example gradients** projected into
    /// the per-layer Kronecker eigenbases `U_A ⊗ U_G` — the EKFAC
    /// second-moment scales (George et al. 2018) — computed on the
    /// first `rows` rows of `x` with model-sampled targets seeded by
    /// `seed` (Section 5 convention, so the moments estimate the
    /// standard Fisher; `y` is passed for backends that estimate from
    /// empirical gradients instead). Returns one `d_out × (d_in+1)`
    /// matrix per layer.
    ///
    /// Implementations must **not** materialize per-example weight
    /// gradients: the per-example gradient is the rank-1 outer product
    /// `g āᵀ`, so its projection factors into projections of the two
    /// vectors — `O(rows·(a+g)·ag)` total instead of `O(rows·a²g²)`
    /// (see [`Net::grad_sq_in_basis`](crate::nn::Net::grad_sq_in_basis)).
    fn grad_sq_in_basis(
        &mut self,
        p: &Params,
        x: &Mat,
        y: &Mat,
        rows: usize,
        seed: u64,
        bases: &[KronBasis],
    ) -> Vec<Mat>;
}
