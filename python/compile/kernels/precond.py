"""Kronecker preconditioner application (paper Section 4.2):

``U = Ginv @ V @ Ainv`` — the `(A (x) B)^-1 vec(V) = vec(B^-1 V A^-1)`
vec-trick realized as two tiled GEMMs. This is the per-layer hot spot
of applying the block-diagonal inverse Fisher to a gradient.
"""

from . import matmul


def kron_apply(ginv, v, ainv):
    """``ginv @ v @ ainv`` with `v` shaped like a weight matrix."""
    assert ginv.shape[1] == v.shape[0] and v.shape[1] == ainv.shape[0]
    return matmul.matmul(matmul.matmul(ginv, v), ainv)
