//! Kronecker-factored Fisher approximations (paper Sections 3–5).
//!
//! - [`stats`]: per-batch second moments `Ā_{i,j}`, `G_{i,j}` and their
//!   online exponentially-decayed estimates (Section 5).
//! - [`damping`]: the factored Tikhonov technique (Section 6.3) with the
//!   trace-norm `π_i`.
//! - [`blockdiag`]: the block-diagonal inverse `F̌⁻¹` (Section 4.2).
//! - [`tridiag`]: the block-tridiagonal inverse `F̂⁻¹` (Section 4.3),
//!   built from the Ψ/Σ/Λ/Ξ machinery and the Appendix-B structured
//!   inverse.
//! - [`ekfac`]: diagonal rescaling in the Kronecker eigenbasis (George
//!   et al. 2018).
//! - [`kfc`]: Kronecker Factors for Convolution (Grosse & Martens
//!   2016) — patch/spatially-averaged factor semantics for conv
//!   layers, sharing the block-diagonal inverse machinery.
//! - [`kpsvd`]: rank-R Kronecker-sum approximation `Σᵣ Aᵣ⊗Gᵣ` per
//!   block (Koroko et al. 2022), fit by power iteration on the
//!   Van Loan–Pitsianis rearrangement.
//! - [`ikfac`]: iterative inverse maintenance (Chen 2021) — rank-k
//!   Woodbury corrections against factor drift instead of full
//!   refactorization at every `t_inv` boundary.
//! - [`precond`]: the open [`Preconditioner`] seam + registry through
//!   which the optimizer reaches all of the above (and external
//!   structures can plug in).
//! - [`exact`]: dense exact `F` and exact `F̃` over a layer range for
//!   small networks — the substrate behind the Figure 2/3/5/6
//!   structure experiments.
//!
//! # Optional capabilities
//!
//! [`FisherInverse`] and [`Preconditioner`] are deliberately small
//! cores (`apply` / `build`) surrounded by **optional capability
//! pairs**. Each pair has inert defaults so a minimal structure
//! implements nothing extra, and each pair must be implemented
//! *completely or not at all* (pinned by a registry-wide test in
//! [`precond`]):
//!
//! - **Scale re-estimation** (EKFAC): [`FisherInverse::eigenbases`]
//!   (default `None`) + [`FisherInverse::set_scales`] (default
//!   `false`). The optimizer only projects per-example gradients when
//!   `eigenbases()` is `Some`, and only swaps scales in when
//!   `set_scales` accepts them.
//! - **Incremental update** (iterative K-FAC):
//!   [`Preconditioner::incremental`] (default `false`) +
//!   [`FisherInverse::update`] (default
//!   [`UpdateOutcome::NeedsRebuild`]). The optimizer only computes a
//!   stats delta when the preconditioner opts in, and any `update` that
//!   declines falls through to the ordinary full rebuild bit-for-bit.
//! - **Sharded build** (distributed): `Preconditioner::layer_part_len`
//!   (default `None`) + `build_layer_part` (default empty) +
//!   `assemble_parts` (default `None`). `dist::sharded_build` falls
//!   back to a replicated build whenever any layer's part length is
//!   `None`.
//! - **Architecture fencing**: `Preconditioner::check_arch` (default
//!   `Ok`) is the one non-paired option — structures whose factor
//!   semantics are only defined for dense layers (tridiag, EKFAC)
//!   return a descriptive `Err` at construction time instead of
//!   silently degrading on conv nets.

pub mod blockdiag;
pub mod damping;
pub mod ekfac;
pub mod exact;
pub mod ikfac;
pub mod kfc;
pub mod kpsvd;
pub mod precond;
pub mod stats;
pub mod tridiag;

pub use blockdiag::BlockDiagInverse;
pub use ekfac::EkfacInverse;
pub use ikfac::IkfacInverse;
pub use kfc::KfcInverse;
pub use kpsvd::KpsvdInverse;
pub use precond::{PrecondRef, Preconditioner};
pub use stats::{KfacStats, RawStats};
pub use tridiag::TridiagInverse;

use crate::linalg::{KronBasis, Mat};
use crate::nn::Params;

/// Reject NaN/Inf-poisoned factor statistics *before* they reach a
/// factorization, with a message naming the structure and layer (the
/// eigensolver's own guard can only report matrix dimensions). Called
/// by every per-layer inverse build.
pub(crate) fn check_factors_finite(structure: &str, layer: usize, aa: &Mat, gg: &Mat) {
    assert!(
        aa.all_finite(),
        "{structure}: non-finite activation statistics Ā for layer {layer} — \
         refusing to build an inverse from poisoned factors"
    );
    assert!(
        gg.all_finite(),
        "{structure}: non-finite pre-activation-gradient statistics G for layer {layer} — \
         refusing to build an inverse from poisoned factors"
    );
}

/// Result of offering a stats delta to a cached [`FisherInverse`]
/// (the incremental-update capability; see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateOutcome {
    /// The cached inverse absorbed the delta in place; the optimizer
    /// keeps it (with a bumped `inv_epoch`) instead of rebuilding.
    Updated,
    /// The structure cannot (or chooses not to) absorb this delta —
    /// the optimizer must run the ordinary full rebuild. The inverse
    /// MUST be left unmodified when returning this.
    NeedsRebuild,
}

/// A built approximate inverse Fisher: applies `F₀⁻¹` to a
/// gradient-shaped `Params` (i.e. computes the update proposal
/// `Δ = -F₀⁻¹ ∇h` up to sign). Produced by a [`Preconditioner`] at
/// every inverse refresh.
pub trait FisherInverse {
    fn apply(&self, grads: &Params) -> Params;

    /// The per-layer Kronecker eigenbases `(U_A, U_G)` when this
    /// inverse is a diagonal operator in an eigenbasis (EKFAC); `None`
    /// for structures without one (the default). The optimizer hands
    /// these to `ModelBackend::grad_sq_in_basis` (the backend seam) to
    /// project per-example gradients for the amortized scale
    /// re-estimation. Paired with [`set_scales`](Self::set_scales).
    fn eigenbases(&self) -> Option<&[KronBasis]> {
        None
    }

    /// Replace the diagonal scales with externally re-estimated
    /// second moments `scales` (one weight-shaped matrix per layer),
    /// damped by `γ²`. Returns `false` when the structure has no
    /// re-estimable scales (the default no-op). Paired with
    /// [`eigenbases`](Self::eigenbases).
    fn set_scales(&mut self, _scales: &[Mat], _gamma: f64) -> bool {
        false
    }

    /// Absorb a factor-statistics drift `stats_delta` (new EMA minus
    /// the EMA this inverse was built/last rebuilt from) at damping
    /// `gamma`, if the structure supports incremental maintenance.
    /// The default declines, which the optimizer turns into the
    /// ordinary full rebuild — so structures without an incremental
    /// path need no code. Implementations must be deterministic pure
    /// functions of `(built-from state, stats_delta, gamma)` and must
    /// not mutate `self` when declining: checkpoint resume replays the
    /// recorded delta against a freshly rebuilt base and requires
    /// bit-identical results. Paired with
    /// [`Preconditioner::incremental`](precond::Preconditioner::incremental).
    fn update(&mut self, _stats_delta: &RawStats, _gamma: f64) -> UpdateOutcome {
        UpdateOutcome::NeedsRebuild
    }
}
