//! AVX2+FMA micro-kernel and fused BLAS-2 helpers (x86_64 only).
//!
//! The GEMM tile is 4×8: each of the four A rows broadcasts into a
//! `__m256d`, the eight B columns live in two 4-lane vectors, and the
//! eight accumulators plus the three live operands fit comfortably in
//! the sixteen ymm registers. Every function here is compiled with
//! `#[target_feature]` and must only be called after
//! `is_x86_feature_detected!("avx2")`/`("fma")` both passed — the
//! dispatch layer in [`super`] is the sole caller and enforces that.
//!
//! The crate denies `unsafe_op_in_unsafe_fn`, so each kernel body sits
//! in an explicit `unsafe {}` block restating what the caller contract
//! guarantees for the pointer arithmetic inside.

use std::arch::x86_64::*;

/// `acc[r*8 + c] = Σ_p apanel[p*4 + r] · bpanel[p*8 + c]`, overwriting
/// the 4×8 tile. Panels are the zero-padded packed layout of
/// `linalg::gemm` (A in MR-strips, B in NR-strips).
///
/// # Safety
/// Requires avx2+fma at runtime; `apanel`/`bpanel` must be readable for
/// `kc*4` / `kc*8` f64 and `acc` writable for 32 f64.
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn micro_4x8(kc: usize, apanel: *const f64, bpanel: *const f64, acc: *mut f64) {
    // SAFETY: the caller guarantees the panel extents above (packed
    // layout: A advances 4 and B advances 8 f64 per k-step, so after kc
    // steps every read stays inside `kc*4`/`kc*8`), and `acc` holds the
    // full 32-f64 tile the eight stores cover.
    unsafe {
        let mut c00 = _mm256_setzero_pd();
        let mut c01 = _mm256_setzero_pd();
        let mut c10 = _mm256_setzero_pd();
        let mut c11 = _mm256_setzero_pd();
        let mut c20 = _mm256_setzero_pd();
        let mut c21 = _mm256_setzero_pd();
        let mut c30 = _mm256_setzero_pd();
        let mut c31 = _mm256_setzero_pd();
        let mut ap = apanel;
        let mut bp = bpanel;
        for _ in 0..kc {
            let b0 = _mm256_loadu_pd(bp);
            let b1 = _mm256_loadu_pd(bp.add(4));
            let a0 = _mm256_set1_pd(*ap);
            c00 = _mm256_fmadd_pd(a0, b0, c00);
            c01 = _mm256_fmadd_pd(a0, b1, c01);
            let a1 = _mm256_set1_pd(*ap.add(1));
            c10 = _mm256_fmadd_pd(a1, b0, c10);
            c11 = _mm256_fmadd_pd(a1, b1, c11);
            let a2 = _mm256_set1_pd(*ap.add(2));
            c20 = _mm256_fmadd_pd(a2, b0, c20);
            c21 = _mm256_fmadd_pd(a2, b1, c21);
            let a3 = _mm256_set1_pd(*ap.add(3));
            c30 = _mm256_fmadd_pd(a3, b0, c30);
            c31 = _mm256_fmadd_pd(a3, b1, c31);
            ap = ap.add(4);
            bp = bp.add(8);
        }
        _mm256_storeu_pd(acc, c00);
        _mm256_storeu_pd(acc.add(4), c01);
        _mm256_storeu_pd(acc.add(8), c10);
        _mm256_storeu_pd(acc.add(12), c11);
        _mm256_storeu_pd(acc.add(16), c20);
        _mm256_storeu_pd(acc.add(20), c21);
        _mm256_storeu_pd(acc.add(24), c30);
        _mm256_storeu_pd(acc.add(28), c31);
    }
}

/// Fused `aw += Wᵀv`, `av += Vᵀv` in one pass over the rows (see the
/// safe wrapper [`super::fused_tdot2`] for the contract and bounds).
///
/// # Safety
/// Requires avx2+fma; all pointers must cover the extents asserted by
/// the wrapper (`vcol`: `(rows-1)*vstride+1`, `wa`/`xa`:
/// `(rows-1)*ld + t`, `aw`/`av`: `t`).
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn fused_tdot2(
    rows: usize,
    t: usize,
    vcol: *const f64,
    vstride: usize,
    wa: *const f64,
    lda: usize,
    xa: *const f64,
    ldb: usize,
    aw: *mut f64,
    av: *mut f64,
) {
    // SAFETY: the wrapper asserts the extents above, so every indexed
    // access stays in bounds: `vcol` is read at stride `vstride` for
    // `rows` rows, each row of `wa`/`xa` spans `t` f64 from offset
    // `r*ld`, and `aw`/`av` are read-modify-written over `0..t` only.
    unsafe {
        for r in 0..rows {
            let vr = *vcol.add(r * vstride);
            if vr == 0.0 {
                continue;
            }
            let vb = _mm256_set1_pd(vr);
            let wrow = wa.add(r * lda);
            let xrow = xa.add(r * ldb);
            let mut i = 0;
            while i + 4 <= t {
                let awv = _mm256_loadu_pd(aw.add(i));
                let avv = _mm256_loadu_pd(av.add(i));
                let wv = _mm256_loadu_pd(wrow.add(i));
                let xv = _mm256_loadu_pd(xrow.add(i));
                _mm256_storeu_pd(aw.add(i), _mm256_fmadd_pd(vb, wv, awv));
                _mm256_storeu_pd(av.add(i), _mm256_fmadd_pd(vb, xv, avv));
                i += 4;
            }
            while i < t {
                *aw.add(i) += *wrow.add(i) * vr;
                *av.add(i) += *xrow.add(i) * vr;
                i += 1;
            }
        }
    }
}

/// Horizontal sum of a `__m256d`.
///
/// # Safety
/// Caller must run on a CPU with avx2 (always true here: the only
/// callers are `#[target_feature(enable = "avx2,fma")]` kernels, and
/// `inline(always)` folds this into their feature context).
#[inline(always)]
unsafe fn hsum(v: __m256d) -> f64 {
    // SAFETY: value-only lane shuffles/adds — no memory access; the
    // avx2 requirement is discharged by the caller contract above.
    unsafe {
        let hi = _mm256_extractf128_pd::<1>(v);
        let lo = _mm256_castpd256_pd128(v);
        let s = _mm_add_pd(lo, hi);
        let s = _mm_add_sd(s, _mm_unpackhi_pd(s, s));
        _mm_cvtsd_f64(s)
    }
}

/// Fused `p[r·ps] −= X_row·ca + W_row·cb` (see [`super::fused_apply2`]).
///
/// # Safety
/// Requires avx2+fma; pointer extents as asserted by the wrapper.
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn fused_apply2(
    rows: usize,
    t: usize,
    xa: *const f64,
    lda: usize,
    wa: *const f64,
    ldb: usize,
    ca: *const f64,
    cb: *const f64,
    p: *mut f64,
    ps: usize,
) {
    // SAFETY: the wrapper asserts the extents, so each row read of
    // `xa`/`wa` spans `t` f64 from offset `r*ld`, `ca`/`cb` are read
    // over `0..t`, and `p` is written at stride `ps` for `rows` rows.
    unsafe {
        for r in 0..rows {
            let xrow = xa.add(r * lda);
            let wrow = wa.add(r * ldb);
            let mut accx = _mm256_setzero_pd();
            let mut accw = _mm256_setzero_pd();
            let mut i = 0;
            while i + 4 <= t {
                accx = _mm256_fmadd_pd(
                    _mm256_loadu_pd(xrow.add(i)),
                    _mm256_loadu_pd(ca.add(i)),
                    accx,
                );
                accw = _mm256_fmadd_pd(
                    _mm256_loadu_pd(wrow.add(i)),
                    _mm256_loadu_pd(cb.add(i)),
                    accw,
                );
                i += 4;
            }
            let mut acc = hsum(_mm256_add_pd(accx, accw));
            while i < t {
                acc += *xrow.add(i) * *ca.add(i) + *wrow.add(i) * *cb.add(i);
                i += 1;
            }
            *p.add(r * ps) -= acc;
        }
    }
}
