//! Training coordinator: CLI parsing, the [`TrainSession`] builder
//! that composes datasets, backends and optimizers, and versioned
//! checkpoint save/resume.

pub mod checkpoint;
pub mod cli;
pub mod session;

pub use cli::Args;
pub use session::{log_to_csv, Event, LogRow, Problem, TrainReport, TrainSession};
