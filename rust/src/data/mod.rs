//! Synthetic dataset generators.
//!
//! The paper evaluates on MNIST, CURVES and FACES. None of those files
//! ship with this repository, so we build procedural substitutes that
//! exercise the identical code paths (see DESIGN.md §Substitutions):
//!
//! - [`mnist_like`]: stroke-template digit glyphs with elastic jitter,
//!   at 16×16 (Fig 2 network) or 28×28 (autoencoder), intensities in
//!   [0,1] — for sigmoid-CE autoencoding and 10-way classification.
//! - [`curves_like`]: random cubic Bézier curves rendered at 28×28 —
//!   the original CURVES set is itself synthetic curve images.
//! - [`faces_like`]: low-rank Gaussian "eigenface" mixtures (625-dim,
//!   real-valued, standardized) — for the squared-error autoencoder.

pub mod curves_like;
pub mod dataset;
pub mod faces_like;
pub mod mnist_like;

pub use dataset::Dataset;

use crate::linalg::Mat;

/// Render an anti-aliased thick line segment onto a `side × side` canvas
/// stored row-major in `img`. Coordinates in [0,1].
pub(crate) fn draw_segment(
    img: &mut [f64],
    side: usize,
    x0: f64,
    y0: f64,
    x1: f64,
    y1: f64,
    thickness: f64,
) {
    let s = side as f64;
    let (px0, py0) = (x0 * (s - 1.0), y0 * (s - 1.0));
    let (px1, py1) = (x1 * (s - 1.0), y1 * (s - 1.0));
    let dx = px1 - px0;
    let dy = py1 - py0;
    let len2 = (dx * dx + dy * dy).max(1e-12);
    let rad = thickness * s;
    let (lo_x, hi_x) = (
        (px0.min(px1) - rad).floor().max(0.0) as usize,
        (px0.max(px1) + rad).ceil().min(s - 1.0) as usize,
    );
    let (lo_y, hi_y) = (
        (py0.min(py1) - rad).floor().max(0.0) as usize,
        (py0.max(py1) + rad).ceil().min(s - 1.0) as usize,
    );
    for gy in lo_y..=hi_y {
        for gx in lo_x..=hi_x {
            let (fx, fy) = (gx as f64, gy as f64);
            // distance from pixel to segment
            let t = (((fx - px0) * dx + (fy - py0) * dy) / len2).clamp(0.0, 1.0);
            let (cx, cy) = (px0 + t * dx, py0 + t * dy);
            let dist = ((fx - cx).powi(2) + (fy - cy).powi(2)).sqrt();
            let v = (1.0 - (dist / rad)).clamp(0.0, 1.0);
            let idx = gy * side + gx;
            img[idx] = img[idx].max(v * v * (3.0 - 2.0 * v)); // smoothstep
        }
    }
}

/// 3×3 binomial blur (in place via copy).
pub(crate) fn blur(img: &Mat) -> Mat {
    let side = (img.cols as f64).sqrt() as usize;
    let mut out = img.clone();
    for r in 0..img.rows {
        let src = img.row(r);
        let dst = out.row_mut(r);
        for y in 0..side {
            for x in 0..side {
                let mut acc = 0.0;
                let mut wsum = 0.0;
                for dy in -1i64..=1 {
                    for dx in -1i64..=1 {
                        let (nx, ny) = (x as i64 + dx, y as i64 + dy);
                        if nx < 0 || ny < 0 || nx >= side as i64 || ny >= side as i64 {
                            continue;
                        }
                        let w = match (dx.abs(), dy.abs()) {
                            (0, 0) => 4.0,
                            (1, 0) | (0, 1) => 2.0,
                            _ => 1.0,
                        };
                        acc += w * src[(ny as usize) * side + nx as usize];
                        wsum += w;
                    }
                }
                dst[y * side + x] = acc / wsum;
            }
        }
    }
    out
}
