//! Loom harness crate: re-exports the *production* `par` module source
//! (included by path, not copied) so `tests/loom_pool.rs` can model-check
//! the pool, latch, job-handle, and pending-build protocols exactly as
//! the `kfac` crate compiles them. Build with `RUSTFLAGS="--cfg loom"` —
//! without the cfg the tests are empty and the shim resolves to
//! `std::sync`, which loom cannot explore.

#[path = "../../../rust/src/par.rs"]
pub mod par;
