//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects, while the text parser reassigns ids and round-trips
//! cleanly. Programs are compiled once at startup and cached; the
//! training loop then only does literal transfer + execute — Python is
//! never on the request path.
//!
//! The artifact manifest ([`artifact`]) is dependency-free and always
//! compiled; the executor ([`exec`]) needs the `xla` (xla-rs) crate and
//! is gated behind the off-by-default `pjrt` cargo feature.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod exec;

pub use artifact::{ArchManifest, Manifest};
#[cfg(feature = "pjrt")]
pub use exec::{literal_scalar_f64, literal_to_mat, mat_to_literal, Program};

/// Error type for the runtime layer (artifact loading / program
/// execution). Plain string payload so the default build stays
/// dependency-free; `{:#}` formatting (anyhow style) degrades to the
/// same message.
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Runtime-layer result alias.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Shorthand constructor used across the runtime modules.
pub(crate) fn rt_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}
