//! Baseline: SGD with Nesterov's Accelerated Gradient, tuned as in
//! Sutskever et al. (2013) — the baseline the paper compares against
//! (Section 13). Implements the [`Optimizer`] trait, including state
//! snapshot/restore for checkpointing.
//!
//! Update: `v ← μ_t v − ε ∇h(θ + μ_t v)`, `θ ← θ + v`, with the
//! momentum schedule `μ_t = min(1 − 2^{−1−log₂(⌊t/250⌋+1)}, μ_max)`.

use crate::backend::ModelBackend;
use crate::linalg::Mat;
use crate::nn::Params;
use crate::optim::optimizer::{check_mat_shapes, OptState, Optimizer, StepInfo};

#[derive(Clone, Debug)]
pub struct SgdConfig {
    /// Learning rate ε.
    pub lr: f64,
    /// Momentum cap μ_max (Sutskever et al. grid: {0.9, 0.99, 0.995, 0.999}).
    pub mu_max: f64,
    /// Use the increasing μ schedule (else constant μ_max).
    pub mu_schedule: bool,
    /// ℓ2 coefficient η.
    pub eta: f64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { lr: 0.01, mu_max: 0.99, mu_schedule: true, eta: 1e-5 }
    }
}

/// SGD + NAG state.
pub struct Sgd {
    pub cfg: SgdConfig,
    v: Option<Params>,
    t: usize,
}

impl Sgd {
    pub fn new(cfg: SgdConfig) -> Sgd {
        Sgd { cfg, v: None, t: 0 }
    }

    /// Momentum coefficient at step `t` (Sutskever et al. eqn. 2.18-style
    /// schedule).
    pub fn mu_at(&self, t: usize) -> f64 {
        if !self.cfg.mu_schedule {
            return self.cfg.mu_max;
        }
        let base = (t / 250 + 1) as f64;
        let mu = 1.0 - 2.0_f64.powf(-1.0 - base.log2());
        mu.min(self.cfg.mu_max)
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &str {
        "sgd"
    }

    /// One NAG step; `loss` is the (regularized) objective at the
    /// lookahead point.
    fn step(
        &mut self,
        backend: &mut dyn ModelBackend,
        params: &mut Params,
        x: &Mat,
        y: &Mat,
    ) -> StepInfo {
        self.t += 1;
        let mu = self.mu_at(self.t);
        let v = self.v.get_or_insert_with(|| params.zeros_like());
        // lookahead point θ + μv
        let mut look = params.clone();
        look.axpy(mu, v);
        let (loss_raw, mut grad) = backend.grad(&look, x, y);
        grad.axpy(self.cfg.eta, &look);
        let h = loss_raw + 0.5 * self.cfg.eta * look.norm_sq();
        // v ← μv − ε g ; θ ← θ + v
        let mut vnew = v.scale(mu);
        vnew.axpy(-self.cfg.lr, &grad);
        params.axpy(1.0, &vnew);
        let delta_norm = vnew.norm_sq().sqrt();
        *v = vnew;
        StepInfo {
            loss: h,
            mu: Some(mu),
            delta_norm: Some(delta_norm),
            ..Default::default()
        }
    }

    fn state(&self) -> OptState {
        let mut st = OptState::new("sgd");
        st.set_scalar("t", self.t as f64);
        if let Some(v) = &self.v {
            st.set_mats("v", v.0.clone());
        }
        st
    }

    /// Note: `Sgd` learns its buffer shapes lazily (from the first
    /// `step`), so a fresh optimizer can only validate `v` against an
    /// existing buffer; on the checkpoint-resume path the coordinator
    /// has already validated the checkpoint's parameters against the
    /// architecture, which pins the same shapes.
    fn load_state(&mut self, st: &OptState) -> Result<(), String> {
        if st.kind != "sgd" {
            return Err(format!("sgd: cannot load '{}' optimizer state", st.kind));
        }
        self.t = st.require_scalar("t")? as usize;
        self.v = match st.mats("v") {
            Some(v) => {
                if let Some(cur) = &self.v {
                    check_mat_shapes("v", v, &cur.0)?;
                }
                Some(Params(v.to_vec()))
            }
            None => None,
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{ModelBackend, RustBackend};
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    #[test]
    fn mu_schedule_increases_to_cap() {
        let sgd = Sgd::new(SgdConfig { mu_max: 0.99, ..Default::default() });
        assert!(sgd.mu_at(1) <= sgd.mu_at(251));
        assert!(sgd.mu_at(251) <= sgd.mu_at(2501));
        assert!(sgd.mu_at(1_000_000) <= 0.99 + 1e-12);
        assert!((sgd.mu_at(1) - 0.5).abs() < 1e-12, "t<250 gives μ=1-2^-1=0.5");
    }

    fn toy() -> (Arch, Params, Mat, Mat) {
        let arch = Arch::new(vec![5, 4, 3], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
        let mut rng = Rng::new(1);
        let params = arch.sparse_init(&mut rng);
        let x = Mat::randn(64, 5, 1.0, &mut rng);
        let mut y = Mat::zeros(64, 3);
        for r in 0..64 {
            y.set(r, if x.at(r, 0) > 0.0 { 0 } else { 2 }, 1.0);
        }
        (arch, params, x, y)
    }

    #[test]
    fn sgd_decreases_loss_on_toy_problem() {
        let (arch, mut params, x, y) = toy();
        let mut be = RustBackend::new(arch.clone());
        let first = be.loss(&params, &x, &y);
        let mut sgd = Sgd::new(SgdConfig { lr: 0.1, ..Default::default() });
        for _ in 0..200 {
            let info = sgd.step(&mut be, &mut params, &x, &y);
            assert!(info.mu.unwrap() > 0.0);
        }
        let last = be.loss(&params, &x, &y);
        assert!(last < first * 0.5, "first={first} last={last}");
    }

    #[test]
    fn state_roundtrip_resumes_identically() {
        let (arch, mut pa, x, y) = toy();
        let mut be = RustBackend::new(arch.clone());
        let mut a = Sgd::new(SgdConfig { lr: 0.05, ..Default::default() });
        for _ in 0..5 {
            a.step(&mut be, &mut pa, &x, &y);
        }
        let snap = a.state();
        let mut pb = pa.clone();
        let mut b = Sgd::new(SgdConfig { lr: 0.05, ..Default::default() });
        b.load_state(&snap).unwrap();
        for s in 0..5 {
            let ia = a.step(&mut be, &mut pa, &x, &y);
            let ib = b.step(&mut be, &mut pb, &x, &y);
            assert_eq!(ia.loss.to_bits(), ib.loss.to_bits(), "diverged at step {s}");
            assert!(pa == pb, "params diverged at step {s}");
        }
        assert!(b.load_state(&OptState::new("kfac")).is_err());
    }
}
