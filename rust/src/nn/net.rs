//! Forward / backward / linearized-forward passes (paper Algorithm 1 and
//! Appendix C), batched over mini-batches.

use super::{Arch, Layer, Params};
use crate::linalg::{pack, KronBasis, Mat};
use crate::rng::Rng;

/// Cached forward-pass quantities for a mini-batch of `m` cases.
///
/// `abars[i]` is the layer's homogenized GEMM input: for a dense layer
/// `Ā = [A, 1]` with one case per row (`m` rows); for a conv layer the
/// im2col patch matrix (`m·P` rows, one receptive-field patch per
/// output position, homogeneous coordinate last). `ss[i]` holds the
/// layer-local pre-activations (`[m, d]` dense, `[m·P, out_c]` conv),
/// so `z = ss[ℓ-1]` are the output natural parameters (the last layer
/// is always dense).
pub struct Fwd {
    /// Mini-batch size (`abars[0].rows` only when layer 0 is dense).
    pub m: usize,
    pub abars: Vec<Mat>,
    pub ss: Vec<Mat>,
}

impl Fwd {
    /// Output natural parameters `z = s_ℓ`.
    pub fn z(&self) -> &Mat {
        self.ss.last().expect("empty network")
    }

    /// Weight-sharing positions `P` of layer `i` in this batch.
    pub fn positions(&self, i: usize) -> usize {
        self.ss[i].rows / self.m
    }
}

impl Layer {
    /// Homogenize a flat `[m, in_dim]` input into the layer's GEMM
    /// operand: dense appends a `homog` column; conv extracts im2col
    /// patches (`[m·P, K+1]`) with `homog` in the trailing column.
    /// `homog` is 1 for activations and 0 for tangents (the derivative
    /// of the constant coordinate).
    fn capture(&self, x: &Mat, homog: f64) -> Mat {
        match self {
            Layer::Dense { .. } => {
                let mut xb = Mat::zeros(x.rows, x.cols + 1);
                xb.set_block(0, 0, x);
                for r in 0..xb.rows {
                    xb.set(r, x.cols, homog);
                }
                xb
            }
            Layer::Conv2d { shape, .. } => pack::im2col(x, *shape, homog),
        }
    }

    /// Reshape a layer-local activation (`[m·P, out_c]`) to the flat
    /// `[m, P·out_c]` boundary matrix — free in NHWC. Identity for
    /// dense layers.
    fn flatten_out(&self, a: Mat, m: usize) -> Mat {
        match self {
            Layer::Dense { .. } => a,
            Layer::Conv2d { .. } => {
                let cols = a.rows / m * a.cols;
                Mat::from_vec(m, cols, a.data)
            }
        }
    }

    /// Inverse of [`flatten_out`](Self::flatten_out): flat boundary
    /// `[m, P·out_c]` to the layer-local shape.
    fn localize(&self, a: Mat, m: usize) -> Mat {
        match self {
            Layer::Dense { .. } => a,
            Layer::Conv2d { shape, .. } => {
                let p = shape.positions();
                let cols = a.cols / p;
                Mat::from_vec(m * p, cols, a.data)
            }
        }
    }

    /// Gradient w.r.t. the layer's flat input, from the layer-local
    /// pre-activation gradient `g` and the layer's weight `w` (bias
    /// column dropped): dense `g·W`; conv maps to patch space and
    /// scatter-adds through the im2col adjoint.
    fn input_grad(&self, g: &Mat, w: &Mat, m: usize) -> Mat {
        let w_nob = w.drop_last_col();
        match self {
            Layer::Dense { .. } => g.matmul(&w_nob),
            Layer::Conv2d { shape, .. } => {
                let dpatch = g.matmul(&w_nob); // [m·P, K]
                pack::col2im_acc(&dpatch, *shape, m)
            }
        }
    }
}

/// Stateless forward/backward engine for an [`Arch`].
#[derive(Clone)]
pub struct Net {
    pub arch: Arch,
}

impl Net {
    pub fn new(arch: Arch) -> Net {
        Net { arch }
    }

    /// Forward pass (Algorithm 1, forward half). `x` is `[m, d₀]`.
    pub fn forward(&self, params: &Params, x: &Mat) -> Fwd {
        let l = self.arch.num_layers();
        assert_eq!(params.num_layers(), l);
        assert_eq!(x.cols, self.arch.widths[0], "input width mismatch");
        let m = x.rows;
        let mut abars = Vec::with_capacity(l);
        let mut ss = Vec::with_capacity(l);
        let mut flat: Option<Mat> = None; // layer input at the flat boundary
        for i in 0..l {
            let layer = &self.arch.layers[i];
            let abar = layer.capture(flat.as_ref().unwrap_or(x), 1.0);
            let s = abar.matmul_nt(&params.0[i]); // layer-local pre-activations
            if i + 1 < l {
                let act = layer.act();
                let a = Mat::from_fn(s.rows, s.cols, |r, c| act.apply(s.at(r, c)));
                flat = Some(layer.flatten_out(a, m));
            }
            abars.push(abar);
            ss.push(s);
        }
        Fwd { m, abars, ss }
    }

    /// Backward pass from per-case output derivatives `dz` (Algorithm 1,
    /// backward half). Returns the layer-local pre-activation
    /// derivatives `gs[i] = G_i` (`[m, d_{i+1}]` dense, `[m·P, out_c]`
    /// conv; *not* scaled by 1/m).
    pub fn backward(&self, params: &Params, fwd: &Fwd, dz: &Mat) -> Vec<Mat> {
        let l = self.arch.num_layers();
        let mut gs = vec![Mat::zeros(0, 0); l];
        gs[l - 1] = dz.clone();
        for i in (0..l - 1).rev() {
            // dA_i (flat) = layer i+1's gradient w.r.t. its input.
            let da_flat = self.arch.layers[i + 1].input_grad(&gs[i + 1], &params.0[i + 1], fwd.m);
            let da = self.arch.layers[i].localize(da_flat, fwd.m);
            let act = self.arch.act(i);
            let s = &fwd.ss[i];
            // g_i = dA_i ⊙ φ'(s_i); recompute a from s for the derivative.
            gs[i] = Mat::from_fn(da.rows, da.cols, |r, c| {
                let sv = s.at(r, c);
                da.at(r, c) * act.deriv(sv, act.apply(sv))
            });
        }
        gs
    }

    /// Mean gradient `∇_W h` from cached activations and `gs`:
    /// `DW_i = (1/m) G_iᵀ Ā_{i-1}` — for conv layers the row index
    /// runs over cases *and* positions, summing the weight-shared
    /// contributions exactly as the chain rule requires.
    pub fn grads_from(&self, fwd: &Fwd, gs: &[Mat]) -> Params {
        let m = fwd.m as f64;
        Params(
            gs.iter()
                .zip(fwd.abars.iter())
                .map(|(g, abar)| g.matmul_tn(abar).scale(1.0 / m))
                .collect(),
        )
    }

    /// Mean loss + gradient on a labelled mini-batch (no ℓ2 term).
    pub fn loss_and_grad(&self, params: &Params, x: &Mat, y: &Mat) -> (f64, Params) {
        let fwd = self.forward(params, x);
        let loss = self.arch.loss.loss(fwd.z(), y);
        let dz = self.arch.loss.dz(fwd.z(), y);
        let gs = self.backward(params, &fwd, &dz);
        (loss, self.grads_from(&fwd, &gs))
    }

    /// Mean loss only.
    pub fn loss(&self, params: &Params, x: &Mat, y: &Mat) -> f64 {
        let fwd = self.forward(params, x);
        self.arch.loss.loss(fwd.z(), y)
    }

    /// Backward pass with targets **sampled from the model's predictive
    /// distribution** (Section 5) — the `gs` this produces are the ones
    /// whose second moments estimate the true-Fisher `G_{i,j}`.
    pub fn sampled_backward(&self, params: &Params, fwd: &Fwd, rng: &mut Rng) -> Vec<Mat> {
        let y = self.arch.loss.sample(fwd.z(), rng);
        let dz = self.arch.loss.dz(fwd.z(), &y);
        self.backward(params, fwd, &dz)
    }

    /// Batch-mean of **squared per-example gradients** projected into
    /// per-layer Kronecker bases (the EKFAC second-moment scales of
    /// George et al. 2018). The per-example weight gradient of layer
    /// `i` for case `n` is the rank-1 outer product
    /// `DW_n = g_n ā_{i-1,n}ᵀ`, so its basis change factors as
    /// `U_Gᵀ DW_n U_A = (U_Gᵀ g_n)(ā_nᵀ U_A)` — the per-example
    /// *vectors* are projected first and only then squared, costing
    /// `O(m·(a+g)·ag)` in total instead of the `O(m·a²g²)` of
    /// materializing `m` weight-shaped per-example gradients:
    ///
    /// `S_i[p,q] = (1/m) Σ_n (G_i U_G)²_{n,p} (Ā_{i-1} U_A)²_{n,q}`.
    ///
    /// `gs` must *not* be scaled by 1/m (the convention of
    /// [`Net::backward`]); one `d_out × (d_in+1)` matrix per layer.
    /// For a conv layer the per-example gradient is a rank-`P` sum over
    /// positions, `DW_n = Σ_t g_{n,t} ā_{n,t}ᵀ`, so the projected square
    /// no longer factors into row-wise products: the `P`-row blocks are
    /// projected, contracted per example, and only then squared.
    pub fn grad_sq_in_basis(&self, fwd: &Fwd, gs: &[Mat], bases: &[KronBasis]) -> Vec<Mat> {
        assert_eq!(gs.len(), bases.len(), "grad_sq_in_basis: one basis per layer");
        let m = fwd.m;
        gs.iter()
            .zip(fwd.abars.iter())
            .zip(bases.iter())
            .map(|((g, abar), b)| {
                let gt = g.matmul(&b.ug); // row n (or n·P+t) = (U_Gᵀ g)ᵀ
                let at = abar.matmul(&b.ua); // row n (or n·P+t) = (U_Aᵀ ā)ᵀ
                if g.rows == m {
                    // dense: rank-1 per example, projection-first trick
                    gt.hadamard(&gt).matmul_tn(&at.hadamard(&at)).scale(1.0 / m as f64)
                } else {
                    let p = g.rows / m;
                    let mut acc = Mat::zeros(gt.cols, at.cols);
                    for n in 0..m {
                        let gb = gt.block(n * p, (n + 1) * p, 0, gt.cols);
                        let ab = at.block(n * p, (n + 1) * p, 0, at.cols);
                        let dw = gb.matmul_tn(&ab); // projected DW_n
                        acc.axpy(1.0 / m as f64, &dw.hadamard(&dw));
                    }
                    acc
                }
            })
            .collect()
    }

    /// Linearized forward pass (the `Jv` of Appendix C): directional
    /// derivative of `z` w.r.t. parameters along `v`, evaluated with the
    /// activations cached in `fwd`. Returns `Jz` of shape `[m, d_ℓ]`.
    pub fn jvp(&self, params: &Params, fwd: &Fwd, v: &Params) -> Mat {
        let l = self.arch.num_layers();
        let m = fwd.m;
        // jabar: derivative of Ā_i. The input's derivative is zero —
        // and both homogenization and patch extraction are linear, so
        // the tangent flows through `capture` with homog = 0 (the
        // constant coordinate's derivative).
        let mut jabar = Mat::zeros(fwd.abars[0].rows, fwd.abars[0].cols);
        let mut jz = Mat::zeros(0, 0);
        for i in 0..l {
            // js = Ā_{i-1} V_iᵀ + JĀ_{i-1} W_iᵀ
            let mut js = fwd.abars[i].matmul_nt(&v.0[i]);
            let prop = jabar.matmul_nt(&params.0[i]);
            js.axpy(1.0, &prop);
            if i + 1 < l {
                let layer = &self.arch.layers[i];
                let act = layer.act();
                let s = &fwd.ss[i];
                let ja = Mat::from_fn(js.rows, js.cols, |r, c| {
                    let sv = s.at(r, c);
                    js.at(r, c) * act.deriv(sv, act.apply(sv))
                });
                let ja_flat = layer.flatten_out(ja, m);
                jabar = self.arch.layers[i + 1].capture(&ja_flat, 0.0);
            } else {
                jz = js;
            }
        }
        jz
    }

    /// All pairwise exact-Fisher quadratic forms `dᵢᵀ F dⱼ` over the
    /// mini-batch `x` (mean over cases), computed with the Appendix-C
    /// trick: one linearized forward pass per direction, then cheap
    /// `F_R`-weighted inner products. Returns a `k × k` symmetric matrix.
    pub fn fvp_quad(&self, params: &Params, x: &Mat, dirs: &[&Params]) -> Mat {
        let fwd = self.forward(params, x);
        let m = x.rows as f64;
        let jzs: Vec<Mat> = dirs.iter().map(|d| self.jvp(params, &fwd, d)).collect();
        let k = dirs.len();
        let mut q = Mat::zeros(k, k);
        for i in 0..k {
            for j in i..k {
                let v = self.arch.loss.fr_quad(fwd.z(), &jzs[i], &jzs[j]) / m;
                q.set(i, j, v);
                q.set(j, i, v);
            }
        }
        q
    }

    /// Exact Fisher–vector product `F v` over the mini-batch (mean),
    /// via `Jᵀ F_R J v`. Used in tests and the exact-Fisher experiments.
    pub fn fvp(&self, params: &Params, x: &Mat, v: &Params) -> Params {
        let fwd = self.forward(params, x);
        let jz = self.jvp(params, &fwd, v);
        let frjz = self.arch.loss.fr_apply(fwd.z(), &jz);
        let gs = self.backward(params, &fwd, &frjz);
        self.grads_from(&fwd, &gs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Act, LossKind};

    fn tiny_arch(loss: LossKind) -> Arch {
        Arch::new(vec![5, 4, 3], vec![Act::Tanh, Act::Identity], loss)
    }

    /// conv(5×5×2, 3×3, stride 2, pad 1) → 3×3×3 = 27 → dense 4.
    fn tiny_conv_arch(loss: LossKind) -> Arch {
        let shape = pack::ConvShape { in_h: 5, in_w: 5, in_c: 2, kh: 3, kw: 3, stride: 2, pad: 1 };
        Arch::from_layers(
            vec![
                Layer::Conv2d { shape, out_c: 3, act: Act::Tanh },
                Layer::Dense { d_in: 27, d_out: 4, act: Act::Identity },
            ],
            loss,
        )
    }

    /// Two stacked conv layers (exercises conv→conv propagation):
    /// conv(6×6×1) → 6×6×2 → conv(6×6×2, stride 2) → 2×2×3 → dense 3.
    fn deep_conv_arch(loss: LossKind) -> Arch {
        let s1 = pack::ConvShape { in_h: 6, in_w: 6, in_c: 1, kh: 3, kw: 3, stride: 1, pad: 1 };
        let s2 = pack::ConvShape { in_h: 6, in_w: 6, in_c: 2, kh: 3, kw: 3, stride: 2, pad: 0 };
        Arch::from_layers(
            vec![
                Layer::Conv2d { shape: s1, out_c: 2, act: Act::Tanh },
                Layer::Conv2d { shape: s2, out_c: 3, act: Act::Relu },
                Layer::Dense { d_in: 12, d_out: 3, act: Act::Identity },
            ],
            loss,
        )
    }

    fn make_targets(loss: LossKind, rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        match loss {
            LossKind::SoftmaxCe => {
                let mut y = Mat::zeros(rows, cols);
                for r in 0..rows {
                    let k = rng.below(cols);
                    y.set(r, k, 1.0);
                }
                y
            }
            LossKind::SigmoidCe => Mat::from_fn(rows, cols, |_, _| rng.bernoulli(0.5)),
            LossKind::SquaredError => Mat::randn(rows, cols, 1.0, rng),
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        for loss in [LossKind::SigmoidCe, LossKind::SoftmaxCe, LossKind::SquaredError] {
            let arch = tiny_arch(loss);
            let net = Net::new(arch.clone());
            let mut rng = Rng::new(1);
            let params = arch.glorot_init(&mut rng);
            let x = Mat::randn(7, 5, 1.0, &mut rng);
            let y = make_targets(loss, 7, 3, &mut rng);
            let (_, grad) = net.loss_and_grad(&params, &x, &y);
            let eps = 1e-6;
            for li in 0..arch.num_layers() {
                for idx in [0usize, 3, 7] {
                    let (r, c) = (idx / params.0[li].cols, idx % params.0[li].cols);
                    let mut pp = params.clone();
                    pp.0[li].set(r, c, params.0[li].at(r, c) + eps);
                    let mut pm = params.clone();
                    pm.0[li].set(r, c, params.0[li].at(r, c) - eps);
                    let fd = (net.loss(&pp, &x, &y) - net.loss(&pm, &x, &y)) / (2.0 * eps);
                    let g = grad.0[li].at(r, c);
                    assert!(
                        (fd - g).abs() < 1e-5 * (1.0 + g.abs()),
                        "{loss:?} l{li} fd={fd} g={g}"
                    );
                }
            }
        }
    }

    #[test]
    fn jvp_matches_finite_difference() {
        let arch = tiny_arch(LossKind::SquaredError);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(2);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(4, 5, 1.0, &mut rng);
        let v =
            Params(params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
        let fwd = net.forward(&params, &x);
        let jz = net.jvp(&params, &fwd, &v);
        let eps = 1e-6;
        let mut pp = params.clone();
        pp.axpy(eps, &v);
        let mut pm = params.clone();
        pm.axpy(-eps, &v);
        let zp = net.forward(&pp, &x);
        let zm = net.forward(&pm, &x);
        let fd = zp.z().sub(zm.z()).scale(1.0 / (2.0 * eps));
        assert!(fd.sub(&jz).max_abs() < 1e-6, "err={}", fd.sub(&jz).max_abs());
    }

    #[test]
    fn fvp_quad_consistent_with_fvp() {
        for loss in [LossKind::SigmoidCe, LossKind::SoftmaxCe, LossKind::SquaredError] {
            let arch = tiny_arch(loss);
            let net = Net::new(arch.clone());
            let mut rng = Rng::new(3);
            let params = arch.glorot_init(&mut rng);
            let x = Mat::randn(6, 5, 1.0, &mut rng);
            let mk = |rng: &mut Rng| {
                Params(params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, rng)).collect())
            };
            let u = mk(&mut rng);
            let v = mk(&mut rng);
            let q = net.fvp_quad(&params, &x, &[&u, &v]);
            let fu = net.fvp(&params, &x, &u);
            let fv = net.fvp(&params, &x, &v);
            assert!((q.at(0, 0) - u.dot(&fu)).abs() < 1e-9, "{loss:?}");
            assert!((q.at(0, 1) - u.dot(&fv)).abs() < 1e-9, "{loss:?}");
            assert!((q.at(1, 1) - v.dot(&fv)).abs() < 1e-9, "{loss:?}");
            // symmetry of F
            assert!((u.dot(&fv) - v.dot(&fu)).abs() < 1e-9, "{loss:?}");
        }
    }

    #[test]
    fn fisher_is_psd_along_random_directions() {
        let arch = tiny_arch(LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(4);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(5, 5, 1.0, &mut rng);
        for _ in 0..10 {
            let v = Params(
                params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect(),
            );
            let q = net.fvp_quad(&params, &x, &[&v]);
            assert!(q.at(0, 0) >= -1e-12);
        }
    }

    #[test]
    fn grad_sq_in_basis_matches_per_example_outer_products() {
        // Dense check of the projection-first trick: materialize every
        // per-example weight gradient DW_n = g_n ā_nᵀ, project it as a
        // matrix, square, and average. The identity is pure algebra, so
        // it must hold for *arbitrary* (not just orthogonal) bases.
        let arch = tiny_arch(LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(6);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(5, 5, 1.0, &mut rng);
        let fwd = net.forward(&params, &x);
        let gs = net.sampled_backward(&params, &fwd, &mut rng);
        let bases: Vec<KronBasis> = (0..arch.num_layers())
            .map(|i| {
                let (r, c) = arch.weight_shape(i);
                KronBasis {
                    ua: Mat::randn(c, c, 1.0, &mut rng),
                    ug: Mat::randn(r, r, 1.0, &mut rng),
                }
            })
            .collect();
        let got = net.grad_sq_in_basis(&fwd, &gs, &bases);
        let m = x.rows;
        for i in 0..arch.num_layers() {
            let (r, c) = arch.weight_shape(i);
            let mut want = Mat::zeros(r, c);
            for n in 0..m {
                let dw = Mat::from_fn(r, c, |p, q| gs[i].at(n, p) * fwd.abars[i].at(n, q));
                let proj = bases[i].ug.matmul_tn(&dw).matmul(&bases[i].ua);
                want.axpy(1.0 / m as f64, &proj.hadamard(&proj));
            }
            let scale = want.max_abs().max(1e-12);
            let err = got[i].sub(&want).max_abs() / scale;
            assert!(err < 1e-12, "layer {i}: rel err {err}");
        }
    }

    #[test]
    fn conv_gradient_matches_finite_difference() {
        for arch in [
            tiny_conv_arch(LossKind::SoftmaxCe),
            tiny_conv_arch(LossKind::SquaredError),
            deep_conv_arch(LossKind::SoftmaxCe),
        ] {
            let net = Net::new(arch.clone());
            let mut rng = Rng::new(21);
            let params = arch.glorot_init(&mut rng);
            let m = 3;
            let x = Mat::randn(m, arch.widths[0], 1.0, &mut rng);
            let y = make_targets(arch.loss, m, *arch.widths.last().unwrap(), &mut rng);
            let (_, grad) = net.loss_and_grad(&params, &x, &y);
            let eps = 1e-6;
            for li in 0..arch.num_layers() {
                let len = params.0[li].rows * params.0[li].cols;
                for idx in [0usize, 3, 7, len - 1] {
                    let (r, c) = (idx / params.0[li].cols, idx % params.0[li].cols);
                    let mut pp = params.clone();
                    pp.0[li].set(r, c, params.0[li].at(r, c) + eps);
                    let mut pm = params.clone();
                    pm.0[li].set(r, c, params.0[li].at(r, c) - eps);
                    let fd = (net.loss(&pp, &x, &y) - net.loss(&pm, &x, &y)) / (2.0 * eps);
                    let g = grad.0[li].at(r, c);
                    assert!(
                        (fd - g).abs() < 1e-5 * (1.0 + g.abs()),
                        "conv l{li} ({r},{c}) fd={fd} g={g}"
                    );
                }
            }
        }
    }

    #[test]
    fn conv_jvp_matches_finite_difference() {
        for arch in [tiny_conv_arch(LossKind::SquaredError), deep_conv_arch(LossKind::SquaredError)]
        {
            let net = Net::new(arch.clone());
            let mut rng = Rng::new(22);
            let params = arch.glorot_init(&mut rng);
            let x = Mat::randn(3, arch.widths[0], 1.0, &mut rng);
            let v = Params(
                params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect(),
            );
            let fwd = net.forward(&params, &x);
            let jz = net.jvp(&params, &fwd, &v);
            let eps = 1e-6;
            let mut pp = params.clone();
            pp.axpy(eps, &v);
            let mut pm = params.clone();
            pm.axpy(-eps, &v);
            let zp = net.forward(&pp, &x);
            let zm = net.forward(&pm, &x);
            let fd = zp.z().sub(zm.z()).scale(1.0 / (2.0 * eps));
            assert!(fd.sub(&jz).max_abs() < 1e-6, "err={}", fd.sub(&jz).max_abs());
        }
    }

    #[test]
    fn conv_fvp_quad_consistent_with_fvp() {
        let arch = tiny_conv_arch(LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(23);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(4, arch.widths[0], 1.0, &mut rng);
        let mk = |rng: &mut Rng| {
            Params(params.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, rng)).collect())
        };
        let u = mk(&mut rng);
        let v = mk(&mut rng);
        let q = net.fvp_quad(&params, &x, &[&u, &v]);
        let fu = net.fvp(&params, &x, &u);
        let fv = net.fvp(&params, &x, &v);
        assert!((q.at(0, 0) - u.dot(&fu)).abs() < 1e-9);
        assert!((q.at(0, 1) - u.dot(&fv)).abs() < 1e-9);
        assert!((q.at(1, 1) - v.dot(&fv)).abs() < 1e-9);
        assert!((u.dot(&fv) - v.dot(&fu)).abs() < 1e-9);
    }

    #[test]
    fn conv_grad_sq_in_basis_matches_per_example_sums() {
        // Conv per-example gradient is rank-P: DW_n = Σ_t g_{n,t} ā_{n,t}ᵀ.
        // Materialize it per example, project as a matrix, square, average.
        let arch = tiny_conv_arch(LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(24);
        let params = arch.glorot_init(&mut rng);
        let m = 4;
        let x = Mat::randn(m, arch.widths[0], 1.0, &mut rng);
        let fwd = net.forward(&params, &x);
        let gs = net.sampled_backward(&params, &fwd, &mut rng);
        let bases: Vec<KronBasis> = (0..arch.num_layers())
            .map(|i| {
                let (r, c) = arch.weight_shape(i);
                KronBasis {
                    ua: Mat::randn(c, c, 1.0, &mut rng),
                    ug: Mat::randn(r, r, 1.0, &mut rng),
                }
            })
            .collect();
        let got = net.grad_sq_in_basis(&fwd, &gs, &bases);
        for i in 0..arch.num_layers() {
            let (r, c) = arch.weight_shape(i);
            let p = gs[i].rows / m;
            let mut want = Mat::zeros(r, c);
            for n in 0..m {
                let mut dw = Mat::zeros(r, c);
                for t in 0..p {
                    let row = n * p + t;
                    for pr in 0..r {
                        for q in 0..c {
                            dw.set(
                                pr,
                                q,
                                dw.at(pr, q) + gs[i].at(row, pr) * fwd.abars[i].at(row, q),
                            );
                        }
                    }
                }
                let proj = bases[i].ug.matmul_tn(&dw).matmul(&bases[i].ua);
                want.axpy(1.0 / m as f64, &proj.hadamard(&proj));
            }
            let scale = want.max_abs().max(1e-12);
            let err = got[i].sub(&want).max_abs() / scale;
            assert!(err < 1e-10, "layer {i}: rel err {err}");
        }
    }

    #[test]
    fn sampled_backward_has_zero_mean_gs() {
        // Lemma 4: E[g] = 0 when targets are sampled from the model.
        let arch = tiny_arch(LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(5);
        let params = arch.glorot_init(&mut rng);
        let x = Mat::randn(2, 5, 1.0, &mut rng);
        let fwd = net.forward(&params, &x);
        let mut acc = Mat::zeros(2, 3);
        let n = 20_000;
        for _ in 0..n {
            let gs = net.sampled_backward(&params, &fwd, &mut rng);
            acc.axpy(1.0 / n as f64, &gs[1]);
        }
        assert!(acc.max_abs() < 0.02, "mean g = {}", acc.max_abs());
    }
}
