//! Theorem 1 / Corollary 2 (paper Section 10): K-FAC updates are
//! invariant to invertible linear reparameterizations of the network of
//! the form `s† = W† ā†`, `ā† = Ω φ̄(Φ s†)`.
//!
//! Our plain feed-forward substrate can represent the sub-family of
//! these transformations with arbitrary homogeneous-affine input
//! transform `T₀ = Ω₀` and arbitrary pre-activation mixing `Φ_i` at the
//! hidden layers of a **linear** network (where the induced activity
//! transform is `T_i = blockdiag(Φ_i⁻¹, 1)`), keeping `Φ_ℓ = I` so both
//! parameterizations compute the same output. The reparameterization is
//! `W_i = Φ_i W†_i T_{i-1}`, and Theorem 1 predicts the K-FAC updates
//! correspond through exactly that linear map — for both the
//! block-diagonal and block-tridiagonal inverses, with exact factor
//! statistics and no damping.

use kfac::fisher::exact::ExactBlocks;
use kfac::fisher::stats::RawStats;
use kfac::fisher::{BlockDiagInverse, FisherInverse, TridiagInverse};
use kfac::linalg::Mat;
use kfac::nn::net::Net;
use kfac::nn::{Act, Arch, LossKind, Params};
use kfac::rng::Rng;

/// Invertible homogeneous-affine transform (last row = e_last, so the
/// homogeneous coordinate is preserved).
fn affine_h(d: usize, rng: &mut Rng) -> Mat {
    let mut m = Mat::randn(d + 1, d + 1, 0.5, rng).add(&Mat::eye(d + 1));
    for c in 0..=d {
        m.set(d, c, if c == d { 1.0 } else { 0.0 });
    }
    m
}

/// Invertible pre-activation mixing.
fn mixing(d: usize, rng: &mut Rng) -> Mat {
    Mat::randn(d, d, 0.4, rng).add(&Mat::eye(d).scale(1.5))
}

/// blockdiag(Φ⁻¹, 1): the activity transform a linear layer induces.
fn induced_t(phi_inv: &Mat) -> Mat {
    let d = phi_inv.rows;
    let mut t = Mat::eye(d + 1);
    t.set_block(0, 0, phi_inv);
    t
}

struct Setup {
    arch: Arch,
    net: Net,
    params: Params,
    x: Mat,
    y: Mat,
}

fn linear_setup(seed: u64) -> Setup {
    // Output width ≥ hidden widths so the exact G_{i,i} = J_iᵀ F_R J_i
    // factors are full-rank (the theorem assumes invertible factors; a
    // rank-deficient G would trigger the jitter fallback, which is not
    // transformation-consistent).
    let arch = Arch::new(
        vec![5, 3, 3],
        vec![Act::Identity, Act::Identity],
        LossKind::SquaredError,
    );
    let mut rng = Rng::new(seed);
    let params = arch.glorot_init(&mut rng);
    let x = Mat::randn(60, 5, 1.0, &mut rng);
    let y = Mat::randn(60, 3, 1.0, &mut rng);
    Setup { net: Net::new(arch.clone()), arch, params, x, y }
}

/// Exact factor statistics (expectations over the model distribution).
fn exact_stats(net: &Net, params: &Params, x: &Mat) -> RawStats {
    let l = net.arch.num_layers();
    let eb = ExactBlocks::compute(net, params, x, 0, l);
    let mut st = RawStats::zeros(&net.arch);
    for i in 0..l {
        st.aa[i] = eb.aa[i][i].clone();
        st.gg[i] = eb.gg[i][i].clone();
    }
    for i in 0..l - 1 {
        st.aa_off[i] = eb.aa[i][i + 1].clone();
        st.gg_off[i] = eb.gg[i][i + 1].clone();
    }
    st
}

fn check_invariance(tridiag: bool) {
    let s = linear_setup(7);
    let l = s.arch.num_layers();
    let mut rng = Rng::new(99);

    // Φ_i per layer (Φ_ℓ = I), T₀ = arbitrary affine input transform,
    // T_i = blockdiag(Φ_i⁻¹, 1) for hidden layers.
    let mut phis: Vec<Mat> = (0..l).map(|i| mixing(s.arch.widths[i + 1], &mut rng)).collect();
    phis[l - 1] = Mat::eye(s.arch.widths[l]);
    let t0 = affine_h(s.arch.widths[0], &mut rng);
    let mut ts: Vec<Mat> = vec![t0];
    for i in 0..l - 1 {
        ts.push(induced_t(&phis[i].inverse()));
    }

    // W†_i = Φ_i⁻¹ W_i T_{i-1}⁻¹ ; transformed inputs ā₀† = T₀ ā₀.
    let params_t = Params(
        (0..l)
            .map(|i| phis[i].inverse().matmul(&s.params.0[i]).matmul(&ts[i].inverse()))
            .collect(),
    );
    let xt = s.x.append_ones_col().matmul_nt(&ts[0]).drop_last_col();

    // sanity: identical outputs (Φ_ℓ = I)
    let f_orig = s.net.forward(&s.params, &s.x);
    let f_t = s.net.forward(&params_t, &xt);
    assert!(
        f_orig.z().sub(f_t.z()).max_abs() < 1e-8,
        "transformed net output mismatch {}",
        f_orig.z().sub(f_t.z()).max_abs()
    );

    // gradients & exact stats in both parameterizations
    let (_, grad) = s.net.loss_and_grad(&s.params, &s.x, &s.y);
    let (_, grad_t) = s.net.loss_and_grad(&params_t, &xt, &s.y);
    let st = exact_stats(&s.net, &s.params, &s.x);
    let st_t = exact_stats(&s.net, &params_t, &xt);

    // un-damped K-FAC updates (γ = 0) in both parameterizations
    let (delta, delta_t): (Params, Params) = if tridiag {
        (
            TridiagInverse::build(&st, 0.0).apply(&grad),
            TridiagInverse::build(&st_t, 0.0).apply(&grad_t),
        )
    } else {
        (
            BlockDiagInverse::build(&st, 0.0).apply(&grad),
            BlockDiagInverse::build(&st_t, 0.0).apply(&grad_t),
        )
    };

    // ζ: W_i = Φ_i W†_i T_{i-1} is linear, so updates must satisfy
    // δ_i = Φ_i δ†_i T_{i-1}.
    //
    // Tolerances: the block-diagonal inverse is exactly invariant (up to
    // f64 roundoff). The block-tridiagonal Σ_{i|i+1} is *singular* at
    // γ = 0 for every network — the homogeneous coordinate of ā_{i-1}
    // is perfectly predictable from ā_i, giving the Ā-Schur complement
    // a zero eigenvalue — so its floored pseudo-inverse is only
    // approximately transformation-consistent (the paper never inverts
    // the undamped F̂ either; cf. Figure 3's caption). We therefore
    // check the tridiagonal case comparatively: orders of magnitude
    // closer to invariant than the (non-invariant) plain gradient.
    let rel_err = |a: &Params, b_t: &Params| -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..l {
            let mapped = phis[i].matmul(&b_t.0[i]).matmul(&ts[i]);
            let scale = a.0[i].max_abs().max(1e-10);
            worst = worst.max(mapped.sub(&a.0[i]).max_abs() / scale);
        }
        worst
    };
    let kfac_err = rel_err(&delta, &delta_t);
    let gd_err = rel_err(&grad, &grad_t);
    assert!(gd_err > 0.1, "test vacuous: plain gradient was invariant (err {gd_err})");
    if tridiag {
        assert!(
            kfac_err < 0.05 && kfac_err < gd_err / 20.0,
            "tridiag invariance violated: kfac err {kfac_err}, gd err {gd_err}"
        );
    } else {
        assert!(kfac_err < 1e-6, "blockdiag invariance violated: rel err {kfac_err}");
    }
}

#[test]
fn blockdiag_update_is_invariant_under_network_transformations() {
    check_invariance(false);
}

#[test]
fn blocktridiag_update_is_invariant_under_network_transformations() {
    check_invariance(true);
}

/// Corollary 3 sanity: with identity factor statistics the K-FAC update
/// *is* the gradient — i.e. K-FAC equals SGD in the whitened/centered
/// parameterization.
#[test]
fn kfac_is_sgd_in_whitened_coordinates() {
    let s = linear_setup(3);
    let mut st = RawStats::zeros(&s.arch);
    for i in 0..s.arch.num_layers() {
        st.aa[i] = Mat::eye(s.arch.widths[i] + 1);
        st.gg[i] = Mat::eye(s.arch.widths[i + 1]);
    }
    let (_, grad) = s.net.loss_and_grad(&s.params, &s.x, &s.y);
    let delta = BlockDiagInverse::build(&st, 0.0).apply(&grad);
    for i in 0..grad.0.len() {
        assert!(delta.0[i].sub(&grad.0[i]).max_abs() < 1e-12);
    }
}
