//! Data-parallel [`ModelBackend`] wrapper.
//!
//! `DistBackend` wraps any backend and all-reduces its per-batch outputs
//! (loss, gradients, Fisher-factor statistics, quadratic forms, EKFAC
//! second moments) across the group, averaging by the contributor count.
//! Every rank therefore feeds bitwise-identical curvature and gradient
//! information to its optimizer, which keeps the whole group's trajectories
//! in lockstep without any parameter synchronization.
//!
//! `eval` is deliberately **not** reduced: the evaluation set is identical
//! on every rank (only training minibatches are sharded), so reducing would
//! only add rounding noise.
//!
//! ## Failure policy
//!
//! A collective failure (peer timeout from a spoke's perspective, hub gone)
//! permanently detaches this backend: it keeps returning **local** values,
//! so a kicked or orphaned worker degrades to single-process training
//! instead of panicking or deadlocking. The hub-side view of the same event
//! is peer exclusion — the survivors' all-reduce keeps working with a
//! smaller contributor count.

use std::sync::Arc;

use super::Collective;
use crate::backend::{BatchStats, ModelBackend};
use crate::linalg::{KronBasis, Mat};
use crate::nn::{Arch, Params};

/// A [`ModelBackend`] whose outputs are averaged across a [`Collective`].
pub struct DistBackend<'a> {
    inner: &'a mut dyn ModelBackend,
    coll: Arc<dyn Collective>,
    detached: bool,
    failures: usize,
}

impl<'a> DistBackend<'a> {
    pub fn new(inner: &'a mut dyn ModelBackend, coll: Arc<dyn Collective>) -> DistBackend<'a> {
        DistBackend { inner, coll, detached: false, failures: 0 }
    }

    /// True once a collective failure has switched this rank to local-only
    /// values (it will never rejoin the group).
    pub fn is_detached(&self) -> bool {
        self.detached
    }

    /// Number of collective ops that have failed on this rank.
    pub fn failures(&self) -> usize {
        self.failures
    }

    /// All-reduce `buf` and average by contributor count. At `size <= 1`
    /// (and after detachment) the buffer is left bitwise untouched — this
    /// is what makes a `ranks=1` distributed run bit-identical to the
    /// single-process trainer.
    fn reduce(&mut self, buf: &mut [f64]) {
        if self.detached || self.coll.size() <= 1 {
            return;
        }
        match self.coll.all_reduce_sum(buf) {
            Ok(count) => {
                if count > 1 {
                    let inv = 1.0 / count as f64;
                    for v in buf.iter_mut() {
                        *v *= inv;
                    }
                }
            }
            Err(_) => {
                // Keep the local values; stop trying (degraded mode).
                self.detached = true;
                self.failures += 1;
            }
        }
    }
}

fn params_len(p: &Params) -> usize {
    p.0.iter().map(|m| m.data.len()).sum()
}

fn write_params(p: &Params, out: &mut Vec<f64>) {
    for m in &p.0 {
        out.extend_from_slice(&m.data);
    }
}

fn read_params(p: &mut Params, src: &[f64]) -> usize {
    let mut i = 0;
    for m in p.0.iter_mut() {
        m.data.copy_from_slice(&src[i..i + m.data.len()]);
        i += m.data.len();
    }
    i
}

impl ModelBackend for DistBackend<'_> {
    fn arch(&self) -> &Arch {
        self.inner.arch()
    }

    fn loss(&mut self, p: &Params, x: &Mat, y: &Mat) -> f64 {
        let mut buf = [self.inner.loss(p, x, y)];
        self.reduce(&mut buf);
        buf[0]
    }

    fn eval(&mut self, p: &Params, x: &Mat, y: &Mat) -> (f64, f64) {
        // Full eval set on every rank — nothing to reduce.
        self.inner.eval(p, x, y)
    }

    fn grad(&mut self, p: &Params, x: &Mat, y: &Mat) -> (f64, Params) {
        let (loss, mut grads) = self.inner.grad(p, x, y);
        let mut flat = Vec::with_capacity(1 + params_len(&grads));
        flat.push(loss);
        write_params(&grads, &mut flat);
        self.reduce(&mut flat);
        read_params(&mut grads, &flat[1..]);
        (flat[0], grads)
    }

    fn grad_and_stats(
        &mut self,
        p: &Params,
        x: &Mat,
        y: &Mat,
        stats_rows: usize,
        seed: u64,
    ) -> (f64, Params, BatchStats) {
        let (loss, mut grads, mut stats) = self.inner.grad_and_stats(p, x, y, stats_rows, seed);
        let np = params_len(&grads);
        let ns = stats.flat_len();
        let mut flat = Vec::with_capacity(1 + np + ns);
        flat.push(loss);
        write_params(&grads, &mut flat);
        let start = flat.len();
        flat.resize(start + ns, 0.0);
        stats.write_flat(&mut flat[start..]);
        self.reduce(&mut flat);
        read_params(&mut grads, &flat[1..1 + np]);
        stats.read_flat(&flat[1 + np..]);
        (flat[0], grads, stats)
    }

    fn fvp_quad(&mut self, p: &Params, x: &Mat, fvp_rows: usize, dirs: &[&Params]) -> Mat {
        let mut q = self.inner.fvp_quad(p, x, fvp_rows, dirs);
        self.reduce(&mut q.data);
        q
    }

    fn grad_sq_in_basis(
        &mut self,
        p: &Params,
        x: &Mat,
        y: &Mat,
        rows: usize,
        seed: u64,
        bases: &[KronBasis],
    ) -> Vec<Mat> {
        let mut mats = self.inner.grad_sq_in_basis(p, x, y, rows, seed, bases);
        let total: usize = mats.iter().map(|m| m.data.len()).sum();
        let mut flat = Vec::with_capacity(total);
        for m in &mats {
            flat.extend_from_slice(&m.data);
        }
        self.reduce(&mut flat);
        let mut i = 0;
        for m in mats.iter_mut() {
            m.data.copy_from_slice(&flat[i..i + m.data.len()]);
            i += m.data.len();
        }
        mats
    }
}
