//! Feed-forward neural network substrate (paper Section 2.1).
//!
//! Everything is batched row-major: a mini-batch of `m` cases is a
//! `Mat` with one case per **row**, so the layer computation
//! `s_i = W_i ā_{i-1}` (column-vector convention in the paper) becomes
//! `S_i = Ā_{i-1} W_iᵀ` here. Homogeneous coordinates are used
//! throughout: `ā = [a; 1]`, and the last column of each `W_i` is the
//! bias (exactly the paper's convention).
//!
//! The output nonlinearity is folded into the loss ([`LossKind`]), so
//! `z = s_ℓ` are the *natural parameters* of the predictive
//! distribution `R_{y|z}` — the condition under which the Fisher equals
//! the Generalized Gauss–Newton matrix (Martens 2014), which the
//! paper's damping and re-scaling machinery relies on.

pub mod net;

pub use net::{Fwd, Net};

use crate::linalg::pack::ConvShape;
use crate::linalg::Mat;
use crate::rng::Rng;

/// Hidden-layer activation functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Tanh,
    Logistic,
    Relu,
    Identity,
}

impl Act {
    /// φ(s), elementwise.
    #[inline]
    pub fn apply(self, s: f64) -> f64 {
        match self {
            Act::Tanh => s.tanh(),
            Act::Logistic => 1.0 / (1.0 + (-s).exp()),
            Act::Relu => s.max(0.0),
            Act::Identity => s,
        }
    }

    /// φ'(s) expressed via (s, a = φ(s)) — avoids recomputing transcendentals.
    #[inline]
    pub fn deriv(self, s: f64, a: f64) -> f64 {
        match self {
            Act::Tanh => 1.0 - a * a,
            Act::Logistic => a * (1.0 - a),
            Act::Relu => {
                if s > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Identity => 1.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Act::Tanh => "tanh",
            Act::Logistic => "logistic",
            Act::Relu => "relu",
            Act::Identity => "identity",
        }
    }

    pub fn from_name(s: &str) -> Option<Act> {
        Some(match s {
            "tanh" => Act::Tanh,
            "logistic" | "sigmoid" => Act::Logistic,
            "relu" => Act::Relu,
            "identity" | "linear" => Act::Identity,
            _ => return None,
        })
    }
}

/// Predictive distribution / loss `L(y, z) = -log r(y|z)` with `z` the
/// natural parameters (paper Section 2.1 + footnote 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    /// Bernoulli with logits `z`: `L = Σ softplus(z) - y z`. Used by the
    /// MNIST/CURVES autoencoders.
    SigmoidCe,
    /// Multinomial with logits `z` (one-hot `y`).
    SoftmaxCe,
    /// Unit-variance Gaussian with mean `z`: `L = ½‖z - y‖²`. Used by
    /// the FACES autoencoder.
    SquaredError,
}

impl LossKind {
    pub fn name(self) -> &'static str {
        match self {
            LossKind::SigmoidCe => "sigmoid_ce",
            LossKind::SoftmaxCe => "softmax_ce",
            LossKind::SquaredError => "squared_error",
        }
    }

    pub fn from_name(s: &str) -> Option<LossKind> {
        Some(match s {
            "sigmoid_ce" => LossKind::SigmoidCe,
            "softmax_ce" => LossKind::SoftmaxCe,
            "squared_error" => LossKind::SquaredError,
            _ => return None,
        })
    }

    /// Mean (over rows) loss `1/m Σ L(y, z)`.
    pub fn loss(self, z: &Mat, y: &Mat) -> f64 {
        assert_eq!((z.rows, z.cols), (y.rows, y.cols));
        let m = z.rows as f64;
        let mut total = 0.0;
        match self {
            LossKind::SigmoidCe => {
                for (zi, yi) in z.data.iter().zip(y.data.iter()) {
                    // numerically stable softplus(z) - y z
                    let sp = if *zi > 0.0 { zi + (-zi).exp().ln_1p() } else { zi.exp().ln_1p() };
                    total += sp - yi * zi;
                }
            }
            LossKind::SoftmaxCe => {
                for r in 0..z.rows {
                    let zr = z.row(r);
                    let yr = y.row(r);
                    let mx = zr.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    let lse = mx + zr.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
                    for (zi, yi) in zr.iter().zip(yr.iter()) {
                        total += yi * (lse - zi);
                    }
                }
            }
            LossKind::SquaredError => {
                for (zi, yi) in z.data.iter().zip(y.data.iter()) {
                    let d = zi - yi;
                    total += 0.5 * d * d;
                }
            }
        }
        total / m
    }

    /// Per-case loss derivative `∂L/∂z` (rows), *not* divided by m.
    /// For all three exp-family losses this is `p(z) - y`.
    pub fn dz(self, z: &Mat, y: &Mat) -> Mat {
        let p = self.predict(z);
        p.sub(y)
    }

    /// Predictive mean `E[y|z]` (σ(z), softmax(z), or z itself).
    pub fn predict(self, z: &Mat) -> Mat {
        match self {
            LossKind::SigmoidCe => z.map(|v| 1.0 / (1.0 + (-v).exp())),
            LossKind::SoftmaxCe => {
                let mut p = z.clone();
                for r in 0..p.rows {
                    let row = p.row_mut(r);
                    let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    let mut sum = 0.0;
                    for v in row.iter_mut() {
                        *v = (*v - mx).exp();
                        sum += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= sum;
                    }
                }
                p
            }
            LossKind::SquaredError => z.clone(),
        }
    }

    /// Sample targets from the predictive distribution `R_{y|z}`
    /// (paper Section 5 — this is what makes the `G` statistics estimate
    /// the *standard* Fisher rather than the empirical one).
    pub fn sample(self, z: &Mat, rng: &mut Rng) -> Mat {
        let p = self.predict(z);
        match self {
            LossKind::SigmoidCe => p.map_rng(rng, |rng, v| rng.bernoulli(v)),
            LossKind::SoftmaxCe => {
                let mut y = Mat::zeros(z.rows, z.cols);
                for r in 0..z.rows {
                    let k = rng.categorical(p.row(r));
                    y.set(r, k, 1.0);
                }
                y
            }
            LossKind::SquaredError => p.map_rng(rng, |rng, v| v + rng.normal()),
        }
    }

    /// Σ over cases of `jz1ᵀ F_R(z) jz2` where `F_R` is the Fisher of the
    /// predictive distribution w.r.t. its natural parameters (Appendix C:
    /// the half-cost trick computes `vᵀFu` from two linearized forward
    /// passes and this quadratic form). *Not* divided by m.
    pub fn fr_quad(self, z: &Mat, jz1: &Mat, jz2: &Mat) -> f64 {
        assert_eq!((z.rows, z.cols), (jz1.rows, jz1.cols));
        assert_eq!((z.rows, z.cols), (jz2.rows, jz2.cols));
        match self {
            LossKind::SquaredError => jz1.dot(jz2),
            LossKind::SigmoidCe => {
                let mut total = 0.0;
                for ((zi, a), b) in z.data.iter().zip(jz1.data.iter()).zip(jz2.data.iter()) {
                    let p = 1.0 / (1.0 + (-zi).exp());
                    total += p * (1.0 - p) * a * b;
                }
                total
            }
            LossKind::SoftmaxCe => {
                let p = self.predict(z);
                let mut total = 0.0;
                for r in 0..z.rows {
                    let (pr, ar, br) = (p.row(r), jz1.row(r), jz2.row(r));
                    let mut sab = 0.0;
                    let mut sa = 0.0;
                    let mut sb = 0.0;
                    for i in 0..pr.len() {
                        sab += pr[i] * ar[i] * br[i];
                        sa += pr[i] * ar[i];
                        sb += pr[i] * br[i];
                    }
                    total += sab - sa * sb;
                }
                total
            }
        }
    }

    /// Apply `F_R(z)` to a batch of vectors (rows). Needed for the exact
    /// small-network Fisher in the structure experiments.
    pub fn fr_apply(self, z: &Mat, v: &Mat) -> Mat {
        match self {
            LossKind::SquaredError => v.clone(),
            LossKind::SigmoidCe => {
                let p = self.predict(z);
                v.zip_map(&p, |vi, pi| vi * pi * (1.0 - pi))
            }
            LossKind::SoftmaxCe => {
                let p = self.predict(z);
                let mut out = Mat::zeros(v.rows, v.cols);
                for r in 0..v.rows {
                    let (pr, vr) = (p.row(r), v.row(r));
                    let dot: f64 = pr.iter().zip(vr.iter()).map(|(a, b)| a * b).sum();
                    for c in 0..v.cols {
                        out.set(r, c, pr[c] * (vr[c] - dot));
                    }
                }
                out
            }
        }
    }

    /// Mean per-case "error" for reporting: reconstruction error
    /// (squared distance between predictive mean and target) for
    /// autoencoders/regression, 0/1 error for classification.
    pub fn error(self, z: &Mat, y: &Mat) -> f64 {
        match self {
            LossKind::SoftmaxCe => {
                let argmax = |row: &[f64]| {
                    // total_cmp: a NaN logit (diverged run) must yield a
                    // deterministic argmax, not a panic mid-report.
                    row.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .unwrap_or(0)
                };
                let mut wrong = 0usize;
                for r in 0..z.rows {
                    if argmax(z.row(r)) != argmax(y.row(r)) {
                        wrong += 1;
                    }
                }
                wrong as f64 / z.rows as f64
            }
            _ => {
                let p = self.predict(z);
                let d = p.sub(y);
                d.dot(&d) / z.rows as f64
            }
        }
    }
}

impl Mat {
    /// Elementwise map with RNG access (used for target sampling).
    pub fn map_rng(&self, rng: &mut Rng, mut f: impl FnMut(&mut Rng, f64) -> f64) -> Mat {
        let mut out = self.clone();
        for v in out.data.iter_mut() {
            *v = f(rng, *v);
        }
        out
    }
}

/// One typed layer. Every variant maps a flat `[m, in_dim]` activation
/// matrix to `[m, out_dim]` and owns one weight matrix of shape
/// `weight_shape()` (bias in the last column).
///
/// - `Dense` is the paper's fully-connected layer: `s = W ā`.
/// - `Conv2d` is a 2-D convolution over NHWC-flattened inputs, lowered
///   onto the packed GEMM via the im2col view in
///   [`crate::linalg::pack`]; its weight is `out_c × (c_in·kh·kw + 1)`,
///   one receptive-field filter (plus bias) per output channel, shared
///   across all `P = out_h·out_w` spatial positions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layer {
    Dense { d_in: usize, d_out: usize, act: Act },
    Conv2d { shape: ConvShape, out_c: usize, act: Act },
}

impl Layer {
    /// Flat input width the layer consumes.
    pub fn in_dim(&self) -> usize {
        match self {
            Layer::Dense { d_in, .. } => *d_in,
            Layer::Conv2d { shape, .. } => shape.in_dim(),
        }
    }

    /// Flat output width the layer produces.
    pub fn out_dim(&self) -> usize {
        match self {
            Layer::Dense { d_out, .. } => *d_out,
            Layer::Conv2d { shape, out_c, .. } => shape.out_dim(*out_c),
        }
    }

    pub fn act(&self) -> Act {
        match self {
            Layer::Dense { act, .. } | Layer::Conv2d { act, .. } => *act,
        }
    }

    /// Spatial weight-sharing positions `P` (1 for dense layers).
    pub fn positions(&self) -> usize {
        match self {
            Layer::Dense { .. } => 1,
            Layer::Conv2d { shape, .. } => shape.positions(),
        }
    }

    /// Kronecker factor dimensions `(a, g)`: the input-side factor is
    /// `a × a` (homogeneous coordinate included), the gradient-side
    /// factor `g × g`. Dense: `(d_in+1, d_out)`; conv: `(K+1, out_c)`
    /// with `K = c_in·kh·kw` (Grosse & Martens 2016).
    pub fn factor_dims(&self) -> (usize, usize) {
        match self {
            Layer::Dense { d_in, d_out, .. } => (d_in + 1, *d_out),
            Layer::Conv2d { shape, out_c, .. } => (shape.patch_len() + 1, *out_c),
        }
    }

    /// Weight matrix shape `(rows, cols) = (g, a)`.
    pub fn weight_shape(&self) -> (usize, usize) {
        let (a, g) = self.factor_dims();
        (g, a)
    }

    pub fn conv_shape(&self) -> Option<ConvShape> {
        match self {
            Layer::Dense { .. } => None,
            Layer::Conv2d { shape, .. } => Some(*shape),
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, Layer::Dense { .. })
    }
}

/// Network architecture: a sequence of typed [`Layer`]s plus the
/// loss/predictive-distribution kind. `widths = [d₀, d₁, …, d_ℓ]` holds
/// the flat boundary dims, derived from the layers at construction —
/// most call sites (datasets, backends, reporting) only need those.
/// The last layer must be dense with `Identity` activation (the output
/// nonlinearity lives in the loss).
#[derive(Clone, Debug, PartialEq)]
pub struct Arch {
    pub layers: Vec<Layer>,
    pub widths: Vec<usize>,
    pub loss: LossKind,
}

impl Arch {
    /// Dense-only constructor (the original MLP spec): one activation
    /// per layer, `widths.len() == acts.len() + 1`.
    pub fn new(widths: Vec<usize>, acts: Vec<Act>, loss: LossKind) -> Arch {
        assert_eq!(widths.len(), acts.len() + 1, "arch: need one act per layer");
        assert_eq!(
            *acts.last().expect("arch: at least one layer"),
            Act::Identity,
            "arch: last activation must be Identity (output link lives in the loss)"
        );
        let layers = acts
            .iter()
            .enumerate()
            .map(|(i, &act)| Layer::Dense { d_in: widths[i], d_out: widths[i + 1], act })
            .collect();
        Arch::from_layers(layers, loss)
    }

    /// General constructor from a typed layer sequence. Adjacent flat
    /// dims must match; conv shapes must be geometrically valid.
    pub fn from_layers(layers: Vec<Layer>, loss: LossKind) -> Arch {
        assert!(!layers.is_empty(), "arch: at least one layer");
        for (i, pair) in layers.windows(2).enumerate() {
            assert_eq!(
                pair[0].out_dim(),
                pair[1].in_dim(),
                "arch: layer {i} out_dim != layer {} in_dim",
                i + 1
            );
        }
        for l in &layers {
            if let Some(s) = l.conv_shape() {
                s.validate();
            }
        }
        let last = layers.last().expect("arch: at least one layer");
        assert!(
            last.is_dense() && last.act() == Act::Identity,
            "arch: last layer must be Dense with Identity activation"
        );
        let mut widths = Vec::with_capacity(layers.len() + 1);
        widths.push(layers[0].in_dim());
        widths.extend(layers.iter().map(|l| l.out_dim()));
        Arch { layers, widths, loss }
    }

    /// Deep autoencoder: hidden activations `act`, linear code layer in
    /// the middle is up to the caller's `widths`; `SigmoidCe` output.
    pub fn autoencoder(widths: &[usize], act: Act) -> Arch {
        assert_eq!(widths.first(), widths.last(), "autoencoder: in/out dims differ");
        let l = widths.len() - 1;
        let mut acts = vec![act; l];
        acts[l - 1] = Act::Identity;
        Arch::new(widths.to_vec(), acts, LossKind::SigmoidCe)
    }

    /// Autoencoder with Gaussian (squared error) output, for real-valued
    /// data like FACES.
    pub fn autoencoder_gaussian(widths: &[usize], act: Act) -> Arch {
        let mut a = Arch::autoencoder(widths, act);
        a.loss = LossKind::SquaredError;
        a
    }

    /// Softmax classifier.
    pub fn classifier(widths: &[usize], act: Act) -> Arch {
        let l = widths.len() - 1;
        let mut acts = vec![act; l];
        acts[l - 1] = Act::Identity;
        Arch::new(widths.to_vec(), acts, LossKind::SoftmaxCe)
    }

    /// Number of layers ℓ.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Activation of layer `i`.
    pub fn act(&self, i: usize) -> Act {
        self.layers[i].act()
    }

    /// Shape of `W_i` (0-based layer index). Dense: `d_{i+1} × (d_i+1)`;
    /// conv: `out_c × (c_in·kh·kw + 1)`.
    pub fn weight_shape(&self, i: usize) -> (usize, usize) {
        self.layers[i].weight_shape()
    }

    /// Kronecker factor dims `(a, g)` of layer `i` (see
    /// [`Layer::factor_dims`]).
    pub fn factor_dims(&self, i: usize) -> (usize, usize) {
        self.layers[i].factor_dims()
    }

    /// True if any layer is non-dense.
    pub fn has_conv(&self) -> bool {
        self.layers.iter().any(|l| !l.is_dense())
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        (0..self.num_layers())
            .map(|i| {
                let (r, c) = self.weight_shape(i);
                r * c
            })
            .sum()
    }

    /// "Sparse initialization" of Martens (2010), also used by the paper:
    /// each unit gets `k` (default 15) incoming connections drawn from
    /// N(0,1); all other weights and the biases are zero.
    pub fn sparse_init(&self, rng: &mut Rng) -> Params {
        let mut ws = Vec::with_capacity(self.num_layers());
        for i in 0..self.num_layers() {
            let (rows, cols) = self.weight_shape(i);
            let fan_in = cols - 1;
            let k = 15usize.min(fan_in);
            let mut w = Mat::zeros(rows, cols);
            for r in 0..rows {
                let perm = rng.permutation(fan_in);
                for &c in perm.iter().take(k) {
                    w.set(r, c, rng.normal());
                }
            }
            ws.push(w);
        }
        Params(ws)
    }

    /// Glorot/Xavier dense initialization (alternative).
    pub fn glorot_init(&self, rng: &mut Rng) -> Params {
        let mut ws = Vec::with_capacity(self.num_layers());
        for i in 0..self.num_layers() {
            let (rows, cols) = self.weight_shape(i);
            let fan_in = (cols - 1) as f64;
            let fan_out = rows as f64;
            let sigma = (2.0 / (fan_in + fan_out)).sqrt();
            let mut w = Mat::randn(rows, cols, sigma, rng);
            for r in 0..rows {
                w.set(r, cols - 1, 0.0); // zero biases
            }
            ws.push(w);
        }
        Params(ws)
    }
}

/// Network parameters: one weight matrix per layer (bias in last column).
/// Supports the vector-space operations the optimizer needs.
#[derive(Clone, Debug, PartialEq)]
pub struct Params(pub Vec<Mat>);

impl Params {
    pub fn zeros_like(&self) -> Params {
        Params(self.0.iter().map(|w| Mat::zeros(w.rows, w.cols)).collect())
    }

    pub fn num_layers(&self) -> usize {
        self.0.len()
    }

    /// Σ_i <a_i, b_i> (Frobenius).
    pub fn dot(&self, other: &Params) -> f64 {
        self.0.iter().zip(other.0.iter()).map(|(a, b)| a.dot(b)).sum()
    }

    pub fn norm_sq(&self) -> f64 {
        self.dot(self)
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f64, other: &Params) {
        for (a, b) in self.0.iter_mut().zip(other.0.iter()) {
            a.axpy(alpha, b);
        }
    }

    pub fn scale(&self, s: f64) -> Params {
        Params(self.0.iter().map(|w| w.scale(s)).collect())
    }

    pub fn add(&self, other: &Params) -> Params {
        Params(self.0.iter().zip(other.0.iter()).map(|(a, b)| a.add(b)).collect())
    }

    /// `alpha*self + beta*other` without mutating either.
    pub fn linear_comb(&self, alpha: f64, beta: f64, other: &Params) -> Params {
        Params(
            self.0
                .iter()
                .zip(other.0.iter())
                .map(|(a, b)| a.scale(alpha).zip_map(&b.scale(beta), |x, y| x + y))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_shapes_and_counts() {
        let a = Arch::autoencoder(&[8, 4, 2, 4, 8], Act::Tanh);
        assert_eq!(a.num_layers(), 4);
        assert_eq!(a.weight_shape(0), (4, 9));
        assert_eq!(a.weight_shape(3), (8, 5));
        assert_eq!(a.num_params(), 4 * 9 + 2 * 5 + 4 * 3 + 8 * 5);
        assert_eq!(a.act(a.num_layers() - 1), Act::Identity);
        assert!(!a.has_conv());
    }

    #[test]
    fn conv_arch_shapes_and_counts() {
        let shape = ConvShape { in_h: 8, in_w: 8, in_c: 1, kh: 3, kw: 3, stride: 2, pad: 1 };
        let a = Arch::from_layers(
            vec![
                Layer::Conv2d { shape, out_c: 4, act: Act::Relu },
                Layer::Dense { d_in: 4 * 4 * 4, d_out: 10, act: Act::Identity },
            ],
            LossKind::SoftmaxCe,
        );
        assert_eq!(a.widths, vec![64, 64, 10]);
        assert_eq!(a.weight_shape(0), (4, 10)); // out_c × (1·3·3 + 1)
        assert_eq!(a.factor_dims(0), (10, 4));
        assert_eq!(a.weight_shape(1), (10, 65));
        assert_eq!(a.num_params(), 4 * 10 + 10 * 65);
        assert!(a.has_conv());
        assert_eq!(a.layers[0].positions(), 16);
    }

    #[test]
    #[should_panic(expected = "out_dim != layer")]
    fn from_layers_rejects_dim_mismatch() {
        let shape = ConvShape { in_h: 8, in_w: 8, in_c: 1, kh: 3, kw: 3, stride: 2, pad: 1 };
        let _ = Arch::from_layers(
            vec![
                Layer::Conv2d { shape, out_c: 4, act: Act::Relu },
                Layer::Dense { d_in: 99, d_out: 10, act: Act::Identity },
            ],
            LossKind::SoftmaxCe,
        );
    }

    #[test]
    fn sparse_init_has_k_nonzeros_per_unit_and_zero_bias() {
        let a = Arch::classifier(&[100, 50, 10], Act::Tanh);
        let p = a.sparse_init(&mut Rng::new(0));
        let w0 = &p.0[0];
        for r in 0..w0.rows {
            let nnz = w0.row(r)[..100].iter().filter(|v| **v != 0.0).count();
            assert_eq!(nnz, 15);
            assert_eq!(w0.row(r)[100], 0.0);
        }
    }

    #[test]
    fn losses_match_finite_difference_dz() {
        let mut rng = Rng::new(1);
        for loss in [LossKind::SigmoidCe, LossKind::SoftmaxCe, LossKind::SquaredError] {
            let z = Mat::randn(3, 5, 1.0, &mut rng);
            let y = match loss {
                LossKind::SoftmaxCe => {
                    let mut y = Mat::zeros(3, 5);
                    for r in 0..3 {
                        y.set(r, r + 1, 1.0);
                    }
                    y
                }
                LossKind::SigmoidCe => Mat::from_fn(3, 5, |r, c| ((r + c) % 2) as f64),
                LossKind::SquaredError => Mat::randn(3, 5, 1.0, &mut rng),
            };
            let dz = loss.dz(&z, &y);
            let eps = 1e-6;
            for r in 0..3 {
                for c in 0..5 {
                    let mut zp = z.clone();
                    zp.set(r, c, z.at(r, c) + eps);
                    let mut zm = z.clone();
                    zm.set(r, c, z.at(r, c) - eps);
                    // loss() is mean over m=3 rows; dz is per-case.
                    let fd = (loss.loss(&zp, &y) - loss.loss(&zm, &y)) / (2.0 * eps) * 3.0;
                    assert!(
                        (fd - dz.at(r, c)).abs() < 1e-4,
                        "{loss:?} ({r},{c}): fd={fd} dz={}",
                        dz.at(r, c)
                    );
                }
            }
        }
    }

    #[test]
    fn fr_quad_is_dz_covariance() {
        // F_R = E_y[dz dzᵀ] under y ~ R(y|z): check Monte Carlo for softmax.
        let mut rng = Rng::new(2);
        let loss = LossKind::SoftmaxCe;
        let z = Mat::randn(1, 4, 1.0, &mut rng);
        let v = Mat::randn(1, 4, 1.0, &mut rng);
        let want = loss.fr_quad(&z, &v, &v);
        let n = 200_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let y = loss.sample(&z, &mut rng);
            let d = loss.dz(&z, &y);
            let s = d.dot(&v);
            acc += s * s;
        }
        let mc = acc / n as f64;
        assert!((mc - want).abs() < 0.05 * want.abs().max(0.05), "mc={mc} want={want}");
    }

    #[test]
    fn fr_apply_matches_fr_quad() {
        let mut rng = Rng::new(3);
        for loss in [LossKind::SigmoidCe, LossKind::SoftmaxCe, LossKind::SquaredError] {
            let z = Mat::randn(4, 6, 0.7, &mut rng);
            let u = Mat::randn(4, 6, 1.0, &mut rng);
            let v = Mat::randn(4, 6, 1.0, &mut rng);
            let got = u.dot(&loss.fr_apply(&z, &v));
            let want = loss.fr_quad(&z, &u, &v);
            assert!((got - want).abs() < 1e-10, "{loss:?}");
        }
    }

    #[test]
    fn params_vector_ops() {
        let a = Arch::classifier(&[4, 3, 2], Act::Tanh);
        let mut rng = Rng::new(4);
        let p = a.glorot_init(&mut rng);
        let q = a.glorot_init(&mut rng);
        let mut r = p.clone();
        r.axpy(2.0, &q);
        let want = p.dot(&p) + 2.0 * p.dot(&q);
        assert!((r.dot(&p) - want).abs() < 1e-10);
        assert!((p.scale(3.0).norm_sq() - 9.0 * p.norm_sq()).abs() < 1e-9);
    }
}
