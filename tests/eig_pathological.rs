//! Pathological-spectrum battery for the three symmetric-eigensolver
//! paths (blocked Householder+QL, unblocked tred2/tql2, cyclic Jacobi):
//!
//! - Wilkinson-type matrices (nearly-degenerate eigenvalue pairs);
//! - tightly clustered eigenvalues (1e-13-wide clusters);
//! - spectra spanning 1e±150 (overflow/underflow safety of the scaled
//!   Householder norms and the QL shifts);
//! - the n = 23–26 dispatch boundary;
//! - the tql2 iteration-exhaustion → Jacobi fallback regression, and
//!   the non-finite-input guard (the two mid-training abort bugs).
//!
//! Every case asserts reconstruction `V diag(w) Vᵀ = A`, orthogonality
//! `VᵀV = I`, an ascending spectrum, and cross-path eigenvalue
//! agreement at 1e-9 (relative to `max|A|`).

use kfac::linalg::{Mat, SymEig};
use kfac::rng::Rng;

/// Random orthogonal matrix (eigenvectors of a random symmetric one).
fn orthogonal(n: usize, rng: &mut Rng) -> Mat {
    SymEig::new_jacobi(&Mat::randn(n, n, 1.0, rng).symmetrize()).v
}

/// `Q diag(w) Qᵀ`, exactly symmetrized.
fn from_spectrum(q: &Mat, w: &[f64]) -> Mat {
    let n = q.rows;
    let qd = Mat::from_fn(n, n, |r, c| q.at(r, c) * w[c]);
    qd.matmul_nt(q).symmetrize()
}

/// All three paths on `a`: reconstruction, orthogonality, sorted
/// spectra, cross-path agreement at `tol` (relative to `max|A|`).
fn check_all_paths(a: &Mat, tol: f64, label: &str) {
    let n = a.rows;
    let scale = 1.0 + a.max_abs();
    let bl = SymEig::new_blocked(a);
    let ql = SymEig::new_ql(a);
    let ja = SymEig::new_jacobi(a);
    for (name, e) in [("blocked", &bl), ("ql", &ql), ("jacobi", &ja)] {
        let rec = e.reconstruct().sub(a).max_abs();
        assert!(rec < tol * scale, "{label}/{name}: reconstruction err {rec:e}");
        let orth = e.v.matmul_tn(&e.v).sub(&Mat::eye(n)).max_abs();
        assert!(orth < tol, "{label}/{name}: orthogonality err {orth:e}");
        for i in 1..n {
            assert!(e.w[i] >= e.w[i - 1], "{label}/{name}: spectrum not sorted at {i}");
        }
        assert!(e.w.iter().all(|v| v.is_finite()), "{label}/{name}: non-finite eigenvalue");
    }
    for i in 0..n {
        assert!(
            (bl.w[i] - ja.w[i]).abs() < tol * scale,
            "{label}: blocked vs jacobi eigenvalue {i}: {} vs {}",
            bl.w[i],
            ja.w[i]
        );
        assert!(
            (ql.w[i] - ja.w[i]).abs() < tol * scale,
            "{label}: ql vs jacobi eigenvalue {i}: {} vs {}",
            ql.w[i],
            ja.w[i]
        );
    }
}

#[test]
fn wilkinson_w21_plus() {
    // W21+: diag |i − 10|, unit subdiagonals — the classic matrix whose
    // top eigenvalue pairs agree to ~1e-14 but are distinct.
    let n = 21;
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        a.set(i, i, (i as f64 - 10.0).abs());
    }
    for i in 0..n - 1 {
        a.set(i, i + 1, 1.0);
        a.set(i + 1, i, 1.0);
    }
    check_all_paths(&a, 1e-9, "wilkinson21");
    // the known largest eigenvalue of W21+
    let e = SymEig::new(&a);
    assert!((e.w[n - 1] - 10.746194).abs() < 1e-5, "λmax = {}", e.w[n - 1]);
}

#[test]
fn wilkinson_like_65_exercises_blocked_panels() {
    // A 65-wide Wilkinson-type matrix spans three NB=32 panels with a
    // ragged tail, with many nearly-degenerate pairs.
    let n = 65;
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        a.set(i, i, (i as f64 - 32.0).abs());
    }
    for i in 0..n - 1 {
        a.set(i, i + 1, 1.0);
        a.set(i + 1, i, 1.0);
    }
    check_all_paths(&a, 1e-9, "wilkinson65");
}

#[test]
fn tightly_clustered_eigenvalues() {
    for n in [24usize, 48] {
        let mut rng = Rng::new(42 + n as u64);
        let q = orthogonal(n, &mut rng);
        // clusters of four eigenvalues 1e-13 apart
        let mut w = Vec::with_capacity(n);
        for i in 0..n {
            w.push((i / 4) as f64 + (i % 4) as f64 * 1e-13);
        }
        let a = from_spectrum(&q, &w);
        check_all_paths(&a, 1e-9, "clustered");
        // recovered spectrum matches the construction (w is ascending)
        let e = SymEig::new(&a);
        let scale = 1.0 + a.max_abs();
        for i in 0..n {
            assert!(
                (e.w[i] - w[i]).abs() < 1e-9 * scale,
                "n={n} eigenvalue {i}: {} vs {}",
                e.w[i],
                w[i]
            );
        }
    }
}

#[test]
fn spectrum_spanning_1e_pm150() {
    for n in [16usize, 40] {
        let mut rng = Rng::new(7 + n as u64);
        let q = orthogonal(n, &mut rng);
        // log-spaced eigenvalues from 1e-150 to 1e+150
        let w: Vec<f64> = (0..n)
            .map(|i| 10f64.powf(-150.0 + 300.0 * i as f64 / (n - 1) as f64))
            .collect();
        let a = from_spectrum(&q, &w);
        check_all_paths(&a, 1e-9, "wide-spectrum");
        // the dominant end of the spectrum is recovered to full
        // relative precision (the tiny end is below eps·‖A‖ and only
        // recoverable in absolute terms)
        let e = SymEig::new(&a);
        assert!(((e.w[n - 1] - 1e150) / 1e150).abs() < 1e-9, "λmax = {:e}", e.w[n - 1]);
        // per-eigenvalue cross-path agreement: check_all_paths' blanket
        // tol·max|A| is vacuous at this scale, so compare the large end
        // relatively and floor the rest at the attainable absolute
        // accuracy (~n·eps·‖A‖, with two orders of margin)
        let bl = SymEig::new_blocked(&a);
        let ja = SymEig::new_jacobi(&a);
        let floor = 3e-13 * a.max_abs();
        for i in 0..n {
            let tol_i = (1e-9 * ja.w[i].abs()).max(floor);
            assert!(
                (bl.w[i] - ja.w[i]).abs() < tol_i,
                "n={n} eigenvalue {i}: blocked={:e} jacobi={:e}",
                bl.w[i],
                ja.w[i]
            );
        }
    }
}

#[test]
fn dispatch_boundary_23_to_26_all_paths_agree() {
    for n in [23usize, 24, 25, 26] {
        for seed in 0..2u64 {
            let mut rng = Rng::new(10_000 * n as u64 + seed);
            let a = Mat::randn(n, n, 1.0, &mut rng).symmetrize();
            check_all_paths(&a, 1e-9, "boundary");
            // the dispatching front door reconstructs too
            let e = SymEig::new(&a);
            let scale = 1.0 + a.max_abs();
            assert!(e.reconstruct().sub(&a).max_abs() < 1e-9 * scale, "n={n} dispatch");
        }
    }
}

#[test]
fn ql_iteration_exhaustion_falls_back_to_valid_jacobi() {
    // Regression for the `tql2: too many iterations` mid-training
    // abort: exhaustion (forced deterministically via the capped test
    // hook) must yield the Jacobi decomposition of the original matrix,
    // not a panic.
    let mut rng = Rng::new(99);
    for n in [12usize, 40] {
        let a = Mat::randn(n, n, 1.0, &mut rng).symmetrize();
        let scale = 1.0 + a.max_abs();
        let before = kfac::linalg::eig::tql2_fallback_count();
        let ql_fallback = SymEig::new_ql_with_iter_cap(&a, 0);
        let blocked_fallback = SymEig::new_blocked_with_iter_cap(&a, 0);
        for e in [ql_fallback, blocked_fallback] {
            assert!(
                e.reconstruct().sub(&a).max_abs() < 1e-9 * scale,
                "n={n}: fallback reconstruction"
            );
            assert!(
                e.v.matmul_tn(&e.v).sub(&Mat::eye(n)).max_abs() < 1e-9,
                "n={n}: fallback orthogonality"
            );
            // agrees with a direct Jacobi run
            let ja = SymEig::new_jacobi(&a);
            for i in 0..n {
                assert!((e.w[i] - ja.w[i]).abs() < 1e-12 * scale, "n={n} eigenvalue {i}");
            }
        }
        assert!(kfac::linalg::eig::tql2_fallback_count() >= before + 2, "not counted");
    }
}

#[test]
fn non_finite_input_panics_with_descriptive_message() {
    // Regression for the NaN-poisoned `partial_cmp(..).unwrap()` sort
    // panic: the guard must fire first, with a message that says why.
    let mut a = Mat::eye(30);
    a.set(3, 4, f64::NAN);
    a.set(4, 3, f64::NAN);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| SymEig::new(&a)));
    let payload = r.expect_err("NaN input must panic");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("non-finite"), "panic message not descriptive: {msg}");
}

#[test]
fn nan_poisoned_direct_paths_terminate_without_sort_panic() {
    // Calling the raw paths (bypassing the guard) on poisoned input
    // must degrade to garbage output, not a partial_cmp unwrap panic or
    // an infinite loop.
    let mut a = Mat::eye(10);
    a.set(2, 7, f64::NAN);
    a.set(7, 2, f64::NAN);
    let ja = SymEig::new_jacobi(&a);
    assert_eq!(ja.w.len(), 10);
    let ql = SymEig::new_ql(&a); // exhausts and falls back internally
    assert_eq!(ql.w.len(), 10);
}
