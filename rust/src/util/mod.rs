//! Small utilities: a minimal JSON parser (for the artifact manifest),
//! CSV output helpers, and simple statistics.

pub mod json;

use std::io::Write;
use std::path::Path;

/// Write rows of named columns as CSV, creating parent directories.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Read back a CSV written by [`write_csv`]: (header, rows).
pub fn read_csv(path: &Path) -> std::io::Result<(Vec<String>, Vec<Vec<f64>>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header: Vec<String> =
        lines.next().unwrap_or("").split(',').map(str::to_string).collect();
    let rows = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.split(',').map(|c| c.parse::<f64>().unwrap_or(f64::NAN)).collect())
        .collect();
    Ok((header, rows))
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    // total_cmp: NaN-safe total order (a NaN sample must not panic the
    // sort — it sorts last and surfaces in the reported number instead).
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn csv_writes() {
        let p = std::env::temp_dir().join("kfac_test_csv/x.csv");
        write_csv(&p, &["a", "b"], &[vec![1.0, 2.0], vec![3.0, 4.5]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("a,b\n1,2\n3,4.5\n"));
    }
}
