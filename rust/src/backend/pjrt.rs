//! PJRT model backend: drives the AOT-compiled JAX/Pallas artifacts.
//!
//! Mini-batches of arbitrary size are processed in fixed-shape chunks
//! (the compiled executables have static shapes); a per-row 0/1 weight
//! mask makes partial chunks *exact* — padded rows contribute nothing
//! to the weighted sums the programs return, and the backend divides by
//! the true row count. This mirrors the paper's remark (Section 13)
//! about partitioning very large mini-batches into "chunks".
//!
//! Program signatures (inputs in order; all f32; `c` = chunk rows,
//! `l` = layers; every output is a **weighted sum** over chunk rows):
//!
//! - `fwd_loss(W…, X, Y, w) → (loss, err)`
//! - `grad(W…, X, Y, w) → (loss, err, dW…)`
//! - `grad_stats(W…, X, Y, w, seed:i32) →
//!    (loss, err, dW…, aa…(l), aa_off…(l−1), gg…(l), gg_off…(l−1))`
//! - `fvp2(W…, X, w, V…, U…) → (vFv, vFu, uFu)`
//!
//! The real implementation needs the `xla` (xla-rs) crate and is gated
//! behind the `pjrt` cargo feature; without it a stub [`PjrtBackend`]
//! with the same surface is compiled whose constructor fails with a
//! descriptive error, so the CLI/experiment binaries still build and
//! fall back to the pure-Rust backend.

#[cfg(feature = "pjrt")]
pub use real::PjrtBackend;
#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtBackend;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::backend::{BatchStats, ModelBackend};
    use crate::linalg::{KronBasis, Mat};
    use crate::nn::{Arch, Params};
    use crate::runtime::{rt_err, Result};
    use std::path::Path;

    const UNAVAILABLE: &str = "PJRT support is not compiled into this build; \
         add the xla-rs/anyhow dependencies and rebuild with \
         `--features pjrt` (see the feature note in Cargo.toml) \
         or use `--backend rust`";

    /// Stub compiled when the `pjrt` feature is off. Cannot be
    /// constructed: [`PjrtBackend::new`] always errors.
    pub struct PjrtBackend {
        _unconstructable: (),
    }

    impl PjrtBackend {
        pub fn new(_artifacts_dir: &Path, _arch_name: &str) -> Result<PjrtBackend> {
            Err(rt_err(UNAVAILABLE))
        }

        pub fn chunk_size(&self) -> usize {
            unreachable!("{UNAVAILABLE}")
        }
    }

    impl ModelBackend for PjrtBackend {
        fn arch(&self) -> &Arch {
            unreachable!("{UNAVAILABLE}")
        }

        fn loss(&mut self, _p: &Params, _x: &Mat, _y: &Mat) -> f64 {
            unreachable!("{UNAVAILABLE}")
        }

        fn eval(&mut self, _p: &Params, _x: &Mat, _y: &Mat) -> (f64, f64) {
            unreachable!("{UNAVAILABLE}")
        }

        fn grad(&mut self, _p: &Params, _x: &Mat, _y: &Mat) -> (f64, Params) {
            unreachable!("{UNAVAILABLE}")
        }

        fn grad_and_stats(
            &mut self,
            _p: &Params,
            _x: &Mat,
            _y: &Mat,
            _stats_rows: usize,
            _seed: u64,
        ) -> (f64, Params, BatchStats) {
            unreachable!("{UNAVAILABLE}")
        }

        fn fvp_quad(&mut self, _p: &Params, _x: &Mat, _fvp_rows: usize, _dirs: &[&Params]) -> Mat {
            unreachable!("{UNAVAILABLE}")
        }

        fn grad_sq_in_basis(
            &mut self,
            _p: &Params,
            _x: &Mat,
            _y: &Mat,
            _rows: usize,
            _seed: u64,
            _bases: &[KronBasis],
        ) -> Vec<Mat> {
            unreachable!("{UNAVAILABLE}")
        }
    }
}

#[cfg(feature = "pjrt")]
mod real {
    use crate::backend::{BatchStats, ModelBackend};
    use crate::fisher::stats::RawStats;
    use crate::linalg::{KronBasis, Mat};
    use crate::nn::{Arch, Params};
    use crate::runtime::exec::{i32_literal, literal_scalar_f64, literal_to_mat, mat_to_literal};
    use crate::runtime::{Manifest, Program};
    use anyhow::{Context, Result};
    use std::path::Path;

    pub struct PjrtBackend {
        arch: Arch,
        chunk: usize,
        // Keep the client alive as long as the executables.
        _client: xla::PjRtClient,
        p_fwd: Program,
        p_grad: Program,
        p_grad_stats: Program,
        p_fvp2: Program,
    }

    impl PjrtBackend {
        /// Load and compile the programs for `arch_name` from `artifacts_dir`.
        pub fn new(artifacts_dir: &Path, arch_name: &str) -> Result<PjrtBackend> {
            let manifest = Manifest::load(artifacts_dir)?;
            let am = manifest.find(arch_name)?.clone();
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let load = |prog: &str| -> Result<Program> {
                Program::load(
                    &client,
                    &manifest.program_path(&am, prog)?,
                    &format!("{arch_name}/{prog}"),
                )
            };
            Ok(PjrtBackend {
                arch: am.arch(),
                chunk: am.chunk,
                p_fwd: load("fwd_loss")?,
                p_grad: load("grad")?,
                p_grad_stats: load("grad_stats")?,
                p_fvp2: load("fvp2")?,
                _client: client,
            })
        }

        pub fn chunk_size(&self) -> usize {
            self.chunk
        }

        fn params_literals(&self, p: &Params) -> Result<Vec<xla::Literal>> {
            p.0.iter().map(mat_to_literal).collect()
        }

        /// Slice rows [lo, lo+chunk) of `m` into a fixed-shape literal,
        /// zero-padding past `hi`; also returns the 0/1 mask literal.
        fn chunk_literal(
            &self,
            m: &Mat,
            lo: usize,
            hi: usize,
        ) -> Result<(xla::Literal, xla::Literal)> {
            let c = self.chunk;
            let mut x = Mat::zeros(c, m.cols);
            let mut w = vec![0.0f64; c];
            for r in 0..c {
                if lo + r < hi {
                    x.row_mut(r).copy_from_slice(m.row(lo + r));
                    w[r] = 1.0;
                }
            }
            Ok((mat_to_literal(&x)?, crate::runtime::exec::vec_to_literal(&w)))
        }

        fn data_chunk(&self, m: &Mat, lo: usize, hi: usize) -> Result<xla::Literal> {
            let c = self.chunk;
            let mut x = Mat::zeros(c, m.cols);
            for r in 0..c {
                if lo + r < hi {
                    x.row_mut(r).copy_from_slice(m.row(lo + r));
                }
            }
            mat_to_literal(&x)
        }

        /// Sum-accumulate grads/stats over chunks of the first `rows` rows.
        fn run_grad_like(
            &mut self,
            p: &Params,
            x: &Mat,
            y: &Mat,
            rows: usize,
            stats: bool,
            seed: u64,
        ) -> Result<(f64, f64, Params, Option<RawStats>)> {
            let l = self.arch.num_layers();
            let mut loss_sum = 0.0;
            let mut err_sum = 0.0;
            let mut grads = Params(
                (0..l)
                    .map(|i| {
                        let (r, c) = self.arch.weight_shape(i);
                        Mat::zeros(r, c)
                    })
                    .collect(),
            );
            let mut st = if stats { Some(RawStats::zeros(&self.arch)) } else { None };
            let wlits = self.params_literals(p)?;
            let mut lo = 0usize;
            let mut chunk_idx = 0u64;
            while lo < rows {
                let hi = (lo + self.chunk).min(rows);
                let (xl, wl) = self.chunk_literal(x, lo, hi)?;
                let yl = self.data_chunk(y, lo, hi)?;
                let seed_lit =
                    i32_literal((seed.wrapping_mul(1000).wrapping_add(chunk_idx)) as i32);
                // Parameter literals are converted once per call and shared by
                // reference across chunks (execute borrows its inputs).
                let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(l + 4);
                inputs.extend(wlits.iter());
                inputs.push(&xl);
                inputs.push(&yl);
                inputs.push(&wl);
                let outs = if stats {
                    inputs.push(&seed_lit);
                    self.p_grad_stats.run(&inputs)?
                } else {
                    self.p_grad.run(&inputs)?
                };
                loss_sum += literal_scalar_f64(&outs[0])?;
                err_sum += literal_scalar_f64(&outs[1])?;
                for i in 0..l {
                    let (r, c) = self.arch.weight_shape(i);
                    grads.0[i].axpy(1.0, &literal_to_mat(&outs[2 + i], r, c)?);
                }
                if let Some(st) = st.as_mut() {
                    let mut k = 2 + l;
                    for i in 0..l {
                        let d = self.arch.widths[i] + 1;
                        st.aa[i].axpy(1.0, &literal_to_mat(&outs[k], d, d)?);
                        k += 1;
                    }
                    for i in 0..l - 1 {
                        let (r, c) = (self.arch.widths[i] + 1, self.arch.widths[i + 1] + 1);
                        st.aa_off[i].axpy(1.0, &literal_to_mat(&outs[k], r, c)?);
                        k += 1;
                    }
                    for i in 0..l {
                        let d = self.arch.widths[i + 1];
                        st.gg[i].axpy(1.0, &literal_to_mat(&outs[k], d, d)?);
                        k += 1;
                    }
                    for i in 0..l - 1 {
                        let (r, c) = (self.arch.widths[i + 1], self.arch.widths[i + 2]);
                        st.gg_off[i].axpy(1.0, &literal_to_mat(&outs[k], r, c)?);
                        k += 1;
                    }
                }
                lo = hi;
                chunk_idx += 1;
            }
            let inv = 1.0 / rows as f64;
            for g in grads.0.iter_mut() {
                *g = g.scale(inv);
            }
            if let Some(st) = st.as_mut() {
                let sc = |v: &mut Vec<Mat>| {
                    for m in v.iter_mut() {
                        *m = m.scale(inv);
                    }
                };
                sc(&mut st.aa);
                sc(&mut st.aa_off);
                sc(&mut st.gg);
                sc(&mut st.gg_off);
            }
            Ok((loss_sum * inv, err_sum * inv, grads, st))
        }

        fn eval_impl(&mut self, p: &Params, x: &Mat, y: &Mat) -> Result<(f64, f64)> {
            let wlits = self.params_literals(p)?;
            let mut loss_sum = 0.0;
            let mut err_sum = 0.0;
            let mut lo = 0usize;
            while lo < x.rows {
                let hi = (lo + self.chunk).min(x.rows);
                let (xl, wl) = self.chunk_literal(x, lo, hi)?;
                let yl = self.data_chunk(y, lo, hi)?;
                let mut inputs: Vec<&xla::Literal> = Vec::new();
                inputs.extend(wlits.iter());
                inputs.push(&xl);
                inputs.push(&yl);
                inputs.push(&wl);
                let outs = self.p_fwd.run(&inputs)?;
                loss_sum += literal_scalar_f64(&outs[0])?;
                err_sum += literal_scalar_f64(&outs[1])?;
                lo = hi;
            }
            Ok((loss_sum / x.rows as f64, err_sum / x.rows as f64))
        }
    }

    impl ModelBackend for PjrtBackend {
        fn arch(&self) -> &Arch {
            &self.arch
        }

        fn loss(&mut self, p: &Params, x: &Mat, y: &Mat) -> f64 {
            self.eval_impl(p, x, y).expect("pjrt fwd_loss").0
        }

        fn eval(&mut self, p: &Params, x: &Mat, y: &Mat) -> (f64, f64) {
            self.eval_impl(p, x, y).expect("pjrt fwd_loss")
        }

        fn grad(&mut self, p: &Params, x: &Mat, y: &Mat) -> (f64, Params) {
            let (loss, _err, grads, _) =
                self.run_grad_like(p, x, y, x.rows, false, 0).expect("pjrt grad");
            (loss, grads)
        }

        fn grad_and_stats(
            &mut self,
            p: &Params,
            x: &Mat,
            y: &Mat,
            stats_rows: usize,
            seed: u64,
        ) -> (f64, Params, BatchStats) {
            let rows = stats_rows.clamp(1, x.rows);
            // Stats (and grads) on the first `rows` rows…
            let (loss_s, _es, grads_s, st) =
                self.run_grad_like(p, x, y, rows, true, seed).expect("pjrt grad_stats");
            let stats = st.unwrap();
            if rows == x.rows {
                return (loss_s, grads_s, stats);
            }
            // …then grads on the remaining rows; combine by row-weighted sum.
            let xr = x.block(rows, x.rows, 0, x.cols);
            let yr = y.block(rows, y.rows, 0, y.cols);
            let (loss_r, _er, grads_r, _) =
                self.run_grad_like(p, &xr, &yr, xr.rows, false, 0).expect("pjrt grad");
            let (w1, w2) = (rows as f64, (x.rows - rows) as f64);
            let total = w1 + w2;
            let mut grads = grads_s.scale(w1 / total);
            grads.axpy(w2 / total, &grads_r);
            ((loss_s * w1 + loss_r * w2) / total, grads, stats)
        }

        fn fvp_quad(&mut self, p: &Params, x: &Mat, fvp_rows: usize, dirs: &[&Params]) -> Mat {
            assert!(!dirs.is_empty() && dirs.len() <= 2, "fvp2 supports 1 or 2 directions");
            let rows = fvp_rows.clamp(1, x.rows);
            let l = self.arch.num_layers();
            let zero = dirs[0].zeros_like();
            let v = dirs[0];
            let u: &Params = if dirs.len() == 2 { dirs[1] } else { &zero };
            let wlits = self.params_literals(p).expect("params literals");
            let vlits = self.params_literals(v).expect("v literals");
            let ulits = self.params_literals(u).expect("u literals");
            let (mut vfv, mut vfu, mut ufu) = (0.0, 0.0, 0.0);
            let mut lo = 0usize;
            while lo < rows {
                let hi = (lo + self.chunk).min(rows);
                let (xl, wl) = self.chunk_literal(x, lo, hi).expect("chunk");
                let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(3 * l + 2);
                inputs.extend(wlits.iter());
                inputs.push(&xl);
                inputs.push(&wl);
                inputs.extend(vlits.iter());
                inputs.extend(ulits.iter());
                let outs = self.p_fvp2.run(&inputs).expect("pjrt fvp2");
                vfv += literal_scalar_f64(&outs[0]).expect("vfv");
                vfu += literal_scalar_f64(&outs[1]).expect("vfu");
                ufu += literal_scalar_f64(&outs[2]).expect("ufu");
                lo = hi;
            }
            let inv = 1.0 / rows as f64;
            if dirs.len() == 1 {
                Mat::from_vec(1, 1, vec![vfv * inv])
            } else {
                Mat::from_vec(2, 2, vec![vfv * inv, vfu * inv, vfu * inv, ufu * inv])
            }
        }

        fn grad_sq_in_basis(
            &mut self,
            p: &Params,
            x: &Mat,
            y: &Mat,
            rows: usize,
            seed: u64,
            bases: &[KronBasis],
        ) -> Vec<Mat> {
            // The AOT artifact set has no per-example-gradient program
            // yet (ROADMAP: "PJRT in CI"). Delegate to the f64 reference
            // substrate rather than aborting mid-training: the EKFAC
            // scale refresh is an amortized statistical estimate on the
            // τ₁ sub-batch, so the reference path's cost is acceptable
            // and `--backend pjrt --optimizer kfac_ekfac` keeps working
            // end-to-end (in f64 instead of the artifacts' f32).
            static FALLBACK_NOTE: std::sync::Once = std::sync::Once::new();
            FALLBACK_NOTE.call_once(|| {
                eprintln!(
                    "note: pjrt backend has no compiled grad_sq program; EKFAC \
                     scale refresh runs on the f64 reference substrate \
                     (--t-scale 0 disables)"
                );
            });
            let mut fallback = crate::backend::RustBackend::new(self.arch.clone());
            fallback.grad_sq_in_basis(p, x, y, rows, seed, bases)
        }
    }
}
