//! KFC — Kronecker Factors for Convolution (Grosse & Martens 2016).
//!
//! The conv-layer Fisher block is approximated as `F_i ≈ Ω_i ⊗ Γ_i`,
//! with `Ω` the second moment of im2col **patches** (sum over spatial
//! positions, mean over cases) and `Γ` the **spatially averaged**
//! pre-activation-gradient second moment — both already produced by
//! the layer-aware statistics pipeline ([`super::stats`]). The
//! derivation rests on spatial homogeneity and spatially uncorrelated
//! derivatives; for a dense layer (`P = 1`) both factors reduce to the
//! paper's `(Ā, G)` exactly, so KFC on a dense layer *is* the §4.2
//! block-diagonal structure, bit for bit.
//!
//! The inverse machinery is therefore shared: factored Tikhonov
//! damping (§6.3, with the π-trace split) and per-factor SPD inverses,
//! applied as `U = Γ⁻¹ V Ω⁻¹`. What KFC adds is the factor
//! *semantics*, which live in the statistics — this module only has to
//! wire them into the registry and the distributed shard seam.

use super::blockdiag::BlockDiagInverse;
use super::damping::damped_factors;
use super::stats::RawStats;
use super::{FisherInverse, Preconditioner};
use crate::linalg::chol::spd_inverse;
use crate::linalg::Mat;
use crate::nn::Params;

/// Cached damped-factor inverses `(Ω+π γ I)⁻¹, (Γ+γ/π I)⁻¹` per layer.
pub struct KfcInverse(pub BlockDiagInverse);

impl KfcInverse {
    /// Build from factor statistics with factored-Tikhonov strength
    /// `γ`. Layer factorizations run in parallel.
    pub fn build(stats: &RawStats, gamma: f64) -> KfcInverse {
        let l = stats.num_layers();
        let pairs = crate::par::par_map_send(l, 1, |i| {
            super::check_factors_finite("kfc", i, &stats.aa[i], &stats.gg[i]);
            let (ad, gd) = damped_factors(&stats.aa[i], &stats.gg[i], gamma);
            (spd_inverse(&ad), spd_inverse(&gd))
        });
        let (ainv, ginv) = pairs.into_iter().unzip();
        KfcInverse(BlockDiagInverse { ainv, ginv })
    }
}

impl FisherInverse for KfcInverse {
    fn apply(&self, grads: &Params) -> Params {
        self.0.apply(grads)
    }
}

/// The KFC preconditioner: block-diagonal over layers, conv blocks
/// factored per Grosse & Martens. Registered as `"kfc"` (CLI:
/// `kfac_kfc`). Implements the per-layer shard seam, so distributed
/// refreshes cover conv layers exactly like dense ones.
pub struct KfcPrecond;

impl Preconditioner for KfcPrecond {
    fn name(&self) -> &str {
        "kfc"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        Box::new(KfcInverse::build(stats, gamma))
    }

    fn layer_part_len(&self, stats: &RawStats, layer: usize) -> Option<usize> {
        let a = stats.aa[layer].rows;
        let g = stats.gg[layer].rows;
        Some(a * a + g * g)
    }

    fn build_layer_part(&self, stats: &RawStats, gamma: f64, layer: usize) -> Vec<f64> {
        // Mirrors KfcInverse::build's per-layer closure exactly so a
        // sharded refresh is bitwise identical to a replicated one.
        super::check_factors_finite("kfc", layer, &stats.aa[layer], &stats.gg[layer]);
        let (ad, gd) = damped_factors(&stats.aa[layer], &stats.gg[layer], gamma);
        let ainv = spd_inverse(&ad);
        let ginv = spd_inverse(&gd);
        let mut out = ainv.data;
        out.extend_from_slice(&ginv.data);
        out
    }

    fn assemble_parts(
        &self,
        stats: &RawStats,
        _gamma: f64,
        parts: &[Vec<f64>],
    ) -> Option<Box<dyn FisherInverse + Send>> {
        if parts.len() != stats.num_layers() {
            return None;
        }
        let mut ainv = Vec::with_capacity(parts.len());
        let mut ginv = Vec::with_capacity(parts.len());
        for (layer, part) in parts.iter().enumerate() {
            let a = stats.aa[layer].rows;
            let g = stats.gg[layer].rows;
            if part.len() != a * a + g * g {
                return None;
            }
            ainv.push(Mat::from_vec(a, a, part[..a * a].to_vec()));
            ginv.push(Mat::from_vec(g, g, part[a * a..].to_vec()));
        }
        Some(Box::new(KfcInverse(BlockDiagInverse { ainv, ginv })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::stats::KfacStats;
    use crate::linalg::kron::{kron, unvec, vec_mat};
    use crate::linalg::pack::ConvShape;
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, Layer, LossKind};
    use crate::rng::Rng;

    fn conv_arch() -> Arch {
        let shape = ConvShape { in_h: 4, in_w: 4, in_c: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
        Arch::from_layers(
            vec![
                Layer::Conv2d { shape, out_c: 3, act: Act::Tanh },
                Layer::Dense { d_in: 48, d_out: 4, act: Act::Identity },
            ],
            LossKind::SoftmaxCe,
        )
    }

    fn conv_stats(arch: &Arch, seed: u64) -> (KfacStats, Params) {
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(seed);
        let p = arch.glorot_init(&mut rng);
        let x = Mat::randn(32, arch.widths[0], 1.0, &mut rng);
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        let mut st = KfacStats::new(arch);
        st.update(&RawStats::from_batch(&fwd, &gs));
        (st, p)
    }

    #[test]
    fn apply_matches_dense_kron_inverse_on_conv_blocks() {
        let arch = conv_arch();
        let (st, p) = conv_stats(&arch, 5);
        let gamma = 0.1;
        let inv = KfcInverse::build(&st.s, gamma);
        let mut rng = Rng::new(6);
        let grads = Params(p.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
        let got = inv.apply(&grads);
        for i in 0..arch.num_layers() {
            let (ad, gd) = damped_factors(&st.s.aa[i], &st.s.gg[i], gamma);
            let dense = kron(&ad, &gd).inverse();
            let want = unvec(
                &dense.matvec(&vec_mat(&grads.0[i])),
                grads.0[i].rows,
                grads.0[i].cols,
            );
            let err = got.0[i].sub(&want).max_abs();
            assert!(err < 1e-7, "layer {i} err={err}");
        }
    }

    #[test]
    fn dense_layers_reduce_to_blockdiag_bitwise() {
        // On an all-dense arch the KFC build is the §4.2 block-diagonal
        // build, bit for bit (identical statistics → identical ops).
        let arch = Arch::new(vec![5, 4, 3], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
        let (st, _) = conv_stats(&arch, 7);
        let kfc = KfcInverse::build(&st.s, 0.3);
        let blk = BlockDiagInverse::build(&st.s, 0.3);
        let ours = kfc.0.ainv.iter().chain(kfc.0.ginv.iter());
        let theirs = blk.ainv.iter().chain(blk.ginv.iter());
        for (a, b) in ours.zip(theirs) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn sharded_parts_reassemble_bitwise() {
        // The PR 8 shard seam: per-layer parts must concatenate to the
        // replicated build exactly, conv blocks included.
        let arch = conv_arch();
        let (st, p) = conv_stats(&arch, 9);
        let gamma = 0.5;
        let pre = KfcPrecond;
        let parts: Vec<Vec<f64>> = (0..arch.num_layers())
            .map(|i| {
                let part = pre.build_layer_part(&st.s, gamma, i);
                assert_eq!(part.len(), pre.layer_part_len(&st.s, i).unwrap());
                part
            })
            .collect();
        let assembled = pre.assemble_parts(&st.s, gamma, &parts).expect("assembles");
        let plain = KfcInverse::build(&st.s, gamma);
        let mut rng = Rng::new(10);
        let g = Params(p.0.iter().map(|w| Mat::randn(w.rows, w.cols, 1.0, &mut rng)).collect());
        let ua = assembled.apply(&g);
        let ub = plain.apply(&g);
        for (a, b) in ua.0.iter().zip(ub.0.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // malformed parts are rejected, not mis-assembled
        let mut bad = parts.clone();
        bad[0].pop();
        assert!(pre.assemble_parts(&st.s, gamma, &bad).is_none());
        assert!(pre.assemble_parts(&st.s, gamma, &parts[..1]).is_none());
    }
}
