"""Counter-based PRNG in pure jnp (no jax.random).

The ``grad_stats`` program must sample targets from the model's
predictive distribution *inside* the lowered HLO (paper Section 5).
``jax.random``'s threefry can lower through CPU custom-calls on some
jaxlib versions, which the pinned xla_extension 0.5.1 cannot execute —
so we use a self-contained stateless generator: a SplitMix32-style
avalanche hash of (seed, counter), which lowers to plain integer HLO
ops everywhere. Statistical quality is far beyond what the Monte-Carlo
Fisher estimate needs (it is averaged over thousands of draws and then
EMA'd across iterations).
"""

import jax.numpy as jnp

_GOLDEN = jnp.uint32(0x9E3779B9)


def _mix(h):
    """fmix32 finalizer (murmur3) — full avalanche on 32 bits."""
    h = h.astype(jnp.uint32)
    h = (h ^ (h >> jnp.uint32(16))) * jnp.uint32(0x85EBCA6B)
    h = (h ^ (h >> jnp.uint32(13))) * jnp.uint32(0xC2B2AE35)
    return h ^ (h >> jnp.uint32(16))


def uniform(seed, shape, stream=0):
    """u32-hash-based uniforms in [0, 1) of the given static shape.

    `seed` may be a traced scalar (int32/uint32); `stream` is a static
    int separating independent draws inside one program.
    """
    n = 1
    for d in shape:
        n *= d
    idx = jnp.arange(n, dtype=jnp.uint32)
    s = jnp.asarray(seed).astype(jnp.uint32)
    h = _mix(idx * _GOLDEN + s * jnp.uint32(0x7FEB352D) + jnp.uint32(stream) * jnp.uint32(0x846CA68B))
    # 24 high bits -> f32 uniform in [0,1)
    u = (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    return u.reshape(shape)


def normal(seed, shape, stream=0):
    """Standard normals via Box–Muller over two uniform streams."""
    u1 = uniform(seed, shape, stream=stream * 2 + 101)
    u2 = uniform(seed, shape, stream=stream * 2 + 102)
    u1 = jnp.maximum(u1, jnp.float32(1e-7))
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * 3.141592653589793) * u2)


def bernoulli(seed, p, stream=0):
    """0/1 f32 draws with per-element probabilities `p`."""
    return (uniform(seed, p.shape, stream=stream) < p).astype(jnp.float32)


def categorical_onehot(seed, logits, stream=0):
    """One-hot categorical draws per row of `logits` (Gumbel-max)."""
    u = uniform(seed, logits.shape, stream=stream)
    g = -jnp.log(-jnp.log(jnp.maximum(u, jnp.float32(1e-7))))
    idx = jnp.argmax(logits + g, axis=-1)
    return jnp.eye(logits.shape[-1], dtype=jnp.float32)[idx]
