//! The K-FAC optimizer — complete implementation of the paper's
//! Algorithm 2:
//!
//! 1. gradient + factor statistics on the mini-batch (statistics on the
//!    τ₁ sub-batch, with model-sampled targets);
//! 2. exponentially-decayed online factor estimates (Section 5);
//! 3. approximate-inverse refresh every `T₃` iterations (or the first 3)
//!    with the factored Tikhonov damping of Section 6.3, through the
//!    open [`Preconditioner`] seam (block-diagonal §4.2,
//!    block-tridiagonal §4.3, EKFAC, or anything user-registered);
//! 4. update proposal `Δ = -F₀⁻¹∇h`, re-scaled on the **exact** Fisher's
//!    quadratic model (Section 6.4) via the Appendix-C FVP trick on the
//!    τ₂ sub-batch — with the previous update `δ₀` as a second direction
//!    when momentum is on (Section 7: joint (α, μ) solve);
//! 5. greedy γ adaptation every `T₂` iterations (Section 6.6) scored by
//!    the quadratic model value `M(δ)`;
//! 6. Levenberg–Marquardt λ adaptation every `T₁` iterations from the
//!    reduction ratio ρ (Section 6.5).
//!
//! `Kfac` implements the [`Optimizer`] trait, including full state
//! snapshot/restore for bit-exact checkpoint resume.

use crate::backend::ModelBackend;
use crate::fisher::precond;
use crate::fisher::{FisherInverse, KfacStats, PrecondRef, RawStats, UpdateOutcome};
use crate::linalg::Mat;
use crate::nn::{Arch, Params};
use crate::optim::optimizer::{check_dims, check_mat_shapes, OptState, Optimizer, StepInfo};
use crate::par::PendingJob;
use std::sync::Arc;

/// Default for [`KfacConfig::refresh_async`]: the `KFAC_ASYNC`
/// environment variable. Anything other than "1"/"true"/"on" (unset,
/// empty, "0", …) selects the deterministic synchronous path.
fn refresh_async_from_env() -> bool {
    matches!(std::env::var("KFAC_ASYNC").as_deref(), Ok("1") | Ok("true") | Ok("on"))
}

/// Hyper-parameters. The defaults are the paper's (Sections 6 and 8).
#[derive(Clone)]
pub struct KfacConfig {
    /// Which inverse-Fisher structure to use (the preconditioner seam).
    pub precond: PrecondRef,
    /// Use the (α, μ) momentum of Section 7.
    pub momentum: bool,
    /// Initial λ (paper: 150; "err on the side of too large").
    pub lambda0: f64,
    /// ℓ2 regularization coefficient η (paper experiments: 1e-5).
    pub eta: f64,
    /// λ-adaptation period T₁ (paper: 5).
    pub t1: usize,
    /// γ-adaptation period T₂ (paper: 20; must be a multiple of the
    /// inverse-rebuild period `t_inv`).
    pub t2: usize,
    /// Statistics-accumulation period: factor statistics are folded in
    /// every `t_cov` iterations, with the EMA decay scaled so the
    /// stationary estimate matches per-step accumulation in
    /// expectation. 1 (or 0) accumulates every step — the paper's
    /// setting, and bit-identical to the pre-split behaviour.
    pub t_cov: usize,
    /// Inverse-rebuild period (the paper's T₃: 20). The old single `t3`
    /// cadence is split into `t_cov`/`t_inv` so statistics can stay
    /// fresh while the expensive rebuild stays amortized — or, with
    /// [`refresh_async`](KfacConfig::refresh_async), gets hidden
    /// entirely.
    pub t_inv: usize,
    /// Scale-refresh period T_scale for eigenbasis-diagonal
    /// preconditioners (EKFAC, George et al. 2018): every T_scale
    /// iterations the diagonal scales of the cached inverse are
    /// re-estimated from second moments of per-example gradients
    /// projected into its eigenbasis — the cheap, frequent update the
    /// T₃-amortized eigendecompositions enable. 0 disables; ignored by
    /// structures without re-estimable scales (block-diag/tridiag).
    pub t_scale: usize,
    /// Rebuild the inverse **asynchronously**: on each `t_inv` boundary
    /// past bootstrap, snapshot the statistics + γ and submit the
    /// per-layer factorization to the background pool, keep stepping on
    /// the previous epoch's inverse, and swap the finished build in
    /// atomically at the next boundary (stale-but-consistent). The T₂
    /// γ line search is disabled in this mode; γ follows the paper's
    /// §6.6 default √(λ+η) at each rebuild. Defaults from the
    /// `KFAC_ASYNC` environment variable ("1"/"true"/"on" to enable);
    /// `false` is the deterministic synchronous path, bit-identical to
    /// the pre-split `t3` cadence.
    pub refresh_async: bool,
    /// Collective group for distributed training (`None` = single
    /// process). With a group of size > 1, each `t_inv` boundary builds
    /// the inverse through `dist::sharded_build`: the per-layer
    /// factorization is sharded round-robin by layer index across ranks
    /// and the parts are broadcast. A refresh that cannot complete (peer
    /// slow past the deadline or dropped) records a stall and keeps
    /// stepping on the previous `inv_epoch` — the async staleness
    /// contract. Both the T₂ γ line search and the background async
    /// refresh are disabled in this mode (the first would bypass
    /// sharding with per-candidate local rebuilds; the second would
    /// interleave two ranks' collective ops); γ follows the §6.6 default
    /// √(λ+η) at each rebuild, as in async mode. A size-1 group is
    /// ignored entirely, keeping the trajectory bit-identical to the
    /// single-process path.
    pub collective: Option<Arc<dyn crate::dist::Collective>>,
    /// λ decay ω₁ (paper: (19/20)^T₁).
    pub omega1: f64,
    /// γ step ω₂ (paper: sqrt(19/20)^T₂).
    pub omega2: f64,
    /// Statistics sub-batch fraction τ₁ (paper: 1/8).
    pub tau1: f64,
    /// FVP sub-batch fraction τ₂ (paper: 1/4).
    pub tau2: f64,
    /// Safety clamps for λ and γ.
    pub lambda_min: f64,
    pub lambda_max: f64,
    pub gamma_min: f64,
    pub gamma_max: f64,
}

impl std::fmt::Debug for KfacConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KfacConfig")
            .field("precond", &self.precond.name())
            .field("momentum", &self.momentum)
            .field("lambda0", &self.lambda0)
            .field("eta", &self.eta)
            .field("t1", &self.t1)
            .field("t2", &self.t2)
            .field("t_cov", &self.t_cov)
            .field("t_inv", &self.t_inv)
            .field("t_scale", &self.t_scale)
            .field("refresh_async", &self.refresh_async)
            .field("collective", &self.collective.as_ref().map(|c| (c.rank(), c.size())))
            .finish()
    }
}

impl Default for KfacConfig {
    fn default() -> Self {
        let t1 = 5usize;
        let t2 = 20usize;
        KfacConfig {
            precond: precond::block_tridiag(),
            momentum: true,
            lambda0: 150.0,
            eta: 1e-5,
            t1,
            t2,
            t_cov: 1,
            t_inv: 20,
            t_scale: 5,
            refresh_async: refresh_async_from_env(),
            collective: None,
            omega1: (19.0_f64 / 20.0).powi(t1 as i32),
            omega2: (19.0_f64 / 20.0).sqrt().powi(t2 as i32),
            tau1: 1.0 / 8.0,
            tau2: 1.0 / 4.0,
            lambda_min: 1e-8,
            lambda_max: 1e8,
            gamma_min: 1e-8,
            gamma_max: 1e6,
        }
    }
}

impl KfacConfig {
    /// Paper defaults with the block-diagonal preconditioner (§4.2).
    pub fn block_diag() -> Self {
        KfacConfig { precond: precond::block_diag(), ..Default::default() }
    }

    /// Paper defaults with the EKFAC eigenbasis preconditioner.
    pub fn ekfac() -> Self {
        KfacConfig { precond: precond::ekfac(), ..Default::default() }
    }

    pub fn no_momentum(mut self) -> Self {
        self.momentum = false;
        self
    }
}

/// Running second-moment scale estimates for an eigenbasis-diagonal
/// inverse (EKFAC): EMA'd over the scale refreshes of the current
/// eigenbasis epoch (the moments are basis-dependent, so eigenbasis
/// rebuilds reset the state).
struct ScaleState {
    /// One weight-shaped second-moment matrix per layer.
    s: Vec<Mat>,
    /// Refreshes absorbed in this epoch (drives the EMA decay).
    k: usize,
}

/// An inverse rebuild in flight on the background pool: the detached
/// build tied to the exact snapshot it was submitted with (a
/// [`par::PendingJob`](crate::par::PendingJob) — the submit/finish
/// protocol itself lives in `par` where the loom suite model-checks
/// it), kept so a checkpoint taken mid-flight can record the inputs
/// and resume by re-submitting the identical (deterministic) build.
struct PendingBuild {
    job: PendingJob<RawStats, Box<dyn FisherInverse + Send>>,
    /// γ the job is building with.
    gamma: f64,
}

/// Submit a preconditioner build as a detached pool job. Builds are
/// deterministic in `(snap, gamma)` and touch nothing else, so the job
/// produces the same bits whether it runs on a worker or inline.
/// `submitted_k` is the iteration at submit time (diagnostic +
/// checkpoint).
fn spawn_precond_build(
    precond: PrecondRef,
    snap: Arc<RawStats>,
    gamma: f64,
    submitted_k: usize,
) -> PendingBuild {
    let job = crate::par::submit_build(snap, submitted_k, move |s| precond.build(s, gamma));
    PendingBuild { job, gamma }
}

/// K-FAC optimizer state.
pub struct Kfac {
    pub cfg: KfacConfig,
    pub stats: KfacStats,
    pub lambda: f64,
    pub gamma: f64,
    inv: Option<Box<dyn FisherInverse + Send>>,
    /// Epoch tag of the cached inverse: incremented on every install
    /// (bootstrap, synchronous rebuild, or asynchronous swap), so a
    /// step's [`StepInfo::inv_epoch`] identifies exactly which inverse
    /// preconditioned it.
    inv_epoch: usize,
    /// Asynchronous rebuild in flight, if any (`refresh_async` only).
    pending: Option<PendingBuild>,
    /// Refresh boundaries that could not serve a fresh inverse: async
    /// builds still in flight at the swap, and distributed sharded
    /// builds that failed on a collective error (the step keeps using
    /// the previous `inv_epoch` either way). Checkpointed alongside
    /// `inv_epoch` in async/distributed mode so a resumed run's stall
    /// accounting matches the uninterrupted one.
    stalls: usize,
    /// The (stats, γ) snapshot the cached inverse was built from —
    /// checkpointed so resume can rebuild `inv` bit-exactly.
    refresh: Option<(RawStats, f64)>,
    /// The latest incremental update absorbed by the cached inverse
    /// (incremental preconditioners only): the `(stats, γ)` snapshot the
    /// drift was measured at. Checkpointed so resume can rebuild the
    /// base from `refresh` and replay this one delta — updates are
    /// memoryless (always relative to the base), so one record suffices
    /// for bit-exact resume no matter how many boundaries were absorbed.
    upd: Option<(RawStats, f64)>,
    /// Re-estimated EKFAC scales applied on top of the cached inverse
    /// (checkpointed; re-applied after the rebuild on resume).
    scale: Option<ScaleState>,
    delta_prev: Option<Params>,
    k: usize,
}

impl Kfac {
    /// Construct, validating that the configured preconditioner's
    /// factor semantics are defined for `arch`
    /// ([`Preconditioner::check_arch`](crate::fisher::Preconditioner::check_arch)).
    /// Structures like the block-tridiagonal or EKFAC reject conv
    /// architectures here, at construction time, instead of silently
    /// degrading during training.
    pub fn try_new(arch: &Arch, cfg: KfacConfig) -> Result<Kfac, String> {
        cfg.precond.check_arch(arch)?;
        let lambda = cfg.lambda0;
        let gamma = (lambda + cfg.eta).sqrt();
        Ok(Kfac {
            cfg,
            stats: KfacStats::new(arch),
            lambda,
            gamma,
            inv: None,
            inv_epoch: 0,
            pending: None,
            stalls: 0,
            refresh: None,
            upd: None,
            scale: None,
            delta_prev: None,
            k: 0,
        })
    }

    /// [`try_new`](Self::try_new), panicking on an architecture the
    /// preconditioner rejects.
    pub fn new(arch: &Arch, cfg: KfacConfig) -> Kfac {
        match Self::try_new(arch, cfg) {
            Ok(k) => k,
            Err(e) => panic!("{e}"),
        }
    }

    /// Current iteration count.
    pub fn iteration(&self) -> usize {
        self.k
    }

    /// Epoch tag of the currently installed inverse (0 = none yet).
    pub fn inverse_epoch(&self) -> usize {
        self.inv_epoch
    }

    /// How many `t_inv` boundaries had to block on a background build
    /// that had not finished (async mode; 0 means the refresh cost was
    /// fully hidden).
    pub fn refresh_stalls(&self) -> usize {
        self.stalls
    }

    /// Install a freshly built inverse with the `(snap, gamma)` it was
    /// built from, advancing the epoch tag. Re-estimated EKFAC scales
    /// live in the old eigenbasis, so a new basis starts a fresh
    /// second-moment epoch.
    fn install_inverse(&mut self, inv: Box<dyn FisherInverse + Send>, snap: RawStats, gamma: f64) {
        self.inv = Some(inv);
        self.inv_epoch += 1;
        self.refresh = Some((snap, gamma));
        self.upd = None;
        self.scale = None;
    }

    /// Record that the cached inverse absorbed a stats delta in place
    /// (incremental preconditioners): the epoch advances like any other
    /// refresh, the base `refresh` record stays (updates are measured
    /// against it), and the update snapshot is kept for checkpoint
    /// replay. Re-estimated scales belong to the pre-update inverse, so
    /// they reset like on a full rebuild.
    fn install_update(&mut self, snap: RawStats, gamma: f64) {
        self.inv_epoch += 1;
        self.upd = Some((snap, gamma));
        self.scale = None;
    }

    /// The previous iteration's update δ₀ (the momentum direction).
    pub fn last_update(&self) -> Option<&Params> {
        self.delta_prev.as_ref()
    }

    /// Solve for the optimal (α, μ) on the exact-Fisher quadratic model
    /// (Sections 6.4 / 7) given the damped quadratic-form matrix `q`
    /// (entries dᵢᵀ(F+(λ+η)I)dⱼ) and linear terms `b` (∇hᵀdᵢ).
    /// Returns (coeffs, model value M*).
    fn solve_quadratic(q: &Mat, b: &[f64]) -> (Vec<f64>, f64) {
        let k = b.len();
        if k == 1 {
            let denom = q.at(0, 0);
            if denom <= 0.0 || !denom.is_finite() {
                return (vec![0.0], 0.0);
            }
            let alpha = -b[0] / denom;
            let mval = 0.5 * alpha * alpha * denom + alpha * b[0];
            return (vec![alpha], mval);
        }
        debug_assert_eq!(k, 2);
        let (a11, a12, a22) = (q.at(0, 0), q.at(0, 1), q.at(1, 1));
        let det = a11 * a22 - a12 * a12;
        if !(det > 1e-300) || !det.is_finite() {
            // δ0 degenerate (zero/parallel) — fall back to 1-D.
            let (c, m) = Self::solve_quadratic(&Mat::from_vec(1, 1, vec![a11]), &b[..1]);
            return (vec![c[0], 0.0], m);
        }
        let alpha = -(a22 * b[0] - a12 * b[1]) / det;
        let mu = -(-a12 * b[0] + a11 * b[1]) / det;
        // M* = ½ cᵀQc + bᵀc
        let quad = 0.5 * (a11 * alpha * alpha + 2.0 * a12 * alpha * mu + a22 * mu * mu);
        let mval = quad + b[0] * alpha + b[1] * mu;
        (vec![alpha, mu], mval)
    }
}

impl Optimizer for Kfac {
    fn name(&self) -> &str {
        "kfac"
    }

    /// One K-FAC iteration on mini-batch `(x, y)`. Mutates `params`.
    fn step(
        &mut self,
        backend: &mut dyn ModelBackend,
        params: &mut Params,
        x: &Mat,
        y: &Mat,
    ) -> StepInfo {
        self.k += 1;
        let k = self.k;
        let cfg = self.cfg.clone();
        let m = x.rows;
        let stats_rows = ((cfg.tau1 * m as f64).ceil() as usize).clamp(1, m);
        let fvp_rows = ((cfg.tau2 * m as f64).ceil() as usize).clamp(1, m);

        // (1) gradient + statistics (statistics only on t_cov
        // boundaries; a plain gradient pass otherwise)
        let t_cov = cfg.t_cov.max(1);
        let accumulate = self.stats.k == 0 || k % t_cov == 0;
        let (loss_raw, mut grad, raw_stats) = if accumulate {
            let (l, g, raw) = backend.grad_and_stats(params, x, y, stats_rows, k as u64);
            (l, g, Some(raw))
        } else {
            let (l, g) = backend.grad(params, x, y);
            (l, g, None)
        };
        let h0 = loss_raw + 0.5 * cfg.eta * params.norm_sq();
        grad.axpy(cfg.eta, params);

        // (2) online factor estimates, decay scaled to the cadence
        if let Some(raw) = &raw_stats {
            self.stats.update_with_period(raw, t_cov);
        }

        // (3) refresh cadence. Bootstrap (first inverses) always builds
        // inline. Past bootstrap, a synchronous run rebuilds on the
        // boundary inside the candidate loop below; an asynchronous run
        // instead collects/installs the previous boundary's background
        // build and submits the next one, stepping on the stale-but-
        // consistent previous epoch in between (the T₂ γ search needs
        // per-candidate rebuilds, so it is disabled in async mode and γ
        // follows the §6.6 default √(λ+η)).
        let bootstrap = self.inv.is_none() || k <= 3;
        let boundary = cfg.t_inv > 0 && k % cfg.t_inv == 0;
        let dist = cfg.collective.as_ref().filter(|c| c.size() > 1);
        let run_async = cfg.refresh_async && !bootstrap && dist.is_none();
        if let Some(coll) = dist {
            if bootstrap || boundary {
                // Distributed refresh: sharded round-robin factorization +
                // broadcast, synchronous on every rank (the statistics were
                // all-reduced, so every rank agrees on the inputs and on γ).
                // The T₂ line search is disabled here, so γ follows the
                // §6.6 default √(λ+η) past bootstrap, exactly like async.
                if !bootstrap {
                    self.gamma =
                        (self.lambda + cfg.eta).sqrt().clamp(cfg.gamma_min, cfg.gamma_max);
                }
                match crate::dist::sharded_build(
                    cfg.precond.as_ref(),
                    &self.stats.s,
                    self.gamma,
                    coll.as_ref(),
                ) {
                    Ok(inv) => {
                        let snap = self.stats.s.clone();
                        let gamma = self.gamma;
                        self.install_inverse(inv, snap, gamma);
                    }
                    Err(_) => {
                        // Degraded mode: keep stepping on the previous
                        // inverse epoch and record the missed refresh.
                        self.stalls += 1;
                        if self.inv.is_none() {
                            // Bootstrap cannot degrade — there is no
                            // previous epoch yet. Build replicated from
                            // the local (already-reduced) statistics.
                            let inv = cfg.precond.build(&self.stats.s, self.gamma);
                            let snap = self.stats.s.clone();
                            let gamma = self.gamma;
                            self.install_inverse(inv, snap, gamma);
                        }
                    }
                }
            }
        }
        let dist_active = dist.is_some();
        if run_async && boundary {
            if let Some(p) = self.pending.take() {
                let (inv, snap, stalled) = p.job.finish();
                if stalled {
                    self.stalls += 1;
                }
                let snap = Arc::try_unwrap(snap).unwrap_or_else(|a| (*a).clone());
                self.install_inverse(inv, snap, p.gamma);
            }
            self.gamma = (self.lambda + cfg.eta).sqrt().clamp(cfg.gamma_min, cfg.gamma_max);
            let snap = Arc::new(self.stats.s.clone());
            self.pending = Some(spawn_precond_build(cfg.precond.clone(), snap, self.gamma, k));
        }

        // candidate γ set (Section 6.6)
        let adjust_gamma = !run_async && !dist_active && cfg.t2 > 0 && k % cfg.t2 == 0;
        let mut refresh_inv = !run_async && !dist_active && (bootstrap || boundary);

        // (3b) incremental inverse maintenance: on a plain synchronous
        // rebuild boundary (not bootstrap, not a T₂ γ-search boundary —
        // the search needs per-candidate full rebuilds), an incremental
        // preconditioner is offered the stats drift since its base
        // refresh first. If the cached inverse absorbs it the expensive
        // rebuild below is skipped; if it declines (drift trigger), the
        // ordinary full rebuild runs unchanged.
        if refresh_inv && !adjust_gamma && !bootstrap && cfg.precond.incremental() {
            let absorbed = match (self.refresh.as_ref(), self.inv.as_mut()) {
                (Some((base, _)), Some(inv)) => {
                    let delta = self.stats.s.delta_from(base);
                    matches!(inv.update(&delta, self.gamma), UpdateOutcome::Updated)
                }
                _ => false,
            };
            if absorbed {
                let snap = self.stats.s.clone();
                let gamma = self.gamma;
                self.install_update(snap, gamma);
                refresh_inv = false;
            }
        }
        let gammas: Vec<f64> = if adjust_gamma {
            vec![
                self.gamma,
                (self.gamma * cfg.omega2).clamp(cfg.gamma_min, cfg.gamma_max),
                (self.gamma / cfg.omega2).clamp(cfg.gamma_min, cfg.gamma_max),
            ]
        } else {
            vec![self.gamma]
        };

        // (4) per-candidate proposal + rescale; pick lowest M(δ)
        struct Cand {
            gamma: f64,
            inv: Option<Box<dyn FisherInverse + Send>>,
            delta: Params,
            coeffs: Vec<f64>,
            mval: f64,
        }
        let mut best: Option<Cand> = None;
        for &g in &gammas {
            let inv_box: Option<Box<dyn FisherInverse + Send>> = if refresh_inv || adjust_gamma {
                Some(cfg.precond.build(&self.stats.s, g))
            } else {
                None
            };
            let inv_ref: &dyn FisherInverse = match &inv_box {
                Some(b) => b.as_ref(),
                None => self.inv.as_ref().expect("inverse cache").as_ref(),
            };
            let delta = inv_ref.apply(&grad).scale(-1.0);

            // quadratic model on the exact Fisher (τ₂ subset)
            let use_mom = cfg.momentum && self.delta_prev.is_some();
            let mut dirs: Vec<&Params> = vec![&delta];
            if use_mom {
                dirs.push(self.delta_prev.as_ref().unwrap());
            }
            let fq = backend.fvp_quad(params, x, fvp_rows, &dirs);
            let damp = self.lambda + cfg.eta;
            let kdim = dirs.len();
            let mut q = Mat::zeros(kdim, kdim);
            let mut b = vec![0.0; kdim];
            for i in 0..kdim {
                b[i] = grad.dot(dirs[i]);
                for j in 0..kdim {
                    q.set(i, j, fq.at(i, j) + damp * dirs[i].dot(dirs[j]));
                }
            }
            let (coeffs, mval) = Self::solve_quadratic(&q, &b);
            let improves = match &best {
                None => true,
                Some(c) => mval < c.mval,
            };
            if improves {
                best = Some(Cand { gamma: g, inv: inv_box, delta, coeffs, mval });
            }
        }
        let cand = best.expect("at least one gamma candidate");
        self.gamma = cand.gamma;
        if let Some(inv) = cand.inv {
            // snapshot the build inputs so checkpoints can rebuild the
            // cached inverse bit-exactly on resume — a stats memcpy per
            // refresh, negligible next to the O(n³) factorizations the
            // refresh itself just performed
            let snap = self.stats.s.clone();
            let gamma = self.gamma;
            self.install_inverse(inv, snap, gamma);
        }

        // assemble δ = αΔ (+ μ δ₀)
        let alpha = cand.coeffs[0];
        let mu = cand.coeffs.get(1).copied().unwrap_or(0.0);
        let mut delta = cand.delta.scale(alpha);
        if mu != 0.0 {
            delta.axpy(mu, self.delta_prev.as_ref().unwrap());
        }

        // (6) ρ and λ (Section 6.5), every T₁ iterations
        let mut rho = None;
        if cfg.t1 > 0 && k % cfg.t1 == 0 && cand.mval < 0.0 {
            let mut theta_new = params.clone();
            theta_new.axpy(1.0, &delta);
            let h1 = backend.loss(&theta_new, x, y) + 0.5 * cfg.eta * theta_new.norm_sq();
            let r = (h1 - h0) / cand.mval;
            rho = Some(r);
            if r > 0.75 {
                self.lambda *= cfg.omega1;
            } else if r < 0.25 {
                self.lambda /= cfg.omega1;
            }
            self.lambda = self.lambda.clamp(cfg.lambda_min, cfg.lambda_max);
        }

        // (7) apply update
        params.axpy(1.0, &delta);
        let delta_norm = delta.norm_sq().sqrt();
        self.delta_prev = Some(delta);

        // (8) amortized EKFAC scale re-estimation (George et al. 2018):
        // every T_scale iterations, estimate second moments of
        // per-example gradients projected into the cached inverse's
        // eigenbasis (τ₁ sub-batch, model-sampled targets), fold them
        // into the running epoch estimate, and swap them in as the
        // diagonal scales — effective from the next iteration. No-op
        // for structures without an eigenbasis.
        if cfg.t_scale > 0 && k % cfg.t_scale == 0 {
            let sq = self.inv.as_ref().and_then(|inv| inv.eigenbases()).map(|bases| {
                backend.grad_sq_in_basis(
                    params,
                    x,
                    y,
                    stats_rows,
                    (k as u64).wrapping_add(0x5CA1E),
                    bases,
                )
            });
            if let Some(sq) = sq {
                match self.scale.as_mut() {
                    Some(sc) => {
                        sc.k += 1;
                        let eps = KfacStats::epsilon(sc.k);
                        for (d, s) in sc.s.iter_mut().zip(sq.iter()) {
                            d.ema(eps, 1.0 - eps, s);
                        }
                    }
                    None => self.scale = Some(ScaleState { s: sq, k: 1 }),
                }
                // the scales re-damp with the γ of the *installed*
                // eigenbasis epoch: in async mode self.gamma may
                // already belong to the in-flight build, so the
                // re-estimation must apply to the epoch it was
                // measured against (the refresh record's γ)
                let g_live = self.refresh.as_ref().map(|(_, g)| *g).unwrap_or(self.gamma);
                let sc = self.scale.as_ref().expect("scale state just set");
                self.inv.as_mut().expect("inverse cache").set_scales(&sc.s, g_live);
            }
        }

        StepInfo {
            loss: h0,
            model_value: Some(cand.mval),
            alpha: Some(alpha),
            mu: Some(mu),
            lambda: Some(self.lambda),
            gamma: Some(self.gamma),
            rho,
            delta_norm: Some(delta_norm),
            inv_epoch: Some(self.inv_epoch),
        }
    }

    fn state(&self) -> OptState {
        let mut st = OptState::new("kfac");
        st.set_str("precond", self.cfg.precond.name());
        st.set_scalar("k", self.k as f64);
        st.set_scalar("lambda", self.lambda);
        st.set_scalar("gamma", self.gamma);
        st.set_scalar("stats_k", self.stats.k as f64);
        st.set_mats("stats_aa", self.stats.s.aa.clone());
        st.set_mats("stats_aa_off", self.stats.s.aa_off.clone());
        st.set_mats("stats_gg", self.stats.s.gg.clone());
        st.set_mats("stats_gg_off", self.stats.s.gg_off.clone());
        if let Some(d) = &self.delta_prev {
            st.set_mats("delta_prev", d.0.clone());
        }
        if let Some((snap, g)) = &self.refresh {
            st.set_scalar("refresh_gamma", *g);
            st.set_mats("refresh_aa", snap.aa.clone());
            st.set_mats("refresh_aa_off", snap.aa_off.clone());
            st.set_mats("refresh_gg", snap.gg.clone());
            st.set_mats("refresh_gg_off", snap.gg_off.clone());
        }
        if let Some((snap, g)) = &self.upd {
            // Incremental-update record (checkpoint v4): resume rebuilds
            // the base from the refresh keys and replays this one delta.
            st.set_scalar("upd_gamma", *g);
            st.set_mats("upd_aa", snap.aa.clone());
            st.set_mats("upd_aa_off", snap.aa_off.clone());
            st.set_mats("upd_gg", snap.gg.clone());
            st.set_mats("upd_gg_off", snap.gg_off.clone());
        }
        if let Some(sc) = &self.scale {
            st.set_scalar("scale_k", sc.k as f64);
            st.set_mats("scale_s", sc.s.clone());
        }
        // Async/distributed-only keys (a plain synchronous snapshot stays
        // bit-compatible with the pre-split format; a size-1 "distributed"
        // run takes the plain path and must snapshot identically to it). A
        // checkpoint cannot wait on the background job, so a mid-flight
        // snapshot records the job's *inputs*; load_state re-submits the
        // identical deterministic build, and the resumed run collects it at
        // the same boundary.
        let dist = self.cfg.collective.as_ref().is_some_and(|c| c.size() > 1);
        if self.cfg.refresh_async || dist {
            st.set_scalar("inv_epoch", self.inv_epoch as f64);
            st.set_scalar("refresh_stalls", self.stalls as f64);
        }
        if let Some(p) = &self.pending {
            let snap = p.job.input();
            st.set_scalar("pending_gamma", p.gamma);
            st.set_scalar("pending_k", p.job.submitted_k() as f64);
            st.set_mats("pending_aa", snap.aa.clone());
            st.set_mats("pending_aa_off", snap.aa_off.clone());
            st.set_mats("pending_gg", snap.gg.clone());
            st.set_mats("pending_gg_off", snap.gg_off.clone());
        }
        st
    }

    fn load_state(&mut self, st: &OptState) -> Result<(), String> {
        if st.kind != "kfac" {
            return Err(format!("kfac: cannot load '{}' optimizer state", st.kind));
        }
        // Resuming with a different curvature structure would silently
        // change the trajectory — the checkpoint pins the preconditioner.
        let pname = st.require_str("precond")?;
        if pname != self.cfg.precond.name() {
            return Err(format!(
                "kfac: checkpoint used preconditioner '{pname}', session uses '{}'",
                self.cfg.precond.name()
            ));
        }
        let aa = st.require_mats("stats_aa")?;
        let aa_off = st.require_mats("stats_aa_off")?;
        let gg = st.require_mats("stats_gg")?;
        let gg_off = st.require_mats("stats_gg_off")?;
        check_mat_shapes("stats_aa", aa, &self.stats.s.aa)?;
        check_mat_shapes("stats_aa_off", aa_off, &self.stats.s.aa_off)?;
        check_mat_shapes("stats_gg", gg, &self.stats.s.gg)?;
        check_mat_shapes("stats_gg_off", gg_off, &self.stats.s.gg_off)?;
        self.k = st.require_scalar("k")? as usize;
        self.lambda = st.require_scalar("lambda")?;
        self.gamma = st.require_scalar("gamma")?;
        self.stats.k = st.require_scalar("stats_k")? as usize;
        self.stats.s.aa = aa.to_vec();
        self.stats.s.aa_off = aa_off.to_vec();
        self.stats.s.gg = gg.to_vec();
        self.stats.s.gg_off = gg_off.to_vec();
        // weight-shaped entries: gg[i].rows × aa[i].rows per layer
        // (shared by the delta_prev and scale_s dimension checks)
        let weight_dims: Vec<(usize, usize)> = self
            .stats
            .s
            .aa
            .iter()
            .zip(self.stats.s.gg.iter())
            .map(|(a, g)| (g.rows, a.rows))
            .collect();
        self.delta_prev = match st.mats("delta_prev") {
            Some(d) => {
                check_dims("delta_prev", d, weight_dims.iter().copied())?;
                Some(Params(d.to_vec()))
            }
            None => None,
        };
        match (st.scalar("refresh_gamma"), st.mats("refresh_aa")) {
            (Some(g), Some(raa)) => {
                check_mat_shapes("refresh_aa", raa, &self.stats.s.aa)?;
                let snap = RawStats {
                    aa: raa.to_vec(),
                    aa_off: st.require_mats("refresh_aa_off")?.to_vec(),
                    gg: st.require_mats("refresh_gg")?.to_vec(),
                    gg_off: st.require_mats("refresh_gg_off")?.to_vec(),
                };
                // deterministic rebuild of the cached inverse
                self.inv = Some(self.cfg.precond.build(&snap, g));
                self.refresh = Some((snap, g));
            }
            _ => {
                self.inv = None;
                self.refresh = None;
            }
        }
        // Replay the checkpointed incremental update (v4) on top of the
        // freshly rebuilt base. Updates are memoryless (pure functions
        // of base + delta + γ), so this single replay reproduces the
        // running inverse bit-exactly.
        self.upd = None;
        if let (Some(ug), Some(uaa)) = (st.scalar("upd_gamma"), st.mats("upd_aa")) {
            check_mat_shapes("upd_aa", uaa, &self.stats.s.aa)?;
            let snap = RawStats {
                aa: uaa.to_vec(),
                aa_off: st.require_mats("upd_aa_off")?.to_vec(),
                gg: st.require_mats("upd_gg")?.to_vec(),
                gg_off: st.require_mats("upd_gg_off")?.to_vec(),
            };
            check_mat_shapes("upd_gg", &snap.gg, &self.stats.s.gg)?;
            match (self.refresh.as_ref(), self.inv.as_mut()) {
                (Some((base, _)), Some(inv)) => {
                    let delta = snap.delta_from(base);
                    if inv.update(&delta, ug) != UpdateOutcome::Updated {
                        return Err(
                            "kfac: cached inverse refused to replay the checkpointed \
                             incremental update (preconditioner/env mismatch?)"
                                .to_string(),
                        );
                    }
                }
                _ => {
                    return Err(
                        "kfac: checkpoint records an incremental update without the \
                         refresh record it is relative to"
                            .to_string(),
                    )
                }
            }
            self.upd = Some((snap, ug));
        }
        self.scale = match (st.scalar("scale_k"), st.mats("scale_s")) {
            (Some(sk), Some(ss)) => {
                check_dims("scale_s", ss, weight_dims.iter().copied())?;
                Some(ScaleState { s: ss.to_vec(), k: sk as usize })
            }
            _ => None,
        };
        // re-apply the running scales on top of the rebuilt inverse so
        // the resumed trajectory is bit-exact, with the γ of the
        // installed epoch (the refresh record's — in async mode
        // self.gamma may already belong to an in-flight build)
        let g_live = self.refresh.as_ref().map(|(_, g)| *g).unwrap_or(self.gamma);
        if let (Some(sc), Some(inv)) = (self.scale.as_ref(), self.inv.as_mut()) {
            inv.set_scales(&sc.s, g_live);
        }
        // Epoch tag: async checkpoints carry it; for pre-split /
        // synchronous snapshots start the count at whether an inverse
        // exists (the tag is diagnostic — the trajectory never reads it).
        self.inv_epoch = match st.scalar("inv_epoch") {
            Some(v) => v as usize,
            None => usize::from(self.inv.is_some()),
        };
        // Stall counter: carried by async/distributed checkpoints so the
        // resumed run's accounting matches the uninterrupted one;
        // pre-dist snapshots don't record it, so resume restarts the
        // count at zero (deliberate — the counter is diagnostic and the
        // trajectory never reads it).
        self.stalls = st.scalar("refresh_stalls").map_or(0, |v| v as usize);
        // Mid-flight background build: re-submit the recorded inputs so
        // the resumed run collects the identical build at the same
        // boundary. A synchronous session discards the pending record —
        // its own cadence rebuilds from live statistics at the boundary.
        self.pending = None;
        if self.cfg.refresh_async {
            if let (Some(pg), Some(pk), Some(paa)) =
                (st.scalar("pending_gamma"), st.scalar("pending_k"), st.mats("pending_aa"))
            {
                check_mat_shapes("pending_aa", paa, &self.stats.s.aa)?;
                let snap = RawStats {
                    aa: paa.to_vec(),
                    aa_off: st.require_mats("pending_aa_off")?.to_vec(),
                    gg: st.require_mats("pending_gg")?.to_vec(),
                    gg_off: st.require_mats("pending_gg_off")?.to_vec(),
                };
                check_mat_shapes("pending_gg", &snap.gg, &self.stats.s.gg)?;
                self.pending = Some(spawn_precond_build(
                    self.cfg.precond.clone(),
                    Arc::new(snap),
                    pg,
                    pk as usize,
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::RustBackend;
    use crate::nn::{Act, LossKind};
    use crate::rng::Rng;

    fn toy_problem(seed: u64) -> (Arch, Params, Mat, Mat) {
        let arch = Arch::new(
            vec![8, 6, 4],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let mut rng = Rng::new(seed);
        let params = arch.sparse_init(&mut rng);
        let x = Mat::randn(64, 8, 1.0, &mut rng);
        let mut y = Mat::zeros(64, 4);
        for r in 0..64 {
            // targets correlated with input so there is signal to learn
            let c = if x.at(r, 0) > 0.0 { 0 } else { 1 };
            y.set(r, c + if x.at(r, 1) > 0.0 { 0 } else { 2 }, 1.0);
        }
        (arch, params, x, y)
    }

    #[test]
    fn solve_quadratic_minimizes() {
        let q = Mat::from_vec(2, 2, vec![2.0, 0.3, 0.3, 1.0]);
        let b = vec![-1.0, 0.5];
        let (c, m) = Kfac::solve_quadratic(&q, &b);
        // gradient of ½cᵀQc + bᵀc must vanish at c
        let g0 = q.at(0, 0) * c[0] + q.at(0, 1) * c[1] + b[0];
        let g1 = q.at(1, 0) * c[0] + q.at(1, 1) * c[1] + b[1];
        assert!(g0.abs() < 1e-12 && g1.abs() < 1e-12);
        assert!(m < 0.0);
        // and M* = ½ bᵀ c
        let m2 = 0.5 * (b[0] * c[0] + b[1] * c[1]);
        assert!((m - m2).abs() < 1e-12);
    }

    #[test]
    fn loss_decreases_over_training() {
        for p in [precond::block_diag(), precond::block_tridiag()] {
            let name = p.name().to_string();
            let (arch, mut params, x, y) = toy_problem(1);
            let mut backend = RustBackend::new(arch.clone());
            let cfg = KfacConfig {
                precond: p,
                lambda0: 10.0,
                refresh_async: false,
                ..Default::default()
            };
            let mut opt = Kfac::new(&arch, cfg);
            let first = {
                use crate::backend::ModelBackend;
                backend.loss(&params, &x, &y)
            };
            let mut last = f64::NAN;
            for _ in 0..30 {
                let info = opt.step(&mut backend, &mut params, &x, &y);
                last = info.loss;
                assert!(info.loss.is_finite());
                assert!(
                    info.model_value.unwrap() <= 1e-12,
                    "model value must be non-positive"
                );
            }
            assert!(last < first * 0.7, "{name}: first={first} last={last}");
        }
    }

    #[test]
    fn ekfac_trains_through_the_seam() {
        let (arch, mut params, x, y) = toy_problem(1);
        let mut backend = RustBackend::new(arch.clone());
        // t_scale = 2: the amortized scale re-estimation is active on
        // the training path, not just the default cadence
        let cfg =
            KfacConfig { lambda0: 10.0, t_scale: 2, refresh_async: false, ..KfacConfig::ekfac() };
        let mut opt = Kfac::new(&arch, cfg);
        let first = {
            use crate::backend::ModelBackend;
            backend.loss(&params, &x, &y)
        };
        let mut last = f64::NAN;
        for _ in 0..30 {
            let info = opt.step(&mut backend, &mut params, &x, &y);
            last = info.loss;
            assert!(info.loss.is_finite());
            assert!(info.model_value.unwrap() <= 1e-12);
        }
        assert!(last < first, "ekfac: first={first} last={last}");
    }

    #[test]
    fn momentum_reuses_previous_direction() {
        let (arch, mut params, x, y) = toy_problem(2);
        let mut backend = RustBackend::new(arch.clone());
        let mut opt = Kfac::new(&arch, KfacConfig { lambda0: 5.0, ..Default::default() });
        let i1 = opt.step(&mut backend, &mut params, &x, &y);
        assert_eq!(i1.mu, Some(0.0), "no momentum available on step 1");
        let i2 = opt.step(&mut backend, &mut params, &x, &y);
        // μ can be any finite value, but must have been solved (non-NaN).
        assert!(i2.mu.unwrap().is_finite());
    }

    #[test]
    fn lambda_adapts_with_rho() {
        let (arch, mut params, x, y) = toy_problem(3);
        let mut backend = RustBackend::new(arch.clone());
        let cfg = KfacConfig { lambda0: 1000.0, t1: 1, ..Default::default() };
        let om1 = cfg.omega1;
        let mut opt = Kfac::new(&arch, cfg);
        // With a huge λ the update is tiny and the quadratic model is
        // accurate, so ρ ≈ 1 > 3/4 and λ must decay.
        let info = opt.step(&mut backend, &mut params, &x, &y);
        assert!(info.rho.is_some());
        let lambda = info.lambda.unwrap();
        assert!(lambda <= 1000.0 * om1 + 1e-9, "lambda={lambda}");
    }

    #[test]
    fn gamma_adjusted_on_t2_boundary() {
        let (arch, mut params, x, y) = toy_problem(4);
        let mut backend = RustBackend::new(arch.clone());
        let cfg = KfacConfig {
            t2: 2,
            t_inv: 2,
            lambda0: 10.0,
            refresh_async: false,
            ..Default::default()
        };
        let mut opt = Kfac::new(&arch, cfg);
        let g0 = opt.gamma;
        opt.step(&mut backend, &mut params, &x, &y);
        let i2 = opt.step(&mut backend, &mut params, &x, &y);
        // on the T2 boundary gamma is re-selected from {γ, ω2γ, γ/ω2}
        let om2 = opt.cfg.omega2;
        let g2 = i2.gamma.unwrap();
        let choices = [g0, g0 * om2, g0 / om2];
        assert!(
            choices.iter().any(|c| (c - g2).abs() < 1e-12),
            "gamma {g2} not in {choices:?}"
        );
    }

    #[test]
    fn rescaling_never_worsens_model_value() {
        // M(αΔ) at optimal α is ≤ M(0) = 0 — the re-scaling of §6.4
        // guarantees a non-positive model value even with bad γ.
        let (arch, mut params, x, y) = toy_problem(5);
        let mut backend = RustBackend::new(arch.clone());
        let mut opt = Kfac::new(
            &arch,
            KfacConfig { lambda0: 0.01, refresh_async: false, ..KfacConfig::block_diag() },
        );
        for _ in 0..5 {
            let info = opt.step(&mut backend, &mut params, &x, &y);
            assert!(info.model_value.unwrap() <= 1e-12);
        }
    }

    #[test]
    fn state_roundtrip_resumes_identically() {
        // Snapshot mid-run, restore into a fresh optimizer, and check
        // that both continue on bit-identical trajectories.
        let (arch, mut params_a, x, y) = toy_problem(6);
        let mut backend = RustBackend::new(arch.clone());
        let cfg =
            KfacConfig { lambda0: 10.0, t_inv: 4, refresh_async: false, ..Default::default() };
        let mut opt_a = Kfac::new(&arch, cfg.clone());
        for _ in 0..7 {
            opt_a.step(&mut backend, &mut params_a, &x, &y);
        }
        let snapshot = opt_a.state();
        let mut params_b = params_a.clone();
        let mut opt_b = Kfac::new(&arch, cfg);
        opt_b.load_state(&snapshot).expect("state loads");
        for s in 0..5 {
            let ia = opt_a.step(&mut backend, &mut params_a, &x, &y);
            let ib = opt_b.step(&mut backend, &mut params_b, &x, &y);
            assert_eq!(ia.loss.to_bits(), ib.loss.to_bits(), "loss diverged at step {s}");
            assert_eq!(ia.lambda, ib.lambda, "lambda diverged at step {s}");
            assert_eq!(ia.gamma, ib.gamma, "gamma diverged at step {s}");
            assert!(params_a == params_b, "params diverged at step {s}");
        }
    }

    #[test]
    fn ekfac_scale_state_roundtrip_is_bit_exact() {
        // Snapshot mid-refresh-interval with live re-estimated scales;
        // the restored optimizer must continue bit-identically.
        let (arch, mut params_a, x, y) = toy_problem(8);
        let mut backend = RustBackend::new(arch.clone());
        let cfg = KfacConfig {
            lambda0: 10.0,
            t_inv: 6,
            t_scale: 2,
            refresh_async: false,
            ..KfacConfig::ekfac()
        };
        let mut opt_a = Kfac::new(&arch, cfg.clone());
        // scale refreshes at k = 2, 4, 6, 8; the rebuilds at k ≤ 3 and
        // k = 6 reset the epoch, so after k = 8 the live epoch holds
        // the EMA of the k = 6 and k = 8 estimates (sc.k = 2) and the
        // k = 9 snapshot lands mid-refresh-interval
        for _ in 0..9 {
            opt_a.step(&mut backend, &mut params_a, &x, &y);
        }
        let snapshot = opt_a.state();
        assert!(snapshot.mats("scale_s").is_some(), "scale state must checkpoint");
        assert!(snapshot.scalar("scale_k").is_some());
        let mut params_b = params_a.clone();
        let mut opt_b = Kfac::new(&arch, cfg);
        opt_b.load_state(&snapshot).expect("state loads");
        for s in 0..5 {
            let ia = opt_a.step(&mut backend, &mut params_a, &x, &y);
            let ib = opt_b.step(&mut backend, &mut params_b, &x, &y);
            assert_eq!(ia.loss.to_bits(), ib.loss.to_bits(), "loss diverged at step {s}");
            assert_eq!(ia.gamma, ib.gamma, "gamma diverged at step {s}");
            assert!(params_a == params_b, "params diverged at step {s}");
        }
    }

    #[test]
    fn scale_refresh_is_noop_for_structures_without_eigenbases() {
        // blktridiag has no re-estimable scales: with t_scale = 1 the
        // trajectory must match t_scale = 0 exactly.
        let run = |t_scale: usize| {
            let (arch, mut params, x, y) = toy_problem(9);
            let mut backend = RustBackend::new(arch.clone());
            let cfg = KfacConfig { lambda0: 10.0, t_scale, ..Default::default() };
            let mut opt = Kfac::new(&arch, cfg);
            for _ in 0..6 {
                opt.step(&mut backend, &mut params, &x, &y);
            }
            params
        };
        assert!(run(1) == run(0), "t_scale must not perturb blktridiag");
    }

    #[test]
    fn load_state_rejects_mismatches() {
        let (arch, _, _, _) = toy_problem(7);
        let mut opt = Kfac::new(&arch, KfacConfig::default());
        let mut wrong = OptState::new("sgd");
        wrong.set_scalar("t", 1.0);
        assert!(opt.load_state(&wrong).is_err(), "wrong kind must be rejected");
        let other_arch = Arch::new(
            vec![4, 3, 2],
            vec![Act::Tanh, Act::Identity],
            LossKind::SoftmaxCe,
        );
        let other = Kfac::new(&other_arch, KfacConfig::default()).state();
        assert!(opt.load_state(&other).is_err(), "wrong shapes must be rejected");
        let ek = Kfac::new(&arch, KfacConfig::ekfac()).state();
        let err = opt.load_state(&ek).unwrap_err();
        assert!(err.contains("preconditioner"), "wrong precond must be rejected: {err}");
    }

    #[test]
    fn async_steps_use_previous_epoch_until_swap() {
        // Staleness contract: bootstrap installs epochs 1..3 inline; a
        // t_inv boundary submits a background build and keeps stepping
        // on the previous epoch, which swaps in exactly at the *next*
        // boundary. With t_inv = 4 over 12 steps the per-step epoch
        // tags must be precisely this sequence — any other value would
        // mean a step observed a half-swapped or early-swapped inverse.
        let (arch, mut params, x, y) = toy_problem(10);
        let mut backend = RustBackend::new(arch.clone());
        let cfg =
            KfacConfig { lambda0: 10.0, t_inv: 4, refresh_async: true, ..Default::default() };
        let mut opt = Kfac::new(&arch, cfg);
        let mut epochs = Vec::new();
        for _ in 0..12 {
            let info = opt.step(&mut backend, &mut params, &x, &y);
            assert!(info.loss.is_finite());
            epochs.push(info.inv_epoch.expect("kfac tags every step"));
        }
        assert_eq!(epochs, vec![1, 2, 3, 3, 3, 3, 3, 4, 4, 4, 4, 5]);
        assert_eq!(opt.inverse_epoch(), 5);
    }

    #[test]
    fn async_state_preserves_stall_counter() {
        // Satellite audit of the async resume path: `refresh_stalls` and
        // `inv_epoch` travel through state()/load_state(), so a resumed
        // run's accounting matches the uninterrupted one. A pre-dist
        // checkpoint (no refresh_stalls key) deliberately restarts the
        // counter at zero.
        let (arch, mut params, x, y) = toy_problem(12);
        let mut backend = RustBackend::new(arch.clone());
        let cfg = KfacConfig { lambda0: 10.0, t_inv: 4, refresh_async: true, ..Default::default() };
        let mut opt = Kfac::new(&arch, cfg.clone());
        for _ in 0..9 {
            opt.step(&mut backend, &mut params, &x, &y);
        }
        let mut st = opt.state();
        assert!(st.scalar("inv_epoch").is_some(), "async snapshot records inv_epoch");
        let recorded = st.scalar("refresh_stalls").expect("async snapshot records refresh_stalls");
        assert_eq!(recorded as usize, opt.refresh_stalls());

        // Force a nonzero counter through the roundtrip.
        st.set_scalar("refresh_stalls", 3.0);
        let mut opt_b = Kfac::new(&arch, cfg.clone());
        opt_b.load_state(&st).expect("state loads");
        assert_eq!(opt_b.refresh_stalls(), 3);
        assert_eq!(opt_b.inverse_epoch(), opt.inverse_epoch());

        // Pre-dist snapshot: drop the key and confirm the documented zero.
        let mut st_old = opt.state();
        st_old.entries.remove("refresh_stalls");
        let mut opt_c = Kfac::new(&arch, cfg);
        opt_c.load_state(&st_old).expect("state loads");
        assert_eq!(opt_c.refresh_stalls(), 0);
    }

    #[test]
    fn async_trajectory_is_deterministic() {
        // The background build is deterministic in its snapshot and is
        // collected at a fixed boundary, so two async runs must agree
        // bit-for-bit — the invariant the mid-flight checkpoint resume
        // leans on.
        let run = || {
            let (arch, mut params, x, y) = toy_problem(11);
            let mut backend = RustBackend::new(arch.clone());
            let cfg =
                KfacConfig { lambda0: 10.0, t_inv: 3, refresh_async: true, ..Default::default() };
            let mut opt = Kfac::new(&arch, cfg);
            let mut losses = Vec::new();
            for _ in 0..10 {
                losses.push(opt.step(&mut backend, &mut params, &x, &y).loss.to_bits());
            }
            (params, losses)
        };
        let (pa, la) = run();
        let (pb, lb) = run();
        assert_eq!(la, lb, "loss trace must be bit-identical");
        assert!(pa == pb, "params must be bit-identical");
    }

    #[test]
    fn ekfac_async_scale_epoch_association() {
        // EKFAC t_scale re-estimation measures second moments in the
        // *installed* eigenbasis, so it must apply to that epoch: the
        // running scale state survives boundaries that merely submit a
        // build (k = 4, refreshes at k = 4 and 6 → scale_k = 2 after 7
        // steps) and resets when the swap actually lands (k = 8 install
        // precedes the re-seed → scale_k = 1 after 8 steps).
        let (arch, mut params, x, y) = toy_problem(12);
        let mut backend = RustBackend::new(arch.clone());
        let cfg = KfacConfig {
            lambda0: 10.0,
            t_inv: 4,
            t_scale: 2,
            refresh_async: true,
            ..KfacConfig::ekfac()
        };
        let mut opt = Kfac::new(&arch, cfg);
        for _ in 0..7 {
            opt.step(&mut backend, &mut params, &x, &y);
        }
        assert_eq!(opt.state().scalar("scale_k"), Some(2.0));
        assert_eq!(opt.inverse_epoch(), 3, "no swap yet: still the bootstrap epoch");
        opt.step(&mut backend, &mut params, &x, &y);
        assert_eq!(opt.inverse_epoch(), 4, "k = 8 installs the k = 4 build");
        assert_eq!(
            opt.state().scalar("scale_k"),
            Some(1.0),
            "swap resets the scale epoch; the k = 8 estimate re-seeds it"
        );
    }

    #[test]
    fn ikfac_zero_drift_threshold_matches_blkdiag_bitwise() {
        // With the drift trigger at 0 every incremental update declines,
        // so every boundary falls through to the full rebuild — whose
        // per-layer numerics (and apply formula) are identical to the
        // block-diagonal structure. The trajectories must agree to the
        // bit.
        let run = |pre: PrecondRef| {
            let (arch, mut params, x, y) = toy_problem(13);
            let mut backend = RustBackend::new(arch.clone());
            let cfg = KfacConfig {
                precond: pre,
                lambda0: 10.0,
                t_inv: 3,
                refresh_async: false,
                ..Default::default()
            };
            let mut opt = Kfac::new(&arch, cfg);
            let mut losses = Vec::new();
            for _ in 0..10 {
                losses.push(opt.step(&mut backend, &mut params, &x, &y).loss.to_bits());
            }
            (params, losses)
        };
        let (pa, la) = run(precond::block_diag());
        let (pb, lb) = run(Arc::new(crate::fisher::ikfac::IkfacPrecond::new(4, 0.0)));
        assert_eq!(la, lb, "loss trace must be bit-identical");
        assert!(pa == pb, "params must be bit-identical");
    }

    #[test]
    fn ikfac_incremental_update_state_roundtrip_is_bit_exact() {
        // A snapshot taken after the cached inverse absorbed incremental
        // updates must record them (checkpoint v4 keys) and restore to a
        // bit-identical trajectory: resume rebuilds the base from the
        // refresh record and replays the latest delta.
        let pre: PrecondRef = Arc::new(crate::fisher::ikfac::IkfacPrecond::new(4, 1e300));
        let (arch, mut params_a, x, y) = toy_problem(14);
        let mut backend = RustBackend::new(arch.clone());
        let cfg = KfacConfig {
            precond: pre,
            lambda0: 10.0,
            t_inv: 4,
            refresh_async: false,
            ..Default::default()
        };
        let mut opt_a = Kfac::new(&arch, cfg.clone());
        // boundaries at k = 4 and 8 engage the incremental hook (base
        // refresh is the k = 3 bootstrap build); k = 9 snapshots with a
        // live update record
        for _ in 0..9 {
            opt_a.step(&mut backend, &mut params_a, &x, &y);
        }
        assert!(opt_a.inverse_epoch() >= 5, "updates must advance the epoch tag");
        let snapshot = opt_a.state();
        assert!(snapshot.scalar("upd_gamma").is_some(), "update record must checkpoint");
        assert!(snapshot.mats("upd_aa").is_some());
        let mut params_b = params_a.clone();
        let mut opt_b = Kfac::new(&arch, cfg);
        opt_b.load_state(&snapshot).expect("state loads");
        for s in 0..5 {
            let ia = opt_a.step(&mut backend, &mut params_a, &x, &y);
            let ib = opt_b.step(&mut backend, &mut params_b, &x, &y);
            assert_eq!(ia.loss.to_bits(), ib.loss.to_bits(), "loss diverged at step {s}");
            assert_eq!(ia.gamma, ib.gamma, "gamma diverged at step {s}");
            assert!(params_a == params_b, "params diverged at step {s}");
        }
    }

    #[test]
    fn try_new_fences_unsupported_arch_at_construction() {
        use crate::linalg::pack::ConvShape;
        use crate::nn::Layer;
        let shape = ConvShape { in_h: 8, in_w: 8, in_c: 1, kh: 3, kw: 3, stride: 2, pad: 1 };
        let conv_arch = Arch::from_layers(
            vec![
                Layer::Conv2d { shape, out_c: 4, act: Act::Tanh },
                Layer::Dense { d_in: 64, d_out: 10, act: Act::Identity },
            ],
            LossKind::SoftmaxCe,
        );
        let err = Kfac::try_new(&conv_arch, KfacConfig::default())
            .err()
            .expect("default (blktridiag) config must be fenced on conv");
        assert!(err.contains("unsupported on conv architectures"), "message changed: {err}");
        assert!(Kfac::try_new(&conv_arch, KfacConfig::block_diag()).is_ok());
        let (dense_arch, _, _, _) = toy_problem(1);
        assert!(Kfac::try_new(&dense_arch, KfacConfig::default()).is_ok());
    }
}
