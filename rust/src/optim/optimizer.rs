//! The open optimizer interface.
//!
//! [`Optimizer`] is the seam the training coordinator drives: one
//! `step` per mini-batch, a unified [`StepInfo`] diagnostic record, and
//! a structured [`OptState`] snapshot for checkpoint save/resume. Both
//! [`Kfac`](crate::optim::Kfac) and [`Sgd`](crate::optim::Sgd)
//! implement it, and downstream crates can plug in their own
//! optimizers without touching the coordinator.

use crate::backend::ModelBackend;
use crate::linalg::Mat;
use crate::nn::Params;
use std::collections::BTreeMap;

/// Per-step diagnostics, unified across optimizers.
///
/// `loss` is always present (the regularized mini-batch objective at
/// the pre-step parameters). Everything else is optional: an optimizer
/// reports the quantities it actually computes (K-FAC fills in λ/γ/α/μ
/// and the quadratic-model value; SGD only its momentum coefficient),
/// and consumers must not assume more than `loss`.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepInfo {
    /// Regularized objective h(θ) on the mini-batch (before the step).
    pub loss: f64,
    /// Quadratic-model value M(δ) (negative ⇒ predicted decrease).
    pub model_value: Option<f64>,
    /// Chosen re-scaling coefficient α.
    pub alpha: Option<f64>,
    /// Momentum coefficient μ.
    pub mu: Option<f64>,
    /// Damping λ after any adaptation this step.
    pub lambda: Option<f64>,
    /// Factored-Tikhonov strength γ after any adaptation this step.
    pub gamma: Option<f64>,
    /// Reduction ratio ρ (only on iterations where it is evaluated).
    pub rho: Option<f64>,
    /// Update norm ‖δ‖₂.
    pub delta_norm: Option<f64>,
    /// Epoch tag of the approximate Fisher inverse the step's proposal
    /// was preconditioned with (K-FAC only; increments on every install,
    /// so an asynchronous refresh in flight leaves this at the previous
    /// epoch until its swap completes).
    pub inv_epoch: Option<usize>,
}

impl StepInfo {
    /// A record carrying only the loss.
    pub fn with_loss(loss: f64) -> StepInfo {
        StepInfo { loss, ..Default::default() }
    }
}

/// One value in an optimizer state snapshot.
#[derive(Clone, Debug, PartialEq)]
pub enum StateVal {
    Scalar(f64),
    Str(String),
    Mats(Vec<Mat>),
}

/// A structured, serializable snapshot of an optimizer's full mutable
/// state (the checkpoint payload). Deliberately schema-free — a tagged
/// key/value tree — so new optimizers can checkpoint without touching
/// the serialization layer.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct OptState {
    /// Which optimizer produced this state (e.g. `"kfac"`, `"sgd"`).
    pub kind: String,
    /// Named state entries, sorted for stable serialization.
    pub entries: BTreeMap<String, StateVal>,
}

impl OptState {
    pub fn new(kind: &str) -> OptState {
        OptState { kind: kind.to_string(), entries: BTreeMap::new() }
    }

    pub fn set_scalar(&mut self, key: &str, v: f64) {
        self.entries.insert(key.to_string(), StateVal::Scalar(v));
    }

    pub fn set_str(&mut self, key: &str, v: &str) {
        self.entries.insert(key.to_string(), StateVal::Str(v.to_string()));
    }

    pub fn set_mats(&mut self, key: &str, v: Vec<Mat>) {
        self.entries.insert(key.to_string(), StateVal::Mats(v));
    }

    pub fn scalar(&self, key: &str) -> Option<f64> {
        match self.entries.get(key) {
            Some(StateVal::Scalar(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn str_val(&self, key: &str) -> Option<&str> {
        match self.entries.get(key) {
            Some(StateVal::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn mats(&self, key: &str) -> Option<&[Mat]> {
        match self.entries.get(key) {
            Some(StateVal::Mats(v)) => Some(v),
            _ => None,
        }
    }

    /// Fetch a required scalar with a descriptive error.
    pub fn require_scalar(&self, key: &str) -> Result<f64, String> {
        self.scalar(key).ok_or_else(|| format!("{} state: missing scalar '{key}'", self.kind))
    }

    /// Fetch a required matrix list with a descriptive error.
    pub fn require_mats(&self, key: &str) -> Result<&[Mat], String> {
        self.mats(key).ok_or_else(|| format!("{} state: missing mats '{key}'", self.kind))
    }

    /// Fetch a required string with a descriptive error.
    pub fn require_str(&self, key: &str) -> Result<&str, String> {
        self.str_val(key).ok_or_else(|| format!("{} state: missing string '{key}'", self.kind))
    }
}

/// Check that a restored matrix list matches expected (rows, cols)
/// dimensions without materializing reference matrices.
pub fn check_dims(
    name: &str,
    got: &[Mat],
    want: impl ExactSizeIterator<Item = (usize, usize)>,
) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{name}: expected {} matrices, got {}", want.len(), got.len()));
    }
    for (i, (g, (rows, cols))) in got.iter().zip(want).enumerate() {
        if (g.rows, g.cols) != (rows, cols) {
            return Err(format!(
                "{name}[{i}]: expected {rows}x{cols}, got {}x{}",
                g.rows, g.cols
            ));
        }
    }
    Ok(())
}

/// Check that a restored matrix list matches the expected shapes.
pub fn check_mat_shapes(name: &str, got: &[Mat], want: &[Mat]) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{name}: expected {} matrices, got {}", want.len(), got.len()));
    }
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        if (g.rows, g.cols) != (w.rows, w.cols) {
            return Err(format!(
                "{name}[{i}]: expected {}x{}, got {}x{}",
                w.rows, w.cols, g.rows, g.cols
            ));
        }
    }
    Ok(())
}

/// A stochastic optimizer the training coordinator can drive.
///
/// Implementations own all their mutable state (iteration counters,
/// damping, momentum buffers, curvature estimates) and mutate `params`
/// in place once per `step`.
pub trait Optimizer {
    /// Short identifier for logs, registries and checkpoint headers.
    fn name(&self) -> &str;

    /// One iteration on mini-batch `(x, y)`. Mutates `params`.
    fn step(
        &mut self,
        backend: &mut dyn ModelBackend,
        params: &mut Params,
        x: &Mat,
        y: &Mat,
    ) -> StepInfo;

    /// Snapshot the full mutable state for checkpointing.
    fn state(&self) -> OptState;

    /// Restore from a snapshot taken by [`Optimizer::state`] on an
    /// optimizer constructed with the same configuration. Must restore
    /// *everything* the trajectory depends on (resume is bit-exact).
    fn load_state(&mut self, state: &OptState) -> Result<(), String>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optstate_roundtrips_values() {
        let mut st = OptState::new("test");
        st.set_scalar("a", 1.5);
        st.set_str("s", "blkdiag");
        st.set_mats("m", vec![Mat::eye(2)]);
        assert_eq!(st.scalar("a"), Some(1.5));
        assert_eq!(st.scalar("missing"), None);
        assert_eq!(st.str_val("s"), Some("blkdiag"));
        assert_eq!(st.mats("m").unwrap().len(), 1);
        assert!(st.require_scalar("a").is_ok());
        assert!(st.require_scalar("b").is_err());
        assert!(st.require_mats("a").is_err(), "scalar is not a mat list");
        assert!(st.require_str("a").is_err(), "scalar is not a string");
    }

    #[test]
    fn dims_check_catches_mismatches() {
        let got = vec![Mat::zeros(2, 3)];
        assert!(check_dims("x", &got, [(2usize, 3usize)].into_iter()).is_ok());
        assert!(check_dims("x", &got, [(3usize, 2usize)].into_iter()).is_err());
        assert!(check_dims("x", &got, std::iter::empty()).is_err());
    }

    #[test]
    fn shape_check_catches_mismatches() {
        let a = vec![Mat::zeros(2, 3)];
        let b = vec![Mat::zeros(2, 3)];
        let c = vec![Mat::zeros(3, 2)];
        assert!(check_mat_shapes("x", &a, &b).is_ok());
        assert!(check_mat_shapes("x", &a, &c).is_err());
        assert!(check_mat_shapes("x", &a, &[]).is_err());
    }

    #[test]
    fn stepinfo_defaults_are_absent() {
        let i = StepInfo::with_loss(2.0);
        assert_eq!(i.loss, 2.0);
        assert!(i.lambda.is_none() && i.rho.is_none() && i.delta_norm.is_none());
    }
}
