//! Benchmarks for the Fisher-approximation operations on a
//! paper-scale architecture (the MNIST autoencoder): statistics
//! computation, inverse refresh (task 5), preconditioner application
//! (task 6) for both structures, and the EKFAC amortized scale-refresh
//! path (per-example gradient projection + diagonal swap).

use kfac::backend::{ModelBackend, RustBackend};
use kfac::bench::{bench, default_budget};
use kfac::coordinator::Problem;
use kfac::fisher::stats::KfacStats;
use kfac::fisher::{BlockDiagInverse, EkfacInverse, FisherInverse, TridiagInverse};
use kfac::linalg::{KronBasis, SymEig};
use kfac::rng::Rng;

fn main() {
    let budget = default_budget();
    let problem = Problem::MnistAe;
    let arch = problem.arch();
    println!("arch {:?} ({} params)", arch.widths, arch.num_params());
    let ds = problem.dataset(256, 0);
    let mut backend = RustBackend::new(arch.clone());
    let params = arch.sparse_init(&mut Rng::new(1));
    let (x, y) = (ds.x.clone(), ds.y.clone());

    bench("grad_and_stats_m256", budget, || {
        std::hint::black_box(backend.grad_and_stats(&params, &x, &y, 32, 7));
    });

    let (_, grad, raw) = backend.grad_and_stats(&params, &x, &y, 256, 7);
    let mut stats = KfacStats::new(&arch);
    stats.update(&raw);
    let gamma = 1.0;

    // One eigendecomposition of a real (damped) activation factor — the
    // unit of work the blocked eigensolver threads inside every
    // tridiag/EKFAC refresh. Pick the factor closest to 256 wide so the
    // number is comparable to the sym_eig_256 linalg bench.
    let aa = &stats.s.aa;
    let (fi, _) = aa
        .iter()
        .enumerate()
        .min_by_key(|(_, m)| (m.rows as i64 - 256).unsigned_abs())
        .expect("at least one layer");
    let factor = aa[fi].add_diag(1.0);
    bench(&format!("sym_eig_factor_{}(mnist_ae)", factor.rows), budget, || {
        std::hint::black_box(SymEig::new(&factor));
    });

    bench("blockdiag_build(mnist_ae)", budget, || {
        std::hint::black_box(BlockDiagInverse::build(&stats.s, gamma));
    });
    bench("tridiag_build(mnist_ae)", budget, || {
        std::hint::black_box(TridiagInverse::build(&stats.s, gamma));
    });
    bench("ekfac_build(mnist_ae)", budget, || {
        std::hint::black_box(EkfacInverse::build(&stats.s, gamma));
    });

    let bd = BlockDiagInverse::build(&stats.s, gamma);
    let tri = TridiagInverse::build(&stats.s, gamma);
    let ek = EkfacInverse::build(&stats.s, gamma);
    bench("blockdiag_apply(mnist_ae)", budget, || {
        std::hint::black_box(bd.apply(&grad));
    });
    bench("tridiag_apply(mnist_ae)", budget, || {
        std::hint::black_box(tri.apply(&grad));
    });
    bench("ekfac_apply(mnist_ae)", budget, || {
        std::hint::black_box(ek.apply(&grad));
    });

    bench("fvp_quad_2dirs_m64", budget, || {
        let d2 = grad.scale(0.5);
        std::hint::black_box(backend.fvp_quad(&params, &x, 64, &[&grad, &d2]));
    });

    // EKFAC amortized scale refresh: project per-example gradients into
    // the cached eigenbasis (one forward + sampled backward + two
    // squared GEMMs per layer), then swap the diagonal in.
    let bases: Vec<KronBasis> = ek.eigenbases().expect("ekfac exposes bases").to_vec();
    bench("ekfac_grad_sq_in_basis_m32", budget, || {
        std::hint::black_box(backend.grad_sq_in_basis(&params, &x, &y, 32, 7, &bases));
    });
    let sq = backend.grad_sq_in_basis(&params, &x, &y, 32, 7, &bases);
    let mut ek_refresh = EkfacInverse::build(&stats.s, gamma);
    bench("ekfac_set_scales(mnist_ae)", budget, || {
        std::hint::black_box(ek_refresh.set_scales(&sq, gamma));
    });
}
