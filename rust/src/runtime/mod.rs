//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects, while the text parser reassigns ids and round-trips
//! cleanly. Programs are compiled once at startup and cached; the
//! training loop then only does literal transfer + execute — Python is
//! never on the request path.

pub mod artifact;
pub mod exec;

pub use artifact::{ArchManifest, Manifest};
pub use exec::{literal_scalar_f64, literal_to_mat, mat_to_literal, Program};
