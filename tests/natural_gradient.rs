//! K-FAC vs the exact natural gradient.
//!
//! 1. For a single **linear** layer with Gaussian output, `g = dz` is
//!    independent of `ā`, so the Kronecker factorization is *exact*:
//!    `F = Ā ⊗ I`, and the block-diagonal K-FAC direction must equal the
//!    exact natural gradient `F⁻¹∇h`.
//! 2. For a deep nonlinear network the approximation is inexact, but the
//!    tridiagonal inverse must approximate `F̃⁻¹` better than the
//!    block-diagonal one (the paper's Figure 6 finding).

use kfac::fisher::exact::ExactBlocks;
use kfac::fisher::stats::RawStats;
use kfac::fisher::{BlockDiagInverse, FisherInverse, TridiagInverse};
use kfac::linalg::kron::{unvec, vec_mat};
use kfac::linalg::Mat;
use kfac::nn::net::Net;
use kfac::nn::{Act, Arch, LossKind, Params};
use kfac::rng::Rng;

fn exact_stats(net: &Net, params: &Params, x: &Mat) -> RawStats {
    let l = net.arch.num_layers();
    let eb = ExactBlocks::compute(net, params, x, 0, l);
    let mut st = RawStats::zeros(&net.arch);
    for i in 0..l {
        st.aa[i] = eb.aa[i][i].clone();
        st.gg[i] = eb.gg[i][i].clone();
    }
    for i in 0..l - 1 {
        st.aa_off[i] = eb.aa[i][i + 1].clone();
        st.gg_off[i] = eb.gg[i][i + 1].clone();
    }
    st
}

#[test]
fn single_linear_layer_kfac_equals_exact_natural_gradient() {
    let arch = Arch::new(vec![6, 4], vec![Act::Identity], LossKind::SquaredError);
    let net = Net::new(arch.clone());
    let mut rng = Rng::new(1);
    let params = arch.glorot_init(&mut rng);
    let x = Mat::randn(80, 6, 1.0, &mut rng);
    let y = Mat::randn(80, 4, 1.0, &mut rng);
    let (_, grad) = net.loss_and_grad(&params, &x, &y);

    // exact natural gradient via dense exact Fisher
    let eb = ExactBlocks::compute(&net, &params, &x, 0, 1);
    let f = eb.f.add_diag(1e-9);
    let ng = unvec(
        &f.inverse().matvec(&vec_mat(&grad.0[0])),
        grad.0[0].rows,
        grad.0[0].cols,
    );

    // K-FAC block-diagonal with exact stats, γ = 0
    let st = exact_stats(&net, &params, &x);
    let delta = BlockDiagInverse::build(&st, 0.0).apply(&grad);
    let err = delta.0[0].sub(&ng).max_abs() / ng.max_abs();
    assert!(err < 1e-5, "kfac != exact natural gradient: rel err {err}");
}

#[test]
fn tridiag_inverse_closer_to_ktilde_inverse_than_blockdiag() {
    // Deep tanh classifier; compare ‖F₀⁻¹ − F̃⁻¹‖_F for both structures
    // (the quantity Figure 6 visualizes), with the same damping γ.
    let arch = Arch::new(
        vec![8, 6, 5, 4],
        vec![Act::Tanh, Act::Tanh, Act::Identity],
        LossKind::SoftmaxCe,
    );
    let net = Net::new(arch.clone());
    let mut rng = Rng::new(2);
    let params = arch.glorot_init(&mut rng);
    let x = Mat::randn(120, 8, 1.0, &mut rng);
    let eb = ExactBlocks::compute(&net, &params, &x, 0, 3);
    let gamma = 0.05;
    let ktilde_inv = eb.ktilde_damped_dense(gamma).inverse();
    let fcheck_inv = eb.fcheck_dense(gamma).inverse();
    let fhat_inv = eb.fhat_inv_dense(gamma);
    let err_check = fcheck_inv.sub(&ktilde_inv).frob_norm();
    let err_hat = fhat_inv.sub(&ktilde_inv).frob_norm();
    assert!(
        err_hat < err_check,
        "tridiag ({err_hat}) should beat blockdiag ({err_check})"
    );
}

#[test]
fn structured_tridiag_apply_matches_dense_on_real_network() {
    // The optimizer's structured ΞᵀΛΞ apply vs the dense F̂⁻¹ formula,
    // with damping, on a nonlinear network's exact statistics.
    let arch = Arch::new(
        vec![7, 5, 4, 3],
        vec![Act::Tanh, Act::Tanh, Act::Identity],
        LossKind::SoftmaxCe,
    );
    let net = Net::new(arch.clone());
    let mut rng = Rng::new(3);
    let params = arch.glorot_init(&mut rng);
    let x = Mat::randn(100, 7, 1.0, &mut rng);
    let st = exact_stats(&net, &params, &x);
    let eb = ExactBlocks::compute(&net, &params, &x, 0, 3);
    let gamma = 0.1;
    let tri = TridiagInverse::build(&st, gamma);
    let dense = eb.fhat_inv_dense(gamma);
    let (_, grad) = {
        let y = {
            let mut y = Mat::zeros(100, 3);
            for r in 0..100 {
                y.set(r, r % 3, 1.0);
            }
            y
        };
        net.loss_and_grad(&params, &x, &y)
    };
    let got = tri.apply(&grad);
    // dense apply
    let total: usize = eb.sizes.iter().sum();
    let mut v = vec![0.0; total];
    for (bi, w) in grad.0.iter().enumerate() {
        let vb = vec_mat(w);
        v[eb.offs[bi]..eb.offs[bi] + vb.len()].copy_from_slice(&vb);
    }
    let uv = dense.matvec(&v);
    for i in 0..3 {
        let (r, c) = (grad.0[i].rows, grad.0[i].cols);
        let want = unvec(&uv[eb.offs[i]..eb.offs[i] + r * c], r, c);
        let rel = got.0[i].sub(&want).max_abs() / want.max_abs().max(1e-12);
        assert!(rel < 1e-5, "block {i} rel err {rel}");
    }
}
