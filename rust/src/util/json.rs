//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! Supports objects, arrays, strings (with the common escapes), numbers,
//! booleans and null. No external dependencies; errors carry byte
//! offsets for debuggability.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Helper: array of usize.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    /// Helper: array of strings.
    pub fn str_vec(&self) -> Option<Vec<String>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    s.push_str(
                        std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                            .map_err(|_| "bad utf8")?,
                    );
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "version": 1,
            "archs": [
                {"name": "tiny_ae", "widths": [8, 4, 8], "acts": ["tanh", "identity"],
                 "loss": "sigmoid_ce", "chunk": 16,
                 "programs": {"grad": "tiny_ae/grad.hlo.txt"}}
            ],
            "note": "hello \"world\"\n"
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let archs = j.get("archs").unwrap().as_arr().unwrap();
        let a0 = &archs[0];
        assert_eq!(a0.get("name").unwrap().as_str(), Some("tiny_ae"));
        assert_eq!(a0.get("widths").unwrap().usize_vec(), Some(vec![8, 4, 8]));
        assert_eq!(
            a0.get("acts").unwrap().str_vec(),
            Some(vec!["tanh".to_string(), "identity".to_string()])
        );
        assert_eq!(
            a0.get("programs").unwrap().get("grad").unwrap().as_str(),
            Some("tiny_ae/grad.hlo.txt")
        );
        assert_eq!(j.get("note").unwrap().as_str(), Some("hello \"world\"\n"));
    }

    #[test]
    fn numbers_and_literals() {
        let j = Json::parse(r#"[1, -2.5, 3e2, true, false, null]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2.5));
        assert_eq!(a[2].as_f64(), Some(300.0));
        assert_eq!(a[3], Json::Bool(true));
        assert_eq!(a[5], Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
    }
}
