//! Factored Tikhonov damping (paper Section 6.3).
//!
//! Instead of adding `(λ+η)I` to each Kronecker block `Ā ⊗ G` (which
//! would break the `(A⊗B)⁻¹ = A⁻¹⊗B⁻¹` identity), the paper adds
//! `π_i γ I` to `Ā_{i-1,i-1}` and `(γ/π_i) I` to `G_{i,i}`, choosing
//!
//! `π_i = sqrt( (tr Ā/(d_{i-1}+1)) / (tr G/d_i) )`
//!
//! (average eigenvalue ratio — the trace-norm minimizer of the residual
//! bound). The damped product then differs from the exact Tikhonov
//! expression only by a residual whose norm the choice of π minimizes.

use crate::linalg::Mat;

/// Trace-norm `π` (ratio of average eigenvalues), with a guard for
/// degenerate (zero/singular) factors.
pub fn pi_trace(aa: &Mat, gg: &Mat) -> f64 {
    let num = aa.trace() / aa.rows as f64;
    let den = gg.trace() / gg.rows as f64;
    if !(num > 0.0) || !(den > 0.0) {
        return 1.0;
    }
    let pi = (num / den).sqrt();
    if pi.is_finite() && pi > 0.0 {
        pi
    } else {
        1.0
    }
}

/// Damped factor pair `(Ā + πγI, G + (γ/π)I)`.
pub fn damped_factors(aa: &Mat, gg: &Mat, gamma: f64) -> (Mat, Mat) {
    let pi = pi_trace(aa, gg);
    (aa.add_diag(pi * gamma), gg.add_diag(gamma / pi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kron::kron;
    use crate::rng::Rng;

    fn random_psd(n: usize, rng: &mut Rng) -> Mat {
        let x = Mat::randn(n + 2, n, 1.0, rng);
        x.matmul_tn(&x).scale(1.0 / n as f64)
    }

    #[test]
    fn pi_is_average_eigenvalue_ratio() {
        let aa = Mat::eye(4).scale(9.0);
        let gg = Mat::eye(3).scale(1.0);
        assert!((pi_trace(&aa, &gg) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pi_guards_degenerate() {
        let z = Mat::zeros(3, 3);
        let g = Mat::eye(2);
        assert_eq!(pi_trace(&z, &g), 1.0);
        assert_eq!(pi_trace(&g, &z), 1.0);
    }

    #[test]
    fn damped_product_close_to_exact_tikhonov() {
        // The residual between (Ā+πγI)⊗(G+γ/πI) and Ā⊗G + γ²I should be
        // the cross terms; sanity-check the factored version dominates
        // the exact one (PSD ordering along random directions).
        let mut rng = Rng::new(1);
        let aa = random_psd(4, &mut rng).add_diag(0.1);
        let gg = random_psd(3, &mut rng).add_diag(0.1);
        let gamma = 0.5;
        let (ad, gd) = damped_factors(&aa, &gg, gamma);
        let fact = kron(&ad, &gd);
        let exact = kron(&aa, &gg).add_diag(gamma * gamma);
        // factored = exact + π γ I⊗G + γ/π Ā⊗I  (both PSD), so
        // fact − exact must be PSD.
        let diff = fact.sub(&exact);
        for _ in 0..10 {
            let v: Vec<f64> = (0..diff.rows).map(|_| rng.normal()).collect();
            let dv = diff.matvec(&v);
            let q: f64 = v.iter().zip(dv.iter()).map(|(a, b)| a * b).sum();
            assert!(q >= -1e-10, "q={q}");
        }
    }

    #[test]
    fn scale_invariance_of_factored_damping() {
        // Rescaling Ā by c and G by 1/c leaves Ā⊗G unchanged; the
        // factored damping with trace-π must produce the same damped
        // product (this is the reparameterization-invariance property
        // that makes the trace norm a good choice).
        let mut rng = Rng::new(2);
        let aa = random_psd(4, &mut rng).add_diag(0.2);
        let gg = random_psd(3, &mut rng).add_diag(0.2);
        let gamma = 0.3;
        let (ad1, gd1) = damped_factors(&aa, &gg, gamma);
        let c = 7.0;
        let (ad2, gd2) = damped_factors(&aa.scale(c), &gg.scale(1.0 / c), gamma);
        let p1 = kron(&ad1, &gd1);
        let p2 = kron(&ad2, &gd2);
        assert!(p1.sub(&p2).max_abs() < 1e-9 * (1.0 + p1.max_abs()));
    }
}
